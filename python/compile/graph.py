"""Serializable model graph IR shared between the JAX build path and DeepliteRT.

Models are defined **once** as graph programs (see ``models/``); the same
graph is

* executed in JAX (``jax_exec.py``) for QAT training, golden outputs and
  AOT lowering, and
* serialized to ``arch.json`` + ``weights.bin`` (``export.py``) for the Rust
  ``dlrt compile`` pass, which quantizes/packs it into a ``.dlrt`` binary.

Supported ops mirror ``rust/src/dlrt/graph.rs`` exactly:

    conv2d       attrs: stride, padding, qcfg (w_bits, a_bits, enabled)
                 weights: w (HWIO), optional b (O); BN is folded at export
    dense        weights: w (IN,OUT), optional b
    maxpool2d    attrs: kernel, stride, padding
    global_avg_pool
    add | concat (concat: axis = channel)
    upsample2x   (nearest)
    relu | relu6 | silu | leaky_relu(0.1) | sigmoid
    flatten
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

OPS = {
    "conv2d", "dense", "maxpool2d", "global_avg_pool", "add", "concat",
    "upsample2x", "relu", "relu6", "silu", "leaky_relu", "sigmoid", "flatten",
}


@dataclass
class QCfg:
    """Per-conv quantization config (the mixed-precision knob)."""

    w_bits: int = 2
    a_bits: int = 2
    enabled: bool = True

    @property
    def tag(self) -> str:
        return f"{self.a_bits}A{self.w_bits}W" if self.enabled else "FP32"

    def to_json(self) -> dict:
        return {"w_bits": self.w_bits, "a_bits": self.a_bits, "enabled": self.enabled}


FP32 = QCfg(enabled=False)


@dataclass
class Node:
    op: str
    name: str
    inputs: list[str]
    output: str
    attrs: dict[str, Any] = field(default_factory=dict)
    # weight tensor names owned by this node, e.g. {"w": "conv1.w", "b": "conv1.b"}
    weights: dict[str, str] = field(default_factory=dict)


@dataclass
class Graph:
    name: str
    input_name: str
    input_shape: tuple[int, int, int, int]  # NHWC
    nodes: list[Node] = field(default_factory=list)
    outputs: list[str] = field(default_factory=list)

    def conv_nodes(self) -> list[Node]:
        return [n for n in self.nodes if n.op == "conv2d"]

    def validate(self) -> None:
        """Every input must be produced before use; output names unique."""
        avail = {self.input_name}
        for n in self.nodes:
            if n.op not in OPS:
                raise ValueError(f"unknown op {n.op!r} in node {n.name}")
            for i in n.inputs:
                if i not in avail:
                    raise ValueError(f"node {n.name} reads undefined tensor {i!r}")
            if n.output in avail:
                raise ValueError(f"tensor {n.output!r} defined twice")
            avail.add(n.output)
        for o in self.outputs:
            if o not in avail:
                raise ValueError(f"graph output {o!r} undefined")
        if not self.outputs:
            raise ValueError("graph has no outputs")


class GraphBuilder:
    """Tiny DSL for writing model definitions.

    All ``conv`` calls create *folded* conv nodes (bias absorbs BN at export
    time); during QAT the JAX executor keeps separate BN state keyed off the
    node name (see ``jax_exec.py``).
    """

    def __init__(self, name: str, input_shape: tuple[int, int, int, int],
                 input_name: str = "input"):
        self.g = Graph(name=name, input_name=input_name, input_shape=input_shape)
        self._uid = 0
        self._channels: dict[str, int] = {input_name: input_shape[3]}

    def _fresh(self, prefix: str) -> str:
        self._uid += 1
        return f"{prefix}_{self._uid}"

    def channels(self, t: str) -> int:
        return self._channels[t]

    def conv(self, x: str, cout: int, k: int = 3, stride: int = 1,
             padding: int | None = None, qcfg: QCfg | None = None,
             bn: bool = True, act: str | None = None, name: str | None = None) -> str:
        """conv2d(+folded BN)+optional activation. Returns output tensor name."""
        name = name or self._fresh("conv")
        pad = padding if padding is not None else k // 2
        cin = self._channels[x]
        out = f"{name}.out"
        node = Node(
            op="conv2d", name=name, inputs=[x], output=out,
            attrs={
                "stride": [stride, stride], "padding": [pad, pad],
                "kernel": [k, k], "cin": cin, "cout": cout,
                "qcfg": (qcfg or FP32), "bn": bn,
            },
            weights={"w": f"{name}.w", "b": f"{name}.b"},
        )
        self.g.nodes.append(node)
        self._channels[out] = cout
        if act:
            out = self.act(out, act, name=f"{name}.{act}")
        return out

    def act(self, x: str, kind: str, name: str | None = None) -> str:
        assert kind in {"relu", "relu6", "silu", "leaky_relu", "sigmoid"}
        name = name or self._fresh(kind)
        out = f"{name}.out"
        self.g.nodes.append(Node(op=kind, name=name, inputs=[x], output=out))
        self._channels[out] = self._channels[x]
        return out

    def maxpool(self, x: str, k: int = 2, stride: int | None = None,
                padding: int = 0, name: str | None = None) -> str:
        name = name or self._fresh("maxpool")
        out = f"{name}.out"
        self.g.nodes.append(Node(
            op="maxpool2d", name=name, inputs=[x], output=out,
            attrs={"kernel": [k, k], "stride": [stride or k, stride or k],
                   "padding": [padding, padding]},
        ))
        self._channels[out] = self._channels[x]
        return out

    def global_avg_pool(self, x: str, name: str | None = None) -> str:
        name = name or self._fresh("gap")
        out = f"{name}.out"
        self.g.nodes.append(Node(op="global_avg_pool", name=name, inputs=[x], output=out))
        self._channels[out] = self._channels[x]
        return out

    def add(self, a: str, b: str, name: str | None = None) -> str:
        name = name or self._fresh("add")
        out = f"{name}.out"
        self.g.nodes.append(Node(op="add", name=name, inputs=[a, b], output=out))
        self._channels[out] = self._channels[a]
        return out

    def concat(self, xs: list[str], name: str | None = None) -> str:
        name = name or self._fresh("concat")
        out = f"{name}.out"
        self.g.nodes.append(Node(op="concat", name=name, inputs=list(xs), output=out))
        self._channels[out] = sum(self._channels[x] for x in xs)
        return out

    def upsample2x(self, x: str, name: str | None = None) -> str:
        name = name or self._fresh("up")
        out = f"{name}.out"
        self.g.nodes.append(Node(op="upsample2x", name=name, inputs=[x], output=out))
        self._channels[out] = self._channels[x]
        return out

    def flatten(self, x: str, name: str | None = None) -> str:
        name = name or self._fresh("flatten")
        out = f"{name}.out"
        self.g.nodes.append(Node(op="flatten", name=name, inputs=[x], output=out))
        return out

    def dense(self, x: str, cout: int, cin: int, name: str | None = None) -> str:
        name = name or self._fresh("dense")
        out = f"{name}.out"
        self.g.nodes.append(Node(
            op="dense", name=name, inputs=[x], output=out,
            attrs={"cin": cin, "cout": cout},
            weights={"w": f"{name}.w", "b": f"{name}.b"},
        ))
        return out

    def finish(self, outputs: list[str]) -> Graph:
        self.g.outputs = list(outputs)
        self.g.validate()
        return self.g


def set_mixed_precision(g: Graph, quantize_from: int = 1, quantize_to: int | None = None,
                        w_bits: int = 2, a_bits: int = 2) -> Graph:
    """Apply the paper's 'conservative' mixed-precision policy in place.

    Convs with index in [quantize_from, quantize_to) get (a_bits, w_bits);
    the rest stay FP32. The paper keeps the first conv (and detection-
    sensitive layers) in FP32.
    """
    convs = g.conv_nodes()
    hi = len(convs) if quantize_to is None else quantize_to
    for idx, n in enumerate(convs):
        if quantize_from <= idx < hi:
            n.attrs["qcfg"] = QCfg(w_bits=w_bits, a_bits=a_bits, enabled=True)
        else:
            n.attrs["qcfg"] = QCfg(enabled=False)
    return g

"""ResNet-18/50 (He et al.) as graph-IR programs.

Faithful to the torchvision topology (7x7/2 stem, maxpool, 4 stages,
global-avg-pool + fc), with two reproduction knobs:

* ``width_mult`` / ``resolution`` — scale the network for the synthetic
  accuracy experiments (e.g. the VWW stand-in trains a width/4 model), while
  latency benches use the full architecture.
* per-conv ``QCfg`` via :func:`compile.graph.set_mixed_precision` — the
  paper's policy quantizes everything except the stem conv and keeps the fc
  in FP32.
"""

from __future__ import annotations

from ..graph import Graph, GraphBuilder, QCfg


def _basic_block(b: GraphBuilder, x: str, cout: int, stride: int, name: str) -> str:
    identity = x
    y = b.conv(x, cout, k=3, stride=stride, act="relu", name=f"{name}.conv1")
    y = b.conv(y, cout, k=3, stride=1, name=f"{name}.conv2")
    if stride != 1 or b.channels(identity) != cout:
        identity = b.conv(identity, cout, k=1, stride=stride, padding=0,
                          name=f"{name}.down")
    y = b.add(y, identity, name=f"{name}.add")
    return b.act(y, "relu", name=f"{name}.relu")


def _bottleneck(b: GraphBuilder, x: str, cmid: int, stride: int, name: str) -> str:
    cout = cmid * 4
    identity = x
    y = b.conv(x, cmid, k=1, stride=1, padding=0, act="relu", name=f"{name}.conv1")
    y = b.conv(y, cmid, k=3, stride=stride, act="relu", name=f"{name}.conv2")
    y = b.conv(y, cout, k=1, stride=1, padding=0, name=f"{name}.conv3")
    if stride != 1 or b.channels(identity) != cout:
        identity = b.conv(identity, cout, k=1, stride=stride, padding=0,
                          name=f"{name}.down")
    y = b.add(y, identity, name=f"{name}.add")
    return b.act(y, "relu", name=f"{name}.relu")


def build_resnet(depth: int = 18, num_classes: int = 1000, resolution: int = 224,
                 width_mult: float = 1.0, batch: int = 1) -> Graph:
    if depth == 18:
        blocks, fn, expansion = [2, 2, 2, 2], _basic_block, 1
    elif depth == 50:
        blocks, fn, expansion = [3, 4, 6, 3], _bottleneck, 4
    else:
        raise ValueError(f"unsupported ResNet depth {depth}")

    def ch(c: int) -> int:
        return max(8, int(round(c * width_mult)))

    b = GraphBuilder(f"resnet{depth}", (batch, resolution, resolution, 3))
    x = b.conv("input", ch(64), k=7, stride=2, padding=3, act="relu", name="stem")
    x = b.maxpool(x, k=3, stride=2, padding=1, name="stem.pool")
    widths = [64, 128, 256, 512]
    for si, (nblk, w) in enumerate(zip(blocks, widths)):
        for bi in range(nblk):
            stride = 2 if (si > 0 and bi == 0) else 1
            x = fn(b, x, ch(w), stride, name=f"layer{si + 1}.{bi}")
    x = b.global_avg_pool(x, name="gap")
    feat = ch(widths[-1]) * expansion
    x = b.dense(x, num_classes, cin=feat, name="fc")
    return b.finish([x])

"""YOLOv5 n/s/m (Ultralytics) as graph-IR programs.

Standard v6.0 topology: CSPDarknet backbone (Conv-BN-SiLU stem, C3 blocks,
SPPF) + PANet neck + 3-scale Detect head. Variant scaling matches the
Ultralytics yamls:

    variant   depth_multiple  width_multiple
    n         0.33            0.25
    s         0.33            0.50
    m         0.67            0.75

The 6x6/2 stem conv of v6.0 is used (not the Focus slice). The Detect head
emits raw per-scale maps ``(N, H, W, na*(5+nc))``; sigmoid/grid decoding and
NMS live in the Rust coordinator postprocessor (as in the paper's runtime).
"""

from __future__ import annotations

import math

from ..graph import Graph, GraphBuilder

VARIANTS = {"n": (0.33, 0.25), "s": (0.33, 0.50), "m": (0.67, 0.75)}
NUM_ANCHORS = 3


def _depth(n: int, dm: float) -> int:
    return max(1, round(n * dm))


def _width(c: int, wm: float) -> int:
    return max(8, math.ceil(c * wm / 8) * 8)


def _cbs(b: GraphBuilder, x: str, c: int, k: int, s: int, name: str) -> str:
    pad = k // 2
    return b.conv(x, c, k=k, stride=s, padding=pad, act="silu", name=name)


def _bottleneck(b: GraphBuilder, x: str, c: int, shortcut: bool, name: str) -> str:
    y = _cbs(b, x, c, 1, 1, f"{name}.cv1")
    y = _cbs(b, y, c, 3, 1, f"{name}.cv2")
    if shortcut and b.channels(x) == c:
        y = b.add(y, x, name=f"{name}.add")
    return y


def _c3(b: GraphBuilder, x: str, cout: int, n: int, shortcut: bool, name: str) -> str:
    ch = cout // 2
    y1 = _cbs(b, x, ch, 1, 1, f"{name}.cv1")
    for i in range(n):
        y1 = _bottleneck(b, y1, ch, shortcut, f"{name}.m{i}")
    y2 = _cbs(b, x, ch, 1, 1, f"{name}.cv2")
    y = b.concat([y1, y2], name=f"{name}.cat")
    return _cbs(b, y, cout, 1, 1, f"{name}.cv3")


def _sppf(b: GraphBuilder, x: str, cout: int, name: str) -> str:
    ch = b.channels(x) // 2
    y = _cbs(b, x, ch, 1, 1, f"{name}.cv1")
    p1 = b.maxpool(y, k=5, stride=1, padding=2, name=f"{name}.p1")
    p2 = b.maxpool(p1, k=5, stride=1, padding=2, name=f"{name}.p2")
    p3 = b.maxpool(p2, k=5, stride=1, padding=2, name=f"{name}.p3")
    y = b.concat([y, p1, p2, p3], name=f"{name}.cat")
    return _cbs(b, y, cout, 1, 1, f"{name}.cv2")


def build_yolov5(variant: str = "n", num_classes: int = 80, resolution: int = 640,
                 width_mult: float = 1.0, batch: int = 1) -> Graph:
    """``width_mult`` stacks on top of the variant's width_multiple (for the
    synthetic-data mini models used in accuracy experiments)."""
    dm, wm = VARIANTS[variant]
    wm = wm * width_mult

    def cw(c: int) -> int:
        return _width(c, wm)

    b = GraphBuilder(f"yolov5{variant}", (batch, resolution, resolution, 3))

    # ---- backbone
    # v6.0 stem: k=6, s=2, p=2 (not the k//2 default)
    x = b.conv("input", cw(64), k=6, stride=2, padding=2, act="silu", name="b0")  # P1/2
    x = _cbs(b, x, cw(128), 3, 2, "b1")                  # P2/4
    x = _c3(b, x, cw(128), _depth(3, dm), True, "b2")
    x = _cbs(b, x, cw(256), 3, 2, "b3")                  # P3/8
    p3 = _c3(b, x, cw(256), _depth(6, dm), True, "b4")
    x = _cbs(b, p3, cw(512), 3, 2, "b5")                 # P4/16
    p4 = _c3(b, x, cw(512), _depth(9, dm), True, "b6")
    x = _cbs(b, p4, cw(1024), 3, 2, "b7")                # P5/32
    x = _c3(b, x, cw(1024), _depth(3, dm), True, "b8")
    p5 = _sppf(b, x, cw(1024), "b9")

    # ---- PANet neck
    h10 = _cbs(b, p5, cw(512), 1, 1, "n10")
    up = b.upsample2x(h10, name="n11.up")
    x = b.concat([up, p4], name="n11.cat")
    h13 = _c3(b, x, cw(512), _depth(3, dm), False, "n13")
    h14 = _cbs(b, h13, cw(256), 1, 1, "n14")
    up = b.upsample2x(h14, name="n15.up")
    x = b.concat([up, p3], name="n15.cat")
    d17 = _c3(b, x, cw(256), _depth(3, dm), False, "n17")      # P3 out
    x = _cbs(b, d17, cw(256), 3, 2, "n18")
    x = b.concat([x, h14], name="n19.cat")
    d20 = _c3(b, x, cw(512), _depth(3, dm), False, "n20")      # P4 out
    x = _cbs(b, d20, cw(512), 3, 2, "n21")
    x = b.concat([x, h10], name="n22.cat")
    d23 = _c3(b, x, cw(1024), _depth(3, dm), False, "n23")     # P5 out

    # ---- Detect head: 1x1 convs, raw maps out
    no = NUM_ANCHORS * (5 + num_classes)
    outs = [
        b.conv(d17, no, k=1, padding=0, bn=False, name="detect.p3"),
        b.conv(d20, no, k=1, padding=0, bn=False, name="detect.p4"),
        b.conv(d23, no, k=1, padding=0, bn=False, name="detect.p5"),
    ]
    return b.finish(outs)

"""VGG16-SSD300 (Liu et al.) as a graph-IR program.

Topology follows the canonical SSD300 implementation: VGG16 conv stack with
ceil-mode pool3 replaced by pad-preserving pooling, dilation-free fc6/fc7
convs (we use k=3 p=1 rather than dilated k=3 d=6 — dilation is not in the
op set; receptive field differs slightly but layer shapes/compute match),
extra feature layers conv8_1..conv11_2, and per-scale multibox heads.

Feature maps (300px input): conv4_3 (38x38, 4 anchors), fc7 (19x19, 6),
conv8_2 (10x10, 6), conv9_2 (5x5, 6), conv10_2 (3x3, 4), conv11_2 (1x1, 4)
→ 8732 boxes total. Heads output raw loc/conf maps; decoding happens in the
Rust coordinator's postprocessor.
"""

from __future__ import annotations

from ..graph import Graph, GraphBuilder

# (feature tensor tag, anchors per cell)
_HEAD_SPEC = [("conv4_3", 4), ("fc7", 6), ("conv8_2", 6), ("conv9_2", 6),
              ("conv10_2", 4), ("conv11_2", 4)]


def build_vgg16_ssd(num_classes: int = 21, resolution: int = 300,
                    width_mult: float = 1.0, batch: int = 1) -> Graph:
    def ch(c: int) -> int:
        return max(8, int(round(c * width_mult)))

    b = GraphBuilder("vgg16_ssd", (batch, resolution, resolution, 3))
    feats: dict[str, str] = {}

    x = "input"
    # VGG16 stack: (count, channels) per stage
    for si, (cnt, c) in enumerate([(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)]):
        for ci in range(cnt):
            x = b.conv(x, ch(c), k=3, act="relu", name=f"conv{si + 1}_{ci + 1}")
            if si == 3 and ci == cnt - 1:
                feats["conv4_3"] = x
        if si < 4:
            # pool3 is ceil-mode in canonical SSD300 (75 -> 38): emulate with
            # symmetric padding (shapes match; alignment shift is immaterial)
            pad = 1 if si == 2 else 0
            x = b.maxpool(x, k=2, stride=2, padding=pad, name=f"pool{si + 1}")
        else:
            x = b.maxpool(x, k=3, stride=1, padding=1, name="pool5")
    x = b.conv(x, ch(1024), k=3, act="relu", name="fc6")
    x = b.conv(x, ch(1024), k=1, padding=0, act="relu", name="fc7")
    feats["fc7"] = x
    # extras: 1x1 squeeze + 3x3/2 (or valid 3x3) expand
    x = b.conv(x, ch(256), k=1, padding=0, act="relu", name="conv8_1")
    x = b.conv(x, ch(512), k=3, stride=2, act="relu", name="conv8_2")
    feats["conv8_2"] = x
    x = b.conv(x, ch(128), k=1, padding=0, act="relu", name="conv9_1")
    x = b.conv(x, ch(256), k=3, stride=2, act="relu", name="conv9_2")
    feats["conv9_2"] = x
    x = b.conv(x, ch(128), k=1, padding=0, act="relu", name="conv10_1")
    x = b.conv(x, ch(256), k=3, padding=0, act="relu", name="conv10_2")
    feats["conv10_2"] = x
    x = b.conv(x, ch(128), k=1, padding=0, act="relu", name="conv11_1")
    x = b.conv(x, ch(256), k=3, padding=0, act="relu", name="conv11_2")
    feats["conv11_2"] = x

    outputs = []
    for tag, anchors in _HEAD_SPEC:
        f = feats[tag]
        outputs.append(b.conv(f, anchors * 4, k=3, bn=False, name=f"{tag}.loc"))
        outputs.append(b.conv(f, anchors * num_classes, k=3, bn=False,
                              name=f"{tag}.conf"))
    return b.finish(outputs)

"""Model zoo: the architectures the paper evaluates (DESIGN.md §6).

All models are graph-IR programs (see ``compile.graph``) so the same
definition trains under QAT in JAX and compiles to ``.dlrt`` in Rust.
"""

from .resnet import build_resnet  # noqa: F401
from .vgg_ssd import build_vgg16_ssd  # noqa: F401
from .yolov5 import build_yolov5  # noqa: F401

REGISTRY = {
    "resnet18": lambda **kw: build_resnet(depth=18, **kw),
    "resnet50": lambda **kw: build_resnet(depth=50, **kw),
    "vgg16_ssd": build_vgg16_ssd,
    "yolov5n": lambda **kw: build_yolov5(variant="n", **kw),
    "yolov5s": lambda **kw: build_yolov5(variant="s", **kw),
    "yolov5m": lambda **kw: build_yolov5(variant="m", **kw),
}

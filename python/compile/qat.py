"""Quantization-aware training loop (the Neutrino analog, build path only).

SGD with momentum over the graph executor's ``qat`` mode: conv weights and
conv inputs are LSQ fake-quantized with learned per-conv scales, batchnorm
runs on batch statistics, and running stats are tracked for deployment
folding. Losses:

* classification — softmax cross-entropy
* detection      — single-scale YOLO-style grid loss (BCE objectness +
                   BCE class + L2 box on positive cells), matching the
                   ``datasets.synth_shapes`` target layout

Both are deliberately compact: the experiments measure the *relative*
accuracy drop FP32 → 2A/2W → 1A/2W, not leaderboard numbers (DESIGN.md §2).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import jax_exec
from .graph import Graph

BN_MOMENTUM = 0.9


@dataclass
class TrainConfig:
    lr: float = 0.05
    momentum: float = 0.9
    weight_decay: float = 1e-4
    batch_size: int = 32
    steps: int = 300
    scale_lr_mult: float = 0.1  # LSQ scales move slower than weights
    seed: int = 0
    log_every: int = 50


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -logp[jnp.arange(labels.shape[0]), labels].mean()


def detection_grid_loss(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    """pred: raw map (N, G, G, 5+nc) — sigmoid applied here; target same layout."""
    obj_t = target[..., 0]
    obj_p = pred[..., 0]
    bce_obj = jnp.maximum(obj_p, 0) - obj_p * obj_t + jnp.log1p(jnp.exp(-jnp.abs(obj_p)))
    pos = obj_t
    box_l2 = ((jax.nn.sigmoid(pred[..., 1:5]) - target[..., 1:5]) ** 2).sum(-1)
    cls_p = pred[..., 5:]
    cls_t = target[..., 5:]
    bce_cls = (jnp.maximum(cls_p, 0) - cls_p * cls_t +
               jnp.log1p(jnp.exp(-jnp.abs(cls_p)))).sum(-1)
    npos = jnp.maximum(pos.sum(), 1.0)
    return bce_obj.mean() + 5.0 * (pos * box_l2).sum() / npos + \
        (pos * bce_cls).sum() / npos


def _sgd_update(params, grads, vel, cfg: TrainConfig):
    new_p, new_v = {}, {}
    for k, p in params.items():
        g = grads[k]
        if k.endswith(".w") and cfg.weight_decay:
            g = g + cfg.weight_decay * p
        lr = cfg.lr * (cfg.scale_lr_mult if ".s_" in k else 1.0)
        v = cfg.momentum * vel[k] + g
        new_v[k] = v
        new_p[k] = p - lr * v
        if ".s_" in k:  # scales must stay positive
            new_p[k] = jnp.maximum(new_p[k], 1e-6)
    return new_p, new_v


def train(g: Graph, data_fn, loss_fn, cfg: TrainConfig,
          params=None, state=None, head: int = 0):
    """Train graph ``g`` under QAT.

    ``data_fn(rng, n) -> (x, y)`` supplies batches; ``loss_fn(outs, y)``
    consumes graph output ``head``. Returns (params, state, history).
    """
    if params is None:
        params, state = jax_exec.init_params(g, seed=cfg.seed)
    vel = {k: jnp.zeros_like(v) for k, v in params.items()}
    rng = np.random.default_rng(cfg.seed + 1)

    @jax.jit
    def step(params, state, vel, x, y):
        def loss_of(p):
            outs, aux = jax_exec.run(g, p, state, x, mode="qat", train=True)
            return loss_fn(outs[head], y), aux

        (loss, aux), grads = jax.value_and_grad(loss_of, has_aux=True)(params)
        new_state = dict(state)
        for k, v in aux.items():
            new_state[k] = BN_MOMENTUM * state[k] + (1 - BN_MOMENTUM) * v
        params, vel = _sgd_update(params, grads, vel, cfg)
        return params, new_state, vel, loss

    history = []
    for it in range(cfg.steps):
        x, y = data_fn(rng, cfg.batch_size)
        params, state, vel, loss = step(params, state, vel,
                                        jnp.asarray(x), jnp.asarray(y))
        if it % cfg.log_every == 0 or it == cfg.steps - 1:
            history.append((it, float(loss)))
    return params, state, history


def eval_classifier(g: Graph, params, state, x, y, mode: str = "deploy_sim",
                    batch: int = 64, head: int = 0) -> float:
    """Top-1 accuracy under the given execution mode."""
    correct = 0
    for i in range(0, len(x), batch):
        outs, _ = jax_exec.run(g, params, state, jnp.asarray(x[i:i + batch]),
                               mode=mode)
        pred = np.asarray(outs[head]).argmax(-1)
        correct += int((pred == y[i:i + batch]).sum())
    return correct / len(x)


def eval_detector_map(g: Graph, params, state, x, targets,
                      mode: str = "deploy_sim", head: int = 0,
                      iou_thresh: float = 0.5, batch: int = 32) -> float:
    """mAP@0.5 on grid predictions (greedy per-cell decode, 11-pt AP).

    Compact evaluator for the synth_shapes task: a predicted cell box matches
    a GT cell box of the same class with IoU >= thresh.
    """
    num_classes = targets.shape[-1] - 5
    all_scores: dict[int, list[tuple[float, int]]] = {c: [] for c in range(num_classes)}
    total_gt = np.zeros(num_classes, np.int64)

    for i in range(0, len(x), batch):
        outs, _ = jax_exec.run(g, params, state, jnp.asarray(x[i:i + batch]), mode=mode)
        pred = np.asarray(jax.nn.sigmoid(outs[head]))
        tgt = targets[i:i + batch]
        grid = pred.shape[1]
        for bi in range(pred.shape[0]):
            gt_boxes, gt_cls = _decode_grid(tgt[bi], grid, raw=False)
            total_gt += np.bincount(gt_cls, minlength=num_classes) if len(gt_cls) else 0
            pb, pc, ps = _decode_grid_pred(pred[bi], grid)
            used = np.zeros(len(gt_boxes), bool)
            order = np.argsort(-ps)
            for j in order:
                best, best_iou = -1, iou_thresh
                for k in range(len(gt_boxes)):
                    if used[k] or gt_cls[k] != pc[j]:
                        continue
                    iou = _iou(pb[j], gt_boxes[k])
                    if iou >= best_iou:
                        best, best_iou = k, iou
                tp = best >= 0
                if tp:
                    used[best] = True
                all_scores[int(pc[j])].append((float(ps[j]), int(tp)))

    aps = []
    for c in range(num_classes):
        if total_gt[c] == 0:
            continue
        sc = sorted(all_scores[c], reverse=True)
        tps = np.cumsum([s[1] for s in sc]) if sc else np.array([])
        if len(tps) == 0:
            aps.append(0.0)
            continue
        recall = tps / total_gt[c]
        precision = tps / np.arange(1, len(tps) + 1)
        ap = 0.0
        for r in np.linspace(0, 1, 11):
            mask = recall >= r
            ap += (precision[mask].max() if mask.any() else 0.0) / 11
        aps.append(float(ap))
    return float(np.mean(aps)) if aps else 0.0


def _decode_grid(t, grid, raw=True):
    boxes, cls = [], []
    for gi in range(grid):
        for gj in range(grid):
            if t[gi, gj, 0] > 0.5:
                cx = (gj + t[gi, gj, 1]) / grid
                cy = (gi + t[gi, gj, 2]) / grid
                w, h = t[gi, gj, 3], t[gi, gj, 4]
                boxes.append((cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2))
                cls.append(int(np.argmax(t[gi, gj, 5:])))
    return np.array(boxes).reshape(-1, 4), np.array(cls, np.int64)


def _decode_grid_pred(p, grid, obj_thresh: float = 0.3):
    boxes, cls, score = [], [], []
    for gi in range(grid):
        for gj in range(grid):
            if p[gi, gj, 0] > obj_thresh:
                cx = (gj + p[gi, gj, 1]) / grid
                cy = (gi + p[gi, gj, 2]) / grid
                w, h = p[gi, gj, 3], p[gi, gj, 4]
                boxes.append((cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2))
                c = int(np.argmax(p[gi, gj, 5:]))
                cls.append(c)
                score.append(float(p[gi, gj, 0] * p[gi, gj, 5 + c]))
    return (np.array(boxes).reshape(-1, 4), np.array(cls, np.int64),
            np.array(score, np.float64))


def _iou(a, b) -> float:
    x0, y0 = max(a[0], b[0]), max(a[1], b[1])
    x1, y1 = min(a[2], b[2]), min(a[3], b[3])
    inter = max(0.0, x1 - x0) * max(0.0, y1 - y0)
    ua = (a[2] - a[0]) * (a[3] - a[1]) + (b[2] - b[0]) * (b[3] - b[1]) - inter
    return inter / ua if ua > 0 else 0.0

"""Synthetic datasets standing in for VWW / COCO-8 / ImageNet (DESIGN.md §2).

The reproduced quantity is the *accuracy drop under ultra-low-bit QAT*, not
absolute SOTA accuracy, so each generator is built to (a) be learnable by a
small CNN in a few hundred steps on one CPU core and (b) have enough texture
that 1–2 bit quantization actually costs accuracy (plain constant-color
tasks quantize for free and would fake a 0% drop).

* ``synth_vww``    — person-present stand-in: binary label, a bright soft
                     blob + distractor noise. (Visual Wake Words analog.)
* ``synth_cls``    — k-class stand-in for ImageNet: class = (blob position
                     quadrant, stripe orientation) combinations.
* ``synth_shapes`` — detection stand-in for COCO-8/VOC: up to ``max_obj``
                     axis-aligned shapes from 8 classes; targets are YOLO
                     grid tensors (obj, class, box) per cell.
"""

from __future__ import annotations

import numpy as np


def _blob(h: int, w: int, cy: float, cx: float, r: float) -> np.ndarray:
    yy, xx = np.mgrid[0:h, 0:w]
    return np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * r * r)))


def synth_vww(rng: np.random.Generator, n: int, res: int = 32):
    """Returns (x [n,res,res,3] float in [0,1], y [n] {0,1})."""
    x = rng.uniform(0.0, 0.35, size=(n, res, res, 3)).astype(np.float32)
    y = rng.integers(0, 2, size=n)
    for i in range(n):
        # distractor texture either way
        fy, fx = rng.uniform(4, res - 4, 2)
        x[i, :, :, rng.integers(0, 3)] += 0.15 * _blob(res, res, fy, fx, res / 3)
        if y[i]:
            cy, cx = rng.uniform(res * 0.25, res * 0.75, 2)
            r = rng.uniform(res / 10, res / 6)
            person = _blob(res, res, cy, cx, r)
            # "person": vertical bright blob with a head bump
            head = 0.8 * _blob(res, res, cy - 2 * r, cx, r / 2)
            for c in range(3):
                x[i, :, :, c] += (0.5 + 0.2 * c / 3) * (person + head)
    return np.clip(x, 0, 1).astype(np.float32), y.astype(np.int32)


def synth_cls(rng: np.random.Generator, n: int, res: int = 32, k: int = 10):
    """k-class classification; class encodes quadrant (4) x orientation (k/4)."""
    x = rng.uniform(0.0, 0.3, size=(n, res, res, 3)).astype(np.float32)
    y = rng.integers(0, k, size=n)
    for i in range(n):
        cls = int(y[i])
        quad, phase = cls % 4, cls // 4
        cy = res * (0.3 if quad in (0, 1) else 0.7)
        cx = res * (0.3 if quad in (0, 2) else 0.7)
        r = res / 8
        x[i, :, :, 0] += _blob(res, res, cy, cx, r)
        yy, xx = np.mgrid[0:res, 0:res]
        stripes = 0.5 * (1 + np.sin((xx if phase % 2 else yy) * (0.4 + 0.25 * phase)))
        x[i, :, :, 1] += 0.35 * stripes
    return np.clip(x, 0, 1).astype(np.float32), y.astype(np.int32)


SHAPE_CLASSES = ["person", "dog", "cat", "car", "bus", "truck", "bicycle",
                 "motorcycle"]  # the paper's COCO-8 subset


def synth_shapes(rng: np.random.Generator, n: int, res: int = 64,
                 num_classes: int = 8, max_obj: int = 3, grid: int = 8):
    """Detection set. Returns (x, targets) with YOLO-style grid targets.

    targets: [n, grid, grid, 5 + num_classes] = (obj, cx, cy, w, h, onehot);
    cx/cy are cell-relative in [0,1], w/h image-relative.
    Each class is a distinct drawing primitive (filled box, ring, cross,
    stripes, ...) so classification is learnable from local appearance.
    """
    x = rng.uniform(0.0, 0.25, size=(n, res, res, 3)).astype(np.float32)
    t = np.zeros((n, grid, grid, 5 + num_classes), np.float32)
    cell = res / grid
    for i in range(n):
        for _ in range(int(rng.integers(1, max_obj + 1))):
            cls = int(rng.integers(0, num_classes))
            bw = rng.uniform(res / 8, res / 3)
            bh = rng.uniform(res / 8, res / 3)
            cy = rng.uniform(bh / 2, res - bh / 2)
            cx = rng.uniform(bw / 2, res - bw / 2)
            y0, y1 = int(cy - bh / 2), int(cy + bh / 2)
            x0, x1 = int(cx - bw / 2), int(cx + bw / 2)
            patch = x[i, y0:y1, x0:x1]
            ph, pw = patch.shape[:2]
            if ph < 2 or pw < 2:
                continue
            yy, xx = np.mgrid[0:ph, 0:pw]
            c = cls % 8
            if c == 0:      # filled bright box
                patch[..., 0] += 0.8
            elif c == 1:    # ring
                rr = np.hypot(yy - ph / 2, xx - pw / 2)
                patch[..., 1] += 0.8 * ((rr > min(ph, pw) / 4) & (rr < min(ph, pw) / 2.2))
            elif c == 2:    # cross
                patch[..., 2] += 0.8 * ((np.abs(yy - ph / 2) < ph / 8) |
                                        (np.abs(xx - pw / 2) < pw / 8))
            elif c == 3:    # horizontal stripes
                patch[..., 0] += 0.7 * ((yy // max(2, ph // 6)) % 2)
            elif c == 4:    # vertical stripes
                patch[..., 1] += 0.7 * ((xx // max(2, pw // 6)) % 2)
            elif c == 5:    # diagonal
                patch[..., 2] += 0.7 * (((yy + xx) // max(2, (ph + pw) // 12)) % 2)
            elif c == 6:    # filled disk
                rr = np.hypot(yy - ph / 2, xx - pw / 2)
                patch[..., 0] += 0.8 * (rr < min(ph, pw) / 2.5)
                patch[..., 1] += 0.6 * (rr < min(ph, pw) / 2.5)
            else:           # checkerboard
                patch[..., 2] += 0.7 * (((yy // max(2, ph // 4)) +
                                         (xx // max(2, pw // 4))) % 2)
            gi, gj = min(grid - 1, int(cy / cell)), min(grid - 1, int(cx / cell))
            if t[i, gi, gj, 0] == 1.0:
                continue  # one object per cell
            t[i, gi, gj, 0] = 1.0
            t[i, gi, gj, 1] = cx / cell - gj
            t[i, gi, gj, 2] = cy / cell - gi
            t[i, gi, gj, 3] = bw / res
            t[i, gi, gj, 4] = bh / res
            t[i, gi, gj, 5 + cls] = 1.0
    return np.clip(x, 0, 1).astype(np.float32), t

"""Serialize graph-IR models to ``arch.json`` + ``weights.bin``.

This is the interchange the Rust ``dlrt compile`` pass consumes
(rust/src/compiler/). Layout:

* ``arch.json`` — graph topology; every tensor-valued field is a
  ``{"offset": <f32 element offset>, "len": <element count>}`` reference
  into ``weights.bin``.
* ``weights.bin`` — little-endian f32, concatenated in reference order.

Conv nodes carry deployment-ready data: raw f32 weights (HWIO), the QAT
scales ``s_w`` / ``s_a`` when quantized, and per-channel folded-BN
``scale`` / ``bias`` (identity scale + plain bias when the conv had no BN).
The Rust compiler performs the integer quantization + bitplane packing.
"""

from __future__ import annotations

import json
import struct
from pathlib import Path

import numpy as np

from . import jax_exec
from .graph import Graph


class _WeightWriter:
    def __init__(self) -> None:
        self.chunks: list[bytes] = []
        self.offset = 0

    def put(self, arr) -> dict:
        a = np.asarray(arr, dtype=np.float32).ravel()
        ref = {"offset": self.offset, "len": int(a.size)}
        self.chunks.append(a.tobytes())
        self.offset += int(a.size)
        return ref

    def bytes(self) -> bytes:
        return b"".join(self.chunks)


def export_model(g: Graph, params: dict, state: dict, out_dir: str | Path) -> Path:
    """Write ``<out_dir>/arch.json`` and ``weights.bin``. Returns out_dir."""
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    ww = _WeightWriter()
    nodes = []
    for n in g.nodes:
        jn: dict = {"op": n.op, "name": n.name, "inputs": n.inputs,
                    "output": n.output}
        if n.op == "conv2d":
            qcfg = n.attrs["qcfg"]
            scale, bias = jax_exec._bn_fold_scale_bias(params, state, n.name)
            jn.update({
                "stride": n.attrs["stride"], "padding": n.attrs["padding"],
                "kernel": n.attrs["kernel"], "cin": n.attrs["cin"],
                "cout": n.attrs["cout"],
                "qcfg": qcfg.to_json(),
                "w": ww.put(params[f"{n.name}.w"]),
                "scale": ww.put(scale),
                "bias": ww.put(bias),
            })
            if qcfg.enabled:
                jn["s_w"] = float(params[f"{n.name}.s_w"])
                jn["s_a"] = float(params[f"{n.name}.s_a"])
        elif n.op == "dense":
            jn.update({
                "cin": n.attrs["cin"], "cout": n.attrs["cout"],
                "w": ww.put(params[f"{n.name}.w"]),
                "b": ww.put(params[f"{n.name}.b"]),
            })
        elif n.op == "maxpool2d":
            jn.update({"kernel": n.attrs["kernel"], "stride": n.attrs["stride"],
                       "padding": n.attrs["padding"]})
        nodes.append(jn)

    arch = {
        "name": g.name,
        "input": {"name": g.input_name, "shape": list(g.input_shape)},
        "outputs": g.outputs,
        "nodes": nodes,
    }
    (out / "arch.json").write_text(json.dumps(arch, indent=1))
    (out / "weights.bin").write_bytes(ww.bytes())
    return out


def export_golden(g: Graph, params: dict, state: dict, x, out_path: str | Path,
                  mode: str = "deploy_sim") -> None:
    """Golden parity vector: input + per-output flats under deployment math."""
    outs, _ = jax_exec.run(g, params, state, x, mode=mode)
    data = {
        "model": g.name,
        "mode": mode,
        "input_shape": list(np.asarray(x).shape),
        "input": [float(v) for v in np.asarray(x, np.float32).ravel()],
        "outputs": [
            {"shape": list(np.asarray(o).shape),
             "data": [float(v) for v in np.asarray(o, np.float32).ravel()]}
            for o in outs
        ],
    }
    Path(out_path).write_text(json.dumps(data))


def export_kernel_goldens(out_path: str | Path, seed: int = 0) -> None:
    """Random bitserial GEMM/conv cases with oracle outputs, for Rust tests."""
    from .kernels import pack, ref

    rng = np.random.default_rng(seed)
    cases = []
    for a_bits, w_bits, m, n, k in [(1, 1, 4, 5, 37), (2, 2, 8, 6, 64),
                                    (1, 2, 7, 9, 130), (3, 2, 5, 4, 96),
                                    (2, 3, 6, 8, 33), (4, 4, 3, 3, 70)]:
        qp, qn = pack.qp_qn(w_bits, signed=True)
        a = rng.integers(0, 2**a_bits, size=(m, k))
        w = rng.integers(-qn, qp + 1, size=(n, k))
        outp = np.asarray(ref.ref_gemm_i32(a, w))
        cases.append({
            "a_bits": a_bits, "w_bits": w_bits, "m": m, "n": n, "k": k,
            "a": a.ravel().tolist(), "w": w.ravel().tolist(),
            "out": outp.ravel().tolist(),
        })
    conv_cases = []
    for a_bits, w_bits, hw, cin, cout, kk, s, p in [
            (2, 2, 8, 5, 6, 3, 1, 1), (1, 2, 9, 4, 7, 3, 2, 1),
            (2, 2, 7, 3, 4, 1, 1, 0), (3, 3, 6, 8, 5, 3, 1, 0)]:
        qp, qn = pack.qp_qn(w_bits, signed=True)
        x = rng.integers(0, 2**a_bits, size=(1, hw, hw, cin))
        w = rng.integers(-qn, qp + 1, size=(kk, kk, cin, cout))
        outp = np.asarray(ref.ref_qconv2d_i32(
            np.asarray(x), np.asarray(w), (s, s), (p, p)))
        conv_cases.append({
            "a_bits": a_bits, "w_bits": w_bits, "h": hw, "w_in": hw,
            "cin": cin, "cout": cout, "k": kk, "stride": s, "padding": p,
            "x": x.ravel().tolist(), "w": w.ravel().tolist(),
            "out_shape": list(outp.shape), "out": outp.ravel().tolist(),
        })
    Path(out_path).write_text(json.dumps({"gemm": cases, "conv": conv_cases}))

"""JAX executor for the shared graph IR (build path only).

Modes
-----
``fp32``          plain float inference (BN applied from state)
``qat``           LSQ fake-quant training: conv weights and conv inputs are
                  fake-quantized with learned per-conv scales; live batchnorm
                  with batch statistics (running stats updated in aux)
``deploy_sim``    integer-exact deployment semantics: hard-quantize with the
                  trained scales, integer conv accumulators, dequantize with
                  per-channel folded BN scale/bias — the *same arithmetic*
                  the Rust runtime executes; used for golden parity vectors
``deploy_kernel`` like deploy_sim but the conv goes through the Pallas
                  bitserial kernel; this is what ``aot.py`` lowers to HLO

The executor is pure jnp, hence differentiable in ``qat`` mode.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.lax as lax
import jax.numpy as jnp

from . import quant
from .graph import Graph, Node, QCfg
from .kernels import bitserial as bs
from .kernels import ref as kref
from .kernels.pack import qp_qn

BN_EPS = 1e-5


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def init_params(g: Graph, seed: int = 0) -> tuple[dict, dict]:
    """He-normal init. Returns (params, state).

    params: conv/dense weights + BN gamma/beta + LSQ scales (s_w, s_a).
    state:  BN running mean/var.
    """
    import numpy as np

    rng = np.random.default_rng(seed)
    params: dict[str, jnp.ndarray] = {}
    state: dict[str, jnp.ndarray] = {}
    for n in g.nodes:
        if n.op == "conv2d":
            kh, kw = n.attrs["kernel"]
            cin, cout = n.attrs["cin"], n.attrs["cout"]
            fan_in = kh * kw * cin
            w = rng.normal(0.0, (2.0 / fan_in) ** 0.5, size=(kh, kw, cin, cout))
            params[f"{n.name}.w"] = jnp.asarray(w, jnp.float32)
            params[f"{n.name}.b"] = jnp.zeros((cout,), jnp.float32)
            if n.attrs.get("bn", True):
                params[f"{n.name}.bn.gamma"] = jnp.ones((cout,), jnp.float32)
                params[f"{n.name}.bn.beta"] = jnp.zeros((cout,), jnp.float32)
                state[f"{n.name}.bn.mean"] = jnp.zeros((cout,), jnp.float32)
                state[f"{n.name}.bn.var"] = jnp.ones((cout,), jnp.float32)
            qcfg: QCfg = n.attrs["qcfg"]
            if qcfg.enabled:
                params[f"{n.name}.s_w"] = quant.init_scale(
                    params[f"{n.name}.w"], qcfg.w_bits, signed=True)
                params[f"{n.name}.s_a"] = jnp.float32(0.1)
        elif n.op == "dense":
            cin, cout = n.attrs["cin"], n.attrs["cout"]
            w = rng.normal(0.0, (2.0 / cin) ** 0.5, size=(cin, cout))
            params[f"{n.name}.w"] = jnp.asarray(w, jnp.float32)
            params[f"{n.name}.b"] = jnp.zeros((cout,), jnp.float32)
    return params, state


# ---------------------------------------------------------------------------
# Op implementations
# ---------------------------------------------------------------------------

def _conv_fp32(x, w, stride, padding):
    return lax.conv_general_dilated(
        x, w, window_strides=tuple(stride),
        padding=[(padding[0], padding[0]), (padding[1], padding[1])],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn_fold_scale_bias(params, state, name):
    """Per-channel (scale, bias) equivalent of the trained BN (or plain bias)."""
    if f"{name}.bn.gamma" in params:
        gamma, beta = params[f"{name}.bn.gamma"], params[f"{name}.bn.beta"]
        mean, var = state[f"{name}.bn.mean"], state[f"{name}.bn.var"]
        inv = gamma / jnp.sqrt(var + BN_EPS)
        return inv, beta - mean * inv
    cout = params[f"{name}.b"].shape[0]
    return jnp.ones((cout,), jnp.float32), params[f"{name}.b"]


def _apply_act(op: str, x):
    if op == "relu":
        return jax.nn.relu(x)
    if op == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if op == "silu":
        return x * jax.nn.sigmoid(x)
    if op == "leaky_relu":
        return jnp.where(x >= 0, x, 0.1 * x)
    if op == "sigmoid":
        return jax.nn.sigmoid(x)
    raise AssertionError(op)


def _maxpool(x, kernel, stride, padding):
    return lax.reduce_window(
        x, -jnp.inf, lax.max,
        window_dimensions=(1, kernel[0], kernel[1], 1),
        window_strides=(1, stride[0], stride[1], 1),
        padding=((0, 0), (padding[0], padding[0]), (padding[1], padding[1]), (0, 0)))


def _upsample2x(x):
    n, h, w, c = x.shape
    return jnp.broadcast_to(x[:, :, None, :, None, :], (n, h, 2, w, 2, c)).reshape(
        n, 2 * h, 2 * w, c)


# ---------------------------------------------------------------------------
# Conv flavor per mode
# ---------------------------------------------------------------------------

def _conv_qat(x, params, state, n: Node, train: bool):
    """Fake-quantized conv + live BN (batch stats when train=True)."""
    qcfg: QCfg = n.attrs["qcfg"]
    name = n.name
    w = params[f"{name}.w"]
    if qcfg.enabled:
        gs_w = quant.lsq_grad_scale(w.size, qcfg.w_bits, True)
        w = quant.lsq_quantize(w, params[f"{name}.s_w"], qcfg.w_bits, True, gs_w)
        gs_a = quant.lsq_grad_scale(x.size, qcfg.a_bits, False)
        x = quant.lsq_quantize(x, params[f"{name}.s_a"], qcfg.a_bits, False, gs_a)
    y = _conv_fp32(x, w, n.attrs["stride"], n.attrs["padding"])
    aux = {}
    if n.attrs.get("bn", True):
        gamma, beta = params[f"{name}.bn.gamma"], params[f"{name}.bn.beta"]
        if train:
            mean = y.mean(axis=(0, 1, 2))
            var = y.var(axis=(0, 1, 2))
            aux[f"{name}.bn.mean"] = mean
            aux[f"{name}.bn.var"] = var
        else:
            mean, var = state[f"{name}.bn.mean"], state[f"{name}.bn.var"]
        y = (y - mean) / jnp.sqrt(var + BN_EPS) * gamma + beta
    else:
        y = y + params[f"{name}.b"]
    return y, aux


def _conv_deploy(x, params, state, n: Node, use_kernel: bool):
    """Deployment-exact conv: integer accumulators + per-channel scale/bias.

    Mirrors rust/src/exec/ops.rs arithmetic step for step.
    """
    qcfg: QCfg = n.attrs["qcfg"]
    name = n.name
    scale, bias = _bn_fold_scale_bias(params, state, name)
    w = params[f"{name}.w"]
    stride, padding = n.attrs["stride"], n.attrs["padding"]
    if not qcfg.enabled:
        y = _conv_fp32(x, w, stride, padding)
        return y * scale + bias, {}
    s_w = params[f"{name}.s_w"]
    s_a = params[f"{name}.s_a"]
    qp_a, _ = qp_qn(qcfg.a_bits, signed=False)
    qp_w, qn_w = qp_qn(qcfg.w_bits, signed=True)
    xq = jnp.clip(jnp.round(x / s_a), 0, qp_a).astype(jnp.int32)
    wq = jnp.clip(jnp.round(w / s_w), -qn_w, qp_w).astype(jnp.int32)
    if use_kernel:
        acc = bs.bitserial_conv2d(xq, wq, a_bits=qcfg.a_bits, w_bits=qcfg.w_bits,
                                  stride=tuple(stride), padding=tuple(padding))
    else:
        acc = kref.ref_qconv2d_i32(xq, wq, tuple(stride), tuple(padding))
    y = acc.astype(jnp.float32) * (s_a * s_w)
    return y * scale + bias, {}


# ---------------------------------------------------------------------------
# Graph executor
# ---------------------------------------------------------------------------

def run(g: Graph, params: dict, state: dict, x: jnp.ndarray, mode: str = "fp32",
        train: bool = False) -> tuple[list[jnp.ndarray], dict]:
    """Execute graph; returns (outputs, bn_aux)."""
    assert mode in {"fp32", "qat", "deploy_sim", "deploy_kernel"}
    env: dict[str, jnp.ndarray] = {g.input_name: x}
    aux: dict[str, jnp.ndarray] = {}

    for n in g.nodes:
        if n.op == "conv2d":
            if mode == "qat":
                y, a = _conv_qat(env[n.inputs[0]], params, state, n, train)
            elif mode in ("deploy_sim", "deploy_kernel"):
                y, a = _conv_deploy(env[n.inputs[0]], params, state, n,
                                    use_kernel=(mode == "deploy_kernel"))
            else:  # fp32: honest float conv + BN from state
                y = _conv_fp32(env[n.inputs[0]], params[f"{n.name}.w"],
                               n.attrs["stride"], n.attrs["padding"])
                scale, bias = _bn_fold_scale_bias(params, state, n.name)
                y, a = y * scale + bias, {}
            aux.update(a)
        elif n.op == "dense":
            xin = env[n.inputs[0]]
            y = xin @ params[f"{n.name}.w"] + params[f"{n.name}.b"]
        elif n.op == "maxpool2d":
            y = _maxpool(env[n.inputs[0]], n.attrs["kernel"], n.attrs["stride"],
                         n.attrs["padding"])
        elif n.op == "global_avg_pool":
            y = env[n.inputs[0]].mean(axis=(1, 2))
        elif n.op == "add":
            y = env[n.inputs[0]] + env[n.inputs[1]]
        elif n.op == "concat":
            y = jnp.concatenate([env[i] for i in n.inputs], axis=-1)
        elif n.op == "upsample2x":
            y = _upsample2x(env[n.inputs[0]])
        elif n.op == "flatten":
            xin = env[n.inputs[0]]
            y = xin.reshape(xin.shape[0], -1)
        elif n.op in {"relu", "relu6", "silu", "leaky_relu", "sigmoid"}:
            y = _apply_act(n.op, env[n.inputs[0]])
        else:
            raise AssertionError(n.op)
        env[n.output] = y

    return [env[o] for o in g.outputs], aux


def make_infer_fn(g: Graph, mode: str) -> Callable:
    """Closure suitable for jax.jit / AOT lowering: (params, state, x) → outputs."""

    def fn(params, state, x):
        outs, _ = run(g, params, state, x, mode=mode, train=False)
        return tuple(outs)

    return fn


def calibrate_activation_scales(g: Graph, params: dict, state: dict,
                                xs: list[jnp.ndarray]) -> dict:
    """PTQ path: set each quantized conv's s_a from observed input ranges.

    Runs the fp32 graph on calibration batches, records per-conv input maxima,
    and fits the unipolar scale (paper §IV calibration).
    """
    maxima: dict[str, float] = {}
    for x in xs:
        env: dict[str, jnp.ndarray] = {g.input_name: x}
        for n in g.nodes:
            ins = [env[i] for i in n.inputs]
            if n.op == "conv2d":
                qcfg: QCfg = n.attrs["qcfg"]
                if qcfg.enabled:
                    m = float(jnp.maximum(ins[0].max(), 0.0))
                    maxima[n.name] = max(maxima.get(n.name, 0.0), m)
                scale, bias = _bn_fold_scale_bias(params, state, n.name)
                y = _conv_fp32(ins[0], params[f"{n.name}.w"], n.attrs["stride"],
                               n.attrs["padding"]) * scale + bias
            elif n.op == "dense":
                y = ins[0] @ params[f"{n.name}.w"] + params[f"{n.name}.b"]
            elif n.op == "maxpool2d":
                y = _maxpool(ins[0], n.attrs["kernel"], n.attrs["stride"],
                             n.attrs["padding"])
            elif n.op == "global_avg_pool":
                y = ins[0].mean(axis=(1, 2))
            elif n.op == "add":
                y = ins[0] + ins[1]
            elif n.op == "concat":
                y = jnp.concatenate(ins, axis=-1)
            elif n.op == "upsample2x":
                y = _upsample2x(ins[0])
            elif n.op == "flatten":
                y = ins[0].reshape(ins[0].shape[0], -1)
            else:
                y = _apply_act(n.op, ins[0])
            env[n.output] = y
    new = dict(params)
    for n in g.conv_nodes():
        qcfg: QCfg = n.attrs["qcfg"]
        if qcfg.enabled and n.name in maxima:
            qp_a, _ = qp_qn(qcfg.a_bits, signed=False)
            new[f"{n.name}.s_a"] = jnp.float32(max(maxima[n.name] / qp_a, 1e-8))
    return new

"""Neutrino-style quantizers: LSQ quantization-aware training + PTQ calibration.

Implements the paper's §IV quantizer

    t_bar = round( clip( t / s, -Q_N, Q_P ) )        (training, STE)
    t_hat = t_bar * s                                 (dequantized value)

with the per-tensor scale ``s`` *learned* so the quantization error
``t - t_hat`` is minimized — i.e. LSQ (Learned Step-size Quantization),
which is what the learned-scale formulation in the paper describes.

Weights use the signed range ``[-Q_N, Q_P]``; activations (post-ReLU)
use the unipolar range ``[0, 2^b - 1]`` — matching the bitserial kernels'
unipolar {0,1} encoding (§V).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.pack import qp_qn


class QConfig(NamedTuple):
    """Per-layer quantization configuration (paper's mixed precision knob)."""

    w_bits: int = 2
    a_bits: int = 2
    enabled: bool = True  # False = layer kept FP32 ("conservative" layers)

    @property
    def tag(self) -> str:
        return f"{self.a_bits}A{self.w_bits}W" if self.enabled else "FP32"


FP32 = QConfig(enabled=False)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def lsq_quantize(t: jnp.ndarray, s: jnp.ndarray, bits: int, signed: bool,
                 grad_scale: float) -> jnp.ndarray:
    """Fake-quantize ``t`` with learned scale ``s`` (returns dequantized t_hat)."""
    qp, qn = qp_qn(bits, signed)
    v = jnp.clip(t / s, -float(qn), float(qp))
    return jnp.round(v) * s


def _lsq_fwd(t, s, bits, signed, grad_scale):
    return lsq_quantize(t, s, bits, signed, grad_scale), (t, s)


def _lsq_bwd(bits, signed, grad_scale, res, g):
    """LSQ gradients: STE for t inside the clip range; scale grad per LSQ.

    d t_hat / d s = -v + round(v)   if -Q_N < v < Q_P
                  = -Q_N            if v <= -Q_N
                  =  Q_P            if v >= Q_P
    """
    t, s = res
    qp, qn = qp_qn(bits, signed)
    v = t / s
    lo, hi = -float(qn), float(qp)
    in_range = (v > lo) & (v < hi)
    dt = jnp.where(in_range, g, 0.0)
    ds_elem = jnp.where(
        v <= lo, lo, jnp.where(v >= hi, hi, jnp.round(v) - v)
    )
    ds = (g * ds_elem).sum() * grad_scale
    return dt, jnp.asarray(ds, dtype=s.dtype)


lsq_quantize.defvjp(_lsq_fwd, _lsq_bwd)


def lsq_grad_scale(numel: int, bits: int, signed: bool = True) -> float:
    """LSQ's gradient normalizer g = 1 / sqrt(numel * Q_P)."""
    import math

    qp, _ = qp_qn(bits, signed)
    return 1.0 / math.sqrt(float(numel) * max(qp, 1))


def init_scale(t: jnp.ndarray, bits: int, signed: bool = True) -> jnp.ndarray:
    """LSQ init: s = 2 * mean(|t|) / sqrt(Q_P)."""
    qp, _ = qp_qn(bits, signed)
    s = 2.0 * jnp.abs(t).mean() / jnp.sqrt(float(max(qp, 1)))
    return jnp.maximum(s, 1e-8).astype(jnp.float32)


def quantize_int(t: jnp.ndarray, s: jnp.ndarray, bits: int, signed: bool) -> jnp.ndarray:
    """Hard-quantize to the integer code (deployment path, no gradients)."""
    qp, qn = qp_qn(bits, signed)
    return jnp.clip(jnp.round(t / s), -float(qn), float(qp)).astype(jnp.int32)


def dequantize(tq: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    return tq.astype(jnp.float32) * s


# ---------------------------------------------------------------------------
# PTQ calibration (the paper's comparison point; also used by the Rust
# compiler when no QAT scales are provided).
# ---------------------------------------------------------------------------

def calibrate_minmax(t: jnp.ndarray, bits: int, signed: bool = True) -> jnp.ndarray:
    """Min/max PTQ: pick s so the observed range maps onto [-Q_N, Q_P]."""
    qp, qn = qp_qn(bits, signed)
    if signed:
        amax = jnp.abs(t).max()
        s = amax / float(max(qn, 1))
    else:
        s = t.max() / float(max(qp, 1))
    return jnp.maximum(s, 1e-8).astype(jnp.float32)


def calibrate_mse(t: jnp.ndarray, bits: int, signed: bool = True,
                  n_grid: int = 40) -> jnp.ndarray:
    """MSE-optimal PTQ: grid-search the scale minimizing ||t - t_hat||^2."""
    base = calibrate_minmax(t, bits, signed)
    candidates = base * jnp.linspace(0.3, 1.2, n_grid)

    def mse(s):
        qp, qn = qp_qn(bits, signed)
        th = jnp.clip(jnp.round(t / s), -float(qn), float(qp)) * s
        return ((t - th) ** 2).mean()

    errs = jax.vmap(mse)(candidates)
    return candidates[jnp.argmin(errs)]


def quant_error(t: jnp.ndarray, s: jnp.ndarray, bits: int, signed: bool) -> jnp.ndarray:
    """error_q = t - t_hat  (paper §IV)."""
    tq = quantize_int(t, s, bits, signed)
    return t - dequantize(tq, s)

"""Pure-jnp correctness oracles for the bitserial kernels.

Everything here is written in the most obvious way possible (integer matmuls
and ``lax.conv_general_dilated`` over small integers, which are exact in
float32 up to 2^24) so it can serve as the trusted reference for:

* the Pallas plane-matmul kernel (``bitserial.py``),
* the packed-word popcount mirror (``pack.popcount_dot_words``),
* the Rust native kernels (via golden vectors exported by ``aot.py``).
"""

from __future__ import annotations

import jax.lax as lax
import jax.numpy as jnp

from . import pack


def ref_gemm_i32(aq: jnp.ndarray, wq: jnp.ndarray) -> jnp.ndarray:
    """Integer GEMM oracle: ``aq (M,K) @ wq (N,K).T`` in int32."""
    return (aq.astype(jnp.int32) @ wq.astype(jnp.int32).T).astype(jnp.int32)


def ref_bitserial_gemm(
    aq: jnp.ndarray, wq: jnp.ndarray, a_bits: int, w_bits: int
) -> jnp.ndarray:
    """Bitserial GEMM oracle with signed weights via offset encoding.

    ``aq``: unsigned activations ``(M, K)`` in ``[0, 2^a_bits)``.
    ``wq``: *signed* weights ``(N, K)`` in ``[-Q_N, Q_P]``.
    Computed the bitserial way (planes + shifts + offset correction) but with
    dense integer arithmetic — must equal ``ref_gemm_i32(aq, wq)`` exactly.
    """
    _, qn = pack.qp_qn(w_bits, signed=True)
    wu = pack.offset_encode(wq, w_bits)  # [0, 2^w)
    a_planes = pack.to_planes(aq, a_bits)  # (a_bits, M, K)
    w_planes = pack.to_planes(wu, w_bits)  # (w_bits, N, K)
    out = jnp.zeros((aq.shape[0], wq.shape[0]), jnp.int32)
    for i in range(w_bits):
        for j in range(a_bits):
            dot = a_planes[j].astype(jnp.int32) @ w_planes[i].astype(jnp.int32).T
            out = out + (dot << (i + j))
    # offset correction: W.A = W'.A - Q_N * sum_k a
    a_sum = aq.astype(jnp.int32).sum(axis=1, keepdims=True)  # (M, 1)
    return out - qn * a_sum


def ref_qconv2d_i32(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    stride: tuple[int, int] = (1, 1),
    padding: tuple[int, int] = (0, 0),
) -> jnp.ndarray:
    """Integer conv oracle. ``xq``: NHWC uint, ``wq``: HWIO signed int.

    Exact int32 result via float conv over small integers.
    """
    out = lax.conv_general_dilated(
        xq.astype(jnp.float32),
        wq.astype(jnp.float32),
        window_strides=stride,
        padding=[(padding[0], padding[0]), (padding[1], padding[1])],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return jnp.round(out).astype(jnp.int32)


def im2col(
    x: jnp.ndarray,
    kh: int,
    kw: int,
    stride: tuple[int, int] = (1, 1),
    padding: tuple[int, int] = (0, 0),
) -> jnp.ndarray:
    """NHWC → (N*OH*OW, KH*KW*C) patch matrix (zero padded).

    Row-major patch layout (kh, kw, c) — identical to the Rust runtime's
    im2col so packed goldens line up word-for-word.
    """
    n, h, w, c = x.shape
    ph, pw = padding
    sh, sw = stride
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (w + 2 * pw - kw) // sw + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = lax.slice(
                xp,
                (0, i, j, 0),
                (n, i + (oh - 1) * sh + 1, j + (ow - 1) * sw + 1, c),
                (1, sh, sw, 1),
            )
            cols.append(patch.reshape(n * oh * ow, c))
    # interleave so each row is (kh, kw, c) contiguous per patch
    stacked = jnp.stack(cols, axis=1)  # (rows, KH*KW, C)
    return stacked.reshape(stacked.shape[0], -1)


def conv_out_hw(
    h: int, w: int, kh: int, kw: int, stride: tuple[int, int], padding: tuple[int, int]
) -> tuple[int, int]:
    oh = (h + 2 * padding[0] - kh) // stride[0] + 1
    ow = (w + 2 * padding[1] - kw) // stride[1] + 1
    return oh, ow


def ref_bitserial_conv2d_i32(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    a_bits: int,
    w_bits: int,
    stride: tuple[int, int] = (1, 1),
    padding: tuple[int, int] = (0, 0),
) -> jnp.ndarray:
    """Bitserial conv oracle = im2col + ref_bitserial_gemm. NHWC/HWIO."""
    n, h, w, _c = xq.shape
    kh, kw, _ci, co = wq.shape
    cols = im2col(xq, kh, kw, stride, padding)  # (N*OH*OW, KH*KW*C)
    wmat = wq.reshape(-1, co).T  # (CO, KH*KW*C)
    out = ref_bitserial_gemm(cols, wmat, a_bits, w_bits)
    oh, ow = conv_out_hw(h, w, kh, kw, stride, padding)
    return out.reshape(n, oh, ow, co)

"""Pallas bitserial GEMM / conv kernels (TPU-adapted, run with interpret=True).

Hardware adaptation (DESIGN.md §3): the paper computes the low-bit dot
product on Arm Neon as ``POPCOUNT(W[i] & A[j])`` over packed words. On a
TPU there is no vector popcount, but over {0,1}-valued planes

    POPCOUNT(W[i] & A[j])  ==  A[j] @ W[i].T

so each bitplane pair becomes an MXU matmul, and the multi-bit product is

    out = sum_i sum_j (A_planes[j] @ W_planes[i].T) << (i + j)
          - Q_N * rowsum(A)                      (signed-weight offset fix)

The kernel tiles M (rows = output pixels) and N (cols = output channels)
across the Pallas grid and streams K (reduction = kh*kw*cin) in blocks,
accumulating in the output ref — the BlockSpec schedule plays the role the
paper's threadblock tiling plays on Arm (HBM→VMEM instead of DRAM→L1).

Values are small integers; float32 accumulation is exact below 2^24 (the
tests check tighter bounds than any real layer reaches).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pack

# Default tile sizes: chosen so one (a_bits + w_bits + 1)-plane working set
# fits VMEM comfortably on a real TPU (see DESIGN.md §8) while staying
# interpreter-friendly. 128 matches the MXU systolic dimension.
TM, TN, TK = 128, 128, 128


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _bitserial_kernel(a_ref, w_ref, o_ref, *, a_bits: int, w_bits: int, nk: int):
    """Grid = (M/TM, N/TN, K/TK); accumulate plane matmuls into o_ref."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = o_ref[...]
    for i in range(w_bits):
        wp = w_ref[i]  # (TN, TK)
        for j in range(a_bits):
            ap = a_ref[j]  # (TM, TK)
            # {0,1} plane matmul == AND+POPCOUNT reduction (MXU on real TPU)
            dot = jax.lax.dot_general(
                ap,
                wp,
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            acc = acc + dot * float(1 << (i + j))
    o_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("a_bits", "w_bits", "tm", "tn", "tk", "interpret")
)
def bitserial_gemm(
    aq: jnp.ndarray,
    wq: jnp.ndarray,
    *,
    a_bits: int,
    w_bits: int,
    tm: int = TM,
    tn: int = TN,
    tk: int = TK,
    interpret: bool = True,
) -> jnp.ndarray:
    """Bitserial GEMM: unsigned ``aq (M,K)`` x signed ``wq (N,K)`` → int32 (M,N).

    Bit-exact vs ``ref.ref_gemm_i32`` for inputs in the quantizer's ranges.
    """
    m, k = aq.shape
    n, k2 = wq.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    _, qn = pack.qp_qn(w_bits, signed=True)

    a_planes = pack.to_planes(aq, a_bits)  # (a_bits, M, K)
    w_planes = pack.to_planes(pack.offset_encode(wq, w_bits), w_bits)

    # Zero padding is safe: zero planes contribute nothing to any dot.
    a_planes = _pad_to(_pad_to(a_planes, 1, tm), 2, tk)
    w_planes = _pad_to(_pad_to(w_planes, 1, tn), 2, tk)
    mp, kp = a_planes.shape[1], a_planes.shape[2]
    np_ = w_planes.shape[1]
    grid = (mp // tm, np_ // tn, kp // tk)

    out = pl.pallas_call(
        functools.partial(
            _bitserial_kernel, a_bits=a_bits, w_bits=w_bits, nk=grid[2]
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((a_bits, tm, tk), lambda mi, ni, ki: (0, mi, ki)),
            pl.BlockSpec((w_bits, tn, tk), lambda mi, ni, ki: (0, ni, ki)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_planes, w_planes)

    out = out[:m, :n].astype(jnp.int32)
    # signed-weight offset correction (computed once per row, cf. Rust kernel)
    a_sum = aq.astype(jnp.int32).sum(axis=1, keepdims=True)
    return out - qn * a_sum


def bitserial_conv2d(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    *,
    a_bits: int,
    w_bits: int,
    stride: tuple[int, int] = (1, 1),
    padding: tuple[int, int] = (0, 0),
    tm: int = TM,
    tn: int = TN,
    tk: int = TK,
    interpret: bool = True,
) -> jnp.ndarray:
    """Bitserial conv2d = im2col + Pallas bitserial GEMM.

    ``xq``: NHWC unsigned activations; ``wq``: HWIO signed weights → int32
    NHWC accumulators. Matches ``ref.ref_qconv2d_i32`` exactly.
    """
    from . import ref as _ref

    n, h, w, _c = xq.shape
    kh, kw, _ci, co = wq.shape
    cols = _ref.im2col(xq, kh, kw, stride, padding)
    wmat = wq.reshape(-1, co).T
    out = bitserial_gemm(
        cols, wmat, a_bits=a_bits, w_bits=w_bits, tm=tm, tn=tn, tk=tk,
        interpret=interpret,
    )
    oh, ow = _ref.conv_out_hw(h, w, kh, kw, stride, padding)
    return out.reshape(n, oh, ow, co)


def qconv2d_f32(
    x: jnp.ndarray,
    w: jnp.ndarray,
    s_x: jnp.ndarray,
    s_w: jnp.ndarray,
    *,
    a_bits: int,
    w_bits: int,
    stride: tuple[int, int] = (1, 1),
    padding: tuple[int, int] = (0, 0),
    bias: jnp.ndarray | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Full quantized conv: quantize f32 inputs → bitserial conv → dequantize.

    This is the op the L2 model graphs call; the quantize / dequantize steps
    fuse into the surrounding HLO at lowering time.
    """
    qp_a, _ = pack.qp_qn(a_bits, signed=False)
    qp_w, qn_w = pack.qp_qn(w_bits, signed=True)
    xq = jnp.clip(jnp.round(x / s_x), 0, qp_a).astype(jnp.int32)
    wq = jnp.clip(jnp.round(w / s_w), -qn_w, qp_w).astype(jnp.int32)
    acc = bitserial_conv2d(
        xq, wq, a_bits=a_bits, w_bits=w_bits, stride=stride, padding=padding,
        interpret=interpret,
    )
    out = acc.astype(jnp.float32) * (s_x * s_w)
    if bias is not None:
        out = out + bias
    return out

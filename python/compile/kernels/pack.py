"""Bitplane packing / unpacking for ultra-low-bit bitserial arithmetic.

The paper (DeepliteRT §V) decomposes w-bit weights and a-bit activations into
bitplanes so that the dot product becomes

    W . A = sum_i sum_j  POPCOUNT(W[i] & A[j]) << (i + j)

Two representations are provided here:

* **plane representation** — each bitplane is a {0,1}-valued float array.
  On TPU, ``POPCOUNT(W[i] & A[j])`` over {0,1} planes is *exactly* the
  matmul ``A[j] @ W[i].T``, which the Pallas kernel feeds to the MXU
  (see DESIGN.md §Hardware-Adaptation).
* **packed-word representation** — bitplanes packed 32 lanes per ``uint32``
  along the reduction axis, mirroring the Rust runtime's u64 layout
  (modulo word width). Used as the golden reference for cross-layer
  parity tests against the Rust popcount kernels.

Encoding conventions (match the paper's quantizer, §IV):

* activations: unipolar unsigned, ``a ∈ [0, 2^a_bits - 1]``
* weights: signed, ``w ∈ [-Q_N, Q_P]`` with ``Q_P = 2^(b-1)-1``,
  ``Q_N = 2^(b-1)``; bitserial kernels consume the *offset encoding*
  ``w' = w + Q_N ∈ [0, 2^b - 1]`` and correct with ``- Q_N * sum(a)``.
"""

from __future__ import annotations

import jax.numpy as jnp


def qp_qn(bits: int, signed: bool = True) -> tuple[int, int]:
    """Clipping limits (Q_P, Q_N) for a ``bits``-bit code (paper §IV)."""
    if bits < 1:
        raise ValueError(f"bits must be >= 1, got {bits}")
    if signed:
        return 2 ** (bits - 1) - 1, 2 ** (bits - 1)
    return 2**bits - 1, 0


def to_planes(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Decompose unsigned integer-valued ``x`` into ``bits`` {0,1} planes.

    Returns float32 array of shape ``(bits, *x.shape)``; plane ``i`` holds
    bit ``i`` (LSB first). Values must lie in ``[0, 2^bits)``.
    """
    xi = x.astype(jnp.int32)
    planes = [(xi >> i) & 1 for i in range(bits)]
    return jnp.stack(planes).astype(jnp.float32)


def from_planes(planes: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`to_planes` → int32 array of shape ``planes.shape[1:]``."""
    bits = planes.shape[0]
    p = planes.astype(jnp.int32)
    out = jnp.zeros(planes.shape[1:], jnp.int32)
    for i in range(bits):
        out = out + (p[i] << i)
    return out


def offset_encode(wq: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Signed quantized weights ``[-Q_N, Q_P]`` → unsigned ``[0, 2^bits)``."""
    _, qn = qp_qn(bits, signed=True)
    return wq.astype(jnp.int32) + qn


def offset_decode(wu: jnp.ndarray, bits: int) -> jnp.ndarray:
    _, qn = qp_qn(bits, signed=True)
    return wu.astype(jnp.int32) - qn


def pack_words_u32(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack the last axis of unsigned ``x`` into uint32 words per bitplane.

    ``x``: integer-valued, shape ``(..., K)``, values in ``[0, 2^bits)``.
    Returns uint32 of shape ``(bits, ..., ceil(K/32))``: bit ``k % 32`` of
    word ``k // 32`` in plane ``i`` is bit ``i`` of ``x[..., k]``.

    This mirrors the Rust runtime's packed layout (which uses u64 words;
    2 consecutive u32 words == 1 u64 word, little-endian lane order).
    """
    k = x.shape[-1]
    pad = (-k) % 32
    xi = x.astype(jnp.uint32)
    if pad:
        xi = jnp.pad(xi, [(0, 0)] * (xi.ndim - 1) + [(0, pad)])
    lanes = jnp.arange(32, dtype=jnp.uint32)
    grouped = xi.reshape(*xi.shape[:-1], -1, 32)  # (..., W, 32)
    planes = []
    for i in range(bits):
        bit = (grouped >> i) & 1
        word = (bit << lanes).sum(axis=-1, dtype=jnp.uint32)
        planes.append(word)
    return jnp.stack(planes)


def unpack_words_u32(words: jnp.ndarray, k: int) -> jnp.ndarray:
    """Inverse of :func:`pack_words_u32` → int32 of shape ``(..., k)``."""
    bits = words.shape[0]
    lanes = jnp.arange(32, dtype=jnp.uint32)
    out = jnp.zeros(words.shape[1:-1] + (words.shape[-1] * 32,), jnp.int32)
    for i in range(bits):
        bit = ((words[i][..., None] >> lanes) & 1).astype(jnp.int32)
        out = out + (bit.reshape(*bit.shape[:-2], -1) << i)
    return out[..., :k]


def popcount_dot_words(a_words: jnp.ndarray, w_words: jnp.ndarray) -> jnp.ndarray:
    """Bitserial dot product over packed words — the paper's eq. (§V), verbatim.

    ``a_words``: uint32 ``(a_bits, M, W)``; ``w_words``: uint32 ``(w_bits, N, W)``.
    Returns int32 ``(M, N)`` = sum_ij popcount(W[i] & A[j]) << (i+j).

    Pure-jnp mirror of the Rust u64 kernel; used for parity goldens only
    (the fast TPU path is the plane-matmul Pallas kernel).
    """
    import jax.lax as lax

    a_bits, _m, _w = a_words.shape
    w_bits = w_words.shape[0]
    out = None
    for i in range(w_bits):
        for j in range(a_bits):
            anded = jnp.bitwise_and(a_words[j][:, None, :], w_words[i][None, :, :])
            pc = lax.population_count(anded).astype(jnp.int32).sum(axis=-1)
            term = pc << (i + j)
            out = term if out is None else out + term
    return out

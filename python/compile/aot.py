"""AOT build: lower JAX graphs to HLO text + export interchange artifacts.

Run once via ``make artifacts`` (never on the request path). Produces under
``artifacts/``:

* ``<name>.hlo.txt``        — HLO text for the Rust PJRT runtime
  (HLO *text*, not ``.serialize()``: jax>=0.5 emits 64-bit instruction ids
  that xla_extension 0.5.1 rejects; the text parser reassigns ids)
* ``<name>.manifest.json``  — parameter order/shapes for the HLO entry
* ``models/<name>/``        — arch.json + weights.bin for ``dlrt compile``
* ``golden/``               — cross-layer parity vectors (kernel + model)
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import export as ex
from . import jax_exec
from .graph import Graph, set_mixed_precision
from .kernels import bitserial
from .models import REGISTRY


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_graph(g: Graph, params: dict, state: dict, mode: str,
                out_dir: Path, name: str) -> None:
    """Lower ``run(g, ...)`` to HLO text with parameters passed positionally.

    Parameter order = sorted(params) ++ sorted(state) ++ [input]; recorded in
    the manifest so the Rust runtime can feed literals in order.
    """
    pkeys = sorted(params)
    skeys = sorted(state)

    def fn(*args):
        p = dict(zip(pkeys, args[: len(pkeys)]))
        s = dict(zip(skeys, args[len(pkeys): len(pkeys) + len(skeys)]))
        x = args[-1]
        outs, _ = jax_exec.run(g, p, s, x, mode=mode, train=False)
        return tuple(outs)

    specs = [jax.ShapeDtypeStruct(np.asarray(params[k]).shape, jnp.float32)
             for k in pkeys]
    specs += [jax.ShapeDtypeStruct(np.asarray(state[k]).shape, jnp.float32)
              for k in skeys]
    specs.append(jax.ShapeDtypeStruct(g.input_shape, jnp.float32))
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    (out_dir / f"{name}.hlo.txt").write_text(to_hlo_text(lowered))
    manifest = {
        "name": name, "graph": g.name, "mode": mode,
        "input_shape": list(g.input_shape),
        "params": [{"name": k, "shape": list(np.asarray(params[k]).shape)}
                   for k in pkeys],
        "state": [{"name": k, "shape": list(np.asarray(state[k]).shape)}
                  for k in skeys],
        "outputs": g.outputs,
    }
    (out_dir / f"{name}.manifest.json").write_text(json.dumps(manifest, indent=1))


def lower_bitserial_gemm(out_dir: Path, m: int = 256, k: int = 256, n: int = 128,
                         a_bits: int = 2, w_bits: int = 2) -> None:
    """Kernel-only artifact: the Pallas bitserial GEMM as loadable HLO."""

    def fn(aq, wq):
        return (bitserial.bitserial_gemm(aq, wq, a_bits=a_bits, w_bits=w_bits),)

    specs = (jax.ShapeDtypeStruct((m, k), jnp.int32),
             jax.ShapeDtypeStruct((n, k), jnp.int32))
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    name = f"bitserial_gemm_m{m}k{k}n{n}_{a_bits}a{w_bits}w"
    (out_dir / f"{name}.hlo.txt").write_text(to_hlo_text(lowered))
    (out_dir / f"{name}.manifest.json").write_text(json.dumps({
        "name": name, "m": m, "k": k, "n": n,
        "a_bits": a_bits, "w_bits": w_bits}))


def build_artifacts(out_root: str) -> None:
    out = Path(out_root)
    (out / "models").mkdir(parents=True, exist_ok=True)
    (out / "golden").mkdir(exist_ok=True)

    # --- kernel goldens (Rust unit tests consume these)
    ex.export_kernel_goldens(out / "golden" / "kernels.json")

    # --- kernel-only PJRT artifacts
    lower_bitserial_gemm(out)
    lower_bitserial_gemm(out, m=64, k=64, n=32, a_bits=1, w_bits=2)

    # --- interchange + goldens for small models (format/parity tests)
    rng = np.random.default_rng(0)
    small_models = [
        ("resnet18_mini", REGISTRY["resnet18"](num_classes=2, resolution=64,
                                               width_mult=0.25)),
        ("yolov5n_mini", REGISTRY["yolov5n"](num_classes=8, resolution=64,
                                             width_mult=0.5)),
    ]
    for name, g in small_models:
        set_mixed_precision(g, quantize_from=1, w_bits=2, a_bits=2)
        params, state = jax_exec.init_params(g, seed=42)
        # randomize BN state a bit so folding is non-trivial in parity tests
        for k in state:
            if k.endswith(".mean"):
                state[k] = jnp.asarray(rng.normal(0, 0.05, state[k].shape),
                                       jnp.float32)
            else:
                state[k] = jnp.asarray(rng.uniform(0.5, 1.5, state[k].shape),
                                       jnp.float32)
        # calibrate activation scales so the quantized path is non-degenerate
        xs = [jnp.asarray(rng.uniform(0, 1, (2, *g.input_shape[1:])), jnp.float32)]
        params = jax_exec.calibrate_activation_scales(g, params, state, xs)
        ex.export_model(g, params, state, out / "models" / name)
        x = jnp.asarray(rng.uniform(0, 1, g.input_shape), jnp.float32)
        ex.export_golden(g, params, state, x, out / "golden" / f"{name}.json",
                         mode="deploy_sim")
        ex.export_golden(g, params, state, x,
                         out / "golden" / f"{name}_fp32.json", mode="fp32")

    # --- PJRT model artifacts (FP32 baseline engine + quantized kernel graph)
    g = REGISTRY["resnet18"](num_classes=1000, resolution=96)
    params, state = jax_exec.init_params(g, seed=0)
    lower_graph(g, params, state, "fp32", out, "resnet18_fp32_96")

    g = REGISTRY["resnet18"](num_classes=2, resolution=64, width_mult=0.25)
    set_mixed_precision(g, quantize_from=1, w_bits=2, a_bits=2)
    params, state = jax_exec.init_params(g, seed=42)
    lower_graph(g, params, state, "deploy_kernel", out, "resnet18_mini_2a2w")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    build_artifacts(args.out)
    print(f"artifacts written to {args.out}")


if __name__ == "__main__":
    main()

"""Fig. 2 accuracy side — detection accuracy across model scales.

Paper's Fig. 2 shows the accuracy cliff of shrinking YOLOv5 variants
(n → smaller) on VOC/COCO — the motivation for quantizing a *larger*
model instead of shrinking further. We reproduce the trend on the
synth-shapes stand-in: detector capacity (width) sweep, FP32 vs 2A2W QAT,
showing (a) accuracy falls as width shrinks and (b) a quantized wide model
beats a small FP32 model (the paper's argument).
"""

from __future__ import annotations

import numpy as np

from compile import datasets, qat
from compile.graph import QCfg

from . import common

RES = 32
GRID = 4
STEPS = 260
EVAL_N = 192


def main() -> None:
    rng = np.random.default_rng(77)
    eval_data = datasets.synth_shapes(rng, EVAL_N, res=RES, grid=GRID)
    data_fn = lambda r, n: datasets.synth_shapes(r, n, res=RES, grid=GRID)
    cfg = qat.TrainConfig(steps=STEPS, batch_size=24, lr=0.02, seed=0, log_every=80)

    widths = [1.0, 0.5, 0.25]  # "m / s / n"-like capacity ladder
    results = {}
    ft_cfg = qat.TrainConfig(steps=STEPS // 2, batch_size=24, lr=0.008, seed=1,
                             log_every=80)
    for w in widths:
        g_fp = common.small_detector(w, RES, grid=GRID, mixed="none")
        m, hist, ckpt = common.train_eval_detector(g_fp, data_fn, eval_data, cfg)
        results[f"w{w}_FP32"] = {"map50": m, "loss_curve": hist}
        print(f"width {w} FP32: mAP@0.5 = {m:.3f}")
        g = common.small_detector(w, RES, grid=GRID, qcfg=QCfg(2, 2), mixed="conservative")
        init = common.warm_start(g, *ckpt)
        init = (common.calibrate(g, init[0], init[1], data_fn), init[1])
        m, hist, _ = common.train_eval_detector(g, data_fn, eval_data, ft_cfg,
                                                init=init)
        results[f"w{w}_2A2W"] = {"map50": m, "loss_curve": hist}
        print(f"width {w} 2A2W: mAP@0.5 = {m:.3f}")

    rec = {
        "experiment": "fig2_yolo_accuracy",
        "dataset": "synth-shapes (COCO-8/VOC stand-in)",
        "sweep": "detector width in {1.0, 0.5, 0.25}, FP32 vs 2A2W QAT",
        "paper": "Fig.2: accuracy drops sharply for smaller YOLOv5 variants",
        "results": results,
    }
    common.save("fig2_yolo_accuracy", rec)

    print("\ntrend check (paper's motivation):")
    for w in widths:
        print(f"  width {w}: FP32 {results[f'w{w}_FP32']['map50']:.3f}  "
              f"2A2W {results[f'w{w}_2A2W']['map50']:.3f}")


if __name__ == "__main__":
    main()

"""Table I accuracy side — conservative mixed precision on COCO-8 stand-in.

Paper row: YOLOv5n FP32 mAP 0.424 → mixed (FP32 + 2-bit, conservative)
mAP 0.414 (~1% drop) with 2.54x latency reduction. We train the detector
stand-in on synth-shapes (8 classes = the paper's person/dog/cat/car/bus/
truck/bicycle/motorcycle subset) under three policies:

  FP32          — no quantization (paper's baseline row)
  conservative  — stem + last body conv + head FP32, rest 2-bit (paper's row)
  aggressive    — everything but stem/head 2-bit (shows why 'conservative'
                  is needed on compact detectors)
"""

from __future__ import annotations

import numpy as np

from compile import datasets, qat
from compile.graph import QCfg

from . import common

RES = 32
GRID = 4
STEPS = 300
EVAL_N = 224


def main() -> None:
    rng = np.random.default_rng(5150)
    eval_data = datasets.synth_shapes(rng, EVAL_N, res=RES, grid=GRID)
    data_fn = lambda r, n: datasets.synth_shapes(r, n, res=RES, grid=GRID)
    cfg = qat.TrainConfig(steps=STEPS, batch_size=24, lr=0.02, seed=3, log_every=100)

    results = {}
    # full-precision training first (Neutrino pipeline), then QAT fine-tune
    g_fp = common.small_detector(0.5, RES, grid=GRID, mixed="none")
    m, hist, ckpt = common.train_eval_detector(g_fp, data_fn, eval_data, cfg)
    results["fp32"] = {"map50": m, "loss_curve": hist}
    print(f"fp32: mAP@0.5 = {m:.3f}")
    ft_cfg = qat.TrainConfig(steps=STEPS // 2, batch_size=24, lr=0.008, seed=4,
                             log_every=100)
    for tag, mixed in [("mixed", "conservative"), ("aggressive", "all")]:
        g = common.small_detector(0.5, RES, grid=GRID, qcfg=QCfg(2, 2), mixed=mixed)
        init = common.warm_start(g, *ckpt)
        init = (common.calibrate(g, init[0], init[1], data_fn), init[1])
        m, hist, _ = common.train_eval_detector(g, data_fn, eval_data, ft_cfg,
                                                init=init)
        results[tag] = {"map50": m, "loss_curve": hist}
        print(f"{tag}: mAP@0.5 = {m:.3f}")

    rec = {
        "experiment": "table1_yolov5n",
        "dataset": "synth-shapes-8 (COCO-8 stand-in)",
        "policy": "conservative mixed precision (paper Table I)",
        "paper": {"map_fp32": 0.424, "map_mixed": 0.414,
                  "latency_fp32_ms": 250, "latency_mixed_ms": 98.371},
        "map_fp32": results["fp32"]["map50"],
        "map_mixed": results["mixed"]["map50"],
        "map_aggressive": results["aggressive"]["map50"],
        "results": results,
    }
    common.save("table1_yolov5n", rec)
    drop = rec["map_fp32"] - rec["map_mixed"]
    print(f"\nmAP drop (conservative mixed): {drop:.3f} (paper: 0.010)")


if __name__ == "__main__":
    main()

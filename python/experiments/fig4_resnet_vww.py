"""Fig. 4/5 accuracy side — ResNet18 on the VWW stand-in.

Paper: 2A/2W drops < 1% accuracy, 1A/2W drops < 2% vs FP32 on VWW.
We train the width-0.25 ResNet18 at 32px on synth-vww and measure the
deployment (integer-exact) accuracy of FP32 vs 2A2W vs 1A2W.
"""

from __future__ import annotations

import numpy as np

from compile import datasets, qat
from compile.graph import QCfg

from . import common

RES = 32
STEPS = 220
EVAL_N = 512


def main() -> None:
    rng = np.random.default_rng(1234)
    eval_data = datasets.synth_vww(rng, EVAL_N, res=RES)
    data_fn = lambda r, n: datasets.synth_vww(r, n, res=RES)
    cfg = qat.TrainConfig(steps=STEPS, batch_size=32, lr=0.05, seed=0, log_every=50)

    results = {}
    g_fp = common.classifier(0.25, RES, 2, quantize=False)
    acc, hist, ckpt = common.train_eval_classifier(g_fp, data_fn, eval_data, cfg)
    results["FP32"] = {"accuracy": acc, "loss_curve": hist}
    print(f"FP32: deploy accuracy {acc:.4f}")
    ft_cfg = qat.TrainConfig(steps=STEPS // 2, batch_size=32, lr=0.01, seed=1,
                             log_every=50)
    for tag, qcfg in [("2A2W", QCfg(2, 2)), ("1A2W", QCfg(2, 1))]:
        g = common.classifier(0.25, RES, 2, qcfg=qcfg, quantize=True)
        init = common.warm_start(g, *ckpt)
        init = (common.calibrate(g, init[0], init[1], data_fn), init[1])
        acc, hist, _ = common.train_eval_classifier(g, data_fn, eval_data, ft_cfg,
                                                    init=init)
        results[tag] = {"accuracy": acc, "loss_curve": hist}
        print(f"{tag}: deploy accuracy {acc:.4f}")

    rec = {
        "experiment": "fig4_resnet_vww",
        "dataset": "synth-vww (VWW stand-in)",
        "model": "resnet18 w0.25 @32px",
        "steps": STEPS,
        "paper": {"drop_2A2W": "<1%", "drop_1A2W": "<2%",
                  "size_reduction": "15.58x", "speedup_pi3": "3.75x"},
        "results": results,
        "drop_2A2W": results["FP32"]["accuracy"] - results["2A2W"]["accuracy"],
        "drop_1A2W": results["FP32"]["accuracy"] - results["1A2W"]["accuracy"],
    }
    common.save("fig4_resnet_vww", rec)
    print(f"\ndrop 2A2W: {rec['drop_2A2W'] * 100:.2f}% (paper <1%)")
    print(f"drop 1A2W: {rec['drop_1A2W'] * 100:.2f}% (paper <2%)")


if __name__ == "__main__":
    main()

"""Shared helpers for the accuracy experiments."""

from __future__ import annotations

import json
from pathlib import Path

from compile import qat
from compile.graph import Graph, GraphBuilder, QCfg

OUT_DIR = Path(__file__).resolve().parents[2] / "artifacts" / "experiments"


def save(name: str, record: dict) -> Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{name}.json"
    path.write_text(json.dumps(record, indent=1))
    print(f"wrote {path}")
    return path


def small_detector(width: float, res: int, num_classes: int = 8,
                   grid: int | None = None, qcfg: QCfg | None = None,
                   mixed: str = "none") -> Graph:
    """Single-scale YOLO-style detector used as the accuracy stand-in.

    Downsamples 8x (grid = res/8). ``mixed`` controls the precision policy:
      none   — every conv FP32
      all    — every conv (except stem/head) quantized with ``qcfg``
      conservative — like 'all' but the last body conv also stays FP32
                     (the paper's Table-I policy)
    """
    q = qcfg or QCfg(2, 2)

    def pick(i: int, total: int) -> QCfg:
        if mixed == "none":
            return QCfg(enabled=False)
        if i == 0:  # stem
            return QCfg(enabled=False)
        if mixed == "conservative" and i >= total - 2:
            return QCfg(enabled=False)
        return q

    c1 = max(8, int(16 * width))
    c2 = max(8, int(32 * width))
    c3 = max(12, int(64 * width))
    total = 5
    b = GraphBuilder("smalldet", (1, res, res, 3))
    x = b.conv("input", c1, k=3, stride=2, act="relu", qcfg=pick(0, total), name="c0")
    x = b.conv(x, c2, k=3, stride=2, act="relu", qcfg=pick(1, total), name="c1")
    x = b.conv(x, c2, k=3, stride=1, act="relu", qcfg=pick(2, total), name="c2")
    x = b.conv(x, c3, k=3, stride=2, act="relu", qcfg=pick(3, total), name="c3")
    x = b.conv(x, c3, k=3, stride=1, act="relu", qcfg=pick(4, total), name="c4")
    head = b.conv(x, 5 + num_classes, k=1, padding=0, bn=False,
                  qcfg=QCfg(enabled=False), name="head")
    return b.finish([head])


def classifier(width: float, res: int, num_classes: int,
               qcfg: QCfg | None = None, quantize: bool = True) -> Graph:
    """ResNet-ish classifier stand-in (stem FP32, body quantizable)."""
    from compile.graph import set_mixed_precision
    from compile.models import REGISTRY

    g = REGISTRY["resnet18"](num_classes=num_classes, resolution=res,
                             width_mult=width)
    if quantize and qcfg is not None:
        set_mixed_precision(g, quantize_from=1, w_bits=qcfg.w_bits,
                            a_bits=qcfg.a_bits)
    else:
        set_mixed_precision(g, quantize_from=10**9)  # all FP32
    return g


def warm_start(g_quant: Graph, fp32_params: dict, fp32_state: dict, seed: int = 0):
    """Initialize a quantized graph from a trained FP32 checkpoint
    (the Neutrino pipeline: full-precision training → QAT fine-tune)."""
    from compile import jax_exec, quant

    params, state = jax_exec.init_params(g_quant, seed=seed)
    for k in params:
        if k in fp32_params:
            params[k] = fp32_params[k]
    for k in state:
        if k in fp32_state:
            state[k] = fp32_state[k]
    # re-fit weight scales on the warm weights
    for n in g_quant.conv_nodes():
        qcfg = n.attrs["qcfg"]
        if qcfg.enabled:
            params[f"{n.name}.s_w"] = quant.init_scale(
                params[f"{n.name}.w"], qcfg.w_bits, signed=True)
    return params, state


def calibrate(g_quant: Graph, params: dict, state: dict, data_fn, batches: int = 2,
              batch_size: int = 32):
    """Set activation scales from observed FP32 ranges (PTQ-style init) —
    without this, warm-started QAT starts from badly clipped activations."""
    import numpy as np

    from compile import jax_exec

    rng = np.random.default_rng(99)
    xs = [data_fn(rng, batch_size)[0] for _ in range(batches)]
    import jax.numpy as jnp

    return jax_exec.calibrate_activation_scales(
        g_quant, params, state, [jnp.asarray(x) for x in xs])


def train_eval_classifier(g: Graph, data_fn, eval_data, cfg: qat.TrainConfig,
                          init=None):
    params, state = init if init is not None else (None, None)
    params, state, hist = qat.train(g, data_fn, qat.softmax_xent, cfg,
                                    params=params, state=state)
    xe, ye = eval_data
    acc = qat.eval_classifier(g, params, state, xe, ye, mode="deploy_sim")
    return acc, hist, (params, state)


def train_eval_detector(g: Graph, data_fn, eval_data, cfg: qat.TrainConfig,
                        init=None):
    params, state = init if init is not None else (None, None)
    params, state, hist = qat.train(g, data_fn, qat.detection_grid_loss, cfg,
                                    params=params, state=state)
    xe, te = eval_data
    m = qat.eval_detector_map(g, params, state, xe, te, mode="deploy_sim")
    return m, hist, (params, state)

"""Accuracy experiments on synthetic stand-in datasets (DESIGN.md §2, §6).

Each module trains small models under QAT and writes a JSON record to
``artifacts/experiments/``; the Rust benches join these with the latency
side, and EXPERIMENTS.md records paper-vs-measured.
"""

"""Model zoo: topology validation, output shapes, mode consistency."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import jax_exec
from compile.graph import QCfg, set_mixed_precision
from compile.models import REGISTRY


def _mini(name, **kw):
    return REGISTRY[name](**kw)


def test_resnet18_shapes():
    g = _mini("resnet18", num_classes=10, resolution=64, width_mult=0.25)
    params, state = jax_exec.init_params(g, seed=0)
    x = jnp.zeros(g.input_shape, jnp.float32)
    outs, _ = jax_exec.run(g, params, state, x, mode="fp32")
    assert outs[0].shape == (1, 10)
    # 20 convs in resnet18 (1 stem + 16 block + 3 downsample)
    assert len(g.conv_nodes()) == 20


def test_resnet50_shapes():
    g = _mini("resnet50", num_classes=7, resolution=64, width_mult=0.125)
    params, state = jax_exec.init_params(g, seed=0)
    outs, _ = jax_exec.run(g, params, state, jnp.zeros(g.input_shape), mode="fp32")
    assert outs[0].shape == (1, 7)
    # 53 convs (1 stem + 48 block + 4 downsample)
    assert len(g.conv_nodes()) == 53


def test_vgg16_ssd_head_shapes():
    g = _mini("vgg16_ssd", num_classes=21, resolution=300, width_mult=0.125)
    params, state = jax_exec.init_params(g, seed=0)
    outs, _ = jax_exec.run(g, params, state, jnp.zeros(g.input_shape), mode="fp32")
    # 6 scales x (loc, conf); grid sizes of canonical SSD300
    grids = [38, 19, 10, 5, 3, 1]
    anchors = [4, 6, 6, 6, 4, 4]
    assert len(outs) == 12
    for si, (gsz, na) in enumerate(zip(grids, anchors)):
        loc, conf = outs[2 * si], outs[2 * si + 1]
        assert loc.shape == (1, gsz, gsz, na * 4), (si, loc.shape)
        assert conf.shape == (1, gsz, gsz, na * 21)
    total = sum(g_ * g_ * a for g_, a in zip(grids, anchors))
    assert total == 8732  # the SSD300 box count


@pytest.mark.parametrize("variant,res", [("n", 64), ("s", 64)])
def test_yolov5_detect_shapes(variant, res):
    g = _mini(f"yolov5{variant}", num_classes=8, resolution=res, width_mult=0.5)
    params, state = jax_exec.init_params(g, seed=0)
    outs, _ = jax_exec.run(g, params, state, jnp.zeros(g.input_shape), mode="fp32")
    no = 3 * (5 + 8)
    assert [o.shape for o in outs] == [
        (1, res // 8, res // 8, no), (1, res // 16, res // 16, no),
        (1, res // 32, res // 32, no)]


def test_yolov5_variant_scaling():
    gn = _mini("yolov5n", num_classes=80, resolution=64)
    gs = _mini("yolov5s", num_classes=80, resolution=64)
    gm = _mini("yolov5m", num_classes=80, resolution=64)
    pn = sum(np.prod([*n.attrs["kernel"], n.attrs["cin"], n.attrs["cout"]])
             for n in gn.conv_nodes())
    ps = sum(np.prod([*n.attrs["kernel"], n.attrs["cin"], n.attrs["cout"]])
             for n in gs.conv_nodes())
    pm = sum(np.prod([*n.attrs["kernel"], n.attrs["cin"], n.attrs["cout"]])
             for n in gm.conv_nodes())
    assert pn < ps < pm
    # s has ~4x the weights of n (width 0.5 vs 0.25); m deeper+wider still
    assert 2.5 < ps / pn < 5.5
    assert len(gm.conv_nodes()) > len(gs.conv_nodes())


def test_graph_validation_catches_bad_graphs():
    from compile.graph import Graph, Node

    g = Graph("bad", "input", (1, 8, 8, 3),
              [Node(op="relu", name="r", inputs=["nope"], output="r.out")],
              ["r.out"])
    with pytest.raises(ValueError, match="undefined"):
        g.validate()


def test_mixed_precision_policy():
    g = _mini("resnet18", num_classes=2, resolution=32, width_mult=0.25)
    set_mixed_precision(g, quantize_from=1, quantize_to=10, w_bits=2, a_bits=1)
    convs = g.conv_nodes()
    assert not convs[0].attrs["qcfg"].enabled          # stem stays FP32
    assert convs[1].attrs["qcfg"].tag == "1A2W"
    assert not convs[10].attrs["qcfg"].enabled


def test_deploy_sim_close_to_qat_fakequant():
    """Integer deployment must agree with fake-quant inference (same math)."""
    g = _mini("resnet18", num_classes=4, resolution=32, width_mult=0.25)
    set_mixed_precision(g, quantize_from=1, w_bits=2, a_bits=2)
    params, state = jax_exec.init_params(g, seed=1)
    rng = np.random.default_rng(2)
    xs = [jnp.asarray(rng.uniform(0, 1, (2, 32, 32, 3)), jnp.float32)]
    params = jax_exec.calibrate_activation_scales(g, params, state, xs)
    x = jnp.asarray(rng.uniform(0, 1, (1, 32, 32, 3)), jnp.float32)
    sim, _ = jax_exec.run(g, params, state, x, mode="deploy_sim")
    qat, _ = jax_exec.run(g, params, state, x, mode="qat", train=False)
    np.testing.assert_allclose(np.asarray(sim[0]), np.asarray(qat[0]),
                               rtol=1e-3, atol=1e-3)


def test_deploy_kernel_matches_deploy_sim():
    """Pallas path == integer oracle path on a real (mini) network."""
    g = _mini("resnet18", num_classes=3, resolution=32, width_mult=0.25)
    set_mixed_precision(g, quantize_from=1, w_bits=2, a_bits=2)
    params, state = jax_exec.init_params(g, seed=3)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.uniform(0, 1, (1, 32, 32, 3)), jnp.float32)
    sim, _ = jax_exec.run(g, params, state, x, mode="deploy_sim")
    ker, _ = jax_exec.run(g, params, state, x, mode="deploy_kernel")
    np.testing.assert_allclose(np.asarray(sim[0]), np.asarray(ker[0]),
                               rtol=1e-5, atol=1e-5)

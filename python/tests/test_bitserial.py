"""Pallas bitserial kernel vs pure-jnp oracles — the core L1 signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitserial, pack, ref


def rand_qtensors(rng, m, n, k, a_bits, w_bits):
    qp_w, qn_w = pack.qp_qn(w_bits, signed=True)
    a = rng.integers(0, 2**a_bits, size=(m, k))
    w = rng.integers(-qn_w, qp_w + 1, size=(n, k))
    return jnp.asarray(a), jnp.asarray(w)


@pytest.mark.parametrize("a_bits,w_bits", [(1, 1), (1, 2), (2, 2), (3, 2), (4, 4)])
def test_ref_bitserial_gemm_equals_int_gemm(a_bits, w_bits):
    rng = np.random.default_rng(42)
    a, w = rand_qtensors(rng, 9, 11, 37, a_bits, w_bits)
    got = ref.ref_bitserial_gemm(a, w, a_bits, w_bits)
    want = ref.ref_gemm_i32(a, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("a_bits,w_bits", [(1, 1), (1, 2), (2, 2)])
def test_pallas_gemm_exact_small(a_bits, w_bits):
    rng = np.random.default_rng(7)
    a, w = rand_qtensors(rng, 17, 13, 29, a_bits, w_bits)
    got = bitserial.bitserial_gemm(a, w, a_bits=a_bits, w_bits=w_bits,
                                   tm=8, tn=8, tk=8)
    want = ref.ref_gemm_i32(a, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_gemm_multi_tile_grid():
    """Exercise a >1 grid in every dimension incl. K accumulation."""
    rng = np.random.default_rng(3)
    a, w = rand_qtensors(rng, 40, 24, 70, 2, 2)
    got = bitserial.bitserial_gemm(a, w, a_bits=2, w_bits=2, tm=16, tn=8, tk=32)
    want = ref.ref_gemm_i32(a, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    a_bits=st.integers(1, 3),
    w_bits=st.integers(1, 3),
    m=st.integers(1, 33),
    n=st.integers(1, 17),
    k=st.integers(1, 65),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_gemm_property(a_bits, w_bits, m, n, k, seed):
    rng = np.random.default_rng(seed)
    a, w = rand_qtensors(rng, m, n, k, a_bits, w_bits)
    got = bitserial.bitserial_gemm(a, w, a_bits=a_bits, w_bits=w_bits,
                                   tm=16, tn=16, tk=16)
    want = ref.ref_gemm_i32(a, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("stride,padding", [((1, 1), (0, 0)), ((1, 1), (1, 1)),
                                            ((2, 2), (1, 1)), ((2, 1), (0, 1))])
def test_im2col_conv_matches_lax_conv(stride, padding):
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(0, 4, size=(2, 9, 8, 5)))
    w = jnp.asarray(rng.integers(-2, 2, size=(3, 3, 5, 6)))
    got = ref.ref_bitserial_conv2d_i32(x, w, 2, 2, stride, padding)
    want = ref.ref_qconv2d_i32(x, w, stride, padding)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("a_bits,w_bits", [(2, 2), (1, 2)])
def test_pallas_conv_matches_oracle(a_bits, w_bits):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(0, 2**a_bits, size=(1, 8, 8, 7)))
    qp, qn = pack.qp_qn(w_bits, signed=True)
    w = jnp.asarray(rng.integers(-qn, qp + 1, size=(3, 3, 7, 9)))
    got = bitserial.bitserial_conv2d(x, w, a_bits=a_bits, w_bits=w_bits,
                                     stride=(1, 1), padding=(1, 1),
                                     tm=32, tn=8, tk=16)
    want = ref.ref_qconv2d_i32(x, w, (1, 1), (1, 1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qconv2d_f32_dequant_scaling():
    """Quantize→bitserial→dequantize ≈ float conv of the fake-quantized inputs."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.uniform(0, 1.5, size=(1, 6, 6, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.2, size=(3, 3, 4, 5)), jnp.float32)
    s_x, s_w = jnp.float32(0.1), jnp.float32(0.05)
    out = bitserial.qconv2d_f32(x, w, s_x, s_w, a_bits=2, w_bits=2,
                                stride=(1, 1), padding=(1, 1))
    # reference: conv of the hard-quantized+dequantized tensors
    from compile import quant
    xq = quant.quantize_int(x, s_x, 2, signed=False)
    wq = quant.quantize_int(w, s_w, 2, signed=True)
    want = ref.ref_qconv2d_i32(xq, wq, (1, 1), (1, 1)).astype(jnp.float32) * (s_x * s_w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)

"""LSQ quantizer and PTQ calibration tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant
from compile.kernels.pack import qp_qn


def test_fake_quant_values_on_grid():
    t = jnp.asarray(np.linspace(-2, 2, 101), jnp.float32)
    s = jnp.float32(0.25)
    th = quant.lsq_quantize(t, s, 2, True, 1.0)
    codes = np.asarray(th) / 0.25
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-6)
    qp, qn = qp_qn(2, True)
    assert codes.min() >= -qn and codes.max() <= qp


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(1, 4), signed=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_quant_error_bounded(bits, signed, seed):
    """Inside the clip range, |t - t_hat| <= s/2."""
    rng = np.random.default_rng(seed)
    qp, qn = qp_qn(bits, signed)
    s = 0.1
    lo = -qn * s if signed else 0.0
    t = jnp.asarray(rng.uniform(lo, qp * s, size=64), jnp.float32)
    err = quant.quant_error(t, jnp.float32(s), bits, signed)
    assert np.abs(np.asarray(err)).max() <= s / 2 + 1e-6


def test_ste_gradient_passthrough_and_clip():
    s = jnp.float32(0.5)
    grad = jax.grad(lambda t: quant.lsq_quantize(t, s, 2, True, 1.0).sum())
    t = jnp.asarray([-5.0, -0.3, 0.2, 5.0], jnp.float32)
    g = np.asarray(grad(t))
    # out-of-range elements get zero grad (clipped); in-range pass through
    np.testing.assert_array_equal(g, [0.0, 1.0, 1.0, 0.0])


def test_lsq_scale_gradient_signs():
    """Clipped-high values push s up; exactly-representable values give ~0."""
    s = jnp.float32(1.0)

    def gfun(bits, t):
        return float(jax.grad(
            lambda s_: quant.lsq_quantize(jnp.asarray([t], jnp.float32), s_,
                                          bits, True, 1.0).sum())(s))

    # value far above Q_P*s: d/ds = Q_P (= 1 for 2-bit signed)
    assert gfun(2, 10.0) == pytest.approx(1.0)
    # value below -Q_N*s: d/ds = -Q_N (= -2 for 2-bit signed)
    assert gfun(2, -10.0) == pytest.approx(-2.0)
    # interior grid point (v=1 with Q_P=3): round(v) - v = 0
    assert gfun(3, 1.0) == pytest.approx(0.0, abs=1e-6)


def test_lsq_training_recovers_good_scale():
    """Gradient descent on s alone should reduce quantization MSE."""
    rng = np.random.default_rng(0)
    t = jnp.asarray(rng.normal(0, 1, size=512), jnp.float32)
    bits = 3
    s = quant.init_scale(t, bits) * 3.0  # deliberately bad init
    gs = quant.lsq_grad_scale(t.size, bits)

    def loss(s_):
        return ((quant.lsq_quantize(t, s_, bits, True, gs) - t) ** 2).sum()

    l0 = float(loss(s))
    g = jax.grad(loss)
    for _ in range(200):
        s = s - 0.05 * g(s)
    assert float(loss(s)) < 0.5 * l0


@pytest.mark.parametrize("bits", [1, 2, 3, 4])
def test_calibrate_minmax_covers_range(bits):
    rng = np.random.default_rng(bits)
    t = jnp.asarray(rng.normal(0, 1, size=256), jnp.float32)
    s = quant.calibrate_minmax(t, bits, signed=True)
    tq = quant.quantize_int(t, s, bits, signed=True)
    qp, qn = qp_qn(bits, True)
    assert int(tq.min()) >= -qn and int(tq.max()) <= qp
    # the max-|t| element must hit an extreme code
    assert max(abs(int(tq.min())), int(tq.max())) in (qn, qp)


def test_calibrate_mse_not_worse_than_minmax():
    rng = np.random.default_rng(123)
    # heavy-tailed tensor: minmax badly over-stretches the grid
    t = jnp.asarray(rng.standard_t(2, size=1024), jnp.float32)
    bits = 2

    def mse(s):
        return float((quant.quant_error(t, s, bits, True) ** 2).mean())

    assert mse(quant.calibrate_mse(t, bits)) <= mse(quant.calibrate_minmax(t, bits)) + 1e-9


def test_qconfig_tags():
    assert quant.QConfig(2, 2).tag == "2A2W"
    assert quant.QConfig(2, 1).tag == "1A2W"
    assert quant.FP32.tag == "FP32"

"""QAT training smoke tests + dataset generators."""

import numpy as np
import pytest

from compile import datasets, jax_exec, qat
from compile.graph import GraphBuilder, QCfg, set_mixed_precision


def _tiny_classifier(res=16, classes=2):
    b = GraphBuilder("tinycls", (None, res, res, 3))
    b.g.input_shape = (1, res, res, 3)  # batch dim is dynamic at train time
    x = b.conv("input", 8, k=3, stride=2, act="relu", name="c1")
    x = b.conv(x, 16, k=3, stride=2, act="relu", name="c2",
               qcfg=QCfg(w_bits=2, a_bits=2))
    x = b.conv(x, 16, k=3, stride=1, act="relu", name="c3",
               qcfg=QCfg(w_bits=2, a_bits=2))
    x = b.global_avg_pool(x)
    x = b.dense(x, classes, cin=16)
    return b.finish([x])


def test_synth_vww_balanced_and_bounded():
    rng = np.random.default_rng(0)
    x, y = datasets.synth_vww(rng, 64, res=16)
    assert x.shape == (64, 16, 16, 3) and x.min() >= 0 and x.max() <= 1
    assert 0.2 < y.mean() < 0.8


def test_synth_shapes_targets_wellformed():
    rng = np.random.default_rng(1)
    x, t = datasets.synth_shapes(rng, 16, res=32, grid=4)
    assert t.shape == (16, 4, 4, 13)
    obj = t[..., 0]
    assert obj.sum() >= 16  # at least one object per image
    pos = t[obj > 0]
    assert (pos[:, 1:3] >= 0).all() and (pos[:, 1:3] <= 1).all()
    assert (pos[:, 5:].sum(-1) == 1).all()


def test_qat_reduces_loss_and_beats_chance():
    g = _tiny_classifier(res=16)
    cfg = qat.TrainConfig(steps=80, batch_size=32, lr=0.08, seed=0, log_every=20)
    data = lambda rng, n: datasets.synth_vww(rng, n, res=16)
    params, state, hist = qat.train(g, data, qat.softmax_xent, cfg)
    assert hist[-1][1] < hist[0][1] * 0.9
    rng = np.random.default_rng(99)
    xe, ye = datasets.synth_vww(rng, 128, res=16)
    acc_qat = qat.eval_classifier(g, params, state, xe, ye, mode="qat")
    assert acc_qat > 0.6  # well above 0.5 chance after 80 steps
    # deployment path should roughly preserve the trained accuracy
    acc_dep = qat.eval_classifier(g, params, state, xe, ye, mode="deploy_sim")
    assert acc_dep > acc_qat - 0.15


def test_lsq_scales_move_during_training():
    g = _tiny_classifier(res=16)
    p0, s0 = jax_exec.init_params(g, seed=0)
    cfg = qat.TrainConfig(steps=30, batch_size=16, lr=0.05, seed=0)
    data = lambda rng, n: datasets.synth_vww(rng, n, res=16)
    params, _, _ = qat.train(g, data, qat.softmax_xent, cfg)
    moved = [k for k in params if ".s_" in k
             and abs(float(params[k]) - float(p0[k])) > 1e-7]
    assert moved, "no LSQ scale learned anything"
    assert all(float(params[k]) > 0 for k in params if ".s_" in k)


def test_detection_loss_decreases():
    b = GraphBuilder("tinydet", (1, 32, 32, 3))
    x = b.conv("input", 8, k=3, stride=2, act="relu", name="c1")
    x = b.conv(x, 16, k=3, stride=2, act="relu", name="c2")
    x = b.conv(x, 16, k=3, stride=2, act="relu", name="c3")
    x = b.conv(x, 13, k=1, padding=0, bn=False, name="head")
    g = b.finish([x])
    cfg = qat.TrainConfig(steps=60, batch_size=16, lr=0.02, seed=1, log_every=20)
    data = lambda rng, n: datasets.synth_shapes(rng, n, res=32, grid=4)
    params, state, hist = qat.train(g, data, qat.detection_grid_loss, cfg)
    assert hist[-1][1] < hist[0][1]


def test_decoders_roundtrip_ground_truth():
    """GT targets re-encoded as saturated logits decode to matching boxes."""
    import jax

    rng = np.random.default_rng(5)
    _x, t = datasets.synth_shapes(rng, 8, res=32, grid=4)
    logits = np.where(t > 0.5, 8.0, -8.0)  # sigmoid ~= {1, 0}
    clip = lambda p: np.clip(p, 1e-4, 1 - 1e-4)
    logits[..., 1:5] = np.log(clip(t[..., 1:5]) / (1 - clip(t[..., 1:5])))
    for bi in range(len(t)):
        pred = np.asarray(jax.nn.sigmoid(logits[bi]))
        pb, pc, _ps = qat._decode_grid_pred(pred, 4)
        gb, gc = qat._decode_grid(t[bi], 4)
        assert len(pb) == len(gb)
        for j in range(len(pb)):
            ious = [qat._iou(pb[j], gb[k]) for k in range(len(gb))
                    if gc[k] == pc[j]]
            assert ious and max(ious) > 0.9


def test_iou_basics():
    assert qat._iou((0, 0, 1, 1), (0, 0, 1, 1)) == pytest.approx(1.0)
    assert qat._iou((0, 0, 1, 1), (2, 2, 3, 3)) == 0.0
    assert qat._iou((0, 0, 2, 2), (1, 1, 3, 3)) == pytest.approx(1 / 7)

"""Bitplane packing round-trips and the popcount/plane-matmul identity."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pack


@pytest.mark.parametrize("bits", [1, 2, 3, 4])
def test_planes_roundtrip(bits):
    rng = np.random.default_rng(bits)
    x = rng.integers(0, 2**bits, size=(5, 7))
    planes = pack.to_planes(jnp.asarray(x), bits)
    assert planes.shape == (bits, 5, 7)
    assert set(np.unique(np.asarray(planes))) <= {0.0, 1.0}
    back = pack.from_planes(planes)
    np.testing.assert_array_equal(np.asarray(back), x)


@pytest.mark.parametrize("bits", [1, 2, 3])
def test_offset_encoding_roundtrip(bits):
    qp, qn = pack.qp_qn(bits, signed=True)
    w = np.arange(-qn, qp + 1)
    wu = pack.offset_encode(jnp.asarray(w), bits)
    assert int(wu.min()) == 0 and int(wu.max()) == 2**bits - 1
    back = pack.offset_decode(wu, bits)
    np.testing.assert_array_equal(np.asarray(back), w)


@pytest.mark.parametrize("bits,signed,expect", [
    (1, True, (0, 1)), (2, True, (1, 2)), (3, True, (3, 4)),
    (8, True, (127, 128)), (1, False, (1, 0)), (2, False, (3, 0)),
])
def test_qp_qn(bits, signed, expect):
    assert pack.qp_qn(bits, signed) == expect


def test_qp_qn_rejects_zero_bits():
    with pytest.raises(ValueError):
        pack.qp_qn(0)


@settings(max_examples=25, deadline=None)
@given(
    bits=st.integers(1, 4),
    k=st.integers(1, 70),
    m=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_words_roundtrip(bits, k, m, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2**bits, size=(m, k))
    words = pack.pack_words_u32(jnp.asarray(x), bits)
    assert words.shape == (bits, m, (k + 31) // 32)
    back = pack.unpack_words_u32(words, k)
    np.testing.assert_array_equal(np.asarray(back), x)


@settings(max_examples=20, deadline=None)
@given(
    a_bits=st.integers(1, 3),
    w_bits=st.integers(1, 3),
    m=st.integers(1, 5),
    n=st.integers(1, 5),
    k=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_popcount_dot_equals_int_gemm(a_bits, w_bits, m, n, k, seed):
    """The paper's packed-word popcount equation == dense unsigned GEMM."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2**a_bits, size=(m, k))
    w = rng.integers(0, 2**w_bits, size=(n, k))
    aw = pack.pack_words_u32(jnp.asarray(a), a_bits)
    ww = pack.pack_words_u32(jnp.asarray(w), w_bits)
    got = pack.popcount_dot_words(aw, ww)
    want = a @ w.T
    np.testing.assert_array_equal(np.asarray(got), want)

//! Cost-model explorer: project every paper model × target CPU × engine.
//!
//! Prints the full latency matrix the paper's evaluation spans, from the
//! analytical Cortex-A53/A72/A57 model (DESIGN.md §8).
//!
//! Run: `cargo run --release --example cost_explorer`

use anyhow::Result;
use dlrt::bench_harness::Table;
use dlrt::costmodel::{self, EngineKind, CORTEX_A53, CORTEX_A57, CORTEX_A72};
use dlrt::dlrt::graph::QCfg;
use dlrt::models;

fn main() -> Result<()> {
    let q = QCfg::new(2, 2);
    let specs: Vec<(&str, dlrt::Graph)> = vec![
        ("resnet18@224", models::build_resnet(18, 1000, 224, 1.0, q, 0)),
        ("resnet50@224", models::build_resnet(50, 1000, 224, 1.0, q, 0)),
        ("vgg16_ssd@300", models::build_vgg16_ssd(21, 300, 1.0, q, 0)),
        ("yolov5n@320", models::build_yolov5("n", 80, 320, 1.0, q, 0)),
        ("yolov5s@320", models::build_yolov5("s", 80, 320, 1.0, q, 0)),
        ("yolov5m@320", models::build_yolov5("m", 80, 320, 1.0, q, 0)),
    ];
    for cpu in [&CORTEX_A53, &CORTEX_A72, &CORTEX_A57] {
        let mut table = Table::new(
            &format!("projected latency (ms), 4 threads — {}", cpu.name),
            &["model", "FP32", "INT8", "DLRT 2A2W (mixed)", "DLRT 1A1W", "speedup vs FP32"],
        );
        for (name, g) in &specs {
            let fp32 = costmodel::graph_latency_ms(g, cpu, Some(EngineKind::Fp32), 4)?;
            let int8 = costmodel::graph_latency_ms(g, cpu, Some(EngineKind::Int8), 4)?;
            let mixed = costmodel::graph_latency_ms(g, cpu, None, 4)?;
            let b1 = costmodel::graph_latency_ms(
                g, cpu, Some(EngineKind::Bitserial { w_bits: 1, a_bits: 1 }), 4)?;
            table.row(vec![
                name.to_string(),
                format!("{fp32:.0}"),
                format!("{int8:.0}"),
                format!("{mixed:.0}"),
                format!("{b1:.0}"),
                format!("{:.2}x", fp32 / mixed),
            ]);
        }
        table.print();
    }
    println!("\n(The projected FP32->2A2W speedups land in the paper's 2-5x band;");
    println!(" measured host-CPU ratios are in `cargo bench` outputs.)");
    Ok(())
}

//! End-to-end driver (DESIGN.md §6 / EXPERIMENTS.md §E2E): exercises every
//! layer of the stack on a real small workload and proves they compose.
//!
//! Pipeline (the paper's Fig. 3, bottom to top):
//!   1. JAX build path (ran once via `make artifacts`): model authored in
//!      JAX, quantized, exported (arch.json + weights.bin), goldens dumped,
//!      FP32 graph AOT-lowered to HLO.
//!   2. `dlrt compile`: quantize + bitplane-pack -> .dlrt.
//!   3. Runtime correctness: .dlrt outputs match the JAX deploy-sim goldens.
//!   4. Cross-engine: bitserial vs FP32-native vs INT8 vs the PJRT-compiled
//!      XLA artifact (framework baseline) on the same input.
//!   5. Serving: batched requests through the coordinator; latency +
//!      throughput + compression reported (paper's headline metrics).
//!
//! Run: `cargo run --release --example e2e_pipeline`

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};
use dlrt::bench_harness::{bench_ms, ms, speedup, Table};
use dlrt::compiler::{compile_graph, load_arch, EngineChoice};
use dlrt::coordinator::{InferenceServer, ServerConfig};
use dlrt::dlrt::format;
use dlrt::exec::Executor;
use dlrt::util::json::Json;
use dlrt::Tensor;

fn main() -> Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("golden/resnet18_mini.json").exists() {
        bail!("run `make artifacts` first");
    }

    // ---- stage 1+2: exported model -> .dlrt ------------------------------
    println!("[1/5] compiling exported resnet18_mini (QAT 2A/2W mixed) ...");
    let g = load_arch(&dir.join("models/resnet18_mini"))?;
    let quant = compile_graph(&g, EngineChoice::Auto)?;
    let dlrt_path = std::env::temp_dir().join("e2e_resnet18.dlrt");
    format::save(&quant, &dlrt_path)?;
    let model = format::load(&dlrt_path)?;
    println!("      engines {:?}, {} weight bytes", model.engine_summary(),
             model.weight_bytes());

    // ---- stage 3: golden parity ------------------------------------------
    println!("[2/5] verifying against JAX deploy-sim goldens ...");
    let golden = Json::parse(&std::fs::read_to_string(
        dir.join("golden/resnet18_mini.json"))?)?;
    let input = Tensor::new(
        golden.get("input_shape")?.usize_vec()?,
        golden.get("input")?.f32_vec()?,
    )?;
    let want = &golden.get("outputs")?.arr()?[0];
    let want_t = Tensor::new(want.get("shape")?.usize_vec()?,
                             want.get("data")?.f32_vec()?)?;
    let mut ex = Executor::new(1);
    let got = ex.run(&model, &input)?;
    let scale = want_t.data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
    let diff = got[0].max_abs_diff(&want_t) / scale;
    println!("      relative diff vs JAX: {diff:.2e}");
    if diff > 2e-4 {
        bail!("golden parity failed: {diff}");
    }

    // ---- stage 4: cross-engine comparison --------------------------------
    println!("[3/5] cross-engine latency on the same checkpoint ...");
    let fp32 = compile_graph(&g, EngineChoice::ForceFp32)?;
    let int8 = compile_graph(&g, EngineChoice::ForceInt8)?;
    let reps = 10;
    let t_q = bench_ms(2, reps, || { ex.run(&model, &input).unwrap(); });
    let t_f = bench_ms(2, reps, || { ex.run(&fp32, &input).unwrap(); });
    let t_8 = bench_ms(2, reps, || { ex.run(&int8, &input).unwrap(); });

    // PJRT framework baseline: the same architecture AOT-compiled by XLA
    // (only when the crate was built with the `pjrt` feature)
    let t_pj = pjrt_baseline(dir, &input)?;

    let mut table = Table::new("e2e — resnet18_mini (64px), host CPU, 1 thread",
                               &["engine", "median", "vs FP32-native"]);
    table.row(vec!["DLRT bitserial 2A2W".into(), ms(t_q.median_ms),
                   speedup(t_f.median_ms, t_q.median_ms)]);
    table.row(vec!["INT8 native".into(), ms(t_8.median_ms),
                   speedup(t_f.median_ms, t_8.median_ms)]);
    table.row(vec!["FP32 native".into(), ms(t_f.median_ms), "1.00x".into()]);
    if let Some(t_pj) = t_pj {
        table.row(vec!["XLA/PJRT (quantized graph)".into(), ms(t_pj),
                       speedup(t_f.median_ms, t_pj)]);
    }
    table.print();
    table.save_json("e2e_pipeline");

    // ---- stage 5: serving ------------------------------------------------
    println!("\n[5/5] serving 64 batched requests through the coordinator ...");
    let server = InferenceServer::start(Arc::new(model), ServerConfig {
        workers: 1,
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        ..ServerConfig::default()
    });
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..64).map(|_| server.submit(input.clone())).collect();
    for rx in rxs {
        rx.recv().expect("server alive")?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let msn = server.metrics();
    println!("      throughput {:.1} req/s | exec p50 {} | mean batch {:.2}",
             64.0 / wall, ms(msn.p50_exec_ms), msn.mean_batch);
    server.shutdown();
    std::fs::remove_file(&dlrt_path).ok();
    println!("\nE2E OK — all five stages composed.");
    Ok(())
}

/// Median latency of the XLA/PJRT framework baseline, or `None` when the
/// crate was built without the `pjrt` feature.
#[cfg(feature = "pjrt")]
fn pjrt_baseline(dir: &Path, input: &Tensor) -> Result<Option<f64>> {
    println!("[4/5] PJRT (XLA CPU) framework baseline ...");
    let rt = dlrt::runtime::PjrtRuntime::cpu()?;
    let pjrt = rt.load_hlo(&dir.join("resnet18_mini_2a2w"))?;
    let mut rng = dlrt::util::rng::Rng::new(5);
    let mut pj_inputs: Vec<Tensor> = pjrt.manifest.params.iter()
        .map(|(_, shape)| {
            let n: usize = shape.iter().product::<usize>().max(1);
            Tensor::new(shape.clone(), (0..n).map(|_| rng.f32() * 0.1 + 0.05).collect())
                .unwrap()
        })
        .collect();
    pj_inputs.push(input.clone());
    let t_pj = bench_ms(1, 5, || { pjrt.run_f32(&pj_inputs).unwrap(); });
    Ok(Some(t_pj.median_ms))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_baseline(_dir: &Path, _input: &Tensor) -> Result<Option<f64>> {
    println!("[4/5] PJRT baseline skipped (build with `--features pjrt`)");
    Ok(None)
}

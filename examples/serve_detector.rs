//! Edge detection service: YOLOv5n through the full serving stack.
//!
//! Builds a quantized YOLOv5n, starts the coordinator (router + dynamic
//! batcher + worker pool), pushes a stream of synthetic camera frames,
//! decodes the raw head maps into boxes (sigmoid-grid decode + NMS in the
//! coordinator postprocessor, as in the paper's runtime), and reports
//! serving metrics.
//!
//! Run: `cargo run --release --example serve_detector -- [--frames N]`

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::coordinator::postproc::{decode_yolo, nms, DEFAULT_ANCHORS};
use dlrt::coordinator::{InferenceServer, ServerConfig};
use dlrt::dlrt::graph::QCfg;
use dlrt::models::build_yolov5;
use dlrt::util::cli::Args;
use dlrt::util::rng::Rng;
use dlrt::Tensor;

const NUM_CLASSES: usize = 8; // the paper's COCO-8 subset
const RES: usize = 128;

fn synthetic_frame(rng: &mut Rng) -> Tensor {
    let mut t = Tensor::zeros(vec![1, RES, RES, 3]);
    for v in t.data.iter_mut() {
        *v = rng.f32() * 0.3;
    }
    // a bright square "object" somewhere
    let cy = rng.usize(RES - 32) + 16;
    let cx = rng.usize(RES - 32) + 16;
    for dy in 0..16 {
        for dx in 0..16 {
            let idx = (((cy + dy) * RES) + cx + dx) * 3;
            t.data[idx] = 0.9;
        }
    }
    t
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let frames = args.usize_or("frames", 24)?;

    println!("building quantized YOLOv5n ({NUM_CLASSES} classes, {RES}px)...");
    let g = build_yolov5("n", NUM_CLASSES, RES, 1.0, QCfg::new(2, 2), 7);
    let model = Arc::new(compile_graph(&g, EngineChoice::Auto)?);
    println!("engines: {:?}", model.engine_summary());

    let server = InferenceServer::start(model, ServerConfig {
        workers: 1,
        max_batch: 4,
        max_wait: Duration::from_millis(3),
        ..ServerConfig::default()
    });

    let mut rng = Rng::new(1);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..frames).map(|_| server.submit(synthetic_frame(&mut rng))).collect();

    let mut total_dets = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let maps = rx.recv().expect("server alive")?;
        let dets = nms(
            decode_yolo(&maps, NUM_CLASSES, &[8, 16, 32], &DEFAULT_ANCHORS, 0.25),
            0.45,
        );
        total_dets += dets.len();
        if i < 3 {
            println!("frame {i}: {} detections (top: {:?})", dets.len(),
                     dets.first().map(|d| (d.class_id, d.score)));
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!("\n-- serving report --");
    println!("frames      : {frames} ({total_dets} raw detections; random weights)");
    println!("throughput  : {:.2} FPS", frames as f64 / wall);
    println!("exec p50/p95: {:.1} / {:.1} ms", m.p50_exec_ms, m.p95_exec_ms);
    println!("queue p50   : {:.1} ms", m.p50_queue_ms);
    println!("mean batch  : {:.2}", m.mean_batch);
    server.shutdown();
    Ok(())
}

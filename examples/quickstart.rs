//! Quickstart: the five-line DeepliteRT story.
//!
//! 1. load a model exported by the JAX build path (`make artifacts`),
//! 2. compile it (quantize + bitplane-pack) to a deployable `.dlrt`,
//! 3. load the `.dlrt` back (this is all a device would ship),
//! 4. run inference,
//! 5. compare size + latency against the FP32 baseline engine.
//!
//! Run: `cargo run --release --example quickstart`

use std::path::Path;

use anyhow::{Context, Result};
use dlrt::bench_harness::{bench_ms, ms, speedup};
use dlrt::compiler::{compile_graph, load_arch, EngineChoice};
use dlrt::dlrt::format;
use dlrt::exec::Executor;
use dlrt::util::rng::Rng;
use dlrt::Tensor;

fn main() -> Result<()> {
    let model_dir = Path::new("artifacts/models/resnet18_mini");
    let graph = load_arch(model_dir)
        .context("run `make artifacts` first (builds the exported models)")?;
    println!("loaded {} ({} convs)", graph.name, graph.conv_nodes().count());

    // 2. compile: mixed-precision 2A/2W bitserial per the exported QCfg
    let quantized = compile_graph(&graph, EngineChoice::Auto)?;
    let out = std::env::temp_dir().join("quickstart_resnet18.dlrt");
    format::save(&quantized, &out)?;
    println!("compiled -> {} ({} bytes)", out.display(),
             std::fs::metadata(&out)?.len());

    // 3. deployable artifact only from here on
    let model = format::load(&out)?;
    println!("engines: {:?}", model.engine_summary());

    // 4. inference on a random image
    let mut rng = Rng::new(42);
    let s = model.graph.input_shape;
    let mut x = Tensor::zeros(vec![1, s[1], s[2], s[3]]);
    for v in x.data.iter_mut() {
        *v = rng.f32();
    }
    let mut ex = Executor::new(1);
    let y = ex.run(&model, &x)?;
    println!("logits: {:?}", &y[0].data);

    // 5. against the FP32 baseline engine (same checkpoint)
    let fp32 = compile_graph(&graph, EngineChoice::ForceFp32)?;
    let t_q = bench_ms(2, 10, || {
        ex.run(&model, &x).unwrap();
    });
    let t_f = bench_ms(2, 10, || {
        ex.run(&fp32, &x).unwrap();
    });
    println!("\nmodel size : {} B (fp32 engine: {} B, {:.1}x smaller)",
             model.weight_bytes(), fp32.weight_bytes(),
             fp32.weight_bytes() as f64 / model.weight_bytes() as f64);
    println!("latency    : {} (fp32 engine: {}, {} faster)",
             ms(t_q.median_ms), ms(t_f.median_ms),
             speedup(t_f.median_ms, t_q.median_ms));
    std::fs::remove_file(&out).ok();
    Ok(())
}

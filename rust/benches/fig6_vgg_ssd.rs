//! Fig. 6 — VGG16-SSD300 on Pascal VOC: DLRT 2A/2W vs FP32 baseline.
//! Paper headline: 3.19x (Pi 3B+) and 2.95x (Pi 4B) speedup at <0.02 mAP drop.
//!
//! Run: `cargo bench --bench fig6_vgg_ssd`

use dlrt::bench_harness::{bench_ms, ms, Table};
use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::costmodel::{self, EngineKind, CORTEX_A53, CORTEX_A72};
use dlrt::dlrt::graph::QCfg;
use dlrt::exec::Executor;
use dlrt::models::build_vgg16_ssd;
use dlrt::util::rng::Rng;
use dlrt::Tensor;

fn main() {
    let mut t = Table::new(
        "Fig.6 projection — VGG16-SSD300/VOC (4 threads)",
        &["platform", "FP32", "DLRT 2A2W", "speedup", "paper"],
    );
    for (cpu, paper) in [(&CORTEX_A53, "3.19x"), (&CORTEX_A72, "2.95x")] {
        let g = build_vgg16_ssd(21, 300, 1.0, QCfg::new(2, 2), 0);
        let fp32 =
            costmodel::graph_latency_ms(&g, cpu, Some(EngineKind::Fp32), 4).unwrap();
        let b22 = costmodel::graph_latency_ms(&g, cpu, None, 4).unwrap();
        t.row(vec![
            cpu.name.to_string(),
            ms(fp32),
            ms(b22),
            format!("{:.2}x", fp32 / b22),
            paper.to_string(),
        ]);
    }
    t.print();
    t.save_json("fig6_projection");
    println!("(paper also notes the best Pi-4B configuration still exceeds 1 s —");
    println!(" visible above — motivating the YOLOv5 section.)");

    // ---- measured at reduced scale (width 0.25 @300px; thinner widths
    //      starve the bitserial engine: k < 128 wastes most of each u64 word)
    let mut m = Table::new(
        "Fig.6 measured — VGG16-SSD width=0.25 @300px, host CPU (1 thread)",
        &["engine", "median", "speedup vs FP32"],
    );
    let g = build_vgg16_ssd(21, 300, 0.25, QCfg::new(2, 2), 0);
    let mq = compile_graph(&g, EngineChoice::Auto).unwrap();
    let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
    let mut rng = Rng::new(4);
    let mut x = Tensor::zeros(vec![1, 300, 300, 3]);
    for v in x.data.iter_mut() {
        *v = rng.f32();
    }
    let mut ex = Executor::new(1);
    let t_f = bench_ms(1, 3, || { ex.run(&mf, &x).unwrap(); });
    let t_q = bench_ms(1, 3, || { ex.run(&mq, &x).unwrap(); });
    m.row(vec!["FP32 native".into(), ms(t_f.median_ms), "1.00x".into()]);
    m.row(vec!["DLRT 2A2W (mixed)".into(), ms(t_q.median_ms),
               format!("{:.2}x", t_f.median_ms / t_q.median_ms)]);
    m.print();
    m.save_json("fig6_measured");
}

//! Fig. 4/5 — ResNet18 on VWW (224px): DeepliteRT 2A/2W and 1A/2W vs the
//! FP32 (ONNX-Runtime-role) and INT8 (TFLite+XNNPACK-role) baselines on
//! RPi 3B+ and RPi 4B. Paper headline: 3.75x (Pi3) and 2.90x (Pi4) speedup
//! with 15.58x size reduction.
//!
//! Run: `cargo bench --bench fig5_resnet_vww`

use dlrt::bench_harness::{bench_ms, ms, Table};
use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::costmodel::{self, EngineKind, CORTEX_A53, CORTEX_A72};
use dlrt::dlrt::graph::QCfg;
use dlrt::exec::Executor;
use dlrt::models::build_resnet;
use dlrt::util::rng::Rng;
use dlrt::Tensor;

fn main() {
    // ---- projections at paper scale (224px, 4 threads) -------------------
    let mut t = Table::new(
        "Fig.4/5 projection — ResNet18-VWW @224px (4 threads)",
        &["platform", "FP32", "INT8", "DLRT 2A2W", "DLRT 1A2W", "speedup (paper)"],
    );
    for (cpu, paper) in [(&CORTEX_A53, "3.75x"), (&CORTEX_A72, "2.90x")] {
        let g22 = build_resnet(18, 2, 224, 1.0, QCfg::new(2, 2), 0);
        let g12 = build_resnet(18, 2, 224, 1.0, QCfg::new(1, 2), 0);
        let fp32 = costmodel::graph_latency_ms(&g22, cpu, Some(EngineKind::Fp32), 4)
            .unwrap();
        let int8 = costmodel::graph_latency_ms(&g22, cpu, Some(EngineKind::Int8), 4)
            .unwrap();
        let b22 = costmodel::graph_latency_ms(&g22, cpu, None, 4).unwrap();
        let b12 = costmodel::graph_latency_ms(&g12, cpu, None, 4).unwrap();
        t.row(vec![
            cpu.name.to_string(),
            ms(fp32),
            ms(int8),
            ms(b22),
            ms(b12),
            format!("{:.2}x ({paper})", fp32 / b22),
        ]);
    }
    t.print();
    t.save_json("fig5_projection");

    // ---- measured on host CPU (reduced: 112px) ---------------------------
    let mut m = Table::new(
        "Fig.4/5 measured — ResNet18-VWW @112px, host CPU (1 thread)",
        &["engine", "median", "speedup vs FP32"],
    );
    let g = build_resnet(18, 2, 112, 1.0, QCfg::new(2, 2), 0);
    let mq = compile_graph(&g, EngineChoice::Auto).unwrap();
    let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
    let m8 = compile_graph(&g, EngineChoice::ForceInt8).unwrap();
    let mut rng = Rng::new(3);
    let mut x = Tensor::zeros(vec![1, 112, 112, 3]);
    for v in x.data.iter_mut() {
        *v = rng.f32();
    }
    let mut ex = Executor::new(1);
    let t_f = bench_ms(1, 5, || { ex.run(&mf, &x).unwrap(); });
    let t_8 = bench_ms(1, 5, || { ex.run(&m8, &x).unwrap(); });
    let t_q = bench_ms(1, 5, || { ex.run(&mq, &x).unwrap(); });
    m.row(vec!["FP32 native".into(), ms(t_f.median_ms), "1.00x".into()]);
    m.row(vec!["INT8 native".into(), ms(t_8.median_ms),
               format!("{:.2}x", t_f.median_ms / t_8.median_ms)]);
    m.row(vec!["DLRT 2A2W (mixed)".into(), ms(t_q.median_ms),
               format!("{:.2}x", t_f.median_ms / t_q.median_ms)]);
    m.print();
    m.save_json("fig5_measured");

    // accuracy column comes from the python experiment (make exp-fig4);
    // EXPERIMENTS.md joins both sides.
    let acc = std::path::Path::new("artifacts/experiments/fig4_resnet_vww.json");
    if acc.exists() {
        println!("\naccuracy results found: {}", acc.display());
        println!("{}", std::fs::read_to_string(acc).unwrap_or_default());
    } else {
        println!("\n(accuracy side: run `make exp-fig4` to train the VWW stand-in)");
    }
}

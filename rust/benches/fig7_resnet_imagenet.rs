//! Fig. 7 — ResNet18/50 on ImageNet: inference time across runtimes on the
//! Jetson Nano (Cortex-A57), including the embedded-GPU reference bar.
//! Paper headline: DLRT ~50% slower than the embedded GPU, 2-5x faster than
//! CPU baselines.
//!
//! Measured side: native engines + the XLA/PJRT framework baseline at 96px.
//!
//! Run: `cargo bench --bench fig7_resnet_imagenet`

use dlrt::bench_harness::{bench_ms, ms, Table};
use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::costmodel::{self, EngineKind, CORTEX_A57, JETSON_NANO_GPU};
use dlrt::dlrt::graph::QCfg;
use dlrt::exec::planner::{build_plan_with, PlanOpts};
use dlrt::exec::Executor;
use dlrt::models::build_resnet;
use dlrt::util::rng::Rng;
use dlrt::Tensor;

fn main() {
    let mut t = Table::new(
        "Fig.7 projection — ImageNet classification on Jetson Nano (A57, 4 threads)",
        &["model", "FP32 CPU", "INT8 CPU", "DLRT 2A2W", "GPU (ref)", "DLRT/GPU"],
    );
    for depth in [18usize, 50] {
        let g = build_resnet(depth, 1000, 224, 1.0, QCfg::new(2, 2), 0);
        let fp32 =
            costmodel::graph_latency_ms(&g, &CORTEX_A57, Some(EngineKind::Fp32), 4).unwrap();
        let int8 =
            costmodel::graph_latency_ms(&g, &CORTEX_A57, Some(EngineKind::Int8), 4).unwrap();
        let b22 = costmodel::graph_latency_ms(&g, &CORTEX_A57, None, 4).unwrap();
        let gpu = costmodel::gpu_latency_ms(&g, &JETSON_NANO_GPU).unwrap();
        t.row(vec![
            format!("resnet{depth}@224"),
            ms(fp32),
            ms(int8),
            ms(b22),
            ms(gpu),
            format!("{:.2}x (paper ~1.5x)", b22 / gpu),
        ]);
    }
    t.print();
    t.save_json("fig7_projection");

    // ---- measured: native engines + PJRT baseline @96px ------------------
    let mut m = Table::new(
        "Fig.7 measured — ResNet18 @96px, host CPU (1 thread)",
        &["runtime", "median", "speedup vs FP32-native"],
    );
    let g = build_resnet(18, 1000, 96, 1.0, QCfg::new(2, 2), 0);
    let mq = compile_graph(&g, EngineChoice::Auto).unwrap();
    let vec_convs = if mq.isa == dlrt::kernels::ukernel::Isa::Scalar {
        0
    } else {
        mq.plan.conv_kernels
    };
    println!(
        "dispatch: isa={}, {}/{} convs vectorized",
        mq.isa.name(),
        vec_convs,
        mq.plan.conv_kernels
    );
    let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
    let m8 = compile_graph(&g, EngineChoice::ForceInt8).unwrap();
    let mut rng = Rng::new(5);
    let mut x = Tensor::zeros(vec![1, 96, 96, 3]);
    for v in x.data.iter_mut() {
        *v = rng.f32();
    }
    // plan ablations: same kernels throughout — (a) everything disabled,
    // (b) only the residual-add fusion disabled (isolates the new pass)
    let mut mq_nofuse = mq.clone();
    mq_nofuse.plan = build_plan_with(&g, PlanOpts::none()).unwrap();
    let mut mq_nores = mq.clone();
    mq_nores.plan = build_plan_with(
        &g,
        PlanOpts { fuse_residual_add: false, ..PlanOpts::default() },
    )
    .unwrap();

    let mut ex = Executor::new(1);
    let t_f = bench_ms(1, 5, || { ex.run(&mf, &x).unwrap(); });
    let t_8 = bench_ms(1, 5, || { ex.run(&m8, &x).unwrap(); });
    let t_q = bench_ms(1, 5, || { ex.run(&mq, &x).unwrap(); });
    let t_qn = bench_ms(1, 5, || { ex.run(&mq_nofuse, &x).unwrap(); });
    let t_qr = bench_ms(1, 5, || { ex.run(&mq_nores, &x).unwrap(); });
    m.row(vec!["FP32 native".into(), ms(t_f.median_ms), "1.00x".into()]);
    m.row(vec!["INT8 native".into(), ms(t_8.median_ms),
               format!("{:.2}x", t_f.median_ms / t_8.median_ms)]);
    m.row(vec!["DLRT 2A2W (fused plan)".into(), ms(t_q.median_ms),
               format!("{:.2}x", t_f.median_ms / t_q.median_ms)]);
    m.row(vec!["DLRT 2A2W (no residual fusion)".into(), ms(t_qr.median_ms),
               format!("{:.2}x", t_f.median_ms / t_qr.median_ms)]);
    m.row(vec!["DLRT 2A2W (unfused plan)".into(), ms(t_qn.median_ms),
               format!("{:.2}x", t_f.median_ms / t_qn.median_ms)]);
    println!("fusion ablation: fused {} vs unfused {} ({:.2}x per-inference)",
             ms(t_q.median_ms), ms(t_qn.median_ms),
             t_qn.median_ms / t_q.median_ms);
    println!(
        "residual-add fusion: {} fused adds save {:.2}% per-inference \
         ({} vs {}), arena {} -> {} B",
        mq.plan.fused_add_instrs(),
        100.0 * (t_qr.median_ms - t_q.median_ms) / t_qr.median_ms,
        ms(t_qr.median_ms),
        ms(t_q.median_ms),
        mq_nores.plan.arena_bytes(1),
        mq.plan.arena_bytes(1),
    );

    // XLA/PJRT framework baseline (the ONNX-Runtime role), same 96px graph
    pjrt_row(&mut m, &mut rng, &x, t_f.median_ms);
    m.print();
    m.save_json("fig7_measured");
}

#[cfg(feature = "pjrt")]
fn pjrt_row(m: &mut Table, rng: &mut Rng, x: &Tensor, t_f_ms: f64) {
    let stem = std::path::Path::new("artifacts/resnet18_fp32_96");
    if !stem.with_extension("hlo.txt").exists()
        && !std::path::Path::new("artifacts/resnet18_fp32_96.hlo.txt").exists()
    {
        println!("(PJRT row skipped: run `make artifacts`)");
        return;
    }
    let rt = dlrt::runtime::PjrtRuntime::cpu().unwrap();
    let model = rt.load_hlo(stem).unwrap();
    let mut inputs: Vec<Tensor> = model.manifest.params.iter()
        .map(|(_, shape)| {
            let n: usize = shape.iter().product::<usize>().max(1);
            Tensor::new(shape.clone(),
                        (0..n).map(|_| rng.f32() * 0.1 + 0.05).collect()).unwrap()
        })
        .collect();
    inputs.push(x.clone());
    let t_pj = bench_ms(1, 5, || { model.run_f32(&inputs).unwrap(); });
    m.row(vec!["XLA/PJRT FP32 (framework baseline)".into(), ms(t_pj.median_ms),
               format!("{:.2}x", t_f_ms / t_pj.median_ms)]);
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_row(_m: &mut Table, _rng: &mut Rng, _x: &Tensor, _t_f_ms: f64) {
    println!("(PJRT row skipped: build with `--features pjrt` and run `make artifacts`)");
}

//! Fig. 8 — YOLOv5s/m @320px: DLRT vs TFLite+XNNPACK (FP16) vs ONNX Runtime
//! (FP32) on the Raspberry Pi 4B. Paper headlines: up to 2.2x over
//! TFLite+XNNPACK, 3.2x over ONNX Runtime; ~9 FPS (s) and ~3 FPS (m).
//!
//! Role mapping (DESIGN.md §2): ONNX-Runtime-FP32 → our FP32 engine;
//! TFLite+XNNPACK-FP16 → our FP32 engine at 0.7x cost (FP16 halves
//! bandwidth, not Neon FMA throughput on A72 — XNNPACK gains ~1.4x).
//!
//! Run: `cargo bench --bench fig8_yolo_latency`

use dlrt::bench_harness::{bench_ms, ms, Table};
use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::costmodel::{self, EngineKind, CORTEX_A72};
use dlrt::dlrt::graph::QCfg;
use dlrt::exec::planner::{build_plan_with, PlanOpts};
use dlrt::exec::Executor;
use dlrt::models::build_yolov5;
use dlrt::util::rng::Rng;
use dlrt::Tensor;

const XNNPACK_FP16_FACTOR: f64 = 0.7;

fn main() {
    let mut t = Table::new(
        "Fig.8 projection — YOLOv5 @320px on Cortex-A72 (4 threads)",
        &["model", "ORT FP32 (role)", "TFLite+XNN FP16 (role)", "DLRT mixed",
          "DLRT FPS", "vs ORT", "vs XNN", "paper"],
    );
    for (v, paper) in [("s", "9 FPS, 3.2x/2.2x"), ("m", "3 FPS, 3.2x/2.2x")] {
        let g = build_yolov5(v, 1 + 4, 320, 1.0, QCfg::new(2, 2), 0); // person class head
        let ort = costmodel::graph_latency_ms(&g, &CORTEX_A72, Some(EngineKind::Fp32), 4)
            .unwrap();
        let xnn = ort * XNNPACK_FP16_FACTOR;
        let dlrt_ms = costmodel::graph_latency_ms(&g, &CORTEX_A72, None, 4).unwrap();
        t.row(vec![
            format!("yolov5{v}"),
            ms(ort),
            ms(xnn),
            ms(dlrt_ms),
            format!("{:.1}", 1000.0 / dlrt_ms),
            format!("{:.2}x", ort / dlrt_ms),
            format!("{:.2}x", xnn / dlrt_ms),
            paper.to_string(),
        ]);
    }
    t.print();
    t.save_json("fig8_projection");

    // ---- measured at reduced scale (width 0.5 yolov5s @160px) ------------
    let mut m = Table::new(
        "Fig.8 measured — yolov5s width=0.5 @160px, host CPU (1 thread)",
        &["engine", "median", "speedup vs FP32"],
    );
    let g = build_yolov5("s", 5, 160, 0.5, QCfg::new(2, 2), 0);
    let mq = compile_graph(&g, EngineChoice::Auto).unwrap();
    let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
    // strided-vs-copy ablation: same kernels, but multi-use concat inputs
    // (SPPF pyramid, PANet skips) densify through copy_channels again
    let mut mq_copy = mq.clone();
    mq_copy.plan =
        build_plan_with(&g, PlanOpts { strided_reads: false, ..PlanOpts::default() })
            .unwrap();
    let mut rng = Rng::new(6);
    let mut x = Tensor::zeros(vec![1, 160, 160, 3]);
    for v in x.data.iter_mut() {
        *v = rng.f32();
    }
    let mut ex = Executor::new(1);
    let t_f = bench_ms(1, 4, || { ex.run(&mf, &x).unwrap(); });
    let t_q = bench_ms(1, 4, || { ex.run(&mq, &x).unwrap(); });
    let t_qc = bench_ms(1, 4, || { ex.run(&mq_copy, &x).unwrap(); });
    m.row(vec!["FP32 native".into(), ms(t_f.median_ms), "1.00x".into()]);
    m.row(vec!["DLRT 2A2W (mixed)".into(), ms(t_q.median_ms),
               format!("{:.2}x", t_f.median_ms / t_q.median_ms)]);
    m.row(vec!["DLRT 2A2W (copy concats)".into(), ms(t_qc.median_ms),
               format!("{:.2}x", t_f.median_ms / t_qc.median_ms)]);
    println!(
        "strided reads: {} stripe readers, {} copy instrs (vs {} with copies), \
         arena {} -> {} B",
        mq.plan.read_view_instrs(),
        mq.plan.concat_copy_instrs(),
        mq_copy.plan.concat_copy_instrs(),
        mq_copy.plan.arena_bytes(1),
        mq.plan.arena_bytes(1),
    );
    m.print();
    m.save_json("fig8_measured");
}

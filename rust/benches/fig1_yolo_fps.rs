//! Fig. 1 — YOLOv5 FPS vs input resolution on the Raspberry Pi 4B
//! (Cortex-A72), showing the paper's motivating point: even INT8 YOLOv5
//! tops out at ~4-5 FPS unless the model is tiny and low-res.
//!
//! Projected series from the A72 cost model at paper scale, plus a measured
//! host-CPU series at reduced width (ratios transfer; DESIGN.md §2).
//!
//! Run: `cargo bench --bench fig1_yolo_fps`

use dlrt::bench_harness::{bench_ms, ms, Table};
use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::costmodel::{self, EngineKind, CORTEX_A72};
use dlrt::dlrt::graph::QCfg;
use dlrt::exec::planner::{build_plan_with, PlanOpts};
use dlrt::exec::Executor;
use dlrt::models::build_yolov5;
use dlrt::util::rng::Rng;
use dlrt::Tensor;

const RESOLUTIONS: [usize; 4] = [128, 192, 256, 320];

fn main() {
    // ---- paper-scale projections (the figure's series) -------------------
    for engine in ["FP32", "INT8"] {
        let force = if engine == "FP32" { EngineKind::Fp32 } else { EngineKind::Int8 };
        let mut t = Table::new(
            &format!("Fig.1 projection — YOLOv5 {engine} FPS on Cortex-A72 (4 threads)"),
            &["variant", "128px", "192px", "256px", "320px"],
        );
        for v in ["n", "s", "m"] {
            let mut cells = vec![format!("yolov5{v}")];
            for res in RESOLUTIONS {
                let g = build_yolov5(v, 80, res, 1.0, QCfg::FP32, 0);
                let lat = costmodel::graph_latency_ms(&g, &CORTEX_A72, Some(force), 4)
                    .unwrap();
                cells.push(format!("{:.1}", 1000.0 / lat));
            }
            t.row(cells);
        }
        t.print();
        t.save_json(&format!("fig1_{}", engine.to_lowercase()));
    }
    println!("\npaper's point: YOLOv5s INT8 @320px lands well under 5 FPS; only the");
    println!("tiniest (n, <=256px) configurations are usable without DLRT.");

    // ---- measured (host CPU, width 0.25, fp32 vs int8 vs bitserial) ------
    // "no fusion" reruns the same kernels with residual-add fusion and
    // concat-in-place disabled: the delta is the whole-tensor add passes
    // and concat copies the planner removed (YOLOv5 heads are concat-heavy).
    // "copy cats" disables only the stride-aware *reads*: multi-use concat
    // inputs (SPPF pyramid, PANet skips) fall back to copy_channels, so the
    // delta isolates the strided-vs-copy win of partial striping.
    let mut t = Table::new(
        "Fig.1 measured — yolov5n width=0.25 on host CPU (1 thread)",
        &["res", "FP32", "INT8", "DLRT 2A2W", "DLRT copy cats", "DLRT no add/cat fusion",
          "DLRT FPS"],
    );
    let mut rng = Rng::new(2);
    for res in [128usize, 192] {
        let g = build_yolov5("n", 80, res, 0.25, QCfg::new(2, 2), 0);
        let mq = compile_graph(&g, EngineChoice::Auto).unwrap();
        let vec_convs = if mq.isa == dlrt::kernels::ukernel::Isa::Scalar {
            0
        } else {
            mq.plan.conv_kernels
        };
        println!(
            "res {res} dispatch: isa={}, {}/{} convs vectorized",
            mq.isa.name(),
            vec_convs,
            mq.plan.conv_kernels
        );
        let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
        let m8 = compile_graph(&g, EngineChoice::ForceInt8).unwrap();
        let mut mq_nofuse = mq.clone();
        mq_nofuse.plan = build_plan_with(
            &g,
            PlanOpts { fuse_residual_add: false, concat_in_place: false,
                       ..PlanOpts::default() },
        )
        .unwrap();
        let mut mq_copycat = mq.clone();
        mq_copycat.plan = build_plan_with(
            &g,
            PlanOpts { strided_reads: false, ..PlanOpts::default() },
        )
        .unwrap();
        let mut x = Tensor::zeros(vec![1, res, res, 3]);
        for v in x.data.iter_mut() {
            *v = rng.f32();
        }
        let mut ex = Executor::new(1);
        let t_f = bench_ms(1, 5, || { ex.run(&mf, &x).unwrap(); });
        let t_8 = bench_ms(1, 5, || { ex.run(&m8, &x).unwrap(); });
        let t_q = bench_ms(1, 5, || { ex.run(&mq, &x).unwrap(); });
        let t_qc = bench_ms(1, 5, || { ex.run(&mq_copycat, &x).unwrap(); });
        let t_qn = bench_ms(1, 5, || { ex.run(&mq_nofuse, &x).unwrap(); });
        t.row(vec![
            format!("{res}"),
            ms(t_f.median_ms),
            ms(t_8.median_ms),
            ms(t_q.median_ms),
            ms(t_qc.median_ms),
            ms(t_qn.median_ms),
            format!("{:.1}", 1000.0 / t_q.median_ms),
        ]);
        println!(
            "res {res}: {} fused adds, {} in-place concats ({} partial, {} fallbacks), \
             {} stripe readers — add/concat fusion saves {:.2}% per-inference \
             (strided reads alone {:.2}%), arena {} -> {} B",
            mq.plan.fused_add_instrs(),
            mq.plan.in_place_concats,
            mq.plan.partial_concats,
            mq.plan.concat_fallbacks.len(),
            mq.plan.read_view_instrs(),
            100.0 * (t_qn.median_ms - t_q.median_ms) / t_qn.median_ms,
            100.0 * (t_qc.median_ms - t_q.median_ms) / t_qc.median_ms,
            mq_nofuse.plan.arena_bytes(1),
            mq.plan.arena_bytes(1),
        );
    }
    t.print();
    t.save_json("fig1_measured");
}

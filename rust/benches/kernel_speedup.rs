//! §V kernel-level claims: bitserial vs FP32 vs INT8 GEMM on ResNet-shaped
//! problems, measured on the host CPU, plus the A53 end-to-end projection
//! the paper reports (2.9x @2-bit, 4.4x @1-bit on ResNet18).
//!
//! Run: `cargo bench --bench kernel_speedup`

use dlrt::bench_harness::{bench_ms, ms, reps_for, Table};
use dlrt::costmodel::{self, EngineKind, CORTEX_A53};
use dlrt::dlrt::graph::QCfg;
use dlrt::kernels::bitserial::{gemm_bitserial, pack_rows_u8, pack_weights_offset};
use dlrt::kernels::fp32::gemm_rowmajor_bt;
use dlrt::kernels::int8::gemm_u8i8_i32;
use dlrt::kernels::ukernel::{available_isas, kernel_for, PackedW};
use dlrt::models::build_resnet;
use dlrt::tune::tune_bit_shape;
use dlrt::util::rng::Rng;

/// ResNet18-layer-shaped GEMMs: (rows = OH*OW, k = kh*kw*cin, n = cout).
const SHAPES: [(usize, usize, usize); 3] =
    [(784, 1152, 128), (196, 2304, 256), (3136, 576, 64)];

fn main() {
    let mut table = Table::new(
        "Kernel GEMM speedups (host CPU, 1 thread) — paper §V mechanism",
        &["shape (rows,k,n)", "FP32", "INT8", "2A2W", "1A2W", "1A1W",
          "2A2W vs FP32", "1A1W vs FP32"],
    );
    let mut rng = Rng::new(1);
    for (m, k, n) in SHAPES {
        let a_f: Vec<f32> = (0..m * k).map(|_| rng.f32()).collect();
        let b_f: Vec<f32> = (0..n * k).map(|_| rng.normal() * 0.1).collect();
        let mut out_f = vec![0.0f32; m * n];
        let t_first = bench_ms(0, 1, || gemm_rowmajor_bt(&a_f, &b_f, m, n, k, &mut out_f, 1));
        let reps = reps_for(t_first.median_ms, 1200.0);
        let t_f = bench_ms(1, reps, || gemm_rowmajor_bt(&a_f, &b_f, m, n, k, &mut out_f, 1));

        let a_u: Vec<u8> = (0..m * k).map(|_| rng.usize(4) as u8).collect();
        let b_i: Vec<i8> = (0..n * k).map(|_| rng.range(-127, 128) as i8).collect();
        let mut out_i = vec![0i32; m * n];
        let t_8 = bench_ms(1, reps, || gemm_u8i8_i32(&a_u, &b_i, m, n, k, &mut out_i, 1));

        let mut t_bits = Vec::new();
        for (ab, wb) in [(2usize, 2usize), (1, 2), (1, 1)] {
            let codes_a: Vec<u8> = (0..m * k).map(|_| rng.usize(1 << ab) as u8).collect();
            let wq: Vec<i32> = (0..n * k)
                .map(|_| rng.range(-(1 << (wb - 1)), 1 << (wb - 1)) as i32)
                .collect();
            let wp = pack_weights_offset(&wq, n, k, wb);
            let mut out_b = vec![0i32; m * n];
            // packing activations is part of the runtime cost: include it
            let t = bench_ms(1, reps, || {
                let ap = pack_rows_u8(&codes_a, m, k, ab);
                gemm_bitserial(&ap, &wp, wb, &mut out_b, 1);
            });
            t_bits.push(t.median_ms);
        }
        table.row(vec![
            format!("({m},{k},{n})"),
            ms(t_f.median_ms),
            ms(t_8.median_ms),
            ms(t_bits[0]),
            ms(t_bits[1]),
            ms(t_bits[2]),
            format!("{:.2}x", t_f.median_ms / t_bits[0]),
            format!("{:.2}x", t_f.median_ms / t_bits[2]),
        ]);
    }
    table.print();
    table.save_json("kernel_speedup");

    // ---- per-ISA micro-kernel comparison --------------------------------
    // Same bitserial GEMM through every registered inner kernel the host
    // can run (weights prepacked to each kernel's tile layout); the last
    // column is the dispatch win: best SIMD kernel vs the scalar fallback.
    let isas = available_isas();
    let cols: Vec<String> = std::iter::once("shape (rows,k,n)".to_string())
        .chain(isas.iter().map(|i| i.name().to_string()))
        .chain(["SIMD vs scalar".to_string(), "tuned".to_string(),
                "tuned vs default".to_string()])
        .collect();
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t_isa = Table::new(
        "Bitserial 2A2W GEMM per micro-kernel ISA (1 thread) — runtime dispatch",
        &col_refs,
    );
    for (m, k, n) in SHAPES {
        let codes_a: Vec<u8> = (0..m * k).map(|_| rng.usize(4) as u8).collect();
        let wq: Vec<i32> = (0..n * k).map(|_| rng.range(-2, 2) as i32).collect();
        let wp = pack_weights_offset(&wq, n, k, 2);
        let ap = pack_rows_u8(&codes_a, m, k, 2);
        let mut out_b = vec![0i32; m * n];
        let mut row = vec![format!("({m},{k},{n})")];
        let mut medians = Vec::new();
        for &isa in &isas {
            let uk = kernel_for(isa).expect("listed ISA has a kernel");
            let pw = PackedW::from_packed(&wp, uk.weight_layout());
            let first = bench_ms(0, 1, || (uk.gemm_bit)(&uk.desc, &ap, &pw, 2, &mut out_b, 1));
            let reps = reps_for(first.median_ms, 800.0);
            let tt = bench_ms(1, reps, || (uk.gemm_bit)(&uk.desc, &ap, &pw, 2, &mut out_b, 1));
            medians.push(tt.median_ms);
            row.push(ms(tt.median_ms));
        }
        // available_isas() is best-first with scalar always last
        let scalar_ms = *medians.last().unwrap();
        row.push(format!("{:.2}x", scalar_ms / medians[0]));
        // tuned-vs-default: the `dlrt tune` geometry search on the best
        // kernel for this shape (tuned is never slower by construction)
        let (_, default_ms, tuned_ms) =
            tune_bit_shape(isas[0], m, n, k, 6, 5).expect("best ISA has a kernel");
        row.push(ms(tuned_ms));
        row.push(format!("{:.2}x", default_ms / tuned_ms.max(1e-9)));
        t_isa.row(row);
    }
    t_isa.print();
    t_isa.save_json("kernel_speedup_isa");

    // ---- paper §V end-to-end projection ---------------------------------
    let mut proj = Table::new(
        "ResNet18@224 on Cortex-A53 (projected, 4 threads) — paper §V",
        &["config", "latency", "speedup", "paper"],
    );
    let g2 = build_resnet(18, 1000, 224, 1.0, QCfg::new(2, 2), 0);
    let g1 = build_resnet(18, 1000, 224, 1.0, QCfg::new(1, 1), 0);
    let fp32 = costmodel::graph_latency_ms(&g2, &CORTEX_A53, Some(EngineKind::Fp32), 4)
        .unwrap();
    let b2 = costmodel::graph_latency_ms(&g2, &CORTEX_A53, None, 4).unwrap();
    let b1 = costmodel::graph_latency_ms(&g1, &CORTEX_A53, None, 4).unwrap();
    proj.row(vec!["FP32 baseline".into(), ms(fp32), "1.0x".into(), "1.0x".into()]);
    proj.row(vec!["DLRT 2-bit".into(), ms(b2), format!("{:.1}x", fp32 / b2),
                  "2.9x".into()]);
    proj.row(vec!["DLRT 1-bit".into(), ms(b1), format!("{:.1}x", fp32 / b1),
                  "4.4x".into()]);
    proj.print();
    proj.save_json("kernel_speedup_projection");
}

//! Ablation — the design choices DESIGN.md §5.2 calls out for the
//! bitserial engine:
//!   (a) thread scaling of the bitserial GEMM,
//!   (b) bit-width sweep (1..4 bits each side) at fixed shape,
//!   (c) activation packing cost share (pack+gemm vs gemm alone),
//!   (d) M×N cache-tile sweep around the kernel's `TILE_M`×`TILE_N` default,
//!   (e) micro-kernel ISA sweep: the same GEMM through every registered
//!       inner kernel the host can run (scalar vs host SIMD).
//!
//! Run: `cargo bench --bench ablation_tiling`

use dlrt::bench_harness::{bench_ms, ms, Table};
use dlrt::kernels::bitserial::{
    gemm_bitserial, gemm_bitserial_tiled, pack_rows_u8, pack_weights_offset, MAX_TILE_M,
    TILE_M, TILE_N,
};
use dlrt::util::rng::Rng;

fn main() {
    let (m, k, n) = (784usize, 1152usize, 128usize);
    let mut rng = Rng::new(11);

    // ---- (a) thread scaling ----------------------------------------------
    let codes: Vec<u8> = (0..m * k).map(|_| rng.usize(4) as u8).collect();
    let wq: Vec<i32> = (0..n * k).map(|_| rng.range(-2, 2) as i32).collect();
    let ap = pack_rows_u8(&codes, m, k, 2);
    let wp = pack_weights_offset(&wq, n, k, 2);
    let mut out = vec![0i32; m * n];
    let mut t = Table::new(
        "Ablation (a): bitserial GEMM thread scaling (784x1152x128, 2A2W)",
        &["threads", "median", "scaling"],
    );
    let base = bench_ms(1, 9, || gemm_bitserial(&ap, &wp, 2, &mut out, 1)).median_ms;
    for threads in [1usize, 2, 4] {
        let tt = bench_ms(1, 9, || gemm_bitserial(&ap, &wp, 2, &mut out, threads));
        t.row(vec![threads.to_string(), ms(tt.median_ms),
                   format!("{:.2}x", base / tt.median_ms)]);
    }
    t.print();
    t.save_json("ablation_threads");

    // ---- (b) bit-width sweep ---------------------------------------------
    let mut t = Table::new(
        "Ablation (b): bit-width sweep (same shape; cost ∝ w_bits*a_bits)",
        &["config", "median", "vs 1A1W"],
    );
    let mut base_1a1w = 0.0;
    for (ab, wb) in [(1usize, 1usize), (1, 2), (2, 2), (3, 2), (2, 3), (4, 4)] {
        let codes: Vec<u8> = (0..m * k).map(|_| rng.usize(1 << ab) as u8).collect();
        let wq: Vec<i32> = (0..n * k)
            .map(|_| rng.range(-(1 << (wb - 1)), 1 << (wb - 1)) as i32)
            .collect();
        let ap = pack_rows_u8(&codes, m, k, ab);
        let wp = pack_weights_offset(&wq, n, k, wb);
        let tt = bench_ms(1, 7, || gemm_bitserial(&ap, &wp, wb, &mut out, 1));
        if (ab, wb) == (1, 1) {
            base_1a1w = tt.median_ms;
        }
        t.row(vec![format!("{ab}A{wb}W"), ms(tt.median_ms),
                   format!("{:.2}x", tt.median_ms / base_1a1w)]);
    }
    t.print();
    t.save_json("ablation_bits");

    // ---- (c) packing cost share -------------------------------------------
    let codes: Vec<u8> = (0..m * k).map(|_| rng.usize(4) as u8).collect();
    let t_pack = bench_ms(1, 9, || {
        std::hint::black_box(pack_rows_u8(&codes, m, k, 2));
    });
    let ap = pack_rows_u8(&codes, m, k, 2);
    let t_gemm = bench_ms(1, 9, || gemm_bitserial(&ap, &wp, 2, &mut out, 1));
    let mut t = Table::new(
        "Ablation (c): activation packing cost share (2A2W)",
        &["stage", "median", "share"],
    );
    let total = t_pack.median_ms + t_gemm.median_ms;
    t.row(vec!["pack activations".into(), ms(t_pack.median_ms),
               format!("{:.0}%", 100.0 * t_pack.median_ms / total)]);
    t.row(vec!["bitserial GEMM".into(), ms(t_gemm.median_ms),
               format!("{:.0}%", 100.0 * t_gemm.median_ms / total)]);
    t.print();
    t.save_json("ablation_pack");

    // ---- (d) cache-tile sweep --------------------------------------------
    // Same 2A2W shape; the default (TILE_M, TILE_N) should be the fastest
    // configuration or within ~5% of the best measured one.
    let codes: Vec<u8> = (0..m * k).map(|_| rng.usize(4) as u8).collect();
    let ap = pack_rows_u8(&codes, m, k, 2);
    let nthreads = 4;
    let mut t = Table::new(
        "Ablation (d): M×N cache-tile sweep (784x1152x128, 2A2W, 4 threads)",
        &["tile (M,N)", "median", "vs default"],
    );
    let t_default = bench_ms(2, 9, || {
        gemm_bitserial_tiled(&ap, &wp, 2, &mut out, nthreads, TILE_M, TILE_N)
    })
    .median_ms;
    let mut best = (t_default, TILE_M, TILE_N);
    for (tm, tn) in [
        (8usize, 8usize), (16, 8), (16, 16), (32, 8), (TILE_M, TILE_N), (32, 32),
        (64, 16), (64, 32), (MAX_TILE_M, 64),
    ] {
        let med = if (tm, tn) == (TILE_M, TILE_N) {
            t_default
        } else {
            bench_ms(2, 9, || gemm_bitserial_tiled(&ap, &wp, 2, &mut out, nthreads, tm, tn))
                .median_ms
        };
        if med < best.0 {
            best = (med, tm, tn);
        }
        let tag = if (tm, tn) == (TILE_M, TILE_N) { " (default)" } else { "" };
        t.row(vec![format!("({tm},{tn}){tag}"), ms(med),
                   format!("{:.2}x", t_default / med)]);
    }
    t.print();
    t.save_json("ablation_tiles");
    let slowdown = 100.0 * (t_default / best.0 - 1.0);
    println!(
        "default ({TILE_M},{TILE_N}) = {}; best ({},{}) = {} — default is {:.1}% off best{}",
        ms(t_default), best.1, best.2, ms(best.0), slowdown,
        if slowdown <= 5.0 { " [OK: within 5%]" } else { " [WARN: retune TILE_M/TILE_N]" },
    );

    // ---- (e) micro-kernel ISA sweep ---------------------------------------
    // Same 2A2W shape through every registered inner kernel this host can
    // run, each with weights prepacked to its own tile layout.
    use dlrt::kernels::ukernel::{available_isas, kernel_for, PackedW};
    let mut t = Table::new(
        "Ablation (e): micro-kernel ISA sweep (784x1152x128, 2A2W, 1 thread)",
        &["isa", "tile (M,N)", "median", "vs scalar"],
    );
    let isas = available_isas();
    let mut rows: Vec<(String, String, f64)> = Vec::new();
    for &isa in &isas {
        let uk = kernel_for(isa).expect("listed ISA has a kernel");
        let pw = PackedW::from_packed(&wp, uk.weight_layout());
        let tt = bench_ms(1, 9, || (uk.gemm_bit)(&uk.desc, &ap, &pw, 2, &mut out, 1));
        rows.push((
            isa.name().to_string(),
            format!("({},{})", uk.desc.tile_m, uk.desc.tile_n),
            tt.median_ms,
        ));
    }
    // available_isas() keeps scalar last, so the baseline is the final row
    // (captured before the tuned extra row below)
    let scalar_ms = rows.last().map(|r| r.2).unwrap_or(1.0);
    // tuned-vs-default: the `dlrt tune` geometry search on the best kernel,
    // weights repacked to the winning tile order
    if let Some((desc, _, tuned_ms)) = dlrt::tune::tune_bit_shape(isas[0], m, n, k, 6, 5) {
        rows.push((
            format!("{} tuned", isas[0].name()),
            format!("({},{})", desc.tile_m, desc.tile_n),
            tuned_ms,
        ));
    }
    for (name, tile, med) in rows {
        t.row(vec![name, tile, ms(med), format!("{:.2}x", scalar_ms / med)]);
    }
    t.print();
    t.save_json("ablation_isa");
}

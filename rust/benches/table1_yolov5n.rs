//! Table I — YOLOv5n @352px on COCO-8 classes, Cortex-A53: conservative
//! mixed precision (FP32 + 2-bit). Paper row: FP32 mAP 0.424 @ 250 ms →
//! mixed mAP 0.414 @ 98.4 ms (2.54x).
//!
//! Latency side here (projection + measured-at-reduced-scale); the mAP
//! column comes from `make exp-table1` (QAT on the synth-shapes COCO-8
//! stand-in) and is joined if present.
//!
//! Run: `cargo bench --bench table1_yolov5n`

use dlrt::bench_harness::{bench_ms, ms, Table};
use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::costmodel::{self, EngineKind, CORTEX_A53};
use dlrt::dlrt::graph::QCfg;
use dlrt::exec::Executor;
use dlrt::models::{build_yolov5, set_mixed_precision};
use dlrt::util::json::Json;
use dlrt::util::rng::Rng;
use dlrt::Tensor;

fn main() {
    // conservative policy: stem + detect heads + last C3 stay FP32
    let mut g = build_yolov5("n", 8, 352, 1.0, QCfg::new(2, 2), 0);
    let nconv = g.conv_nodes().count();
    set_mixed_precision(&mut g, 1, Some(nconv - 6), 2, 2);

    let fp32 = costmodel::graph_latency_ms(&g, &CORTEX_A53, Some(EngineKind::Fp32), 4)
        .unwrap();
    let mixed = costmodel::graph_latency_ms(&g, &CORTEX_A53, None, 4).unwrap();

    // accuracy side from the python experiment, if present
    let (map_fp32, map_mixed) = read_maps().unwrap_or((f64::NAN, f64::NAN));

    let mut t = Table::new(
        "Table I — YOLOv5n @352px, COCO-8, Cortex-A53 (projection + synth mAP)",
        &["config", "mAP (synth)", "latency", "speedup", "paper"],
    );
    t.row(vec![
        "YOLOv5n FP32".into(),
        fmt_map(map_fp32),
        ms(fp32),
        "1.00x".into(),
        "0.424 / 250 ms".into(),
    ]);
    t.row(vec![
        "YOLOv5n mixed (FP32+2bit, conservative)".into(),
        fmt_map(map_mixed),
        ms(mixed),
        format!("{:.2}x", fp32 / mixed),
        "0.414 / 98.4 ms (2.54x)".into(),
    ]);
    t.print();
    t.save_json("table1_projection");

    // ---- measured latency at reduced scale. YOLOv5n's channels are thin
    //      (16..256), so many layers sit in the small-k regime where u64
    //      bitserial underutilizes words — expect a modest measured ratio
    //      (the paper's Neon kernels at 128 bits face the same effect;
    //      hence Table I's 2.54x rather than ResNet's 2.9-3.75x). ---------
    let mut m = Table::new(
        "Table I measured — yolov5n full width @160px, host CPU (1 thread)",
        &["config", "median", "speedup"],
    );
    let mut gm = build_yolov5("n", 8, 160, 1.0, QCfg::new(2, 2), 0);
    let nconv = gm.conv_nodes().count();
    set_mixed_precision(&mut gm, 1, Some(nconv - 6), 2, 2);
    let mq = compile_graph(&gm, EngineChoice::Auto).unwrap();
    let mf = compile_graph(&gm, EngineChoice::ForceFp32).unwrap();
    let mut rng = Rng::new(7);
    let mut x = Tensor::zeros(vec![1, 160, 160, 3]);
    for v in x.data.iter_mut() {
        *v = rng.f32();
    }
    let mut ex = Executor::new(1);
    let t_f = bench_ms(1, 5, || { ex.run(&mf, &x).unwrap(); });
    let t_q = bench_ms(1, 5, || { ex.run(&mq, &x).unwrap(); });
    m.row(vec!["FP32".into(), ms(t_f.median_ms), "1.00x".into()]);
    m.row(vec!["mixed FP32+2bit".into(), ms(t_q.median_ms),
               format!("{:.2}x", t_f.median_ms / t_q.median_ms)]);
    m.print();
    m.save_json("table1_measured");
}

fn fmt_map(v: f64) -> String {
    if v.is_nan() {
        "run `make exp-table1`".into()
    } else {
        format!("{v:.3}")
    }
}

fn read_maps() -> Option<(f64, f64)> {
    let text = std::fs::read_to_string("artifacts/experiments/table1_yolov5n.json").ok()?;
    let v = Json::parse(&text).ok()?;
    Some((
        v.get("map_fp32").ok()?.num().ok()?,
        v.get("map_mixed").ok()?.num().ok()?,
    ))
}

//! §VII.A model-size claims: quantized `.dlrt` vs FP32 storage for every
//! evaluation model. Paper headline: 15.58x reduction for ResNet18-VWW
//! ("up to 16x compression with 2-bit quantization", §VIII).
//!
//! Also reports peak activation memory from the executor's liveness planner.
//!
//! Run: `cargo bench --bench model_size`

use dlrt::bench_harness::Table;
use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::dlrt::graph::QCfg;
use dlrt::exec::planner::peak_live_elems;
use dlrt::models;

fn mb(bytes: usize) -> String {
    format!("{:.2} MB", bytes as f64 / 1e6)
}

fn main() {
    let q = QCfg::new(2, 2);
    let specs: Vec<(&str, dlrt::Graph)> = vec![
        ("resnet18-vww@224", models::build_resnet(18, 2, 224, 1.0, q, 0)),
        ("resnet18@224", models::build_resnet(18, 1000, 224, 1.0, q, 0)),
        ("resnet50@224", models::build_resnet(50, 1000, 224, 1.0, q, 0)),
        ("vgg16_ssd@300", models::build_vgg16_ssd(21, 300, 1.0, q, 0)),
        ("yolov5n@320", models::build_yolov5("n", 80, 320, 1.0, q, 0)),
        ("yolov5s@320", models::build_yolov5("s", 80, 320, 1.0, q, 0)),
        ("yolov5m@320", models::build_yolov5("m", 80, 320, 1.0, q, 0)),
    ];
    let mut t = Table::new(
        "Model storage — FP32 vs DLRT 2A2W packed (paper §VII.A: 15.58x on ResNet18-VWW)",
        &["model", "FP32", "DLRT packed", "compression", "peak act (f32)"],
    );
    for (name, g) in specs {
        let mq = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
        let peak = peak_live_elems(&g).unwrap();
        t.row(vec![
            name.to_string(),
            mb(mf.weight_bytes()),
            mb(mq.weight_bytes()),
            format!("{:.2}x", mf.weight_bytes() as f64 / mq.weight_bytes() as f64),
            mb(peak * 4),
        ]);
    }
    t.print();
    t.save_json("model_size");
    println!("\n(compression < 16x exactly where mixed precision keeps layers FP32 —");
    println!(" the stem/head convs; the paper's 15.58x counts the quantized body.)");
}

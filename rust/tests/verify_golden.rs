//! Golden tests for the plan verifier: one minimal hand-written plan per
//! diagnostic kind, asserting the exact rule name and instruction index.
//! These pin the diagnostic surface — `tests/verify_fuzz.rs` proves breadth
//! over generated plans, this file proves each rule fires for the textbook
//! shape of its violation and nothing else.

use dlrt::dlrt::graph::Op;
use dlrt::exec::planner::{ChanView, ExecPlan, Instr, OutSpec};
use dlrt::exec::verify::{
    verify, RULE_ARITY, RULE_CLOBBERED_READ, RULE_FOOTPRINT_OOB, RULE_IN_PLACE_ALIAS,
    RULE_KERNEL_IDX, RULE_SAME_SLOT_OVERLAP, RULE_SLOT_OOB, RULE_THREAD_RACE,
    RULE_UNINIT_READ, RULE_UNLOWERED_OP, RULE_WRITE_OVERLAP,
};

/// A bare instruction with no fusion, views, or concat metadata.
fn instr(
    name: &str,
    op: Op,
    in_slots: Vec<usize>,
    in_tails: Vec<Vec<usize>>,
    out_slot: usize,
    out_tail: Vec<usize>,
) -> Instr {
    Instr {
        name: name.into(),
        kernel_idx: None,
        op,
        fused: None,
        fused_add: false,
        fused_post: None,
        in_views: vec![None; in_slots.len()],
        in_slots,
        in_tails,
        cat_offs: Vec::new(),
        cat_partial: false,
        out_slot,
        out_tail,
        out_view: None,
        in_place: false,
    }
}

/// Two 32-element slots; the request input is a dense 4×4×2 in slot 0.
fn plan(instrs: Vec<Instr>, outputs: Vec<OutSpec>) -> ExecPlan {
    ExecPlan {
        instrs,
        slot_sizes: vec![32, 32],
        input_slot: 0,
        input_tail: vec![4, 4, 2],
        outputs,
        nominal_batch: 1,
        conv_kernels: 0,
        dense_kernels: 0,
        in_place_concats: 0,
        partial_concats: 0,
        concat_fallbacks: Vec::new(),
    }
}

fn out1() -> Vec<OutSpec> {
    vec![OutSpec { slot: 1, tail: vec![4, 4, 2] }]
}

fn expect(p: &ExecPlan, rule: &str, instr_idx: Option<usize>) {
    let d = verify(p).expect_err(&format!("plan should be rejected by rule {rule}"));
    assert_eq!(d.rule, rule, "wrong rule: {d}");
    assert_eq!(d.instr, instr_idx, "wrong instruction: {d}");
}

#[test]
fn control_plan_passes_with_counted_evidence() {
    let p = plan(
        vec![instr("act", Op::Relu, vec![0], vec![vec![4, 4, 2]], 1, vec![4, 4, 2])],
        out1(),
    );
    let rep = verify(&p).unwrap_or_else(|d| panic!("control plan rejected: {d}"));
    assert_eq!(rep.instrs, 1);
    assert_eq!(rep.slots, 2);
    assert_eq!(rep.regions, 2, "input region + one write");
    assert_eq!(rep.kills, 0);
    assert_eq!(rep.reads, 2, "one instruction input + one graph output");
}

#[test]
fn golden_arity_misaligned_input_views() {
    let mut i = instr("act", Op::Relu, vec![0], vec![vec![4, 4, 2]], 1, vec![4, 4, 2]);
    i.in_views.clear();
    expect(&plan(vec![i], out1()), RULE_ARITY, Some(0));
}

#[test]
fn golden_arity_cat_offs_on_non_concat() {
    let mut i = instr("act", Op::Relu, vec![0], vec![vec![4, 4, 2]], 1, vec![4, 4, 2]);
    i.cat_offs = vec![0];
    expect(&plan(vec![i], out1()), RULE_ARITY, Some(0));
}

#[test]
fn golden_kernel_idx_on_non_kernel_op() {
    // a Relu has no compiled-kernel table entry, so any resolved index is a
    // planner bug the verifier must refuse
    let mut i = instr("act", Op::Relu, vec![0], vec![vec![4, 4, 2]], 1, vec![4, 4, 2]);
    i.kernel_idx = Some(0);
    expect(&plan(vec![i], out1()), RULE_KERNEL_IDX, Some(0));
}

#[test]
fn golden_unlowered_flatten() {
    let i = instr("flat", Op::Flatten, vec![0], vec![vec![4, 4, 2]], 1, vec![32]);
    expect(&plan(vec![i], out1()), RULE_UNLOWERED_OP, Some(0));
}

#[test]
fn golden_in_place_alias_must_be_same_slot() {
    let mut i = instr("act", Op::Relu, vec![0], vec![vec![4, 4, 2]], 1, vec![4, 4, 2]);
    i.in_place = true; // claims in-place but reads slot 0, writes slot 1
    expect(&plan(vec![i], out1()), RULE_IN_PLACE_ALIAS, Some(0));
}

#[test]
fn golden_slot_out_of_range() {
    let i = instr("act", Op::Relu, vec![5], vec![vec![4, 4, 2]], 1, vec![4, 4, 2]);
    let p = plan(vec![i], out1());
    let d = verify(&p).unwrap_err();
    assert_eq!(d.rule, RULE_SLOT_OOB, "{d}");
    assert_eq!(d.instr, Some(0), "{d}");
    assert_eq!(d.slot, Some(5), "{d}");
}

#[test]
fn golden_footprint_exceeds_slot() {
    let i = instr("act", Op::Relu, vec![0], vec![vec![4, 4, 2]], 1, vec![100, 100, 100]);
    let p = plan(vec![i], out1());
    let d = verify(&p).unwrap_err();
    assert_eq!(d.rule, RULE_FOOTPRINT_OOB, "{d}");
    assert_eq!(d.instr, Some(0), "{d}");
    assert_eq!(d.slot, Some(1), "{d}");
}

#[test]
fn golden_thread_race_stripe_escapes_its_row() {
    let mut i = instr("act", Op::Relu, vec![0], vec![vec![4, 4, 2]], 1, vec![4, 4, 2]);
    // 2 channels written at offset 1 of a 2-channel row: [1, 3) exceeds the
    // row, so worker chunks writing "their" rows would collide
    i.out_view = Some(ChanView { stride: 2, off: 1 });
    expect(&plan(vec![i], out1()), RULE_THREAD_RACE, Some(0));
}

#[test]
fn golden_write_overlap_concat_stripes_collide() {
    let mut i = instr(
        "cat",
        Op::Concat,
        vec![0, 0],
        vec![vec![4, 4, 1], vec![4, 4, 1]],
        1,
        vec![4, 4, 2],
    );
    i.cat_offs = vec![0, 0]; // both inputs land on channel 0
    expect(&plan(vec![i], out1()), RULE_WRITE_OVERLAP, Some(0));
}

#[test]
fn golden_same_slot_read_write_overlap() {
    // reads slot 0 densely while writing slot 0 densely, without the
    // in-place lowering that makes that legal
    let i = instr("act", Op::Relu, vec![0], vec![vec![4, 4, 2]], 0, vec![4, 4, 2]);
    let p = plan(vec![i], vec![OutSpec { slot: 0, tail: vec![4, 4, 2] }]);
    expect(&p, RULE_SAME_SLOT_OVERLAP, Some(0));
}

#[test]
fn golden_uninit_read() {
    // slot 1 is never written before this read
    let i = instr("act", Op::Relu, vec![1], vec![vec![4, 4, 2]], 0, vec![4, 4, 2]);
    let p = plan(vec![i], vec![OutSpec { slot: 0, tail: vec![4, 4, 2] }]);
    let d = verify(&p).unwrap_err();
    assert_eq!(d.rule, RULE_UNINIT_READ, "{d}");
    assert_eq!(d.instr, Some(0), "{d}");
    assert_eq!(d.slot, Some(1), "{d}");
}

#[test]
fn golden_clobbered_read_names_writer_and_killer() {
    // instr 0 fills slot 1; instr 1 reuses the slot with a smaller value,
    // killing it; instr 2 reads the full original footprint
    let p = plan(
        vec![
            instr("a", Op::Relu, vec![0], vec![vec![4, 4, 2]], 1, vec![4, 4, 2]),
            instr("b", Op::Relu, vec![0], vec![vec![2, 2, 2]], 1, vec![2, 2, 2]),
            instr("c", Op::Relu, vec![1], vec![vec![4, 4, 2]], 0, vec![4, 4, 2]),
        ],
        vec![OutSpec { slot: 0, tail: vec![4, 4, 2] }],
    );
    let d = verify(&p).unwrap_err();
    assert_eq!(d.rule, RULE_CLOBBERED_READ, "{d}");
    assert_eq!(d.instr, Some(2), "{d}");
    assert_eq!(d.slot, Some(1), "{d}");
    assert!(d.detail.contains("instr 0"), "should name the writer: {d}");
    assert!(d.detail.contains("instr 1"), "should name the killer: {d}");
}

#[test]
fn golden_output_of_unwritten_slot_is_plan_level() {
    let p = plan(Vec::new(), out1()); // no instruction ever writes slot 1
    let d = verify(&p).unwrap_err();
    assert_eq!(d.rule, RULE_UNINIT_READ, "{d}");
    assert_eq!(d.instr, None, "{d}");
    assert_eq!(d.name, "output[0]", "{d}");
}

#[test]
fn golden_input_slot_out_of_range_is_plan_level() {
    let mut p = plan(Vec::new(), Vec::new());
    p.input_slot = 7;
    let d = verify(&p).unwrap_err();
    assert_eq!(d.rule, RULE_SLOT_OOB, "{d}");
    assert_eq!(d.instr, None, "{d}");
    assert_eq!(d.name, "input", "{d}");
    assert_eq!(d.slot, Some(7), "{d}");
}

#[test]
fn golden_concat_stripes_that_tile_the_row_pass() {
    // the legal version of the write-overlap case: offsets [0, 1] tile the
    // 2-channel row exactly, and the output read proves full coverage
    let mut i = instr(
        "cat",
        Op::Concat,
        vec![0, 0],
        vec![vec![4, 4, 1], vec![4, 4, 1]],
        1,
        vec![4, 4, 2],
    );
    i.cat_offs = vec![0, 1];
    let p = plan(vec![i], out1());
    let rep = verify(&p).unwrap_or_else(|d| panic!("legal concat rejected: {d}"));
    assert!(rep.race_checks > 0, "stripe writes must be race-proven");
}

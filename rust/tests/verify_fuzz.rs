//! Mutation fuzzing of the plan verifier: proves it has teeth.
//!
//! Every seeded random graph from the shared generator is lowered three ways
//! (full optimizations, none, and no strided reads) and all resulting plans
//! must verify **clean** — zero false positives, or `build_plan_with` /
//! `format::load` would start refusing valid models. Then each plan gets one
//! targeted corruption per mutation class — shrink a slot, widen a stripe
//! past its row, collapse two producer stripes onto the same channels,
//! retarget a read at a not-yet-written slot, resurrect a value that slot
//! reuse overwrote, skew a concat destination offset, point a resolved
//! kernel index past the kernel tables — and the verifier must
//! reject every single mutant. Per-class applied/caught counters are printed
//! in greppable form and asserted non-vacuous, so a generator drift that
//! stops producing some pattern fails loudly instead of silently shrinking
//! coverage.

mod common;

use std::collections::{BTreeMap, BTreeSet};

use common::random_graph;
use dlrt::dlrt::graph::Op;
use dlrt::exec::planner::{build_plan_with, ExecPlan, Instr, PlanOpts};
use dlrt::exec::verify::verify;

/// Seeds per run: the CI release smoke sweeps the full 500; debug builds
/// (plain `cargo test`) run a subset to keep tier-1 fast.
const SEEDS: u64 = if cfg!(debug_assertions) { 150 } else { 500 };

fn numel(tail: &[usize]) -> usize {
    tail.iter().product()
}

/// Slot elements a strided access occupies (`rows × stride`, mirroring how
/// the executor slices the arena).
fn occ_view(tail: &[usize], stride: usize) -> usize {
    let rows: usize = tail[..tail.len() - 1].iter().product();
    rows * stride
}

fn read_occ(ins: &Instr, k: usize) -> usize {
    match &ins.in_views[k] {
        Some(v) => occ_view(&ins.in_tails[k], v.stride),
        None => numel(&ins.in_tails[k]),
    }
}

fn write_occ(ins: &Instr) -> usize {
    let stride = match (&ins.out_view, matches!(ins.op, Op::Concat)) {
        (Some(v), _) => v.stride,
        (None, true) => *ins.out_tail.last().unwrap(),
        (None, false) => return numel(&ins.out_tail),
    };
    occ_view(&ins.out_tail, stride)
}

// ---------------------------------------------------------------------------
// mutation classes — each finds an applicable site and returns the corrupted
// plan plus a human description, or None when the plan has no such site
// ---------------------------------------------------------------------------

/// Shrink the slot that some access fills exactly, by one element: that
/// access no longer fits.
fn mutate_shrink_slot(p: &ExecPlan) -> Option<(ExecPlan, String)> {
    let mut max_occ = vec![0usize; p.slot_sizes.len()];
    let bump = |s: usize, occ: usize, m: &mut Vec<usize>| m[s] = m[s].max(occ);
    bump(p.input_slot, numel(&p.input_tail), &mut max_occ);
    for ins in &p.instrs {
        for k in 0..ins.in_slots.len() {
            bump(ins.in_slots[k], read_occ(ins, k), &mut max_occ);
        }
        bump(ins.out_slot, write_occ(ins), &mut max_occ);
    }
    for o in &p.outputs {
        bump(o.slot, numel(&o.tail), &mut max_occ);
    }
    let (s, &occ) = max_occ.iter().enumerate().max_by_key(|&(_, &o)| o)?;
    if occ == 0 {
        return None;
    }
    let mut m = p.clone();
    m.slot_sizes[s] = occ - 1;
    Some((m, format!("slot {s} shrunk from {} to {}", p.slot_sizes[s], occ - 1)))
}

/// Shift a strided writer so its stripe ends one element past its row: rows
/// are no longer byte-disjoint and the worker partition would race.
fn mutate_widen_stripe(p: &ExecPlan) -> Option<(ExecPlan, String)> {
    let (i, off) = p.instrs.iter().enumerate().find_map(|(i, ins)| {
        if matches!(ins.op, Op::Concat) {
            return None;
        }
        let v = ins.out_view.as_ref()?;
        let c = *ins.out_tail.last()?;
        if c == 0 || c > v.stride {
            return None;
        }
        Some((i, v.stride + 1 - c))
    })?;
    let mut m = p.clone();
    m.instrs[i].out_view.as_mut().unwrap().off = off;
    Some((m, format!("instr {i}: stripe shifted to end at stride+1")))
}

/// Collapse two producers striping disjoint channel ranges of an output
/// root onto the same offset: the later stripe silently overwrites the
/// earlier one, and the root's reader sees dead bytes.
fn mutate_overlap_stripes(p: &ExecPlan) -> Option<(ExecPlan, String)> {
    let out_slots: BTreeSet<usize> = p.outputs.iter().map(|o| o.slot).collect();
    let n = p.instrs.len();
    for i1 in 0..n {
        let a = &p.instrs[i1];
        if matches!(a.op, Op::Concat) {
            continue;
        }
        let Some(v1) = a.out_view else { continue };
        let s = a.out_slot;
        // the root must actually be observed: an output spec reads its full
        // extent at the end of the program
        if !out_slots.contains(&s) {
            continue;
        }
        for i2 in i1 + 1..n {
            let b = &p.instrs[i2];
            if b.out_slot != s || matches!(b.op, Op::Concat) {
                continue;
            }
            let Some(v2) = b.out_view else { continue };
            if v2.stride != v1.stride || v2.off == v1.off {
                continue;
            }
            let c2 = *b.out_tail.last().unwrap_or(&0);
            // the relocated stripe must stay inside its row, so the failure
            // is the aliasing itself, not an eager geometry error
            if c2 == 0 || v1.off + c2 > v1.stride {
                continue;
            }
            // nothing after i2 may rewrite the root and re-cover the
            // channels i2 vacated
            if p.instrs[i2 + 1..]
                .iter()
                .any(|w| w.out_slot == s && (w.out_view.is_none() || matches!(w.op, Op::Concat)))
            {
                continue;
            }
            let mut m = p.clone();
            m.instrs[i2].out_view.as_mut().unwrap().off = v1.off;
            return Some((
                m,
                format!("instrs {i1}/{i2}: root stripes collapsed onto channel offset {}", v1.off),
            ));
        }
    }
    None
}

/// Retarget a read at a slot that holds nothing yet at that program point.
fn mutate_retarget_read(p: &ExecPlan) -> Option<(ExecPlan, String)> {
    let nslots = p.slot_sizes.len();
    let mut first_write = vec![usize::MAX; nslots];
    for (i, ins) in p.instrs.iter().enumerate() {
        if first_write[ins.out_slot] == usize::MAX {
            first_write[ins.out_slot] = i;
        }
    }
    for (i, ins) in p.instrs.iter().enumerate() {
        for k in 0..ins.in_slots.len() {
            if ins.in_place && k == 0 {
                // keep the in-place invariant intact so the *uninit read* is
                // the violation, not the alias structure
                continue;
            }
            let fits = read_occ(ins, k);
            if let Some(b) = (0..nslots)
                .find(|&b| b != p.input_slot && first_write[b] > i && fits <= p.slot_sizes[b])
            {
                let mut m = p.clone();
                m.instrs[i].in_slots[k] = b;
                return Some((m, format!("instr {i} input {k} retargeted at unwritten slot {b}")));
            }
        }
    }
    None
}

/// Point a later instruction at a value that legal slot reuse overwrote:
/// instr i2 reuses slot s over a bigger dense value, and a downstream reader
/// is retargeted at the dead value's full footprint.
fn mutate_resurrect_dead(p: &ExecPlan) -> Option<(ExecPlan, String)> {
    let n = p.instrs.len();
    let dense_occ = |ins: &Instr| -> Option<usize> {
        if matches!(ins.op, Op::Concat) || ins.out_view.is_some() {
            None
        } else {
            Some(numel(&ins.out_tail))
        }
    };
    for i2 in 0..n {
        let Some(occ2) = dense_occ(&p.instrs[i2]) else { continue };
        let s = p.instrs[i2].out_slot;
        // the biggest dense value alive in s just before i2: the request
        // input (if untouched so far) or the previous writer
        let tail1: Vec<usize> = if s == p.input_slot
            && p.instrs[..i2].iter().all(|w| w.out_slot != s)
            && numel(&p.input_tail) > occ2
        {
            p.input_tail.clone()
        } else {
            match p.instrs[..i2].iter().rev().find(|w| w.out_slot == s) {
                Some(a) => match dense_occ(a) {
                    Some(occ1) if occ1 > occ2 => a.out_tail.clone(),
                    _ => continue,
                },
                None => continue,
            }
        };
        // first retargetable reader after i2, before anyone rewrites s
        for j in i2 + 1..n {
            if p.instrs[j].out_slot == s {
                break;
            }
            let c = &p.instrs[j];
            if c.in_slots.is_empty() || c.in_place || matches!(c.op, Op::Concat) {
                continue;
            }
            let mut m = p.clone();
            let ins = &mut m.instrs[j];
            ins.in_slots[0] = s;
            ins.in_tails[0] = tail1.clone();
            ins.in_views[0] = None;
            return Some((
                m,
                format!("instr {j} reads the slot-{s} value instr {i2} overwrote"),
            ));
        }
    }
    None
}

/// Skew a full concat's destination offset by one channel: the bumped
/// stripe collides with its neighbor inside the same instruction.
fn mutate_skew_cat_off(p: &ExecPlan) -> Option<(ExecPlan, String)> {
    for (i, ins) in p.instrs.iter().enumerate() {
        if !matches!(ins.op, Op::Concat) || ins.cat_partial || ins.in_slots.len() < 2 {
            continue;
        }
        let k = (0..ins.cat_offs.len()).min_by_key(|&k| ins.cat_offs[k])?;
        let mut m = p.clone();
        m.instrs[i].cat_offs[k] += 1;
        return Some((m, format!("instr {i}: destination offset of input {k} skewed by one")));
    }
    None
}

/// Point a conv/dense instruction's resolved kernel index past the end of
/// the plan's kernel tables: the executor would index a kernel that doesn't
/// exist (or silently run the wrong layer's weights after a table edit).
fn mutate_skew_kernel_idx(p: &ExecPlan) -> Option<(ExecPlan, String)> {
    let (i, old) = p
        .instrs
        .iter()
        .enumerate()
        .find_map(|(i, ins)| ins.kernel_idx.map(|k| (i, k)))?;
    let bogus = p.conv_kernels + p.dense_kernels + 7;
    let mut m = p.clone();
    m.instrs[i].kernel_idx = Some(bogus);
    Some((m, format!("instr {i}: kernel index {old} skewed to out-of-table {bogus}")))
}

// ---------------------------------------------------------------------------
// driver
// ---------------------------------------------------------------------------

type Mutator = fn(&ExecPlan) -> Option<(ExecPlan, String)>;

const CLASSES: [(&str, Mutator); 7] = [
    ("shrink-slot", mutate_shrink_slot),
    ("widen-stripe", mutate_widen_stripe),
    ("overlap-stripes", mutate_overlap_stripes),
    ("retarget-read", mutate_retarget_read),
    ("resurrect-dead", mutate_resurrect_dead),
    ("skew-cat-off", mutate_skew_cat_off),
    ("skew-kernel-idx", mutate_skew_kernel_idx),
];

struct ClassStat {
    name: &'static str,
    applied: usize,
    caught: usize,
    rules: BTreeMap<&'static str, usize>,
}

#[test]
fn verifier_accepts_all_valid_plans_and_rejects_every_mutation() {
    let mut stats: Vec<ClassStat> = CLASSES
        .iter()
        .map(|&(name, _)| ClassStat { name, applied: 0, caught: 0, rules: BTreeMap::new() })
        .collect();
    let mut plans_ok = 0usize;
    let mut failures: Vec<String> = Vec::new();
    for seed in 0..SEEDS {
        let g = random_graph(seed);
        let variants = [
            ("default", PlanOpts::default()),
            ("none", PlanOpts::none()),
            ("no-strided-reads", PlanOpts { strided_reads: false, ..PlanOpts::default() }),
        ];
        for (vname, opts) in variants {
            // build_plan_with already runs the verifier (opts.verify), so a
            // false positive surfaces here as a build error
            let plan = match build_plan_with(&g, opts) {
                Ok(p) => p,
                Err(e) => {
                    failures.push(format!("seed {seed} [{vname}]: build rejected: {e:#}"));
                    continue;
                }
            };
            match verify(&plan) {
                Ok(_) => plans_ok += 1,
                Err(d) => failures.push(format!("seed {seed} [{vname}]: false positive: {d}")),
            }
            for (ci, (cname, mutate)) in CLASSES.iter().enumerate() {
                let Some((mutated, what)) = mutate(&plan) else { continue };
                stats[ci].applied += 1;
                match verify(&mutated) {
                    Err(d) => {
                        stats[ci].caught += 1;
                        *stats[ci].rules.entry(d.rule).or_insert(0) += 1;
                    }
                    Ok(_) => panic!(
                        "verify_fuzz seed {seed} [{vname}]: {cname} mutation slipped \
                         through the verifier ({what})"
                    ),
                }
            }
        }
    }
    assert!(
        failures.is_empty(),
        "verifier rejected {} valid plans:\n{}",
        failures.len(),
        failures.join("\n")
    );
    for st in &stats {
        assert_eq!(
            st.caught, st.applied,
            "{}: {} mutations applied but only {} caught",
            st.name, st.applied, st.caught
        );
        assert!(
            st.applied > 0,
            "{} mutation never applicable across {SEEDS} seeds — fuzzer gone vacuous",
            st.name
        );
    }
    // greppable summary (CI asserts on these lines)
    println!(
        "verify_fuzz: {SEEDS} seeds x 3 plan variants — {plans_ok} plans accepted, \
         0 false positives"
    );
    for st in &stats {
        let rules: Vec<String> = st.rules.iter().map(|(r, n)| format!("{r}x{n}")).collect();
        println!(
            "verify_fuzz mutation {:<16} {}/{} caught via {}",
            st.name,
            st.caught,
            st.applied,
            rules.join(", ")
        );
    }
}

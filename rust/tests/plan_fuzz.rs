//! Randomized differential testing of the execution planner: seeded random
//! graphs (conv / residual add / concat / pool / upsample / activations /
//! flatten / dense, with branching, fan-out ≥ 2 multi-use tensors, nested
//! residuals, concat-of-concat, and SPPF-style serial-pool pyramids) must
//! produce **bit-identical** outputs between the planned arena executor —
//! activation fusion, residual-add fusion, in-place lowering, concat
//! striping with stride-aware reads and partial (mixed eligible/copy)
//! concats and all — and the unfused env-map reference interpreter,
//! across {bitserial, fp32, int8} × {1, 3} threads × batch {1, 3}. Seeds
//! rotate through every host-available micro-kernel ISA (forced at compile
//! time), so the SIMD and scalar inner kernels both see the full graph zoo
//! without multiplying the runtime by the ISA count. Odd seeds additionally
//! compile against a synthetic tuning DB (odd tile sizes, thread splits,
//! direct staging), so tuned schedules ride the same differential harness.
//!
//! A failure prints the reproducing seed and a full graph dump; re-run a
//! single seed with `DLRT_FUZZ_SEED=<seed> cargo test --test plan_fuzz`.

mod common;

use common::{dump, fuzz_input, random_graph};
use dlrt::compiler::{compile_graph_for_isa, compile_graph_tuned, EngineChoice};
use dlrt::dlrt::graph::Graph;
use dlrt::exec::{reference, Executor};
use dlrt::kernels::ukernel::{available_isas, Isa};

/// Seeds per run: the CI release smoke sweeps the full 500+; debug builds
/// (plain `cargo test`) run a subset to keep tier-1 fast.
const SEEDS: u64 = if cfg!(debug_assertions) { 150 } else { 500 };

/// Aggregate pass statistics so the suite can prove the generator actually
/// exercises every lowering (a vacuous fuzzer would pass silently).
#[derive(Default)]
struct Coverage {
    fused_adds: usize,
    in_place_concats: usize,
    partial_concats: usize,
    concat_fallbacks: usize,
    strided: usize,
    stripe_reads: usize,
    same_slot: usize,
    fused_acts: usize,
    in_place: usize,
    /// plans compiled with at least one tuned conv schedule attached
    tuned_plans: usize,
    /// seeds run per micro-kernel ISA (each must stay non-zero)
    isa_seeds: std::collections::BTreeMap<&'static str, usize>,
}

fn fail(seed: u64, g: &Graph, what: &str, detail: String) -> ! {
    panic!(
        "plan_fuzz seed {seed}: {what}\n{detail}\nreproduce with \
         DLRT_FUZZ_SEED={seed}\ngraph:\n{}",
        dump(g)
    )
}

fn check_seed(seed: u64, isa: Isa, cov: &mut Coverage) {
    let g = random_graph(seed);
    *cov.isa_seeds.entry(isa.name()).or_insert(0) += 1;
    // odd seeds compile against a synthetic tuning DB so tuned loop
    // blocking / thread splits / direct staging face the same zoo
    let db = if seed % 2 == 1 {
        match dlrt::tune::synthetic_db(&g, isa) {
            Ok(d) => Some(d),
            Err(e) => fail(seed, &g, "synthetic tuning DB failed",
                           format!("isa={}: {e:#}", isa.name())),
        }
    } else {
        None
    };
    for engine in [EngineChoice::Auto, EngineChoice::ForceFp32, EngineChoice::ForceInt8] {
        let compiled = match &db {
            Some(d) => compile_graph_tuned(&g, engine, isa, Some(d)),
            None => compile_graph_for_isa(&g, engine, isa),
        };
        let model = match compiled {
            Ok(m) => m,
            Err(e) => {
                fail(seed, &g, "compile failed",
                     format!("{engine:?} isa={}: {e:#}", isa.name()))
            }
        };
        if model.convs.iter().any(|c| c.sched.is_some()) {
            cov.tuned_plans += 1;
        }
        cov.fused_adds += model.plan.fused_add_instrs();
        cov.in_place_concats += model.plan.in_place_concats;
        cov.partial_concats += model.plan.partial_concats;
        cov.concat_fallbacks += model.plan.concat_fallbacks.len();
        cov.strided += model.plan.strided_instrs();
        cov.stripe_reads += model.plan.read_view_instrs();
        cov.same_slot += model.plan.same_slot_stripe_instrs();
        cov.fused_acts += model.plan.fused_instrs();
        cov.in_place += model.plan.in_place_instrs();
        for threads in [1usize, 3] {
            // instrumented runs: per-instruction profiling must never
            // change results on any generated graph
            let mut ex = Executor::new(threads);
            ex.enable_profiling(&model.plan);
            for batch in [1usize, 3] {
                let x = fuzz_input(&g, batch, seed);
                let label = format!(
                    "{engine:?} isa={} threads={threads} batch={batch}",
                    isa.name()
                );
                let got = match ex.run(&model, &x) {
                    Ok(o) => o,
                    Err(e) => fail(seed, &g, "planned run failed",
                                   format!("{label}: {e:#}")),
                };
                let want = match reference::run_unfused(&model, &x, threads) {
                    Ok(o) => o,
                    Err(e) => fail(seed, &g, "reference run failed",
                                   format!("{label}: {e:#}")),
                };
                if got.len() != want.len() {
                    fail(seed, &g, "output count mismatch",
                         format!("{label}: {} vs {}", got.len(), want.len()));
                }
                for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                    if a.shape != b.shape {
                        fail(seed, &g, "shape mismatch",
                             format!("{label} output {i}: {:?} vs {:?}", a.shape, b.shape));
                    }
                    if a.data != b.data {
                        let bad = a
                            .data
                            .iter()
                            .zip(&b.data)
                            .position(|(x, y)| x != y)
                            .unwrap_or(0);
                        fail(
                            seed,
                            &g,
                            "planned executor diverged from reference",
                            format!(
                                "{label} output {i} first diff at elem {bad}: {} vs {}",
                                a.data[bad], b.data[bad]
                            ),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn randomized_graphs_match_reference_bit_for_bit() {
    // DLRT_FUZZ_SEED replays one failing seed with full output
    let isas = available_isas();
    // same rotation for replay and sweep, so DLRT_FUZZ_SEED reproduces the
    // exact (graph, ISA) pairing that failed
    let isa_of = |seed: u64| isas[(seed as usize) % isas.len()];
    if let Ok(s) = std::env::var("DLRT_FUZZ_SEED") {
        let seed: u64 = s.parse().expect("DLRT_FUZZ_SEED must be an integer");
        let mut cov = Coverage::default();
        check_seed(seed, isa_of(seed), &mut cov);
        return;
    }
    let mut cov = Coverage::default();
    for seed in 0..SEEDS {
        check_seed(seed, isa_of(seed), &mut cov);
    }
    // the generator must keep hitting every lowering; if these ever drop
    // to zero the fuzzer has gone vacuous, which is itself a failure
    assert!(cov.fused_adds > 0, "no residual adds fused across {SEEDS} seeds");
    assert!(cov.in_place_concats > 0, "no concats elided across {SEEDS} seeds");
    assert!(cov.partial_concats > 0, "no partial concat stripes across {SEEDS} seeds");
    assert!(cov.concat_fallbacks > 0, "no concat fallbacks across {SEEDS} seeds");
    assert!(cov.strided > 0, "no strided writers across {SEEDS} seeds");
    assert!(cov.stripe_reads > 0, "no strided readers across {SEEDS} seeds");
    assert!(cov.same_slot > 0, "no same-slot stripe hops across {SEEDS} seeds");
    assert!(cov.fused_acts > 0, "no fused activations across {SEEDS} seeds");
    assert!(cov.in_place > 0, "no in-place activations across {SEEDS} seeds");
    assert!(cov.tuned_plans > 0, "no tuned plans compiled across {SEEDS} seeds");
    for isa in &isas {
        assert!(
            cov.isa_seeds.get(isa.name()).copied().unwrap_or(0) > 0,
            "isa {} never exercised across {SEEDS} seeds",
            isa.name()
        );
    }
    let isa_cov: Vec<String> =
        cov.isa_seeds.iter().map(|(n, c)| format!("{n}x{c}")).collect();
    println!("plan_fuzz isa rotation: {}", isa_cov.join(", "));
    println!("plan_fuzz tuned plans: {}", cov.tuned_plans);
    println!(
        "plan_fuzz: {SEEDS} seeds × 3 engines — {} fused adds, {} in-place concats \
         ({} partial concats, {} fallbacks), {} striped writers, {} stripe readers \
         ({} same-slot), {} fused acts, {} in-place acts",
        cov.fused_adds, cov.in_place_concats, cov.partial_concats,
        cov.concat_fallbacks, cov.strided, cov.stripe_reads, cov.same_slot,
        cov.fused_acts, cov.in_place
    );
}

//! Randomized differential testing of the execution planner: seeded random
//! graphs (conv / residual add / concat / pool / upsample / activations /
//! flatten / dense, with branching, fan-out ≥ 2 multi-use tensors, nested
//! residuals, concat-of-concat, and SPPF-style serial-pool pyramids) must
//! produce **bit-identical** outputs between the planned arena executor —
//! activation fusion, residual-add fusion, in-place lowering, concat
//! striping with stride-aware reads and partial (mixed eligible/copy)
//! concats and all — and the unfused env-map reference interpreter,
//! across {bitserial, fp32, int8} × {1, 3} threads × batch {1, 3}.
//!
//! A failure prints the reproducing seed and a full graph dump; re-run a
//! single seed with `DLRT_FUZZ_SEED=<seed> cargo test --test plan_fuzz`.

use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::dlrt::graph::{Graph, Op, QCfg};
use dlrt::exec::{reference, Executor};
use dlrt::models::GraphBuilder;
use dlrt::util::rng::Rng;
use dlrt::Tensor;

/// Seeds per run: the CI release smoke sweeps the full 500+; debug builds
/// (plain `cargo test`) run a subset to keep tier-1 fast.
const SEEDS: u64 = if cfg!(debug_assertions) { 150 } else { 500 };

#[derive(Clone)]
struct T {
    name: String,
    h: usize,
    w: usize,
    c: usize,
}

fn random_act(rng: &mut Rng) -> Op {
    match rng.usize(5) {
        0 => Op::Relu,
        1 => Op::Relu6,
        2 => Op::LeakyRelu,
        3 => Op::Silu,
        _ => Op::Sigmoid,
    }
}

fn random_act_opt(rng: &mut Rng) -> Option<Op> {
    if rng.usize(2) == 0 {
        Some(random_act(rng))
    } else {
        None
    }
}

fn random_qcfg(rng: &mut Rng) -> QCfg {
    if rng.usize(4) == 0 {
        QCfg::FP32
    } else {
        QCfg::new(1 + rng.usize(3) as u8, 1 + rng.usize(3) as u8)
    }
}

/// Build a random valid graph. Structure decisions come from a generator
/// RNG derived from (but distinct from) the seed the builder uses for
/// weights, so weights and topology vary independently.
fn random_graph(seed: u64) -> Graph {
    let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1));
    let h = [4usize, 6, 8][rng.usize(3)];
    let c = 1 + rng.usize(4);
    let mut b = GraphBuilder::new(&format!("fuzz{seed}"), [1, h, h, c], seed);
    let mut pool: Vec<T> = vec![T { name: "input".into(), h, w: h, c }];
    let mut last = pool[0].clone();
    let mut uid = 0usize;
    let n_ops = 4 + rng.usize(8);
    for _ in 0..n_ops {
        let pick = rng.usize(100);
        let t = pool[rng.usize(pool.len())].clone();
        let new = if pick < 20 {
            // conv: random kernel/stride/bits, optional fused-able act
            let k = [1usize, 3][rng.usize(2)];
            let s = if t.h >= 2 && t.w >= 2 && rng.usize(4) == 0 { 2 } else { 1 };
            let p = k / 2;
            let cout = 1 + rng.usize(6);
            let name = b.conv(&t.name, cout, k, s, random_qcfg(&mut rng),
                              random_act_opt(&mut rng));
            let oh = (t.h + 2 * p - k) / s + 1;
            let ow = (t.w + 2 * p - k) / s + 1;
            Some(T { name, h: oh, w: ow, c: cout })
        } else if pick < 40 {
            // residual block: shape-preserving conv (+ optional act) + add
            // with the skip tensor — the Add/residual fusion's home turf
            // (nests when `t` is itself a residual output)
            let y = b.conv(&t.name, t.c, 3, 1, random_qcfg(&mut rng),
                           random_act_opt(&mut rng));
            let sum = b.add(&y, &t.name);
            let sum = if rng.usize(2) == 0 {
                uid += 1;
                b.act_named(&format!("post{uid}"), &sum, random_act(&mut rng))
            } else {
                sum
            };
            Some(T { name: sum, ..t.clone() })
        } else if pick < 52 {
            // concat of 2-3 same-spatial tensors (concat outputs included,
            // so concat-of-concat arises; multi-use inputs stripe via read
            // views; duplicated inputs and the graph input force per-
            // producer copy fallbacks — i.e. partial stripes)
            let mates: Vec<T> =
                pool.iter().filter(|x| x.h == t.h && x.w == t.w).cloned().collect();
            let take = 2 + rng.usize(2);
            let chosen: Vec<T> =
                (0..take).map(|_| mates[rng.usize(mates.len())].clone()).collect();
            let ctot: usize = chosen.iter().map(|x| x.c).sum();
            if ctot <= 32 {
                let names: Vec<&str> = chosen.iter().map(|x| x.name.as_str()).collect();
                let name = b.concat(&names);
                Some(T { name, h: t.h, w: t.w, c: ctot })
            } else {
                None
            }
        } else if pick < 60 {
            // SPPF-style serial-pool pyramid: conv → pool → pool, all
            // levels concat'd. Every producer is multi-use (the next pool
            // + the concat), so striping them exercises stride-aware reads
            // including the same-slot stripe-to-stripe pool path.
            if t.h >= 2 && t.w >= 2 && t.c <= 8 {
                let ch = 1 + rng.usize(4);
                let y = b.conv(&t.name, ch, 1, 1, random_qcfg(&mut rng),
                               random_act_opt(&mut rng));
                let p1 = b.maxpool(&y, 3, 1, 1);
                let p2 = b.maxpool(&p1, 3, 1, 1);
                let name = b.concat(&[&y, &p1, &p2]);
                Some(T { name, h: t.h, w: t.w, c: 3 * ch })
            } else {
                None
            }
        } else if pick < 68 {
            // maxpool (downsampling or padded same-size)
            if t.h >= 2 && t.w >= 2 {
                if rng.usize(2) == 0 {
                    let name = b.maxpool(&t.name, 2, 2, 0);
                    Some(T { name, h: (t.h - 2) / 2 + 1, w: (t.w - 2) / 2 + 1, c: t.c })
                } else {
                    let name = b.maxpool(&t.name, 3, 1, 1);
                    Some(T { name, ..t.clone() })
                }
            } else {
                None
            }
        } else if pick < 78 {
            // upsample (bounded so tensors stay small)
            if t.h <= 8 && t.w <= 8 {
                let name = b.upsample2x(&t.name);
                Some(T { name, h: 2 * t.h, w: 2 * t.w, c: t.c })
            } else {
                None
            }
        } else if pick < 90 {
            // standalone activation (in-place / stripe-capable)
            uid += 1;
            let name = b.act_named(&format!("act{uid}"), &t.name, random_act(&mut rng));
            Some(T { name, ..t.clone() })
        } else {
            // add of two same-shape tensors (incl. x + x)
            let mates: Vec<T> = pool
                .iter()
                .filter(|x| x.h == t.h && x.w == t.w && x.c == t.c)
                .cloned()
                .collect();
            let other = mates[rng.usize(mates.len())].clone();
            let name = b.add(&t.name, &other.name);
            Some(T { name, ..t.clone() })
        };
        if let Some(nt) = new {
            pool.push(nt.clone());
            last = nt;
        }
    }

    let mut outputs: Vec<String> = Vec::new();
    match rng.usize(4) {
        0 => {
            // classifier tail: flatten alias + dense (+ optional act)
            let f = b.flatten(&last.name);
            let mut d = b.dense(&f, last.h * last.w * last.c, 1 + rng.usize(5));
            if rng.usize(2) == 0 {
                d = b.act_named("head", &d, Op::Sigmoid);
            }
            outputs.push(d);
        }
        1 => {
            let gap = b.global_avg_pool(&last.name);
            let d = b.dense(&gap, last.c, 1 + rng.usize(5));
            outputs.push(d);
        }
        _ => outputs.push(last.name.clone()),
    }
    // sometimes expose a mid-graph tensor too (outputs pin their slots)
    if rng.usize(3) == 0 {
        let extra = pool[rng.usize(pool.len())].name.clone();
        if !outputs.contains(&extra) {
            outputs.push(extra);
        }
    }
    b.finish(outputs)
}

fn dump(g: &Graph) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    writeln!(s, "  input {:?} {:?}", g.input_name, g.input_shape).unwrap();
    for n in &g.nodes {
        let extra = match &n.op {
            Op::Conv2d { kernel, stride, padding, qcfg, .. } => {
                format!(" k{kernel:?} s{stride:?} p{padding:?} {}", qcfg.tag())
            }
            _ => String::new(),
        };
        writeln!(s, "  {:<12} {:<16} {:?} -> {}{extra}", n.op.name(), n.name, n.inputs,
                 n.output)
            .unwrap();
    }
    writeln!(s, "  outputs {:?}", g.outputs).unwrap();
    s
}

/// Deterministic input mixing exact low-bit codes with negatives and
/// non-representable values.
fn fuzz_input(g: &Graph, batch: usize, seed: u64) -> Tensor {
    let s = g.input_shape;
    let mut rng = Rng::new(seed ^ 0xf00d);
    let mut x = Tensor::zeros(vec![batch, s[1], s[2], s[3]]);
    for v in x.data.iter_mut() {
        *v = (rng.usize(9) as f32) * 0.125 - 0.5;
    }
    x
}

/// Aggregate pass statistics so the suite can prove the generator actually
/// exercises every lowering (a vacuous fuzzer would pass silently).
#[derive(Default)]
struct Coverage {
    fused_adds: usize,
    in_place_concats: usize,
    partial_concats: usize,
    concat_fallbacks: usize,
    strided: usize,
    stripe_reads: usize,
    same_slot: usize,
    fused_acts: usize,
    in_place: usize,
}

fn fail(seed: u64, g: &Graph, what: &str, detail: String) -> ! {
    panic!(
        "plan_fuzz seed {seed}: {what}\n{detail}\nreproduce with \
         DLRT_FUZZ_SEED={seed}\ngraph:\n{}",
        dump(g)
    )
}

fn check_seed(seed: u64, cov: &mut Coverage) {
    let g = random_graph(seed);
    for engine in [EngineChoice::Auto, EngineChoice::ForceFp32, EngineChoice::ForceInt8] {
        let model = match compile_graph(&g, engine) {
            Ok(m) => m,
            Err(e) => fail(seed, &g, "compile failed", format!("{engine:?}: {e:#}")),
        };
        cov.fused_adds += model.plan.fused_add_instrs();
        cov.in_place_concats += model.plan.in_place_concats;
        cov.partial_concats += model.plan.partial_concats;
        cov.concat_fallbacks += model.plan.concat_fallbacks.len();
        cov.strided += model.plan.strided_instrs();
        cov.stripe_reads += model.plan.read_view_instrs();
        cov.same_slot += model.plan.same_slot_stripe_instrs();
        cov.fused_acts += model.plan.fused_instrs();
        cov.in_place += model.plan.in_place_instrs();
        for threads in [1usize, 3] {
            let mut ex = Executor::new(threads);
            for batch in [1usize, 3] {
                let x = fuzz_input(&g, batch, seed);
                let label = format!("{engine:?} threads={threads} batch={batch}");
                let got = match ex.run(&model, &x) {
                    Ok(o) => o,
                    Err(e) => fail(seed, &g, "planned run failed",
                                   format!("{label}: {e:#}")),
                };
                let want = match reference::run_unfused(&model, &x, threads) {
                    Ok(o) => o,
                    Err(e) => fail(seed, &g, "reference run failed",
                                   format!("{label}: {e:#}")),
                };
                if got.len() != want.len() {
                    fail(seed, &g, "output count mismatch",
                         format!("{label}: {} vs {}", got.len(), want.len()));
                }
                for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                    if a.shape != b.shape {
                        fail(seed, &g, "shape mismatch",
                             format!("{label} output {i}: {:?} vs {:?}", a.shape, b.shape));
                    }
                    if a.data != b.data {
                        let bad = a
                            .data
                            .iter()
                            .zip(&b.data)
                            .position(|(x, y)| x != y)
                            .unwrap_or(0);
                        fail(
                            seed,
                            &g,
                            "planned executor diverged from reference",
                            format!(
                                "{label} output {i} first diff at elem {bad}: {} vs {}",
                                a.data[bad], b.data[bad]
                            ),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn randomized_graphs_match_reference_bit_for_bit() {
    // DLRT_FUZZ_SEED replays one failing seed with full output
    if let Ok(s) = std::env::var("DLRT_FUZZ_SEED") {
        let seed: u64 = s.parse().expect("DLRT_FUZZ_SEED must be an integer");
        let mut cov = Coverage::default();
        check_seed(seed, &mut cov);
        return;
    }
    let mut cov = Coverage::default();
    for seed in 0..SEEDS {
        check_seed(seed, &mut cov);
    }
    // the generator must keep hitting every lowering; if these ever drop
    // to zero the fuzzer has gone vacuous, which is itself a failure
    assert!(cov.fused_adds > 0, "no residual adds fused across {SEEDS} seeds");
    assert!(cov.in_place_concats > 0, "no concats elided across {SEEDS} seeds");
    assert!(cov.partial_concats > 0, "no partial concat stripes across {SEEDS} seeds");
    assert!(cov.concat_fallbacks > 0, "no concat fallbacks across {SEEDS} seeds");
    assert!(cov.strided > 0, "no strided writers across {SEEDS} seeds");
    assert!(cov.stripe_reads > 0, "no strided readers across {SEEDS} seeds");
    assert!(cov.same_slot > 0, "no same-slot stripe hops across {SEEDS} seeds");
    assert!(cov.fused_acts > 0, "no fused activations across {SEEDS} seeds");
    assert!(cov.in_place > 0, "no in-place activations across {SEEDS} seeds");
    println!(
        "plan_fuzz: {SEEDS} seeds × 3 engines — {} fused adds, {} in-place concats \
         ({} partial concats, {} fallbacks), {} striped writers, {} stripe readers \
         ({} same-slot), {} fused acts, {} in-place acts",
        cov.fused_adds, cov.in_place_concats, cov.partial_concats,
        cov.concat_fallbacks, cov.strided, cov.stripe_reads, cov.same_slot,
        cov.fused_acts, cov.in_place
    );
}

//! Per-instruction profiler integration tests: profiling is off by
//! default and costs nothing when disabled, the recorded per-instruction
//! times account for the end-to-end wall time when enabled, and the
//! profile exports as a valid Chrome trace-event document.

use std::time::Instant;

use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::exec::{CompiledModel, Executor};
use dlrt::models::tiny_test_graph;
use dlrt::obs::trace::profile_trace_json;
use dlrt::util::json::Json;
use dlrt::Tensor;

fn test_input() -> Tensor {
    let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
    for (i, v) in x.data.iter_mut().enumerate() {
        *v = ((i * 37) % 23) as f32 * 0.0625 - 0.5;
    }
    x
}

/// Wall time of `runs` back-to-back executions, minimized over `trials`
/// measurement windows — min-of-N rejects scheduler noise, so the
/// comparison below stays stable on loaded CI machines.
fn min_wall_s(
    ex: &mut Executor,
    model: &CompiledModel,
    x: &Tensor,
    trials: usize,
    runs: usize,
) -> f64 {
    let mut outs = Vec::new();
    let mut best = f64::INFINITY;
    for _ in 0..trials {
        let t0 = Instant::now();
        for _ in 0..runs {
            ex.run_into(model, x, &mut outs).unwrap();
        }
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

#[test]
fn profiling_off_by_default_and_disabled_overhead_within_two_percent() {
    let model = compile_graph(&tiny_test_graph(false), EngineChoice::Auto).unwrap();
    let x = test_input();

    let mut base = Executor::new(1);
    assert!(base.profiler().is_none(), "profiling must be off by default");

    // an executor that had profiling enabled and then disabled must be
    // back on the exact baseline path
    let mut toggled = Executor::new(1);
    toggled.enable_profiling(&model.plan);
    toggled.disable_profiling();
    assert!(toggled.profiler().is_none(), "disable_profiling must clear the profiler");

    // warm both (arena growth, page faults), then interleave measurements
    min_wall_s(&mut base, &model, &x, 1, 5);
    min_wall_s(&mut toggled, &model, &x, 1, 5);
    let t_base = min_wall_s(&mut base, &model, &x, 9, 20);
    let t_off = min_wall_s(&mut toggled, &model, &x, 9, 20);
    // 2% relative bound, plus a small absolute allowance so sub-millisecond
    // windows don't fail on clock granularity alone
    assert!(
        t_off <= t_base * 1.02 + 200e-6,
        "disabled-profiling run {:.1}us is more than 2% over baseline {:.1}us",
        t_off * 1e6,
        t_base * 1e6
    );
}

#[test]
fn instr_times_account_for_end_to_end_wall_time() {
    let model = compile_graph(&tiny_test_graph(false), EngineChoice::Auto).unwrap();
    let x = test_input();
    let mut ex = Executor::new(1);
    let mut outs = Vec::new();
    ex.run_into(&model, &x, &mut outs).unwrap(); // warm

    ex.enable_profiling(&model.plan);
    let reps = 20;
    for _ in 0..reps {
        ex.run_into(&model, &x, &mut outs).unwrap();
    }
    let prof = ex.profiler().unwrap();
    assert_eq!(prof.len(), model.plan.instrs.len());
    assert_eq!(prof.runs(), reps as u64);

    // the per-instruction spans must explain the measured wall time: within
    // 10% low (clock-read gaps between instructions) and never above it by
    // more than timer jitter
    let covered = prof.sum_total_s() / prof.run_total_s();
    assert!(
        (0.90..=1.02).contains(&covered),
        "instruction spans cover {:.1}% of end-to-end wall time",
        covered * 100.0
    );

    // every instruction was sampled every run, with coherent statistics
    let mut sum = 0.0;
    for i in 0..prof.len() {
        let st = prof.stats(i);
        assert_eq!(st.count, reps as u64, "instr {i} sample count");
        assert!(st.total_s >= 0.0 && st.mean_s >= 0.0 && st.p95_s >= 0.0);
        assert!((st.mean_s - st.total_s / st.count as f64).abs() < 1e-12);
        sum += prof.instr_total_s(i);
    }
    assert!((sum - prof.sum_total_s()).abs() < 1e-9);
}

#[test]
fn profile_exports_valid_chrome_trace_json() {
    let model = compile_graph(&tiny_test_graph(false), EngineChoice::Auto).unwrap();
    let x = test_input();
    let mut ex = Executor::new(1);
    ex.enable_profiling(&model.plan);
    let mut outs = Vec::new();
    for _ in 0..3 {
        ex.run_into(&model, &x, &mut outs).unwrap();
    }
    let meta = model.plan.instr_meta();
    let doc = profile_trace_json(&meta, ex.profiler().unwrap());

    // round-trips through the parser and carries one event per instruction
    // plus the whole-run "exec" envelope span
    let v = Json::parse(&doc.to_string()).unwrap();
    let events = v.get("traceEvents").unwrap().arr().unwrap();
    assert_eq!(events.len(), meta.len() + 1);
    assert_eq!(events[0].get("name").unwrap().str().unwrap(), "exec");
    for (ev, m) in events[1..].iter().zip(&meta) {
        assert_eq!(ev.get("name").unwrap().str().unwrap(), m.name);
        // complete span ("X", with dur) unless the duration rounded to 0,
        // which chrome_event renders as an instant ("i")
        match ev.get("ph").unwrap().str().unwrap() {
            "X" => assert!(ev.get("dur").unwrap().num().unwrap() > 0.0),
            ph => assert_eq!(ph, "i"),
        }
    }
}

//! End-to-end tests for the HTTP inference gateway: boot on an ephemeral
//! port, drive it over real sockets, and assert the full request path
//! (socket → registry → bounded queue → batcher → planned executor →
//! response) returns bit-identical outputs to a direct `Executor::run`,
//! sheds load with 429s under a tiny queue bound, exposes consistent
//! Prometheus metrics, and drains queued work on graceful shutdown.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::coordinator::ServerConfig;
use dlrt::dlrt::format;
use dlrt::exec::Executor;
use dlrt::models::{single_conv_graph, tiny_test_graph};
use dlrt::serve::http::{http_once, HttpClient, Request};
use dlrt::serve::registry::ModelRegistry;
use dlrt::serve::{Gateway, GatewayConfig};
use dlrt::util::json::Json;
use dlrt::Tensor;

fn test_input(seed: u64) -> Tensor {
    let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
    for (i, v) in x.data.iter_mut().enumerate() {
        *v = ((i as u64 * 31 + seed * 7) % 17) as f32 * 0.125;
    }
    x
}

fn raw_bytes(t: &Tensor) -> Vec<u8> {
    dlrt::serve::http::f32s_to_le_bytes(&t.data)
}

fn f32s(bytes: &[u8]) -> Vec<f32> {
    dlrt::serve::http::le_bytes_to_f32s(bytes)
}

/// Boot a gateway serving the tiny builder graph under "tiny".
fn boot(cfg: ServerConfig) -> (Gateway, Arc<ModelRegistry>, String) {
    boot_with(cfg, GatewayConfig::default())
}

/// [`boot`] with an explicit gateway config (connection caps, shard count).
fn boot_with(cfg: ServerConfig, gw_cfg: GatewayConfig) -> (Gateway, Arc<ModelRegistry>, String) {
    let registry = Arc::new(ModelRegistry::new(cfg));
    let tiny = compile_graph(&tiny_test_graph(false), EngineChoice::Auto).unwrap();
    registry.install("tiny", "builder:tiny", tiny).unwrap();
    let gw = Gateway::bind("127.0.0.1:0", registry.clone(), gw_cfg).unwrap();
    let addr = gw.local_addr().to_string();
    (gw, registry, addr)
}

fn default_cfg() -> ServerConfig {
    ServerConfig { max_wait: Duration::from_millis(1), ..ServerConfig::default() }
}

/// Value of one Prometheus series in an exposition-format body.
fn metric_value(text: &str, series: &str) -> Option<f64> {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .find_map(|l| l.strip_prefix(series).and_then(|rest| rest.trim().parse().ok()))
}

/// Deadline-poll `GET /metrics` until `pred` accepts the body; returns the
/// accepted body. Panics (with the last body) on deadline — no fixed
/// sleeps anywhere, so slow CI machines only make the test take longer,
/// never fail.
fn poll_metrics(addr: &str, deadline: Duration, pred: impl Fn(&str) -> bool) -> String {
    let end = Instant::now() + deadline;
    let mut last = String::new();
    loop {
        if let Ok(resp) = http_once(addr, "GET", "/metrics", "x", Vec::new()) {
            if let Ok(body) = resp.body_str() {
                if pred(body) {
                    return body.to_string();
                }
                last = body.to_string();
            }
        }
        assert!(
            Instant::now() < end,
            "metrics never satisfied the predicate; last body:\n{last}"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn healthz_and_model_listing() {
    let (gw, _reg, addr) = boot(default_cfg());
    let resp = http_once(&addr, "GET", "/healthz", "text/plain", Vec::new()).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.body, b"ok\n");

    let resp = http_once(&addr, "GET", "/v1/models", "text/plain", Vec::new()).unwrap();
    assert_eq!(resp.status, 200);
    let v = Json::parse(resp.body_str().unwrap()).unwrap();
    let models = v.get("models").unwrap().arr().unwrap();
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].get("name").unwrap().str().unwrap(), "tiny");
    assert_eq!(
        models[0].get("input_shape").unwrap().usize_vec().unwrap(),
        vec![1, 8, 8, 3]
    );
    assert!(models[0].get("arena_bytes_per_item").unwrap().usize().unwrap() > 0);

    // unknown path and wrong method: 404 for typos (even under /v1/),
    // 405 only for known paths with the wrong verb
    assert_eq!(http_once(&addr, "GET", "/nope", "x", Vec::new()).unwrap().status, 404);
    assert_eq!(http_once(&addr, "GET", "/v1/model", "x", Vec::new()).unwrap().status, 404);
    assert_eq!(http_once(&addr, "POST", "/healthz", "x", Vec::new()).unwrap().status, 405);
    assert_eq!(http_once(&addr, "DELETE", "/v1/models", "x", Vec::new()).unwrap().status, 405);
    gw.shutdown();
}

#[test]
fn raw_and_json_infer_are_bit_identical_to_direct_run() {
    let (gw, reg, addr) = boot(default_cfg());
    let x = test_input(1);
    let direct = {
        let entry = reg.get("tiny").unwrap();
        let mut ex = Executor::new(1);
        ex.run(&entry.model, &x).unwrap()
    };

    // raw f32 LE round trip
    let resp = http_once(
        &addr,
        "POST",
        "/v1/models/tiny/infer",
        "application/octet-stream",
        raw_bytes(&x),
    )
    .unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    assert_eq!(f32s(&resp.body), direct[0].data, "raw output differs from direct run");
    let shapes = resp.header("x-dlrt-shapes").expect("shapes header").to_string();
    let shapes = Json::parse(&shapes).unwrap();
    assert_eq!(shapes.arr().unwrap()[0].usize_vec().unwrap(), direct[0].shape);

    // JSON round trip (f64 shortest-repr printing is exact for f32)
    let body = {
        let mut s = String::from("{\"shape\":[1,8,8,3],\"data\":[");
        for (i, v) in x.data.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("{}", *v as f64));
        }
        s.push_str("]}");
        s.into_bytes()
    };
    let resp =
        http_once(&addr, "POST", "/v1/models/tiny/infer", "application/json", body).unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let v = Json::parse(resp.body_str().unwrap()).unwrap();
    let outs = v.get("outputs").unwrap().arr().unwrap();
    assert_eq!(outs[0].get("shape").unwrap().usize_vec().unwrap(), direct[0].shape);
    assert_eq!(outs[0].get("data").unwrap().f32_vec().unwrap(), direct[0].data);

    // malformed inputs are 400s, unknown model 404
    let resp = http_once(
        &addr,
        "POST",
        "/v1/models/tiny/infer",
        "application/octet-stream",
        vec![0u8; 12],
    )
    .unwrap();
    assert_eq!(resp.status, 400);
    let resp = http_once(
        &addr,
        "POST",
        "/v1/models/ghost/infer",
        "application/octet-stream",
        raw_bytes(&x),
    )
    .unwrap();
    assert_eq!(resp.status, 404);
    gw.shutdown();
}

#[test]
fn second_model_hot_loaded_from_dlrt_file_and_unloaded() {
    let (gw, _reg, addr) = boot(default_cfg());

    // save a second model to disk and hot-load it through the admin API
    let oneconv = compile_graph(&single_conv_graph(2, 2, 0.5, 0.25), EngineChoice::Auto).unwrap();
    let path = std::env::temp_dir()
        .join(format!("dlrt_gateway_test_{}.dlrt", std::process::id()));
    format::save(&oneconv, &path).unwrap();

    let body = format!("{{\"path\": {:?}}}", path.to_string_lossy());
    let resp = http_once(
        &addr,
        "POST",
        "/v1/models/oneconv/load",
        "application/json",
        body.into_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));

    let resp = http_once(&addr, "GET", "/v1/models", "x", Vec::new()).unwrap();
    let v = Json::parse(resp.body_str().unwrap()).unwrap();
    assert_eq!(v.get("models").unwrap().arr().unwrap().len(), 2);

    // outputs match a direct run of the reloaded artifact
    let x = test_input(2);
    let direct = {
        let m = format::load(&path).unwrap();
        let mut ex = Executor::new(1);
        ex.run(&m, &x).unwrap()
    };
    let resp = http_once(
        &addr,
        "POST",
        "/v1/models/oneconv/infer",
        "application/octet-stream",
        raw_bytes(&x),
    )
    .unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    assert_eq!(f32s(&resp.body), direct[0].data);

    // unload: model disappears, infer turns 404
    let resp = http_once(&addr, "POST", "/v1/models/oneconv/unload", "x", Vec::new()).unwrap();
    assert_eq!(resp.status, 200);
    let resp = http_once(
        &addr,
        "POST",
        "/v1/models/oneconv/infer",
        "application/octet-stream",
        raw_bytes(&x),
    )
    .unwrap();
    assert_eq!(resp.status, 404);

    std::fs::remove_file(&path).ok();
    gw.shutdown();
}

#[test]
fn concurrent_mixed_model_load_is_correct_and_metered() {
    let (gw, reg, addr) = boot(ServerConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        ..ServerConfig::default()
    });
    let oneconv = compile_graph(&single_conv_graph(2, 2, 0.5, 0.25), EngineChoice::Auto).unwrap();
    reg.install("oneconv", "builder:oneconv", oneconv).unwrap();

    let x = test_input(3);
    let expect_tiny = {
        let mut ex = Executor::new(1);
        ex.run(&reg.get("tiny").unwrap().model, &x).unwrap()
    };
    let expect_oneconv = {
        let mut ex = Executor::new(1);
        ex.run(&reg.get("oneconv").unwrap().model, &x).unwrap()
    };

    const THREADS: usize = 6;
    const PER: usize = 8;
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let addr = addr.clone();
            let x = x.clone();
            let expect_tiny = &expect_tiny;
            let expect_oneconv = &expect_oneconv;
            scope.spawn(move || {
                let mut client = HttpClient::new(&addr, Duration::from_secs(30));
                for i in 0..PER {
                    let use_tiny = (t + i) % 2 == 0;
                    let model = if use_tiny { "tiny" } else { "oneconv" };
                    let req = Request::with_body(
                        "POST",
                        &format!("/v1/models/{model}/infer"),
                        "application/octet-stream",
                        raw_bytes(&x),
                    );
                    let resp = client.send(&req).unwrap();
                    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
                    let want = if use_tiny { &expect_tiny[0] } else { &expect_oneconv[0] };
                    assert_eq!(f32s(&resp.body), want.data, "thread {t} req {i} ({model})");
                }
            });
        }
    });

    // per-model completion counters match the traffic we sent
    let total = THREADS * PER;
    let tiny_done = reg.get("tiny").unwrap().server.metrics().completed;
    let oneconv_done = reg.get("oneconv").unwrap().server.metrics().completed;
    assert_eq!(tiny_done + oneconv_done, total);
    assert_eq!(tiny_done, total / 2);

    // metrics endpoint agrees and is exposition-format parseable
    let resp = http_once(&addr, "GET", "/metrics", "x", Vec::new()).unwrap();
    assert_eq!(resp.status, 200);
    let text = resp.body_str().unwrap().to_string();
    let mut found_tiny = false;
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let (series, value) = line.rsplit_once(' ').expect("sample line");
        assert!(value.parse::<f64>().is_ok(), "bad value: {line:?}");
        if series == "dlrt_model_completed_total{model=\"tiny\"}" {
            assert_eq!(value.parse::<usize>().unwrap(), tiny_done);
            found_tiny = true;
        }
    }
    assert!(found_tiny, "missing per-model counter in:\n{text}");
    gw.shutdown();
}

#[test]
fn tiny_queue_bound_sheds_with_429() {
    // one worker, wide batch window, queue capped at 2: a burst of 12
    // concurrent requests must see some 429s (and the accepted ones
    // finish correctly)
    let (gw, _reg, addr) = boot(ServerConfig {
        workers: 1,
        max_batch: 64,
        max_wait: Duration::from_millis(300),
        queue_cap: 2,
        ..ServerConfig::default()
    });
    let x = test_input(4);
    let barrier = std::sync::Barrier::new(12);
    let statuses: Vec<u16> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..12)
            .map(|_| {
                let addr = addr.clone();
                let x = x.clone();
                let barrier = &barrier;
                scope.spawn(move || {
                    // connect first so the burst is simultaneous
                    let mut client = HttpClient::new(&addr, Duration::from_secs(30));
                    let probe = Request::new("GET", "/healthz");
                    client.send(&probe).unwrap();
                    barrier.wait();
                    let req = Request::with_body(
                        "POST",
                        "/v1/models/tiny/infer",
                        "application/octet-stream",
                        raw_bytes(&x),
                    );
                    match client.send(&req) {
                        Ok(resp) => resp.status,
                        Err(_) => 0,
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let ok = statuses.iter().filter(|&&st| st == 200).count();
    let shed = statuses.iter().filter(|&&st| st == 429).count();
    assert_eq!(ok + shed, 12, "unexpected statuses: {statuses:?}");
    assert!(ok >= 1, "no request got through: {statuses:?}");
    assert!(shed >= 1, "queue bound never shed: {statuses:?}");

    // 429s carry Retry-After and count in the gateway metrics
    let resp = http_once(&addr, "GET", "/metrics", "x", Vec::new()).unwrap();
    let text = resp.body_str().unwrap().to_string();
    let line = text
        .lines()
        .find(|l| l.starts_with("dlrt_http_responses_total{class=\"429\"}"))
        .expect("429 counter");
    let counted: usize = line.rsplit_once(' ').unwrap().1.parse().unwrap();
    assert_eq!(counted, shed);
    gw.shutdown();
}

#[test]
fn graceful_shutdown_drains_queued_requests() {
    // wide batching window so requests sit in the queue when the drain
    // starts; they must complete (not error, not hang) without waiting
    // out the window
    let (gw, reg, addr) = boot(ServerConfig {
        workers: 1,
        max_batch: 64,
        max_wait: Duration::from_millis(2000),
        ..ServerConfig::default()
    });
    let x = test_input(5);
    let expect = {
        let mut ex = Executor::new(1);
        ex.run(&reg.get("tiny").unwrap().model, &x).unwrap()
    };

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let addr = addr.clone();
                let x = x.clone();
                let expect = &expect;
                scope.spawn(move || {
                    let resp = http_once(
                        &addr,
                        "POST",
                        "/v1/models/tiny/infer",
                        "application/octet-stream",
                        raw_bytes(&x),
                    )
                    .unwrap();
                    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
                    assert_eq!(f32s(&resp.body), expect[0].data);
                })
            })
            .collect();

        // wait (deadline-polling the public /metrics gauge, not internal
        // state) until every request is queued behind the window, then
        // shut down mid-window: drain must execute them now, not at the
        // window's 2s deadline
        poll_metrics(&addr, Duration::from_secs(10), |body| {
            metric_value(body, "dlrt_model_queue_depth{model=\"tiny\"}") == Some(4.0)
        });
        assert_eq!(reg.get("tiny").unwrap().server.queue_depth(), 4);
        let t0 = Instant::now();
        gw.shutdown();
        assert!(
            t0.elapsed() < Duration::from_millis(1500),
            "shutdown waited out the batching window instead of draining"
        );
        for h in handles {
            h.join().unwrap();
        }
    });

    // the port is closed afterwards
    assert!(
        http_once(&addr, "GET", "/healthz", "x", Vec::new()).is_err(),
        "listener still accepting after shutdown"
    );
}

#[test]
fn graceful_drain_under_concurrent_load() {
    // shutdown while senders are actively hammering the gateway: every
    // accepted (200) response must carry bit-correct output, no sender may
    // hang, and the completion counter must cover every 200 we saw
    let (gw, reg, addr) = boot(ServerConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        ..ServerConfig::default()
    });
    let x = test_input(6);
    let expect = {
        let mut ex = Executor::new(1);
        ex.run(&reg.get("tiny").unwrap().model, &x).unwrap()
    };
    let stop = std::sync::atomic::AtomicBool::new(false);

    const SENDERS: usize = 4;
    let oks: Vec<usize> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..SENDERS)
            .map(|_| {
                let addr = addr.clone();
                let x = x.clone();
                let expect = &expect;
                let stop = &stop;
                scope.spawn(move || {
                    let mut client = HttpClient::new(&addr, Duration::from_secs(30));
                    let mut ok = 0usize;
                    // bounded iterations so a wedged gateway fails loudly
                    // instead of hanging the suite
                    for _ in 0..2000 {
                        if stop.load(std::sync::atomic::Ordering::Relaxed) {
                            break;
                        }
                        let req = Request::with_body(
                            "POST",
                            "/v1/models/tiny/infer",
                            "application/octet-stream",
                            raw_bytes(&x),
                        );
                        match client.send(&req) {
                            Ok(resp) if resp.status == 200 => {
                                assert_eq!(
                                    f32s(&resp.body),
                                    expect[0].data,
                                    "drained response corrupted"
                                );
                                ok += 1;
                            }
                            Ok(resp) => {
                                // only load-shedding statuses are legal
                                assert!(
                                    resp.status == 429 || resp.status == 503,
                                    "unexpected status {}",
                                    resp.status
                                );
                            }
                            // listener closed mid-drain: the sender is done
                            Err(_) => break,
                        }
                    }
                    ok
                })
            })
            .collect();

        // wait until real traffic is flowing (public metrics, no sleeps),
        // then drain under load
        poll_metrics(&addr, Duration::from_secs(10), |body| {
            metric_value(body, "dlrt_model_completed_total{model=\"tiny\"}")
                .is_some_and(|v| v >= 8.0)
        });
        gw.shutdown();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let ok_total: usize = oks.iter().sum();
    assert!(ok_total >= 8, "hardly any request completed: {oks:?}");
    // every 200 the clients saw corresponds to completed server work
    let completed = reg.get("tiny").unwrap().server.metrics().completed;
    assert!(
        completed >= ok_total,
        "completed counter {completed} below client-observed successes {ok_total}"
    );
    // the port is closed afterwards
    assert!(
        http_once(&addr, "GET", "/healthz", "x", Vec::new()).is_err(),
        "listener still accepting after drain"
    );
}

#[test]
fn accept_path_survives_stalled_readers() {
    // connection cap 2: two keep-alive holders own both slots, then eight
    // more sockets connect and never write a request or read a byte. The
    // event-driven accept path must shed them without blocking (queued 503
    // with a bounded flush deadline, no limiter slot), so a fresh probe is
    // still answered promptly and the gateway recovers once the holders
    // leave. The old thread-per-connection accept loop wedged here.
    let (gw, _reg, addr) = boot_with(
        default_cfg(),
        GatewayConfig { max_connections: 2, ..GatewayConfig::default() },
    );

    let mut holders: Vec<HttpClient> = (0..2)
        .map(|_| {
            let mut c = HttpClient::new(&addr, Duration::from_secs(30));
            let resp = c.send(&Request::new("GET", "/healthz")).unwrap();
            assert_eq!(resp.status, 200);
            c
        })
        .collect();

    // stalled peers: connected, silent, and not reading their shed 503s
    let stalled: Vec<std::net::TcpStream> =
        (0..8).map(|_| std::net::TcpStream::connect(&addr).unwrap()).collect();

    // the accept path stays responsive behind the stalled herd
    let t0 = Instant::now();
    let resp = http_once(&addr, "GET", "/healthz", "x", Vec::new()).unwrap();
    assert_eq!(resp.status, 503, "{}", String::from_utf8_lossy(&resp.body));
    assert!(t0.elapsed() < Duration::from_secs(2), "over-cap shed took {:?}", t0.elapsed());

    // holders leave: their slots free and new connections serve again
    holders.clear();
    let end = Instant::now() + Duration::from_secs(10);
    loop {
        if let Ok(resp) = http_once(&addr, "GET", "/healthz", "x", Vec::new()) {
            if resp.status == 200 {
                break;
            }
        }
        assert!(Instant::now() < end, "gateway never recovered after holders left");
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(stalled);
    gw.shutdown();
}

#[test]
fn requests_from_distinct_connections_share_a_batch() {
    // one worker with a wide batching window: two infers arriving on
    // *different* sockets inside the window must coalesce into a single
    // executed batch, observable via the X-DLRT-Batch-Size reply header.
    // Retried a few rounds since the rendezvous is timing-dependent.
    let (gw, _reg, addr) = boot(ServerConfig {
        workers: 1,
        max_batch: 8,
        max_wait: Duration::from_millis(200),
        ..ServerConfig::default()
    });
    let x = test_input(7);
    let mut best = 0usize;
    for _ in 0..5 {
        let barrier = std::sync::Barrier::new(2);
        let sizes: Vec<usize> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let addr = addr.clone();
                    let x = x.clone();
                    let barrier = &barrier;
                    scope.spawn(move || {
                        // connect first so the two submissions land together
                        let mut client = HttpClient::new(&addr, Duration::from_secs(30));
                        client.send(&Request::new("GET", "/healthz")).unwrap();
                        barrier.wait();
                        let req = Request::with_body(
                            "POST",
                            "/v1/models/tiny/infer",
                            "application/octet-stream",
                            raw_bytes(&x),
                        );
                        let resp = client.send(&req).unwrap();
                        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
                        resp.header("x-dlrt-batch-size")
                            .expect("batch-size header")
                            .parse::<usize>()
                            .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        best = best.max(*sizes.iter().max().unwrap());
        if best >= 2 {
            break;
        }
    }
    assert!(best >= 2, "cross-connection requests never shared a batch");
    gw.shutdown();
}

#[test]
fn open_loop_soak_over_many_connections() {
    // ~300 keep-alive sockets driving 2k open-loop requests: nothing may
    // error at the transport level, every request is either served or
    // cleanly shed, tail latency stays sane, and responses after the storm
    // remain bit-identical to a direct run
    let (gw, reg, addr) = boot_with(
        ServerConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            queue_cap: 256,
            ..ServerConfig::default()
        },
        GatewayConfig { max_connections: 512, ..GatewayConfig::default() },
    );
    let cfg = dlrt::serve::loadgen::LoadgenConfig {
        addr: addr.clone(),
        model: "tiny".to_string(),
        requests: 2000,
        concurrency: 16,
        rate: 2000.0,
        json: false,
        timeout: Duration::from_secs(10),
        conns: 300,
    };
    let rep = dlrt::serve::loadgen::run(&cfg).unwrap();
    assert_eq!(rep.transport_errors, 0, "statuses: {:?}", rep.status_counts);
    assert_eq!(rep.sent, 2000);
    let shed: usize = rep.status_counts.values().sum();
    assert_eq!(rep.ok + shed, rep.sent, "lost requests: {:?}", rep.status_counts);
    for st in rep.status_counts.keys() {
        // only load-shedding statuses are acceptable under overload
        assert!(*st == 429 || *st == 503, "unexpected status {st}: {:?}", rep.status_counts);
    }
    assert!(rep.ok >= rep.sent / 2, "shed more than half: {:?}", rep.status_counts);
    assert!(rep.p99_ms < 5000.0, "p99 {:.1}ms", rep.p99_ms);

    // the per-replica occupancy gauge is exported
    let resp = http_once(&addr, "GET", "/metrics", "x", Vec::new()).unwrap();
    let text = resp.body_str().unwrap().to_string();
    assert!(
        text.contains("dlrt_model_replica_occupancy{model=\"tiny\",replica=\"0\"}"),
        "missing replica occupancy gauge:\n{text}"
    );

    // bit parity after the storm
    let x = test_input(8);
    let expect = {
        let mut ex = Executor::new(1);
        ex.run(&reg.get("tiny").unwrap().model, &x).unwrap()
    };
    for _ in 0..3 {
        let resp = http_once(
            &addr,
            "POST",
            "/v1/models/tiny/infer",
            "application/octet-stream",
            raw_bytes(&x),
        )
        .unwrap();
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        assert_eq!(f32s(&resp.body), expect[0].data, "post-soak output corrupted");
    }
    gw.shutdown();
}

#[test]
#[ignore = "10k-socket soak: needs high FD limits and minutes of wall time; run with --ignored"]
fn soak_10k_connections() {
    let (gw, _reg, addr) = boot_with(
        ServerConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            queue_cap: 512,
            ..ServerConfig::default()
        },
        GatewayConfig { max_connections: 12_000, ..GatewayConfig::default() },
    );
    let cfg = dlrt::serve::loadgen::LoadgenConfig {
        addr: addr.clone(),
        model: "tiny".to_string(),
        requests: 20_000,
        concurrency: 32,
        rate: 4000.0,
        json: false,
        timeout: Duration::from_secs(30),
        conns: 10_000,
    };
    let rep = dlrt::serve::loadgen::run(&cfg).unwrap();
    assert_eq!(rep.transport_errors, 0, "statuses: {:?}", rep.status_counts);
    let shed: usize = rep.status_counts.values().sum();
    assert_eq!(rep.ok + shed, rep.sent, "lost requests: {:?}", rep.status_counts);
    assert!(rep.ok >= rep.sent / 2, "shed more than half: {:?}", rep.status_counts);
    gw.shutdown();
}

#[test]
fn admin_shutdown_endpoint_requests_drain() {
    let (gw, _reg, addr) = boot(default_cfg());
    assert!(!gw.shutdown_requested());
    let resp = http_once(&addr, "POST", "/v1/admin/shutdown", "x", Vec::new()).unwrap();
    assert_eq!(resp.status, 200);
    assert!(gw.shutdown_requested());
    gw.shutdown();
}

#[test]
fn request_ids_reach_access_log_and_trace_endpoint() {
    let (gw, _reg, addr) = boot(default_cfg());
    let lines = Arc::new(std::sync::Mutex::new(Vec::<String>::new()));
    {
        let lines = lines.clone();
        gw.set_access_sink(Box::new(move |line| lines.lock().unwrap().push(line.to_string())));
    }

    // a client-supplied X-Request-Id round-trips into the response header
    let x = test_input(9);
    let mut client = HttpClient::new(&addr, Duration::from_secs(30));
    let mut req = Request::with_body(
        "POST",
        "/v1/models/tiny/infer",
        "application/octet-stream",
        raw_bytes(&x),
    );
    req.headers.push(("X-Request-Id".to_string(), "test-rid-42".to_string()));
    let resp = client.send(&req).unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    assert_eq!(resp.header("x-request-id"), Some("test-rid-42"));

    // without the header the gateway generates one
    let req2 = Request::with_body(
        "POST",
        "/v1/models/tiny/infer",
        "application/octet-stream",
        raw_bytes(&x),
    );
    let resp2 = client.send(&req2).unwrap();
    assert_eq!(resp2.status, 200);
    let generated = resp2.header("x-request-id").expect("generated request id").to_string();
    assert!(generated.starts_with("req-"), "generated id {generated:?}");

    // both requests produced structured access-log lines carrying their ids
    let lines = lines.lock().unwrap();
    assert_eq!(lines.len(), 2, "access lines: {lines:?}");
    assert!(lines[0].contains("id=test-rid-42"), "{}", lines[0]);
    assert!(lines[0].contains("model=tiny"), "{}", lines[0]);
    assert!(lines[0].contains("status=200"), "{}", lines[0]);
    assert!(lines[1].contains(&format!("id={generated}")), "{}", lines[1]);
    for tok in lines[0].split(' ') {
        assert!(tok.contains('='), "unstructured token {tok:?} in {:?}", lines[0]);
    }
    drop(lines);

    // the span ring exports as a Chrome trace-event document
    let resp = http_once(&addr, "GET", "/v1/debug/trace", "x", Vec::new()).unwrap();
    assert_eq!(resp.status, 200);
    let v = Json::parse(resp.body_str().unwrap()).unwrap();
    let events = v.get("traceEvents").unwrap().arr().unwrap();
    assert!(!events.is_empty(), "trace buffer exported no spans");
    let names: Vec<&str> =
        events.iter().map(|e| e.get("name").unwrap().str().unwrap()).collect();
    for want in ["parse", "queue-wait", "exec", "respond"] {
        assert!(names.contains(&want), "missing {want:?} span in {names:?}");
    }
    gw.shutdown();
}

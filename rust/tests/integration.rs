//! Cross-layer integration tests: JAX build path ↔ Rust runtime parity.
//!
//! These consume artifacts produced by `make artifacts`; when artifacts are
//! missing the tests skip with a notice (so `cargo test` works standalone)
//! — CI runs `make test` which builds artifacts first.

use std::path::{Path, PathBuf};

use dlrt::compiler::{compile_graph, load_arch, EngineChoice};
use dlrt::exec::Executor;
use dlrt::util::json::Json;
use dlrt::Tensor;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    if dir.join("golden").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        None
    }
}

struct Golden {
    input: Tensor,
    outputs: Vec<Tensor>,
    mode: String,
}

fn load_golden(path: &Path) -> Golden {
    let v = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    let shape = v.get("input_shape").unwrap().usize_vec().unwrap();
    let input = Tensor::new(shape, v.get("input").unwrap().f32_vec().unwrap()).unwrap();
    let outputs = v
        .get("outputs")
        .unwrap()
        .arr()
        .unwrap()
        .iter()
        .map(|o| {
            Tensor::new(
                o.get("shape").unwrap().usize_vec().unwrap(),
                o.get("data").unwrap().f32_vec().unwrap(),
            )
            .unwrap()
        })
        .collect();
    Golden { input, outputs, mode: v.get("mode").unwrap().str().unwrap().to_string() }
}

/// Relative-scale max error between Rust outputs and JAX goldens.
fn check_outputs(got: &[Tensor], want: &[Tensor], tol: f32, label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: output count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.shape, w.shape, "{label}: output {i} shape");
        let scale = w.data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
        let diff = g.max_abs_diff(w) / scale;
        assert!(diff < tol, "{label}: output {i} relative diff {diff} > {tol}");
    }
}

/// The decisive end-to-end parity: JAX `deploy_sim` (integer semantics) ==
/// Rust bitserial runtime, on a real quantized ResNet with folded BN.
#[test]
fn resnet18_mini_quantized_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_arch(&dir.join("models/resnet18_mini")).unwrap();
    let model = compile_graph(&g, EngineChoice::Auto).unwrap();
    assert_eq!(model.engine_summary().get("bitserial"), Some(&19));
    let golden = load_golden(&dir.join("golden/resnet18_mini.json"));
    assert_eq!(golden.mode, "deploy_sim");
    let mut ex = Executor::new(1);
    let got = ex.run(&model, &golden.input).unwrap();
    check_outputs(&got, &golden.outputs, 2e-4, "resnet18_mini deploy");
}

#[test]
fn resnet18_mini_fp32_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_arch(&dir.join("models/resnet18_mini")).unwrap();
    let model = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
    let golden = load_golden(&dir.join("golden/resnet18_mini_fp32.json"));
    let mut ex = Executor::new(1);
    let got = ex.run(&model, &golden.input).unwrap();
    check_outputs(&got, &golden.outputs, 2e-4, "resnet18_mini fp32");
}

#[test]
fn yolov5n_mini_quantized_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_arch(&dir.join("models/yolov5n_mini")).unwrap();
    let model = compile_graph(&g, EngineChoice::Auto).unwrap();
    let golden = load_golden(&dir.join("golden/yolov5n_mini.json"));
    let mut ex = Executor::new(1);
    let got = ex.run(&model, &golden.input).unwrap();
    // silu/sigmoid transcendentals differ slightly between XLA and libm
    check_outputs(&got, &golden.outputs, 1e-3, "yolov5n_mini deploy");
}

#[test]
fn yolov5n_mini_fp32_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_arch(&dir.join("models/yolov5n_mini")).unwrap();
    let model = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
    let golden = load_golden(&dir.join("golden/yolov5n_mini_fp32.json"));
    let mut ex = Executor::new(1);
    let got = ex.run(&model, &golden.input).unwrap();
    check_outputs(&got, &golden.outputs, 1e-3, "yolov5n_mini fp32");
}

/// Parity must survive a .dlrt serialization round-trip.
#[test]
fn dlrt_file_roundtrip_preserves_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_arch(&dir.join("models/resnet18_mini")).unwrap();
    let model = compile_graph(&g, EngineChoice::Auto).unwrap();
    let path = std::env::temp_dir().join(format!("itest_{}.dlrt", std::process::id()));
    dlrt::dlrt::format::save(&model, &path).unwrap();
    let loaded = dlrt::dlrt::format::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let golden = load_golden(&dir.join("golden/resnet18_mini.json"));
    let mut ex = Executor::new(1);
    let got = ex.run(&loaded, &golden.input).unwrap();
    check_outputs(&got, &golden.outputs, 2e-4, "dlrt roundtrip");
}

/// Multithreaded execution must be numerically identical to single-thread.
#[test]
fn threading_does_not_change_results() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_arch(&dir.join("models/yolov5n_mini")).unwrap();
    let model = compile_graph(&g, EngineChoice::Auto).unwrap();
    let golden = load_golden(&dir.join("golden/yolov5n_mini.json"));
    let mut ex1 = Executor::new(1);
    let mut ex4 = Executor::new(4);
    let y1 = ex1.run(&model, &golden.input).unwrap();
    let y4 = ex4.run(&model, &golden.input).unwrap();
    for (a, b) in y1.iter().zip(&y4) {
        assert_eq!(a.data, b.data);
    }
}

/// Mismatched elementwise input shapes must surface as proper errors, not
/// reach the kernels unchecked. Since plan lowering moved into
/// `compile_graph`, static shape mismatches are caught at compile time —
/// before a model can ever be deployed — rather than at the first request.
mod elementwise_shape_validation {
    use std::collections::BTreeMap;

    use dlrt::compiler::{compile_graph, EngineChoice};
    use dlrt::exec::Executor;
    use dlrt::{Graph, Node, Op, Tensor};

    /// input [1,8,8,3] → maxpool/2 [1,4,4,3] → <op>(input, pooled)
    fn mismatch_graph(op: Op) -> Graph {
        Graph {
            name: "mismatch".into(),
            input_name: "input".into(),
            input_shape: [1, 8, 8, 3],
            nodes: vec![
                Node {
                    op: Op::MaxPool2d {
                        kernel: [2, 2],
                        stride: [2, 2],
                        padding: [0, 0],
                    },
                    name: "pool".into(),
                    inputs: vec!["input".into()],
                    output: "pool.out".into(),
                },
                Node {
                    op,
                    name: "bad".into(),
                    inputs: vec!["input".into(), "pool.out".into()],
                    output: "bad.out".into(),
                },
            ],
            outputs: vec!["bad.out".into()],
            weights: BTreeMap::new(),
        }
    }

    #[test]
    fn add_rejects_mismatched_shapes_at_compile_time() {
        let g = mismatch_graph(Op::Add);
        g.validate_topology().unwrap();
        let err = compile_graph(&g, EngineChoice::Auto).unwrap_err();
        assert!(format!("{err:#}").contains("add shape mismatch"), "{err:#}");
    }

    #[test]
    fn concat_rejects_spatial_mismatch_at_compile_time() {
        let g = mismatch_graph(Op::Concat);
        let err = compile_graph(&g, EngineChoice::Auto).unwrap_err();
        assert!(format!("{err:#}").contains("concat spatial mismatch"), "{err:#}");
    }

    #[test]
    fn matching_shapes_still_execute() {
        // same topology but Add(input, input): shapes agree, runs clean
        let mut g = mismatch_graph(Op::Add);
        g.nodes[1].inputs = vec!["input".into(), "input".into()];
        let m = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mut ex = Executor::new(1);
        let out = ex.run(&m, &Tensor::zeros(vec![1, 8, 8, 3])).unwrap();
        assert_eq!(out[0].shape, vec![1, 8, 8, 3]);
    }
}

/// The PJRT path runs the full FP32 ResNet18 (96px) artifact end to end.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_runs_full_resnet_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let stem = dir.join("resnet18_fp32_96");
    if !stem.with_extension("").exists() && !dir.join("resnet18_fp32_96.hlo.txt").exists() {
        eprintln!("SKIP: resnet18_fp32_96 artifact missing");
        return;
    }
    let rt = dlrt::runtime::PjrtRuntime::cpu().unwrap();
    let model = rt.load_hlo(&stem).unwrap();
    let mut rng = dlrt::util::rng::Rng::new(3);
    // strictly positive values keep BN variance parameters valid
    let mut inputs: Vec<Tensor> = model
        .manifest
        .params
        .iter()
        .map(|(_, shape)| {
            let n: usize = shape.iter().product::<usize>().max(1);
            Tensor::new(shape.clone(), (0..n).map(|_| rng.f32() * 0.1 + 0.05).collect())
                .unwrap()
        })
        .collect();
    let mut x = Tensor::zeros(model.manifest.input_shape.clone());
    for v in x.data.iter_mut() {
        *v = rng.f32();
    }
    inputs.push(x);
    let outs = model.run_f32(&inputs).unwrap();
    assert_eq!(outs[0].shape, vec![1, 1000]);
    assert!(outs[0].data.iter().all(|v| v.is_finite()));
}

//! Golden parity for the compile-time execution plans: the planned/fused
//! arena executor must match the retained env-map reference interpreter
//! **bit for bit** on every engine and every host-available micro-kernel
//! ISA, plus structural plan invariants (arena within the interpreter's
//! peak working set, slot disjointness).

use dlrt::compiler::{compile_graph, compile_graph_for_isa, EngineChoice};
use dlrt::dlrt::graph::{Graph, Op, QCfg};
use dlrt::exec::planner::{build_plan_with, peak_live_elems, PlanOpts};
use dlrt::exec::{reference, Executor};
use dlrt::kernels::ukernel::available_isas;
use dlrt::models::{single_conv_graph, tiny_test_graph, GraphBuilder};
use dlrt::Tensor;

fn smooth_input(shape: Vec<usize>) -> Tensor {
    let mut x = Tensor::zeros(shape);
    for (i, v) in x.data.iter_mut().enumerate() {
        *v = ((i % 7) as f32) * 0.125 - 0.25; // mix of exact codes + negatives
    }
    x
}

/// A graph touching every op the planner lowers: fused conv epilogues
/// (silu/relu), residual add, standalone in-place leaky-relu, upsample,
/// concat with a skip connection, maxpool, flatten alias, dense, sigmoid.
fn multi_op_graph() -> Graph {
    let q = QCfg::new(2, 2);
    let mut b = GraphBuilder::new("multi", [1, 8, 8, 3], 13);
    let c1 = b.conv_named("c1", "input", 8, 3, 1, 1, q, Some(Op::Silu));
    let c2 = b.conv_named("c2", &c1, 8, 3, 2, 1, QCfg::FP32, Some(Op::Relu));
    let c3 = b.conv_named("c3", &c2, 8, 1, 1, 0, q, None);
    let s = b.add(&c3, &c2);
    let r = b.act_named("post", &s, Op::LeakyRelu);
    let u = b.upsample2x(&r);
    let cat = b.concat(&[&u, &c1]);
    let p = b.maxpool(&cat, 2, 2, 0);
    let f = b.flatten(&p);
    let d = b.dense(&f, 4 * 4 * 16, 10);
    let sg = b.act_named("probs", &d, Op::Sigmoid);
    b.finish(vec![sg])
}

fn assert_bit_identical(got: &[Tensor], want: &[Tensor], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: output count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.shape, w.shape, "{label}: output {i} shape");
        assert_eq!(g.data, w.data, "{label}: output {i} diverged from interpreter");
    }
}

#[test]
fn planned_executor_matches_interpreter_bit_for_bit() {
    let graphs: Vec<(&str, Graph)> = vec![
        ("single_conv", single_conv_graph(2, 2, 0.5, 0.25)),
        ("tiny_exact", tiny_test_graph(true)),
        ("tiny", tiny_test_graph(false)),
        ("multi_op", multi_op_graph()),
    ];
    // every engine × every host-available micro-kernel ISA × thread count:
    // the planned executor must agree with the interpreter bit for bit no
    // matter which SIMD inner kernel the dispatch resolves to
    for (gname, g) in &graphs {
        for engine in [EngineChoice::Auto, EngineChoice::ForceFp32, EngineChoice::ForceInt8] {
            for isa in available_isas() {
                let model = compile_graph_for_isa(g, engine, isa).unwrap();
                let x = smooth_input(vec![1, 8, 8, 3]);
                for nthreads in [1usize, 3] {
                    // run instrumented: profiling must never change results
                    let mut ex = Executor::new(nthreads);
                    ex.enable_profiling(&model.plan);
                    let got = ex.run(&model, &x).unwrap();
                    let want = reference::run_unfused(&model, &x, nthreads).unwrap();
                    assert_bit_identical(
                        &got,
                        &want,
                        &format!("{gname}/{engine:?}/{}/t{nthreads}", isa.name()),
                    );
                    assert_eq!(ex.profiler().unwrap().runs(), 1);
                }
            }
        }
    }
}

#[test]
fn planned_executor_matches_interpreter_on_batches() {
    let g = multi_op_graph();
    let model = compile_graph(&g, EngineChoice::Auto).unwrap();
    let x = smooth_input(vec![3, 8, 8, 3]);
    let mut ex = Executor::new(2);
    ex.enable_profiling(&model.plan);
    let got = ex.run(&model, &x).unwrap();
    let want = reference::run_unfused(&model, &x, 2).unwrap();
    assert_bit_identical(&got, &want, "multi_op batch=3");
}

#[test]
fn unfused_plan_matches_fused_plan() {
    // toggling the fusion/in-place passes must not change results, only
    // the instruction stream (this is what the fig7 ablation bench relies on)
    let g = multi_op_graph();
    let fused = compile_graph(&g, EngineChoice::Auto).unwrap();
    let mut unfused = fused.clone();
    unfused.plan = build_plan_with(&g, PlanOpts::none()).unwrap();
    assert!(fused.plan.fused_instrs() > 0);
    assert!(fused.plan.fused_add_instrs() > 0);
    assert_eq!(unfused.plan.fused_instrs(), 0);
    assert_eq!(unfused.plan.fused_add_instrs(), 0);
    assert_eq!(unfused.plan.in_place_concats, 0);
    assert!(unfused.plan.instrs.len() > fused.plan.instrs.len());
    let x = smooth_input(vec![1, 8, 8, 3]);
    // profiler sized for the fused plan: the unfused run (different instr
    // count) must take the guarded fast path, not index out of bounds
    let mut ex = Executor::new(1);
    ex.enable_profiling(&fused.plan);
    let y_fused = ex.run(&fused, &x).unwrap();
    let y_unfused = ex.run(&unfused, &x).unwrap();
    assert_bit_identical(&y_fused, &y_unfused, "fused vs unfused plan");
    assert_eq!(ex.profiler().unwrap().runs(), 1, "mismatched plan must skip profiling");
    // every single-pass combination agrees too (passes compose freely)
    for opts in [
        PlanOpts { fuse_residual_add: false, ..PlanOpts::default() },
        PlanOpts { concat_in_place: false, ..PlanOpts::default() },
        PlanOpts { fuse_activations: false, in_place: false, ..PlanOpts::default() },
    ] {
        let mut m = fused.clone();
        m.plan = build_plan_with(&g, opts).unwrap();
        let y = ex.run(&m, &x).unwrap();
        assert_bit_identical(&y, &y_fused, &format!("{opts:?}"));
    }
}

/// Directed: a residual chain whose skip operand is the network input
/// itself — the residual slot is the input slot, which outlives the conv.
#[test]
fn residual_skip_from_network_input_fuses_and_matches() {
    let q = QCfg::new(2, 2);
    let mut b = GraphBuilder::new("skipin", [1, 8, 8, 3], 21);
    let c = b.conv_named("c", "input", 3, 3, 1, 1, q, None);
    let s = b.add(&c, "input");
    let r = b.act_named("r", &s, Op::Relu);
    let g = b.finish(vec![r]);
    for engine in [EngineChoice::Auto, EngineChoice::ForceFp32, EngineChoice::ForceInt8] {
        let model = compile_graph(&g, engine).unwrap();
        assert_eq!(model.plan.fused_add_instrs(), 1, "{engine:?}");
        assert_eq!(model.plan.instrs.len(), 1, "{engine:?}: conv absorbs add+relu");
        let x = smooth_input(vec![1, 8, 8, 3]);
        for nthreads in [1usize, 3] {
            let mut ex = Executor::new(nthreads);
            let got = ex.run(&model, &x).unwrap();
            let want = reference::run_unfused(&model, &x, nthreads).unwrap();
            assert_bit_identical(&got, &want, &format!("skipin/{engine:?}/t{nthreads}"));
        }
    }
}

/// Directed: concat whose producers run different engines / bit-widths —
/// stripes interleave a 1A1W bitserial conv, an FP32 conv, and an int8-able
/// 3A3W conv into one slot.
#[test]
fn mixed_bit_width_concat_producers_stripe_in_place() {
    let mut b = GraphBuilder::new("mixcat", [1, 8, 8, 3], 22);
    let a = b.conv_named("a", "input", 4, 3, 1, 1, QCfg::new(1, 1), Some(Op::Relu));
    let c = b.conv_named("c", "input", 5, 1, 1, 0, QCfg::FP32, None);
    let d = b.conv_named("d", "input", 3, 3, 1, 1, QCfg::new(3, 3), Some(Op::Silu));
    let cat = b.concat(&[&a, &c, &d]);
    let g = b.finish(vec![cat]);
    for engine in [EngineChoice::Auto, EngineChoice::ForceFp32, EngineChoice::ForceInt8] {
        let model = compile_graph(&g, engine).unwrap();
        assert_eq!(model.plan.in_place_concats, 1, "{engine:?}");
        assert_eq!(model.plan.strided_instrs(), 3, "{engine:?}");
        assert!(model.plan.instrs.iter().all(|i| !matches!(i.op, Op::Concat)));
        let x = smooth_input(vec![1, 8, 8, 3]);
        for nthreads in [1usize, 3] {
            let mut ex = Executor::new(nthreads);
            let got = ex.run(&model, &x).unwrap();
            let want = reference::run_unfused(&model, &x, nthreads).unwrap();
            assert_bit_identical(&got, &want, &format!("mixcat/{engine:?}/t{nthreads}"));
        }
    }
}

/// Directed: an Add feeding another Add — the conv absorbs only the first
/// add; the second stays a standalone instruction (fusion must not fire
/// twice into one epilogue).
#[test]
fn chained_adds_fuse_exactly_once() {
    let mut b = GraphBuilder::new("addchain", [1, 8, 8, 3], 23);
    let p = b.maxpool("input", 3, 1, 1); // same-shape non-conv operand
    let c = b.conv_named("c", "input", 3, 3, 1, 1, QCfg::new(2, 2), None);
    let s1 = b.add(&c, "input");
    let s2 = b.add(&s1, &p);
    let g = b.finish(vec![s2]);
    let model = compile_graph(&g, EngineChoice::Auto).unwrap();
    assert_eq!(model.plan.fused_add_instrs(), 1);
    let adds = model.plan.instrs.iter().filter(|i| matches!(i.op, Op::Add)).count();
    assert_eq!(adds, 1, "second add must stay standalone");
    let x = smooth_input(vec![1, 8, 8, 3]);
    let mut ex = Executor::new(1);
    let got = ex.run(&model, &x).unwrap();
    let want = reference::run_unfused(&model, &x, 1).unwrap();
    assert_bit_identical(&got, &want, "addchain");
}

/// Directed: the yolov5n SPPF block — cv1 conv, three serial k5 maxpools,
/// concat of all four pyramid levels, cv2 conv. Stride-aware reads make
/// every producer eligible (each pool reads the previous level's stripe
/// out of the concat root and writes its own stripe of the same slot), so
/// zero copy instructions remain — bit-exact on every engine and thread
/// count.
#[test]
fn sppf_block_stripes_fully_and_matches() {
    let q = QCfg::new(2, 2);
    let mut b = GraphBuilder::new("sppf", [1, 8, 8, 4], 31);
    let y = b.conv_named("cv1", "input", 4, 1, 1, 0, q, Some(Op::Silu));
    let p1 = b.maxpool(&y, 5, 1, 2);
    let p2 = b.maxpool(&p1, 5, 1, 2);
    let p3 = b.maxpool(&p2, 5, 1, 2);
    let cat = b.concat(&[&y, &p1, &p2, &p3]);
    let out = b.conv_named("cv2", &cat, 8, 1, 1, 0, q, Some(Op::Silu));
    let g = b.finish(vec![out]);
    for engine in [EngineChoice::Auto, EngineChoice::ForceFp32, EngineChoice::ForceInt8] {
        let model = compile_graph(&g, engine).unwrap();
        let p = &model.plan;
        assert_eq!(p.in_place_concats, 1, "{engine:?}");
        assert!(p.concat_fallbacks.is_empty(), "{engine:?}: {:?}", p.concat_fallbacks);
        assert_eq!(p.concat_copy_instrs(), 0, "{engine:?}");
        assert_eq!(p.strided_instrs(), 4, "{engine:?}: all four levels stripe");
        assert_eq!(p.read_view_instrs(), 3, "{engine:?}: each pool reads a stripe");
        assert_eq!(p.same_slot_stripe_instrs(), 3, "{engine:?}");
        let x = smooth_input(vec![1, 8, 8, 4]);
        for nthreads in [1usize, 3] {
            let mut ex = Executor::new(nthreads);
            let got = ex.run(&model, &x).unwrap();
            let want = reference::run_unfused(&model, &x, nthreads).unwrap();
            assert_bit_identical(&got, &want, &format!("sppf/{engine:?}/t{nthreads}"));
        }
    }
}

/// Directed: a partial stripe — the eligible conv producer writes its
/// stripe while the other input (also consumed by a Dense through a
/// Flatten alias, which has no strided read path) keeps a copy
/// instruction carrying exactly itself at its destination offset.
#[test]
fn partial_stripe_copies_exactly_one_producer() {
    let q = QCfg::new(2, 2);
    let mut b = GraphBuilder::new("partial", [1, 8, 8, 3], 32);
    let a = b.conv_named("a", "input", 4, 3, 1, 1, q, Some(Op::Relu));
    let c = b.conv_named("c", "input", 2, 1, 1, 0, QCfg::FP32, None);
    let cat = b.concat(&[&a, &c]);
    let f = b.flatten(&c);
    let d = b.dense(&f, 8 * 8 * 2, 4);
    let g = b.finish(vec![cat, d]);
    for engine in [EngineChoice::Auto, EngineChoice::ForceFp32, EngineChoice::ForceInt8] {
        let model = compile_graph(&g, engine).unwrap();
        let p = &model.plan;
        assert_eq!(p.partial_concats, 1, "{engine:?}");
        assert_eq!(p.in_place_concats, 0, "{engine:?}");
        assert_eq!(p.concat_copy_instrs(), 1, "{engine:?}");
        assert_eq!(p.concat_fallbacks.len(), 1, "{engine:?}");
        assert!(p.concat_fallbacks[0].contains("no strided read path"),
                "{engine:?}: {:?}", p.concat_fallbacks);
        let cat_i = p.instrs.iter().find(|i| matches!(i.op, Op::Concat)).unwrap();
        assert_eq!(cat_i.in_slots.len(), 1, "{engine:?}: only the ineligible input");
        assert_eq!(cat_i.cat_offs, vec![4], "{engine:?}");
        let x = smooth_input(vec![1, 8, 8, 3]);
        for nthreads in [1usize, 3] {
            let mut ex = Executor::new(nthreads);
            let got = ex.run(&model, &x).unwrap();
            let want = reference::run_unfused(&model, &x, nthreads).unwrap();
            assert_bit_identical(&got, &want,
                                 &format!("partial/{engine:?}/t{nthreads}"));
        }
    }
}

/// Directed: consumers reading a concat-resident tensor through strided
/// views (a conv whose own stripe lands in the same slot, and a
/// global-avg-pool head) must be bit-identical both to the interpreter
/// and to the same model re-planned with `strided_reads` off, where the
/// tensor densifies through the copy fallback instead.
#[test]
fn strided_view_consumers_match_dense_clone_plan() {
    let q = QCfg::new(2, 2);
    let mut b = GraphBuilder::new("views", [1, 8, 8, 3], 33);
    let s = b.conv_named("s", "input", 4, 3, 1, 1, q, Some(Op::Silu));
    let c2 = b.conv_named("c2", &s, 3, 3, 1, 1, q, None);
    let cat = b.concat(&[&s, &c2]);
    let gp = b.global_avg_pool(&s);
    let d = b.dense(&gp, 4, 5);
    let g = b.finish(vec![cat, d]);
    for engine in [EngineChoice::Auto, EngineChoice::ForceFp32, EngineChoice::ForceInt8] {
        let model = compile_graph(&g, engine).unwrap();
        assert_eq!(model.plan.concat_copy_instrs(), 0, "{engine:?}");
        assert!(model.plan.read_view_instrs() >= 2, "{engine:?}: c2 + gap read views");
        // c2 reads s's stripe of the very slot its own stripe lands in
        assert!(model.plan.same_slot_stripe_instrs() >= 1, "{engine:?}");
        let mut dense_clone = model.clone();
        dense_clone.plan = build_plan_with(
            &g,
            PlanOpts { strided_reads: false, ..PlanOpts::default() },
        )
        .unwrap();
        assert!(dense_clone.plan.concat_copy_instrs() >= 1, "{engine:?}");
        assert_eq!(dense_clone.plan.read_view_instrs(), 0, "{engine:?}");
        let x = smooth_input(vec![1, 8, 8, 3]);
        for nthreads in [1usize, 3] {
            let mut ex = Executor::new(nthreads);
            let got = ex.run(&model, &x).unwrap();
            let densified = ex.run(&dense_clone, &x).unwrap();
            let want = reference::run_unfused(&model, &x, nthreads).unwrap();
            assert_bit_identical(&got, &want,
                                 &format!("views/{engine:?}/t{nthreads}"));
            assert_bit_identical(&densified, &want,
                                 &format!("views-dense/{engine:?}/t{nthreads}"));
        }
    }
}

#[test]
fn arena_stays_within_interpreter_peak() {
    // On chain-style graphs, slot recycling must never need more memory
    // than the interpreter's liveness-based peak (what `inspect` reports).
    // (Wide graphs with skip connections can exceed the peak by a stranded
    // free slot — the contiguous-slot abstraction's price — so they get
    // the looser total-footprint bound below.)
    for (gname, g) in [
        ("single_conv", single_conv_graph(2, 2, 0.5, 0.25)),
        ("tiny", tiny_test_graph(false)),
        ("tiny_exact", tiny_test_graph(true)),
    ] {
        let model = compile_graph(&g, EngineChoice::Auto).unwrap();
        let peak = peak_live_elems(&g).unwrap();
        let arena = model.plan.arena_elems(model.plan.nominal_batch);
        assert!(arena <= peak, "{gname}: arena {arena} f32 > interpreter peak {peak}");
    }
}

#[test]
fn arena_reuse_beats_no_reuse_on_wide_graphs() {
    // even with skip connections, slot recycling must stay well under the
    // allocate-every-tensor footprint
    let g = multi_op_graph();
    let model = compile_graph(&g, EngineChoice::Auto).unwrap();
    let shapes = g.infer_shapes().unwrap();
    let total: usize = shapes.values().map(|s| s.iter().product::<usize>()).sum();
    let arena = model.plan.arena_elems(model.plan.nominal_batch);
    assert!(arena < total, "arena {arena} f32 >= total tensor footprint {total}");
    // and slots are genuinely shared: fewer slots than tensors
    assert!(model.plan.slot_sizes.len() < shapes.len());
}

#[test]
fn plan_slots_are_disjoint_per_instruction() {
    for g in [tiny_test_graph(false), multi_op_graph()] {
        let model = compile_graph(&g, EngineChoice::Auto).unwrap();
        for i in &model.plan.instrs {
            if i.in_place {
                assert_eq!(i.in_slots[0], i.out_slot);
            } else {
                // same-slot is legal only through disjoint channel-stripe
                // views of one concat root (validated by the planner)
                for (k, &s) in i.in_slots.iter().enumerate() {
                    if s != i.out_slot {
                        continue;
                    }
                    let iv = i.in_views[k]
                        .unwrap_or_else(|| panic!("instr {} writes a live input", i.name));
                    let ov = i.out_view.expect("same-slot output must be a stripe");
                    assert_eq!(iv.stride, ov.stride, "instr {}", i.name);
                    let cin = *i.in_tails[k].last().unwrap();
                    let cout = *i.out_tail.last().unwrap();
                    assert!(
                        iv.off + cin <= ov.off || ov.off + cout <= iv.off,
                        "instr {} overlapping stripes",
                        i.name
                    );
                }
            }
            let nslots = model.plan.slot_sizes.len();
            assert!(i.out_slot < nslots);
            assert!(i.in_slots.iter().all(|&s| s < nslots));
        }
    }
}

/// Tuned schedules from a synthetic DB with deliberately odd tile sizes,
/// per-conv thread splits and direct staging must stay bit-identical both
/// to the untuned plan and to the reference interpreter, across engines ×
/// host ISAs × thread counts — and every tuned plan must stay green under
/// the static verifier (geometry is loop blocking, never a layout hazard).
#[test]
fn tuned_schedules_stay_bit_identical_and_verifier_green() {
    use dlrt::compiler::compile_graph_tuned;
    use dlrt::tune::synthetic_db;
    let graphs: Vec<(&str, Graph)> = vec![
        ("tiny_exact", tiny_test_graph(true)),
        ("multi_op", multi_op_graph()),
    ];
    for (gname, g) in &graphs {
        for engine in [EngineChoice::Auto, EngineChoice::ForceFp32, EngineChoice::ForceInt8] {
            for isa in available_isas() {
                let db = synthetic_db(g, isa).unwrap();
                let tuned = compile_graph_tuned(g, engine, isa, Some(&db)).unwrap();
                assert!(tuned.convs.iter().all(|c| c.sched.is_some()),
                        "{gname}: synthetic DB must cover every conv");
                dlrt::exec::verify::verify(&tuned.plan).unwrap_or_else(|d| {
                    panic!("{gname}/{engine:?}/{}: tuned plan rejected — {d}", isa.name())
                });
                let untuned = compile_graph_tuned(g, engine, isa, None).unwrap();
                let x = smooth_input(vec![1, 8, 8, 3]);
                for nthreads in [1usize, 3] {
                    let mut ex = Executor::new(nthreads);
                    let got = ex.run(&tuned, &x).unwrap();
                    let base = ex.run(&untuned, &x).unwrap();
                    let want = reference::run_unfused(&untuned, &x, nthreads).unwrap();
                    let label = format!("tuned {gname}/{engine:?}/{}/t{nthreads}", isa.name());
                    assert_bit_identical(&got, &base, &label);
                    assert_bit_identical(&got, &want, &label);
                }
            }
        }
    }
}

#[test]
fn multi_op_plan_uses_every_lowering() {
    let g = multi_op_graph();
    let model = compile_graph(&g, EngineChoice::Auto).unwrap();
    let p = &model.plan;
    assert!(p.fused_instrs() >= 2, "expected conv+act fusions, got {}", p.fused_instrs());
    assert!(p.in_place_instrs() >= 1, "expected an in-place activation");
    assert!(
        p.instrs.iter().all(|i| !matches!(i.op, Op::Flatten)),
        "flatten must lower to an alias"
    );
    // fewer instructions than graph nodes: fusion + alias removal worked
    assert!(p.instrs.len() < g.nodes.len());
}

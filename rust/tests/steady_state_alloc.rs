//! Steady-state audit of the bitserial conv path: once scratch buffers have
//! grown to the layer's size and the kernel pool exists, a full
//! im2col → quantize → pack → tiled GEMM → dequant pass must perform **zero
//! heap allocations** and **zero thread spawns** (the pool-reuse test in
//! `util::threads` covers the spawning half; this binary counts allocations
//! through a wrapping global allocator).
//!
//! Kept as the only test in this binary so no concurrently running test can
//! allocate while the counter window is open.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use dlrt::dlrt::tensor::Packed;
use dlrt::kernels::bitserial::{
    dequant_scale_bias, gemm_bitserial, pack_rows_u8_into, pack_weights_offset,
};
use dlrt::kernels::im2col::{im2col_quant_u8, ConvDims};
use dlrt::util::rng::Rng;

struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[test]
fn bitserial_conv_path_allocates_nothing_at_steady_state() {
    // a conv-shaped workload: 16x16x8 input, 3x3 kernel, 32 output channels
    let d = ConvDims::new(1, 16, 16, 8, 3, 3, [1, 1], [1, 1]);
    let (rows, patch, cout) = (d.rows(), d.patch(), 32usize);
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..d.n * d.h * d.w * d.c).map(|_| rng.f32()).collect();
    let wq: Vec<i32> = (0..cout * patch).map(|_| rng.range(-2, 2) as i32).collect();
    let wp = pack_weights_offset(&wq, cout, patch, 2);
    let scale = vec![1.0f32; cout];
    let bias = vec![0.0f32; cout];

    // pre-sized executor-style scratch
    let mut cols = vec![0u8; rows * patch];
    let mut packed = Packed::new_zeroed(0, 0, 1);
    let mut acc = vec![0i32; rows * cout];
    let mut out = vec![0.0f32; rows * cout];
    let nthreads = 3; // exercise the pool dispatch path, not just inline

    let mut run = |cols: &mut Vec<u8>, packed: &mut Packed| {
        im2col_quant_u8(&x, &d, 0.1, 3, cols);
        pack_rows_u8_into(cols, rows, patch, 2, packed);
        gemm_bitserial(packed, &wp, 2, &mut acc, nthreads);
        dequant_scale_bias(&acc, cout, 0.01, &scale, &bias, &mut out);
    };

    // warm-up: grows every scratch buffer and spins up the worker pool
    for _ in 0..3 {
        run(&mut cols, &mut packed);
    }

    COUNTING.store(true, Ordering::SeqCst);
    for _ in 0..10 {
        run(&mut cols, &mut packed);
    }
    COUNTING.store(false, Ordering::SeqCst);

    let allocs = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        allocs, 0,
        "steady-state bitserial conv path performed {allocs} heap allocations"
    );
    // keep the results observable so the loop can't be optimized out
    assert!(out.iter().all(|v| v.is_finite()));
}

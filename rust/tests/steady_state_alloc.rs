//! Steady-state allocation audit, in two phases sharing one counting
//! window (kept as the only test in this binary so no concurrently running
//! test can allocate while the counter is armed):
//!
//! 1. **Kernel path** — a bare im2col → quantize → pack → tiled GEMM →
//!    dequant bitserial conv pass over pre-grown scratch.
//! 2. **Whole network** — a full multi-op model (conv + residual add +
//!    pool + activation + flatten + dense) executed end-to-end through the
//!    planned arena executor via `Executor::run_into`.
//! 3. **SPPF strided-read pyramid** — multi-use pool levels striped into
//!    one concat root, consumed through stride-aware reads (same-slot
//!    pool hops, strided im2col, strided gap), with the strided plan's
//!    arena strictly below the copy-fallback plan's.
//!
//! All must perform **zero heap allocations** and **zero thread spawns**
//! once buffers have grown and the kernel pool exists (the pool-reuse test
//! in `util::threads` covers the spawning half; this binary counts
//! allocations through a wrapping global allocator).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::dlrt::graph::{Graph, Op, QCfg};
use dlrt::dlrt::tensor::{Packed, Tensor};
use dlrt::exec::Executor;
use dlrt::kernels::bitserial::{
    dequant_scale_bias, gemm_bitserial, pack_rows_u8_into, pack_weights_offset,
};
use dlrt::kernels::im2col::{im2col_quant_u8, ConvDims};
use dlrt::models::GraphBuilder;
use dlrt::obs::trace::{SpanKind, SpanRec, TraceBuffer};
use dlrt::util::rng::Rng;

struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Count allocations across `reps` runs of `f` after `warmup` runs.
fn count_steady_state<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> usize {
    for _ in 0..warmup {
        f();
    }
    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    for _ in 0..reps {
        f();
    }
    COUNTING.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

/// SPPF-style pyramid over multi-use levels (conv → pool → pool, all
/// concat'd) + conv + gap + dense head: every stride-aware *read* path —
/// same-slot pool stripe hops, strided im2col, strided global-avg-pool —
/// in one servable network.
fn sppf_graph() -> Graph {
    let q = QCfg::new(2, 2);
    let mut b = GraphBuilder::new("sppf", [1, 8, 8, 4], 19);
    let y = b.conv_named("cv1", "input", 4, 1, 1, 0, q, Some(Op::Relu));
    let p1 = b.maxpool(&y, 5, 1, 2);
    let p2 = b.maxpool(&p1, 5, 1, 2);
    let cat = b.concat(&[&y, &p1, &p2]);
    let z = b.conv_named("cv2", &cat, 8, 1, 1, 0, q, Some(Op::Relu));
    let gp = b.global_avg_pool(&y); // strided gap: reads y's stripe
    let g2 = b.global_avg_pool(&z);
    let d = b.dense(&g2, 8, 10);
    b.finish(vec![d, gp])
}

/// conv + fused residual add (+ post-add relu) + in-place concat with a
/// striped FP32 producer + standalone in-place activation + pool + flatten
/// alias + dense: every lowering the planner performs, in one servable
/// network.
fn serving_graph() -> Graph {
    let q = QCfg::new(2, 2);
    let mut b = GraphBuilder::new("net", [1, 8, 8, 3], 17);
    let c1 = b.conv_named("c1", "input", 8, 3, 1, 1, q, Some(Op::Relu)); // fused epilogue
    let c2 = b.conv_named("c2", &c1, 8, 3, 1, 1, q, None);
    let s = b.add(&c2, &c1); // fused into c2's epilogue (two-accumulator)
    let r = b.act_named("r", &s, Op::Relu); // fused post-add activation
    let d = b.conv_named("d", &c1, 4, 1, 1, 0, QCfg::FP32, None); // striped fp32 conv
    let cat = b.concat(&[&r, &d]); // elided: both producers write stripes
    let a = b.act_named("a", &cat, Op::LeakyRelu); // standalone, in place
    let p = b.maxpool(&a, 2, 2, 0);
    let f = b.flatten(&p); // metadata-only alias
    let dn = b.dense(&f, 4 * 4 * 12, 10);
    b.finish(vec![dn])
}

#[test]
fn steady_state_paths_allocate_nothing() {
    // ---- phase 1: bare bitserial conv kernel path ----------------------
    // a conv-shaped workload: 16x16x8 input, 3x3 kernel, 32 output channels
    let d = ConvDims::new(1, 16, 16, 8, 3, 3, [1, 1], [1, 1]);
    let (rows, patch, cout) = (d.rows(), d.patch(), 32usize);
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..d.n * d.h * d.w * d.c).map(|_| rng.f32()).collect();
    let wq: Vec<i32> = (0..cout * patch).map(|_| rng.range(-2, 2) as i32).collect();
    let wp = pack_weights_offset(&wq, cout, patch, 2);
    let scale = vec![1.0f32; cout];
    let bias = vec![0.0f32; cout];

    // pre-sized executor-style scratch
    let mut cols = vec![0u8; rows * patch];
    let mut packed = Packed::new_zeroed(0, 0, 1);
    let mut acc = vec![0i32; rows * cout];
    let mut out = vec![0.0f32; rows * cout];
    let nthreads = 3; // exercise the pool dispatch path, not just inline

    let allocs = count_steady_state(3, 10, || {
        im2col_quant_u8(&x, &d, 0.1, 3, &mut cols);
        pack_rows_u8_into(&cols, rows, patch, 2, &mut packed);
        gemm_bitserial(&packed, &wp, 2, &mut acc, nthreads);
        dequant_scale_bias(&acc, cout, 0.01, &scale, &bias, &mut out);
    });
    assert_eq!(
        allocs, 0,
        "steady-state bitserial conv path performed {allocs} heap allocations"
    );
    // keep the results observable so the loop can't be optimized out
    assert!(out.iter().all(|v| v.is_finite()));

    // ---- phase 2: full multi-op network through the planned executor ---
    let g = serving_graph();
    let model = compile_graph(&g, EngineChoice::Auto).unwrap();
    assert!(model.plan.fused_instrs() >= 1, "expected a fused conv epilogue");
    assert!(model.plan.fused_add_instrs() >= 1, "expected a fused residual add");
    assert_eq!(model.plan.in_place_concats, 1, "expected the concat elided");
    assert!(model.plan.strided_instrs() >= 2, "expected striped concat producers");
    assert!(model.plan.in_place_instrs() >= 1, "expected an in-place activation");

    // regression-guard the slot savings: the fully fused plan must use
    // strictly less arena than the pass-disabled plan of the same graph
    let unfused = dlrt::exec::planner::build_plan_with(
        &g,
        dlrt::exec::planner::PlanOpts::none(),
    )
    .unwrap();
    assert!(
        model.plan.arena_bytes(1) < unfused.arena_bytes(1),
        "fused arena {} B not below unfused {} B",
        model.plan.arena_bytes(1),
        unfused.arena_bytes(1)
    );

    let mut ex = Executor::new(nthreads);
    let mut input = Tensor::zeros(vec![1, 8, 8, 3]);
    for (i, v) in input.data.iter_mut().enumerate() {
        *v = ((i % 4) as f32) * 0.25;
    }
    let mut outs: Vec<Tensor> = Vec::new();

    let allocs = count_steady_state(3, 10, || {
        ex.run_into(&model, &input, &mut outs).unwrap();
    });
    assert_eq!(
        allocs, 0,
        "steady-state end-to-end run performed {allocs} heap allocations"
    );
    assert_eq!(outs[0].shape, vec![1, 10]);
    assert!(outs[0].data.iter().all(|v| v.is_finite()));

    // ---- phase 3: SPPF pyramid through the strided read path -----------
    let g = sppf_graph();
    let model = compile_graph(&g, EngineChoice::Auto).unwrap();
    assert_eq!(model.plan.in_place_concats, 1, "expected the SPPF concat elided");
    assert_eq!(model.plan.concat_copy_instrs(), 0, "expected zero copy_channels");
    assert!(model.plan.read_view_instrs() >= 3, "expected stripe readers");
    assert!(model.plan.same_slot_stripe_instrs() >= 2,
            "expected stripe-to-stripe pool hops");
    // the strided plan folds every pyramid level into the root slot: its
    // arena must be strictly below the copy-fallback plan's
    let copy_plan = dlrt::exec::planner::build_plan_with(
        &g,
        dlrt::exec::planner::PlanOpts {
            strided_reads: false,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(copy_plan.concat_copy_instrs() >= 1);
    assert!(
        model.plan.arena_bytes(1) < copy_plan.arena_bytes(1),
        "strided arena {} B not below copy-fallback {} B",
        model.plan.arena_bytes(1),
        copy_plan.arena_bytes(1)
    );

    let mut input = Tensor::zeros(vec![1, 8, 8, 4]);
    for (i, v) in input.data.iter_mut().enumerate() {
        *v = ((i % 5) as f32) * 0.25;
    }
    let allocs = count_steady_state(3, 10, || {
        ex.run_into(&model, &input, &mut outs).unwrap();
    });
    assert_eq!(
        allocs, 0,
        "steady-state SPPF strided-read run performed {allocs} heap allocations"
    );
    assert_eq!(outs[0].shape, vec![1, 10]);
    assert!(outs[0].data.iter().all(|v| v.is_finite()));

    // ---- phase 4: profiling + tracing armed — still zero allocations ---
    // The profiler rings are preallocated by enable_profiling and the span
    // ring by with_capacity; recording into either must not allocate.
    ex.enable_profiling(&model.plan);
    let trace = TraceBuffer::with_capacity(256);
    let allocs = count_steady_state(3, 10, || {
        let t0 = std::time::Instant::now();
        ex.run_into(&model, &input, &mut outs).unwrap();
        trace.record(SpanRec {
            kind: SpanKind::Exec,
            req: 1,
            ts_us: trace.now_us(),
            dur_us: t0.elapsed().as_micros() as u64,
            batch_index: 0,
            batch_size: 1,
            status: 200,
        });
    });
    assert_eq!(
        allocs, 0,
        "steady-state profiled+traced run performed {allocs} heap allocations"
    );
    let prof = ex.profiler().expect("profiling enabled");
    assert_eq!(prof.len(), model.plan.instrs.len());
    assert_eq!(prof.runs(), 13, "profiler saw warmup + counted runs");
    assert!(prof.sum_total_s() > 0.0);
    assert_eq!(trace.total(), 13);
    assert_eq!(outs[0].shape, vec![1, 10]);
}

//! Shared test substrate: the seeded random-graph generator and helpers
//! used by both the differential plan fuzzer (`plan_fuzz.rs`) and the
//! verifier mutation fuzzer (`verify_fuzz.rs`). Keeping one generator means
//! the verifier is proven against exactly the plan population the executor
//! is proven on.

// Each test binary compiles this module separately and uses a different
// subset of it; unused-item warnings here would be noise under -D warnings.
#![allow(dead_code)]

use dlrt::dlrt::graph::{Graph, Op, QCfg};
use dlrt::models::GraphBuilder;
use dlrt::util::rng::Rng;
use dlrt::Tensor;

#[derive(Clone)]
pub struct T {
    pub name: String,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

pub fn random_act(rng: &mut Rng) -> Op {
    match rng.usize(5) {
        0 => Op::Relu,
        1 => Op::Relu6,
        2 => Op::LeakyRelu,
        3 => Op::Silu,
        _ => Op::Sigmoid,
    }
}

pub fn random_act_opt(rng: &mut Rng) -> Option<Op> {
    if rng.usize(2) == 0 {
        Some(random_act(rng))
    } else {
        None
    }
}

pub fn random_qcfg(rng: &mut Rng) -> QCfg {
    if rng.usize(4) == 0 {
        QCfg::FP32
    } else {
        QCfg::new(1 + rng.usize(3) as u8, 1 + rng.usize(3) as u8)
    }
}

/// Build a random valid graph. Structure decisions come from a generator
/// RNG derived from (but distinct from) the seed the builder uses for
/// weights, so weights and topology vary independently.
pub fn random_graph(seed: u64) -> Graph {
    let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1));
    let h = [4usize, 6, 8][rng.usize(3)];
    let c = 1 + rng.usize(4);
    let mut b = GraphBuilder::new(&format!("fuzz{seed}"), [1, h, h, c], seed);
    let mut pool: Vec<T> = vec![T { name: "input".into(), h, w: h, c }];
    let mut last = pool[0].clone();
    let mut uid = 0usize;
    let n_ops = 4 + rng.usize(8);
    for _ in 0..n_ops {
        let pick = rng.usize(100);
        let t = pool[rng.usize(pool.len())].clone();
        let new = if pick < 20 {
            // conv: random kernel/stride/bits, optional fused-able act
            let k = [1usize, 3][rng.usize(2)];
            let s = if t.h >= 2 && t.w >= 2 && rng.usize(4) == 0 { 2 } else { 1 };
            let p = k / 2;
            let cout = 1 + rng.usize(6);
            let name = b.conv(&t.name, cout, k, s, random_qcfg(&mut rng),
                              random_act_opt(&mut rng));
            let oh = (t.h + 2 * p - k) / s + 1;
            let ow = (t.w + 2 * p - k) / s + 1;
            Some(T { name, h: oh, w: ow, c: cout })
        } else if pick < 40 {
            // residual block: shape-preserving conv (+ optional act) + add
            // with the skip tensor — the Add/residual fusion's home turf
            // (nests when `t` is itself a residual output)
            let y = b.conv(&t.name, t.c, 3, 1, random_qcfg(&mut rng),
                           random_act_opt(&mut rng));
            let sum = b.add(&y, &t.name);
            let sum = if rng.usize(2) == 0 {
                uid += 1;
                b.act_named(&format!("post{uid}"), &sum, random_act(&mut rng))
            } else {
                sum
            };
            Some(T { name: sum, ..t.clone() })
        } else if pick < 52 {
            // concat of 2-3 same-spatial tensors (concat outputs included,
            // so concat-of-concat arises; multi-use inputs stripe via read
            // views; duplicated inputs and the graph input force per-
            // producer copy fallbacks — i.e. partial stripes)
            let mates: Vec<T> =
                pool.iter().filter(|x| x.h == t.h && x.w == t.w).cloned().collect();
            let take = 2 + rng.usize(2);
            let chosen: Vec<T> =
                (0..take).map(|_| mates[rng.usize(mates.len())].clone()).collect();
            let ctot: usize = chosen.iter().map(|x| x.c).sum();
            if ctot <= 32 {
                let names: Vec<&str> = chosen.iter().map(|x| x.name.as_str()).collect();
                let name = b.concat(&names);
                Some(T { name, h: t.h, w: t.w, c: ctot })
            } else {
                None
            }
        } else if pick < 60 {
            // SPPF-style serial-pool pyramid: conv → pool → pool, all
            // levels concat'd. Every producer is multi-use (the next pool
            // + the concat), so striping them exercises stride-aware reads
            // including the same-slot stripe-to-stripe pool path.
            if t.h >= 2 && t.w >= 2 && t.c <= 8 {
                let ch = 1 + rng.usize(4);
                let y = b.conv(&t.name, ch, 1, 1, random_qcfg(&mut rng),
                               random_act_opt(&mut rng));
                let p1 = b.maxpool(&y, 3, 1, 1);
                let p2 = b.maxpool(&p1, 3, 1, 1);
                let name = b.concat(&[&y, &p1, &p2]);
                Some(T { name, h: t.h, w: t.w, c: 3 * ch })
            } else {
                None
            }
        } else if pick < 68 {
            // maxpool (downsampling or padded same-size)
            if t.h >= 2 && t.w >= 2 {
                if rng.usize(2) == 0 {
                    let name = b.maxpool(&t.name, 2, 2, 0);
                    Some(T { name, h: (t.h - 2) / 2 + 1, w: (t.w - 2) / 2 + 1, c: t.c })
                } else {
                    let name = b.maxpool(&t.name, 3, 1, 1);
                    Some(T { name, ..t.clone() })
                }
            } else {
                None
            }
        } else if pick < 78 {
            // upsample (bounded so tensors stay small)
            if t.h <= 8 && t.w <= 8 {
                let name = b.upsample2x(&t.name);
                Some(T { name, h: 2 * t.h, w: 2 * t.w, c: t.c })
            } else {
                None
            }
        } else if pick < 90 {
            // standalone activation (in-place / stripe-capable)
            uid += 1;
            let name = b.act_named(&format!("act{uid}"), &t.name, random_act(&mut rng));
            Some(T { name, ..t.clone() })
        } else {
            // add of two same-shape tensors (incl. x + x)
            let mates: Vec<T> = pool
                .iter()
                .filter(|x| x.h == t.h && x.w == t.w && x.c == t.c)
                .cloned()
                .collect();
            let other = mates[rng.usize(mates.len())].clone();
            let name = b.add(&t.name, &other.name);
            Some(T { name, ..t.clone() })
        };
        if let Some(nt) = new {
            pool.push(nt.clone());
            last = nt;
        }
    }

    let mut outputs: Vec<String> = Vec::new();
    match rng.usize(4) {
        0 => {
            // classifier tail: flatten alias + dense (+ optional act)
            let f = b.flatten(&last.name);
            let mut d = b.dense(&f, last.h * last.w * last.c, 1 + rng.usize(5));
            if rng.usize(2) == 0 {
                d = b.act_named("head", &d, Op::Sigmoid);
            }
            outputs.push(d);
        }
        1 => {
            let gap = b.global_avg_pool(&last.name);
            let d = b.dense(&gap, last.c, 1 + rng.usize(5));
            outputs.push(d);
        }
        _ => outputs.push(last.name.clone()),
    }
    // sometimes expose a mid-graph tensor too (outputs pin their slots)
    if rng.usize(3) == 0 {
        let extra = pool[rng.usize(pool.len())].name.clone();
        if !outputs.contains(&extra) {
            outputs.push(extra);
        }
    }
    b.finish(outputs)
}

pub fn dump(g: &Graph) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    writeln!(s, "  input {:?} {:?}", g.input_name, g.input_shape).unwrap();
    for n in &g.nodes {
        let extra = match &n.op {
            Op::Conv2d { kernel, stride, padding, qcfg, .. } => {
                format!(" k{kernel:?} s{stride:?} p{padding:?} {}", qcfg.tag())
            }
            _ => String::new(),
        };
        writeln!(s, "  {:<12} {:<16} {:?} -> {}{extra}", n.op.name(), n.name, n.inputs,
                 n.output)
            .unwrap();
    }
    writeln!(s, "  outputs {:?}", g.outputs).unwrap();
    s
}

/// Deterministic input mixing exact low-bit codes with negatives and
/// non-representable values.
pub fn fuzz_input(g: &Graph, batch: usize, seed: u64) -> Tensor {
    let s = g.input_shape;
    let mut rng = Rng::new(seed ^ 0xf00d);
    let mut x = Tensor::zeros(vec![batch, s[1], s[2], s[3]]);
    for v in x.data.iter_mut() {
        *v = (rng.usize(9) as f32) * 0.125 - 0.5;
    }
    x
}

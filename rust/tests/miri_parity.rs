//! A deliberately tiny plan-parity case for Miri: one quantized conv with a
//! fused activation, a pool, and a concat, run through the arena executor on
//! a 2-thread pool against the unfused reference interpreter. Small enough
//! to interpret in seconds, yet it exercises the crate's entire unsafe
//! surface — lifetime-erased jobs, the latch, and aliased arena slot views —
//! under Miri's borrow and data-race checking. The CI miri job runs this
//! alongside the kernel unit tests.

use dlrt::compiler::{compile_graph, EngineChoice};
use dlrt::dlrt::graph::{Op, QCfg};
use dlrt::exec::{reference, Executor};
use dlrt::models::GraphBuilder;
use dlrt::Tensor;

#[test]
fn tiny_plan_parity_under_two_threads() {
    let mut b = GraphBuilder::new("miri", [1, 4, 4, 2], 7);
    let c1 = b.conv("input", 2, 1, 1, QCfg::new(2, 2), Some(Op::Relu));
    let p1 = b.maxpool(&c1, 3, 1, 1);
    let cat = b.concat(&[&c1, &p1]);
    let g = b.finish(vec![cat]);
    let model = compile_graph(&g, EngineChoice::Auto).unwrap();

    let mut x = Tensor::zeros(vec![1, 4, 4, 2]);
    for (i, v) in x.data.iter_mut().enumerate() {
        *v = ((i % 7) as f32) * 0.25 - 0.75;
    }

    let mut ex = Executor::new(2);
    let got = ex.run(&model, &x).unwrap();
    let want = reference::run_unfused(&model, &x, 2).unwrap();
    assert_eq!(got.len(), want.len());
    for (a, w) in got.iter().zip(&want) {
        assert_eq!(a.shape, w.shape);
        assert_eq!(a.data, w.data);
    }
}

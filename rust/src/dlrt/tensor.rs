//! Dense f32 tensor (NHWC activation layout) + packed bitplane storage.

use anyhow::{bail, Result};

/// Dense row-major f32 tensor. Activations use NHWC; conv weights HWIO.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// NHWC accessors (debug/test convenience; hot paths index manually).
    pub fn nhwc(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.shape.len(), 4, "not a rank-4 tensor: {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3])
    }

    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        let (_, hh, ww, cc) = self.nhwc();
        self.data[((n * hh + h) * ww + w) * cc + c]
    }

    /// Max |a-b| over elements; shape must match.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Bitplane-packed matrix: `rows` logical rows of `k` codes, each row stored
/// as `bits` planes of `words_per_row` u64 words (LSB-first lanes).
///
/// This is the deployment layout of the paper's kernels: plane `i` of row
/// `r` occupies `data[((r * bits) + i) * words_per_row ..][..words_per_row]`,
/// so the innermost bitserial loop streams contiguous words for all planes
/// of one row.
#[derive(Clone, Debug, PartialEq)]
pub struct Packed {
    pub rows: usize,
    pub k: usize,
    pub bits: usize,
    pub words_per_row: usize,
    pub data: Vec<u64>,
}

impl Packed {
    pub fn words_for(k: usize) -> usize {
        k.div_ceil(64)
    }

    pub fn new_zeroed(rows: usize, k: usize, bits: usize) -> Packed {
        let wpr = Self::words_for(k);
        Packed { rows, k, bits, words_per_row: wpr, data: vec![0; rows * bits * wpr] }
    }

    #[inline]
    pub fn row_plane(&self, row: usize, plane: usize) -> &[u64] {
        let base = (row * self.bits + plane) * self.words_per_row;
        &self.data[base..base + self.words_per_row]
    }

    #[inline]
    pub fn row_plane_mut(&mut self, row: usize, plane: usize) -> &mut [u64] {
        let base = (row * self.bits + plane) * self.words_per_row;
        &mut self.data[base..base + self.words_per_row]
    }

    /// Pack unsigned codes (`< 2^bits`) laid out as rows x k.
    pub fn pack(codes: &[u32], rows: usize, k: usize, bits: usize) -> Packed {
        assert_eq!(codes.len(), rows * k);
        let mut p = Packed::new_zeroed(rows, k, bits);
        for r in 0..rows {
            for j in 0..k {
                let v = codes[r * k + j];
                debug_assert!(v < (1 << bits), "code {v} out of {bits}-bit range");
                let word = j / 64;
                let lane = j % 64;
                for i in 0..bits {
                    if (v >> i) & 1 == 1 {
                        p.row_plane_mut(r, i)[word] |= 1u64 << lane;
                    }
                }
            }
        }
        p
    }

    /// Unpack back to codes (tests / inspection).
    pub fn unpack(&self) -> Vec<u32> {
        let mut out = vec![0u32; self.rows * self.k];
        for r in 0..self.rows {
            for i in 0..self.bits {
                let plane = self.row_plane(r, i);
                for j in 0..self.k {
                    let bit = (plane[j / 64] >> (j % 64)) & 1;
                    out[r * self.k + j] |= (bit as u32) << i;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn tensor_shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let t = Tensor::zeros(vec![1, 2, 2, 3]);
        assert_eq!(t.numel(), 12);
        assert_eq!(t.at4(0, 1, 1, 2), 0.0);
    }

    #[test]
    fn pack_roundtrip_small() {
        let codes: Vec<u32> = vec![0, 1, 2, 3, 3, 2, 1, 0];
        let p = Packed::pack(&codes, 2, 4, 2);
        assert_eq!(p.unpack(), codes);
    }

    #[test]
    fn pack_roundtrip_property() {
        prop::check(100, |rng, _| {
            let bits = rng.usize(4) + 1;
            let rows = rng.usize(6) + 1;
            let k = rng.usize(200) + 1;
            let codes: Vec<u32> =
                (0..rows * k).map(|_| rng.usize(1 << bits) as u32).collect();
            let p = Packed::pack(&codes, rows, k, bits);
            prop::ensure(p.unpack() == codes, format!("bits={bits} rows={rows} k={k}"))
        });
    }

    #[test]
    fn plane_layout_is_contiguous_per_row() {
        let mut rng = Rng::new(7);
        let k = 130; // 3 words
        let codes: Vec<u32> = (0..2 * k).map(|_| rng.usize(4) as u32).collect();
        let p = Packed::pack(&codes, 2, k, 2);
        assert_eq!(p.words_per_row, 3);
        assert_eq!(p.data.len(), 2 * 2 * 3);
        // popcount over planes reproduces code sums
        let sum_codes: u32 = codes[..k].iter().sum();
        let s: u32 = (0..2)
            .map(|i| {
                p.row_plane(0, i).iter().map(|w| w.count_ones()).sum::<u32>() << i
            })
            .sum();
        assert_eq!(s, sum_codes);
    }
}

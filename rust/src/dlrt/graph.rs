//! Graph IR — mirrors `python/compile/graph.py` op-for-op.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Result};

/// Per-conv quantization config (mixed-precision knob; paper §VII.D).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QCfg {
    pub w_bits: u8,
    pub a_bits: u8,
    pub enabled: bool,
}

impl QCfg {
    pub const FP32: QCfg = QCfg { w_bits: 0, a_bits: 0, enabled: false };

    pub fn new(a_bits: u8, w_bits: u8) -> QCfg {
        QCfg { w_bits, a_bits, enabled: true }
    }

    pub fn tag(&self) -> String {
        if self.enabled {
            format!("{}A{}W", self.a_bits, self.w_bits)
        } else {
            "FP32".to_string()
        }
    }
}

/// Signed clipping limits for a b-bit code (paper §IV): (Q_P, Q_N).
pub fn qp_qn(bits: u8, signed: bool) -> (i32, i32) {
    assert!(bits >= 1);
    if signed {
        ((1 << (bits - 1)) - 1, 1 << (bits - 1))
    } else {
        ((1 << bits) - 1, 0)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Conv2d {
        stride: [usize; 2],
        padding: [usize; 2],
        kernel: [usize; 2],
        cin: usize,
        cout: usize,
        qcfg: QCfg,
    },
    Dense { cin: usize, cout: usize },
    MaxPool2d { kernel: [usize; 2], stride: [usize; 2], padding: [usize; 2] },
    GlobalAvgPool,
    Add,
    Concat,
    Upsample2x,
    Relu,
    Relu6,
    Silu,
    LeakyRelu,
    Sigmoid,
    Flatten,
}

impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Conv2d { .. } => "conv2d",
            Op::Dense { .. } => "dense",
            Op::MaxPool2d { .. } => "maxpool2d",
            Op::GlobalAvgPool => "global_avg_pool",
            Op::Add => "add",
            Op::Concat => "concat",
            Op::Upsample2x => "upsample2x",
            Op::Relu => "relu",
            Op::Relu6 => "relu6",
            Op::Silu => "silu",
            Op::LeakyRelu => "leaky_relu",
            Op::Sigmoid => "sigmoid",
            Op::Flatten => "flatten",
        }
    }

    /// Elementwise activations — candidates for conv-epilogue fusion and
    /// in-place lowering in the execution planner. Defined via
    /// [`crate::kernels::elementwise::ActKind`] so the two sets cannot
    /// drift apart.
    pub fn is_activation(&self) -> bool {
        crate::kernels::elementwise::ActKind::from_op(self).is_some()
    }
}

#[derive(Clone, Debug)]
pub struct Node {
    pub op: Op,
    pub name: String,
    pub inputs: Vec<String>,
    pub output: String,
}

/// Weight payload attached to conv/dense nodes by the compiler.
#[derive(Clone, Debug, Default)]
pub struct NodeWeights {
    /// Raw f32 weights (conv: HWIO, dense: IN×OUT).
    pub w: Vec<f32>,
    /// Per-channel folded-BN scale (conv) — empty for dense.
    pub scale: Vec<f32>,
    /// Per-channel bias (conv folded-BN beta / dense bias).
    pub bias: Vec<f32>,
    /// Quantization scales (set when qcfg.enabled).
    pub s_w: f32,
    pub s_a: f32,
}

#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    pub input_name: String,
    pub input_shape: [usize; 4], // NHWC
    pub nodes: Vec<Node>,
    pub outputs: Vec<String>,
    /// node name → weights (convs and denses only)
    pub weights: BTreeMap<String, NodeWeights>,
}

impl Graph {
    /// Topology checks only (used for models loaded from `.dlrt`, whose
    /// weights live in the compiled kernels, not on the graph).
    pub fn validate_topology(&self) -> Result<()> {
        let mut avail: BTreeSet<&str> = BTreeSet::new();
        avail.insert(&self.input_name);
        for n in &self.nodes {
            for i in &n.inputs {
                if !avail.contains(i.as_str()) {
                    bail!("node {} reads undefined tensor {i:?}", n.name);
                }
            }
            if !avail.insert(&n.output) {
                bail!("tensor {:?} defined twice", n.output);
            }
        }
        if self.outputs.is_empty() {
            bail!("graph has no outputs");
        }
        for o in &self.outputs {
            if !avail.contains(o.as_str()) {
                bail!("graph output {o:?} undefined");
            }
        }
        Ok(())
    }

    /// Topology + weight-presence checks (for freshly built/parsed graphs).
    pub fn validate(&self) -> Result<()> {
        self.validate_topology()?;
        for n in &self.nodes {
            if matches!(n.op, Op::Conv2d { .. } | Op::Dense { .. })
                && !self.weights.contains_key(&n.name)
            {
                bail!("node {} has no weights", n.name);
            }
        }
        Ok(())
    }

    pub fn conv_nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| matches!(n.op, Op::Conv2d { .. }))
    }

    /// Infer the shape of every tensor from the input shape.
    pub fn infer_shapes(&self) -> Result<BTreeMap<String, Vec<usize>>> {
        let mut shapes: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        shapes.insert(self.input_name.clone(), self.input_shape.to_vec());
        for n in &self.nodes {
            let ins: Vec<&Vec<usize>> = n
                .inputs
                .iter()
                .map(|i| shapes.get(i).ok_or_else(|| anyhow::anyhow!("missing {i}")))
                .collect::<Result<_>>()?;
            let out = infer_node_shape(&n.op, &ins, &n.name)?;
            shapes.insert(n.output.clone(), out);
        }
        Ok(shapes)
    }

    /// Total conv MACs for one forward pass (used by the cost model).
    pub fn conv_macs(&self) -> Result<u64> {
        let shapes = self.infer_shapes()?;
        let mut total = 0u64;
        for n in self.conv_nodes() {
            if let Op::Conv2d { kernel, cin, cout, .. } = n.op {
                let os = &shapes[&n.output];
                total += (os[0] * os[1] * os[2] * cout * kernel[0] * kernel[1] * cin) as u64;
            }
        }
        Ok(total)
    }
}

pub fn conv_out_hw(
    h: usize,
    w: usize,
    kernel: [usize; 2],
    stride: [usize; 2],
    padding: [usize; 2],
) -> (usize, usize) {
    assert!(
        h + 2 * padding[0] >= kernel[0] && w + 2 * padding[1] >= kernel[1],
        "window {kernel:?} larger than padded input {h}x{w} (pad {padding:?}) — \
         input resolution too small for this architecture"
    );
    (
        (h + 2 * padding[0] - kernel[0]) / stride[0] + 1,
        (w + 2 * padding[1] - kernel[1]) / stride[1] + 1,
    )
}

/// Checked [`conv_out_hw`]: `None` on a zero stride or a window larger than
/// the padded input (where `conv_out_hw` would panic). The single source of
/// window legality for shape inference *and* `ExecPlan::validate`, so
/// compile-time and per-request checks cannot drift apart — untrusted
/// graphs (a malformed `.dlrt` header) must error, never abort.
pub fn conv_out_hw_checked(
    h: usize,
    w: usize,
    kernel: [usize; 2],
    stride: [usize; 2],
    padding: [usize; 2],
) -> Option<(usize, usize)> {
    if stride[0] == 0
        || stride[1] == 0
        || h + 2 * padding[0] < kernel[0]
        || w + 2 * padding[1] < kernel[1]
    {
        return None;
    }
    Some(conv_out_hw(h, w, kernel, stride, padding))
}

fn infer_node_shape(op: &Op, ins: &[&Vec<usize>], name: &str) -> Result<Vec<usize>> {
    // arity guards: untrusted graphs (e.g. a malformed .dlrt header) reach
    // shape inference via plan lowering, so bad arity must error, not panic
    if ins.is_empty() {
        bail!("{name}: {} node has no inputs", op.name());
    }
    if matches!(op, Op::Add) && ins.len() != 2 {
        bail!("{name}: add expects 2 inputs, got {}", ins.len());
    }
    let r4 = |s: &Vec<usize>| -> Result<[usize; 4]> {
        if s.len() != 4 {
            bail!("{name}: expected rank-4, got {s:?}");
        }
        Ok([s[0], s[1], s[2], s[3]])
    };
    Ok(match op {
        Op::Conv2d { stride, padding, kernel, cin, cout, .. } => {
            let [n, h, w, c] = r4(ins[0])?;
            if c != *cin {
                bail!("{name}: cin {cin} != input channels {c}");
            }
            let Some((oh, ow)) = conv_out_hw_checked(h, w, *kernel, *stride, *padding)
            else {
                bail!(
                    "{name}: zero stride or window {kernel:?} larger than padded \
                     input {h}x{w} (pad {padding:?})"
                );
            };
            vec![n, oh, ow, *cout]
        }
        Op::Dense { cin, cout } => {
            if ins[0].last() != Some(cin) {
                bail!("{name}: dense cin mismatch {:?} vs {cin}", ins[0]);
            }
            let mut s = ins[0].clone();
            *s.last_mut().unwrap() = *cout;
            s
        }
        Op::MaxPool2d { kernel, stride, padding } => {
            let [n, h, w, c] = r4(ins[0])?;
            let Some((oh, ow)) = conv_out_hw_checked(h, w, *kernel, *stride, *padding)
            else {
                bail!(
                    "{name}: zero stride or window {kernel:?} larger than padded \
                     input {h}x{w} (pad {padding:?})"
                );
            };
            vec![n, oh, ow, c]
        }
        Op::GlobalAvgPool => {
            let [n, _, _, c] = r4(ins[0])?;
            vec![n, c]
        }
        Op::Add => {
            if ins[0] != ins[1] {
                bail!("{name}: add shape mismatch {:?} vs {:?}", ins[0], ins[1]);
            }
            ins[0].clone()
        }
        Op::Concat => {
            let [n, h, w, _] = r4(ins[0])?;
            let mut c = 0;
            for s in ins {
                let [n2, h2, w2, c2] = r4(s)?;
                if (n2, h2, w2) != (n, h, w) {
                    bail!("{name}: concat spatial mismatch");
                }
                c += c2;
            }
            vec![n, h, w, c]
        }
        Op::Upsample2x => {
            let [n, h, w, c] = r4(ins[0])?;
            vec![n, 2 * h, 2 * w, c]
        }
        Op::Flatten => {
            let numel: usize = ins[0][1..].iter().product();
            vec![ins[0][0], numel]
        }
        Op::Relu | Op::Relu6 | Op::Silu | Op::LeakyRelu | Op::Sigmoid => ins[0].clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut g = Graph {
            name: "t".into(),
            input_name: "input".into(),
            input_shape: [1, 8, 8, 3],
            nodes: vec![
                Node {
                    op: Op::Conv2d {
                        stride: [2, 2],
                        padding: [1, 1],
                        kernel: [3, 3],
                        cin: 3,
                        cout: 8,
                        qcfg: QCfg::new(2, 2),
                    },
                    name: "c1".into(),
                    inputs: vec!["input".into()],
                    output: "c1.out".into(),
                },
                Node {
                    op: Op::Relu,
                    name: "r1".into(),
                    inputs: vec!["c1.out".into()],
                    output: "r1.out".into(),
                },
                Node {
                    op: Op::GlobalAvgPool,
                    name: "gap".into(),
                    inputs: vec!["r1.out".into()],
                    output: "gap.out".into(),
                },
            ],
            outputs: vec!["gap.out".into()],
            weights: BTreeMap::new(),
        };
        g.weights.insert(
            "c1".into(),
            NodeWeights {
                w: vec![0.0; 3 * 3 * 3 * 8],
                scale: vec![1.0; 8],
                bias: vec![0.0; 8],
                s_w: 0.1,
                s_a: 0.1,
            },
        );
        g
    }

    #[test]
    fn validates_and_infers() {
        let g = tiny();
        g.validate().unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes["c1.out"], vec![1, 4, 4, 8]);
        assert_eq!(shapes["gap.out"], vec![1, 8]);
        assert_eq!(g.conv_macs().unwrap(), (4 * 4 * 8 * 3 * 3 * 3) as u64);
    }

    #[test]
    fn rejects_undefined_input() {
        let mut g = tiny();
        g.nodes[1].inputs[0] = "nope".into();
        assert!(g.validate().is_err());
    }

    #[test]
    fn rejects_missing_weights() {
        let mut g = tiny();
        g.weights.clear();
        assert!(g.validate().is_err());
    }

    #[test]
    fn qcfg_tags_and_limits() {
        assert_eq!(QCfg::new(2, 2).tag(), "2A2W");
        assert_eq!(QCfg::new(1, 2).tag(), "1A2W");
        assert_eq!(QCfg::FP32.tag(), "FP32");
        assert_eq!(qp_qn(2, true), (1, 2));
        assert_eq!(qp_qn(1, true), (0, 1));
        assert_eq!(qp_qn(2, false), (3, 0));
        assert_eq!(qp_qn(8, true), (127, 128));
    }

    #[test]
    fn conv_out_dims() {
        assert_eq!(conv_out_hw(224, 224, [7, 7], [2, 2], [3, 3]), (112, 112));
        assert_eq!(conv_out_hw(8, 8, [3, 3], [1, 1], [0, 0]), (6, 6));
    }
}

//! Graph IR, tensors, and the `.dlrt` deployable model format.

pub mod format;
pub mod graph;
pub mod tensor;

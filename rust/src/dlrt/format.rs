//! `.dlrt` — the deployable model file (paper §VI: Deeplite Compiler output).
//!
//! Layout (little-endian):
//!
//! ```text
//!   bytes 0..4    magic  b"DLRT"
//!   bytes 4..8    version u32 (currently 3; version-2 files still load)
//!   bytes 8..16   header length u64
//!   header        JSON: graph topology + per-layer engine records whose
//!                 blob fields are {offset, len} references into the payload
//!   payload       raw blobs, 8-byte aligned: u64 packed planes, f32
//!                 scales/biases/weights, i8 codes
//! ```
//!
//! The header is JSON (not a packed struct) so `dlrt inspect` can dump it
//! and version skew stays debuggable; all bulk data lives in the payload.
//!
//! **Version 2** saves bitserial weight planes *prepacked* in the writing
//! host's selected micro-kernel layout: each bitserial record carries
//! `layout` (`"row_major"` or `"tile_n"`), `plane_stride`, and — for
//! `tile_n` — the `tile_n`/`chunk` geometry; the header records the writer's
//! `isa` for provenance. A loader whose own selected kernel wants a
//! different layout repacks once at load time, so the serving path always
//! runs the layout its kernel streams best.
//!
//! **Version 3** adds the `dlrt tune` sections, both optional (a v3 file
//! without them is a v2 file with a bumped version): a per-conv `sched`
//! record (tuned tile geometry / thread split / staging the conv was
//! prepacked with) and a top-level `tuning` section holding the whole
//! tuning DB. Both are validated on load — `load` is the trust boundary —
//! and both degrade, never error, on ISA skew: when the loading host's
//! selected ISA differs from the file's (different machine, or
//! `DLRT_FORCE_ISA`), the per-conv schedules are dropped, the embedded DB
//! is re-consulted for the host's ISA, and whatever misses falls back to
//! the kernel's static defaults with a logged note.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::dlrt::graph::{Graph, Node, Op, QCfg};
use crate::dlrt::tensor::Packed;
use crate::exec::{CompiledConv, CompiledDense, CompiledModel, ConvKernel};
use crate::kernels::ukernel::{self, PackedW, WLayout};
use crate::util::json::{arr, num, obj, s, Json};

pub const MAGIC: &[u8; 4] = b"DLRT";
pub const VERSION: u32 = 3;
/// Oldest version `load` still accepts (pre-tuning files load unchanged).
pub const MIN_VERSION: u32 = 2;

// ---------------------------------------------------------------------------
// payload writer / reader
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Payload {
    bytes: Vec<u8>,
}

impl Payload {
    fn align8(&mut self) {
        while self.bytes.len() % 8 != 0 {
            self.bytes.push(0);
        }
    }

    fn put_f32(&mut self, data: &[f32]) -> Json {
        self.align8();
        let off = self.bytes.len();
        for v in data {
            self.bytes.extend_from_slice(&v.to_le_bytes());
        }
        obj(vec![("offset", num(off as f64)), ("len", num(data.len() as f64))])
    }

    fn put_u64(&mut self, data: &[u64]) -> Json {
        self.align8();
        let off = self.bytes.len();
        for v in data {
            self.bytes.extend_from_slice(&v.to_le_bytes());
        }
        obj(vec![("offset", num(off as f64)), ("len", num(data.len() as f64))])
    }

    fn put_i8(&mut self, data: &[i8]) -> Json {
        self.align8();
        let off = self.bytes.len();
        self.bytes.extend(data.iter().map(|&v| v as u8));
        obj(vec![("offset", num(off as f64)), ("len", num(data.len() as f64))])
    }
}

/// Resolve a `{offset, len}` payload reference to a byte slice, with all
/// arithmetic checked: a hostile header can claim arbitrary offsets/lengths,
/// and `off + elem * len` must not wrap in release builds.
fn get_blob<'a>(payload: &'a [u8], r: &Json, elem: usize, what: &str) -> Result<&'a [u8]> {
    let off = r.get("offset")?.usize()?;
    let len = r.get("len")?.usize()?;
    let end = len
        .checked_mul(elem)
        .and_then(|n| off.checked_add(n))
        .ok_or_else(|| anyhow!("{what} blob range overflows: offset {off} len {len}"))?;
    payload.get(off..end).ok_or_else(|| {
        anyhow!("{what} blob out of bounds: {off}..{end} of {} payload bytes", payload.len())
    })
}

fn get_f32(payload: &[u8], r: &Json) -> Result<Vec<f32>> {
    let bytes = get_blob(payload, r, 4, "f32")?;
    Ok(bytes.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect())
}

fn get_u64(payload: &[u8], r: &Json) -> Result<Vec<u64>> {
    let bytes = get_blob(payload, r, 8, "u64")?;
    Ok(bytes
        .chunks_exact(8)
        .map(|b| u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
        .collect())
}

fn get_i8(payload: &[u8], r: &Json) -> Result<Vec<i8>> {
    let bytes = get_blob(payload, r, 1, "i8")?;
    Ok(bytes.iter().map(|&b| b as i8).collect())
}

// ---------------------------------------------------------------------------
// graph topology <-> json
// ---------------------------------------------------------------------------

fn usize2_json(v: [usize; 2]) -> Json {
    arr(vec![num(v[0] as f64), num(v[1] as f64)])
}

fn node_to_json(n: &Node) -> Json {
    let mut fields = vec![
        ("op", s(n.op.name())),
        ("name", s(&n.name)),
        ("inputs", arr(n.inputs.iter().map(|i| s(i)).collect())),
        ("output", s(&n.output)),
    ];
    match &n.op {
        Op::Conv2d { stride, padding, kernel, cin, cout, qcfg } => {
            fields.push(("stride", usize2_json(*stride)));
            fields.push(("padding", usize2_json(*padding)));
            fields.push(("kernel", usize2_json(*kernel)));
            fields.push(("cin", num(*cin as f64)));
            fields.push(("cout", num(*cout as f64)));
            fields.push(("qcfg", obj(vec![
                ("w_bits", num(qcfg.w_bits as f64)),
                ("a_bits", num(qcfg.a_bits as f64)),
                ("enabled", Json::Bool(qcfg.enabled)),
            ])));
        }
        Op::Dense { cin, cout } => {
            fields.push(("cin", num(*cin as f64)));
            fields.push(("cout", num(*cout as f64)));
        }
        Op::MaxPool2d { kernel, stride, padding } => {
            fields.push(("kernel", usize2_json(*kernel)));
            fields.push(("stride", usize2_json(*stride)));
            fields.push(("padding", usize2_json(*padding)));
        }
        _ => {}
    }
    obj(fields)
}

fn node_from_json(v: &Json) -> Result<Node> {
    let pair = |key: &str| -> Result<[usize; 2]> {
        let p = v.get(key)?.usize_vec()?;
        if p.len() != 2 {
            bail!("field {key:?} must have exactly 2 entries, got {}", p.len());
        }
        Ok([p[0], p[1]])
    };
    let op = match v.get("op")?.str()? {
        "conv2d" => {
            let qj = v.get("qcfg")?;
            let qcfg = if qj.get("enabled")?.bool()? {
                QCfg::new(qj.get("a_bits")?.usize()? as u8, qj.get("w_bits")?.usize()? as u8)
            } else {
                QCfg::FP32
            };
            Op::Conv2d {
                stride: pair("stride")?,
                padding: pair("padding")?,
                kernel: pair("kernel")?,
                cin: v.get("cin")?.usize()?,
                cout: v.get("cout")?.usize()?,
                qcfg,
            }
        }
        "dense" => Op::Dense { cin: v.get("cin")?.usize()?, cout: v.get("cout")?.usize()? },
        "maxpool2d" => Op::MaxPool2d {
            kernel: pair("kernel")?,
            stride: pair("stride")?,
            padding: pair("padding")?,
        },
        "global_avg_pool" => Op::GlobalAvgPool,
        "add" => Op::Add,
        "concat" => Op::Concat,
        "upsample2x" => Op::Upsample2x,
        "relu" => Op::Relu,
        "relu6" => Op::Relu6,
        "silu" => Op::Silu,
        "leaky_relu" => Op::LeakyRelu,
        "sigmoid" => Op::Sigmoid,
        "flatten" => Op::Flatten,
        other => bail!("unknown op {other:?}"),
    };
    Ok(Node {
        op,
        name: v.get("name")?.str()?.to_string(),
        inputs: v.get("inputs")?.arr()?.iter().map(|i| Ok(i.str()?.to_string()))
            .collect::<Result<_>>()?,
        output: v.get("output")?.str()?.to_string(),
    })
}

pub fn graph_to_json(g: &Graph) -> Json {
    obj(vec![
        ("name", s(&g.name)),
        ("input", obj(vec![
            ("name", s(&g.input_name)),
            ("shape", arr(g.input_shape.iter().map(|&d| num(d as f64)).collect())),
        ])),
        ("outputs", arr(g.outputs.iter().map(|o| s(o)).collect())),
        ("nodes", arr(g.nodes.iter().map(node_to_json).collect())),
    ])
}

pub fn graph_from_json(v: &Json) -> Result<Graph> {
    let input = v.get("input")?;
    let shape = input.get("shape")?.usize_vec()?;
    if shape.len() != 4 {
        bail!("input shape must be rank 4 (NHWC), got rank {}", shape.len());
    }
    let g = Graph {
        name: v.get("name")?.str()?.to_string(),
        input_name: input.get("name")?.str()?.to_string(),
        input_shape: [shape[0], shape[1], shape[2], shape[3]],
        nodes: v.get("nodes")?.arr()?.iter().map(node_from_json).collect::<Result<_>>()?,
        outputs: v.get("outputs")?.arr()?.iter().map(|o| Ok(o.str()?.to_string()))
            .collect::<Result<_>>()?,
        weights: BTreeMap::new(),
    };
    g.validate_topology()?;
    Ok(g)
}

// ---------------------------------------------------------------------------
// save / load
// ---------------------------------------------------------------------------

pub fn save(model: &CompiledModel, path: &Path) -> Result<()> {
    save_with(model, crate::tune::ambient_db(), path)
}

/// [`save`] with an explicit tuning DB to embed (`dlrt tune --out` feeds
/// `dlrt compile --tune-db` feeds this): per-conv tuned schedules ride on
/// their conv records; `db` lands whole in the header's `tuning` section so
/// a loading host with a *different* ISA can still look its own entries up.
pub fn save_with(
    model: &CompiledModel,
    db: Option<&crate::tune::TuningDb>,
    path: &Path,
) -> Result<()> {
    let mut payload = Payload::default();
    let mut convs = BTreeMap::new();
    for c in &model.convs {
        let mut fields = vec![
            ("engine", s(c.kernel.engine_name())),
            ("scale", payload.put_f32(&c.scale)),
            ("bias", payload.put_f32(&c.bias)),
        ];
        match &c.kernel {
            ConvKernel::Bitserial { packed, s_w, s_a, w_bits, a_bits } => {
                fields.push(("rows", num(packed.rows as f64)));
                fields.push(("k", num(packed.k as f64)));
                fields.push(("bits", num(packed.bits as f64)));
                // prepacked layout, exactly as held in memory: the loader
                // repacks only when its own kernel wants a different one
                match packed.layout {
                    WLayout::RowMajor => fields.push(("layout", s("row_major"))),
                    WLayout::TileN { tile_n, chunk } => {
                        fields.push(("layout", s("tile_n")));
                        fields.push(("tile_n", num(tile_n as f64)));
                        fields.push(("chunk", num(chunk as f64)));
                    }
                }
                fields.push(("plane_stride", num(packed.plane_stride as f64)));
                fields.push(("planes", payload.put_u64(&packed.data)));
                fields.push(("s_w", num(*s_w as f64)));
                fields.push(("s_a", num(*s_a as f64)));
                fields.push(("w_bits", num(*w_bits as f64)));
                fields.push(("a_bits", num(*a_bits as f64)));
            }
            ConvKernel::Fp32 { wt } => {
                fields.push(("wt", payload.put_f32(wt)));
            }
            ConvKernel::Int8 { codes, s_w, s_a } => {
                fields.push(("codes", payload.put_i8(codes)));
                fields.push(("s_w", num(*s_w as f64)));
                fields.push(("s_a", num(*s_a as f64)));
            }
        }
        if let Some(sc) = &c.sched {
            fields.push(("sched", crate::tune::sched_to_json(sc)));
        }
        convs.insert(c.name.clone(), obj(fields));
    }
    let mut denses = BTreeMap::new();
    for d in &model.denses {
        denses.insert(d.name.clone(),
                      obj(vec![("w", payload.put_f32(&d.w)), ("b", payload.put_f32(&d.b))]));
    }
    let mut header_fields = vec![
        ("graph", graph_to_json(&model.graph)),
        // writer provenance: which ISA the planes were prepacked for
        ("isa", s(model.isa.name())),
        ("convs", Json::Obj(convs)),
        ("denses", Json::Obj(denses)),
    ];
    if let Some(d) = db.filter(|d| !d.is_empty()) {
        header_fields.push(("tuning", d.to_json()));
    }
    let header = obj(header_fields).to_string();

    let mut out = Vec::with_capacity(16 + header.len() + payload.bytes.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(header.len() as u64).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    out.extend_from_slice(&payload.bytes);
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

pub fn load(path: &Path) -> Result<CompiledModel> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() < 16 || &bytes[0..4] != MAGIC {
        bail!("{}: not a .dlrt file", path.display());
    }
    let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
    if !(MIN_VERSION..=VERSION).contains(&version) {
        bail!("unsupported .dlrt version {version}");
    }
    let hlen: usize = u64::from_le_bytes(bytes[8..16].try_into().unwrap())
        .try_into()
        .map_err(|_| anyhow!("{}: header length does not fit in usize", path.display()))?;
    let body = hlen
        .checked_add(16)
        .ok_or_else(|| anyhow!("{}: header length overflows", path.display()))?;
    let header_bytes = bytes.get(16..body).ok_or_else(|| {
        anyhow!("{}: truncated header ({} bytes, header claims {hlen})", path.display(), bytes.len())
    })?;
    let header = Json::parse(std::str::from_utf8(header_bytes)?)?;
    // payload starts at the first 8-byte boundary the writer aligned to,
    // relative to payload start (offsets are payload-relative)
    let payload = &bytes[body..];

    let graph = graph_from_json(header.get("graph")?)?;

    // the loading host's own selected kernel decides the layout weights
    // must end up in; the file's recorded `isa` is provenance only
    let isa = ukernel::selected_isa().map_err(anyhow::Error::msg)?;
    let uk = ukernel::kernel_for(isa)
        .ok_or_else(|| anyhow!("selected ISA '{}' has no kernel entry", isa.name()))?;
    let default_layout = uk.weight_layout();

    // v3 tuning sections. `load` is the trust boundary: the embedded DB is
    // bounds-checked record by record before any schedule can steer a
    // prepack or a GEMM.
    let label = path.display().to_string();
    let tuning_db = match header.opt("tuning") {
        Some(tj) => Some(crate::tune::TuningDb::from_json(&label, tj)?),
        None => None,
    };
    let file_isa = header.opt("isa").and_then(|v| v.str().ok()).unwrap_or("").to_string();
    let same_isa = file_isa == isa.name();
    // ISA skew (another machine's file, or DLRT_FORCE_ISA overriding the
    // tuned target): the per-conv schedules were searched — and their
    // weights prepacked — for the file's ISA, so drop them and re-consult
    // the embedded DB for entries tuned for ours. Misses degrade to the
    // kernel's static defaults; never an error, never a mis-prepack.
    let fallback_db = tuning_db.as_ref().filter(|d| !same_isa && d.has_isa(isa));
    let gemm_shapes = match fallback_db {
        Some(_) => crate::exec::planner::conv_gemm_shapes(&graph)?,
        None => Vec::new(),
    };
    if !same_isa && tuning_db.is_some() && fallback_db.is_none() {
        eprintln!("note: {label}: tuned for ISA {file_isa:?} but this host selected '{}'; \
                   using static kernel defaults", isa.name());
    }

    let mut conv_recs: BTreeMap<&str, &Json> = BTreeMap::new();
    if let Json::Obj(convs) = header.get("convs")? {
        for (name, c) in convs {
            conv_recs.insert(name.as_str(), c);
        }
    }
    let mut dense_recs: BTreeMap<&str, &Json> = BTreeMap::new();
    if let Json::Obj(denses) = header.get("denses")? {
        for (name, d) in denses {
            dense_recs.insert(name.as_str(), d);
        }
    }

    // kernel vectors are built by walking the stored topology in node
    // order — the same order the planner assigns `kernel_idx` by, so the
    // re-lowered plan's indices land on the right kernels
    let mut model_convs: Vec<CompiledConv> = Vec::new();
    let mut model_denses: Vec<CompiledDense> = Vec::new();
    for node in &graph.nodes {
        match &node.op {
            Op::Conv2d { .. } => {
                let name = node.name.as_str();
                let c = *conv_recs
                    .get(name)
                    .ok_or_else(|| anyhow!("{name}: conv node has no kernel record"))?;
                let scale = get_f32(payload, c.get("scale")?)?;
                let bias = get_f32(payload, c.get("bias")?)?;
                let engine_str = c.get("engine")?.str()?;
                let sched = if same_isa {
                    match c.opt("sched") {
                        Some(sj) => {
                            let sc = crate::tune::sched_from_json(sj)
                                .and_then(|sc| {
                                    crate::tune::validate_sched(engine_str, isa, &sc)
                                        .map(|()| sc)
                                })
                                .map_err(|e| {
                                    anyhow!("{label}: {name}: bad tuned schedule: {e}")
                                })?;
                            Some(sc)
                        }
                        None => None,
                    }
                } else {
                    fallback_db.and_then(|d| {
                        let sh = gemm_shapes.iter().find(|sh| sh.name == name)?;
                        d.lookup("conv", sh.rows, sh.k, sh.cout, engine_str, isa)
                            .map(|(e, _)| e.sched)
                    })
                };
                let kernel = match engine_str {
                    "bitserial" => {
                        let rows = c.get("rows")?.usize()?;
                        let k = c.get("k")?.usize()?;
                        let bits = c.get("bits")?.usize()?;
                        let wpr = Packed::words_for(k);
                        let layout = match c.get("layout")?.str()? {
                            "row_major" => WLayout::RowMajor,
                            "tile_n" => {
                                let tile_n = c.get("tile_n")?.usize()?;
                                let chunk = c.get("chunk")?.usize()?;
                                if tile_n == 0 || chunk == 0 {
                                    bail!("{name}: tile_n layout with zero geometry");
                                }
                                WLayout::TileN { tile_n, chunk }
                            }
                            other => bail!("{name}: unknown weight layout {other:?}"),
                        };
                        let plane_stride = c.get("plane_stride")?.usize()?;
                        let stride_ok = match layout {
                            WLayout::RowMajor => plane_stride == wpr,
                            WLayout::TileN { chunk, .. } => {
                                wpr.div_ceil(chunk).checked_mul(chunk)
                                    == Some(plane_stride)
                            }
                        };
                        if !stride_ok {
                            bail!(
                                "{name}: plane stride {plane_stride} inconsistent with \
                                 layout (k={k}, {wpr} words/row)"
                            );
                        }
                        let data = get_u64(payload, c.get("planes")?)?;
                        let want = rows
                            .checked_mul(bits)
                            .and_then(|n| n.checked_mul(plane_stride))
                            .ok_or_else(|| anyhow!("{name}: packed plane size overflows"))?;
                        if data.len() != want {
                            bail!(
                                "{name}: packed plane size mismatch: {} words, expected {want}",
                                data.len()
                            );
                        }
                        let mut packed = PackedW {
                            rows,
                            k,
                            bits,
                            words_per_row: wpr,
                            plane_stride,
                            layout,
                            data,
                        };
                        // cross-ISA repack: serialized layout doesn't match
                        // what this host's kernel streams — rebuild once
                        // here (a tuned schedule owns its conv's layout)
                        let want = match &sched {
                            Some(sc) => uk.weight_layout_for(&sc.desc_for(isa)),
                            None => default_layout,
                        };
                        if packed.layout != want {
                            packed = PackedW::from_packed(&packed.to_row_major(), want);
                        }
                        ConvKernel::Bitserial {
                            packed,
                            s_w: c.get("s_w")?.f32()?,
                            s_a: c.get("s_a")?.f32()?,
                            w_bits: c.get("w_bits")?.usize()? as u8,
                            a_bits: c.get("a_bits")?.usize()? as u8,
                        }
                    }
                    "fp32" => ConvKernel::Fp32 { wt: get_f32(payload, c.get("wt")?)? },
                    "int8" => ConvKernel::Int8 {
                        codes: get_i8(payload, c.get("codes")?)?,
                        s_w: c.get("s_w")?.f32()?,
                        s_a: c.get("s_a")?.f32()?,
                    },
                    other => bail!("unknown engine {other:?}"),
                };
                model_convs.push(CompiledConv {
                    name: node.name.clone(),
                    kernel,
                    scale,
                    bias,
                    sched,
                });
            }
            Op::Dense { .. } => {
                let name = node.name.as_str();
                let d = *dense_recs
                    .get(name)
                    .ok_or_else(|| anyhow!("{name}: dense node has no kernel record"))?;
                model_denses.push(CompiledDense {
                    name: node.name.clone(),
                    w: get_f32(payload, d.get("w")?)?,
                    b: get_f32(payload, d.get("b")?)?,
                });
            }
            _ => {}
        }
    }
    // re-lower the execution plan from the stored topology: plans are
    // derived state, so the file format stays engine-only and version-stable
    let model = CompiledModel::new(graph, model_convs, model_denses, isa)?;
    // The planner already verified the plan it built, but load() is the trust
    // boundary for foreign files: run the static checker here so a model whose
    // stored topology lowers to an unsound plan is refused with a diagnostic
    // instead of executing (or panicking) later.
    crate::exec::verify::verify(&model.plan)
        .map_err(|d| anyhow!("{}: rejected by plan verifier — {d}", path.display()))?;
    Ok(model)
}

/// Load a deployable model from either a `.dlrt` file or an exported
/// `arch.json` + `weights.bin` directory (compiled on the spot): the model
/// registry's load-by-path entry point, so operators can point `--models`
/// or the admin load endpoint at whatever artifact they have.
pub fn load_auto(path: &Path) -> Result<CompiledModel> {
    if path.is_dir() {
        let g = crate::compiler::load_arch(path)?;
        crate::compiler::compile_graph(&g, crate::compiler::EngineChoice::Auto)
    } else {
        load(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_graph, EngineChoice};
    use crate::dlrt::tensor::Tensor;
    use crate::exec::Executor;
    use crate::models::tiny_test_graph;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dlrt_test_{}_{name}", std::process::id()))
    }

    #[test]
    fn save_load_roundtrip_preserves_outputs() {
        for engine in [EngineChoice::Auto, EngineChoice::ForceFp32, EngineChoice::ForceInt8] {
            let g = tiny_test_graph(false);
            let m = compile_graph(&g, engine).unwrap();
            let path = tmp(&format!("{engine:?}.dlrt"));
            save(&m, &path).unwrap();
            let m2 = load(&path).unwrap();
            std::fs::remove_file(&path).ok();
            assert_eq!(m.engine_summary(), m2.engine_summary());
            let mut ex = Executor::new(1);
            let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
            for (i, v) in x.data.iter_mut().enumerate() {
                *v = (i % 5) as f32 * 0.1;
            }
            let y1 = ex.run(&m, &x).unwrap();
            let y2 = ex.run(&m2, &x).unwrap();
            assert_eq!(y1[0].data, y2[0].data, "{engine:?}");
        }
    }

    /// A model prepacked for one ISA's tile layout must reload cleanly on a
    /// host that selects another: `load` repacks to the host layout, and the
    /// integer bitserial/int8 kernels keep outputs bit-exact across layouts.
    #[test]
    fn cross_isa_reload_repacks_and_stays_bit_exact() {
        use crate::compiler::compile_graph_for_isa;
        use crate::kernels::ukernel::available_isas;
        let g = tiny_test_graph(false);
        let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = (i % 7) as f32 * 0.09;
        }
        for isa in available_isas() {
            let m = compile_graph_for_isa(&g, EngineChoice::Auto, isa).unwrap();
            let path = tmp(&format!("xisa_{}.dlrt", isa.name()));
            save(&m, &path).unwrap();
            let m2 = load(&path).unwrap();
            std::fs::remove_file(&path).ok();
            let mut ex = Executor::new(1);
            let y1 = ex.run(&m, &x).unwrap();
            let y2 = ex.run(&m2, &x).unwrap();
            assert_eq!(y1[0].data, y2[0].data, "saved under {}", isa.name());
        }
    }

    /// v3 same-ISA roundtrip: tuned schedules and the embedded DB survive
    /// save/load, the loader validates and re-applies them, and outputs
    /// stay bit-identical to the in-memory tuned model.
    #[test]
    fn tuned_roundtrip_applies_schedules_and_stays_bit_exact() {
        use crate::compiler::compile_graph_tuned;
        let g = tiny_test_graph(false);
        let isa = ukernel::selected_isa().unwrap();
        let db = crate::tune::synthetic_db(&g, isa).unwrap();
        let m = compile_graph_tuned(&g, EngineChoice::Auto, isa, Some(&db)).unwrap();
        assert!(m.convs.iter().all(|c| c.sched.is_some()));
        let path = tmp("tuned.dlrt");
        save_with(&m, Some(&db), &path).unwrap();
        let m2 = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        for (a, b) in m.convs.iter().zip(&m2.convs) {
            assert_eq!(a.sched, b.sched, "{}", a.name);
        }
        let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = (i % 5) as f32 * 0.1;
        }
        let mut ex = Executor::new(2);
        let y1 = ex.run(&m, &x).unwrap();
        let y2 = ex.run(&m2, &x).unwrap();
        assert_eq!(y1[0].data, y2[0].data);
    }

    /// A `.dlrt` tuned (and prepacked) for one ISA must load on a host that
    /// selects another without error or mis-prepack: per-conv schedules are
    /// dropped, the embedded DB is re-consulted for the host's ISA, and
    /// misses fall back to static defaults. Swept over every available ISA,
    /// so the selected one exercises the apply direction and every other
    /// one the fallback direction.
    #[test]
    fn cross_isa_tuned_roundtrip_falls_back_cleanly() {
        use crate::compiler::compile_graph_tuned;
        use crate::kernels::ukernel::available_isas;
        let g = tiny_test_graph(false);
        let host = ukernel::selected_isa().unwrap();
        let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = (i % 7) as f32 * 0.09;
        }
        for isa in available_isas() {
            let db = crate::tune::synthetic_db(&g, isa).unwrap();
            let m = compile_graph_tuned(&g, EngineChoice::Auto, isa, Some(&db)).unwrap();
            let path = tmp(&format!("xtuned_{}.dlrt", isa.name()));
            save_with(&m, Some(&db), &path).unwrap();
            let m2 = load(&path).unwrap();
            std::fs::remove_file(&path).ok();
            assert_eq!(m2.isa, host);
            if isa == host {
                assert!(m2.convs.iter().all(|c| c.sched.is_some()));
            } else {
                // the embedded DB only holds entries tuned for the saving
                // ISA — this host must degrade to defaults, not error
                assert!(m2.convs.iter().all(|c| c.sched.is_none()));
            }
            let mut ex = Executor::new(1);
            let y1 = ex.run(&m, &x).unwrap();
            let y2 = ex.run(&m2, &x).unwrap();
            assert_eq!(y1[0].data, y2[0].data, "saved tuned under {}", isa.name());
        }
    }

    /// Version-2 files (pre-tuning) still load: both v3 sections are
    /// optional, so a sched-free v3 body is bytewise a valid v2 body.
    #[test]
    fn loads_version2_files() {
        use crate::compiler::compile_graph_tuned;
        let g = tiny_test_graph(false);
        let isa = ukernel::selected_isa().unwrap();
        let m = compile_graph_tuned(&g, EngineChoice::Auto, isa, None).unwrap();
        let path = tmp("v2.dlrt");
        save_with(&m, None, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&2u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let m2 = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(m.engine_summary(), m2.engine_summary());
    }

    /// Patch the header JSON in place (same-length substitution keeps the
    /// binary framing intact) to simulate a hostile/corrupt tuning record.
    fn corrupt_header(path: &Path, from: &str, to: &str) {
        assert_eq!(from.len(), to.len());
        let mut bytes = std::fs::read(path).unwrap();
        let hlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let hdr = std::str::from_utf8(&bytes[16..16 + hlen]).unwrap();
        let patched = hdr.replacen(from, to, 1);
        assert_ne!(patched, hdr, "pattern {from:?} not found in header");
        bytes[16..16 + hlen].copy_from_slice(patched.as_bytes());
        std::fs::write(path, bytes).unwrap();
    }

    /// Untrusted tuning-DB records must be refused at load with a
    /// path-prefixed diagnostic — zero tile geometry, bogus staging tags
    /// and a bad DB version all reject instead of mis-prepacking
    /// (alongside the existing bad-magic / bad-version / truncation cases).
    #[test]
    fn rejects_corrupt_tuning_records() {
        use crate::compiler::compile_graph_tuned;
        let g = tiny_test_graph(false);
        let isa = ukernel::selected_isa().unwrap();
        let db = crate::tune::synthetic_db(&g, isa).unwrap();
        // compile untuned so the only tuning bytes in the file are the
        // embedded DB section itself
        let plain = compile_graph_tuned(&g, EngineChoice::Auto, isa, None).unwrap();
        for (what, from, to) in [
            ("zero tile_m", "\"tile_m\":5", "\"tile_m\":0"),
            ("bad staging", "\"staging\":\"gather\"", "\"staging\":\"gathxr\""),
            ("bad DB version", "\"version\":1", "\"version\":9"),
        ] {
            let path = tmp(&format!("baddb_{}.dlrt", what.replace(' ', "_")));
            save_with(&plain, Some(&db), &path).unwrap();
            corrupt_header(&path, from, to);
            let err = load(&path).unwrap_err().to_string();
            std::fs::remove_file(&path).ok();
            assert!(err.contains(&path.display().to_string()),
                    "{what}: diagnostic not path-prefixed: {err}");
        }
    }

    /// A corrupt per-conv `sched` record (as opposed to the DB section) is
    /// likewise refused with a path-prefixed diagnostic naming the conv.
    #[test]
    fn rejects_corrupt_per_conv_schedule() {
        use crate::compiler::compile_graph_tuned;
        let g = tiny_test_graph(false);
        let isa = ukernel::selected_isa().unwrap();
        let db = crate::tune::synthetic_db(&g, isa).unwrap();
        let m = compile_graph_tuned(&g, EngineChoice::Auto, isa, Some(&db)).unwrap();
        let path = tmp("badsched.dlrt");
        // no embedded DB: the only "tile_m" bytes are per-conv scheds
        save_with(&m, None, &path).unwrap();
        corrupt_header(&path, "\"tile_m\":5", "\"tile_m\":0");
        let err = load(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("bad tuned schedule") && err.contains("tile_m")
                    && err.contains(&path.display().to_string()),
                "unexpected error: {err}");
    }

    #[test]
    fn rejects_corrupt_files() {
        let path = tmp("corrupt.dlrt");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, b"DLRT\x63\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00").unwrap();
        assert!(load(&path).is_err()); // bad version
        std::fs::remove_file(&path).ok();
    }

    /// A file whose payload is cut short must come back as a diagnostic
    /// error, never an out-of-bounds panic: every blob read is range-checked.
    #[test]
    fn truncated_payload_is_a_diagnostic_error_not_a_panic() {
        let g = tiny_test_graph(false);
        let m = compile_graph(&g, EngineChoice::Auto).unwrap();
        let path = tmp("truncated.dlrt");
        save(&m, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // cut the payload in half, leaving the header intact: the JSON still
        // parses, so the failure must land in checked blob resolution
        let hlen = u64::from_le_bytes(full[8..16].try_into().unwrap()) as usize;
        let payload_len = full.len() - 16 - hlen;
        std::fs::write(&path, &full[..16 + hlen + payload_len / 2]).unwrap();
        let err = load(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("blob out of bounds"), "unexpected error: {err}");
    }

    /// A hostile header length (here u64::MAX) must not wrap the `16 + hlen`
    /// arithmetic in release builds and read from a bogus offset.
    #[test]
    fn absurd_header_length_is_rejected() {
        let path = tmp("hugehdr.dlrt");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(b"{}");
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(
            err.contains("overflow") || err.contains("truncated header") || err.contains("usize"),
            "unexpected error: {err}"
        );
    }

    /// A header whose graph declares a non-rank-4 input shape must be refused
    /// in `graph_from_json`, not panic on the `[shape[0], .., shape[3]]` index.
    #[test]
    fn non_rank4_input_shape_is_rejected() {
        let path = tmp("rank2.dlrt");
        let header = r#"{"graph":{"name":"x","input":{"name":"i","shape":[1,8]},"outputs":["i"],"nodes":[]},"convs":{},"denses":{}}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("rank 4"), "unexpected error: {err}");
    }

    #[test]
    fn graph_json_roundtrip() {
        let g = tiny_test_graph(false);
        let j = graph_to_json(&g);
        let g2 = graph_from_json(&j).unwrap();
        assert_eq!(g.name, g2.name);
        assert_eq!(g.nodes.len(), g2.nodes.len());
        for (a, b) in g.nodes.iter().zip(&g2.nodes) {
            assert_eq!(a.op, b.op, "{}", a.name);
            assert_eq!(a.inputs, b.inputs);
        }
    }
}

//! Analytical Arm CPU cost model — projects layer latencies for the
//! paper's target platforms (we have no Arm hardware; DESIGN.md §2).
//!
//! Per conv layer the model computes compute-bound and memory-bound times
//! and takes the max (simple roofline):
//!
//! * FP32:  `t = MACs / (fp32_macs_per_cycle · f · cores·eff)`
//! * INT8:  `t = MACs / (int8_macs_per_cycle · f · cores·eff)`
//! * bitserial: word-ops = `rows · cout · ⌈k/64⌉ · w_bits · a_bits`
//!   each word-op = AND + CNT + accumulate on the Neon pipe;
//!   `t = word_ops / (bitops_per_cycle · f · cores·eff)`
//!   plus the im2col+quantize pass: `rows · k` byte ops on the scalar pipe.
//!
//! Constants are derived from published microarchitecture numbers (see
//! [`params`]) and sanity-checked against the paper's measured ratios
//! (ResNet18 on A53: 2.9× @2A2W, 4.4× @1A1W vs FP32 — §V).

pub mod params;

use crate::dlrt::graph::{Graph, Op, QCfg};
use crate::kernels::ukernel::{self, UKernelDesc};
pub use params::{cpu_by_name, CpuParams, CORTEX_A53, CORTEX_A57, CORTEX_A72,
                 JETSON_NANO_GPU};

/// Which engine a layer runs on, for costing purposes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Fp32,
    Int8,
    Bitserial { w_bits: u8, a_bits: u8 },
}

impl EngineKind {
    /// Short stable tag for tables and JSON: `fp32`, `int8`, `w2a2`-style
    /// bitserial precisions.
    pub fn label(self) -> String {
        match self {
            EngineKind::Fp32 => "fp32".to_string(),
            EngineKind::Int8 => "int8".to_string(),
            EngineKind::Bitserial { w_bits, a_bits } => format!("w{w_bits}a{a_bits}"),
        }
    }
}

/// Cost of one conv layer on `cpu`, in seconds, under the host-selected
/// micro-kernel's default tile geometry.
pub fn conv_cost_s(
    cpu: &CpuParams,
    rows: usize,   // N*OH*OW output pixels
    k: usize,      // patch = kh*kw*cin
    cout: usize,
    engine: EngineKind,
    threads: usize,
) -> f64 {
    conv_cost_s_for(cpu, &host_kernel_desc(), rows, k, cout, engine, threads)
}

/// Cost of one conv layer on `cpu` under an explicit tile geometry — the
/// schedule-search prior for `dlrt tune`, which ranks candidate
/// `UKernelDesc` overrides by this projection before benchmarking the top
/// of the ranking on the actual machine.
#[allow(clippy::too_many_arguments)]
pub fn conv_cost_s_for(
    cpu: &CpuParams,
    desc: &UKernelDesc,
    rows: usize,
    k: usize,
    cout: usize,
    engine: EngineKind,
    threads: usize,
) -> f64 {
    let eff_cores = effective_cores(cpu, threads);
    let hz = cpu.freq_ghz * 1e9;
    let macs = (rows * k * cout) as f64;
    let compute = match engine {
        EngineKind::Fp32 => macs / (cpu.fp32_macs_per_cycle * hz * eff_cores),
        EngineKind::Int8 => {
            let gemm = macs / (cpu.int8_macs_per_cycle * hz * eff_cores);
            // quantize pass over the patch matrix
            let quant = (rows * k) as f64 / (cpu.bytes_per_cycle_scalar * hz * eff_cores);
            gemm + quant
        }
        EngineKind::Bitserial { w_bits, a_bits } => {
            let words = k.div_ceil(64) as f64;
            let word_ops = rows as f64 * cout as f64 * words
                * (w_bits as f64 * a_bits as f64 + 0.5 /* row-sum correction */);
            // The blocked kernel refetches each weight-plane word once per
            // M-tile and each activation word once per N-tile; everything
            // else stays cache/register resident, so the amortized reload
            // overhead per word-op follows the tile geometry being costed.
            let tile_reload =
                1.0 + 1.0 / desc.tile_m.max(1) as f64 + 1.0 / desc.tile_n.max(1) as f64;
            let gemm = word_ops * tile_reload / (cpu.bitops_per_cycle * hz * eff_cores);
            // im2col + quantize + pack: ~3 passes over rows*k bytes
            let pack = 3.0 * (rows * k) as f64
                / (cpu.bytes_per_cycle_scalar * hz * eff_cores);
            gemm + pack
        }
    };
    // memory floor: stream weights + write outputs once
    let weight_bytes = match engine {
        EngineKind::Fp32 => (k * cout * 4) as f64,
        EngineKind::Int8 => (k * cout) as f64,
        EngineKind::Bitserial { w_bits, .. } => {
            (k.div_ceil(64) * 8 * w_bits as usize * cout) as f64
        }
    };
    let mem = (weight_bytes + (rows * cout * 4) as f64) / (cpu.mem_gbps * 1e9);
    compute.max(mem)
}

/// Tile geometry of the micro-kernel the host's ISA dispatch selects;
/// falls back to the scalar kernel when the override env var is invalid
/// (projections must never hard-fail on a bad `DLRT_FORCE_ISA`).
fn host_kernel_desc() -> UKernelDesc {
    ukernel::selected_isa()
        .ok()
        .and_then(ukernel::kernel_for)
        .or_else(|| ukernel::kernel_for(ukernel::Isa::Scalar))
        .map(|u| u.desc)
        .expect("scalar kernel is always registered")
}

fn effective_cores(cpu: &CpuParams, threads: usize) -> f64 {
    let t = threads.clamp(1, cpu.cores) as f64;
    // sub-linear thread scaling (shared L2 + DRAM): eff = t^alpha
    t.powf(cpu.parallel_alpha)
}

/// Engine per conv implied by its QCfg under the given policy.
fn engine_of(qcfg: QCfg, force: Option<EngineKind>) -> EngineKind {
    if let Some(e) = force {
        return e;
    }
    if qcfg.enabled {
        EngineKind::Bitserial { w_bits: qcfg.w_bits, a_bits: qcfg.a_bits }
    } else {
        EngineKind::Fp32
    }
}

/// Whole-graph latency projection in milliseconds.
///
/// `force`: cost every conv on one engine (baseline projections); `None`
/// follows the graph's mixed-precision QCfg (FP32 layers stay FP32).
/// Non-conv ops are costed as one memory pass over their output.
pub fn graph_latency_ms(
    g: &Graph,
    cpu: &CpuParams,
    force: Option<EngineKind>,
    threads: usize,
) -> anyhow::Result<f64> {
    let shapes = g.infer_shapes()?;
    let mut total = 0.0f64;
    for n in &g.nodes {
        match &n.op {
            Op::Conv2d { kernel, cin, cout, qcfg, .. } => {
                let os = &shapes[&n.output];
                let rows = os[0] * os[1] * os[2];
                let k = kernel[0] * kernel[1] * cin;
                total += conv_cost_s(cpu, rows, k, *cout, engine_of(*qcfg, force), threads);
            }
            _ => {
                let numel: usize = shapes[&n.output].iter().product();
                total += (numel * 4) as f64 / (cpu.mem_gbps * 1e9);
            }
        }
    }
    Ok(total * 1e3)
}

/// GPU projection for the paper's Jetson Nano bar (Fig. 7): a flat
/// utilization fraction of peak FMA throughput + a fixed launch overhead.
pub fn gpu_latency_ms(g: &Graph, gpu: &params::GpuParams) -> anyhow::Result<f64> {
    let macs = g.conv_macs()? as f64;
    Ok((macs / (gpu.peak_mac_per_s * gpu.utilization) + gpu.overhead_s) * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrt::graph::QCfg;
    use crate::models::build_resnet;

    #[test]
    fn engine_labels_are_stable() {
        assert_eq!(EngineKind::Fp32.label(), "fp32");
        assert_eq!(EngineKind::Int8.label(), "int8");
        assert_eq!(EngineKind::Bitserial { w_bits: 2, a_bits: 1 }.label(), "w2a1");
    }

    #[test]
    fn bitserial_speedup_matches_paper_band_a53() {
        // Paper §V: ResNet18 on Cortex-A53, 4 threads: 2.9x @ 2-bit and
        // 4.4x @ 1-bit over the optimized FP32 baseline. Like the paper we
        // keep the stem FP32 (mixed precision); accept a generous band —
        // the claim being reproduced is "2-3x at 2 bits, 4-6x at 1 bit,
        // 1-bit > 2-bit" (DESIGN.md §6, §V row).
        let g2 = build_resnet(18, 1000, 224, 1.0, QCfg::new(2, 2), 0);
        let g1 = build_resnet(18, 1000, 224, 1.0, QCfg::new(1, 1), 0);
        let fp32 = graph_latency_ms(&g2, &CORTEX_A53, Some(EngineKind::Fp32), 4).unwrap();
        let b2 = graph_latency_ms(&g2, &CORTEX_A53, None, 4).unwrap();
        let b1 = graph_latency_ms(&g1, &CORTEX_A53, None, 4).unwrap();
        let s2 = fp32 / b2;
        let s1 = fp32 / b1;
        assert!((2.0..4.0).contains(&s2), "2-bit speedup {s2:.2} (paper 2.9)");
        assert!((3.2..6.5).contains(&s1), "1-bit speedup {s1:.2} (paper 4.4)");
        assert!(s1 > s2);
    }

    #[test]
    fn absolute_fp32_latency_plausible_a72() {
        // Public ResNet18/224 FP32 benchmarks on RPi 4B land in the few-
        // hundred-ms band; the model should project inside [80, 900] ms.
        let g = build_resnet(18, 1000, 224, 1.0, QCfg::FP32, 0);
        let ms = graph_latency_ms(&g, &CORTEX_A72, Some(EngineKind::Fp32), 4).unwrap();
        assert!((80.0..900.0).contains(&ms), "A72 fp32 projection {ms:.1} ms");
    }

    #[test]
    fn a72_faster_than_a53() {
        let g = build_resnet(18, 1000, 224, 1.0, QCfg::new(2, 2), 0);
        let a53 = graph_latency_ms(&g, &CORTEX_A53, None, 4).unwrap();
        let a72 = graph_latency_ms(&g, &CORTEX_A72, None, 4).unwrap();
        assert!(a72 < a53);
    }

    #[test]
    fn int8_between_fp32_and_2bit() {
        let g = build_resnet(18, 1000, 224, 1.0, QCfg::new(2, 2), 0);
        let fp32 = graph_latency_ms(&g, &CORTEX_A72, Some(EngineKind::Fp32), 4).unwrap();
        let int8 = graph_latency_ms(&g, &CORTEX_A72, Some(EngineKind::Int8), 4).unwrap();
        let b2 = graph_latency_ms(
            &g, &CORTEX_A72, Some(EngineKind::Bitserial { w_bits: 2, a_bits: 2 }), 4,
        ).unwrap();
        assert!(int8 < fp32, "{int8} !< {fp32}");
        assert!(b2 < int8, "{b2} !< {int8}");
    }

    #[test]
    fn threads_scale_sublinearly() {
        let g = build_resnet(18, 1000, 224, 1.0, QCfg::FP32, 0);
        let t1 = graph_latency_ms(&g, &CORTEX_A53, Some(EngineKind::Fp32), 1).unwrap();
        let t4 = graph_latency_ms(&g, &CORTEX_A53, Some(EngineKind::Fp32), 4).unwrap();
        let speedup = t1 / t4;
        assert!(speedup > 2.0 && speedup < 4.0, "4-thread speedup {speedup:.2}");
    }

    #[test]
    fn dlrt_approaches_gpu_latency_on_nano() {
        // Fig. 7's headline: "DLRT is only ~50% slower than the embedded
        // GPU". Require the projection to land in the same ballpark
        // (0.5x–3x of the GPU bar).
        let g = build_resnet(18, 1000, 224, 1.0, QCfg::new(2, 2), 0);
        let gpu = gpu_latency_ms(&g, &JETSON_NANO_GPU).unwrap();
        let b2 = graph_latency_ms(&g, &CORTEX_A57, None, 4).unwrap();
        let ratio = b2 / gpu;
        assert!((0.5..3.0).contains(&ratio),
                "DLRT/GPU ratio {ratio:.2} outside the paper's ballpark");
    }
}

//! Microarchitecture parameters for the paper's target platforms.
//!
//! Derivations (per-core, per-cycle throughputs):
//!
//! **Cortex-A53** (RPi 3B+, 1.4 GHz, in-order 2-wide, 64-bit Neon datapath):
//! * FP32: one 2-lane FMA / cycle sustained on the single Neon pipe, but
//!   in-order issue + load pressure: measured GEMMs on A53 sustain ~1
//!   MAC/cycle (≈25% of the 2-lane FMA peak).
//! * INT8: SMLAL-style 8-lane widening MAC every other cycle → ~2/cycle sustained.
//! * bitserial word-op (64-bit AND + CNT + ADD ≈ 3 Neon µops on the
//!   64-bit datapath, plus load + horizontal-add amortization) →
//!   ~0.22 word-ops/cycle sustained, calibrated so the projected
//!   ResNet18 speedups land on the paper's §V numbers (2.9x @2bit).
//!
//! **Cortex-A72** (RPi 4B, 1.5 GHz, OoO 3-wide, 2×128-bit Neon pipes):
//! * FP32: 2×4-lane FMA/cycle peak; sustained GEMM ~2.5 MAC/cycle (XNNPACK-class).
//! * INT8: ~4.5 MAC/cycle (SMLAL chains; A72 predates the SDOT extension).
//! * bitserial: 128-bit AND+CNT+ADD dual-issued → ~0.6 64-bit word-ops
//!   /cycle sustained (two words per 128-bit op, ~60% pipe utilization).
//!
//! **Cortex-A57** (Jetson Nano, 1.43 GHz): A72-class OoO core, slightly
//! lower sustained throughputs.
//!
//! Memory: RPi3 LPDDR2 ~2.5 GB/s effective; RPi4 LPDDR4 ~4.5 GB/s;
//! Nano LPDDR4 ~6 GB/s (shared with GPU).
//!
//! `parallel_alpha`: threads scale as t^alpha (shared L2/DRAM on all three).

/// Per-CPU analytical model constants.
#[derive(Clone, Copy, Debug)]
pub struct CpuParams {
    pub name: &'static str,
    pub freq_ghz: f64,
    pub cores: usize,
    /// sustained fp32 MACs / cycle / core in blocked GEMM
    pub fp32_macs_per_cycle: f64,
    /// sustained int8 MACs / cycle / core (widening vector MAC)
    pub int8_macs_per_cycle: f64,
    /// sustained 64-bit AND+POPCOUNT+accumulate word-ops / cycle / core
    pub bitops_per_cycle: f64,
    /// scalar-side byte throughput (quantize/pack passes)
    pub bytes_per_cycle_scalar: f64,
    /// effective DRAM bandwidth, GB/s
    pub mem_gbps: f64,
    /// thread scaling exponent: speedup(t) = t^alpha
    pub parallel_alpha: f64,
}

pub const CORTEX_A53: CpuParams = CpuParams {
    name: "Cortex-A53 (RPi 3B+)",
    freq_ghz: 1.4,
    cores: 4,
    fp32_macs_per_cycle: 1.0,
    int8_macs_per_cycle: 2.0,
    bitops_per_cycle: 0.22,
    bytes_per_cycle_scalar: 1.5,
    mem_gbps: 2.5,
    parallel_alpha: 0.85,
};

pub const CORTEX_A72: CpuParams = CpuParams {
    name: "Cortex-A72 (RPi 4B)",
    freq_ghz: 1.5,
    cores: 4,
    fp32_macs_per_cycle: 2.5,
    int8_macs_per_cycle: 4.5,
    bitops_per_cycle: 0.60,
    bytes_per_cycle_scalar: 3.0,
    mem_gbps: 4.5,
    parallel_alpha: 0.88,
};

pub const CORTEX_A57: CpuParams = CpuParams {
    name: "Cortex-A57 (Jetson Nano)",
    freq_ghz: 1.43,
    cores: 4,
    fp32_macs_per_cycle: 2.2,
    int8_macs_per_cycle: 4.0,
    bitops_per_cycle: 0.55,
    bytes_per_cycle_scalar: 3.0,
    mem_gbps: 6.0,
    parallel_alpha: 0.88,
};

/// Embedded GPU projection (Fig. 7's Jetson Nano GPU bar).
#[derive(Clone, Copy, Debug)]
pub struct GpuParams {
    pub name: &'static str,
    /// peak MAC/s (Nano: 128 CUDA cores × 0.92 GHz × 1 FMA = 118 GMAC/s)
    pub peak_mac_per_s: f64,
    /// sustained fraction of peak for conv workloads
    pub utilization: f64,
    /// kernel-launch + sync overhead per inference
    pub overhead_s: f64,
}

pub const JETSON_NANO_GPU: GpuParams = GpuParams {
    name: "Jetson Nano GPU (Maxwell 128c)",
    peak_mac_per_s: 118e9,
    utilization: 0.45,
    overhead_s: 3e-3,
};

/// Look up a CPU by CLI name.
pub fn cpu_by_name(name: &str) -> Option<&'static CpuParams> {
    match name {
        "a53" | "rpi3" => Some(&CORTEX_A53),
        "a72" | "rpi4" => Some(&CORTEX_A72),
        "a57" | "nano" => Some(&CORTEX_A57),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert_eq!(cpu_by_name("a53").unwrap().name, CORTEX_A53.name);
        assert_eq!(cpu_by_name("rpi4").unwrap().cores, 4);
        assert!(cpu_by_name("m1").is_none());
    }

    #[test]
    fn ordering_sane() {
        assert!(CORTEX_A72.fp32_macs_per_cycle > CORTEX_A53.fp32_macs_per_cycle);
        assert!(CORTEX_A72.bitops_per_cycle > CORTEX_A53.bitops_per_cycle);
        for p in [CORTEX_A53, CORTEX_A72, CORTEX_A57] {
            assert!(p.int8_macs_per_cycle > p.fp32_macs_per_cycle);
            assert!((0.5..1.0).contains(&p.parallel_alpha));
        }
    }
}

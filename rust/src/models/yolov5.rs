//! YOLOv5 n/s/m native builders (mirror of python/compile/models/yolov5.py).

use crate::dlrt::graph::{Graph, Op, QCfg};

use super::GraphBuilder;

pub const NUM_ANCHORS: usize = 3;

fn variant_params(variant: &str) -> (f32, f32) {
    match variant {
        "n" => (0.33, 0.25),
        "s" => (0.33, 0.50),
        "m" => (0.67, 0.75),
        _ => panic!("unknown yolov5 variant {variant}"),
    }
}

fn depth(n: usize, dm: f32) -> usize {
    ((n as f32 * dm).round() as usize).max(1)
}

fn width(c: usize, wm: f32) -> usize {
    (((c as f32 * wm) / 8.0).ceil() as usize * 8).max(8)
}

fn cbs(b: &mut GraphBuilder, x: &str, c: usize, k: usize, s: usize, name: &str,
       q: QCfg) -> String {
    b.conv_named(name, x, c, k, s, k / 2, q, Some(Op::Silu))
}

fn bottleneck(b: &mut GraphBuilder, x: &str, c: usize, shortcut: bool, name: &str,
              q: QCfg) -> String {
    let y = cbs(b, x, c, 1, 1, &format!("{name}.cv1"), q);
    let y = cbs(b, &y, c, 3, 1, &format!("{name}.cv2"), q);
    if shortcut && b.channels(x) == c {
        b.add(&y, x)
    } else {
        y
    }
}

fn c3(b: &mut GraphBuilder, x: &str, cout: usize, n: usize, shortcut: bool,
      name: &str, q: QCfg) -> String {
    let ch = cout / 2;
    let mut y1 = cbs(b, x, ch, 1, 1, &format!("{name}.cv1"), q);
    for i in 0..n {
        y1 = bottleneck(b, &y1, ch, shortcut, &format!("{name}.m{i}"), q);
    }
    let y2 = cbs(b, x, ch, 1, 1, &format!("{name}.cv2"), q);
    let y = b.concat(&[&y1, &y2]);
    cbs(b, &y, cout, 1, 1, &format!("{name}.cv3"), q)
}

fn sppf(b: &mut GraphBuilder, x: &str, cout: usize, name: &str, q: QCfg) -> String {
    let ch = b.channels(x) / 2;
    let y = cbs(b, x, ch, 1, 1, &format!("{name}.cv1"), q);
    let p1 = b.maxpool(&y, 5, 1, 2);
    let p2 = b.maxpool(&p1, 5, 1, 2);
    let p3 = b.maxpool(&p2, 5, 1, 2);
    let cat = b.concat(&[&y, &p1, &p2, &p3]);
    cbs(b, &cat, cout, 1, 1, &format!("{name}.cv2"), q)
}

pub fn build_yolov5(variant: &str, num_classes: usize, resolution: usize,
                    width_mult: f32, qcfg: QCfg, seed: u64) -> Graph {
    let (dm, wm) = variant_params(variant);
    let wm = wm * width_mult;
    let cw = |c: usize| width(c, wm);
    let mut b = GraphBuilder::new(&format!("yolov5{variant}"),
                                  [1, resolution, resolution, 3], seed);

    // backbone (stem FP32: conservative mixed precision)
    let x = b.conv_named("b0", "input", cw(64), 6, 2, 2, QCfg::FP32, Some(Op::Silu));
    let x = cbs(&mut b, &x, cw(128), 3, 2, "b1", qcfg);
    let x = c3(&mut b, &x, cw(128), depth(3, dm), true, "b2", qcfg);
    let x = cbs(&mut b, &x, cw(256), 3, 2, "b3", qcfg);
    let p3 = c3(&mut b, &x, cw(256), depth(6, dm), true, "b4", qcfg);
    let x = cbs(&mut b, &p3, cw(512), 3, 2, "b5", qcfg);
    let p4 = c3(&mut b, &x, cw(512), depth(9, dm), true, "b6", qcfg);
    let x = cbs(&mut b, &p4, cw(1024), 3, 2, "b7", qcfg);
    let x = c3(&mut b, &x, cw(1024), depth(3, dm), true, "b8", qcfg);
    let p5 = sppf(&mut b, &x, cw(1024), "b9", qcfg);

    // PANet neck
    let h10 = cbs(&mut b, &p5, cw(512), 1, 1, "n10", qcfg);
    let up = b.upsample2x(&h10);
    let x = b.concat(&[&up, &p4]);
    let h13 = c3(&mut b, &x, cw(512), depth(3, dm), false, "n13", qcfg);
    let h14 = cbs(&mut b, &h13, cw(256), 1, 1, "n14", qcfg);
    let up = b.upsample2x(&h14);
    let x = b.concat(&[&up, &p3]);
    let d17 = c3(&mut b, &x, cw(256), depth(3, dm), false, "n17", qcfg);
    let x = cbs(&mut b, &d17, cw(256), 3, 2, "n18", qcfg);
    let x = b.concat(&[&x, &h14]);
    let d20 = c3(&mut b, &x, cw(512), depth(3, dm), false, "n20", qcfg);
    let x = cbs(&mut b, &d20, cw(512), 3, 2, "n21", qcfg);
    let x = b.concat(&[&x, &h10]);
    let d23 = c3(&mut b, &x, cw(1024), depth(3, dm), false, "n23", qcfg);

    // Detect heads: raw maps, FP32 (detection-sensitive)
    let no = NUM_ANCHORS * (5 + num_classes);
    let o1 = b.conv_named("detect.p3", &d17, no, 1, 1, 0, QCfg::FP32, None);
    let o2 = b.conv_named("detect.p4", &d20, no, 1, 1, 0, QCfg::FP32, None);
    let o3 = b.conv_named("detect.p5", &d23, no, 1, 1, 0, QCfg::FP32, None);
    b.finish(vec![o1, o2, o3])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_shapes_and_scaling() {
        let g = build_yolov5("n", 80, 320, 1.0, QCfg::new(2, 2), 0);
        let shapes = g.infer_shapes().unwrap();
        let no = 3 * 85;
        assert_eq!(shapes["detect.p3.out"], vec![1, 40, 40, no]);
        assert_eq!(shapes["detect.p4.out"], vec![1, 20, 20, no]);
        assert_eq!(shapes["detect.p5.out"], vec![1, 10, 10, no]);
    }

    #[test]
    fn variant_macs_ordering() {
        let macs = |v: &str| {
            build_yolov5(v, 80, 320, 1.0, QCfg::FP32, 0).conv_macs().unwrap()
        };
        let (n, s, m) = (macs("n"), macs("s"), macs("m"));
        assert!(n < s && s < m, "{n} {s} {m}");
        // yolov5n at 640 is ~4.5 GFLOPs → ~2.2 GMACs/4 at 320 ≈ 0.5-0.6 GMAC
        assert!((3.0e8..8.0e8).contains(&(n as f64)), "n = {n}");
    }

    #[test]
    fn quantized_fraction_dominates() {
        // >80% of convs are quantized under the default policy
        let g = build_yolov5("s", 8, 128, 1.0, QCfg::new(2, 2), 0);
        let total = g.conv_nodes().count();
        let quant = g
            .conv_nodes()
            .filter(|n| matches!(n.op, Op::Conv2d { qcfg, .. } if qcfg.enabled))
            .count();
        assert!(quant * 5 >= total * 4, "{quant}/{total}");
    }
}

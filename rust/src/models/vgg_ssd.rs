//! VGG16-SSD300 native builder (mirror of python/compile/models/vgg_ssd.py).

use crate::dlrt::graph::{Graph, Op, QCfg};

use super::GraphBuilder;

fn ch(c: usize, wm: f32) -> usize {
    ((c as f32 * wm).round() as usize).max(8)
}

/// (feature tag, anchors per cell) — canonical SSD300 head spec → 8732 boxes.
pub const HEAD_SPEC: [(&str, usize); 6] = [
    ("conv4_3", 4),
    ("fc7", 6),
    ("conv8_2", 6),
    ("conv9_2", 6),
    ("conv10_2", 4),
    ("conv11_2", 4),
];

pub fn build_vgg16_ssd(num_classes: usize, resolution: usize, width_mult: f32,
                       qcfg: QCfg, seed: u64) -> Graph {
    let mut b = GraphBuilder::new("vgg16_ssd", [1, resolution, resolution, 3], seed);
    let mut feats: std::collections::BTreeMap<&str, String> = Default::default();

    let mut x = "input".to_string();
    let stages: [(usize, usize); 5] = [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)];
    for (si, (cnt, c)) in stages.iter().enumerate() {
        for ci in 0..*cnt {
            // first conv stays FP32 (conservative mixed precision)
            let q = if si == 0 && ci == 0 { QCfg::FP32 } else { qcfg };
            x = b.conv_named(&format!("conv{}_{}", si + 1, ci + 1), &x,
                             ch(*c, width_mult), 3, 1, 1, q, Some(Op::Relu));
            if si == 3 && ci == cnt - 1 {
                feats.insert("conv4_3", x.clone());
            }
        }
        if si < 4 {
            let pad = if si == 2 { 1 } else { 0 }; // ceil-mode pool3: 75 -> 38
            x = b.maxpool(&x, 2, 2, pad);
        } else {
            x = b.maxpool(&x, 3, 1, 1);
        }
    }
    x = b.conv_named("fc6", &x, ch(1024, width_mult), 3, 1, 1, qcfg, Some(Op::Relu));
    x = b.conv_named("fc7", &x, ch(1024, width_mult), 1, 1, 0, qcfg, Some(Op::Relu));
    feats.insert("fc7", x.clone());
    x = b.conv_named("conv8_1", &x, ch(256, width_mult), 1, 1, 0, qcfg, Some(Op::Relu));
    x = b.conv_named("conv8_2", &x, ch(512, width_mult), 3, 2, 1, qcfg, Some(Op::Relu));
    feats.insert("conv8_2", x.clone());
    x = b.conv_named("conv9_1", &x, ch(128, width_mult), 1, 1, 0, qcfg, Some(Op::Relu));
    x = b.conv_named("conv9_2", &x, ch(256, width_mult), 3, 2, 1, qcfg, Some(Op::Relu));
    feats.insert("conv9_2", x.clone());
    x = b.conv_named("conv10_1", &x, ch(128, width_mult), 1, 1, 0, qcfg, Some(Op::Relu));
    x = b.conv_named("conv10_2", &x, ch(256, width_mult), 3, 1, 0, qcfg, Some(Op::Relu));
    feats.insert("conv10_2", x.clone());
    x = b.conv_named("conv11_1", &x, ch(128, width_mult), 1, 1, 0, qcfg, Some(Op::Relu));
    x = b.conv_named("conv11_2", &x, ch(256, width_mult), 3, 1, 0, qcfg, Some(Op::Relu));
    feats.insert("conv11_2", x.clone());

    let mut outputs = Vec::new();
    for (tag, anchors) in HEAD_SPEC {
        let f = feats[tag].clone();
        // heads stay FP32 (detection-sensitive, cf. paper mixed precision)
        outputs.push(b.conv_named(&format!("{tag}.loc"), &f, anchors * 4, 3, 1, 1,
                                  QCfg::FP32, None));
        outputs.push(b.conv_named(&format!("{tag}.conf"), &f, anchors * num_classes,
                                  3, 1, 1, QCfg::FP32, None));
    }
    b.finish(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ssd300_box_count() {
        let g = build_vgg16_ssd(21, 300, 0.25, QCfg::new(2, 2), 0);
        let shapes = g.infer_shapes().unwrap();
        let grids = [38usize, 19, 10, 5, 3, 1];
        let mut boxes = 0;
        for ((tag, anchors), grid) in HEAD_SPEC.iter().zip(grids) {
            let s = &shapes[&format!("{tag}.loc.out")];
            assert_eq!(s[1], grid, "{tag}");
            assert_eq!(s[3], anchors * 4);
            boxes += grid * grid * anchors;
        }
        assert_eq!(boxes, 8732);
    }

    #[test]
    fn full_width_macs_sane() {
        // ~31 GMACs for VGG16-SSD300 (paper-standard); allow slack for our
        // non-dilated fc6
        let g = build_vgg16_ssd(21, 300, 1.0, QCfg::FP32, 0);
        let macs = g.conv_macs().unwrap() as f64;
        assert!((2.5e10..4.0e10).contains(&macs), "got {macs}");
    }
}

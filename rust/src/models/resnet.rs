//! ResNet-18/50 native builders (mirror of python/compile/models/resnet.py).

use crate::dlrt::graph::{Graph, Op, QCfg};

use super::GraphBuilder;

fn ch(c: usize, width_mult: f32) -> usize {
    ((c as f32 * width_mult).round() as usize).max(8)
}

fn basic_block(b: &mut GraphBuilder, x: &str, cout: usize, stride: usize,
               name: &str, q: QCfg) -> String {
    let mut identity = x.to_string();
    let y = b.conv_named(&format!("{name}.conv1"), x, cout, 3, stride, 1, q,
                         Some(Op::Relu));
    let y = b.conv_named(&format!("{name}.conv2"), &y, cout, 3, 1, 1, q, None);
    if stride != 1 || b.channels(&identity) != cout {
        identity = b.conv_named(&format!("{name}.down"), &identity, cout, 1,
                                stride, 0, q, None);
    }
    let y = b.add(&y, &identity);
    b.act_named(&format!("{name}.relu"), &y, Op::Relu)
}

fn bottleneck(b: &mut GraphBuilder, x: &str, cmid: usize, stride: usize,
              name: &str, q: QCfg) -> String {
    let cout = cmid * 4;
    let mut identity = x.to_string();
    let y = b.conv_named(&format!("{name}.conv1"), x, cmid, 1, 1, 0, q, Some(Op::Relu));
    let y = b.conv_named(&format!("{name}.conv2"), &y, cmid, 3, stride, 1, q,
                         Some(Op::Relu));
    let y = b.conv_named(&format!("{name}.conv3"), &y, cout, 1, 1, 0, q, None);
    if stride != 1 || b.channels(&identity) != cout {
        identity = b.conv_named(&format!("{name}.down"), &identity, cout, 1,
                                stride, 0, q, None);
    }
    let y = b.add(&y, &identity);
    b.act_named(&format!("{name}.relu"), &y, Op::Relu)
}

/// Build ResNet-18 or -50. `qcfg` applies to all non-stem convs (pass
/// `QCfg::FP32` for a float model; use `models::set_mixed_precision` for
/// finer policies).
pub fn build_resnet(depth: usize, num_classes: usize, resolution: usize,
                    width_mult: f32, qcfg: QCfg, seed: u64) -> Graph {
    let (blocks, use_bottleneck, expansion): (&[usize], bool, usize) = match depth {
        18 => (&[2, 2, 2, 2], false, 1),
        50 => (&[3, 4, 6, 3], true, 4),
        _ => panic!("unsupported resnet depth {depth}"),
    };
    let mut b = GraphBuilder::new(&format!("resnet{depth}"), [1, resolution, resolution, 3],
                                  seed);
    // stem stays FP32 (the paper's conservative policy)
    let x = b.conv_named("stem", "input", ch(64, width_mult), 7, 2, 3, QCfg::FP32,
                         Some(Op::Relu));
    let mut x = b.maxpool(&x, 3, 2, 1);
    let widths = [64usize, 128, 256, 512];
    for (si, (&nblk, &w)) in blocks.iter().zip(widths.iter()).enumerate() {
        for bi in 0..nblk {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            let name = format!("layer{}.{}", si + 1, bi);
            x = if use_bottleneck {
                bottleneck(&mut b, &x, ch(w, width_mult), stride, &name, qcfg)
            } else {
                basic_block(&mut b, &x, ch(w, width_mult), stride, &name, qcfg)
            };
        }
    }
    let x = b.global_avg_pool(&x);
    let feat = ch(widths[3], width_mult) * expansion;
    let out = b.dense(&x, feat, num_classes);
    b.finish(vec![out])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_topology_matches_python() {
        let g = build_resnet(18, 1000, 224, 1.0, QCfg::new(2, 2), 0);
        assert_eq!(g.conv_nodes().count(), 20);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[&g.outputs[0]], vec![1, 1000]);
        // stem downsamples to 112, maxpool to 56, stages to 7
        assert_eq!(shapes["layer4.1.relu.out"], vec![1, 7, 7, 512]);
        // ~1.8 GMACs at 224px (paper-standard number)
        let g1 = g.conv_macs().unwrap();
        assert!((1.6e9..2.0e9).contains(&(g1 as f64)), "got {g1}");
    }

    #[test]
    fn resnet50_topology() {
        let g = build_resnet(50, 1000, 224, 1.0, QCfg::FP32, 0);
        assert_eq!(g.conv_nodes().count(), 53);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes["layer4.2.relu.out"], vec![1, 7, 7, 2048]);
        let macs = g.conv_macs().unwrap() as f64;
        assert!((3.5e9..4.3e9).contains(&macs), "got {macs}"); // ~3.8 GMACs
    }

    #[test]
    fn width_mult_scales_channels() {
        let g = build_resnet(18, 2, 64, 0.25, QCfg::new(2, 2), 0);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes["layer4.1.relu.out"].last(), Some(&128));
    }
}

//! Native graph builders for the paper's evaluation models.
//!
//! These mirror `python/compile/models/*` node-for-node (a consistency test
//! compares topologies against the exported arch.json). They exist so the
//! latency benches can instantiate full-size architectures with seeded
//! random weights without shipping hundred-MB weight files — latency is
//! weight-value independent.

pub mod resnet;
pub mod vgg_ssd;
pub mod yolov5;

use crate::dlrt::graph::{Graph, Node, NodeWeights, Op, QCfg};
use crate::util::rng::Rng;

pub use resnet::build_resnet;
pub use vgg_ssd::build_vgg16_ssd;
pub use yolov5::build_yolov5;

/// Default input resolution for a named builder.
pub fn default_res(model: &str) -> usize {
    match model {
        "vgg16_ssd" => 300,
        m if m.starts_with("yolov5") => 320,
        _ => 224,
    }
}

/// Build a named evaluation model — the single lookup shared by the CLI
/// and the serving registry (`resnet18|resnet50|vgg16_ssd|yolov5n|s|m`).
pub fn build_named(
    name: &str,
    res: usize,
    w_bits: u8,
    a_bits: u8,
    width_mult: f32,
) -> anyhow::Result<Graph> {
    let q = QCfg::new(a_bits, w_bits);
    Ok(match name {
        "resnet18" => build_resnet(18, 1000, res, width_mult, q, 0),
        "resnet50" => build_resnet(50, 1000, res, width_mult, q, 0),
        "vgg16_ssd" => build_vgg16_ssd(21, res, width_mult, q, 0),
        "yolov5n" => build_yolov5("n", 80, res, width_mult, q, 0),
        "yolov5s" => build_yolov5("s", 80, res, width_mult, q, 0),
        "yolov5m" => build_yolov5("m", 80, res, width_mult, q, 0),
        other => anyhow::bail!("unknown model {other:?}"),
    })
}

/// Shared builder DSL (mirror of python GraphBuilder).
pub struct GraphBuilder {
    pub g: Graph,
    rng: Rng,
    uid: usize,
    channels: std::collections::BTreeMap<String, usize>,
}

impl GraphBuilder {
    pub fn new(name: &str, input_shape: [usize; 4], seed: u64) -> GraphBuilder {
        let mut channels = std::collections::BTreeMap::new();
        channels.insert("input".to_string(), input_shape[3]);
        GraphBuilder {
            g: Graph {
                name: name.to_string(),
                input_name: "input".to_string(),
                input_shape,
                nodes: Vec::new(),
                outputs: Vec::new(),
                weights: Default::default(),
            },
            rng: Rng::new(seed),
            uid: 0,
            channels,
        }
    }

    pub fn channels(&self, t: &str) -> usize {
        self.channels[t]
    }

    fn fresh(&mut self, prefix: &str) -> String {
        self.uid += 1;
        format!("{prefix}_{}", self.uid)
    }

    /// conv2d with seeded He-normal weights, identity scale, zero bias, and
    /// QAT-plausible scales (s_w from weight minmax, s_a = 0.05).
    #[allow(clippy::too_many_arguments)]
    pub fn conv_named(
        &mut self,
        name: &str,
        x: &str,
        cout: usize,
        k: usize,
        stride: usize,
        padding: usize,
        qcfg: QCfg,
        act: Option<Op>,
    ) -> String {
        let cin = self.channels[x];
        let out = format!("{name}.out");
        let w = self.rng.he_normal(k * k * cin * cout, k * k * cin);
        let s_w = if qcfg.enabled {
            crate::quant::calibrate_minmax_signed(&w, qcfg.w_bits)
        } else {
            0.0
        };
        self.g.weights.insert(
            name.to_string(),
            NodeWeights {
                w,
                scale: vec![1.0; cout],
                bias: vec![0.0; cout],
                s_w,
                s_a: if qcfg.enabled { 0.05 } else { 0.0 },
            },
        );
        self.g.nodes.push(Node {
            op: Op::Conv2d {
                stride: [stride, stride],
                padding: [padding, padding],
                kernel: [k, k],
                cin,
                cout,
                qcfg,
            },
            name: name.to_string(),
            inputs: vec![x.to_string()],
            output: out.clone(),
        });
        self.channels.insert(out.clone(), cout);
        match act {
            Some(op) => self.act_named(&format!("{name}.act"), &out, op),
            None => out,
        }
    }

    pub fn conv(&mut self, x: &str, cout: usize, k: usize, stride: usize,
                qcfg: QCfg, act: Option<Op>) -> String {
        let name = self.fresh("conv");
        self.conv_named(&name, x, cout, k, stride, k / 2, qcfg, act)
    }

    pub fn act_named(&mut self, name: &str, x: &str, op: Op) -> String {
        let out = format!("{name}.out");
        self.g.nodes.push(Node {
            op,
            name: name.to_string(),
            inputs: vec![x.to_string()],
            output: out.clone(),
        });
        // propagate channel metadata when known (tensors past a flatten
        // have no tracked channel count — activations there still work)
        if let Some(c) = self.channels.get(x).copied() {
            self.channels.insert(out.clone(), c);
        }
        out
    }

    pub fn maxpool(&mut self, x: &str, k: usize, stride: usize, padding: usize) -> String {
        let name = self.fresh("maxpool");
        let out = format!("{name}.out");
        self.g.nodes.push(Node {
            op: Op::MaxPool2d {
                kernel: [k, k],
                stride: [stride, stride],
                padding: [padding, padding],
            },
            name,
            inputs: vec![x.to_string()],
            output: out.clone(),
        });
        self.channels.insert(out.clone(), self.channels[x]);
        out
    }

    pub fn global_avg_pool(&mut self, x: &str) -> String {
        let name = self.fresh("gap");
        let out = format!("{name}.out");
        self.g.nodes.push(Node {
            op: Op::GlobalAvgPool,
            name,
            inputs: vec![x.to_string()],
            output: out.clone(),
        });
        out
    }

    pub fn add(&mut self, a: &str, b: &str) -> String {
        let name = self.fresh("add");
        let out = format!("{name}.out");
        self.g.nodes.push(Node {
            op: Op::Add,
            name,
            inputs: vec![a.to_string(), b.to_string()],
            output: out.clone(),
        });
        self.channels.insert(out.clone(), self.channels[a]);
        out
    }

    pub fn concat(&mut self, xs: &[&str]) -> String {
        let name = self.fresh("concat");
        let out = format!("{name}.out");
        let ctot = xs.iter().map(|x| self.channels[*x]).sum();
        self.g.nodes.push(Node {
            op: Op::Concat,
            name,
            inputs: xs.iter().map(|s| s.to_string()).collect(),
            output: out.clone(),
        });
        self.channels.insert(out.clone(), ctot);
        out
    }

    pub fn upsample2x(&mut self, x: &str) -> String {
        let name = self.fresh("up");
        let out = format!("{name}.out");
        self.g.nodes.push(Node {
            op: Op::Upsample2x,
            name,
            inputs: vec![x.to_string()],
            output: out.clone(),
        });
        self.channels.insert(out.clone(), self.channels[x]);
        out
    }

    /// Flatten to rank-2. Channel metadata is not tracked past this point
    /// (the flattened width depends on spatial dims the builder doesn't
    /// know); follow with `dense` (explicit `cin`) or activations.
    pub fn flatten(&mut self, x: &str) -> String {
        let name = self.fresh("flatten");
        let out = format!("{name}.out");
        self.g.nodes.push(Node {
            op: Op::Flatten,
            name,
            inputs: vec![x.to_string()],
            output: out.clone(),
        });
        out
    }

    pub fn dense(&mut self, x: &str, cin: usize, cout: usize) -> String {
        let name = self.fresh("dense");
        let out = format!("{name}.out");
        let w = self.rng.he_normal(cin * cout, cin);
        self.g.weights.insert(
            name.clone(),
            NodeWeights { w, scale: Vec::new(), bias: vec![0.0; cout], s_w: 0.0, s_a: 0.0 },
        );
        self.g.nodes.push(Node {
            op: Op::Dense { cin, cout },
            name,
            inputs: vec![x.to_string()],
            output: out.clone(),
        });
        self.channels.insert(out.clone(), cout);
        out
    }

    pub fn finish(mut self, outputs: Vec<String>) -> Graph {
        self.g.outputs = outputs;
        self.g.validate().expect("builder produced invalid graph");
        self.g
    }
}

/// Mixed-precision policy matching python `set_mixed_precision`: convs with
/// index in [from, to) get (a_bits, w_bits); the rest stay FP32.
pub fn set_mixed_precision(g: &mut Graph, from: usize, to: Option<usize>,
                           w_bits: u8, a_bits: u8) {
    let conv_names: Vec<String> = g.conv_nodes().map(|n| n.name.clone()).collect();
    let hi = to.unwrap_or(conv_names.len());
    for n in g.nodes.iter_mut() {
        if let Op::Conv2d { qcfg, .. } = &mut n.op {
            let idx = conv_names.iter().position(|c| c == &n.name).unwrap();
            *qcfg = if idx >= from && idx < hi {
                QCfg::new(a_bits, w_bits)
            } else {
                QCfg::FP32
            };
            // refresh s_w for the new bit width
            let enabled = qcfg.enabled;
            let bits = qcfg.w_bits;
            if let Some(nw) = g.weights.get_mut(&n.name) {
                nw.s_w = if enabled {
                    crate::quant::calibrate_minmax_signed(&nw.w, bits)
                } else {
                    0.0
                };
                if enabled && nw.s_a == 0.0 {
                    nw.s_a = 0.05;
                }
            }
        }
    }
}

/// One quantized conv with weights snapped to exact codes (unit tests).
pub fn single_conv_graph(w_bits: u8, a_bits: u8, s_w: f32, s_a: f32) -> Graph {
    let mut b = GraphBuilder::new("oneconv", [1, 8, 8, 3], 11);
    let x = b.conv_named("c", "input", 8, 3, 1, 1, QCfg::new(a_bits, w_bits), None);
    let mut g = b.finish(vec![x]);
    let nw = g.weights.get_mut("c").unwrap();
    nw.s_w = s_w;
    nw.s_a = s_a;
    let (qp, qn) = crate::dlrt::graph::qp_qn(w_bits, true);
    for w in nw.w.iter_mut() {
        *w = (*w / s_w).round().clamp(-(qn as f32), qp as f32) * s_w;
    }
    g
}

/// Tiny 3-conv graph for unit tests. With `quant_exact`, weights/scales are
/// chosen exactly representable at 2 bits so bitserial == fp32 bit-for-bit.
pub fn tiny_test_graph(quant_exact: bool) -> Graph {
    let mut b = GraphBuilder::new("tiny", [1, 8, 8, 3], 7);
    let q = QCfg::new(2, 2);
    let x = b.conv_named("c1", "input", 8, 3, 1, 1, QCfg::FP32, Some(Op::Relu));
    let x = b.conv_named("c2", &x, 8, 3, 2, 1, q, Some(Op::Relu));
    let x = b.conv_named("c3", &x, 4, 1, 1, 0, q, None);
    let out = b.global_avg_pool(&x);
    let mut g = b.finish(vec![out]);
    if quant_exact {
        for (name, nw) in g.weights.iter_mut() {
            if name == "c1" {
                continue;
            }
            // snap weights to {-2,-1,0,1} * 0.5 and scales to round values
            nw.s_w = 0.5;
            nw.s_a = 0.25;
            for w in nw.w.iter_mut() {
                *w = (*w / 0.5).round().clamp(-2.0, 1.0) * 0.5;
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_graph_valid() {
        let g = tiny_test_graph(false);
        g.validate().unwrap();
        assert_eq!(g.conv_nodes().count(), 3);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes["c3.out"], vec![1, 4, 4, 4]);
    }

    #[test]
    fn mixed_precision_reassignment() {
        let mut g = tiny_test_graph(false);
        set_mixed_precision(&mut g, 1, None, 1, 1);
        let tags: Vec<String> = g
            .conv_nodes()
            .map(|n| match &n.op {
                Op::Conv2d { qcfg, .. } => qcfg.tag(),
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(tags, vec!["FP32", "1A1W", "1A1W"]);
        assert!(g.weights["c2"].s_w > 0.0);
    }
}

//! Compile-time execution planning: liveness analysis + the pass pipeline
//! that lowers a [`Graph`] into an [`ExecPlan`].
//!
//! The paper's runtime keeps its inner loop free of graph machinery by
//! deciding everything ahead of time (§VI "Deeplite Runtime"); this module
//! is that decision stage. `build_plan` runs five passes:
//!
//! 1. **Activation fusion** — a Conv2d whose output's sole consumer is an
//!    elementwise activation absorbs it as a fused epilogue
//!    ([`crate::kernels::bitserial::dequant_scale_bias_act`] /
//!    [`crate::kernels::fp32::scale_bias_rows_act`]), so the
//!    pre-activation tensor is never materialized.
//! 2. **Add/residual fusion** — a Conv2d whose output's sole consumer is
//!    an `Add` whose other operand is already live when the conv runs
//!    (produced earlier, or the graph input) absorbs the add into its
//!    epilogue: the two-accumulator variants
//!    ([`crate::kernels::bitserial::dequant_scale_bias_add_act`] /
//!    [`crate::kernels::fp32::scale_bias_rows_add_act`]) add the residual
//!    row in the same pass over the GEMM accumulator, so residual blocks
//!    skip a whole-tensor pass *and* an arena slot.
//! 3. **Post-add activation fusion** — after a residual fuse, an
//!    activation that is now the conv's sole consumer (the ResNet
//!    `conv → add → relu` tail) also folds into the epilogue, applied
//!    after the residual add.
//! 4. **In-place / aliased lowering** — a standalone activation that is
//!    the last consumer of its input mutates the input's slot; `Flatten`
//!    becomes a metadata-only alias (no instruction at all); and a
//!    `Concat` whose every producer is sole-consumed and stride-capable
//!    (conv / pool / upsample / activation / nested concat) is **elided**:
//!    each producer gets a [`ChanView`] — an aliased channel-stripe view
//!    of the concat output slot — and writes its rows directly at the
//!    stripe's column offset, eliminating the `copy_channels` pass.
//!    Concats whose producers don't qualify (multi-use inputs, the graph
//!    input, dense/add producers) fall back to the copy path; the reason
//!    is recorded in [`ExecPlan::concat_fallbacks`].
//! 5. **Slot assignment** — register-allocation style: every instruction
//!    output gets an arena *slot*, and a slot returns to the free list as
//!    soon as the last consumer of every tensor bound to it has run.
//!    Slot sizes are per-batch-item element counts derived from
//!    [`Graph::infer_shapes`]; the executor rescales offsets for the actual
//!    request batch at run time. Striped producers share their concat
//!    root's slot, whose liveness spans from the first producer to the
//!    concat output's last consumer.
//!
//! `use_counts` / `peak_live_elems` are the underlying liveness analysis,
//! also used by the footprint reports.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::dlrt::graph::{conv_out_hw_checked, Graph, Op};
use crate::kernels::elementwise::ActKind;

/// tensor name -> number of consuming nodes (graph outputs add one use).
pub fn use_counts(g: &Graph) -> BTreeMap<&str, usize> {
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    for n in &g.nodes {
        for i in &n.inputs {
            *counts.entry(i.as_str()).or_insert(0) += 1;
        }
    }
    for o in &g.outputs {
        *counts.entry(o.as_str()).or_insert(0) += 1;
    }
    counts
}

/// Peak number of live f32 elements across the schedule (input + all
/// tensors whose consumers haven't all run yet).
pub fn peak_live_elems(g: &Graph) -> anyhow::Result<usize> {
    let shapes = g.infer_shapes()?;
    let numel = |t: &str| -> usize { shapes[t].iter().product() };
    let mut remaining = use_counts(g);
    let mut live: BTreeMap<&str, usize> = BTreeMap::new();
    live.insert(&g.input_name, numel(&g.input_name));
    let mut peak = live[g.input_name.as_str()];
    for n in &g.nodes {
        live.insert(&n.output, numel(&n.output));
        peak = peak.max(live.values().sum());
        for i in &n.inputs {
            if let Some(c) = remaining.get_mut(i.as_str()) {
                *c -= 1;
                if *c == 0 && !g.outputs.iter().any(|o| o == i) {
                    live.remove(i.as_str());
                }
            }
        }
    }
    Ok(peak)
}

// ---------------------------------------------------------------------------
// ExecPlan
// ---------------------------------------------------------------------------

/// Pass-pipeline switches (defaults on; benches toggle them for ablations).
#[derive(Clone, Copy, Debug)]
pub struct PlanOpts {
    /// Fold sole-consumer activations into conv epilogues.
    pub fuse_activations: bool,
    /// Lower last-consumer standalone activations to in-place mutation.
    pub in_place: bool,
    /// Fold sole-consumer residual `Add`s into conv epilogues.
    pub fuse_residual_add: bool,
    /// Let concat producers write channel stripes of the concat slot.
    pub concat_in_place: bool,
}

impl Default for PlanOpts {
    fn default() -> Self {
        PlanOpts {
            fuse_activations: true,
            in_place: true,
            fuse_residual_add: true,
            concat_in_place: true,
        }
    }
}

impl PlanOpts {
    /// Every pass disabled — the ablation baseline (one instruction per
    /// graph node, one slot per liveness interval, no aliasing).
    pub fn none() -> Self {
        PlanOpts {
            fuse_activations: false,
            in_place: false,
            fuse_residual_add: false,
            concat_in_place: false,
        }
    }
}

/// Channel-stripe view of a wider output slot: the instruction writes each
/// of its output rows (`out_tail` minus the channel dim) at column `off` of
/// a row `stride` channels wide — how a concat producer lands directly in
/// its stripe of the concat output slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChanView {
    /// Total channels of a root-slot row (the concat output's channels).
    pub stride: usize,
    /// First channel of this instruction's stripe.
    pub off: usize,
}

/// One lowered instruction: an op reading input slots and writing one
/// output slot. Shape *tails* (dims after the batch dim) are frozen at plan
/// time; the executor prepends the request batch.
#[derive(Clone, Debug)]
pub struct Instr {
    /// Originating node name (key into the compiled conv/dense maps).
    pub name: String,
    pub op: Op,
    /// Fused activation epilogue, applied before any fused add (convs only).
    pub fused: Option<ActKind>,
    /// Residual-add epilogue: `in_slots[1]` holds the residual, added to
    /// the conv result after `fused` and before `fused_post` (convs only).
    pub fused_add: bool,
    /// Activation applied after the fused residual add (the ResNet
    /// `conv → add → relu` tail; requires `fused_add`).
    pub fused_post: Option<ActKind>,
    pub in_slots: Vec<usize>,
    /// Per-input shape tails, aligned with `in_slots`.
    pub in_tails: Vec<Vec<usize>>,
    pub out_slot: usize,
    pub out_tail: Vec<usize>,
    /// Channel-stripe placement of the output inside `out_slot` (concat
    /// in-place producers); `None` writes the slot densely.
    pub out_view: Option<ChanView>,
    /// Activation lowered to mutate its own slot (`in_slots[0] == out_slot`).
    pub in_place: bool,
}

/// Where a graph output lives after the plan runs.
#[derive(Clone, Debug)]
pub struct OutSpec {
    pub slot: usize,
    pub tail: Vec<usize>,
}

/// A lowered, ready-to-execute program: topologically ordered instructions
/// over arena buffer slots. Built once per [`crate::exec::CompiledModel`]
/// and shared read-only by every executor (the coordinator's batch workers
/// all run the same plan against private arenas).
#[derive(Clone, Debug)]
pub struct ExecPlan {
    pub instrs: Vec<Instr>,
    /// Per-slot f32 elements for one batch item (max over tensors that
    /// ever occupy the slot).
    pub slot_sizes: Vec<usize>,
    pub input_slot: usize,
    pub input_tail: Vec<usize>,
    pub outputs: Vec<OutSpec>,
    /// Batch the graph was planned at (shapes rescale linearly).
    pub nominal_batch: usize,
    /// Concat nodes elided entirely (every producer writes its stripe).
    pub in_place_concats: usize,
    /// Why each remaining concat kept the copy path (the logged fallback;
    /// `dlrt inspect --plan` prints these).
    pub concat_fallbacks: Vec<String>,
}

impl ExecPlan {
    /// Total arena f32 elements needed for `batch`.
    pub fn arena_elems(&self, batch: usize) -> usize {
        self.slot_sizes.iter().sum::<usize>() * batch
    }

    /// Arena bytes needed for `batch` (4 bytes per f32 element). This is
    /// the number the serving layer sizes batches and queues against.
    pub fn arena_bytes(&self, batch: usize) -> usize {
        4 * self.arena_elems(batch)
    }

    /// f32 elements of a single request input (batch 1).
    pub fn input_elems(&self) -> usize {
        self.input_tail.iter().product()
    }

    /// Bytes held by one queued request input (batch 1, f32).
    pub fn input_bytes(&self) -> usize {
        4 * self.input_elems()
    }

    /// Largest batch whose arena fits in `budget_bytes`. Never returns 0:
    /// a budget smaller than one batch item degrades to unbatched serving
    /// rather than refusing to serve at all.
    pub fn max_batch_for_budget(&self, budget_bytes: usize) -> usize {
        let per_item = self.arena_bytes(1).max(1);
        (budget_bytes / per_item).max(1)
    }

    pub fn fused_instrs(&self) -> usize {
        self.instrs.iter().filter(|i| i.fused.is_some()).count()
    }

    /// Convs that absorbed a residual `Add` into their epilogue.
    pub fn fused_add_instrs(&self) -> usize {
        self.instrs.iter().filter(|i| i.fused_add).count()
    }

    /// Instructions writing a channel stripe of a concat output slot.
    pub fn strided_instrs(&self) -> usize {
        self.instrs.iter().filter(|i| i.out_view.is_some()).count()
    }

    pub fn in_place_instrs(&self) -> usize {
        self.instrs.iter().filter(|i| i.in_place).count()
    }

    /// Bounds/aliasing checks the executor's unsafe slot views rely on: a
    /// non-in-place instruction never writes a slot it reads, every slot id
    /// is in range, and every tensor fits its slot's per-batch size.
    ///
    /// `build_plan_with` validates every plan it produces, and — because
    /// the plan fields are public and swappable (the fig7 ablation does
    /// exactly that) — the executor re-runs this per request; it is
    /// O(instructions) and allocation-free.
    pub fn validate(&self) -> Result<()> {
        let n = self.slot_sizes.len();
        // overflow-checked products: a hostile plan (or a malformed .dlrt
        // header re-lowered by format::load) declaring astronomical dims
        // must fail validation, not wrap into passing bounds checks that
        // the unsafe arena views then trust
        let numel_checked = |tail: &[usize]| -> Option<usize> {
            tail.iter().try_fold(1usize, |a, &d| a.checked_mul(d))
        };
        let fits = |tail: &[usize], slot: usize| -> bool {
            slot < n && matches!(numel_checked(tail), Some(e) if e <= self.slot_sizes[slot])
        };
        let numel = |tail: &[usize]| -> usize { tail.iter().product() };
        if !fits(&self.input_tail, self.input_slot) {
            return Err(anyhow!("plan: input tensor does not fit its slot"));
        }
        for ins in &self.instrs {
            let arity_ok = ins.in_slots.len() == ins.in_tails.len()
                && match &ins.op {
                    Op::Add => ins.in_slots.len() == 2,
                    Op::Concat => !ins.in_slots.is_empty(),
                    // a fused residual add carries its second accumulator
                    // (the residual) as a second input
                    Op::Conv2d { .. } => {
                        ins.in_slots.len() == if ins.fused_add { 2 } else { 1 }
                    }
                    _ => ins.in_slots.len() == 1,
                };
            // per-op shape legality: recompute the output shape the way
            // exec_instr's kernels will and require the stored tail to
            // match, so a swapped plan can neither panic in a kernel nor
            // silently truncate its output (guarded by arity_ok)
            let shape_ok = arity_ok
                && match &ins.op {
                    Op::Conv2d { stride, padding, kernel, cin, cout, .. } => {
                        let t = &ins.in_tails[0];
                        t.len() == 3
                            && ins.out_tail.len() == 3
                            && t[2] == *cin
                            && conv_out_hw_checked(t[0], t[1], *kernel, *stride, *padding)
                                == Some((ins.out_tail[0], ins.out_tail[1]))
                            && ins.out_tail[2] == *cout
                            // the residual accumulator must be exactly one
                            // output's worth of elements
                            && (!ins.fused_add
                                || numel(&ins.in_tails[1]) == numel(&ins.out_tail))
                    }
                    Op::MaxPool2d { kernel, stride, padding } => {
                        let t = &ins.in_tails[0];
                        t.len() == 3
                            && ins.out_tail.len() == 3
                            && conv_out_hw_checked(t[0], t[1], *kernel, *stride, *padding)
                                == Some((ins.out_tail[0], ins.out_tail[1]))
                            && ins.out_tail[2] == t[2]
                    }
                    Op::Upsample2x => {
                        let t = &ins.in_tails[0];
                        t.len() == 3
                            && ins.out_tail.len() == 3
                            && ins.out_tail[0] == 2 * t[0]
                            && ins.out_tail[1] == 2 * t[1]
                            && ins.out_tail[2] == t[2]
                    }
                    Op::GlobalAvgPool => {
                        let t = &ins.in_tails[0];
                        t.len() == 3
                            && ins.out_tail.len() == 1
                            && ins.out_tail[0] == t[2]
                    }
                    Op::Concat => {
                        ins.out_tail.len() == 3
                            && ins.in_tails.iter().all(|t| {
                                t.len() == 3
                                    && t[0] == ins.out_tail[0]
                                    && t[1] == ins.out_tail[1]
                            })
                            && ins.in_tails.iter().map(|t| t[2]).sum::<usize>()
                                == ins.out_tail[2]
                    }
                    Op::Add => {
                        numel(&ins.in_tails[0]) == numel(&ins.out_tail)
                            && numel(&ins.in_tails[1]) == numel(&ins.out_tail)
                    }
                    Op::Dense { cin, cout } => {
                        *cin > 0
                            && ins.in_tails[0].last() == Some(cin)
                            && ins.out_tail.last() == Some(cout)
                            && ins.out_tail.len() == ins.in_tails[0].len()
                            && ins.out_tail[..ins.out_tail.len() - 1]
                                == ins.in_tails[0][..ins.in_tails[0].len() - 1]
                    }
                    Op::Relu | Op::Relu6 | Op::Silu | Op::LeakyRelu | Op::Sigmoid => {
                        numel(&ins.in_tails[0]) == numel(&ins.out_tail)
                    }
                    Op::Flatten => true, // exec_instr rejects it with an error
                };
            // in-place is only meaningful (and only handled by exec_instr)
            // for activations; anything else would alias read/write views
            let in_place_ok = !ins.in_place || ActKind::from_op(&ins.op).is_some();
            // fused epilogues are a conv-only concept: exec_instr reads
            // `fused`/`fused_add`/`fused_post` nowhere else, so they must
            // not appear anywhere else — and a post-add activation without
            // a fused add would be indistinguishable from `fused`
            let fused_ok = ((ins.fused.is_none() && !ins.fused_add
                && ins.fused_post.is_none())
                || matches!(ins.op, Op::Conv2d { .. }))
                && (ins.fused_post.is_none() || ins.fused_add);
            // strided output views exist only for the ops exec_instr
            // implements stride-aware writes for, never in-place, and the
            // stripe must lie inside a row
            let view_ok = match &ins.out_view {
                None => true,
                Some(v) => {
                    let capable = matches!(
                        ins.op,
                        Op::Conv2d { .. } | Op::MaxPool2d { .. } | Op::Upsample2x
                            | Op::Concat
                    ) || ActKind::from_op(&ins.op).is_some();
                    capable
                        && !ins.in_place
                        && !ins.out_tail.is_empty()
                        && ins
                            .out_tail
                            .last()
                            .and_then(|&c| v.off.checked_add(c))
                            .is_some_and(|end| end <= v.stride)
                }
            };
            let aliasing_ok = if ins.in_place {
                ins.in_slots.first() == Some(&ins.out_slot)
            } else {
                ins.in_slots.iter().all(|&s| s != ins.out_slot)
            };
            // a strided instruction occupies rows × view.stride elements of
            // its slot, not numel(out_tail)
            let out_fits = match &ins.out_view {
                None => fits(&ins.out_tail, ins.out_slot),
                Some(v) => {
                    ins.out_slot < n
                        && !ins.out_tail.is_empty()
                        && matches!(
                            numel_checked(&ins.out_tail[..ins.out_tail.len() - 1])
                                .and_then(|r| r.checked_mul(v.stride)),
                            Some(e) if e <= self.slot_sizes[ins.out_slot]
                        )
                }
            };
            if !shape_ok
                || !in_place_ok
                || !fused_ok
                || !view_ok
                || !aliasing_ok
                || !out_fits
                || ins.in_slots.iter().zip(&ins.in_tails).any(|(&s, t)| !fits(t, s))
            {
                return Err(anyhow!(
                    "plan invariant violated at {:?} ({}): in={:?} out={} of {n} slots",
                    ins.name,
                    ins.op.name(),
                    ins.in_slots,
                    ins.out_slot
                ));
            }
        }
        for o in &self.outputs {
            if !fits(&o.tail, o.slot) {
                return Err(anyhow!("plan: output tensor does not fit its slot"));
            }
        }
        Ok(())
    }
}

/// Lower `g` with the default pass pipeline.
pub fn build_plan(g: &Graph) -> Result<ExecPlan> {
    build_plan_with(g, PlanOpts::default())
}

/// Working node during lowering (fusion rewrites outputs / drops nodes).
struct WNode {
    name: String,
    op: Op,
    inputs: Vec<String>,
    output: String,
    fused: Option<ActKind>,
    fused_add: bool,
    fused_post: Option<ActKind>,
    /// Concat elided by the in-place pass: producers already wrote their
    /// stripes, so no instruction is emitted — only a slot binding.
    elide: bool,
}

/// Consumer count of tensor `t` over the current (post-fusion) node list;
/// graph outputs count as one extra consumer.
fn uses_of(nodes: &[WNode], outputs: &[String], t: &str) -> usize {
    nodes.iter().flat_map(|n| n.inputs.iter()).filter(|i| i.as_str() == t).count()
        + outputs.iter().filter(|o| o.as_str() == t).count()
}

/// Slot allocator state: sizes/liveness plus the tensor-name bindings.
/// `live[s]` counts live tensor names bound to slot `s` (aliases mean a
/// slot can host several names at once); a slot is free only at zero.
struct SlotState {
    sizes: Vec<usize>,
    live: Vec<usize>,
    free: Vec<usize>,
    binding: BTreeMap<String, usize>,
    remaining: BTreeMap<String, usize>,
}

impl SlotState {
    /// Best-fit: smallest free slot that already holds `elems`; else grow
    /// the **largest** free slot to `elems` (cheapest growth); a brand-new
    /// slot is opened only when the free list is empty. Best-fit keeps
    /// small tensors from squatting in large recycled buffers.
    fn alloc(&mut self, elems: usize) -> usize {
        let pick = self
            .free
            .iter()
            .copied()
            .filter(|&s| self.sizes[s] >= elems)
            .min_by_key(|&s| self.sizes[s])
            .or_else(|| self.free.iter().copied().max_by_key(|&s| self.sizes[s]));
        match pick {
            Some(s) => {
                self.free.retain(|&f| f != s);
                if self.sizes[s] < elems {
                    self.sizes[s] = elems;
                }
                s
            }
            None => {
                self.sizes.push(elems);
                self.live.push(0);
                self.sizes.len() - 1
            }
        }
    }

    fn bind(&mut self, name: &str, slot: usize, elems: usize) {
        self.binding.insert(name.to_string(), slot);
        self.live[slot] += 1;
        if self.sizes[slot] < elems {
            self.sizes[slot] = elems;
        }
    }

    fn slot_of(&self, name: &str) -> Result<usize> {
        self.binding
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("plan: tensor {name:?} is not live"))
    }

    /// Consume one use of each input; a tensor whose uses hit zero unbinds,
    /// and a slot with no remaining bindings returns to the free list.
    /// (Graph outputs carry a permanent extra use, so they never unbind.)
    fn release(&mut self, inputs: &[String]) {
        for t in inputs {
            if let Some(c) = self.remaining.get_mut(t) {
                *c -= 1;
                if *c == 0 {
                    if let Some(s) = self.binding.remove(t) {
                        self.live[s] -= 1;
                        if self.live[s] == 0 {
                            self.free.push(s);
                        }
                    }
                }
            }
        }
    }
}

/// Lower `g` into an [`ExecPlan`] with explicit pass switches.
pub fn build_plan_with(g: &Graph, opts: PlanOpts) -> Result<ExecPlan> {
    let shapes = g.infer_shapes()?; // also surfaces static shape mismatches
    let tail_of = |t: &str| -> Vec<usize> { shapes[t][1..].to_vec() };
    let per_batch = |t: &str| -> usize { shapes[t][1..].iter().product() };

    let mut nodes: Vec<WNode> = g
        .nodes
        .iter()
        .map(|n| WNode {
            name: n.name.clone(),
            op: n.op.clone(),
            inputs: n.inputs.clone(),
            output: n.output.clone(),
            fused: None,
            fused_add: false,
            fused_post: None,
            elide: false,
        })
        .collect();

    // --- pass 1: activation fusion -------------------------------------
    if opts.fuse_activations {
        let mut i = 0;
        while i < nodes.len() {
            if matches!(nodes[i].op, Op::Conv2d { .. }) {
                let out = nodes[i].output.clone();
                if uses_of(&nodes, &g.outputs, &out) == 1 {
                    if let Some(j) =
                        nodes.iter().position(|n| n.inputs.iter().any(|t| *t == out))
                    {
                        if let Some(a) = ActKind::from_op(&nodes[j].op) {
                            let act_out = nodes[j].output.clone();
                            nodes[i].fused = Some(a);
                            nodes[i].output = act_out;
                            nodes.remove(j);
                        }
                    }
                }
            }
            i += 1;
        }
    }

    // --- pass 2: Add/residual fusion -----------------------------------
    // A conv whose (possibly activation-fused) output is consumed only by
    // an Add, where the add's other operand is already live when the conv
    // runs (graph input or produced by an earlier node), absorbs the add:
    // the residual becomes the conv's second input and the epilogue's
    // second accumulator. One add per conv (`fused_add` guard): a chain
    // `add → add` fuses only its first link.
    if opts.fuse_residual_add {
        let mut i = 0;
        while i < nodes.len() {
            if matches!(nodes[i].op, Op::Conv2d { .. }) && !nodes[i].fused_add {
                let out = nodes[i].output.clone();
                if uses_of(&nodes, &g.outputs, &out) == 1 {
                    if let Some(j) =
                        nodes.iter().position(|n| n.inputs.iter().any(|t| *t == out))
                    {
                        if matches!(nodes[j].op, Op::Add) {
                            let other = if nodes[j].inputs[0] == out {
                                nodes[j].inputs[1].clone()
                            } else {
                                nodes[j].inputs[0].clone()
                            };
                            let live_before_conv = other == g.input_name
                                || nodes[..i].iter().any(|n| n.output == other);
                            if live_before_conv {
                                let add_out = nodes[j].output.clone();
                                nodes[i].fused_add = true;
                                nodes[i].inputs.push(other);
                                nodes[i].output = add_out;
                                nodes.remove(j);
                            }
                        }
                    }
                }
            }
            i += 1;
        }
    }

    // --- pass 3: post-add activation fusion ----------------------------
    // After a residual fuse the conv's new sole consumer may be the block's
    // trailing activation (ResNet's `add → relu`); fold it in after the
    // residual add.
    if opts.fuse_activations {
        let mut i = 0;
        while i < nodes.len() {
            if nodes[i].fused_add && nodes[i].fused_post.is_none() {
                let out = nodes[i].output.clone();
                if uses_of(&nodes, &g.outputs, &out) == 1 {
                    if let Some(j) =
                        nodes.iter().position(|n| n.inputs.iter().any(|t| *t == out))
                    {
                        if let Some(a) = ActKind::from_op(&nodes[j].op) {
                            let act_out = nodes[j].output.clone();
                            nodes[i].fused_post = Some(a);
                            nodes[i].output = act_out;
                            nodes.remove(j);
                        }
                    }
                }
            }
            i += 1;
        }
    }

    // --- pass 4a: concat-in-place placement ----------------------------
    // Walk concats in reverse topological order so an outer concat claims
    // its stripes before an inner one composes into them (concat-of-concat
    // becomes stripes-of-stripes on the outermost root slot). All-or-
    // nothing per concat: every producer must be sole-consumed, stride-
    // capable, and not the graph input; otherwise the concat keeps the
    // copy path and the reason lands in `concat_fallbacks`.
    let mut placement: BTreeMap<String, (String, usize)> = BTreeMap::new();
    let mut in_place_concats = 0usize;
    let mut concat_fallbacks: Vec<String> = Vec::new();
    if opts.concat_in_place {
        for ci in (0..nodes.len()).rev() {
            if !matches!(nodes[ci].op, Op::Concat) {
                continue;
            }
            let (root, base) = match placement.get(&nodes[ci].output) {
                Some((r, b)) => (r.clone(), *b),
                None => (nodes[ci].output.clone(), 0),
            };
            let mut stripes: Vec<(String, usize)> = Vec::new();
            let mut fallback: Option<String> = None;
            let mut off = base;
            for t in &nodes[ci].inputs {
                let c = *shapes[t].last().expect("concat input has channels");
                let uses = uses_of(&nodes, &g.outputs, t);
                let producer = nodes[..ci].iter().find(|n| n.output == *t);
                let why = if uses != 1 {
                    Some(format!("{t:?} has {uses} consumers"))
                } else if *t == g.input_name || producer.is_none() {
                    Some(format!("{t:?} is the graph input"))
                } else {
                    let p = producer.expect("checked above");
                    let capable = matches!(
                        p.op,
                        Op::Conv2d { .. } | Op::MaxPool2d { .. } | Op::Upsample2x
                            | Op::Concat
                    ) || ActKind::from_op(&p.op).is_some();
                    if capable {
                        None
                    } else {
                        Some(format!(
                            "{t:?} produced by {} ({}, no strided write path)",
                            p.name,
                            p.op.name()
                        ))
                    }
                };
                match why {
                    Some(w) => {
                        fallback = Some(w);
                        break;
                    }
                    None => stripes.push((t.clone(), off)),
                }
                off += c;
            }
            match fallback {
                Some(w) => {
                    concat_fallbacks.push(format!("{}: copy fallback — {w}", nodes[ci].name))
                }
                None => {
                    for (t, o) in stripes {
                        placement.insert(t, (root.clone(), o));
                    }
                    nodes[ci].elide = true;
                    in_place_concats += 1;
                }
            }
        }
        concat_fallbacks.reverse(); // report in topological order
    }

    // remaining-use counts over the post-fusion node list (+1 per graph
    // output keeps output tensors bound for the plan's whole lifetime)
    let mut remaining: BTreeMap<String, usize> = BTreeMap::new();
    for n in &nodes {
        for t in &n.inputs {
            *remaining.entry(t.clone()).or_insert(0) += 1;
        }
    }
    for o in &g.outputs {
        *remaining.entry(o.clone()).or_insert(0) += 1;
    }

    // --- passes 4b+5: in-place / alias lowering + slot assignment -------
    let mut st = SlotState {
        sizes: Vec::new(),
        live: Vec::new(),
        free: Vec::new(),
        binding: BTreeMap::new(),
        remaining,
    };
    let mut instrs: Vec<Instr> = Vec::new();
    // concat root tensor name → its (shared) arena slot, allocated by the
    // first striped producer and kept live by the bindings of every stripe
    // tensor plus, eventually, the concat output itself
    let mut root_slots: BTreeMap<String, usize> = BTreeMap::new();

    let input_slot = st.alloc(per_batch(&g.input_name));
    st.bind(&g.input_name, input_slot, per_batch(&g.input_name));

    for n in &nodes {
        if matches!(n.op, Op::Flatten) {
            // metadata-only alias: same slot, new shape tail, no instruction
            let s = st.slot_of(&n.inputs[0])?;
            st.bind(&n.output, s, per_batch(&n.output));
            st.release(&n.inputs);
            continue;
        }
        if n.elide {
            // in-place concat: every producer already wrote its channel
            // stripe of the root slot — bind the output, emit nothing
            let root = match placement.get(&n.output) {
                Some((r, _)) => r.clone(),
                None => n.output.clone(),
            };
            let s = *root_slots
                .get(&root)
                .ok_or_else(|| anyhow!("plan: concat root {root:?} has no slot"))?;
            st.bind(&n.output, s, per_batch(&root));
            st.release(&n.inputs);
            continue;
        }
        let mut in_slots = Vec::with_capacity(n.inputs.len());
        for t in &n.inputs {
            in_slots.push(st.slot_of(t)?);
        }
        let in_tails: Vec<Vec<usize>> = n.inputs.iter().map(|t| tail_of(t)).collect();

        let sole_last_use = st.remaining.get(&n.inputs[0]).copied() == Some(1)
            && st.live[in_slots[0]] == 1;
        // gate on ActKind::from_op — the same mapping the executor
        // dispatches through — so the two can never drift apart. Striped
        // outputs never lower in place: they must land in the concat slot.
        if opts.in_place
            && ActKind::from_op(&n.op).is_some()
            && sole_last_use
            && !placement.contains_key(&n.output)
        {
            let s = in_slots[0];
            st.bind(&n.output, s, per_batch(&n.output));
            instrs.push(Instr {
                name: n.name.clone(),
                op: n.op.clone(),
                fused: None,
                fused_add: false,
                fused_post: None,
                in_slots,
                in_tails,
                out_slot: s,
                out_tail: tail_of(&n.output),
                out_view: None,
                in_place: true,
            });
            st.release(&n.inputs);
            continue;
        }

        // output placement: a channel stripe of a concat root slot, or a
        // fresh (recycled) slot. Inputs stay bound during allocation so an
        // instruction never writes over a live input.
        let (out_slot, out_view) = match placement.get(&n.output) {
            Some((root, off)) => {
                let s = match root_slots.get(root) {
                    Some(&s) => s,
                    None => {
                        let s = st.alloc(per_batch(root));
                        root_slots.insert(root.clone(), s);
                        s
                    }
                };
                st.bind(&n.output, s, per_batch(root));
                let stride = *shapes[root].last().expect("concat root has channels");
                (s, Some(ChanView { stride, off: *off }))
            }
            None => {
                let s = st.alloc(per_batch(&n.output));
                st.bind(&n.output, s, per_batch(&n.output));
                (s, None)
            }
        };
        instrs.push(Instr {
            name: n.name.clone(),
            op: n.op.clone(),
            fused: n.fused,
            fused_add: n.fused_add,
            fused_post: n.fused_post,
            in_slots,
            in_tails,
            out_slot,
            out_tail: tail_of(&n.output),
            out_view,
            in_place: false,
        });
        st.release(&n.inputs);
    }

    let mut outputs = Vec::with_capacity(g.outputs.len());
    for o in &g.outputs {
        outputs.push(OutSpec { slot: st.slot_of(o)?, tail: tail_of(o) });
    }

    let plan = ExecPlan {
        instrs,
        slot_sizes: st.sizes,
        input_slot,
        input_tail: tail_of(&g.input_name),
        outputs,
        nominal_batch: g.input_shape[0],
        in_place_concats,
        concat_fallbacks,
    };
    // every produced plan passes the same invariant check the executor
    // re-runs per request (see ExecPlan::validate)
    plan.validate()?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrt::graph::QCfg;
    use crate::models::{tiny_test_graph, GraphBuilder};

    #[test]
    fn counts_match_consumers() {
        let g = tiny_test_graph(false);
        let counts = use_counts(&g);
        // every node input is counted; outputs get +1
        for n in &g.nodes {
            for i in &n.inputs {
                assert!(counts[i.as_str()] >= 1);
            }
        }
        for o in &g.outputs {
            assert!(counts[o.as_str()] >= 1);
        }
    }

    #[test]
    fn peak_is_bounded_by_total() {
        let g = tiny_test_graph(false);
        let shapes = g.infer_shapes().unwrap();
        let total: usize = shapes.values().map(|s| s.iter().product::<usize>()).sum();
        let peak = peak_live_elems(&g).unwrap();
        assert!(peak <= total);
        assert!(peak > 0);
    }

    #[test]
    fn memory_accounting_helpers() {
        let g = tiny_test_graph(false);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.arena_bytes(1), 4 * plan.arena_elems(1));
        assert_eq!(plan.arena_bytes(2), 2 * plan.arena_bytes(1));
        assert_eq!(plan.input_elems(), 8 * 8 * 3);
        assert_eq!(plan.input_bytes(), 4 * 8 * 8 * 3);
        // budget for exactly k items admits batch k; a starvation budget
        // still admits one
        assert_eq!(plan.max_batch_for_budget(plan.arena_bytes(3)), 3);
        assert_eq!(plan.max_batch_for_budget(plan.arena_bytes(1) - 1), 1);
        assert_eq!(plan.max_batch_for_budget(0), 1);
    }

    #[test]
    fn fuses_sole_consumer_activations() {
        // tiny graph: conv+relu, conv+relu, conv, gap → 6 nodes, 4 instrs
        let g = tiny_test_graph(false);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.instrs.len(), 4);
        assert_eq!(plan.fused_instrs(), 2);
        assert!(plan.instrs.iter().all(|i| !i.op.is_activation()));
    }

    #[test]
    fn fusion_opt_out_keeps_standalone_activations() {
        let g = tiny_test_graph(false);
        let plan = build_plan_with(&g, PlanOpts::none()).unwrap();
        assert_eq!(plan.instrs.len(), g.nodes.len());
        assert_eq!(plan.fused_instrs(), 0);
        assert_eq!(plan.in_place_instrs(), 0);
        assert_eq!(plan.fused_add_instrs(), 0);
        assert_eq!(plan.in_place_concats, 0);
    }

    /// conv → add → relu (the ResNet block tail): the add folds into the
    /// conv's epilogue as a second accumulator, the relu folds in after it,
    /// and the whole block costs one instruction and one slot fewer.
    #[test]
    fn residual_add_and_post_activation_fuse_into_conv() {
        let mut b = GraphBuilder::new("res", [1, 8, 8, 3], 5);
        let c1 = b.conv_named("c1", "input", 8, 3, 1, 1, QCfg::FP32, Some(Op::Relu));
        let c2 = b.conv_named("c2", &c1, 8, 3, 1, 1, QCfg::FP32, None);
        let s = b.add(&c2, &c1);
        let r = b.act_named("tail", &s, Op::Relu);
        let g = b.finish(vec![r]);
        let plan = build_plan(&g).unwrap();
        // c1 (+relu), c2 (+add +relu): two instructions total
        assert_eq!(plan.instrs.len(), 2, "{:?}", plan.instrs);
        assert_eq!(plan.fused_add_instrs(), 1);
        let c2i = &plan.instrs[1];
        assert!(c2i.fused_add);
        assert_eq!(c2i.fused_post, Some(ActKind::Relu));
        assert_eq!(c2i.fused, None);
        assert_eq!(c2i.in_slots.len(), 2);
        // the residual reads c1's slot; the output is a third, distinct slot
        assert_eq!(c2i.in_slots[1], plan.instrs[0].out_slot);
        assert!(c2i.in_slots.iter().all(|&s| s != c2i.out_slot));
        // and the fused plan needs strictly less arena than the unfused one
        let unfused = build_plan_with(&g, PlanOpts::none()).unwrap();
        assert!(
            plan.arena_elems(1) < unfused.arena_elems(1),
            "fused {} !< unfused {}",
            plan.arena_elems(1),
            unfused.arena_elems(1)
        );
    }

    /// conv → silu → add (the YOLO bottleneck order): the activation fuses
    /// first, then the add; the epilogue applies act *before* the residual.
    #[test]
    fn pre_activation_then_residual_add_fuses() {
        let q = QCfg::new(2, 2);
        let mut b = GraphBuilder::new("yolo", [1, 8, 8, 3], 6);
        let c1 = b.conv_named("c1", "input", 8, 1, 1, 0, q, Some(Op::Silu));
        let c2 = b.conv_named("c2", &c1, 8, 3, 1, 1, q, Some(Op::Silu));
        let s = b.add(&c2, &c1);
        let g = b.finish(vec![s]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.instrs.len(), 2);
        let c2i = &plan.instrs[1];
        assert_eq!(c2i.fused, Some(ActKind::Silu));
        assert!(c2i.fused_add);
        assert_eq!(c2i.fused_post, None);
    }

    /// An add whose conv operand comes *after* the other operand's producer
    /// fuses into that later conv, even when the conv is the add's second
    /// input (the ResNet downsample branch).
    #[test]
    fn add_fuses_into_whichever_conv_runs_last() {
        let mut b = GraphBuilder::new("down", [1, 8, 8, 3], 7);
        let c2 = b.conv_named("c2", "input", 8, 3, 2, 1, QCfg::FP32, None);
        let down = b.conv_named("down", "input", 8, 1, 2, 0, QCfg::FP32, None);
        let s = b.add(&c2, &down);
        let g = b.finish(vec![s]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.fused_add_instrs(), 1);
        // `down` runs after `c2`, so it absorbs the add
        let fused = plan.instrs.iter().find(|i| i.fused_add).unwrap();
        assert_eq!(fused.name, "down");
    }

    /// Residual fusion must not fire when the skip tensor isn't live yet
    /// (produced after the conv) or when the conv output has other uses.
    #[test]
    fn residual_fusion_requires_live_skip_and_sole_use() {
        // conv out also a graph output: two uses, no fusion
        let mut b = GraphBuilder::new("multiuse", [1, 8, 8, 3], 8);
        let c = b.conv_named("c", "input", 3, 3, 1, 1, QCfg::FP32, None);
        let s = b.add(&c, "input");
        let g = b.finish(vec![s, c.clone()]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.fused_add_instrs(), 0);
        assert!(plan.instrs.iter().any(|i| matches!(i.op, Op::Add)));
    }

    /// Every producer of the concat is a sole-consumer conv/pool: the
    /// concat is elided and each producer writes a channel stripe of the
    /// shared root slot.
    #[test]
    fn concat_producers_write_stripes_in_place() {
        let q = QCfg::new(2, 2);
        let mut b = GraphBuilder::new("cat", [1, 8, 8, 3], 9);
        let c1 = b.conv_named("c1", "input", 4, 3, 1, 1, q, Some(Op::Relu));
        let c2 = b.conv_named("c2", "input", 6, 3, 1, 1, QCfg::FP32, None);
        let cat = b.concat(&[&c1, &c2]);
        let g = b.finish(vec![cat]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.in_place_concats, 1);
        assert!(plan.concat_fallbacks.is_empty(), "{:?}", plan.concat_fallbacks);
        assert!(plan.instrs.iter().all(|i| !matches!(i.op, Op::Concat)));
        let v1 = plan.instrs[0].out_view.expect("c1 striped");
        let v2 = plan.instrs[1].out_view.expect("c2 striped");
        assert_eq!((v1.stride, v1.off), (10, 0));
        assert_eq!((v2.stride, v2.off), (10, 4));
        assert_eq!(plan.instrs[0].out_slot, plan.instrs[1].out_slot);
        // no copy pass and no per-producer slots: fused arena is smaller
        let unfused = build_plan_with(&g, PlanOpts::none()).unwrap();
        assert!(plan.arena_elems(1) < unfused.arena_elems(1));
    }

    /// Concat-of-concat composes: the inner concat's producers stripe
    /// straight into the outer root slot at compound offsets.
    #[test]
    fn nested_concats_compose_stripes_on_one_root() {
        let mut b = GraphBuilder::new("nest", [1, 8, 8, 3], 10);
        let a = b.conv_named("a", "input", 2, 1, 1, 0, QCfg::FP32, None);
        let c = b.conv_named("c", "input", 3, 1, 1, 0, QCfg::FP32, None);
        let inner = b.concat(&[&a, &c]);
        let d = b.conv_named("d", "input", 4, 1, 1, 0, QCfg::FP32, None);
        let outer = b.concat(&[&d, &inner]);
        let g = b.finish(vec![outer]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.in_place_concats, 2);
        assert!(plan.instrs.iter().all(|i| !matches!(i.op, Op::Concat)));
        // one root slot, stripes at 0 (d), 4 (a), 6 (c), all stride 9
        let views: Vec<ChanView> =
            plan.instrs.iter().map(|i| i.out_view.expect("striped")).collect();
        assert!(views.iter().all(|v| v.stride == 9));
        let mut offs: Vec<usize> = views.iter().map(|v| v.off).collect();
        offs.sort_unstable();
        assert_eq!(offs, vec![0, 4, 6]);
        let slots: Vec<usize> = plan.instrs.iter().map(|i| i.out_slot).collect();
        assert!(slots.windows(2).all(|w| w[0] == w[1]));
    }

    /// A multi-use producer (the SPPF pattern) forces the copy fallback,
    /// and the reason is recorded for `inspect --plan`.
    #[test]
    fn multi_use_concat_producer_falls_back_with_reason() {
        let mut b = GraphBuilder::new("sppf", [1, 8, 8, 3], 11);
        let c = b.conv_named("c", "input", 4, 1, 1, 0, QCfg::FP32, None);
        let p = b.maxpool(&c, 3, 1, 1); // c feeds both pool and concat
        let cat = b.concat(&[&c, &p]);
        let g = b.finish(vec![cat]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.in_place_concats, 0);
        assert_eq!(plan.concat_fallbacks.len(), 1);
        assert!(plan.concat_fallbacks[0].contains("2 consumers"),
                "{:?}", plan.concat_fallbacks);
        assert!(plan.instrs.iter().any(|i| matches!(i.op, Op::Concat)));
    }

    #[test]
    fn shared_conv_output_is_not_fused() {
        // conv out feeds both the activation and a residual add: folding the
        // relu into the conv would corrupt the add's operand
        let mut b = GraphBuilder::new("res", [1, 8, 8, 3], 5);
        let c1 = b.conv_named("c1", "input", 8, 3, 1, 1, QCfg::FP32, None);
        let r = b.act_named("r", &c1, Op::Relu);
        let s = b.add(&r, &c1);
        let g = b.finish(vec![s]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.fused_instrs(), 0);
        assert_eq!(plan.instrs.len(), 3); // conv, relu, add
        // relu also can't run in place (c1.out still needed by the add)
        assert_eq!(plan.in_place_instrs(), 0);
    }

    #[test]
    fn flatten_is_alias_and_last_use_activation_runs_in_place() {
        let mut b = GraphBuilder::new("t", [1, 8, 8, 3], 5);
        let p = b.maxpool("input", 2, 2, 0);
        let r = b.act_named("r", &p, Op::Relu); // pool.out's last use
        let f = b.flatten(&r);
        let d = b.dense(&f, 4 * 4 * 3, 10);
        let g = b.finish(vec![d]);
        let plan = build_plan(&g).unwrap();
        // maxpool, relu (in place), dense — flatten vanished
        assert_eq!(plan.instrs.len(), 3);
        assert!(plan.instrs.iter().all(|i| !matches!(i.op, Op::Flatten)));
        let relu = &plan.instrs[1];
        assert!(relu.in_place);
        assert_eq!(relu.in_slots[0], relu.out_slot);
        // the dense input aliases the relu output's slot
        assert_eq!(plan.instrs[2].in_slots[0], relu.out_slot);
    }

    #[test]
    fn slots_are_recycled_and_arena_within_interpreter_peak() {
        for g in [tiny_test_graph(false), tiny_test_graph(true)] {
            let plan = build_plan(&g).unwrap();
            // far fewer slots than tensors
            assert!(plan.slot_sizes.len() <= 3, "slots: {:?}", plan.slot_sizes);
            let peak = peak_live_elems(&g).unwrap();
            assert!(
                plan.arena_elems(1) <= peak,
                "arena {} > interpreter peak {peak}",
                plan.arena_elems(1)
            );
        }
    }

    #[test]
    fn instructions_never_write_live_inputs() {
        let g = tiny_test_graph(false);
        let plan = build_plan(&g).unwrap();
        for i in &plan.instrs {
            if !i.in_place {
                assert!(i.in_slots.iter().all(|&s| s != i.out_slot), "{:?}", i);
            }
        }
    }

    #[test]
    fn arena_scales_linearly_with_batch() {
        let g = tiny_test_graph(false);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.arena_elems(3), 3 * plan.arena_elems(1));
        assert_eq!(plan.nominal_batch, 1);
    }

    #[test]
    fn rejects_statically_mismatched_graphs() {
        // Add with unequal shapes must fail at plan (= compile) time
        use crate::dlrt::graph::{Graph, Node};
        let g = Graph {
            name: "bad".into(),
            input_name: "input".into(),
            input_shape: [1, 8, 8, 3],
            nodes: vec![
                Node {
                    op: Op::MaxPool2d { kernel: [2, 2], stride: [2, 2], padding: [0, 0] },
                    name: "pool".into(),
                    inputs: vec!["input".into()],
                    output: "pool.out".into(),
                },
                Node {
                    op: Op::Add,
                    name: "bad".into(),
                    inputs: vec!["input".into(), "pool.out".into()],
                    output: "bad.out".into(),
                },
            ],
            outputs: vec!["bad.out".into()],
            weights: Default::default(),
        };
        let err = build_plan(&g).unwrap_err();
        assert!(format!("{err:#}").contains("add shape mismatch"), "{err:#}");
    }
}

//! Liveness analysis for buffer release during execution.
//!
//! The executor drops an intermediate tensor as soon as its last consumer
//! has run (unless it is a graph output). `use_counts` computes the number
//! of consumers per tensor; `peak_live_elems` estimates the resulting peak
//! working set, which the `model_size`/footprint reports use.

use std::collections::BTreeMap;

use crate::dlrt::graph::Graph;

/// tensor name -> number of consuming nodes (graph outputs add one use).
pub fn use_counts(g: &Graph) -> BTreeMap<&str, usize> {
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    for n in &g.nodes {
        for i in &n.inputs {
            *counts.entry(i.as_str()).or_insert(0) += 1;
        }
    }
    for o in &g.outputs {
        *counts.entry(o.as_str()).or_insert(0) += 1;
    }
    counts
}

/// Peak number of live f32 elements across the schedule (input + all
/// tensors whose consumers haven't all run yet).
pub fn peak_live_elems(g: &Graph) -> anyhow::Result<usize> {
    let shapes = g.infer_shapes()?;
    let numel = |t: &str| -> usize { shapes[t].iter().product() };
    let mut remaining = use_counts(g);
    let mut live: BTreeMap<&str, usize> = BTreeMap::new();
    live.insert(&g.input_name, numel(&g.input_name));
    let mut peak = live[g.input_name.as_str()];
    for n in &g.nodes {
        live.insert(&n.output, numel(&n.output));
        peak = peak.max(live.values().sum());
        for i in &n.inputs {
            if let Some(c) = remaining.get_mut(i.as_str()) {
                *c -= 1;
                if *c == 0 && !g.outputs.iter().any(|o| o == i) {
                    live.remove(i.as_str());
                }
            }
        }
    }
    Ok(peak)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::tiny_test_graph;

    #[test]
    fn counts_match_consumers() {
        let g = tiny_test_graph(false);
        let counts = use_counts(&g);
        // every node input is counted; outputs get +1
        for n in &g.nodes {
            for i in &n.inputs {
                assert!(counts[i.as_str()] >= 1);
            }
        }
        for o in &g.outputs {
            assert!(counts[o.as_str()] >= 1);
        }
    }

    #[test]
    fn peak_is_bounded_by_total() {
        let g = tiny_test_graph(false);
        let shapes = g.infer_shapes().unwrap();
        let total: usize = shapes.values().map(|s| s.iter().product::<usize>()).sum();
        let peak = peak_live_elems(&g).unwrap();
        assert!(peak <= total);
        assert!(peak > 0);
    }
}

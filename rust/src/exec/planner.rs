//! Compile-time execution planning: liveness analysis + the pass pipeline
//! that lowers a [`Graph`] into an [`ExecPlan`].
//!
//! The paper's runtime keeps its inner loop free of graph machinery by
//! deciding everything ahead of time (§VI "Deeplite Runtime"); this module
//! is that decision stage. `build_plan` runs five passes:
//!
//! 1. **Activation fusion** — a Conv2d whose output's sole consumer is an
//!    elementwise activation absorbs it as a fused epilogue
//!    ([`crate::kernels::bitserial::dequant_scale_bias_act`] /
//!    [`crate::kernels::fp32::scale_bias_rows_act`]), so the
//!    pre-activation tensor is never materialized.
//! 2. **Add/residual fusion** — a Conv2d whose output's sole consumer is
//!    an `Add` whose other operand is already live when the conv runs
//!    (produced earlier, or the graph input) absorbs the add into its
//!    epilogue: the two-accumulator variants
//!    ([`crate::kernels::bitserial::dequant_scale_bias_add_act`] /
//!    [`crate::kernels::fp32::scale_bias_rows_add_act`]) add the residual
//!    row in the same pass over the GEMM accumulator, so residual blocks
//!    skip a whole-tensor pass *and* an arena slot.
//! 3. **Post-add activation fusion** — after a residual fuse, an
//!    activation that is now the conv's sole consumer (the ResNet
//!    `conv → add → relu` tail) also folds into the epilogue, applied
//!    after the residual add.
//! 4. **In-place / aliased lowering** — a standalone activation that is
//!    the last consumer of its input mutates the input's slot; `Flatten`
//!    becomes a metadata-only alias (no instruction at all); and `Concat`
//!    producers that qualify write their channel stripe of the concat
//!    output slot directly through a [`ChanView`]. Striping is decided
//!    **per producer**: a producer qualifies when its op has a strided
//!    write path (conv / pool / upsample / activation / nested concat)
//!    and every *other* consumer of its tensor can read a channel stripe
//!    through an input view (conv im2col, pool, upsample, global-avg-pool,
//!    activations, concat copies) — multi-use tensors like YOLOv5's SPPF
//!    pyramid and PANet skip tensors therefore stripe too, with their
//!    consumers reading `(off, stride)` views out of the concat root slot.
//!    A concat whose producers all qualify is **elided** outright; a
//!    *partially* eligible concat keeps a copy instruction for just the
//!    ineligible inputs (the rest stripe in place); per-producer fallback
//!    reasons land in [`ExecPlan::concat_fallbacks`]. With
//!    [`PlanOpts::strided_reads`] off the pass degrades to the older
//!    all-or-nothing, sole-consumer-only behavior (the ablation baseline).
//! 5. **Slot assignment** — register-allocation style: every instruction
//!    output gets an arena *slot*, and a slot returns to the free list as
//!    soon as the last consumer of every tensor bound to it has run.
//!    Slot sizes are per-batch-item element counts derived from
//!    [`Graph::infer_shapes`]; the executor rescales offsets for the actual
//!    request batch at run time. Striped producers share their concat
//!    root's slot, whose liveness spans from the first producer to the
//!    concat output's last consumer.
//!
//! `use_counts` / `peak_live_elems` are the underlying liveness analysis,
//! also used by the footprint reports.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::dlrt::graph::{conv_out_hw_checked, Graph, Op};
use crate::kernels::elementwise::ActKind;

/// tensor name -> number of consuming nodes (graph outputs add one use).
pub fn use_counts(g: &Graph) -> BTreeMap<&str, usize> {
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    for n in &g.nodes {
        for i in &n.inputs {
            *counts.entry(i.as_str()).or_insert(0) += 1;
        }
    }
    for o in &g.outputs {
        *counts.entry(o.as_str()).or_insert(0) += 1;
    }
    counts
}

/// Peak number of live f32 elements across the schedule (input + all
/// tensors whose consumers haven't all run yet).
pub fn peak_live_elems(g: &Graph) -> anyhow::Result<usize> {
    let shapes = g.infer_shapes()?;
    let numel = |t: &str| -> usize { shapes[t].iter().product() };
    let mut remaining = use_counts(g);
    let mut live: BTreeMap<&str, usize> = BTreeMap::new();
    live.insert(&g.input_name, numel(&g.input_name));
    let mut peak = live[g.input_name.as_str()];
    for n in &g.nodes {
        live.insert(&n.output, numel(&n.output));
        peak = peak.max(live.values().sum());
        for i in &n.inputs {
            if let Some(c) = remaining.get_mut(i.as_str()) {
                *c -= 1;
                if *c == 0 && !g.outputs.iter().any(|o| o == i) {
                    live.remove(i.as_str());
                }
            }
        }
    }
    Ok(peak)
}

/// The GEMM problem one conv lowers to (im2col rows × patch × cout), plus
/// whether it is a unit conv (1×1, stride 1, no padding) — the shape key
/// the tuning DB (`crate::tune`) is indexed by and the eligibility bit for
/// the direct (copy-free) im2col staging strategy.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvGemmShape {
    pub name: String,
    /// GEMM M at batch 1: `oh * ow` output positions.
    pub rows: usize,
    /// GEMM K: `kh * kw * cin` patch elements.
    pub k: usize,
    /// GEMM N: output channels.
    pub cout: usize,
    /// 1×1 / stride 1 / pad 0 — im2col is the identity permutation.
    pub unit: bool,
}

/// Per-conv GEMM shapes in node order (shared by `dlrt tune`, the tuned
/// compile path, and `format::load`'s cross-ISA schedule re-resolution).
pub fn conv_gemm_shapes(g: &Graph) -> Result<Vec<ConvGemmShape>> {
    let shapes = g.infer_shapes()?;
    let mut out = Vec::new();
    for n in &g.nodes {
        if let Op::Conv2d { kernel, stride, padding, cin, cout, .. } = &n.op {
            let os = &shapes[&n.output];
            // output shape is [n, oh, ow, cout]; rows is per batch item
            let rows: usize = os[1..os.len() - 1].iter().product();
            let unit = *kernel == [1, 1] && *stride == [1, 1] && *padding == [0, 0];
            out.push(ConvGemmShape {
                name: n.name.clone(),
                rows,
                k: kernel[0] * kernel[1] * cin,
                cout: *cout,
                unit,
            });
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// ExecPlan
// ---------------------------------------------------------------------------

/// Pass-pipeline switches (defaults on; benches toggle them for ablations).
#[derive(Clone, Copy, Debug)]
pub struct PlanOpts {
    /// Fold sole-consumer activations into conv epilogues.
    pub fuse_activations: bool,
    /// Lower last-consumer standalone activations to in-place mutation.
    pub in_place: bool,
    /// Fold sole-consumer residual `Add`s into conv epilogues.
    pub fuse_residual_add: bool,
    /// Let concat producers write channel stripes of the concat slot.
    pub concat_in_place: bool,
    /// Let consumers *read* channel stripes out of a concat root slot
    /// (multi-use producers stripe; concats stripe partially). Off =
    /// PR 4 behavior: sole-consumer producers only, all-or-nothing.
    pub strided_reads: bool,
    /// Run the static plan verifier ([`crate::exec::verify`]) on the
    /// produced plan and fail the build on any diagnostic. A checker, not
    /// a lowering pass, so it stays on even in [`PlanOpts::none`]; with it
    /// off, debug builds still verify behind a debug assertion.
    pub verify: bool,
}

impl Default for PlanOpts {
    fn default() -> Self {
        PlanOpts {
            fuse_activations: true,
            in_place: true,
            fuse_residual_add: true,
            concat_in_place: true,
            strided_reads: true,
            verify: true,
        }
    }
}

impl PlanOpts {
    /// Every pass disabled — the ablation baseline (one instruction per
    /// graph node, one slot per liveness interval, no aliasing). The
    /// verifier is not a pass and stays on.
    pub fn none() -> Self {
        PlanOpts {
            fuse_activations: false,
            in_place: false,
            fuse_residual_add: false,
            concat_in_place: false,
            strided_reads: false,
            verify: true,
        }
    }
}

/// Channel-stripe view of a wider slot: each logical row of the tensor
/// lives at column `off` of a row `stride` channels wide. As an *output*
/// view (`Instr::out_view`) a concat producer writes its rows directly
/// into its stripe of the concat root slot; as an *input* view
/// (`Instr::in_views`) a consumer reads a concat-resident tensor out of
/// the root slot without densifying it first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChanView {
    /// Total channels of a root-slot row (the concat output's channels).
    pub stride: usize,
    /// First channel of this instruction's stripe.
    pub off: usize,
}

impl ChanView {
    /// Channel range `[off, off + c)` of a `c`-channel tensor under this
    /// view (what the instruction actually touches in each root row).
    fn range(&self, c: usize) -> (usize, usize) {
        (self.off, self.off + c)
    }
}

/// Do two channel ranges overlap?
fn ranges_overlap(a: (usize, usize), b: (usize, usize)) -> bool {
    a.0 < b.1 && b.0 < a.1
}

/// One lowered instruction: an op reading input slots and writing one
/// output slot. Shape *tails* (dims after the batch dim) are frozen at plan
/// time; the executor prepends the request batch.
#[derive(Clone, Debug)]
pub struct Instr {
    /// Originating node name (diagnostics; the executor fetches kernels by
    /// `kernel_idx`, not by name).
    pub name: String,
    /// Resolved index into the model's conv or dense kernel vector
    /// (`CompiledModel::convs` / `::denses`, graph node order), assigned at
    /// plan time so the request path never does a name lookup. `Some` for
    /// exactly `Op::Conv2d` / `Op::Dense`, `None` otherwise — enforced by
    /// [`ExecPlan::validate`] and the static verifier.
    pub kernel_idx: Option<usize>,
    pub op: Op,
    /// Fused activation epilogue, applied before any fused add (convs only).
    pub fused: Option<ActKind>,
    /// Residual-add epilogue: `in_slots[1]` holds the residual, added to
    /// the conv result after `fused` and before `fused_post` (convs only).
    pub fused_add: bool,
    /// Activation applied after the fused residual add (the ResNet
    /// `conv → add → relu` tail; requires `fused_add`).
    pub fused_post: Option<ActKind>,
    pub in_slots: Vec<usize>,
    /// Per-input shape tails, aligned with `in_slots`.
    pub in_tails: Vec<Vec<usize>>,
    /// Channel-stripe placement of each input inside its slot (the input
    /// is concat-resident); `None` reads the slot densely. Aligned with
    /// `in_slots`.
    pub in_views: Vec<Option<ChanView>>,
    /// Destination channel offsets per input within the concat output —
    /// `Op::Concat` only, aligned with `in_slots`. A *partial* concat
    /// carries only its copy-fallback inputs here (the striped producers
    /// already wrote their stripes), so the offsets are explicit rather
    /// than running sums.
    pub cat_offs: Vec<usize>,
    /// `Op::Concat` only: some inputs were striped by producers, so the
    /// copies legitimately cover only part of the output's channels.
    /// `validate` requires a non-partial concat's copies to cover every
    /// channel — a full-copy plan with a missing input must be a plan
    /// error, not stale arena bytes.
    pub cat_partial: bool,
    pub out_slot: usize,
    pub out_tail: Vec<usize>,
    /// Channel-stripe placement of the output inside `out_slot` (concat
    /// in-place producers); `None` writes the slot densely.
    pub out_view: Option<ChanView>,
    /// Activation lowered to mutate its own slot (`in_slots[0] == out_slot`).
    pub in_place: bool,
}

/// Where a graph output lives after the plan runs.
#[derive(Clone, Debug)]
pub struct OutSpec {
    pub slot: usize,
    pub tail: Vec<usize>,
}

/// A lowered, ready-to-execute program: topologically ordered instructions
/// over arena buffer slots. Built once per [`crate::exec::CompiledModel`]
/// and shared read-only by every executor (the coordinator's batch workers
/// all run the same plan against private arenas).
#[derive(Clone, Debug)]
pub struct ExecPlan {
    pub instrs: Vec<Instr>,
    /// Per-slot f32 elements for one batch item (max over tensors that
    /// ever occupy the slot).
    pub slot_sizes: Vec<usize>,
    pub input_slot: usize,
    pub input_tail: Vec<usize>,
    pub outputs: Vec<OutSpec>,
    /// Batch the graph was planned at (shapes rescale linearly).
    pub nominal_batch: usize,
    /// Size of the conv kernel table the plan's `kernel_idx` values index
    /// (= number of `Op::Conv2d` nodes; the executor cross-checks it
    /// against `CompiledModel::convs.len()` before every run).
    pub conv_kernels: usize,
    /// As `conv_kernels`, for `Op::Dense` / `CompiledModel::denses`.
    pub dense_kernels: usize,
    /// Concat nodes elided entirely (every producer writes its stripe).
    pub in_place_concats: usize,
    /// Concat nodes that striped some producers and copy only the rest.
    pub partial_concats: usize,
    /// Why each copy-fallback concat input kept the copy path, one entry
    /// per ineligible producer (`dlrt inspect --plan` prints these).
    pub concat_fallbacks: Vec<String>,
}

/// Fused-epilogue suffix in the order the epilogue applies it
/// (`+relu +add +relu`), shared by `dlrt inspect --plan` and the
/// profiler's instruction labels.
pub fn fused_label(ins: &Instr) -> String {
    let mut out = String::new();
    let mut push = |tag: &str| {
        if !out.is_empty() {
            out.push(' ');
        }
        out.push('+');
        out.push_str(tag);
    };
    if let Some(a) = ins.fused {
        push(a.name());
    }
    if ins.fused_add {
        push("add");
    }
    if let Some(a) = ins.fused_post {
        push(a.name());
    }
    out
}

impl ExecPlan {
    /// Total arena f32 elements needed for `batch`.
    pub fn arena_elems(&self, batch: usize) -> usize {
        self.slot_sizes.iter().sum::<usize>() * batch
    }

    /// Arena bytes needed for `batch` (4 bytes per f32 element). This is
    /// the number the serving layer sizes batches and queues against.
    pub fn arena_bytes(&self, batch: usize) -> usize {
        4 * self.arena_elems(batch)
    }

    /// f32 elements of a single request input (batch 1).
    pub fn input_elems(&self) -> usize {
        self.input_tail.iter().product()
    }

    /// Bytes held by one queued request input (batch 1, f32).
    pub fn input_bytes(&self) -> usize {
        4 * self.input_elems()
    }

    /// Largest batch whose arena fits in `budget_bytes`. Never returns 0:
    /// a budget smaller than one batch item degrades to unbatched serving
    /// rather than refusing to serve at all.
    pub fn max_batch_for_budget(&self, budget_bytes: usize) -> usize {
        let per_item = self.arena_bytes(1).max(1);
        (budget_bytes / per_item).max(1)
    }

    pub fn fused_instrs(&self) -> usize {
        self.instrs.iter().filter(|i| i.fused.is_some()).count()
    }

    /// Convs that absorbed a residual `Add` into their epilogue.
    pub fn fused_add_instrs(&self) -> usize {
        self.instrs.iter().filter(|i| i.fused_add).count()
    }

    /// Instructions writing a channel stripe of a concat output slot.
    pub fn strided_instrs(&self) -> usize {
        self.instrs.iter().filter(|i| i.out_view.is_some()).count()
    }

    /// Instructions reading at least one input through a channel-stripe
    /// view (a concat-resident tensor consumed without densification).
    pub fn read_view_instrs(&self) -> usize {
        self.instrs.iter().filter(|i| i.in_views.iter().any(|v| v.is_some())).count()
    }

    /// Instructions that read and write *disjoint stripes of one slot*
    /// (the SPPF pattern: a pool consuming one pyramid level and producing
    /// the next, both resident in the same concat root).
    pub fn same_slot_stripe_instrs(&self) -> usize {
        self.instrs
            .iter()
            .filter(|i| !i.in_place && i.in_slots.iter().any(|&s| s == i.out_slot))
            .count()
    }

    /// Remaining `copy_channels` passes: Concat instructions left in the
    /// plan (each copies its listed inputs; striped inputs don't appear).
    pub fn concat_copy_instrs(&self) -> usize {
        self.instrs.iter().filter(|i| matches!(i.op, Op::Concat)).count()
    }

    pub fn in_place_instrs(&self) -> usize {
        self.instrs.iter().filter(|i| i.in_place).count()
    }

    /// Static per-instruction metadata for profiler/trace labels: op class,
    /// kernel-table index, FLOPs and activation bytes moved per batch item.
    /// Labels only — execution never consults this, so it adds no plan
    /// footprint for the verifier to model.
    pub fn instr_meta(&self) -> Vec<crate::obs::InstrMeta> {
        self.instrs
            .iter()
            .map(|ins| {
                let out_elems = ins.out_tail.iter().product::<usize>() as u64;
                let in_elems: u64 =
                    ins.in_tails.iter().map(|t| t.iter().product::<usize>() as u64).sum();
                let flops = match &ins.op {
                    // 2·MACs over the output pixels (fused epilogues are
                    // O(out_elems), negligible next to the GEMM)
                    Op::Conv2d { kernel, cin, cout, .. } => {
                        let pixels = ins.out_tail[..ins.out_tail.len() - 1]
                            .iter()
                            .product::<usize>() as u64;
                        2 * pixels * (kernel[0] * kernel[1] * cin * cout) as u64
                    }
                    Op::Dense { cin, cout } => 2 * (cin * cout) as u64,
                    _ => out_elems,
                };
                crate::obs::InstrMeta {
                    name: ins.name.clone(),
                    op: ins.op.name(),
                    class: crate::obs::op_class(ins.op.name()),
                    kernel_idx: ins.kernel_idx,
                    out_slot: ins.out_slot,
                    flops,
                    bytes: 4 * (in_elems + out_elems),
                    fused: fused_label(ins),
                    strided: ins.out_view.is_some()
                        || ins.in_views.iter().any(|v| v.is_some()),
                    in_place: ins.in_place,
                }
            })
            .collect()
    }

    /// Bounds/aliasing checks the executor's unsafe slot views rely on: a
    /// non-in-place instruction never writes a slot it reads, every slot id
    /// is in range, and every tensor fits its slot's per-batch size.
    ///
    /// `build_plan_with` validates every plan it produces, and — because
    /// the plan fields are public and swappable (the fig7 ablation does
    /// exactly that) — the executor re-runs this per request; it is
    /// O(instructions) and allocation-free.
    pub fn validate(&self) -> Result<()> {
        let n = self.slot_sizes.len();
        // overflow-checked products: a hostile plan (or a malformed .dlrt
        // header re-lowered by format::load) declaring astronomical dims
        // must fail validation, not wrap into passing bounds checks that
        // the unsafe arena views then trust
        let numel_checked = |tail: &[usize]| -> Option<usize> {
            tail.iter().try_fold(1usize, |a, &d| a.checked_mul(d))
        };
        let fits = |tail: &[usize], slot: usize| -> bool {
            slot < n && matches!(numel_checked(tail), Some(e) if e <= self.slot_sizes[slot])
        };
        let numel = |tail: &[usize]| -> usize { tail.iter().product() };
        if !fits(&self.input_tail, self.input_slot) {
            return Err(anyhow!("plan: input tensor does not fit its slot"));
        }
        for ins in &self.instrs {
            let arity_ok = ins.in_slots.len() == ins.in_tails.len()
                && ins.in_views.len() == ins.in_slots.len()
                // destination offsets exist exactly for concat copies
                && (if matches!(ins.op, Op::Concat) {
                    ins.cat_offs.len() == ins.in_slots.len()
                } else {
                    ins.cat_offs.is_empty() && !ins.cat_partial
                })
                && match &ins.op {
                    Op::Add => ins.in_slots.len() == 2,
                    Op::Concat => !ins.in_slots.is_empty(),
                    // a fused residual add carries its second accumulator
                    // (the residual) as a second input
                    Op::Conv2d { .. } => {
                        ins.in_slots.len() == if ins.fused_add { 2 } else { 1 }
                    }
                    _ => ins.in_slots.len() == 1,
                };
            // per-op shape legality: recompute the output shape the way
            // exec_instr's kernels will and require the stored tail to
            // match, so a swapped plan can neither panic in a kernel nor
            // silently truncate its output (guarded by arity_ok)
            let shape_ok = arity_ok
                && match &ins.op {
                    Op::Conv2d { stride, padding, kernel, cin, cout, .. } => {
                        let t = &ins.in_tails[0];
                        t.len() == 3
                            && ins.out_tail.len() == 3
                            && t[2] == *cin
                            && conv_out_hw_checked(t[0], t[1], *kernel, *stride, *padding)
                                == Some((ins.out_tail[0], ins.out_tail[1]))
                            && ins.out_tail[2] == *cout
                            // the residual accumulator must be exactly one
                            // output's worth of elements
                            && (!ins.fused_add
                                || numel(&ins.in_tails[1]) == numel(&ins.out_tail))
                    }
                    Op::MaxPool2d { kernel, stride, padding } => {
                        let t = &ins.in_tails[0];
                        t.len() == 3
                            && ins.out_tail.len() == 3
                            && conv_out_hw_checked(t[0], t[1], *kernel, *stride, *padding)
                                == Some((ins.out_tail[0], ins.out_tail[1]))
                            && ins.out_tail[2] == t[2]
                    }
                    Op::Upsample2x => {
                        let t = &ins.in_tails[0];
                        t.len() == 3
                            && ins.out_tail.len() == 3
                            && ins.out_tail[0] == 2 * t[0]
                            && ins.out_tail[1] == 2 * t[1]
                            && ins.out_tail[2] == t[2]
                    }
                    Op::GlobalAvgPool => {
                        let t = &ins.in_tails[0];
                        t.len() == 3
                            && ins.out_tail.len() == 1
                            && ins.out_tail[0] == t[2]
                    }
                    Op::Concat => {
                        // a partial concat's copies may cover only a
                        // subset of the output's channels (striped
                        // producers wrote the rest): offsets must be
                        // ascending, disjoint, and inside the output row.
                        // A full-copy concat must cover *every* channel —
                        // gap-free offsets summing to the output width.
                        ins.out_tail.len() == 3
                            && ins.in_tails.iter().all(|t| {
                                t.len() == 3
                                    && t[0] == ins.out_tail[0]
                                    && t[1] == ins.out_tail[1]
                            })
                            && ins
                                .in_tails
                                .iter()
                                .zip(&ins.cat_offs)
                                .try_fold(0usize, |prev, (t, &off)| {
                                    let end = off.checked_add(t[2])?;
                                    (off >= prev
                                        && end <= ins.out_tail[2]
                                        && (ins.cat_partial || off == prev))
                                        .then_some(end)
                                })
                                .is_some_and(|covered| {
                                    ins.cat_partial || covered == ins.out_tail[2]
                                })
                    }
                    Op::Add => {
                        numel(&ins.in_tails[0]) == numel(&ins.out_tail)
                            && numel(&ins.in_tails[1]) == numel(&ins.out_tail)
                    }
                    Op::Dense { cin, cout } => {
                        *cin > 0
                            && ins.in_tails[0].last() == Some(cin)
                            && ins.out_tail.last() == Some(cout)
                            && ins.out_tail.len() == ins.in_tails[0].len()
                            && ins.out_tail[..ins.out_tail.len() - 1]
                                == ins.in_tails[0][..ins.in_tails[0].len() - 1]
                    }
                    Op::Relu | Op::Relu6 | Op::Silu | Op::LeakyRelu | Op::Sigmoid => {
                        numel(&ins.in_tails[0]) == numel(&ins.out_tail)
                    }
                    Op::Flatten => true, // exec_instr rejects it with an error
                };
            // conv/dense instructions must carry an in-range resolved
            // kernel index (the executor indexes the kernel vectors with it
            // unchecked beyond this); no other op may carry one
            let kernel_idx_ok = match &ins.op {
                Op::Conv2d { .. } => {
                    matches!(ins.kernel_idx, Some(i) if i < self.conv_kernels)
                }
                Op::Dense { .. } => {
                    matches!(ins.kernel_idx, Some(i) if i < self.dense_kernels)
                }
                _ => ins.kernel_idx.is_none(),
            };
            // in-place is only meaningful (and only handled by exec_instr)
            // for activations; anything else would alias read/write views
            let in_place_ok = !ins.in_place || ActKind::from_op(&ins.op).is_some();
            // fused epilogues are a conv-only concept: exec_instr reads
            // `fused`/`fused_add`/`fused_post` nowhere else, so they must
            // not appear anywhere else — and a post-add activation without
            // a fused add would be indistinguishable from `fused`
            let fused_ok = ((ins.fused.is_none() && !ins.fused_add
                && ins.fused_post.is_none())
                || matches!(ins.op, Op::Conv2d { .. }))
                && (ins.fused_post.is_none() || ins.fused_add);
            // strided output views exist only for the ops exec_instr
            // implements stride-aware writes for, never in-place, and the
            // stripe must lie inside a row
            let view_ok = match &ins.out_view {
                None => true,
                Some(v) => {
                    let capable = matches!(
                        ins.op,
                        Op::Conv2d { .. } | Op::MaxPool2d { .. } | Op::Upsample2x
                            | Op::Concat
                    ) || ActKind::from_op(&ins.op).is_some();
                    capable
                        && !ins.in_place
                        && !ins.out_tail.is_empty()
                        && ins
                            .out_tail
                            .last()
                            .and_then(|&c| v.off.checked_add(c))
                            .is_some_and(|end| end <= v.stride)
                }
            };
            // input views exist only on the inputs exec_instr routes
            // through a strided read path, and the stripe must lie inside
            // a root row and its rows×stride footprint inside the slot
            let read_capable = |op: &Op, idx: usize| -> bool {
                match op {
                    Op::Conv2d { .. } => idx == 0, // residual reads are dense
                    Op::MaxPool2d { .. } | Op::Upsample2x | Op::GlobalAvgPool => {
                        idx == 0
                    }
                    Op::Concat => true,
                    op => ActKind::from_op(op).is_some() && idx == 0,
                }
            };
            let in_ok = ins.in_slots.iter().enumerate().all(|(i, &s)| {
                let t = &ins.in_tails[i];
                match &ins.in_views[i] {
                    None => fits(t, s),
                    Some(v) => {
                        read_capable(&ins.op, i)
                            && !ins.in_place
                            && !t.is_empty()
                            && t.last()
                                .and_then(|&c| v.off.checked_add(c))
                                .is_some_and(|end| end <= v.stride)
                            && s < n
                            && matches!(
                                numel_checked(&t[..t.len() - 1])
                                    .and_then(|r| r.checked_mul(v.stride)),
                                Some(e) if e <= self.slot_sizes[s]
                            )
                    }
                }
            });
            let aliasing_ok = if ins.in_place {
                ins.in_slots.first() == Some(&ins.out_slot)
                    && ins.in_views.iter().all(|v| v.is_none())
            } else {
                // an input may share the output slot only when it is read
                // through a channel-stripe view of the same root row
                // (equal stride) whose range clears everything the
                // instruction writes — the SPPF pattern of a pool reading
                // one pyramid level and writing the next, or a concat
                // copying an input resident in its own root. A dense
                // concat output counts as a full-width view at offset 0
                // (only its cat_offs destination stripes are written).
                ins.in_slots.iter().enumerate().all(|(i, &s)| {
                    if s != ins.out_slot {
                        return true;
                    }
                    let iv = match &ins.in_views[i] {
                        Some(iv) => iv,
                        None => return false,
                    };
                    let ov = match &ins.out_view {
                        Some(ov) => *ov,
                        None => match (&ins.op, ins.out_tail.last()) {
                            // dense concat out: writes only its dest
                            // stripes of the ctot-wide root row
                            (Op::Concat, Some(&ctot)) => {
                                ChanView { stride: ctot, off: 0 }
                            }
                            _ => return false,
                        },
                    };
                    if iv.stride != ov.stride {
                        return false;
                    }
                    let cin = ins.in_tails[i].last().copied().unwrap_or(0);
                    let r = iv.range(cin);
                    if matches!(ins.op, Op::Concat) {
                        // copies land at ov.off + cat_offs[j]; the read
                        // stripe must clear every destination stripe
                        ins.in_tails.iter().zip(&ins.cat_offs).all(|(t, &o)| {
                            let c = t.last().copied().unwrap_or(0);
                            let d0 = ov.off.saturating_add(o);
                            !ranges_overlap(r, (d0, d0.saturating_add(c)))
                        })
                    } else {
                        let cout = ins.out_tail.last().copied().unwrap_or(0);
                        !ranges_overlap(r, ov.range(cout))
                    }
                })
            };
            // a strided instruction occupies rows × view.stride elements of
            // its slot, not numel(out_tail)
            let out_fits = match &ins.out_view {
                None => fits(&ins.out_tail, ins.out_slot),
                Some(v) => {
                    ins.out_slot < n
                        && !ins.out_tail.is_empty()
                        && matches!(
                            numel_checked(&ins.out_tail[..ins.out_tail.len() - 1])
                                .and_then(|r| r.checked_mul(v.stride)),
                            Some(e) if e <= self.slot_sizes[ins.out_slot]
                        )
                }
            };
            if !shape_ok
                || !kernel_idx_ok
                || !in_place_ok
                || !fused_ok
                || !view_ok
                || !in_ok
                || !aliasing_ok
                || !out_fits
            {
                return Err(anyhow!(
                    "plan invariant violated at {:?} ({}): in={:?} out={} of {n} slots",
                    ins.name,
                    ins.op.name(),
                    ins.in_slots,
                    ins.out_slot
                ));
            }
        }
        for o in &self.outputs {
            if !fits(&o.tail, o.slot) {
                return Err(anyhow!("plan: output tensor does not fit its slot"));
            }
        }
        Ok(())
    }
}

/// Lower `g` with the default pass pipeline.
pub fn build_plan(g: &Graph) -> Result<ExecPlan> {
    build_plan_with(g, PlanOpts::default())
}

/// Working node during lowering (fusion rewrites outputs / drops nodes).
struct WNode {
    name: String,
    op: Op,
    inputs: Vec<String>,
    output: String,
    fused: Option<ActKind>,
    fused_add: bool,
    fused_post: Option<ActKind>,
    /// Concat elided by the in-place pass: producers already wrote their
    /// stripes, so no instruction is emitted — only a slot binding.
    elide: bool,
    /// Concat only: which inputs stripe in place (aligned with `inputs`).
    /// Non-striped inputs stay on this concat's copy instruction. Empty
    /// means no input stripes (pre-pass default).
    striped: Vec<bool>,
}

/// Consumer count of tensor `t` over the current (post-fusion) node list;
/// graph outputs count as one extra consumer.
fn uses_of(nodes: &[WNode], outputs: &[String], t: &str) -> usize {
    nodes.iter().flat_map(|n| n.inputs.iter()).filter(|i| i.as_str() == t).count()
        + outputs.iter().filter(|o| o.as_str() == t).count()
}

/// Why concat input `t` of concat node `ci` cannot write its channel
/// stripe of the concat root directly — `None` means eligible. With
/// `strided_reads` every *other* consumer of `t` is checked for a strided
/// read path (im2col / pool / upsample / gap / activation / concat copy);
/// without it any multi-use tensor is ineligible (the PR 4 rule).
fn stripe_ineligibility(
    nodes: &[WNode],
    g: &Graph,
    ci: usize,
    t: &str,
    placement: &BTreeMap<String, (String, usize)>,
    strided_reads: bool,
) -> Option<String> {
    if nodes[ci].inputs.iter().filter(|x| x.as_str() == t).count() > 1 {
        return Some(format!("{t:?} appears more than once in this concat"));
    }
    let producer = nodes[..ci].iter().find(|n| n.output == t);
    if t == g.input_name || producer.is_none() {
        return Some(format!("{t:?} is the graph input"));
    }
    if g.outputs.iter().any(|o| o == t) {
        return Some(format!("{t:?} is a graph output (extracted densely)"));
    }
    if placement.contains_key(t) {
        return Some(format!("{t:?} is already striped into another concat"));
    }
    let p = producer.expect("checked above");
    let write_capable = matches!(
        p.op,
        Op::Conv2d { .. } | Op::MaxPool2d { .. } | Op::Upsample2x | Op::Concat
    ) || ActKind::from_op(&p.op).is_some();
    if !write_capable {
        return Some(format!(
            "{t:?} produced by {} ({}, no strided write path)",
            p.name,
            p.op.name()
        ));
    }
    if !strided_reads {
        let uses = uses_of(nodes, &g.outputs, t);
        if uses != 1 {
            return Some(format!("{t:?} has {uses} consumers"));
        }
        return None;
    }
    // every consumer besides this concat must read through a view
    for (k, n) in nodes.iter().enumerate() {
        if k == ci {
            continue;
        }
        for (idx, inp) in n.inputs.iter().enumerate() {
            if inp != t {
                continue;
            }
            let ok = match &n.op {
                // a residual-fused conv reads its second input densely in
                // the epilogue; the im2col'd main input reads strided
                Op::Conv2d { .. } => idx == 0,
                Op::MaxPool2d { .. } | Op::Upsample2x | Op::GlobalAvgPool
                | Op::Concat => true,
                op => ActKind::from_op(op).is_some(),
            };
            if !ok {
                let what = if matches!(n.op, Op::Conv2d { .. }) {
                    "consumed as a residual by"
                } else {
                    "consumed by"
                };
                return Some(format!(
                    "{t:?} {what} {} ({}, no strided read path)",
                    n.name,
                    n.op.name()
                ));
            }
        }
    }
    None
}

/// Slot allocator state: sizes/liveness plus the tensor-name bindings.
/// `live[s]` counts live tensor names bound to slot `s` (aliases mean a
/// slot can host several names at once); a slot is free only at zero.
struct SlotState {
    sizes: Vec<usize>,
    live: Vec<usize>,
    free: Vec<usize>,
    binding: BTreeMap<String, usize>,
    remaining: BTreeMap<String, usize>,
}

impl SlotState {
    /// Best-fit: smallest free slot that already holds `elems`; else grow
    /// the **largest** free slot to `elems` (cheapest growth); a brand-new
    /// slot is opened only when the free list is empty. Best-fit keeps
    /// small tensors from squatting in large recycled buffers.
    fn alloc(&mut self, elems: usize) -> usize {
        let pick = self
            .free
            .iter()
            .copied()
            .filter(|&s| self.sizes[s] >= elems)
            .min_by_key(|&s| self.sizes[s])
            .or_else(|| self.free.iter().copied().max_by_key(|&s| self.sizes[s]));
        match pick {
            Some(s) => {
                self.free.retain(|&f| f != s);
                if self.sizes[s] < elems {
                    self.sizes[s] = elems;
                }
                s
            }
            None => {
                self.sizes.push(elems);
                self.live.push(0);
                self.sizes.len() - 1
            }
        }
    }

    fn bind(&mut self, name: &str, slot: usize, elems: usize) {
        self.binding.insert(name.to_string(), slot);
        self.live[slot] += 1;
        if self.sizes[slot] < elems {
            self.sizes[slot] = elems;
        }
    }

    fn slot_of(&self, name: &str) -> Result<usize> {
        self.binding
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("plan: tensor {name:?} is not live"))
    }

    /// Consume one use of each input; a tensor whose uses hit zero unbinds,
    /// and a slot with no remaining bindings returns to the free list.
    /// (Graph outputs carry a permanent extra use, so they never unbind.)
    fn release(&mut self, inputs: &[String]) {
        for t in inputs {
            if let Some(c) = self.remaining.get_mut(t) {
                *c -= 1;
                if *c == 0 {
                    if let Some(s) = self.binding.remove(t) {
                        self.live[s] -= 1;
                        if self.live[s] == 0 {
                            self.free.push(s);
                        }
                    }
                }
            }
        }
    }
}

/// Lower `g` into an [`ExecPlan`] with explicit pass switches.
pub fn build_plan_with(g: &Graph, opts: PlanOpts) -> Result<ExecPlan> {
    let shapes = g.infer_shapes()?; // also surfaces static shape mismatches
    let tail_of = |t: &str| -> Vec<usize> { shapes[t][1..].to_vec() };
    let per_batch = |t: &str| -> usize { shapes[t][1..].iter().product() };

    // kernel-index resolution: conv/dense node name → ordinal in graph node
    // order, matching the layout the compiler builds CompiledModel::convs /
    // ::denses in. Fusion rewrites a node's *output*, never its name, so
    // these survive every pass below.
    let mut conv_ord: BTreeMap<&str, usize> = BTreeMap::new();
    let mut dense_ord: BTreeMap<&str, usize> = BTreeMap::new();
    for n in &g.nodes {
        match n.op {
            Op::Conv2d { .. } => {
                let i = conv_ord.len();
                conv_ord.insert(n.name.as_str(), i);
            }
            Op::Dense { .. } => {
                let i = dense_ord.len();
                dense_ord.insert(n.name.as_str(), i);
            }
            _ => {}
        }
    }

    let mut nodes: Vec<WNode> = g
        .nodes
        .iter()
        .map(|n| WNode {
            name: n.name.clone(),
            op: n.op.clone(),
            inputs: n.inputs.clone(),
            output: n.output.clone(),
            fused: None,
            fused_add: false,
            fused_post: None,
            elide: false,
            striped: Vec::new(),
        })
        .collect();

    // --- pass 1: activation fusion -------------------------------------
    if opts.fuse_activations {
        let mut i = 0;
        while i < nodes.len() {
            if matches!(nodes[i].op, Op::Conv2d { .. }) {
                let out = nodes[i].output.clone();
                if uses_of(&nodes, &g.outputs, &out) == 1 {
                    if let Some(j) =
                        nodes.iter().position(|n| n.inputs.iter().any(|t| *t == out))
                    {
                        if let Some(a) = ActKind::from_op(&nodes[j].op) {
                            let act_out = nodes[j].output.clone();
                            nodes[i].fused = Some(a);
                            nodes[i].output = act_out;
                            nodes.remove(j);
                        }
                    }
                }
            }
            i += 1;
        }
    }

    // --- pass 2: Add/residual fusion -----------------------------------
    // A conv whose (possibly activation-fused) output is consumed only by
    // an Add, where the add's other operand is already live when the conv
    // runs (graph input or produced by an earlier node), absorbs the add:
    // the residual becomes the conv's second input and the epilogue's
    // second accumulator. One add per conv (`fused_add` guard): a chain
    // `add → add` fuses only its first link.
    if opts.fuse_residual_add {
        let mut i = 0;
        while i < nodes.len() {
            if matches!(nodes[i].op, Op::Conv2d { .. }) && !nodes[i].fused_add {
                let out = nodes[i].output.clone();
                if uses_of(&nodes, &g.outputs, &out) == 1 {
                    if let Some(j) =
                        nodes.iter().position(|n| n.inputs.iter().any(|t| *t == out))
                    {
                        if matches!(nodes[j].op, Op::Add) {
                            let other = if nodes[j].inputs[0] == out {
                                nodes[j].inputs[1].clone()
                            } else {
                                nodes[j].inputs[0].clone()
                            };
                            let live_before_conv = other == g.input_name
                                || nodes[..i].iter().any(|n| n.output == other);
                            if live_before_conv {
                                let add_out = nodes[j].output.clone();
                                nodes[i].fused_add = true;
                                nodes[i].inputs.push(other);
                                nodes[i].output = add_out;
                                nodes.remove(j);
                            }
                        }
                    }
                }
            }
            i += 1;
        }
    }

    // --- pass 3: post-add activation fusion ----------------------------
    // After a residual fuse the conv's new sole consumer may be the block's
    // trailing activation (ResNet's `add → relu`); fold it in after the
    // residual add.
    if opts.fuse_activations {
        let mut i = 0;
        while i < nodes.len() {
            if nodes[i].fused_add && nodes[i].fused_post.is_none() {
                let out = nodes[i].output.clone();
                if uses_of(&nodes, &g.outputs, &out) == 1 {
                    if let Some(j) =
                        nodes.iter().position(|n| n.inputs.iter().any(|t| *t == out))
                    {
                        if let Some(a) = ActKind::from_op(&nodes[j].op) {
                            let act_out = nodes[j].output.clone();
                            nodes[i].fused_post = Some(a);
                            nodes[i].output = act_out;
                            nodes.remove(j);
                        }
                    }
                }
            }
            i += 1;
        }
    }

    // --- pass 4a: concat-in-place placement ----------------------------
    // Walk concats in reverse topological order so an outer concat claims
    // its stripes before an inner one composes into them (concat-of-concat
    // becomes stripes-of-stripes on the outermost root slot). Eligibility
    // is decided *per producer* (see `stripe_ineligibility`): eligible
    // inputs stripe in place even when their tensor has other consumers
    // (those read the stripe through input views), ineligible inputs stay
    // on the concat's copy instruction with their reason recorded. With
    // `strided_reads` off this degrades to PR 4's all-or-nothing,
    // sole-consumer-only rule (the ablation baseline).
    let mut placement: BTreeMap<String, (String, usize)> = BTreeMap::new();
    let mut in_place_concats = 0usize;
    let mut partial_concats = 0usize;
    let mut per_cat_fallbacks: Vec<Vec<String>> = Vec::new();
    if opts.concat_in_place {
        for ci in (0..nodes.len()).rev() {
            if !matches!(nodes[ci].op, Op::Concat) {
                continue;
            }
            let (root, base) = match placement.get(&nodes[ci].output) {
                Some((r, b)) => (r.clone(), *b),
                None => (nodes[ci].output.clone(), 0),
            };
            let inputs = nodes[ci].inputs.clone();
            let mut stripes: Vec<(usize, String, usize)> = Vec::new();
            let mut fallbacks: Vec<String> = Vec::new();
            let mut off = base;
            for (j, t) in inputs.iter().enumerate() {
                let c = *shapes[t].last().expect("concat input has channels");
                match stripe_ineligibility(&nodes, g, ci, t, &placement,
                                           opts.strided_reads) {
                    Some(w) => fallbacks.push(format!(
                        "{}: {t:?} copy fallback — {w}",
                        nodes[ci].name
                    )),
                    None => stripes.push((j, t.clone(), off)),
                }
                off += c;
            }
            if !fallbacks.is_empty() && !opts.strided_reads {
                // all-or-nothing without read views: the copy instruction
                // rebuilds the whole output, so nothing may stripe
                stripes.clear();
                fallbacks.truncate(1);
            }
            if fallbacks.is_empty() {
                nodes[ci].elide = true;
                in_place_concats += 1;
            } else if !stripes.is_empty() {
                partial_concats += 1;
            }
            let mut striped = vec![false; inputs.len()];
            for (j, t, o) in stripes {
                striped[j] = true;
                placement.insert(t, (root.clone(), o));
            }
            nodes[ci].striped = striped;
            per_cat_fallbacks.push(fallbacks);
        }
    }
    // report in topological order (we walked concats in reverse)
    let mut concat_fallbacks: Vec<String> = Vec::new();
    for v in per_cat_fallbacks.into_iter().rev() {
        concat_fallbacks.extend(v);
    }

    // remaining-use counts over the post-fusion node list (+1 per graph
    // output keeps output tensors bound for the plan's whole lifetime)
    let mut remaining: BTreeMap<String, usize> = BTreeMap::new();
    for n in &nodes {
        for t in &n.inputs {
            *remaining.entry(t.clone()).or_insert(0) += 1;
        }
    }
    for o in &g.outputs {
        *remaining.entry(o.clone()).or_insert(0) += 1;
    }

    // --- passes 4b+5: in-place / alias lowering + slot assignment -------
    let mut st = SlotState {
        sizes: Vec::new(),
        live: Vec::new(),
        free: Vec::new(),
        binding: BTreeMap::new(),
        remaining,
    };
    let mut instrs: Vec<Instr> = Vec::new();
    // concat root tensor name → its (shared) arena slot, allocated by the
    // first striped producer and kept live by the bindings of every stripe
    // tensor plus, eventually, the concat output itself
    let mut root_slots: BTreeMap<String, usize> = BTreeMap::new();

    let input_slot = st.alloc(per_batch(&g.input_name));
    st.bind(&g.input_name, input_slot, per_batch(&g.input_name));

    // read-side placement: a concat-resident input is consumed through a
    // channel-stripe view of its root slot instead of being densified
    let view_of = |t: &str| -> Option<ChanView> {
        placement.get(t).map(|(root, off)| ChanView {
            stride: *shapes[root].last().expect("concat root has channels"),
            off: *off,
        })
    };

    for n in &nodes {
        if matches!(n.op, Op::Flatten) {
            // metadata-only alias: same slot, new shape tail, no instruction
            // (an aliased tensor is never concat-resident — a flatten
            // consumer makes its input stripe-ineligible)
            let s = st.slot_of(&n.inputs[0])?;
            st.bind(&n.output, s, per_batch(&n.output));
            st.release(&n.inputs);
            continue;
        }
        if n.elide {
            // in-place concat: every producer already wrote its channel
            // stripe of the root slot — bind the output, emit nothing
            let root = match placement.get(&n.output) {
                Some((r, _)) => r.clone(),
                None => n.output.clone(),
            };
            let s = *root_slots
                .get(&root)
                .ok_or_else(|| anyhow!("plan: concat root {root:?} has no slot"))?;
            st.bind(&n.output, s, per_batch(&root));
            st.release(&n.inputs);
            continue;
        }
        if matches!(n.op, Op::Concat) {
            // full or partial copy concat: emit copies for the non-striped
            // inputs only, at explicit destination offsets (the striped
            // producers already wrote their stripes of the root slot)
            let (root, base) = match placement.get(&n.output) {
                Some((r, b)) => (r.clone(), *b),
                None => (n.output.clone(), 0),
            };
            let s = match root_slots.get(&root) {
                Some(&s) => s,
                None => {
                    let s = st.alloc(per_batch(&root));
                    root_slots.insert(root.clone(), s);
                    s
                }
            };
            st.bind(&n.output, s, per_batch(&root));
            let mut in_slots = Vec::new();
            let mut in_tails = Vec::new();
            let mut in_views = Vec::new();
            let mut cat_offs = Vec::new();
            let mut off = 0usize;
            for (j, t) in n.inputs.iter().enumerate() {
                let c = *shapes[t].last().expect("concat input has channels");
                if !n.striped.get(j).copied().unwrap_or(false) {
                    in_slots.push(st.slot_of(t)?);
                    in_tails.push(tail_of(t));
                    in_views.push(view_of(t));
                    cat_offs.push(off);
                }
                off += c;
            }
            let out_view = if root == n.output {
                None
            } else {
                let stride = *shapes[&root].last().expect("concat root has channels");
                Some(ChanView { stride, off: base })
            };
            instrs.push(Instr {
                name: n.name.clone(),
                kernel_idx: None,
                op: n.op.clone(),
                fused: None,
                fused_add: false,
                fused_post: None,
                in_slots,
                in_tails,
                in_views,
                cat_offs,
                cat_partial: n.striped.iter().any(|&b| b),
                out_slot: s,
                out_tail: tail_of(&n.output),
                out_view,
                in_place: false,
            });
            st.release(&n.inputs);
            continue;
        }
        let mut in_slots = Vec::with_capacity(n.inputs.len());
        for t in &n.inputs {
            in_slots.push(st.slot_of(t)?);
        }
        let in_tails: Vec<Vec<usize>> = n.inputs.iter().map(|t| tail_of(t)).collect();
        let in_views: Vec<Option<ChanView>> =
            n.inputs.iter().map(|t| view_of(t)).collect();

        let sole_last_use = st.remaining.get(&n.inputs[0]).copied() == Some(1)
            && st.live[in_slots[0]] == 1;
        // gate on ActKind::from_op — the same mapping the executor
        // dispatches through — so the two can never drift apart. Striped
        // outputs never lower in place (they must land in the concat slot),
        // and neither do concat-resident *inputs*: mutating the stripe
        // in place would corrupt the concat output's channel range.
        if opts.in_place
            && ActKind::from_op(&n.op).is_some()
            && sole_last_use
            && !placement.contains_key(&n.output)
            && in_views[0].is_none()
        {
            let s = in_slots[0];
            st.bind(&n.output, s, per_batch(&n.output));
            instrs.push(Instr {
                name: n.name.clone(),
                kernel_idx: None,
                op: n.op.clone(),
                fused: None,
                fused_add: false,
                fused_post: None,
                in_slots,
                in_tails,
                in_views,
                cat_offs: Vec::new(),
                cat_partial: false,
                out_slot: s,
                out_tail: tail_of(&n.output),
                out_view: None,
                in_place: true,
            });
            st.release(&n.inputs);
            continue;
        }

        // output placement: a channel stripe of a concat root slot, or a
        // fresh (recycled) slot. Inputs stay bound during allocation so an
        // instruction never writes over a live input — except its own
        // stripe-disjoint concat root, which validate() checks.
        let (out_slot, out_view) = match placement.get(&n.output) {
            Some((root, off)) => {
                let s = match root_slots.get(root) {
                    Some(&s) => s,
                    None => {
                        let s = st.alloc(per_batch(root));
                        root_slots.insert(root.clone(), s);
                        s
                    }
                };
                st.bind(&n.output, s, per_batch(root));
                let stride = *shapes[root].last().expect("concat root has channels");
                (s, Some(ChanView { stride, off: *off }))
            }
            None => {
                let s = st.alloc(per_batch(&n.output));
                st.bind(&n.output, s, per_batch(&n.output));
                (s, None)
            }
        };
        let kernel_idx = match &n.op {
            Op::Conv2d { .. } => Some(
                *conv_ord
                    .get(n.name.as_str())
                    .ok_or_else(|| anyhow!("plan: conv {:?} missing from graph", n.name))?,
            ),
            Op::Dense { .. } => Some(
                *dense_ord
                    .get(n.name.as_str())
                    .ok_or_else(|| anyhow!("plan: dense {:?} missing from graph", n.name))?,
            ),
            _ => None,
        };
        instrs.push(Instr {
            name: n.name.clone(),
            kernel_idx,
            op: n.op.clone(),
            fused: n.fused,
            fused_add: n.fused_add,
            fused_post: n.fused_post,
            in_slots,
            in_tails,
            in_views,
            cat_offs: Vec::new(),
            cat_partial: false,
            out_slot,
            out_tail: tail_of(&n.output),
            out_view,
            in_place: false,
        });
        st.release(&n.inputs);
    }

    let mut outputs = Vec::with_capacity(g.outputs.len());
    for o in &g.outputs {
        outputs.push(OutSpec { slot: st.slot_of(o)?, tail: tail_of(o) });
    }

    let plan = ExecPlan {
        instrs,
        slot_sizes: st.sizes,
        input_slot,
        input_tail: tail_of(&g.input_name),
        outputs,
        nominal_batch: g.input_shape[0],
        conv_kernels: conv_ord.len(),
        dense_kernels: dense_ord.len(),
        in_place_concats,
        partial_concats,
        concat_fallbacks,
    };
    // every produced plan passes the same invariant check the executor
    // re-runs per request (see ExecPlan::validate)
    plan.validate()?;
    // ... and the deeper abstract-interpretation pass: alias, race, and
    // coverage analysis over the full instruction stream (exec/verify.rs).
    // Opting out still leaves a debug assertion — a planner bug must never
    // ship a plan the verifier would reject.
    if opts.verify {
        crate::exec::verify::verify(&plan)
            .map_err(|d| anyhow!("planner produced an invalid plan — {d}"))?;
    } else if cfg!(debug_assertions) {
        if let Err(d) = crate::exec::verify::verify(&plan) {
            panic!("planner produced an invalid plan — {d}");
        }
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrt::graph::QCfg;
    use crate::models::{tiny_test_graph, GraphBuilder};

    #[test]
    fn counts_match_consumers() {
        let g = tiny_test_graph(false);
        let counts = use_counts(&g);
        // every node input is counted; outputs get +1
        for n in &g.nodes {
            for i in &n.inputs {
                assert!(counts[i.as_str()] >= 1);
            }
        }
        for o in &g.outputs {
            assert!(counts[o.as_str()] >= 1);
        }
    }

    #[test]
    fn peak_is_bounded_by_total() {
        let g = tiny_test_graph(false);
        let shapes = g.infer_shapes().unwrap();
        let total: usize = shapes.values().map(|s| s.iter().product::<usize>()).sum();
        let peak = peak_live_elems(&g).unwrap();
        assert!(peak <= total);
        assert!(peak > 0);
    }

    #[test]
    fn memory_accounting_helpers() {
        let g = tiny_test_graph(false);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.arena_bytes(1), 4 * plan.arena_elems(1));
        assert_eq!(plan.arena_bytes(2), 2 * plan.arena_bytes(1));
        assert_eq!(plan.input_elems(), 8 * 8 * 3);
        assert_eq!(plan.input_bytes(), 4 * 8 * 8 * 3);
        // budget for exactly k items admits batch k; a starvation budget
        // still admits one
        assert_eq!(plan.max_batch_for_budget(plan.arena_bytes(3)), 3);
        assert_eq!(plan.max_batch_for_budget(plan.arena_bytes(1) - 1), 1);
        assert_eq!(plan.max_batch_for_budget(0), 1);
    }

    #[test]
    fn fuses_sole_consumer_activations() {
        // tiny graph: conv+relu, conv+relu, conv, gap → 6 nodes, 4 instrs
        let g = tiny_test_graph(false);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.instrs.len(), 4);
        assert_eq!(plan.fused_instrs(), 2);
        assert!(plan.instrs.iter().all(|i| !i.op.is_activation()));
    }

    #[test]
    fn fusion_opt_out_keeps_standalone_activations() {
        let g = tiny_test_graph(false);
        let plan = build_plan_with(&g, PlanOpts::none()).unwrap();
        assert_eq!(plan.instrs.len(), g.nodes.len());
        assert_eq!(plan.fused_instrs(), 0);
        assert_eq!(plan.in_place_instrs(), 0);
        assert_eq!(plan.fused_add_instrs(), 0);
        assert_eq!(plan.in_place_concats, 0);
    }

    /// conv → add → relu (the ResNet block tail): the add folds into the
    /// conv's epilogue as a second accumulator, the relu folds in after it,
    /// and the whole block costs one instruction and one slot fewer.
    #[test]
    fn residual_add_and_post_activation_fuse_into_conv() {
        let mut b = GraphBuilder::new("res", [1, 8, 8, 3], 5);
        let c1 = b.conv_named("c1", "input", 8, 3, 1, 1, QCfg::FP32, Some(Op::Relu));
        let c2 = b.conv_named("c2", &c1, 8, 3, 1, 1, QCfg::FP32, None);
        let s = b.add(&c2, &c1);
        let r = b.act_named("tail", &s, Op::Relu);
        let g = b.finish(vec![r]);
        let plan = build_plan(&g).unwrap();
        // c1 (+relu), c2 (+add +relu): two instructions total
        assert_eq!(plan.instrs.len(), 2, "{:?}", plan.instrs);
        assert_eq!(plan.fused_add_instrs(), 1);
        let c2i = &plan.instrs[1];
        assert!(c2i.fused_add);
        assert_eq!(c2i.fused_post, Some(ActKind::Relu));
        assert_eq!(c2i.fused, None);
        assert_eq!(c2i.in_slots.len(), 2);
        // the residual reads c1's slot; the output is a third, distinct slot
        assert_eq!(c2i.in_slots[1], plan.instrs[0].out_slot);
        assert!(c2i.in_slots.iter().all(|&s| s != c2i.out_slot));
        // and the fused plan needs strictly less arena than the unfused one
        let unfused = build_plan_with(&g, PlanOpts::none()).unwrap();
        assert!(
            plan.arena_elems(1) < unfused.arena_elems(1),
            "fused {} !< unfused {}",
            plan.arena_elems(1),
            unfused.arena_elems(1)
        );
    }

    /// conv → silu → add (the YOLO bottleneck order): the activation fuses
    /// first, then the add; the epilogue applies act *before* the residual.
    #[test]
    fn pre_activation_then_residual_add_fuses() {
        let q = QCfg::new(2, 2);
        let mut b = GraphBuilder::new("yolo", [1, 8, 8, 3], 6);
        let c1 = b.conv_named("c1", "input", 8, 1, 1, 0, q, Some(Op::Silu));
        let c2 = b.conv_named("c2", &c1, 8, 3, 1, 1, q, Some(Op::Silu));
        let s = b.add(&c2, &c1);
        let g = b.finish(vec![s]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.instrs.len(), 2);
        let c2i = &plan.instrs[1];
        assert_eq!(c2i.fused, Some(ActKind::Silu));
        assert!(c2i.fused_add);
        assert_eq!(c2i.fused_post, None);
    }

    /// An add whose conv operand comes *after* the other operand's producer
    /// fuses into that later conv, even when the conv is the add's second
    /// input (the ResNet downsample branch).
    #[test]
    fn add_fuses_into_whichever_conv_runs_last() {
        let mut b = GraphBuilder::new("down", [1, 8, 8, 3], 7);
        let c2 = b.conv_named("c2", "input", 8, 3, 2, 1, QCfg::FP32, None);
        let down = b.conv_named("down", "input", 8, 1, 2, 0, QCfg::FP32, None);
        let s = b.add(&c2, &down);
        let g = b.finish(vec![s]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.fused_add_instrs(), 1);
        // `down` runs after `c2`, so it absorbs the add
        let fused = plan.instrs.iter().find(|i| i.fused_add).unwrap();
        assert_eq!(fused.name, "down");
    }

    /// Residual fusion must not fire when the skip tensor isn't live yet
    /// (produced after the conv) or when the conv output has other uses.
    #[test]
    fn residual_fusion_requires_live_skip_and_sole_use() {
        // conv out also a graph output: two uses, no fusion
        let mut b = GraphBuilder::new("multiuse", [1, 8, 8, 3], 8);
        let c = b.conv_named("c", "input", 3, 3, 1, 1, QCfg::FP32, None);
        let s = b.add(&c, "input");
        let g = b.finish(vec![s, c.clone()]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.fused_add_instrs(), 0);
        assert!(plan.instrs.iter().any(|i| matches!(i.op, Op::Add)));
    }

    /// Every producer of the concat is a sole-consumer conv/pool: the
    /// concat is elided and each producer writes a channel stripe of the
    /// shared root slot.
    #[test]
    fn concat_producers_write_stripes_in_place() {
        let q = QCfg::new(2, 2);
        let mut b = GraphBuilder::new("cat", [1, 8, 8, 3], 9);
        let c1 = b.conv_named("c1", "input", 4, 3, 1, 1, q, Some(Op::Relu));
        let c2 = b.conv_named("c2", "input", 6, 3, 1, 1, QCfg::FP32, None);
        let cat = b.concat(&[&c1, &c2]);
        let g = b.finish(vec![cat]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.in_place_concats, 1);
        assert!(plan.concat_fallbacks.is_empty(), "{:?}", plan.concat_fallbacks);
        assert!(plan.instrs.iter().all(|i| !matches!(i.op, Op::Concat)));
        let v1 = plan.instrs[0].out_view.expect("c1 striped");
        let v2 = plan.instrs[1].out_view.expect("c2 striped");
        assert_eq!((v1.stride, v1.off), (10, 0));
        assert_eq!((v2.stride, v2.off), (10, 4));
        assert_eq!(plan.instrs[0].out_slot, plan.instrs[1].out_slot);
        // no copy pass and no per-producer slots: fused arena is smaller
        let unfused = build_plan_with(&g, PlanOpts::none()).unwrap();
        assert!(plan.arena_elems(1) < unfused.arena_elems(1));
    }

    /// Concat-of-concat composes: the inner concat's producers stripe
    /// straight into the outer root slot at compound offsets.
    #[test]
    fn nested_concats_compose_stripes_on_one_root() {
        let mut b = GraphBuilder::new("nest", [1, 8, 8, 3], 10);
        let a = b.conv_named("a", "input", 2, 1, 1, 0, QCfg::FP32, None);
        let c = b.conv_named("c", "input", 3, 1, 1, 0, QCfg::FP32, None);
        let inner = b.concat(&[&a, &c]);
        let d = b.conv_named("d", "input", 4, 1, 1, 0, QCfg::FP32, None);
        let outer = b.concat(&[&d, &inner]);
        let g = b.finish(vec![outer]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.in_place_concats, 2);
        assert!(plan.instrs.iter().all(|i| !matches!(i.op, Op::Concat)));
        // one root slot, stripes at 0 (d), 4 (a), 6 (c), all stride 9
        let views: Vec<ChanView> =
            plan.instrs.iter().map(|i| i.out_view.expect("striped")).collect();
        assert!(views.iter().all(|v| v.stride == 9));
        let mut offs: Vec<usize> = views.iter().map(|v| v.off).collect();
        offs.sort_unstable();
        assert_eq!(offs, vec![0, 4, 6]);
        let slots: Vec<usize> = plan.instrs.iter().map(|i| i.out_slot).collect();
        assert!(slots.windows(2).all(|w| w[0] == w[1]));
    }

    /// A multi-use producer (the SPPF pattern) stripes anyway under the
    /// default pipeline: its pool consumer reads the stripe through an
    /// input view of the concat root — including the stripe-to-stripe
    /// same-slot case. With `strided_reads` off the PR 4 all-or-nothing
    /// copy fallback returns, reason recorded for `inspect --plan`.
    #[test]
    fn multi_use_concat_producer_stripes_with_read_views() {
        let mut b = GraphBuilder::new("sppf", [1, 8, 8, 3], 11);
        let c = b.conv_named("c", "input", 4, 1, 1, 0, QCfg::FP32, None);
        let p = b.maxpool(&c, 3, 1, 1); // c feeds both pool and concat
        let cat = b.concat(&[&c, &p]);
        let g = b.finish(vec![cat]);

        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.in_place_concats, 1);
        assert!(plan.concat_fallbacks.is_empty(), "{:?}", plan.concat_fallbacks);
        assert!(plan.instrs.iter().all(|i| !matches!(i.op, Op::Concat)));
        assert_eq!(plan.concat_copy_instrs(), 0);
        // the pool reads c's stripe and writes its own stripe of the same
        // root slot (disjoint channel ranges)
        let pool = &plan.instrs[1];
        assert_eq!(pool.in_views[0], Some(ChanView { stride: 8, off: 0 }));
        assert_eq!(pool.out_view, Some(ChanView { stride: 8, off: 4 }));
        assert_eq!(pool.in_slots[0], pool.out_slot);
        assert_eq!(plan.read_view_instrs(), 1);
        assert_eq!(plan.same_slot_stripe_instrs(), 1);

        // ablation baseline: no read views → the old copy fallback
        let old = build_plan_with(
            &g,
            PlanOpts { strided_reads: false, ..PlanOpts::default() },
        )
        .unwrap();
        assert_eq!(old.in_place_concats, 0);
        assert_eq!(old.concat_fallbacks.len(), 1);
        assert!(old.concat_fallbacks[0].contains("2 consumers"),
                "{:?}", old.concat_fallbacks);
        assert!(old.instrs.iter().any(|i| matches!(i.op, Op::Concat)));
    }

    /// Mixed eligibility: the conv producer stripes in place while the
    /// graph-input operand keeps a (partial) copy instruction carrying
    /// only that input, at its explicit destination offset.
    #[test]
    fn partial_concat_stripes_eligible_and_copies_the_rest() {
        let mut b = GraphBuilder::new("partial", [1, 8, 8, 3], 12);
        let c = b.conv_named("c", "input", 4, 3, 1, 1, QCfg::FP32, Some(Op::Relu));
        let cat = b.concat(&[&c, "input"]);
        let g = b.finish(vec![cat]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.in_place_concats, 0);
        assert_eq!(plan.partial_concats, 1);
        assert_eq!(plan.concat_fallbacks.len(), 1);
        assert!(plan.concat_fallbacks[0].contains("graph input"),
                "{:?}", plan.concat_fallbacks);
        // conv writes its stripe; the copy instruction carries only the
        // ineligible input, destined at channel 4 of the 7-wide root
        assert_eq!(plan.instrs[0].out_view, Some(ChanView { stride: 7, off: 0 }));
        let cat_i = plan.instrs.iter().find(|i| matches!(i.op, Op::Concat)).unwrap();
        assert_eq!(cat_i.in_slots.len(), 1);
        assert_eq!(cat_i.cat_offs, vec![4]);
        assert_eq!(cat_i.in_tails[0], vec![8, 8, 3]);
        assert_eq!(cat_i.out_slot, plan.instrs[0].out_slot);
        // without read views the whole concat falls back to a full copy
        let old = build_plan_with(
            &g,
            PlanOpts { strided_reads: false, ..PlanOpts::default() },
        )
        .unwrap();
        assert_eq!(old.partial_concats, 0);
        let full = old.instrs.iter().find(|i| matches!(i.op, Op::Concat)).unwrap();
        assert_eq!(full.in_slots.len(), 2);
        assert_eq!(full.cat_offs, vec![0, 4]);
    }

    /// A consumer of a concat-resident tensor that cannot read a stripe
    /// (a Dense behind a Flatten alias) makes that producer ineligible;
    /// the sibling still stripes.
    #[test]
    fn dense_consumer_blocks_striping_of_its_input_only() {
        let mut b = GraphBuilder::new("blocked", [1, 8, 8, 3], 14);
        let a = b.conv_named("a", "input", 4, 1, 1, 0, QCfg::FP32, None);
        let c = b.conv_named("c", "input", 2, 1, 1, 0, QCfg::FP32, None);
        let cat = b.concat(&[&a, &c]);
        let f = b.flatten(&c); // second consumer of c without a view path
        let d = b.dense(&f, 8 * 8 * 2, 5);
        let g = b.finish(vec![cat, d]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.partial_concats, 1);
        assert_eq!(plan.concat_fallbacks.len(), 1);
        assert!(plan.concat_fallbacks[0].contains("no strided read path"),
                "{:?}", plan.concat_fallbacks);
        // a striped at 0; c copied at 4
        assert_eq!(plan.instrs[0].out_view, Some(ChanView { stride: 6, off: 0 }));
        let cat_i = plan.instrs.iter().find(|i| matches!(i.op, Op::Concat)).unwrap();
        assert_eq!(cat_i.cat_offs, vec![4]);
        assert_eq!(cat_i.in_views[0], None);
    }

    #[test]
    fn shared_conv_output_is_not_fused() {
        // conv out feeds both the activation and a residual add: folding the
        // relu into the conv would corrupt the add's operand
        let mut b = GraphBuilder::new("res", [1, 8, 8, 3], 5);
        let c1 = b.conv_named("c1", "input", 8, 3, 1, 1, QCfg::FP32, None);
        let r = b.act_named("r", &c1, Op::Relu);
        let s = b.add(&r, &c1);
        let g = b.finish(vec![s]);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.fused_instrs(), 0);
        assert_eq!(plan.instrs.len(), 3); // conv, relu, add
        // relu also can't run in place (c1.out still needed by the add)
        assert_eq!(plan.in_place_instrs(), 0);
    }

    #[test]
    fn flatten_is_alias_and_last_use_activation_runs_in_place() {
        let mut b = GraphBuilder::new("t", [1, 8, 8, 3], 5);
        let p = b.maxpool("input", 2, 2, 0);
        let r = b.act_named("r", &p, Op::Relu); // pool.out's last use
        let f = b.flatten(&r);
        let d = b.dense(&f, 4 * 4 * 3, 10);
        let g = b.finish(vec![d]);
        let plan = build_plan(&g).unwrap();
        // maxpool, relu (in place), dense — flatten vanished
        assert_eq!(plan.instrs.len(), 3);
        assert!(plan.instrs.iter().all(|i| !matches!(i.op, Op::Flatten)));
        let relu = &plan.instrs[1];
        assert!(relu.in_place);
        assert_eq!(relu.in_slots[0], relu.out_slot);
        // the dense input aliases the relu output's slot
        assert_eq!(plan.instrs[2].in_slots[0], relu.out_slot);
    }

    #[test]
    fn slots_are_recycled_and_arena_within_interpreter_peak() {
        for g in [tiny_test_graph(false), tiny_test_graph(true)] {
            let plan = build_plan(&g).unwrap();
            // far fewer slots than tensors
            assert!(plan.slot_sizes.len() <= 3, "slots: {:?}", plan.slot_sizes);
            let peak = peak_live_elems(&g).unwrap();
            assert!(
                plan.arena_elems(1) <= peak,
                "arena {} > interpreter peak {peak}",
                plan.arena_elems(1)
            );
        }
    }

    #[test]
    fn instructions_never_write_live_inputs() {
        let g = tiny_test_graph(false);
        let plan = build_plan(&g).unwrap();
        for i in &plan.instrs {
            if !i.in_place {
                assert!(i.in_slots.iter().all(|&s| s != i.out_slot), "{:?}", i);
            }
        }
    }

    #[test]
    fn arena_scales_linearly_with_batch() {
        let g = tiny_test_graph(false);
        let plan = build_plan(&g).unwrap();
        assert_eq!(plan.arena_elems(3), 3 * plan.arena_elems(1));
        assert_eq!(plan.nominal_batch, 1);
    }

    #[test]
    fn rejects_statically_mismatched_graphs() {
        // Add with unequal shapes must fail at plan (= compile) time
        use crate::dlrt::graph::{Graph, Node};
        let g = Graph {
            name: "bad".into(),
            input_name: "input".into(),
            input_shape: [1, 8, 8, 3],
            nodes: vec![
                Node {
                    op: Op::MaxPool2d { kernel: [2, 2], stride: [2, 2], padding: [0, 0] },
                    name: "pool".into(),
                    inputs: vec!["input".into()],
                    output: "pool.out".into(),
                },
                Node {
                    op: Op::Add,
                    name: "bad".into(),
                    inputs: vec!["input".into(), "pool.out".into()],
                    output: "bad.out".into(),
                },
            ],
            outputs: vec!["bad.out".into()],
            weights: Default::default(),
        };
        let err = build_plan(&g).unwrap_err();
        assert!(format!("{err:#}").contains("add shape mismatch"), "{err:#}");
    }
}

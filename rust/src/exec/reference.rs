//! Reference interpreter — the pre-plan executor, retained as the semantic
//! oracle.
//!
//! Walks the graph node by node with an env map, allocating a fresh tensor
//! per node and fusing nothing. It is deliberately the *slow, obvious*
//! implementation: parity tests assert the planned executor matches it
//! bit-for-bit (same kernels, same float-op order), so any plan lowering
//! bug surfaces as a golden mismatch rather than a silent numeric drift.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::dlrt::graph::{qp_qn, Node, Op};
use crate::dlrt::tensor::Tensor;
use crate::kernels::bitserial::{dequant_scale_bias, gemm_bitserial, pack_rows_u8};
use crate::kernels::elementwise as ew;
use crate::kernels::fp32::{dense_rowmajor, gemm_rowmajor_bt, scale_bias_rows};
use crate::kernels::im2col::{im2col_f32, im2col_quant_u8, ConvDims};
use crate::kernels::int8::gemm_u8i8_i32;
use crate::kernels::pool;

use super::{CompiledConv, CompiledModel, ConvKernel};

/// Run `model` on `input` with the unfused env-map interpreter.
pub fn run_unfused(
    model: &CompiledModel,
    input: &Tensor,
    nthreads: usize,
) -> Result<Vec<Tensor>> {
    let g = &model.graph;
    if input.shape.len() != 4 || input.shape[1..] != g.input_shape[1..] {
        bail!(
            "input shape {:?} incompatible with model input {:?} (batch may vary)",
            input.shape,
            g.input_shape
        );
    }
    let mut env: BTreeMap<&str, Tensor> = BTreeMap::new();
    let mut remaining = super::planner::use_counts(g);
    env.insert(&g.input_name, input.clone());

    for node in &g.nodes {
        let out = run_node(model, node, &env, nthreads)?;
        // release inputs whose last consumer this was
        for i in &node.inputs {
            if let Some(c) = remaining.get_mut(i.as_str()) {
                *c -= 1;
                if *c == 0 && !g.outputs.iter().any(|o| o == i) {
                    env.remove(i.as_str());
                }
            }
        }
        env.insert(&node.output, out);
    }
    g.outputs
        .iter()
        .map(|o| {
            env.get(o.as_str())
                .cloned()
                .ok_or_else(|| anyhow!("output {o} not produced"))
        })
        .collect()
}

fn run_node(
    model: &CompiledModel,
    node: &Node,
    env: &BTreeMap<&str, Tensor>,
    nthreads: usize,
) -> Result<Tensor> {
    let input = |idx: usize| -> Result<&Tensor> {
        env.get(node.inputs[idx].as_str())
            .ok_or_else(|| anyhow!("missing tensor {}", node.inputs[idx]))
    };
    Ok(match &node.op {
        Op::Conv2d { stride, padding, kernel, cin, cout, .. } => {
            let x = input(0)?;
            let (n, h, w, c) = x.nhwc();
            if c != *cin {
                bail!("{}: cin mismatch", node.name);
            }
            let d = ConvDims::new(n, h, w, c, kernel[0], kernel[1], *stride, *padding);
            let conv = model
                .conv_named(&node.name)
                .ok_or_else(|| anyhow!("no compiled conv for {}", node.name))?;
            conv_node(x, &d, conv, *cout, nthreads)
        }
        Op::Dense { cin, cout } => {
            let x = input(0)?;
            let dense = model
                .dense_named(&node.name)
                .ok_or_else(|| anyhow!("no compiled dense for {}", node.name))?;
            let rows = x.numel() / cin;
            let mut out = vec![0.0f32; rows * cout];
            dense_rowmajor(&x.data, &dense.w, &dense.b, rows, *cin, *cout, &mut out,
                           nthreads);
            let mut shape = x.shape.clone();
            *shape.last_mut().unwrap() = *cout;
            Tensor::new(shape, out)?
        }
        Op::MaxPool2d { kernel, stride, padding } => {
            let x = input(0)?;
            let (n, h, w, c) = x.nhwc();
            let (oh, ow) = crate::dlrt::graph::conv_out_hw(h, w, *kernel, *stride, *padding);
            let mut out = Tensor::zeros(vec![n, oh, ow, c]);
            pool::maxpool2d(&x.data, n, h, w, c, *kernel, *stride, *padding, &mut out.data);
            out
        }
        Op::GlobalAvgPool => {
            let x = input(0)?;
            let (n, h, w, c) = x.nhwc();
            let mut out = Tensor::zeros(vec![n, c]);
            pool::global_avg_pool(&x.data, n, h, w, c, &mut out.data);
            out
        }
        Op::Upsample2x => {
            let x = input(0)?;
            let (n, h, w, c) = x.nhwc();
            let mut out = Tensor::zeros(vec![n, 2 * h, 2 * w, c]);
            pool::upsample2x(&x.data, n, h, w, c, &mut out.data);
            out
        }
        Op::Add => {
            let (a, b) = (input(0)?, input(1)?);
            if a.shape != b.shape {
                bail!("{}: add shape mismatch {:?} vs {:?}", node.name, a.shape, b.shape);
            }
            let mut out = Tensor::zeros(a.shape.clone());
            ew::add(&a.data, &b.data, &mut out.data);
            out
        }
        Op::Concat => {
            let ts: Vec<&Tensor> = (0..node.inputs.len()).map(input).collect::<Result<_>>()?;
            if ts.is_empty() {
                bail!("{}: concat with no inputs", node.name);
            }
            for t in &ts {
                if t.shape.len() != 4 {
                    bail!("{}: concat expects rank-4 NHWC, got {:?}", node.name, t.shape);
                }
            }
            let (n, h, w, _) = ts[0].nhwc();
            for t in &ts[1..] {
                let (n2, h2, w2, _) = t.nhwc();
                if (n2, h2, w2) != (n, h, w) {
                    bail!(
                        "{}: concat spatial mismatch {:?} vs {:?}",
                        node.name,
                        t.shape,
                        ts[0].shape
                    );
                }
            }
            let rows = n * h * w;
            let parts: Vec<(&[f32], usize)> =
                ts.iter().map(|t| (t.data.as_slice(), t.shape[3])).collect();
            let ctot: usize = parts.iter().map(|(_, c)| c).sum();
            let mut out = Tensor::zeros(vec![n, h, w, ctot]);
            ew::concat_channels(&parts, rows, &mut out.data);
            out
        }
        Op::Flatten => {
            let x = input(0)?;
            let numel: usize = x.shape[1..].iter().product();
            Tensor::new(vec![x.shape[0], numel], x.data.clone())?
        }
        Op::Relu | Op::Relu6 | Op::Silu | Op::LeakyRelu | Op::Sigmoid => {
            let x = input(0)?;
            let mut out = x.clone();
            match node.op {
                Op::Relu => ew::relu(&mut out.data),
                Op::Relu6 => ew::relu6(&mut out.data),
                Op::Silu => ew::silu(&mut out.data),
                Op::LeakyRelu => ew::leaky_relu(&mut out.data),
                Op::Sigmoid => ew::sigmoid(&mut out.data),
                _ => unreachable!(),
            }
            out
        }
    })
}

fn conv_node(
    x: &Tensor,
    d: &ConvDims,
    conv: &CompiledConv,
    cout: usize,
    nthreads: usize,
) -> Tensor {
    let rows = d.rows();
    let patch = d.patch();
    let mut out = Tensor::zeros(vec![d.n, d.oh, d.ow, cout]);
    match &conv.kernel {
        ConvKernel::Fp32 { wt } => {
            let mut cols = vec![0.0f32; rows * patch];
            im2col_f32(&x.data, d, &mut cols);
            gemm_rowmajor_bt(&cols, wt, rows, cout, patch, &mut out.data, nthreads);
            scale_bias_rows(&mut out.data, cout, &conv.scale, &conv.bias);
        }
        ConvKernel::Bitserial { packed, s_w, s_a, w_bits, a_bits } => {
            let (qp_a, _) = qp_qn(*a_bits, false);
            let mut cols = vec![0u8; rows * patch];
            im2col_quant_u8(&x.data, d, *s_a, qp_a as u8, &mut cols);
            let ap = pack_rows_u8(&cols, rows, patch, *a_bits as usize);
            let mut acc = vec![0i32; rows * cout];
            // unpack the prepacked tile layout back to row-major and use the
            // plain scalar GEMM: the oracle must stay independent of the
            // micro-kernel registry it is the reference for
            let rm = packed.to_row_major();
            gemm_bitserial(&ap, &rm, *w_bits as usize, &mut acc, nthreads);
            dequant_scale_bias(&acc, cout, s_a * s_w, &conv.scale, &conv.bias, &mut out.data);
        }
        ConvKernel::Int8 { codes, s_w, s_a } => {
            let mut cols = vec![0u8; rows * patch];
            im2col_quant_u8(&x.data, d, *s_a, 255, &mut cols);
            let mut acc = vec![0i32; rows * cout];
            gemm_u8i8_i32(&cols, codes, rows, cout, patch, &mut acc, nthreads);
            dequant_scale_bias(&acc, cout, s_a * s_w, &conv.scale, &conv.bias, &mut out.data);
        }
    }
    out
}

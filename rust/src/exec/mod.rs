//! Graph executor: runs a [`CompiledModel`]'s [`planner::ExecPlan`] against
//! a persistent arena.
//!
//! The compiler lowers the graph through the planner's pass pipeline
//! (activation fusion → Add/residual fusion → post-add activation fusion →
//! in-place/alias/concat-stripe lowering → arena slot assignment), so at
//! request time the executor is a flat loop over instructions reading and
//! writing disjoint slot ranges of one reusable buffer: no per-node tensor
//! allocation, no env-map walks, no activation clones, no residual-add or
//! concat-copy passes where the plan fused them away — and concat-resident
//! tensors are both written *and read* as channel stripes of the concat
//! root slot (strided im2col / pool / activation reads), so multi-use
//! concat inputs like YOLOv5's SPPF pyramid never densify either. Once the arena and
//! kernel scratch have grown to the model's largest layer, a run performs
//! **zero heap allocations** (enforced by `tests/steady_state_alloc.rs`).
//!
//! Arithmetic matches `python/compile/jax_exec.py` mode `deploy_sim` step
//! for step (fused epilogues perform the identical float ops in the same
//! order), so golden parity holds bit-for-bit against the retained
//! [`reference`] interpreter and to float round-off of the transcendental
//! activations against JAX.

pub mod planner;
pub mod reference;
pub mod verify;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::dlrt::graph::{qp_qn, Graph, Op};
use crate::dlrt::tensor::{Packed, Tensor};
use crate::kernels::bitserial::{
    dequant_scale_bias_act, dequant_scale_bias_add_act, pack_rows_u8_into,
};
use crate::kernels::elementwise::{self as ew, ActKind};
use crate::kernels::fp32::{dense_rowmajor, scale_bias_rows_act, scale_bias_rows_add_act};
use crate::kernels::im2col::{
    im2col_f32_view, im2col_quant_u8_view, quantize_direct_u8, stage_direct_f32, ConvDims,
};
use crate::kernels::pool;
use crate::kernels::ukernel::{self, Isa, PackedW, UKernel};
use crate::obs;
use crate::util::threads;

use self::planner::{ChanView, ExecPlan, Instr};

/// Which engine executes a conv layer (chosen by the compiler).
#[derive(Clone, Debug)]
pub enum ConvKernel {
    /// The paper's bitserial engine: offset-encoded weight planes prepacked
    /// at compile time into the selected micro-kernel's tile-walk layout.
    Bitserial { packed: PackedW, s_w: f32, s_a: f32, w_bits: u8, a_bits: u8 },
    /// FP32 baseline: transposed (cout × patch) weights.
    Fp32 { wt: Vec<f32> },
    /// INT8 baseline: (cout × patch) i8 codes + scales.
    Int8 { codes: Vec<i8>, s_w: f32, s_a: f32 },
}

impl ConvKernel {
    pub fn engine_name(&self) -> &'static str {
        match self {
            ConvKernel::Bitserial { .. } => "bitserial",
            ConvKernel::Fp32 { .. } => "fp32",
            ConvKernel::Int8 { .. } => "int8",
        }
    }
}

/// A conv layer ready to execute.
#[derive(Clone, Debug)]
pub struct CompiledConv {
    /// Graph node this kernel belongs to (diagnostics, save/load keying).
    pub name: String,
    pub kernel: ConvKernel,
    /// per-channel folded-BN scale and bias
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
    /// Tuned schedule from the tuning DB (`dlrt tune`), if one matched at
    /// compile/load time: geometry override for the bitserial GEMM (the
    /// weights are prepacked in its tile order), a per-conv thread split,
    /// and the im2col staging strategy. `None` = static kernel defaults.
    pub sched: Option<crate::tune::Schedule>,
}

#[derive(Clone, Debug)]
pub struct CompiledDense {
    /// Graph node this kernel belongs to (diagnostics, save/load keying).
    pub name: String,
    pub w: Vec<f32>, // (cin × cout) row-major, as exported
    pub b: Vec<f32>,
}

/// A deployable model: topology + per-layer compiled kernels + the lowered
/// execution plan. The plan is built once here and shared read-only by
/// every executor (the coordinator's batch workers run one plan against
/// private arenas).
///
/// Kernels live in **dense vectors in graph node order**; plan instructions
/// carry the matching index (`Instr::kernel_idx`, assigned at compile time),
/// so the request path never walks a name-keyed map. The ISA the kernels
/// were selected (and weights prepacked) for is recorded in `isa`.
#[derive(Clone, Debug)]
pub struct CompiledModel {
    pub graph: Graph,
    /// Conv kernels, one per `Op::Conv2d` node, in graph node order.
    pub convs: Vec<CompiledConv>,
    /// Dense kernels, one per `Op::Dense` node, in graph node order.
    pub denses: Vec<CompiledDense>,
    /// The micro-kernel ISA this model was compiled (and prepacked) for.
    pub isa: Isa,
    pub plan: ExecPlan,
}

impl CompiledModel {
    /// Attach kernels to a graph and lower it through the planner pass
    /// pipeline. Statically invalid graphs (shape mismatches, undefined
    /// tensors) are rejected here, at compile time, not at request time.
    /// `convs`/`denses` must be in graph node order — the plan's kernel
    /// indices are assigned by that order, and `run_into` cross-checks the
    /// counts before every run.
    pub fn new(
        graph: Graph,
        convs: Vec<CompiledConv>,
        denses: Vec<CompiledDense>,
        isa: Isa,
    ) -> Result<CompiledModel> {
        let plan = planner::build_plan(&graph)?;
        if plan.conv_kernels != convs.len() || plan.dense_kernels != denses.len() {
            bail!(
                "kernel table ({} convs, {} denses) does not match graph ({}, {})",
                convs.len(),
                denses.len(),
                plan.conv_kernels,
                plan.dense_kernels
            );
        }
        Ok(CompiledModel { graph, convs, denses, isa, plan })
    }

    /// The compiled conv for graph node `name` (linear scan — diagnostics
    /// and the reference interpreter only, never the serving path).
    pub fn conv_named(&self, name: &str) -> Option<&CompiledConv> {
        self.convs.iter().find(|c| c.name == name)
    }

    /// As [`CompiledModel::conv_named`], for dense layers.
    pub fn dense_named(&self, name: &str) -> Option<&CompiledDense> {
        self.denses.iter().find(|d| d.name == name)
    }

    /// Total weight bytes as stored (the paper's model-size metric).
    pub fn weight_bytes(&self) -> usize {
        let mut total = 0;
        for c in &self.convs {
            total += match &c.kernel {
                ConvKernel::Bitserial { packed, .. } => packed.storage_bytes(),
                ConvKernel::Fp32 { wt } => wt.len() * 4,
                ConvKernel::Int8 { codes, .. } => codes.len(),
            };
            total += (c.scale.len() + c.bias.len()) * 4;
        }
        for d in &self.denses {
            total += (d.w.len() + d.b.len()) * 4;
        }
        total
    }

    pub fn engine_summary(&self) -> BTreeMap<&'static str, usize> {
        let mut m = BTreeMap::new();
        for c in &self.convs {
            *m.entry(c.kernel.engine_name()).or_insert(0) += 1;
        }
        m
    }
}

/// Reusable kernel scratch (im2col columns, packed activation planes, i32
/// accumulators, fp32 GEMM staging for strided/fused epilogues): grows to
/// the largest layer, then steady-state reuse.
struct Scratch {
    cols_f32: Vec<f32>,
    cols_u8: Vec<u8>,
    acc: Vec<i32>,
    /// fp32 GEMM result when the epilogue can't run in place (residual add
    /// or channel-stripe output): the epilogue reads from here and writes
    /// the final values straight to their destination.
    gemm_f32: Vec<f32>,
    packed: Packed,
}

/// Read/write views over the arena slots of one plan execution.
///
/// Slots are disjoint ranges of one buffer. An instruction pairs one
/// output slot with input slots of *different* ids (the planner guarantees
/// it; `exec_instr` asserts it), and in-place instructions take only the
/// mutable view — so the slices handed out never alias. The one sanctioned
/// same-slot case — disjoint channel-stripe views of a concat root
/// (validated by `ExecPlan::validate`) — never takes `read` and `write`
/// together: `exec_instr` routes it through a single `write` view plus a
/// same-buffer kernel, or finishes the read into scratch first (convs).
struct ArenaViews<'a> {
    base: *mut f32,
    offsets: &'a [usize],
}

impl ArenaViews<'_> {
    /// # Safety
    /// `offsets[slot] + elems` must lie inside the arena (guaranteed when
    /// `elems` ≤ the slot's validated size) and no live `&mut` view of this
    /// slot may exist.
    #[inline]
    unsafe fn read(&self, slot: usize, elems: usize) -> &[f32] {
        // SAFETY: the caller upholds the bounds/no-aliasing contract above.
        unsafe { std::slice::from_raw_parts(self.base.add(self.offsets[slot]), elems) }
    }

    /// # Safety
    /// As [`ArenaViews::read`], plus: this must be the only view (shared or
    /// mutable) of `slot` for the duration of the borrow.
    #[inline]
    #[allow(clippy::mut_from_ref)] // disjoint-slot views over one buffer
    unsafe fn write(&self, slot: usize, elems: usize) -> &mut [f32] {
        // SAFETY: the caller upholds the bounds/exclusive-view contract
        // documented above.
        unsafe { std::slice::from_raw_parts_mut(self.base.add(self.offsets[slot]), elems) }
    }
}

/// Executor with a persistent arena + reusable kernel scratch (one instance
/// per worker thread).
///
/// The arena is laid out from the model plan's slot sizes rescaled to the
/// request batch, grown once, and reused across requests; the persistent
/// kernel pool handle taken at construction means steady-state traffic also
/// never spawns a thread.
pub struct Executor {
    pub nthreads: usize,
    pool: &'static threads::ThreadPool,
    scratch: Scratch,
    arena: Vec<f32>,
    slot_offsets: Vec<usize>,
    /// Per-instruction wall-time rings; `None` (the default) keeps the
    /// instruction loop free of timer calls entirely.
    profiler: Option<obs::InstrProfiler>,
}

impl Executor {
    pub fn new(nthreads: usize) -> Executor {
        Executor {
            nthreads,
            // grab (and, on first use, spin up) the process-wide kernel pool
            // here so no inference pays thread-spawn latency
            pool: threads::global(),
            scratch: Scratch {
                cols_f32: Vec::new(),
                cols_u8: Vec::new(),
                acc: Vec::new(),
                gemm_f32: Vec::new(),
                packed: Packed::new_zeroed(0, 0, 1),
            },
            arena: Vec::new(),
            slot_offsets: Vec::new(),
            profiler: None,
        }
    }

    /// The persistent kernel worker pool this executor dispatches to.
    pub fn pool(&self) -> &'static threads::ThreadPool {
        self.pool
    }

    /// Preallocate per-instruction profiling rings sized for `plan`.
    /// Profiling stays attached across runs; a run with a plan of a
    /// different instruction count is executed unprofiled rather than
    /// misattributed.
    pub fn enable_profiling(&mut self, plan: &ExecPlan) {
        let classes: Vec<u8> =
            plan.instrs.iter().map(|ins| obs::op_class(ins.op.name()) as u8).collect();
        self.profiler = Some(obs::InstrProfiler::new(classes));
    }

    pub fn disable_profiling(&mut self) {
        self.profiler = None;
    }

    pub fn profiler(&self) -> Option<&obs::InstrProfiler> {
        self.profiler.as_ref()
    }

    pub fn profiler_mut(&mut self) -> Option<&mut obs::InstrProfiler> {
        self.profiler.as_mut()
    }

    /// Run the model on `input` (NHWC; batch may differ from the nominal
    /// graph batch). Returns the graph outputs in declaration order.
    pub fn run(&mut self, model: &CompiledModel, input: &Tensor) -> Result<Vec<Tensor>> {
        let mut outs = Vec::new();
        self.run_into(model, input, &mut outs)?;
        Ok(outs)
    }

    /// [`Executor::run`] writing into caller-owned output tensors whose
    /// buffers are reused across calls — the zero-allocation serving path.
    pub fn run_into(
        &mut self,
        model: &CompiledModel,
        input: &Tensor,
        outs: &mut Vec<Tensor>,
    ) -> Result<()> {
        let g = &model.graph;
        if input.shape.len() != 4 || input.shape[1..] != g.input_shape[1..] {
            bail!(
                "input shape {:?} incompatible with model input {:?} (batch may vary)",
                input.shape,
                g.input_shape
            );
        }
        let plan = &model.plan;
        // plan fields are public and swappable (the fig7 ablation swaps
        // them) — re-check the bounds/aliasing invariants the unsafe slot
        // views rely on, every run, in every build profile
        plan.validate()?;
        if plan.input_tail[..] != g.input_shape[1..] {
            bail!(
                "plan input {:?} does not match model input {:?}",
                plan.input_tail,
                g.input_shape
            );
        }
        // the plan's kernel indices must address exactly this model's
        // kernel vectors (a swapped plan with a different table is invalid)
        if plan.conv_kernels != model.convs.len() || plan.dense_kernels != model.denses.len() {
            bail!(
                "plan kernel table ({} convs, {} denses) does not match model ({}, {})",
                plan.conv_kernels,
                plan.dense_kernels,
                model.convs.len(),
                model.denses.len()
            );
        }
        // resolve the micro-kernel entry once per run, not per instruction
        let uk = ukernel::kernel_for(model.isa).ok_or_else(|| {
            anyhow!("model compiled for ISA '{}' which this host cannot run", model.isa.name())
        })?;
        let batch = input.shape[0];

        // arena layout for this batch: slot offsets are prefix sums of the
        // plan's per-batch slot sizes; the buffer only ever grows. Checked
        // arithmetic: a wrapped total would leave offsets pointing past the
        // resized arena, which the unsafe slot views must never see.
        self.slot_offsets.clear();
        let mut total = 0usize;
        for &sz in &plan.slot_sizes {
            self.slot_offsets.push(total);
            total = sz
                .checked_mul(batch)
                .and_then(|b| total.checked_add(b))
                .ok_or_else(|| anyhow!("arena size overflow at batch {batch}"))?;
        }
        if self.arena.len() < total {
            self.arena.resize(total, 0.0);
        }

        // the request lands directly in its arena slot — no Tensor clone
        let in_off = self.slot_offsets[plan.input_slot];
        self.arena[in_off..in_off + input.numel()].copy_from_slice(&input.data);

        let views = ArenaViews { base: self.arena.as_mut_ptr(), offsets: &self.slot_offsets };
        match self.profiler.as_mut() {
            // profiled loop: two monotonic-clock reads per instruction
            // writing into preallocated rings (tests/profile.rs bounds the
            // cost; steady_state_alloc asserts it stays alloc-free)
            Some(prof) if prof.len() == plan.instrs.len() => {
                let run_t0 = std::time::Instant::now();
                for (i, instr) in plan.instrs.iter().enumerate() {
                    let t0 = std::time::Instant::now();
                    exec_instr(&mut self.scratch, self.nthreads, &views, model, uk, instr, batch)?;
                    let dur = t0.elapsed().as_secs_f64();
                    prof.record(i, (t0 - run_t0).as_secs_f64(), dur);
                }
                prof.end_run(run_t0.elapsed().as_secs_f64());
            }
            // disabled (or plan-mismatched) fast path: the exact pre-
            // instrumentation loop, no timer calls
            _ => {
                for instr in &plan.instrs {
                    exec_instr(&mut self.scratch, self.nthreads, &views, model, uk, instr, batch)?;
                }
            }
        }

        // copy outputs into reusable caller tensors
        outs.resize_with(plan.outputs.len(), || Tensor { shape: Vec::new(), data: Vec::new() });
        for (o, spec) in outs.iter_mut().zip(&plan.outputs) {
            let elems = batch * spec.tail.iter().product::<usize>();
            o.shape.clear();
            o.shape.push(batch);
            o.shape.extend_from_slice(&spec.tail);
            o.data.resize(elems, 0.0);
            let off = self.slot_offsets[spec.slot];
            o.data.copy_from_slice(&self.arena[off..off + elems]);
        }
        Ok(())
    }
}

/// Resolve an optional channel-stripe view to `(row_stride, col_off)`,
/// with `(c, 0)` — the dense layout of a `c`-channel tensor — as the
/// default. Every strided kernel call site shares this one convention.
#[inline]
fn view_or(v: &Option<ChanView>, c: usize) -> (usize, usize) {
    match v {
        Some(v) => (v.stride, v.off),
        None => (c, 0),
    }
}

/// Execute one lowered instruction against the arena. Conv/dense kernels
/// are fetched by the instruction's resolved index (`kernel_idx`, assigned
/// at compile time and range-checked by `ExecPlan::validate`) — no name
/// lookup on the request path.
fn exec_instr(
    scratch: &mut Scratch,
    nthreads: usize,
    views: &ArenaViews,
    model: &CompiledModel,
    uk: &'static UKernel,
    instr: &Instr,
    batch: usize,
) -> Result<()> {
    // SAFETY (for every `views.read`/`views.write` below): run_into runs
    // `ExecPlan::validate()` on this plan each request, which guarantees
    // slot ids are in range, every tail (or rows × view.stride footprint)
    // fits its slot (so offset + elems stays inside the arena), and
    // out_slot is disjoint from all in_slots for non-in-place instructions
    // — except inputs sharing the output slot through *disjoint*
    // channel-stripe views, which this function never materializes as a
    // separate shared view: those paths take a single mutable view of the
    // slot and hand it to a same-buffer kernel (or, for convs, finish the
    // read into scratch before the output view is created). Each
    // instruction therefore holds exactly one mutable view at a time,
    // never overlapping a live shared view.
    debug_assert!(
        instr.in_place
            || instr
                .in_slots
                .iter()
                .enumerate()
                .all(|(i, &s)| s != instr.out_slot || instr.in_views[i].is_some()),
        "instruction would write a live input slot: {instr:?}"
    );
    // A channel-stripe view occupies rows × view.stride elements of its
    // slot (rows = every dim but the channel one, times batch).
    let rows_of =
        |tail: &[usize]| -> usize { batch * tail[..tail.len() - 1].iter().product::<usize>() };
    let in_elems = |i: usize| -> usize {
        match &instr.in_views[i] {
            Some(v) => rows_of(&instr.in_tails[i]) * v.stride,
            None => batch * instr.in_tails[i].iter().product::<usize>(),
        }
    };
    let out_elems = batch * instr.out_tail.iter().product::<usize>();
    let out_len = match &instr.out_view {
        Some(v) => rows_of(&instr.out_tail) * v.stride,
        None => out_elems,
    };
    match &instr.op {
        Op::Conv2d { stride, padding, kernel, cout, .. } => {
            let t = &instr.in_tails[0]; // [h, w, c]
            let d = ConvDims::new(batch, t[0], t[1], t[2], kernel[0], kernel[1], *stride,
                                  *padding);
            let conv = instr
                .kernel_idx
                .and_then(|i| model.convs.get(i))
                .ok_or_else(|| anyhow!("no resolved conv kernel for {}", instr.name))?;
            // stage the (possibly strided-read) im2col first and drop the
            // input view before the output view exists: the conv may read
            // one stripe of its own output slot (concat-resident input),
            // and the two views must never be live at once
            {
                let (is_, io) = view_or(&instr.in_views[0], t[2]);
                // SAFETY: validated footprint; dropped before any view of
                // the output slot exists (see the block comment above).
                let x = unsafe { views.read(instr.in_slots[0], in_elems(0)) };
                conv_stage_cols(scratch, x, &d, conv, is_, io);
            }
            // the fused residual add's second accumulator (may share the
            // conv input's slot — two shared reads alias safely; never the
            // output slot, which validate() forbids for view-less inputs)
            let res = if instr.fused_add {
                // SAFETY: validated footprint; shared reads may alias each
                // other but never the (not-yet-created) output view.
                Some(unsafe { views.read(instr.in_slots[1], in_elems(1)) })
            } else {
                None
            };
            // SAFETY: validated footprint; the input view was dropped above,
            // so this is the only live view of the slot.
            let out = unsafe { views.write(instr.out_slot, out_len) };
            conv_finish(scratch, nthreads, uk, &d, conv, *cout, instr.fused, res,
                        instr.fused_post, instr.out_view, out);
        }
        Op::Dense { cin, cout } => {
            // SAFETY: validated footprints over distinct slots (block
            // comment above): one shared view, one disjoint mutable view.
            let x = unsafe { views.read(instr.in_slots[0], in_elems(0)) };
            // SAFETY: as above — out_slot is distinct from the input slot.
            let out = unsafe { views.write(instr.out_slot, out_elems) };
            let dense = instr
                .kernel_idx
                .and_then(|i| model.denses.get(i))
                .ok_or_else(|| anyhow!("no resolved dense kernel for {}", instr.name))?;
            let rows = x.len() / cin;
            dense_rowmajor(x, &dense.w, &dense.b, rows, *cin, *cout, out, nthreads);
        }
        Op::MaxPool2d { kernel, stride, padding } => {
            let t = &instr.in_tails[0];
            let (is_, io) = view_or(&instr.in_views[0], t[2]);
            let (os, oo) = view_or(&instr.out_view, t[2]);
            if instr.in_slots[0] == instr.out_slot {
                // SAFETY: disjoint stripes of one slot (validated, equal
                // strides): a single mutable view serves both sides.
                let buf =
                    unsafe { views.write(instr.out_slot, in_elems(0).max(out_len)) };
                pool::maxpool2d_same(buf, batch, t[0], t[1], t[2], *kernel, *stride,
                                     *padding, os, io, oo);
            } else {
                // SAFETY: validated footprints over distinct slots.
                let x = unsafe { views.read(instr.in_slots[0], in_elems(0)) };
                // SAFETY: as above — the sole mutable view, disjoint slot.
                let out = unsafe { views.write(instr.out_slot, out_len) };
                pool::maxpool2d_view(x, batch, t[0], t[1], t[2], *kernel, *stride,
                                     *padding, is_, io, out, os, oo);
            }
        }
        Op::GlobalAvgPool => {
            let t = &instr.in_tails[0];
            let (is_, io) = view_or(&instr.in_views[0], t[2]);
            // SAFETY: validated footprints over distinct slots.
            let x = unsafe { views.read(instr.in_slots[0], in_elems(0)) };
            // SAFETY: as above — the sole mutable view, disjoint slot.
            let out = unsafe { views.write(instr.out_slot, out_elems) };
            pool::global_avg_pool_view(x, batch, t[0], t[1], t[2], is_, io, out);
        }
        Op::Upsample2x => {
            let t = &instr.in_tails[0];
            let (is_, io) = view_or(&instr.in_views[0], t[2]);
            let (os, oo) = view_or(&instr.out_view, t[2]);
            if instr.in_slots[0] == instr.out_slot {
                // SAFETY: disjoint stripes of one slot (validated): one
                // mutable view serves both sides.
                let buf =
                    unsafe { views.write(instr.out_slot, in_elems(0).max(out_len)) };
                pool::upsample2x_same(buf, batch, t[0], t[1], t[2], os, io, oo);
            } else {
                // SAFETY: validated footprints over distinct slots.
                let x = unsafe { views.read(instr.in_slots[0], in_elems(0)) };
                // SAFETY: as above — the sole mutable view, disjoint slot.
                let out = unsafe { views.write(instr.out_slot, out_len) };
                pool::upsample2x_view(x, batch, t[0], t[1], t[2], is_, io, out, os, oo);
            }
        }
        Op::Add => {
            // SAFETY: validated footprints; the two shared reads may alias
            // each other (x + x) but never the distinct output slot.
            let a = unsafe { views.read(instr.in_slots[0], in_elems(0)) };
            // SAFETY: as above.
            let b = unsafe { views.read(instr.in_slots[1], in_elems(1)) };
            // SAFETY: as above — the sole mutable view, disjoint slot.
            let out = unsafe { views.write(instr.out_slot, out_elems) };
            ew::add(a, b, out);
        }
        Op::Concat => {
            // one striped copy per listed input (a partial concat lists
            // only its copy-fallback inputs — the striped producers wrote
            // their stripes already). With an out_view this concat is
            // itself a stripe of a wider root (nested): destinations
            // shift by the view base. Inputs may themselves be read
            // through views, including out of this very slot.
            let ctot = instr.out_tail[2];
            let rows = batch * instr.out_tail[0] * instr.out_tail[1];
            let (os, base) = match &instr.out_view {
                Some(v) => (v.stride, v.off),
                None => (ctot, 0),
            };
            // SAFETY: validated footprint; the one mutable view — same-slot
            // inputs are copied through it rather than a shared alias.
            let out = unsafe { views.write(instr.out_slot, out_len) };
            for i in 0..instr.in_slots.len() {
                let ci = instr.in_tails[i][2];
                let dst = base + instr.cat_offs[i];
                let (is_, io) = view_or(&instr.in_views[i], ci);
                if instr.in_slots[i] == instr.out_slot {
                    // same root, disjoint stripes (validated): reuse the
                    // mutable view instead of aliasing a shared one
                    ew::copy_channels_same(out, ci, os, io, dst, rows);
                } else {
                    // SAFETY: validated footprint of a slot distinct from
                    // the output's, so it cannot alias `out`.
                    let x = unsafe { views.read(instr.in_slots[i], in_elems(i)) };
                    ew::copy_channels_view(x, ci, is_, io, rows, out, os, dst);
                }
            }
        }
        Op::Flatten => {
            bail!("flatten reached the executor (planner lowers it to an alias)")
        }
        Op::Relu | Op::Relu6 | Op::Silu | Op::LeakyRelu | Op::Sigmoid => {
            let act = ActKind::from_op(&instr.op).expect("activation op");
            let c = *instr.out_tail.last().expect("non-empty tail");
            let rows = out_elems / c;
            let (is_, io) = view_or(&instr.in_views[0], c);
            match &instr.out_view {
                Some(v) if instr.in_slots[0] == instr.out_slot => {
                    // SAFETY: stripe-to-stripe within one root slot
                    // (validated disjoint): one mutable view serves both.
                    let buf =
                        unsafe { views.write(instr.out_slot, in_elems(0).max(out_len)) };
                    ew::act_same(act, buf, c, v.stride, io, v.off, rows);
                }
                Some(v) => {
                    // SAFETY: validated footprints over distinct slots —
                    // a (possibly strided) read, activated into the stripe.
                    let x = unsafe { views.read(instr.in_slots[0], in_elems(0)) };
                    // SAFETY: as above — the sole mutable view.
                    let out = unsafe { views.write(instr.out_slot, out_len) };
                    ew::act_view(act, x, c, is_, io, rows, out, v.stride, v.off);
                }
                None if instr.in_views[0].is_some() => {
                    // SAFETY: strided read and dense write of distinct
                    // slots, both footprints validated.
                    let x = unsafe { views.read(instr.in_slots[0], in_elems(0)) };
                    // SAFETY: as above — the sole mutable view.
                    let out = unsafe { views.write(instr.out_slot, out_elems) };
                    ew::act_view(act, x, c, is_, io, rows, out, c, 0);
                }
                None => {
                    // SAFETY: in-place this IS the input slot (the only
                    // view); otherwise the slots are validated distinct.
                    let out = unsafe { views.write(instr.out_slot, out_elems) };
                    if !instr.in_place {
                        // SAFETY: distinct slot (in_place is false), so the
                        // shared read cannot alias `out`.
                        let x = unsafe { views.read(instr.in_slots[0], in_elems(0)) };
                        out.copy_from_slice(x);
                    }
                    act.apply(out);
                }
            }
        }
    }
    Ok(())
}

/// Stage a conv's im2col columns into scratch, engine-dispatched, reading
/// the input through a channel-stripe view (`src_stride`/`src_off`;
/// `src_stride == d.c`, `src_off == 0` is dense). This is the *only* part
/// of a conv that touches the input slot — `exec_instr` drops the input
/// view right after, so a conv may legally consume one stripe of the slot
/// its own output stripe lands in.
fn conv_stage_cols(
    scratch: &mut Scratch,
    x: &[f32],
    d: &ConvDims,
    conv: &CompiledConv,
    src_stride: usize,
    src_off: usize,
) {
    let rows = d.rows();
    let patch = d.patch();
    // A tuned `Staging::Direct` schedule skips the per-patch gather: on a
    // unit conv (1x1, stride 1, no padding) reading a dense input the patch
    // matrix IS the input, so staging degenerates to a flat copy/quantize.
    // Guarded on the exact dims here (not just the DB entry) so a stale or
    // nearest-shape entry can never mis-stage — it only falls back to the
    // gather, which is bit-identical by construction.
    let direct = conv.sched.map(|s| s.staging == crate::tune::Staging::Direct).unwrap_or(false)
        && d.kh == 1 && d.kw == 1 && d.stride == [1, 1] && d.padding == [0, 0]
        && src_off == 0 && src_stride == d.c;
    match &conv.kernel {
        ConvKernel::Fp32 { .. } => {
            scratch.cols_f32.resize(rows * patch, 0.0);
            if direct {
                stage_direct_f32(x, &mut scratch.cols_f32);
            } else {
                im2col_f32_view(x, d, src_stride, src_off, &mut scratch.cols_f32);
            }
        }
        ConvKernel::Bitserial { s_a, a_bits, .. } => {
            let (qp_a, _) = qp_qn(*a_bits, false);
            scratch.cols_u8.resize(rows * patch, 0);
            if direct {
                quantize_direct_u8(x, *s_a, qp_a as u8, &mut scratch.cols_u8);
            } else {
                im2col_quant_u8_view(x, d, *s_a, qp_a as u8, src_stride, src_off,
                                     &mut scratch.cols_u8);
            }
        }
        ConvKernel::Int8 { s_a, .. } => {
            scratch.cols_u8.resize(rows * patch, 0);
            if direct {
                quantize_direct_u8(x, *s_a, 255, &mut scratch.cols_u8);
            } else {
                im2col_quant_u8_view(x, d, *s_a, 255, src_stride, src_off,
                                     &mut scratch.cols_u8);
            }
        }
    }
}

/// Finish a compiled conv from the staged columns into `out`,
/// engine-dispatched through the selected micro-kernel's resolved GEMM fn
/// pointers, with the plan's fused epilogue (activation, residual add,
/// post-add activation) applied in the dequant/scale pass — and, when
/// `view` is set, written into the conv's channel stripe of a concat
/// output slot instead of densely.
///
/// The common dense/no-residual case keeps the original specialized
/// epilogues; every fused path performs the identical float ops in the
/// same order, so results stay bit-identical to the unfused reference.
#[allow(clippy::too_many_arguments)]
fn conv_finish(
    scratch: &mut Scratch,
    nthreads: usize,
    uk: &UKernel,
    d: &ConvDims,
    conv: &CompiledConv,
    cout: usize,
    fused: Option<ActKind>,
    res: Option<&[f32]>,
    fused_post: Option<ActKind>,
    view: Option<ChanView>,
    out: &mut [f32],
) {
    let rows = d.rows();
    let patch = d.patch();
    let (ostride, ooff) = match view {
        Some(v) => (v.stride, v.off),
        None => (cout, 0),
    };
    debug_assert_eq!(out.len(), rows * ostride);
    debug_assert!(res.map(|r| r.len() == rows * cout).unwrap_or(true));
    let plain = res.is_none() && view.is_none();
    // Tuned schedule: tile-geometry override for the bitserial GEMM (the
    // weights were prepacked in this tile order) plus an optional per-conv
    // thread split. Integer GEMMs are bit-exact at any thread count; fp32
    // schedules always inherit (enforced at DB validation).
    let (desc, gthreads) = match conv.sched {
        Some(s) => (s.desc_for(uk.desc.isa), s.gemm_threads(nthreads)),
        None => (uk.desc, nthreads),
    };
    match &conv.kernel {
        ConvKernel::Fp32 { wt } => {
            if plain {
                (uk.gemm_f32)(&scratch.cols_f32, wt, rows, cout, patch, out, nthreads);
                scale_bias_rows_act(out, cout, &conv.scale, &conv.bias, fused);
            } else {
                // the epilogue can't mutate in place (it adds a residual
                // and/or writes strided): stage the GEMM in scratch
                scratch.gemm_f32.resize(rows * cout, 0.0);
                (uk.gemm_f32)(&scratch.cols_f32, wt, rows, cout, patch,
                              &mut scratch.gemm_f32, nthreads);
                scale_bias_rows_add_act(&scratch.gemm_f32, cout, &conv.scale, &conv.bias,
                                        fused, res, fused_post, out, ostride, ooff);
            }
        }
        ConvKernel::Bitserial { packed, s_w, s_a, w_bits, a_bits } => {
            pack_rows_u8_into(&scratch.cols_u8, rows, patch, *a_bits as usize,
                              &mut scratch.packed);
            scratch.acc.resize(rows * cout, 0);
            (uk.gemm_bit)(&desc, &scratch.packed, packed, *w_bits as usize,
                          &mut scratch.acc[..rows * cout], gthreads);
            if plain {
                dequant_scale_bias_act(&scratch.acc[..rows * cout], cout, s_a * s_w,
                                       &conv.scale, &conv.bias, fused, out);
            } else {
                dequant_scale_bias_add_act(&scratch.acc[..rows * cout], cout, s_a * s_w,
                                           &conv.scale, &conv.bias, fused, res, fused_post,
                                           out, ostride, ooff);
            }
        }
        ConvKernel::Int8 { codes, s_w, s_a } => {
            scratch.acc.resize(rows * cout, 0);
            (uk.gemm_u8i8)(&scratch.cols_u8, codes, rows, cout, patch,
                           &mut scratch.acc[..rows * cout], gthreads);
            if plain {
                dequant_scale_bias_act(&scratch.acc[..rows * cout], cout, s_a * s_w,
                                       &conv.scale, &conv.bias, fused, out);
            } else {
                dequant_scale_bias_add_act(&scratch.acc[..rows * cout], cout, s_a * s_w,
                                           &conv.scale, &conv.bias, fused, res, fused_post,
                                           out, ostride, ooff);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_graph, EngineChoice};
    use crate::models::tiny_test_graph;

    #[test]
    fn fp32_vs_bitserial_exact_on_representable_conv() {
        // Single quantized conv whose weights are exact 2-bit codes
        // (s_w = 0.5) fed inputs that are exact 2-bit activation codes
        // (s_a = 0.25): bitserial and FP32 engines agree exactly (all
        // intermediate values are small dyadic rationals).
        use crate::models::single_conv_graph;

        let g = single_conv_graph(2, 2, 0.5, 0.25);
        let mq = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
        assert_eq!(mq.engine_summary().get("bitserial"), Some(&1));
        let mut ex = Executor::new(1);
        let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i % 4) as f32) * 0.25; // exact 2-bit codes at s_a=0.25
        }
        let yq = ex.run(&mq, &x).unwrap();
        let yf = ex.run(&mf, &x).unwrap();
        assert_eq!(yq[0].data, yf[0].data, "engines diverged");
    }

    #[test]
    fn quantized_network_close_to_fp32_on_smooth_input() {
        // End-to-end: 2A2W quantization error stays bounded on the tiny
        // 3-conv graph (the accuracy claim, in miniature).
        let g = tiny_test_graph(true);
        let mq = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
        let mut ex = Executor::new(1);
        let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i % 4) as f32) * 0.25;
        }
        let yq = ex.run(&mq, &x).unwrap();
        let yf = ex.run(&mf, &x).unwrap();
        let scale = yf[0].data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-3);
        assert!(yq[0].max_abs_diff(&yf[0]) / scale < 0.6,
                "quantization error unreasonably large: {} vs scale {scale}",
                yq[0].max_abs_diff(&yf[0]));
    }

    #[test]
    fn batch_dimension_flexible() {
        let g = tiny_test_graph(false);
        let m = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mut ex = Executor::new(1);
        let x1 = Tensor::zeros(vec![1, 8, 8, 3]);
        let x3 = Tensor::zeros(vec![3, 8, 8, 3]);
        let y1 = ex.run(&m, &x1).unwrap();
        let y3 = ex.run(&m, &x3).unwrap();
        assert_eq!(y1[0].shape[0], 1);
        assert_eq!(y3[0].shape[0], 3);
        // batch entries are independent: first sample equal to float
        // round-off (batching changes GEMM row-block boundaries)
        for (a, b) in y3[0].data[..y1[0].numel()].iter().zip(&y1[0].data) {
            assert!((a - b).abs() <= 1e-5 + 1e-5 * b.abs(), "{a} vs {b}");
        }
    }

    #[test]
    fn batch_shrink_after_growth_still_correct() {
        // the arena only grows; a small batch after a large one must slice
        // the oversized buffer correctly
        let g = tiny_test_graph(false);
        let m = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mut ex = Executor::new(1);
        let mut x1 = Tensor::zeros(vec![1, 8, 8, 3]);
        for (i, v) in x1.data.iter_mut().enumerate() {
            *v = (i % 5) as f32 * 0.25;
        }
        let y_before = ex.run(&m, &x1).unwrap();
        ex.run(&m, &Tensor::zeros(vec![4, 8, 8, 3])).unwrap(); // grow
        let y_after = ex.run(&m, &x1).unwrap();
        assert_eq!(y_before[0].data, y_after[0].data);
    }

    #[test]
    fn run_into_reuses_output_buffers() {
        let g = tiny_test_graph(false);
        let m = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mut ex = Executor::new(1);
        let x = Tensor::zeros(vec![1, 8, 8, 3]);
        let mut outs = Vec::new();
        ex.run_into(&m, &x, &mut outs).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].shape, vec![1, 4]);
        let want = outs[0].data.clone();
        ex.run_into(&m, &x, &mut outs).unwrap();
        assert_eq!(outs[0].data, want);
    }

    #[test]
    fn rejects_wrong_spatial_shape() {
        let g = tiny_test_graph(false);
        let m = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mut ex = Executor::new(1);
        assert!(ex.run(&m, &Tensor::zeros(vec![1, 9, 8, 3])).is_err());
    }
}

//! Graph executor: runs a [`CompiledModel`] with liveness-based buffer release.
//!
//! Arithmetic matches `python/compile/jax_exec.py` mode `deploy_sim` step
//! for step (same op order inside the dequant expression), so golden parity
//! tests hold to float round-off of the transcendental activations.

pub mod planner;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::dlrt::graph::{qp_qn, Graph, Node, Op};
use crate::dlrt::tensor::{Packed, Tensor};
use crate::kernels::bitserial::{dequant_scale_bias, gemm_bitserial, pack_rows_u8_into};
use crate::kernels::elementwise as ew;
use crate::kernels::fp32::{gemm_rowmajor_bt, scale_bias_rows};
use crate::kernels::im2col::{im2col_f32, im2col_quant_u8, ConvDims};
use crate::kernels::int8::gemm_u8i8_i32;
use crate::kernels::pool;
use crate::util::threads;

/// Which engine executes a conv layer (chosen by the compiler).
#[derive(Clone, Debug)]
pub enum ConvKernel {
    /// The paper's bitserial engine: packed offset-encoded weight planes.
    Bitserial { packed: Packed, s_w: f32, s_a: f32, w_bits: u8, a_bits: u8 },
    /// FP32 baseline: transposed (cout × patch) weights.
    Fp32 { wt: Vec<f32> },
    /// INT8 baseline: (cout × patch) i8 codes + scales.
    Int8 { codes: Vec<i8>, s_w: f32, s_a: f32 },
}

impl ConvKernel {
    pub fn engine_name(&self) -> &'static str {
        match self {
            ConvKernel::Bitserial { .. } => "bitserial",
            ConvKernel::Fp32 { .. } => "fp32",
            ConvKernel::Int8 { .. } => "int8",
        }
    }
}

/// A conv layer ready to execute.
#[derive(Clone, Debug)]
pub struct CompiledConv {
    pub kernel: ConvKernel,
    /// per-channel folded-BN scale and bias
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct CompiledDense {
    pub w: Vec<f32>, // (cin × cout) row-major, as exported
    pub b: Vec<f32>,
}

/// A deployable model: topology + per-layer compiled kernels.
#[derive(Clone, Debug)]
pub struct CompiledModel {
    pub graph: Graph,
    pub convs: BTreeMap<String, CompiledConv>,
    pub denses: BTreeMap<String, CompiledDense>,
}

impl CompiledModel {
    /// Total weight bytes as stored (the paper's model-size metric).
    pub fn weight_bytes(&self) -> usize {
        let mut total = 0;
        for c in self.convs.values() {
            total += match &c.kernel {
                ConvKernel::Bitserial { packed, .. } => packed.data.len() * 8,
                ConvKernel::Fp32 { wt } => wt.len() * 4,
                ConvKernel::Int8 { codes, .. } => codes.len(),
            };
            total += (c.scale.len() + c.bias.len()) * 4;
        }
        for d in self.denses.values() {
            total += (d.w.len() + d.b.len()) * 4;
        }
        total
    }

    pub fn engine_summary(&self) -> BTreeMap<&'static str, usize> {
        let mut m = BTreeMap::new();
        for c in self.convs.values() {
            *m.entry(c.kernel.engine_name()).or_insert(0) += 1;
        }
        m
    }
}

/// Executor with reusable scratch buffers (one instance per worker thread).
///
/// Scratch (im2col columns, packed activation planes, i32 accumulators)
/// grows to the largest layer and is then reused: at steady state the
/// bitserial conv path performs no heap allocation and — via the persistent
/// kernel pool handle taken at construction — no thread spawning.
pub struct Executor {
    pub nthreads: usize,
    pool: &'static threads::ThreadPool,
    scratch_cols_f32: Vec<f32>,
    scratch_cols_u8: Vec<u8>,
    scratch_acc: Vec<i32>,
    scratch_packed: Packed,
}

impl Executor {
    pub fn new(nthreads: usize) -> Executor {
        Executor {
            nthreads,
            // grab (and, on first use, spin up) the process-wide kernel pool
            // here so no inference pays thread-spawn latency
            pool: threads::global(),
            scratch_cols_f32: Vec::new(),
            scratch_cols_u8: Vec::new(),
            scratch_acc: Vec::new(),
            scratch_packed: Packed::new_zeroed(0, 0, 1),
        }
    }

    /// The persistent kernel worker pool this executor dispatches to.
    pub fn pool(&self) -> &'static threads::ThreadPool {
        self.pool
    }

    /// Run the model on `input` (NHWC; batch may differ from the nominal
    /// graph batch). Returns the graph outputs in declaration order.
    pub fn run(&mut self, model: &CompiledModel, input: &Tensor) -> Result<Vec<Tensor>> {
        let g = &model.graph;
        if input.shape.len() != 4 || input.shape[1..] != g.input_shape[1..] {
            bail!(
                "input shape {:?} incompatible with model input {:?} (batch may vary)",
                input.shape,
                g.input_shape
            );
        }
        let mut env: BTreeMap<&str, Tensor> = BTreeMap::new();
        let mut remaining = planner::use_counts(g);
        env.insert(&g.input_name, input.clone());

        for node in &g.nodes {
            let out = self.run_node(model, node, &env)?;
            // release inputs whose last consumer this was
            for i in &node.inputs {
                if let Some(c) = remaining.get_mut(i.as_str()) {
                    *c -= 1;
                    if *c == 0 && !g.outputs.iter().any(|o| o == i) {
                        env.remove(i.as_str());
                    }
                }
            }
            env.insert(&node.output, out);
        }
        g.outputs
            .iter()
            .map(|o| {
                env.get(o.as_str())
                    .cloned()
                    .ok_or_else(|| anyhow!("output {o} not produced"))
            })
            .collect()
    }

    fn run_node(
        &mut self,
        model: &CompiledModel,
        node: &Node,
        env: &BTreeMap<&str, Tensor>,
    ) -> Result<Tensor> {
        let input = |idx: usize| -> Result<&Tensor> {
            env.get(node.inputs[idx].as_str())
                .ok_or_else(|| anyhow!("missing tensor {}", node.inputs[idx]))
        };
        Ok(match &node.op {
            Op::Conv2d { stride, padding, kernel, cin, cout, .. } => {
                let x = input(0)?;
                let (n, h, w, c) = x.nhwc();
                if c != *cin {
                    bail!("{}: cin mismatch", node.name);
                }
                let d = ConvDims::new(n, h, w, c, kernel[0], kernel[1], *stride, *padding);
                let conv = model
                    .convs
                    .get(&node.name)
                    .ok_or_else(|| anyhow!("no compiled conv for {}", node.name))?;
                self.conv(x, &d, conv, *cout)?
            }
            Op::Dense { cin, cout } => {
                let x = input(0)?;
                let dense = model
                    .denses
                    .get(&node.name)
                    .ok_or_else(|| anyhow!("no compiled dense for {}", node.name))?;
                let rows = x.numel() / cin;
                let mut out = vec![0.0f32; rows * cout];
                for r in 0..rows {
                    let xr = &x.data[r * cin..(r + 1) * cin];
                    let or = &mut out[r * cout..(r + 1) * cout];
                    or.copy_from_slice(&dense.b);
                    for (i, &xv) in xr.iter().enumerate() {
                        if xv != 0.0 {
                            let wr = &dense.w[i * cout..(i + 1) * cout];
                            for (o, &wv) in or.iter_mut().zip(wr) {
                                *o += xv * wv;
                            }
                        }
                    }
                }
                let mut shape = x.shape.clone();
                *shape.last_mut().unwrap() = *cout;
                Tensor::new(shape, out)?
            }
            Op::MaxPool2d { kernel, stride, padding } => {
                let x = input(0)?;
                let (n, h, w, c) = x.nhwc();
                let (oh, ow) =
                    crate::dlrt::graph::conv_out_hw(h, w, *kernel, *stride, *padding);
                let mut out = Tensor::zeros(vec![n, oh, ow, c]);
                pool::maxpool2d(&x.data, n, h, w, c, *kernel, *stride, *padding,
                                &mut out.data);
                out
            }
            Op::GlobalAvgPool => {
                let x = input(0)?;
                let (n, h, w, c) = x.nhwc();
                let mut out = Tensor::zeros(vec![n, c]);
                pool::global_avg_pool(&x.data, n, h, w, c, &mut out.data);
                out
            }
            Op::Upsample2x => {
                let x = input(0)?;
                let (n, h, w, c) = x.nhwc();
                let mut out = Tensor::zeros(vec![n, 2 * h, 2 * w, c]);
                pool::upsample2x(&x.data, n, h, w, c, &mut out.data);
                out
            }
            Op::Add => {
                let (a, b) = (input(0)?, input(1)?);
                if a.shape != b.shape {
                    bail!(
                        "{}: add shape mismatch {:?} vs {:?}",
                        node.name,
                        a.shape,
                        b.shape
                    );
                }
                let mut out = Tensor::zeros(a.shape.clone());
                ew::add(&a.data, &b.data, &mut out.data);
                out
            }
            Op::Concat => {
                let ts: Vec<&Tensor> =
                    (0..node.inputs.len()).map(input).collect::<Result<_>>()?;
                if ts.is_empty() {
                    bail!("{}: concat with no inputs", node.name);
                }
                for t in &ts {
                    if t.shape.len() != 4 {
                        bail!("{}: concat expects rank-4 NHWC, got {:?}", node.name, t.shape);
                    }
                }
                let (n, h, w, _) = ts[0].nhwc();
                for t in &ts[1..] {
                    let (n2, h2, w2, _) = t.nhwc();
                    if (n2, h2, w2) != (n, h, w) {
                        bail!(
                            "{}: concat spatial mismatch {:?} vs {:?}",
                            node.name,
                            t.shape,
                            ts[0].shape
                        );
                    }
                }
                let rows = n * h * w;
                let parts: Vec<(&[f32], usize)> =
                    ts.iter().map(|t| (t.data.as_slice(), t.shape[3])).collect();
                let ctot: usize = parts.iter().map(|(_, c)| c).sum();
                let mut out = Tensor::zeros(vec![n, h, w, ctot]);
                ew::concat_channels(&parts, rows, &mut out.data);
                out
            }
            Op::Flatten => {
                let x = input(0)?;
                let numel: usize = x.shape[1..].iter().product();
                Tensor::new(vec![x.shape[0], numel], x.data.clone())?
            }
            Op::Relu | Op::Relu6 | Op::Silu | Op::LeakyRelu | Op::Sigmoid => {
                let x = input(0)?;
                let mut out = x.clone();
                match node.op {
                    Op::Relu => ew::relu(&mut out.data),
                    Op::Relu6 => ew::relu6(&mut out.data),
                    Op::Silu => ew::silu(&mut out.data),
                    Op::LeakyRelu => ew::leaky_relu(&mut out.data),
                    Op::Sigmoid => ew::sigmoid(&mut out.data),
                    _ => unreachable!(),
                }
                out
            }
        })
    }

    fn conv(
        &mut self,
        x: &Tensor,
        d: &ConvDims,
        conv: &CompiledConv,
        cout: usize,
    ) -> Result<Tensor> {
        let rows = d.rows();
        let patch = d.patch();
        let mut out = Tensor::zeros(vec![d.n, d.oh, d.ow, cout]);
        match &conv.kernel {
            ConvKernel::Fp32 { wt } => {
                self.scratch_cols_f32.resize(rows * patch, 0.0);
                im2col_f32(&x.data, d, &mut self.scratch_cols_f32);
                gemm_rowmajor_bt(&self.scratch_cols_f32, wt, rows, cout, patch,
                                 &mut out.data, self.nthreads);
                scale_bias_rows(&mut out.data, cout, &conv.scale, &conv.bias);
            }
            ConvKernel::Bitserial { packed, s_w, s_a, w_bits, a_bits } => {
                let (qp_a, _) = qp_qn(*a_bits, false);
                self.scratch_cols_u8.resize(rows * patch, 0);
                im2col_quant_u8(&x.data, d, *s_a, qp_a as u8, &mut self.scratch_cols_u8);
                pack_rows_u8_into(&self.scratch_cols_u8, rows, patch,
                                  *a_bits as usize, &mut self.scratch_packed);
                self.scratch_acc.resize(rows * cout, 0);
                gemm_bitserial(&self.scratch_packed, packed, *w_bits as usize,
                               &mut self.scratch_acc[..rows * cout], self.nthreads);
                dequant_scale_bias(&self.scratch_acc[..rows * cout], cout,
                                   s_a * s_w, &conv.scale, &conv.bias, &mut out.data);
            }
            ConvKernel::Int8 { codes, s_w, s_a } => {
                self.scratch_cols_u8.resize(rows * patch, 0);
                im2col_quant_u8(&x.data, d, *s_a, 255, &mut self.scratch_cols_u8);
                self.scratch_acc.resize(rows * cout, 0);
                gemm_u8i8_i32(&self.scratch_cols_u8, codes, rows, cout, patch,
                              &mut self.scratch_acc[..rows * cout], self.nthreads);
                dequant_scale_bias(&self.scratch_acc[..rows * cout], cout, s_a * s_w,
                                   &conv.scale, &conv.bias, &mut out.data);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_graph, EngineChoice};
    use crate::models::tiny_test_graph;

    #[test]
    fn fp32_vs_bitserial_exact_on_representable_conv() {
        // Single quantized conv whose weights are exact 2-bit codes
        // (s_w = 0.5) fed inputs that are exact 2-bit activation codes
        // (s_a = 0.25): bitserial and FP32 engines agree exactly (all
        // intermediate values are small dyadic rationals).
        use crate::models::single_conv_graph;

        let g = single_conv_graph(2, 2, 0.5, 0.25);
        let mq = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
        assert_eq!(mq.engine_summary().get("bitserial"), Some(&1));
        let mut ex = Executor::new(1);
        let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i % 4) as f32) * 0.25; // exact 2-bit codes at s_a=0.25
        }
        let yq = ex.run(&mq, &x).unwrap();
        let yf = ex.run(&mf, &x).unwrap();
        assert_eq!(yq[0].data, yf[0].data, "engines diverged");
    }

    #[test]
    fn quantized_network_close_to_fp32_on_smooth_input() {
        // End-to-end: 2A2W quantization error stays bounded on the tiny
        // 3-conv graph (the accuracy claim, in miniature).
        let g = tiny_test_graph(true);
        let mq = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
        let mut ex = Executor::new(1);
        let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i % 4) as f32) * 0.25;
        }
        let yq = ex.run(&mq, &x).unwrap();
        let yf = ex.run(&mf, &x).unwrap();
        let scale = yf[0].data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-3);
        assert!(yq[0].max_abs_diff(&yf[0]) / scale < 0.6,
                "quantization error unreasonably large: {} vs scale {scale}",
                yq[0].max_abs_diff(&yf[0]));
    }

    #[test]
    fn batch_dimension_flexible() {
        let g = tiny_test_graph(false);
        let m = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mut ex = Executor::new(1);
        let x1 = Tensor::zeros(vec![1, 8, 8, 3]);
        let x3 = Tensor::zeros(vec![3, 8, 8, 3]);
        let y1 = ex.run(&m, &x1).unwrap();
        let y3 = ex.run(&m, &x3).unwrap();
        assert_eq!(y1[0].shape[0], 1);
        assert_eq!(y3[0].shape[0], 3);
        // batch entries are independent: first sample equal to float
        // round-off (batching changes GEMM row-block boundaries)
        for (a, b) in y3[0].data[..y1[0].numel()].iter().zip(&y1[0].data) {
            assert!((a - b).abs() <= 1e-5 + 1e-5 * b.abs(), "{a} vs {b}");
        }
    }

    #[test]
    fn rejects_wrong_spatial_shape() {
        let g = tiny_test_graph(false);
        let m = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mut ex = Executor::new(1);
        assert!(ex.run(&m, &Tensor::zeros(vec![1, 9, 8, 3])).is_err());
    }
}

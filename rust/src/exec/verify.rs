//! Static plan verification: abstract interpretation over the [`ExecPlan`]
//! instruction stream.
//!
//! [`ExecPlan::validate`] spot-checks per-instruction invariants (arity,
//! shapes, bounds, view capability). This module goes further: it *runs* the
//! plan abstractly, tracking which byte ranges of every arena slot hold a
//! live value at each program point, and rejects plans whose aggressive
//! aliasing tricks — fused epilogues, channel-stripe writes, same-slot SPPF
//! hops, slot reuse — would read stale or never-written bytes, overlap
//! concurrent writes, or race across the worker pool's row partition.
//!
//! The abstract domain is a set of **regions** per slot. A region is the
//! byte footprint of one write (dense, or a channel stripe through a
//! [`ChanView`]) plus its provenance: which instruction wrote it and which
//! later write — legal slot reuse — killed it. Everything is measured in
//! f32 elements of a single batch item; batches scale every row count
//! linearly, so a plan proven safe at batch 1 is safe at any batch.
//!
//! Per instruction, in order:
//!
//! 1. **structure** — aligned input arrays, concat-only `cat_offs`, no
//!    unlowered `Flatten`, in-place really in place, conv/dense kernel
//!    indices resolved and in range of the plan's kernel tables (`arity`,
//!    `unlowered-op`, `in-place-alias`, `kernel-idx`).
//! 2. **bounds** — every slot id in range, every footprint inside its
//!    slot's per-batch size, overflow-checked (`slot-oob`,
//!    `footprint-oob`).
//! 3. **race proof** — a strided write must stay inside its row
//!    (`hi ≤ stride`, the lemma that makes row partitions byte-disjoint),
//!    and the [`chunk_ranges`] partition is re-derived for several worker
//!    counts to prove consecutive chunks' byte extents disjoint
//!    (`thread-race`).
//! 4. **aliasing** — the instruction's own write stripes must be pairwise
//!    disjoint (`write-overlap`), and reads from the output slot must not
//!    overlap what it writes unless lowered in-place
//!    (`same-slot-overlap`).
//! 5. **coverage** — every byte read must be covered by live regions:
//!    never-written bytes are `uninit-read`; bytes whose writer was
//!    overwritten by a later slot tenant are `clobbered-read` (the
//!    diagnostic names both the writer and the killer). Graph outputs are
//!    checked as reads at the end of the program, which is also what proves
//!    every concat root an output or consumer observes is fully covered by
//!    its stripes.
//! 6. **apply** — the write kills every overlapping live region (slot
//!    reuse is legal; only *observing* dead bytes is an error) and becomes
//!    a live region itself.
//!
//! Wiring: `build_plan_with` runs this on every plan it produces (the
//! [`PlanOpts::verify`] toggle), `format::load` refuses untrusted `.dlrt`
//! files that fail it, and `dlrt verify <model>` / `dlrt inspect --plan`
//! expose it on the CLI. `tests/verify_fuzz.rs` proves it has teeth by
//! mutating valid fuzz plans one corruption at a time.
//!
//! [`PlanOpts::verify`]: crate::exec::planner::PlanOpts::verify

use std::fmt;

use crate::dlrt::graph::Op;
use crate::exec::planner::{ChanView, ExecPlan, Instr};
use crate::util::threads::chunk_ranges;

// ---------------------------------------------------------------------------
// diagnostics
// ---------------------------------------------------------------------------

/// Rule names, stable for tests and CI greps.
pub const RULE_ARITY: &str = "arity";
pub const RULE_UNLOWERED_OP: &str = "unlowered-op";
pub const RULE_IN_PLACE_ALIAS: &str = "in-place-alias";
pub const RULE_KERNEL_IDX: &str = "kernel-idx";
pub const RULE_SLOT_OOB: &str = "slot-oob";
pub const RULE_FOOTPRINT_OOB: &str = "footprint-oob";
pub const RULE_THREAD_RACE: &str = "thread-race";
pub const RULE_WRITE_OVERLAP: &str = "write-overlap";
pub const RULE_SAME_SLOT_OVERLAP: &str = "same-slot-overlap";
pub const RULE_UNINIT_READ: &str = "uninit-read";
pub const RULE_CLOBBERED_READ: &str = "clobbered-read";

/// A structured verification failure: which rule, where, and why.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// One of the `RULE_*` constants.
    pub rule: &'static str,
    /// Offending instruction index, or `None` for the plan-level input
    /// spec / output specs.
    pub instr: Option<usize>,
    /// Instruction name (or `"input"` / `"output[k]"` for plan-level
    /// checks) — ties the diagnostic back to the graph node.
    pub name: String,
    /// Slot the violation concerns, when one is identifiable.
    pub slot: Option<usize>,
    /// Human-readable explanation with the concrete byte ranges.
    pub detail: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rule {}", self.rule)?;
        match self.instr {
            Some(i) => write!(f, " at instr {i} ({})", self.name)?,
            None => write!(f, " at {}", self.name)?,
        }
        if let Some(s) = self.slot {
            write!(f, " slot {s}")?;
        }
        write!(f, ": {}", self.detail)
    }
}

impl std::error::Error for Diagnostic {}

/// Statistics from a successful verification, for `dlrt verify` output.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct VerifyReport {
    pub instrs: usize,
    pub slots: usize,
    /// Write regions tracked across the program.
    pub regions: usize,
    /// Live regions overwritten by slot reuse (legal kills).
    pub kills: usize,
    /// Read footprints (instruction inputs + graph outputs) proven covered
    /// by live bytes.
    pub reads: usize,
    /// `(strided write, worker count)` row partitions re-derived and proven
    /// byte-disjoint.
    pub race_checks: usize,
}

// ---------------------------------------------------------------------------
// footprints
// ---------------------------------------------------------------------------

/// Byte footprint of one access inside a slot, in f32 elements at batch 1.
///
/// `Strided` is a channel stripe: rows `0..rows`, each touching elements
/// `[r*stride + lo, r*stride + hi)`. A full-width stripe (`lo == 0 &&
/// hi == stride`) is normalized to `Contig` — the bytes are identical to a
/// dense tensor's, which is exactly how `Flatten` aliases and dense readers
/// of elided concat roots see them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Foot {
    Contig { len: usize },
    Strided { rows: usize, stride: usize, lo: usize, hi: usize },
}

impl Foot {
    fn strided(rows: usize, stride: usize, lo: usize, hi: usize) -> Foot {
        if rows == 0 || lo >= hi {
            Foot::Contig { len: 0 }
        } else if lo == 0 && hi == stride {
            match rows.checked_mul(stride) {
                Some(len) => Foot::Contig { len },
                // overflow: keep the raw form; occupancy() will reject it
                None => Foot::Strided { rows, stride, lo, hi },
            }
        } else {
            Foot::Strided { rows, stride, lo, hi }
        }
    }

    fn is_empty(&self) -> bool {
        match *self {
            Foot::Contig { len } => len == 0,
            Foot::Strided { rows, lo, hi, .. } => rows == 0 || lo >= hi,
        }
    }

    /// Slot elements the access occupies (what must fit in the slot): the
    /// executor slices `rows × stride` for a strided access. Checked — a
    /// hostile plan declaring astronomical dims must fail, not wrap.
    fn occupancy(&self) -> Option<usize> {
        match *self {
            Foot::Contig { len } => Some(len),
            Foot::Strided { rows, stride, .. } => rows.checked_mul(stride),
        }
    }

    /// One-past-the-last element touched. Only called on footprints that
    /// already passed `occupancy` bounds checks, so the arithmetic fits.
    fn end(&self) -> usize {
        match *self {
            Foot::Contig { len } => len,
            Foot::Strided { rows, stride, hi, .. } => {
                if rows == 0 {
                    0
                } else {
                    (rows - 1) * stride + hi
                }
            }
        }
    }

    /// Do the two footprints touch any common element? Exact for
    /// contig/contig, contig/stripe, and equal-stride stripe pairs (the
    /// only aliasing the planner emits); conservative (byte extents) for
    /// mixed strides.
    fn overlaps(&self, other: &Foot) -> bool {
        if self.is_empty() || other.is_empty() {
            return false;
        }
        match (*self, *other) {
            (Foot::Contig { len: a }, Foot::Contig { len: b }) => a > 0 && b > 0,
            (Foot::Contig { len }, Foot::Strided { lo, .. })
            | (Foot::Strided { lo, .. }, Foot::Contig { len }) => lo < len,
            (
                Foot::Strided { stride: s1, lo: l1, hi: h1, .. },
                Foot::Strided { stride: s2, lo: l2, hi: h2, .. },
            ) => {
                if s1 == s2 {
                    // same row geometry: overlap iff channel ranges overlap
                    l1 < h2 && l2 < h1
                } else {
                    l1 < other.end() && l2 < self.end()
                }
            }
        }
    }
}

impl fmt::Display for Foot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Foot::Contig { len } => write!(f, "[0, {len})"),
            Foot::Strided { rows, stride, lo, hi } => {
                write!(f, "{rows} rows × channels [{lo}, {hi}) of {stride}")
            }
        }
    }
}

fn numel_checked(tail: &[usize]) -> Option<usize> {
    tail.iter().try_fold(1usize, |a, &d| a.checked_mul(d))
}

/// Footprint of a tensor access: dense, or a channel stripe under `view`.
fn foot_of(tail: &[usize], view: Option<&ChanView>) -> Result<Foot, String> {
    match view {
        None => match numel_checked(tail) {
            Some(len) => Ok(Foot::Contig { len }),
            None => Err(format!("element count of shape {tail:?} overflows")),
        },
        Some(v) => {
            let Some((&c, rows_tail)) = tail.split_last() else {
                return Err("a strided view needs a channel dimension".into());
            };
            let rows = numel_checked(rows_tail)
                .ok_or_else(|| format!("row count of shape {tail:?} overflows"))?;
            let hi = v
                .off
                .checked_add(c)
                .ok_or_else(|| format!("stripe end {} + {c} overflows", v.off))?;
            Ok(Foot::strided(rows, v.stride, v.off, hi))
        }
    }
}

// ---------------------------------------------------------------------------
// abstract state
// ---------------------------------------------------------------------------

/// One write's footprint plus provenance. `writer == None` is the request
/// input; `killer` is the instruction whose write overwrote these bytes.
#[derive(Debug, Clone, Copy)]
struct Region {
    foot: Foot,
    writer: Option<usize>,
    killer: Option<usize>,
}

/// Why a read footprint is not covered by live bytes.
enum Gap {
    Uninit,
    Clobbered { writer: Option<usize>, killer: usize },
}

/// Merge-and-sweep: do the (unsorted, possibly overlapping) intervals cover
/// `[lo, hi)` completely?
fn intervals_cover(iv: &mut Vec<(usize, usize)>, lo: usize, hi: usize) -> bool {
    iv.sort_unstable();
    let mut covered_to = lo;
    for &(a, b) in iv.iter() {
        if a > covered_to {
            break;
        }
        covered_to = covered_to.max(b);
        if covered_to >= hi {
            return true;
        }
    }
    covered_to >= hi
}

/// Is a strided read of `rows` rows × channels `[lo, hi)` at `stride` fully
/// covered by the live regions? Coverage comes from one dense region
/// spanning the whole extent, or from merged equal-stride stripes with at
/// least as many rows (plus whatever channel prefix a shorter dense region
/// still provides at this row depth).
fn covered_strided(live: &[&Region], rows: usize, stride: usize, lo: usize, hi: usize) -> bool {
    let extent = (rows - 1) * stride + hi;
    let mut iv: Vec<(usize, usize)> = Vec::new();
    for r in live {
        match r.foot {
            Foot::Contig { len } => {
                if len >= extent {
                    return true;
                }
                // a shorter dense region still covers the channel prefix
                // present in all `rows` rows
                let avail = len.saturating_sub((rows - 1) * stride).min(stride);
                if avail > 0 {
                    iv.push((0, avail));
                }
            }
            Foot::Strided { rows: r2, stride: s2, lo: l2, hi: h2 } => {
                if s2 == stride && r2 >= rows {
                    iv.push((l2, h2));
                }
            }
        }
    }
    intervals_cover(&mut iv, lo, hi)
}

/// Is `foot` fully covered by live bytes of `regions`? On failure, blame a
/// dead overlapping region (clobbered) if one exists, else uninit.
fn covered(regions: &[Region], foot: &Foot) -> Result<(), Gap> {
    if foot.is_empty() {
        return Ok(());
    }
    let live: Vec<&Region> = regions.iter().filter(|r| r.killer.is_none()).collect();
    let ok = match *foot {
        Foot::Contig { len } => {
            // a dense read is a full-width strided read for any candidate
            // row geometry that tiles it exactly — this is how dense
            // consumers of elided concat roots are proven covered by the
            // root's stripes
            let mut strides: Vec<usize> = live
                .iter()
                .filter_map(|r| match r.foot {
                    Foot::Strided { stride, .. } => Some(stride),
                    Foot::Contig { .. } => None,
                })
                .collect();
            strides.sort_unstable();
            strides.dedup();
            live.iter()
                .any(|r| matches!(r.foot, Foot::Contig { len: l } if l >= len))
                || strides
                    .iter()
                    .any(|&s| s > 0 && len % s == 0 && covered_strided(&live, len / s, s, 0, s))
        }
        Foot::Strided { rows, stride, lo, hi } => covered_strided(&live, rows, stride, lo, hi),
    };
    if ok {
        return Ok(());
    }
    for r in regions {
        if let Some(k) = r.killer {
            if r.foot.overlaps(foot) {
                return Err(Gap::Clobbered { writer: r.writer, killer: k });
            }
        }
    }
    Err(Gap::Uninit)
}

// ---------------------------------------------------------------------------
// the interpreter
// ---------------------------------------------------------------------------

/// Worker counts the race proof re-derives the row partition for. The
/// partition arithmetic ([`chunk_ranges`]) is monotone in the thread count,
/// so a handful of representative counts (including the odd one the fuzzer
/// runs with) proves the pattern.
const RACE_THREADS: [usize; 4] = [2, 3, 4, 8];

struct Vm<'p> {
    plan: &'p ExecPlan,
    regions: Vec<Vec<Region>>,
    report: VerifyReport,
}

/// Verify `plan` by abstract interpretation. `Ok` carries statistics for
/// human output; `Err` carries a structured [`Diagnostic`] naming the rule,
/// instruction, slot, and byte ranges involved.
pub fn verify(plan: &ExecPlan) -> Result<VerifyReport, Diagnostic> {
    let nslots = plan.slot_sizes.len();
    let mut vm = Vm {
        plan,
        regions: vec![Vec::new(); nslots],
        report: VerifyReport {
            instrs: plan.instrs.len(),
            slots: nslots,
            ..VerifyReport::default()
        },
    };

    // seed the request input as a live dense region
    let plan_diag = |rule, name: &str, slot, detail| Diagnostic {
        rule,
        instr: None,
        name: name.into(),
        slot,
        detail,
    };
    if plan.input_slot >= nslots {
        return Err(plan_diag(
            RULE_SLOT_OOB,
            "input",
            Some(plan.input_slot),
            format!("input slot {} out of range ({nslots} slots)", plan.input_slot),
        ));
    }
    let input_foot = foot_of(&plan.input_tail, None)
        .map_err(|e| plan_diag(RULE_FOOTPRINT_OOB, "input", Some(plan.input_slot), e))?;
    let occ = input_foot.occupancy().unwrap_or(usize::MAX);
    if occ > plan.slot_sizes[plan.input_slot] {
        return Err(plan_diag(
            RULE_FOOTPRINT_OOB,
            "input",
            Some(plan.input_slot),
            format!(
                "input needs {occ} elems but slot {} holds {}",
                plan.input_slot, plan.slot_sizes[plan.input_slot]
            ),
        ));
    }
    vm.regions[plan.input_slot].push(Region { foot: input_foot, writer: None, killer: None });
    vm.report.regions += 1;

    for (i, ins) in plan.instrs.iter().enumerate() {
        vm.step(i, ins)?;
    }

    // graph outputs are reads at the end of the program: every byte the
    // caller receives must be live — this is the concat-root coverage proof
    for (k, o) in plan.outputs.iter().enumerate() {
        let name = format!("output[{k}]");
        if o.slot >= nslots {
            return Err(plan_diag(
                RULE_SLOT_OOB,
                &name,
                Some(o.slot),
                format!("output slot {} out of range ({nslots} slots)", o.slot),
            ));
        }
        let foot = foot_of(&o.tail, None)
            .map_err(|e| plan_diag(RULE_FOOTPRINT_OOB, &name, Some(o.slot), e))?;
        let occ = foot.occupancy().unwrap_or(usize::MAX);
        if occ > plan.slot_sizes[o.slot] {
            return Err(plan_diag(
                RULE_FOOTPRINT_OOB,
                &name,
                Some(o.slot),
                format!("output needs {occ} elems but slot {} holds {}", o.slot,
                        plan.slot_sizes[o.slot]),
            ));
        }
        vm.check_covered(&foot, o.slot, None, &name, "output tensor")?;
    }

    Ok(vm.report)
}

impl Vm<'_> {
    fn diag(
        &self,
        rule: &'static str,
        i: usize,
        ins: &Instr,
        slot: Option<usize>,
        detail: String,
    ) -> Diagnostic {
        Diagnostic { rule, instr: Some(i), name: ins.name.clone(), slot, detail }
    }

    /// Structural bounds check of one footprint. Strided *writes* whose
    /// stripe escapes its row break the row-disjointness lemma the worker
    /// partition relies on — that is a race, not just an overflow.
    fn check_foot(
        &self,
        i: usize,
        ins: &Instr,
        foot: &Foot,
        slot: usize,
        what: &str,
        is_write: bool,
    ) -> Result<(), Diagnostic> {
        if let Foot::Strided { stride, lo, hi, .. } = *foot {
            if hi > stride {
                let (rule, why) = if is_write {
                    (
                        RULE_THREAD_RACE,
                        "rows are no longer byte-disjoint across worker chunks",
                    )
                } else {
                    (RULE_FOOTPRINT_OOB, "the read bleeds into the next row")
                };
                return Err(self.diag(
                    rule,
                    i,
                    ins,
                    Some(slot),
                    format!("{what}: stripe [{lo}, {hi}) exceeds its {stride}-channel row — {why}"),
                ));
            }
        }
        let occ = foot.occupancy().ok_or_else(|| {
            self.diag(
                RULE_FOOTPRINT_OOB,
                i,
                ins,
                Some(slot),
                format!("{what}: footprint size overflows"),
            )
        })?;
        if occ > self.plan.slot_sizes[slot] {
            return Err(self.diag(
                RULE_FOOTPRINT_OOB,
                i,
                ins,
                Some(slot),
                format!(
                    "{what}: needs {occ} elems but slot {slot} holds {}",
                    self.plan.slot_sizes[slot]
                ),
            ));
        }
        Ok(())
    }

    /// Coverage check of one read footprint against the slot's regions.
    fn check_covered(
        &mut self,
        foot: &Foot,
        slot: usize,
        instr: Option<usize>,
        name: &str,
        what: &str,
    ) -> Result<(), Diagnostic> {
        match covered(&self.regions[slot], foot) {
            Ok(()) => {
                self.report.reads += 1;
                Ok(())
            }
            Err(Gap::Uninit) => Err(Diagnostic {
                rule: RULE_UNINIT_READ,
                instr,
                name: name.into(),
                slot: Some(slot),
                detail: format!("{what} reads {foot} of slot {slot}, which was never written"),
            }),
            Err(Gap::Clobbered { writer, killer }) => Err(Diagnostic {
                rule: RULE_CLOBBERED_READ,
                instr,
                name: name.into(),
                slot: Some(slot),
                detail: format!(
                    "{what} reads {foot} of slot {slot}, but the value written by {} was \
                     overwritten by instr {killer} (slot reuse)",
                    match writer {
                        Some(w) => format!("instr {w}"),
                        None => "the request input".into(),
                    }
                ),
            }),
        }
    }

    fn step(&mut self, i: usize, ins: &Instr) -> Result<(), Diagnostic> {
        let nslots = self.plan.slot_sizes.len();
        let is_concat = matches!(ins.op, Op::Concat);

        // ---- structure ----------------------------------------------------
        if ins.in_tails.len() != ins.in_slots.len() || ins.in_views.len() != ins.in_slots.len() {
            return Err(self.diag(
                RULE_ARITY,
                i,
                ins,
                None,
                format!(
                    "{} input slots but {} tails and {} views",
                    ins.in_slots.len(),
                    ins.in_tails.len(),
                    ins.in_views.len()
                ),
            ));
        }
        if is_concat {
            if ins.cat_offs.len() != ins.in_slots.len() {
                return Err(self.diag(
                    RULE_ARITY,
                    i,
                    ins,
                    None,
                    format!(
                        "concat with {} inputs but {} destination offsets",
                        ins.in_slots.len(),
                        ins.cat_offs.len()
                    ),
                ));
            }
            if ins.out_tail.is_empty() || ins.in_tails.iter().any(|t| t.is_empty()) {
                return Err(self.diag(
                    RULE_ARITY,
                    i,
                    ins,
                    None,
                    "concat tensors need a channel dimension".into(),
                ));
            }
        } else if !ins.cat_offs.is_empty() || ins.cat_partial {
            return Err(self.diag(
                RULE_ARITY,
                i,
                ins,
                None,
                "cat_offs/cat_partial on a non-concat instruction".into(),
            ));
        }
        if matches!(ins.op, Op::Flatten) {
            return Err(self.diag(
                RULE_UNLOWERED_OP,
                i,
                ins,
                None,
                "Flatten must be lowered to a metadata-only alias, not an instruction".into(),
            ));
        }
        if ins.in_place
            && (ins.in_slots.first() != Some(&ins.out_slot)
                || ins.in_views.iter().any(|v| v.is_some())
                || ins.out_view.is_some())
        {
            return Err(self.diag(
                RULE_IN_PLACE_ALIAS,
                i,
                ins,
                Some(ins.out_slot),
                format!(
                    "in-place instruction must read and write the same slot densely \
                     (reads {:?}, writes {})",
                    ins.in_slots, ins.out_slot
                ),
            ));
        }

        // conv/dense must carry a resolved kernel index addressing the
        // plan's kernel tables (the executor indexes its kernel vectors
        // with it); any other op carrying one is a corrupted plan
        let kernel_idx_ok = match &ins.op {
            Op::Conv2d { .. } => {
                matches!(ins.kernel_idx, Some(k) if k < self.plan.conv_kernels)
            }
            Op::Dense { .. } => {
                matches!(ins.kernel_idx, Some(k) if k < self.plan.dense_kernels)
            }
            _ => ins.kernel_idx.is_none(),
        };
        if !kernel_idx_ok {
            return Err(self.diag(
                RULE_KERNEL_IDX,
                i,
                ins,
                None,
                format!(
                    "{} carries kernel_idx {:?} against tables of {} convs / {} denses",
                    ins.op.name(),
                    ins.kernel_idx,
                    self.plan.conv_kernels,
                    self.plan.dense_kernels
                ),
            ));
        }

        // ---- slot ids -----------------------------------------------------
        for &s in ins.in_slots.iter().chain(std::iter::once(&ins.out_slot)) {
            if s >= nslots {
                return Err(self.diag(
                    RULE_SLOT_OOB,
                    i,
                    ins,
                    Some(s),
                    format!("slot {s} out of range ({nslots} slots)"),
                ));
            }
        }

        // ---- footprints ---------------------------------------------------
        let mut read_foots: Vec<(usize, Foot)> = Vec::with_capacity(ins.in_slots.len());
        for (k, &s) in ins.in_slots.iter().enumerate() {
            let f = foot_of(&ins.in_tails[k], ins.in_views[k].as_ref())
                .map_err(|e| self.diag(RULE_FOOTPRINT_OOB, i, ins, Some(s), format!("input {k}: {e}")))?;
            self.check_foot(i, ins, &f, s, &format!("input {k}"), false)?;
            read_foots.push((s, f));
        }
        let write_foots: Vec<Foot> = if is_concat {
            // each copied input lands as a channel stripe of the output row
            // at `base + cat_offs[k]`; nested concats compound through the
            // output view's base offset
            let rows = numel_checked(&ins.out_tail[..ins.out_tail.len() - 1]).ok_or_else(|| {
                self.diag(RULE_FOOTPRINT_OOB, i, ins, Some(ins.out_slot),
                          "concat row count overflows".into())
            })?;
            let (base, stride) = match ins.out_view {
                Some(v) => (v.off, v.stride),
                None => (0, *ins.out_tail.last().expect("checked non-empty")),
            };
            let mut feet = Vec::with_capacity(ins.in_tails.len());
            for (k, t) in ins.in_tails.iter().enumerate() {
                let c = *t.last().expect("checked non-empty");
                let lo = base.checked_add(ins.cat_offs[k]).ok_or_else(|| {
                    self.diag(RULE_FOOTPRINT_OOB, i, ins, Some(ins.out_slot),
                              format!("destination offset of input {k} overflows"))
                })?;
                let hi = lo.checked_add(c).ok_or_else(|| {
                    self.diag(RULE_FOOTPRINT_OOB, i, ins, Some(ins.out_slot),
                              format!("destination stripe of input {k} overflows"))
                })?;
                feet.push(Foot::strided(rows, stride, lo, hi));
            }
            feet
        } else {
            vec![foot_of(&ins.out_tail, ins.out_view.as_ref()).map_err(|e| {
                self.diag(RULE_FOOTPRINT_OOB, i, ins, Some(ins.out_slot), format!("output: {e}"))
            })?]
        };
        for (k, f) in write_foots.iter().enumerate() {
            let what =
                if is_concat { format!("destination stripe {k}") } else { "output".to_string() };
            self.check_foot(i, ins, f, ins.out_slot, &what, true)?;
        }

        // ---- race proof: re-derive the worker row partition --------------
        // Every strided footprint now satisfies hi ≤ stride, so row byte
        // extents are disjoint by construction; re-derive the actual chunk
        // partition for several worker counts and prove consecutive chunks'
        // byte extents never overlap — against the same chunk_ranges math
        // the pool dispatches.
        for f in &write_foots {
            if let Foot::Strided { rows, stride, lo, hi } = *f {
                for nt in RACE_THREADS {
                    let mut prev_end: Option<usize> = None;
                    for (clo, chi) in chunk_ranges(rows, nt) {
                        let start = clo * stride + lo;
                        let end = (chi - 1) * stride + hi;
                        if let Some(pe) = prev_end {
                            if start < pe {
                                return Err(self.diag(
                                    RULE_THREAD_RACE,
                                    i,
                                    ins,
                                    Some(ins.out_slot),
                                    format!(
                                        "{nt}-thread row partition of write {f}: chunk starting \
                                         at elem {start} begins before the previous chunk ends \
                                         at {pe}"
                                    ),
                                ));
                            }
                        }
                        prev_end = Some(end);
                    }
                    self.report.race_checks += 1;
                }
            }
        }

        // ---- the instruction's own writes must not overlap ---------------
        for (a, fa) in write_foots.iter().enumerate() {
            for (b, fb) in write_foots.iter().enumerate().skip(a + 1) {
                if fa.overlaps(fb) {
                    return Err(self.diag(
                        RULE_WRITE_OVERLAP,
                        i,
                        ins,
                        Some(ins.out_slot),
                        format!("destination stripes {a} ({fa}) and {b} ({fb}) overlap"),
                    ));
                }
            }
        }

        // ---- same-slot reads must clear the writes (unless in-place) ------
        if !ins.in_place {
            for (k, (s, rf)) in read_foots.iter().enumerate() {
                if *s != ins.out_slot {
                    continue;
                }
                for wf in &write_foots {
                    if rf.overlaps(wf) {
                        return Err(self.diag(
                            RULE_SAME_SLOT_OVERLAP,
                            i,
                            ins,
                            Some(ins.out_slot),
                            format!("input {k} reads {rf} while the instruction writes {wf}"),
                        ));
                    }
                }
            }
        }

        // ---- every byte read must be live ---------------------------------
        let name = ins.name.clone();
        for (k, (s, rf)) in read_foots.iter().enumerate() {
            self.check_covered(rf, *s, Some(i), &name, &format!("input {k}"))?;
        }

        // ---- apply: kill overwritten regions, record the new value --------
        for f in write_foots {
            if f.is_empty() {
                continue;
            }
            for r in self.regions[ins.out_slot].iter_mut() {
                if r.killer.is_none() && r.foot.overlaps(&f) {
                    r.killer = Some(i);
                    self.report.kills += 1;
                }
            }
            self.regions[ins.out_slot].push(Region { foot: f, writer: Some(i), killer: None });
            self.report.regions += 1;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::planner::{build_plan, build_plan_with, PlanOpts};
    use crate::models::tiny_test_graph;

    #[test]
    fn tiny_graph_plans_verify_clean() {
        for fused in [false, true] {
            let g = tiny_test_graph(fused);
            for opts in [PlanOpts::default(), PlanOpts::none()] {
                let plan = build_plan_with(&g, opts).unwrap();
                let report = verify(&plan).unwrap_or_else(|d| panic!("rejected: {d}"));
                assert_eq!(report.instrs, plan.instrs.len());
                assert!(report.reads > 0);
                assert!(report.regions > 0);
            }
        }
    }

    #[test]
    fn shrunk_slot_is_rejected_with_footprint_rule() {
        let g = tiny_test_graph(true);
        let mut plan = build_plan(&g).unwrap();
        let victim = plan.instrs[0].out_slot;
        plan.slot_sizes[victim] = 0;
        let d = verify(&plan).unwrap_err();
        assert_eq!(d.rule, RULE_FOOTPRINT_OOB, "{d}");
        assert_eq!(d.slot, Some(victim), "{d}");
    }

    #[test]
    fn reading_an_unwritten_slot_is_rejected() {
        let g = tiny_test_graph(false);
        let mut plan = build_plan(&g).unwrap();
        // grow a fresh slot nothing ever writes and point an input at it
        plan.slot_sizes.push(1 << 20);
        let fresh = plan.slot_sizes.len() - 1;
        let victim = plan
            .instrs
            .iter()
            .position(|i| !i.in_place && i.in_views.iter().all(|v| v.is_none()))
            .expect("a dense reader exists");
        plan.instrs[victim].in_slots[0] = fresh;
        let d = verify(&plan).unwrap_err();
        assert_eq!(d.rule, RULE_UNINIT_READ, "{d}");
        assert_eq!(d.instr, Some(victim), "{d}");
        assert_eq!(d.slot, Some(fresh), "{d}");
    }

    #[test]
    fn skewed_kernel_index_is_rejected() {
        let g = tiny_test_graph(false);
        let mut plan = build_plan(&g).unwrap();
        let victim = plan
            .instrs
            .iter()
            .position(|i| i.kernel_idx.is_some())
            .expect("a conv or dense instruction exists");
        plan.instrs[victim].kernel_idx = Some(plan.conv_kernels + plan.dense_kernels + 7);
        let d = verify(&plan).unwrap_err();
        assert_eq!(d.rule, RULE_KERNEL_IDX, "{d}");
        assert_eq!(d.instr, Some(victim), "{d}");
    }

    #[test]
    fn diagnostic_display_names_rule_instr_and_slot() {
        let d = Diagnostic {
            rule: RULE_CLOBBERED_READ,
            instr: Some(7),
            name: "cv3".into(),
            slot: Some(2),
            detail: "stale bytes".into(),
        };
        let s = format!("{d}");
        assert!(s.contains("rule clobbered-read"), "{s}");
        assert!(s.contains("instr 7"), "{s}");
        assert!(s.contains("cv3"), "{s}");
        assert!(s.contains("slot 2"), "{s}");
    }
}

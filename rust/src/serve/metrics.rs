//! Gateway counters and the Prometheus text exposition (`GET /metrics`).
//!
//! Rendering follows the Prometheus text format 0.0.4: `# HELP` / `# TYPE`
//! comment pairs, then `name{label="value"} number` samples. Per-model
//! series come from each model's [`MetricsSnapshot`] (monotonic counters +
//! windowed latency quantiles); gateway-level series are plain atomics
//! bumped on the request path.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::MetricsSnapshot;
use crate::coordinator::metrics::{HIST_BUCKETS_MS, HistSnapshot};
use crate::obs::OP_CLASSES;

/// HTTP-level counters, one instance per gateway.
#[derive(Default)]
pub struct GatewayStats {
    /// 2xx responses
    pub ok: AtomicU64,
    /// 4xx responses other than 429
    pub bad_request: AtomicU64,
    /// 429 admission rejections
    pub rejected: AtomicU64,
    /// 503 shed/draining responses
    pub unavailable: AtomicU64,
    /// other 5xx responses
    pub internal: AtomicU64,
    /// connections accepted over the gateway's lifetime
    pub connections: AtomicU64,
    /// inference requests currently blocked on a model worker
    pub in_flight: AtomicU64,
}

impl GatewayStats {
    /// Classify one response status into its counter.
    pub fn record(&self, status: u16) {
        let c = match status {
            200..=299 => &self.ok,
            429 => &self.rejected,
            503 => &self.unavailable,
            500..=599 => &self.internal,
            _ => &self.bad_request,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    pub fn responses_total(&self) -> u64 {
        self.ok.load(Ordering::Relaxed)
            + self.bad_request.load(Ordering::Relaxed)
            + self.rejected.load(Ordering::Relaxed)
            + self.unavailable.load(Ordering::Relaxed)
            + self.internal.load(Ordering::Relaxed)
    }
}

/// Everything `/metrics` needs to know about one registered model.
pub struct ModelStats {
    pub name: String,
    pub queue_depth: usize,
    pub queue_cap: usize,
    pub max_batch: usize,
    pub workers: usize,
    pub arena_bytes_per_item: usize,
    /// per-replica (busy batch workers, total workers), indexed by replica
    pub replica_busy: Vec<(u64, usize)>,
    pub snap: MetricsSnapshot,
}

/// Escape a label value per the exposition format.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn sample(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(&escape_label(v));
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    if value.fract() == 0.0 && value.abs() < 1e15 {
        out.push_str(&format!("{}", value as i64));
    } else {
        out.push_str(&format!("{value}"));
    }
    out.push('\n');
}

fn header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// Render one Prometheus histogram (`_bucket`/`_sum`/`_count`) for a model.
/// Bucket counts are already cumulative in the snapshot; the `+Inf` bucket
/// equals `count` by definition.
fn hist_series(out: &mut String, name: &str, model: &str, h: &HistSnapshot) {
    let bucket = format!("{name}_bucket");
    for (le, &c) in HIST_BUCKETS_MS.iter().zip(&h.cumulative) {
        let le = format!("{le}");
        sample(out, &bucket, &[("model", model), ("le", &le)], c as f64);
    }
    sample(out, &bucket, &[("model", model), ("le", "+Inf")], h.count as f64);
    sample(out, &format!("{name}_sum"), &[("model", model)], h.sum_ms);
    sample(out, &format!("{name}_count"), &[("model", model)], h.count as f64);
}

/// Render the full exposition for the gateway + all registered models.
pub fn render_prometheus(stats: &GatewayStats, models: &[ModelStats]) -> String {
    let mut out = String::new();

    header(&mut out, "dlrt_http_responses_total", "HTTP responses by class", "counter");
    for (class, v) in [
        ("2xx", &stats.ok),
        ("4xx", &stats.bad_request),
        ("429", &stats.rejected),
        ("503", &stats.unavailable),
        ("5xx", &stats.internal),
    ] {
        sample(
            &mut out,
            "dlrt_http_responses_total",
            &[("class", class)],
            v.load(Ordering::Relaxed) as f64,
        );
    }
    header(&mut out, "dlrt_http_connections_total", "TCP connections accepted", "counter");
    sample(
        &mut out,
        "dlrt_http_connections_total",
        &[],
        stats.connections.load(Ordering::Relaxed) as f64,
    );
    header(&mut out, "dlrt_http_in_flight", "inference requests awaiting a worker", "gauge");
    sample(&mut out, "dlrt_http_in_flight", &[], stats.in_flight.load(Ordering::Relaxed) as f64);

    header(&mut out, "dlrt_model_completed_total", "requests answered per model", "counter");
    for m in models {
        sample(
            &mut out,
            "dlrt_model_completed_total",
            &[("model", &m.name)],
            m.snap.completed as f64,
        );
    }
    header(&mut out, "dlrt_model_errors_total", "execution errors per model", "counter");
    for m in models {
        sample(&mut out, "dlrt_model_errors_total", &[("model", &m.name)], m.snap.errors as f64);
    }
    header(&mut out, "dlrt_model_queue_depth", "requests waiting to batch", "gauge");
    for m in models {
        sample(&mut out, "dlrt_model_queue_depth", &[("model", &m.name)], m.queue_depth as f64);
    }
    header(&mut out, "dlrt_model_queue_cap", "admission queue bound (0 = unbounded)", "gauge");
    for m in models {
        sample(&mut out, "dlrt_model_queue_cap", &[("model", &m.name)], m.queue_cap as f64);
    }
    header(&mut out, "dlrt_model_max_batch", "effective (plan-clamped) batch limit", "gauge");
    for m in models {
        sample(&mut out, "dlrt_model_max_batch", &[("model", &m.name)], m.max_batch as f64);
    }
    header(&mut out, "dlrt_model_workers", "coordinator workers per model", "gauge");
    for m in models {
        sample(&mut out, "dlrt_model_workers", &[("model", &m.name)], m.workers as f64);
    }
    header(
        &mut out,
        "dlrt_model_replica_occupancy",
        "batch workers currently executing, per replica",
        "gauge",
    );
    for m in models {
        for (r, (busy, _workers)) in m.replica_busy.iter().enumerate() {
            let replica = format!("{r}");
            sample(
                &mut out,
                "dlrt_model_replica_occupancy",
                &[("model", &m.name), ("replica", &replica)],
                *busy as f64,
            );
        }
    }
    header(
        &mut out,
        "dlrt_model_arena_bytes_per_item",
        "execution-plan arena bytes per batch item",
        "gauge",
    );
    for m in models {
        sample(
            &mut out,
            "dlrt_model_arena_bytes_per_item",
            &[("model", &m.name)],
            m.arena_bytes_per_item as f64,
        );
    }
    header(&mut out, "dlrt_model_mean_batch", "mean executed batch size", "gauge");
    for m in models {
        sample(&mut out, "dlrt_model_mean_batch", &[("model", &m.name)], m.snap.mean_batch);
    }
    header(&mut out, "dlrt_model_throughput_rps", "completed requests per second", "gauge");
    for m in models {
        sample(
            &mut out,
            "dlrt_model_throughput_rps",
            &[("model", &m.name)],
            m.snap.throughput_rps,
        );
    }
    header(
        &mut out,
        "dlrt_model_exec_latency_ms",
        "execution latency quantiles (windowed)",
        "gauge",
    );
    for m in models {
        for (q, v) in [
            ("0.5", m.snap.p50_exec_ms),
            ("0.95", m.snap.p95_exec_ms),
            ("0.99", m.snap.p99_exec_ms),
        ] {
            sample(
                &mut out,
                "dlrt_model_exec_latency_ms",
                &[("model", &m.name), ("quantile", q)],
                v,
            );
        }
    }
    header(
        &mut out,
        "dlrt_model_queue_latency_ms",
        "queueing latency quantiles (windowed)",
        "gauge",
    );
    for m in models {
        for (q, v) in [
            ("0.5", m.snap.p50_queue_ms),
            ("0.95", m.snap.p95_queue_ms),
            ("0.99", m.snap.p99_queue_ms),
        ] {
            sample(
                &mut out,
                "dlrt_model_queue_latency_ms",
                &[("model", &m.name), ("quantile", q)],
                v,
            );
        }
    }
    header(
        &mut out,
        "dlrt_model_exec_time_ms",
        "execution time per batch (fixed buckets, ms)",
        "histogram",
    );
    for m in models {
        hist_series(&mut out, "dlrt_model_exec_time_ms", &m.name, &m.snap.exec_hist);
    }
    header(
        &mut out,
        "dlrt_model_queue_time_ms",
        "queue wait per request (fixed buckets, ms)",
        "histogram",
    );
    for m in models {
        hist_series(&mut out, "dlrt_model_queue_time_ms", &m.name, &m.snap.queue_hist);
    }
    header(
        &mut out,
        "dlrt_model_op_class_exec_seconds_total",
        "execution seconds by operator class (from profiler rings)",
        "counter",
    );
    for m in models {
        for (class, &s) in OP_CLASSES.iter().zip(&m.snap.class_exec_s) {
            sample(
                &mut out,
                "dlrt_model_op_class_exec_seconds_total",
                &[("model", &m.name), ("class", class)],
                s,
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_models() -> Vec<ModelStats> {
        // 3 exec samples all <= 2.5ms (bucket index 4); one conv-heavy
        // class breakdown so the counter series is non-zero.
        let mut class_exec_s = [0.0; crate::obs::N_CLASSES];
        class_exec_s[0] = 1.5;
        vec![ModelStats {
            name: "tiny".to_string(),
            queue_depth: 1,
            queue_cap: 8,
            max_batch: 4,
            workers: 2,
            arena_bytes_per_item: 4096,
            replica_busy: vec![(1, 1), (0, 1)],
            snap: MetricsSnapshot {
                completed: 10,
                errors: 1,
                p50_exec_ms: 1.25,
                p95_exec_ms: 2.0,
                p99_exec_ms: 2.5,
                p50_queue_ms: 0.1,
                p95_queue_ms: 0.2,
                p99_queue_ms: 0.3,
                mean_batch: 2.0,
                throughput_rps: 100.0,
                window: 10,
                queue_hist: HistSnapshot::default(),
                exec_hist: HistSnapshot {
                    cumulative: vec![0, 0, 0, 0, 3, 3, 3, 3, 3, 3, 3, 3],
                    sum_ms: 5.75,
                    count: 3,
                },
                class_exec_s,
            },
        }]
    }

    #[test]
    fn exposition_is_parseable() {
        let stats = GatewayStats::default();
        stats.record(200);
        stats.record(429);
        let text = render_prometheus(&stats, &fake_models());
        let mut samples = 0;
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let (series, value) = line.rsplit_once(' ').expect("sample has a value");
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
            let name = series.split('{').next().unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
                "bad metric name in {line:?}"
            );
            if let Some(rest) = series.strip_prefix(name) {
                if !rest.is_empty() {
                    assert!(rest.starts_with('{') && rest.ends_with('}'), "bad labels {line:?}");
                }
            }
            samples += 1;
        }
        assert!(samples > 10);
        assert!(text.contains("dlrt_model_completed_total{model=\"tiny\"} 10"));
        assert!(text.contains("dlrt_http_responses_total{class=\"429\"} 1"));
        assert!(text.contains("quantile=\"0.99\""));
        // one occupancy gauge per replica, labeled by index
        assert!(text.contains("dlrt_model_replica_occupancy{model=\"tiny\",replica=\"0\"} 1"));
        assert!(text.contains("dlrt_model_replica_occupancy{model=\"tiny\",replica=\"1\"} 0"));
    }

    #[test]
    fn histogram_exposition() {
        let text = render_prometheus(&GatewayStats::default(), &fake_models());
        // cumulative buckets, an explicit +Inf bucket equal to _count
        assert!(text.contains("dlrt_model_exec_time_ms_bucket{model=\"tiny\",le=\"1\"} 0"));
        assert!(text.contains("dlrt_model_exec_time_ms_bucket{model=\"tiny\",le=\"2.5\"} 3"));
        assert!(text.contains("dlrt_model_exec_time_ms_bucket{model=\"tiny\",le=\"+Inf\"} 3"));
        assert!(text.contains("dlrt_model_exec_time_ms_sum{model=\"tiny\"} 5.75"));
        assert!(text.contains("dlrt_model_exec_time_ms_count{model=\"tiny\"} 3"));
        // an empty histogram still exposes the +Inf bucket and zero count
        assert!(text.contains("dlrt_model_queue_time_ms_bucket{model=\"tiny\",le=\"+Inf\"} 0"));
        assert!(text.contains("dlrt_model_queue_time_ms_count{model=\"tiny\"} 0"));
        // per-op-class counters cover every class name
        for class in OP_CLASSES {
            let series = format!("exec_seconds_total{{model=\"tiny\",class=\"{class}\"}}");
            assert!(text.contains(&series), "missing class series for {class}");
        }
        let conv = "dlrt_model_op_class_exec_seconds_total{model=\"tiny\",class=\"conv\"} 1.5";
        assert!(text.contains(conv));
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn status_classes() {
        let s = GatewayStats::default();
        for code in [200, 204, 400, 404, 429, 500, 503] {
            s.record(code);
        }
        assert_eq!(s.ok.load(Ordering::Relaxed), 2);
        assert_eq!(s.bad_request.load(Ordering::Relaxed), 2);
        assert_eq!(s.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(s.internal.load(Ordering::Relaxed), 1);
        assert_eq!(s.unavailable.load(Ordering::Relaxed), 1);
        assert_eq!(s.responses_total(), 7);
    }
}

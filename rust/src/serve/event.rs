//! Readiness-driven connection engine for the gateway.
//!
//! N shard threads each own a listener (SO_REUSEPORT on Linux so the
//! kernel spreads connections across per-shard accept queues; a cloned
//! listener handle elsewhere), a `poll(2)` loop over their accepted
//! connections, and an injector queue that batch-worker completion
//! callbacks push finished responses into. No thread ever blocks on a
//! client socket: reads are non-blocking and feed the resumable
//! [`StreamParser`], writes are buffered and flushed on `POLLOUT`, and a
//! peer that stops reading only stalls its own connection slot — never
//! the accept path, never another connection.
//!
//! Cross-shard signaling uses a loopback TCP pair as a self-pipe (std
//! has no eventfd): coordinator workers push a [`Completion`] and write
//! one byte to the shard's waker, which `poll` observes as readability.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::obs::trace::{SpanKind, SpanRec};

use super::http::{ParseEvent, Response, StreamParser};
use super::{Action, GwShared, ReqCtx};

/// How long an over-cap shed connection gets to pick up its 503 before
/// the slot is reclaimed; a stalled peer never holds resources longer.
const SHED_FLUSH_TIMEOUT: Duration = Duration::from_secs(2);

/// Per-shard read scratch, reused across connections.
const READ_CHUNK: usize = 64 * 1024;

// ---------------------------------------------------------------------------
// poll(2) binding
// ---------------------------------------------------------------------------

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_short};

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const POLLERR: c_short = 0x008;
    pub const POLLHUP: c_short = 0x010;

    #[cfg(target_os = "linux")]
    type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type NfdsT = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
    }

    /// `poll(2)`; a negative timeout blocks until an event. EINTR retries.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> usize {
        loop {
            // SAFETY: the pointer/length pair describes a live mutable
            // slice of #[repr(C)] pollfd records matching the kernel ABI;
            // the kernel only writes `revents` within those bounds.
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
            if rc >= 0 {
                return rc as usize;
            }
            if std::io::Error::last_os_error().kind() != std::io::ErrorKind::Interrupted {
                // unrecoverable poll failure: report nothing ready — the
                // deadline sweep still makes progress
                return 0;
            }
        }
    }
}

#[cfg(not(unix))]
mod sys {
    //! Degraded fallback for platforms without `poll(2)`: after a short
    //! sleep every descriptor is reported ready. The loop burns a little
    //! CPU but stays correct, because all I/O is non-blocking and every
    //! read/write path tolerates `WouldBlock`.

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> usize {
        let ms = if timeout_ms < 0 { 2 } else { timeout_ms.min(2) };
        std::thread::sleep(std::time::Duration::from_millis(ms as u64));
        for f in fds.iter_mut() {
            f.revents = f.events;
        }
        fds.len()
    }
}

#[cfg(unix)]
fn raw_fd(s: &TcpStream) -> i32 {
    use std::os::unix::io::AsRawFd;
    s.as_raw_fd()
}

#[cfg(unix)]
fn raw_listener_fd(l: &TcpListener) -> i32 {
    use std::os::unix::io::AsRawFd;
    l.as_raw_fd()
}

#[cfg(not(unix))]
fn raw_fd(_s: &TcpStream) -> i32 {
    -1
}

#[cfg(not(unix))]
fn raw_listener_fd(_l: &TcpListener) -> i32 {
    -1
}

// ---------------------------------------------------------------------------
// SO_REUSEPORT acceptor sharding (Linux)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod reuseport {
    use std::net::{SocketAddr, TcpListener};
    use std::os::raw::{c_int, c_uint};
    use std::os::unix::io::FromRawFd;

    const AF_INET: c_int = 2;
    const SOCK_STREAM: c_int = 1;
    const SOCK_CLOEXEC: c_int = 0o2000000;
    const SOL_SOCKET: c_int = 1;
    const SO_REUSEADDR: c_int = 2;
    const SO_REUSEPORT: c_int = 15;

    #[repr(C)]
    struct SockaddrIn {
        sin_family: u16,
        /// network byte order
        sin_port: u16,
        /// network byte order
        sin_addr: u32,
        sin_zero: [u8; 8],
    }

    extern "C" {
        fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
        fn setsockopt(
            fd: c_int,
            level: c_int,
            name: c_int,
            val: *const c_int,
            len: c_uint,
        ) -> c_int;
        #[link_name = "bind"]
        fn c_bind(fd: c_int, addr: *const SockaddrIn, len: c_uint) -> c_int;
        fn listen(fd: c_int, backlog: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    /// Bind an IPv4 listener with SO_REUSEPORT set, giving each shard its
    /// own kernel accept queue. Returns `None` (the caller falls back to a
    /// shared listener) for IPv6 addresses or on any syscall failure.
    pub fn bind(addr: SocketAddr) -> Option<TcpListener> {
        let SocketAddr::V4(v4) = addr else { return None };
        // SAFETY: plain socket(2) call; the returned fd is checked below
        // and either closed or moved into a TcpListener.
        let fd = unsafe { socket(AF_INET, SOCK_STREAM | SOCK_CLOEXEC, 0) };
        if fd < 0 {
            return None;
        }
        let sa = SockaddrIn {
            sin_family: AF_INET as u16,
            sin_port: v4.port().to_be(),
            // octets() are already network-ordered; keep their memory layout
            sin_addr: u32::from_ne_bytes(v4.ip().octets()),
            sin_zero: [0; 8],
        };
        let one: c_int = 1;
        let optlen = std::mem::size_of::<c_int>() as c_uint;
        let salen = std::mem::size_of::<SockaddrIn>() as c_uint;
        // SAFETY: fd is a live socket we own; the option value and
        // sockaddr pointers reference properly sized stack locals for the
        // duration of each call.
        let rc = unsafe {
            let mut rc = setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, &one, optlen);
            if rc == 0 {
                rc = setsockopt(fd, SOL_SOCKET, SO_REUSEPORT, &one, optlen);
            }
            if rc == 0 {
                rc = c_bind(fd, &sa, salen);
            }
            if rc == 0 {
                rc = listen(fd, 1024);
            }
            rc
        };
        if rc != 0 {
            // SAFETY: fd came from socket(2) above and was never wrapped.
            unsafe { close(fd) };
            return None;
        }
        // SAFETY: fd is a freshly bound, listening socket; ownership moves
        // into the TcpListener, which closes it on drop.
        Some(unsafe { TcpListener::from_raw_fd(fd) })
    }
}

/// Raise the process fd soft limit toward the hard limit when `need`
/// concurrent sockets would not fit (CI runners default to 1024, far
/// below a 10k-connection soak). Best effort; failure is harmless.
#[cfg(target_os = "linux")]
pub fn raise_nofile_limit(need: usize) {
    #[repr(C)]
    struct Rlimit {
        cur: u64,
        max: u64,
    }
    const RLIMIT_NOFILE: i32 = 7;
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    let mut lim = Rlimit { cur: 0, max: 0 };
    // SAFETY: the pointer references a live, correctly laid out local
    // struct the kernel fills in.
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return;
    }
    // headroom for listeners, wakers, model files, and stdio
    let want = need as u64 + 64;
    if lim.cur >= want {
        return;
    }
    let new = Rlimit { cur: want.min(lim.max), max: lim.max };
    // SAFETY: the pointer references a live local struct; raising only
    // the soft limit toward the hard limit needs no privileges.
    let _ = unsafe { setrlimit(RLIMIT_NOFILE, &new) };
}

#[cfg(not(target_os = "linux"))]
pub fn raise_nofile_limit(_need: usize) {}

// ---------------------------------------------------------------------------
// tokens, wakers, injectors
// ---------------------------------------------------------------------------

/// Identifies one connection slot in one shard. The generation guards
/// against slot reuse: a completion for a connection that died while its
/// request executed carries a stale generation and is dropped.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct ConnToken {
    slot: u32,
    gen: u32,
}

/// A finished response headed back to a shard's event loop.
pub(super) struct Completion {
    pub token: ConnToken,
    pub resp: Response,
    /// close after flushing (the request asked, or the gateway is draining)
    pub close: bool,
}

/// Self-pipe: writing one byte makes the owning shard's `poll` return.
struct Waker {
    tx: TcpStream,
}

impl Waker {
    fn wake(&self) {
        // non-blocking 1-byte write; WouldBlock means wakes are already
        // pending, which is just as good
        let _ = (&self.tx).write(&[1]);
    }
}

/// Build a connected loopback pair (std has no socketpair/eventfd). The
/// accept side verifies the peer is our own connect, not a stranger that
/// raced us to the ephemeral port.
fn wake_pair() -> std::io::Result<(TcpStream, TcpStream)> {
    let l = TcpListener::bind("127.0.0.1:0")?;
    let tx = TcpStream::connect(l.local_addr()?)?;
    let local = tx.local_addr()?;
    loop {
        let (rx, peer) = l.accept()?;
        if peer == local {
            tx.set_nonblocking(true)?;
            rx.set_nonblocking(true)?;
            return Ok((tx, rx));
        }
    }
}

/// Completion mailbox of one shard. Coordinator-worker callbacks push
/// from their threads; the shard drains on its next loop iteration.
pub struct Injector {
    queue: Mutex<Vec<Completion>>,
    waker: Waker,
}

impl Injector {
    pub(super) fn push(&self, c: Completion) {
        self.queue.lock().unwrap().push(c);
        self.waker.wake();
    }

    /// Wake the shard without queueing anything (stop signal).
    pub(super) fn wake(&self) {
        self.waker.wake();
    }

    fn drain(&self) -> Vec<Completion> {
        std::mem::take(&mut *self.queue.lock().unwrap())
    }
}

// ---------------------------------------------------------------------------
// listener sharding
// ---------------------------------------------------------------------------

/// One listener per shard: SO_REUSEPORT when available (per-shard kernel
/// accept queues), otherwise clones of a single shared listener.
fn shard_listeners(listen: &str, n: usize) -> Result<(SocketAddr, Vec<TcpListener>)> {
    use std::net::ToSocketAddrs;
    let addr = listen
        .to_socket_addrs()
        .with_context(|| format!("resolving {listen}"))?
        .next()
        .ok_or_else(|| anyhow!("no address for {listen}"))?;
    #[cfg(target_os = "linux")]
    if n > 1 {
        if let Some(first) = reuseport::bind(addr) {
            if let Ok(bound) = first.local_addr() {
                // port 0 resolved by the first bind; siblings join it
                let mut ls = vec![first];
                while ls.len() < n {
                    match reuseport::bind(bound) {
                        Some(l) => ls.push(l),
                        None => break,
                    }
                }
                if ls.len() == n {
                    for l in &ls {
                        l.set_nonblocking(true).context("set_nonblocking")?;
                    }
                    return Ok((bound, ls));
                }
                // partial failure: drop what we made, fall through to the
                // shared-listener path
            }
        }
    }
    let first = TcpListener::bind(addr).with_context(|| format!("binding {listen}"))?;
    first.set_nonblocking(true).context("set_nonblocking")?;
    let bound = first.local_addr()?;
    let mut ls = vec![first];
    while ls.len() < n {
        ls.push(ls[0].try_clone().context("cloning listener")?);
    }
    Ok((bound, ls))
}

// ---------------------------------------------------------------------------
// shard event loop
// ---------------------------------------------------------------------------

struct Conn {
    stream: TcpStream,
    parser: StreamParser,
    /// outgoing chunks; `out_off` bytes of the front one already written
    out: VecDeque<Vec<u8>>,
    out_off: usize,
    /// an infer is in flight: reads pause so responses stay ordered and a
    /// flooding peer gets TCP backpressure instead of unbounded buffering
    pending: bool,
    close_after_flush: bool,
    /// holds a ConnLimiter slot (over-cap shed connections do not)
    holds_slot: bool,
    /// over-cap 503: close at this deadline even if the peer never reads
    shed_deadline: Option<Instant>,
    last_activity: Instant,
}

impl Conn {
    fn new(stream: TcpStream, max_body: usize, holds_slot: bool) -> Conn {
        Conn {
            stream,
            parser: StreamParser::new(max_body),
            out: VecDeque::new(),
            out_off: 0,
            pending: false,
            close_after_flush: false,
            holds_slot,
            shed_deadline: None,
            last_activity: Instant::now(),
        }
    }
}

/// Queue `resp` on `conn` as head + body chunks. The body `Vec` moves into
/// the write queue — raw-f32 infer bodies are written from the single
/// buffer the completion callback rendered, no further copies.
fn queue_response(conn: &mut Conn, resp: Response, close: bool) {
    conn.out.push_back(resp.head_bytes(close));
    if !resp.body.is_empty() {
        conn.out.push_back(resp.body);
    }
    if close {
        conn.close_after_flush = true;
    }
}

/// A running shard: its injector (for completions and stop wakes) plus
/// the loop thread to join on shutdown.
pub(super) struct ShardHandle {
    pub injector: Arc<Injector>,
    pub thread: JoinHandle<()>,
}

/// Bind `listen` and start `n` shard event loops over it.
pub(super) fn spawn_shards(
    listen: &str,
    n: usize,
    shared: &Arc<GwShared>,
) -> Result<(SocketAddr, Vec<ShardHandle>)> {
    let (addr, listeners) = shard_listeners(listen, n.max(1))?;
    let mut shards = Vec::with_capacity(listeners.len());
    for (i, listener) in listeners.into_iter().enumerate() {
        let (wtx, wrx) = wake_pair().context("creating shard waker")?;
        let injector =
            Arc::new(Injector { queue: Mutex::new(Vec::new()), waker: Waker { tx: wtx } });
        let shard = Shard {
            shared: shared.clone(),
            injector: injector.clone(),
            listener: Some(listener),
            waker_rx: wrx,
            conns: Vec::new(),
            gens: Vec::new(),
            free: Vec::new(),
            scratch: vec![0u8; READ_CHUNK],
            draining: false,
            drain_deadline: None,
        };
        let thread = std::thread::Builder::new()
            .name(format!("dlrt-gw-{i}"))
            .spawn(move || shard.run())
            .context("spawning gateway shard")?;
        shards.push(ShardHandle { injector, thread });
    }
    Ok((addr, shards))
}

struct Shard {
    shared: Arc<GwShared>,
    injector: Arc<Injector>,
    listener: Option<TcpListener>,
    waker_rx: TcpStream,
    conns: Vec<Option<Conn>>,
    /// per-slot generation, bumped on close so stale tokens miss
    gens: Vec<u32>,
    free: Vec<usize>,
    scratch: Vec<u8>,
    draining: bool,
    drain_deadline: Option<Instant>,
}

impl Shard {
    fn run(mut self) {
        loop {
            if !self.draining && self.shared.stop.load(Ordering::SeqCst) {
                self.begin_drain();
            }
            for c in self.injector.drain() {
                self.complete(c);
            }
            if self.draining {
                if self.conns.iter().all(Option::is_none) {
                    return;
                }
                if self.drain_deadline.is_some_and(|d| Instant::now() >= d) {
                    return; // deadline: remaining connections drop here
                }
            }
            self.poll_once();
            self.sweep_deadlines();
        }
    }

    /// Stop accepting (the listener drops, closing the port), close idle
    /// connections, and mark the rest close-after-flush. Connections with
    /// an infer in flight stay until their completion arrives — the
    /// registry drain happening in parallel guarantees it will.
    fn begin_drain(&mut self) {
        self.draining = true;
        self.drain_deadline = Some(Instant::now() + self.shared.cfg.drain_timeout);
        self.listener = None;
        for slot in 0..self.conns.len() {
            let close_now = match self.conns[slot].as_ref() {
                Some(c) => !c.pending && c.out.is_empty(),
                None => false,
            };
            if close_now {
                self.close_conn(slot);
            } else if let Some(c) = self.conns[slot].as_mut() {
                c.close_after_flush = true;
            }
        }
    }

    /// Deliver one completion pushed by a coordinator worker callback.
    fn complete(&mut self, c: Completion) {
        let slot = c.token.slot as usize;
        if slot >= self.conns.len() || self.gens[slot] != c.token.gen {
            return; // connection died while the batch executed
        }
        let close = c.close || self.draining;
        {
            let Some(conn) = self.conns[slot].as_mut() else { return };
            conn.pending = false;
            conn.last_activity = Instant::now();
            queue_response(conn, c.resp, close);
        }
        self.flush(slot);
        // pipelined bytes may already hold the next request
        self.advance(slot);
    }

    fn poll_timeout_ms(&self) -> i32 {
        let now = Instant::now();
        let mut next: Option<Instant> = None;
        for c in self.conns.iter().flatten() {
            let dl = match c.shed_deadline {
                Some(d) => d,
                // pending connections are woken by their completion
                None if c.pending => continue,
                None => c.last_activity + self.shared.cfg.idle_timeout,
            };
            next = Some(next.map_or(dl, |n| n.min(dl)));
        }
        if let Some(d) = self.drain_deadline {
            next = Some(next.map_or(d, |n| n.min(d)));
        }
        match next {
            // +1 rounds up so we don't spin on a sub-ms remainder
            Some(d) => d.saturating_duration_since(now).as_millis().min(60_000) as i32 + 1,
            None => -1,
        }
    }

    fn poll_once(&mut self) {
        let mut fds: Vec<sys::PollFd> = Vec::with_capacity(self.conns.len() + 2);
        fds.push(sys::PollFd { fd: raw_fd(&self.waker_rx), events: sys::POLLIN, revents: 0 });
        let listener_at = self.listener.as_ref().map(|l| {
            fds.push(sys::PollFd { fd: raw_listener_fd(l), events: sys::POLLIN, revents: 0 });
            fds.len() - 1
        });
        let base = fds.len();
        let mut slots: Vec<usize> = Vec::with_capacity(self.conns.len());
        for (slot, conn) in self.conns.iter().enumerate() {
            let Some(c) = conn else { continue };
            let mut ev = 0;
            if !c.pending && !c.close_after_flush && c.shed_deadline.is_none() {
                ev |= sys::POLLIN;
            }
            if !c.out.is_empty() {
                ev |= sys::POLLOUT;
            }
            // POLLERR/POLLHUP are reported regardless of `events`
            fds.push(sys::PollFd { fd: raw_fd(&c.stream), events: ev, revents: 0 });
            slots.push(slot);
        }
        if sys::poll_fds(&mut fds, self.poll_timeout_ms()) == 0 {
            return;
        }
        if fds[0].revents != 0 {
            self.drain_waker();
        }
        if let Some(i) = listener_at {
            if fds[i].revents != 0 {
                self.accept_ready();
            }
        }
        for (k, &slot) in slots.iter().enumerate() {
            let re = fds[base + k].revents;
            if re == 0 {
                continue;
            }
            if re & sys::POLLERR != 0 {
                self.close_conn(slot);
                continue;
            }
            if re & sys::POLLOUT != 0 {
                self.flush(slot);
            }
            if re & (sys::POLLIN | sys::POLLHUP) != 0 {
                self.read_ready(slot);
            }
        }
    }

    fn drain_waker(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match self.waker_rx.read(&mut buf) {
                Ok(0) => return, // write side gone (gateway teardown)
                Ok(_) => continue,
                Err(_) => return, // WouldBlock: drained
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            let Some(listener) = self.listener.as_ref() else { return };
            match listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let seq = self.shared.stats.connections.fetch_add(1, Ordering::Relaxed);
                    self.shared.trace.record(SpanRec {
                        kind: SpanKind::Accept,
                        req: seq,
                        ts_us: self.shared.trace.now_us(),
                        dur_us: 0,
                        batch_index: 0,
                        batch_size: 0,
                        status: 0,
                    });
                    let admitted = self.shared.conns.try_acquire();
                    let mut conn = Conn::new(stream, self.shared.cfg.max_body_bytes, admitted);
                    if !admitted {
                        // over the connection cap: shed WITHOUT blocking —
                        // the 503 is queued and flushed by POLLOUT; a peer
                        // that never reads it is cut off at the deadline
                        let resp = Response::text(503, "too many connections\n");
                        self.shared.stats.record(resp.status);
                        queue_response(&mut conn, resp, true);
                        conn.shed_deadline = Some(Instant::now() + SHED_FLUSH_TIMEOUT);
                    }
                    let slot = self.insert(conn);
                    if self.conns[slot].as_ref().is_some_and(|c| !c.out.is_empty()) {
                        self.flush(slot);
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(_) => return,
            }
        }
    }

    fn insert(&mut self, conn: Conn) -> usize {
        match self.free.pop() {
            Some(slot) => {
                self.conns[slot] = Some(conn);
                slot
            }
            None => {
                self.conns.push(Some(conn));
                self.gens.push(0);
                self.conns.len() - 1
            }
        }
    }

    fn close_conn(&mut self, slot: usize) {
        if let Some(c) = self.conns[slot].take() {
            if c.holds_slot {
                self.shared.conns.release();
            }
            self.gens[slot] = self.gens[slot].wrapping_add(1);
            self.free.push(slot);
        }
    }

    fn read_ready(&mut self, slot: usize) {
        let mut saw_eof = false;
        loop {
            let Some(c) = self.conns[slot].as_mut() else { return };
            if c.pending || c.close_after_flush || c.shed_deadline.is_some() {
                break;
            }
            match c.stream.read(&mut self.scratch) {
                Ok(0) => {
                    saw_eof = true;
                    break;
                }
                Ok(n) => {
                    c.last_activity = Instant::now();
                    c.parser.feed(&self.scratch[..n]);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close_conn(slot);
                    return;
                }
            }
        }
        self.advance(slot);
        if saw_eof {
            // peer closed: anything parseable has been dispatched; the
            // socket can produce no further requests
            self.close_conn(slot);
        }
    }

    /// Drain parser events: dispatch complete requests, answer protocol
    /// errors, stop at the first in-flight infer (response ordering).
    fn advance(&mut self, slot: usize) {
        loop {
            let token = ConnToken { slot: slot as u32, gen: self.gens[slot] };
            let event = {
                let Some(c) = self.conns[slot].as_mut() else { return };
                if c.pending || c.close_after_flush {
                    return;
                }
                match c.parser.next() {
                    Ok(Some(ev)) => ev,
                    Ok(None) => return,
                    Err(_) => {
                        let resp = Response::text(400, "malformed request\n");
                        self.shared.stats.record(resp.status);
                        queue_response(c, resp, true);
                        self.flush(slot);
                        return;
                    }
                }
            };
            match event {
                ParseEvent::Request(req) => {
                    let close = req.close || self.draining;
                    let ctx = ReqCtx { token, injector: self.injector.clone() };
                    match super::dispatch(&self.shared, req, ctx) {
                        Action::Respond(resp) => {
                            let Some(c) = self.conns[slot].as_mut() else { return };
                            queue_response(c, resp, close);
                        }
                        Action::Pending => {
                            let Some(c) = self.conns[slot].as_mut() else { return };
                            c.pending = true;
                        }
                    }
                }
                ParseEvent::TooLarge(n) => {
                    let resp = Response::text(413, &format!("body of {n} bytes over limit\n"));
                    self.shared.stats.record(resp.status);
                    let Some(c) = self.conns[slot].as_mut() else { return };
                    queue_response(c, resp, true);
                }
                ParseEvent::Unsupported(what) => {
                    let resp = Response::text(501, &format!("{what}\n"));
                    self.shared.stats.record(resp.status);
                    let Some(c) = self.conns[slot].as_mut() else { return };
                    queue_response(c, resp, true);
                }
            }
            self.flush(slot);
        }
    }

    /// Write queued chunks until the socket would block; close once empty
    /// if the connection is marked close-after-flush (or we are draining
    /// and nothing is in flight).
    fn flush(&mut self, slot: usize) {
        loop {
            let Some(c) = self.conns[slot].as_mut() else { return };
            let Some(front) = c.out.front() else { break };
            match c.stream.write(&front[c.out_off..]) {
                Ok(0) => {
                    self.close_conn(slot);
                    return;
                }
                Ok(n) => {
                    c.out_off += n;
                    if c.out_off >= front.len() {
                        c.out.pop_front();
                        c.out_off = 0;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close_conn(slot);
                    return;
                }
            }
        }
        let Some(c) = self.conns[slot].as_mut() else { return };
        if c.out.is_empty() && (c.close_after_flush || (self.draining && !c.pending)) {
            self.close_conn(slot);
        }
    }

    fn sweep_deadlines(&mut self) {
        let now = Instant::now();
        let idle = self.shared.cfg.idle_timeout;
        for slot in 0..self.conns.len() {
            let expired = match self.conns[slot].as_ref() {
                Some(c) => match c.shed_deadline {
                    Some(d) => now >= d,
                    None => {
                        !c.pending && now.saturating_duration_since(c.last_activity) >= idle
                    }
                },
                None => false,
            };
            if expired {
                self.close_conn(slot);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wake_pair_signals_and_drains() {
        let (tx, mut rx) = wake_pair().unwrap();
        let w = Waker { tx };
        w.wake();
        w.wake();
        // non-blocking read sees the bytes (possibly coalesced)
        let mut buf = [0u8; 8];
        let deadline = Instant::now() + Duration::from_secs(5);
        let n = loop {
            match rx.read(&mut buf) {
                Ok(n) => break n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    assert!(Instant::now() < deadline, "wake byte never arrived");
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => panic!("{e}"),
            }
        };
        assert!(n >= 1);
    }

    #[test]
    fn injector_queue_roundtrip() {
        let (tx, _rx) = wake_pair().unwrap();
        let inj = Injector { queue: Mutex::new(Vec::new()), waker: Waker { tx } };
        inj.push(Completion {
            token: ConnToken { slot: 3, gen: 7 },
            resp: Response::text(200, "ok\n"),
            close: false,
        });
        let got = inj.drain();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].token, ConnToken { slot: 3, gen: 7 });
        assert!(inj.drain().is_empty());
    }

    #[test]
    fn shard_listeners_share_one_port() {
        let (addr, ls) = shard_listeners("127.0.0.1:0", 3).unwrap();
        assert_eq!(ls.len(), 3);
        for l in &ls {
            assert_eq!(l.local_addr().unwrap().port(), addr.port());
        }
        // the port actually accepts
        let _c = TcpStream::connect(addr).unwrap();
    }

    #[test]
    fn nofile_limit_raise_is_harmless() {
        raise_nofile_limit(64); // must never panic or error loudly
    }

    #[test]
    fn response_chunks_preserve_wire_bytes() {
        let resp = Response::bytes(200, vec![1, 2, 3]).header("X-T", "v");
        let mut whole = Vec::new();
        resp.write_to(&mut whole, false).unwrap();
        let mut conn_out: Vec<u8> = Vec::new();
        let head = resp.head_bytes(false);
        conn_out.extend_from_slice(&head);
        conn_out.extend_from_slice(&resp.body);
        assert_eq!(whole, conn_out, "chunked queueing must match write_to bytes");
    }
}

//! Multi-model registry: name → compiled model + its serving coordinator.
//!
//! Each registered model owns a full [`InferenceServer`] (bounded queue,
//! batcher, workers), so models are isolated: one model's overload sheds
//! its own traffic without stalling the others. The registry map is
//! `RwLock`'d — the request path takes a read lock for a single `Arc`
//! clone; loads/unloads take the write lock only to swap map entries, and
//! drain replaced servers *outside* the lock.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, bail, Context, Result};

use crate::compiler::{compile_graph, EngineChoice};
use crate::coordinator::{InferenceServer, ServerConfig};
use crate::dlrt::format;
use crate::exec::CompiledModel;
use crate::models;
use crate::util::json::Json;

/// Where a model comes from.
#[derive(Clone, Debug)]
pub enum ModelSource {
    /// `.dlrt` file or exported `arch.json` + `weights.bin` directory.
    Path(String),
    /// Native builder (`resnet18`, `yolov5n`, ...) at a resolution.
    Builder { model: String, res: usize, w_bits: u8, a_bits: u8 },
}

impl ModelSource {
    pub fn describe(&self) -> String {
        match self {
            ModelSource::Path(p) => p.clone(),
            ModelSource::Builder { model, res, w_bits, a_bits } => {
                format!("{model}@{res} ({a_bits}A{w_bits}W)")
            }
        }
    }
}

/// One `--models` item / admin-load request, resolved to a name + source
/// plus optional per-model coordinator overrides.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub source: ModelSource,
    /// `key=value` coordinator overrides from the spec's `;`-segments,
    /// applied over the registry default by [`ModelSpec::apply_overrides`]
    pub overrides: Vec<(String, String)>,
}

impl ModelSpec {
    /// Parse one `--models` item: `[name=]source[;key=value...]` where
    /// `source` is a path (contains a separator, ends in `.dlrt`, or
    /// exists on disk) or a builder spec `model[@res]`. Without `name=`,
    /// paths are named by file stem and builders by their spec string
    /// (`resnet18@64`). Trailing `;key=value` segments override the
    /// per-model coordinator config (`workers`, `max_batch`,
    /// `max_wait_ms`, `threads_per_worker`, `queue_cap`, `replicas`,
    /// `pin_cores`), e.g. `det=yolov5n@320;replicas=2;pin_cores=true`.
    pub fn parse(item: &str) -> Result<ModelSpec> {
        let item = item.trim();
        if item.is_empty() {
            bail!("empty model spec");
        }
        let mut segments = item.split(';');
        let head = segments.next().unwrap_or("").trim();
        if head.is_empty() {
            bail!("empty model spec");
        }
        let mut overrides = Vec::new();
        for seg in segments {
            let seg = seg.trim();
            if seg.is_empty() {
                continue;
            }
            let (k, v) = seg
                .split_once('=')
                .ok_or_else(|| anyhow!("model override {seg:?} is not key=value"))?;
            overrides.push((k.trim().to_string(), v.trim().to_string()));
        }
        let (name, src) = match head.split_once('=') {
            Some((n, s)) => (Some(n.trim().to_string()), s.trim().to_string()),
            None => (None, item.to_string()),
        };
        let looks_like_path =
            src.contains('/') || src.ends_with(".dlrt") || Path::new(&src).exists();
        let source = if looks_like_path {
            ModelSource::Path(src.clone())
        } else {
            let (model, res) = match src.split_once('@') {
                Some((m, r)) => {
                    (m.to_string(), r.parse::<usize>().context("bad @res in model spec")?)
                }
                None => (src.clone(), models::default_res(&src)),
            };
            ModelSource::Builder { model, res, w_bits: 2, a_bits: 2 }
        };
        let name = name.unwrap_or_else(|| match &source {
            ModelSource::Path(p) => Path::new(p)
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| p.clone()),
            ModelSource::Builder { .. } => src.clone(),
        });
        Ok(ModelSpec { name, source, overrides })
    }

    /// Apply this spec's `;key=value` overrides over `base`. Unknown keys
    /// and unparseable values are errors (a typo must not silently serve
    /// with the default config).
    pub fn apply_overrides(&self, mut base: ServerConfig) -> Result<ServerConfig> {
        for (k, v) in &self.overrides {
            let bad = || anyhow!("model {:?}: bad value {v:?} for override {k:?}", self.name);
            match k.as_str() {
                "workers" => base.workers = v.parse().map_err(|_| bad())?,
                "max_batch" => base.max_batch = v.parse().map_err(|_| bad())?,
                "max_wait_ms" => {
                    base.max_wait =
                        std::time::Duration::from_millis(v.parse().map_err(|_| bad())?)
                }
                "threads_per_worker" => {
                    base.threads_per_worker = v.parse().map_err(|_| bad())?
                }
                "queue_cap" => base.queue_cap = v.parse().map_err(|_| bad())?,
                "replicas" => base.replicas = v.parse().map_err(|_| bad())?,
                "pin_cores" => {
                    base.pin_cores = match v.as_str() {
                        "true" | "1" => true,
                        "false" | "0" => false,
                        _ => return Err(bad()),
                    }
                }
                _ => bail!(
                    "model {:?}: unknown override {k:?} (expected workers, max_batch, \
                     max_wait_ms, threads_per_worker, queue_cap, replicas, or pin_cores)",
                    self.name
                ),
            }
        }
        Ok(base)
    }

    /// Admin-endpoint body → spec: `{"path": "m.dlrt"}` or
    /// `{"builder": "resnet18", "res": 64, "w_bits": 2, "a_bits": 2}`.
    pub fn from_json(name: &str, v: &Json) -> Result<ModelSpec> {
        let source = if let Some(p) = v.opt("path") {
            ModelSource::Path(p.str()?.to_string())
        } else if let Some(b) = v.opt("builder") {
            let model = b.str()?.to_string();
            let res = match v.opt("res") {
                Some(r) => r.usize()?,
                None => models::default_res(&model),
            };
            let w_bits = match v.opt("w_bits") {
                Some(x) => x.usize()? as u8,
                None => 2,
            };
            let a_bits = match v.opt("a_bits") {
                Some(x) => x.usize()? as u8,
                None => 2,
            };
            ModelSource::Builder { model, res, w_bits, a_bits }
        } else {
            bail!("load body needs \"path\" or \"builder\"");
        };
        Ok(ModelSpec { name: name.to_string(), source, overrides: Vec::new() })
    }

    /// Compile/load the model this spec names.
    pub fn build(&self) -> Result<CompiledModel> {
        match &self.source {
            ModelSource::Path(p) => format::load_auto(Path::new(p))
                .with_context(|| format!("loading model {:?} from {p}", self.name)),
            ModelSource::Builder { model, res, w_bits, a_bits } => {
                let g = models::build_named(model, *res, *w_bits, *a_bits, 1.0)
                    .with_context(|| format!("building model {:?}", self.name))?;
                compile_graph(&g, EngineChoice::Auto)
            }
        }
    }
}

/// One registered, serving model.
pub struct ModelEntry {
    pub name: String,
    /// human-readable provenance for `/v1/models`
    pub source: String,
    pub model: Arc<CompiledModel>,
    pub server: InferenceServer,
}

/// Name → serving model map shared by the gateway's connection threads.
pub struct ModelRegistry {
    default_cfg: ServerConfig,
    inner: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
}

impl ModelRegistry {
    pub fn new(default_cfg: ServerConfig) -> ModelRegistry {
        ModelRegistry { default_cfg, inner: RwLock::new(BTreeMap::new()) }
    }

    /// The base per-model coordinator config (before plan-aware clamping).
    pub fn default_config(&self) -> ServerConfig {
        self.default_cfg
    }

    /// Compile/load `spec` and start serving it. Replacing an existing
    /// name is a hot swap: the new server takes traffic as soon as the map
    /// entry flips; the old one drains outside the lock (in-flight
    /// requests finish, late holders of the old entry get 503s).
    pub fn load_spec(&self, spec: &ModelSpec) -> Result<()> {
        let cfg = spec.apply_overrides(self.default_cfg)?;
        let compiled = spec.build()?;
        self.install_with_config(&spec.name, &spec.source.describe(), compiled, cfg)
    }

    /// Register an already-compiled model under `name` with the registry's
    /// default config (also the test seam — no filesystem needed).
    pub fn install(&self, name: &str, source: &str, compiled: CompiledModel) -> Result<()> {
        self.install_with_config(name, source, compiled, self.default_cfg)
    }

    /// Register an already-compiled model with an explicit (e.g.
    /// spec-overridden) coordinator config.
    pub fn install_with_config(
        &self,
        name: &str,
        source: &str,
        compiled: CompiledModel,
        cfg: ServerConfig,
    ) -> Result<()> {
        if name.is_empty() || name.contains('/') {
            bail!("model name {name:?} must be non-empty and slash-free");
        }
        let model = Arc::new(compiled);
        let server = InferenceServer::start(model.clone(), cfg);
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            source: source.to_string(),
            model,
            server,
        });
        let old = self.inner.write().unwrap().insert(name.to_string(), entry);
        if let Some(old) = old {
            old.server.drain();
        }
        Ok(())
    }

    /// Stop serving `name`: removed from the map immediately, then drained
    /// (queued requests finish; new submissions are refused with 503).
    pub fn unload(&self, name: &str) -> Result<()> {
        let old = self
            .inner
            .write()
            .unwrap()
            .remove(name)
            .ok_or_else(|| anyhow!("no such model {name:?}"))?;
        old.server.drain();
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.inner.read().unwrap().get(name).cloned()
    }

    /// All entries, name-ordered.
    pub fn list(&self) -> Vec<Arc<ModelEntry>> {
        self.inner.read().unwrap().values().cloned().collect()
    }

    /// Graceful shutdown of every registered server (gateway drain).
    pub fn drain_all(&self) {
        for e in self.list() {
            e.server.drain();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrt::tensor::Tensor;
    use crate::models::tiny_test_graph;

    fn tiny() -> CompiledModel {
        compile_graph(&tiny_test_graph(false), EngineChoice::Auto).unwrap()
    }

    #[test]
    fn spec_parsing() {
        let s = ModelSpec::parse("resnet18@64").unwrap();
        assert_eq!(s.name, "resnet18@64");
        match s.source {
            ModelSource::Builder { ref model, res, .. } => {
                assert_eq!(model, "resnet18");
                assert_eq!(res, 64);
            }
            ref other => panic!("{other:?}"),
        }

        let s = ModelSpec::parse("det=yolov5n").unwrap();
        assert_eq!(s.name, "det");
        match s.source {
            ModelSource::Builder { ref model, res, .. } => {
                assert_eq!(model, "yolov5n");
                assert_eq!(res, 320); // builder default
            }
            ref other => panic!("{other:?}"),
        }

        let s = ModelSpec::parse("/tmp/exported/model.dlrt").unwrap();
        assert_eq!(s.name, "model");
        assert!(matches!(s.source, ModelSource::Path(_)));

        let s = ModelSpec::parse("prod=checkpoints/best.dlrt").unwrap();
        assert_eq!(s.name, "prod");

        assert!(ModelSpec::parse("").is_err());
        assert!(ModelSpec::parse("resnet18@notanumber").is_err());
    }

    #[test]
    fn spec_overrides_parse_and_apply() {
        let s = ModelSpec::parse("det=yolov5n@320;replicas=2;pin_cores=true;max_wait_ms=5")
            .unwrap();
        assert_eq!(s.name, "det");
        assert_eq!(s.overrides.len(), 3);
        let cfg = s.apply_overrides(ServerConfig::default()).unwrap();
        assert_eq!(cfg.replicas, 2);
        assert!(cfg.pin_cores);
        assert_eq!(cfg.max_wait, std::time::Duration::from_millis(5));
        // untouched fields keep the base value
        assert_eq!(cfg.workers, ServerConfig::default().workers);

        // paths still parse when override segments follow
        let s = ModelSpec::parse("prod=checkpoints/best.dlrt;queue_cap=4").unwrap();
        assert_eq!(s.name, "prod");
        assert!(matches!(s.source, ModelSource::Path(_)));
        assert_eq!(s.apply_overrides(ServerConfig::default()).unwrap().queue_cap, 4);

        // specs without overrides behave exactly as before
        assert!(ModelSpec::parse("resnet18@64").unwrap().overrides.is_empty());
    }

    #[test]
    fn spec_overrides_reject_garbage() {
        // unknown key, bad value, and a segment that isn't key=value
        assert!(ModelSpec::parse("m=resnet18@64;turbo=yes")
            .unwrap()
            .apply_overrides(ServerConfig::default())
            .is_err());
        assert!(ModelSpec::parse("m=resnet18@64;workers=lots")
            .unwrap()
            .apply_overrides(ServerConfig::default())
            .is_err());
        assert!(ModelSpec::parse("m=resnet18@64;replicas").is_err());
    }

    #[test]
    fn load_spec_applies_overrides_to_server() {
        let reg = ModelRegistry::new(ServerConfig::default());
        // install through the spec path with an explicit config override
        let spec = ModelSpec {
            name: "tiny".to_string(),
            source: ModelSource::Path("unused".to_string()),
            overrides: vec![("max_batch".to_string(), "2".to_string())],
        };
        let cfg = spec.apply_overrides(reg.default_config()).unwrap();
        reg.install_with_config("tiny", "test", tiny(), cfg).unwrap();
        assert_eq!(reg.get("tiny").unwrap().server.config().max_batch, 2);
        reg.drain_all();
    }

    #[test]
    fn spec_from_json() {
        let v = Json::parse(r#"{"path": "/tmp/m.dlrt"}"#).unwrap();
        let s = ModelSpec::from_json("m", &v).unwrap();
        assert_eq!(s.name, "m");
        assert!(matches!(s.source, ModelSource::Path(_)));

        let v = Json::parse(r#"{"builder": "resnet18", "res": 64, "w_bits": 3}"#).unwrap();
        let s = ModelSpec::from_json("r", &v).unwrap();
        match s.source {
            ModelSource::Builder { res, w_bits, a_bits, .. } => {
                assert_eq!((res, w_bits, a_bits), (64, 3, 2));
            }
            ref other => panic!("{other:?}"),
        }

        let v = Json::parse(r#"{"nope": 1}"#).unwrap();
        assert!(ModelSpec::from_json("x", &v).is_err());
    }

    #[test]
    fn install_get_unload_roundtrip() {
        let reg = ModelRegistry::new(ServerConfig::default());
        reg.install("tiny", "builder:tiny", tiny()).unwrap();
        assert!(reg.get("tiny").is_some());
        assert_eq!(reg.list().len(), 1);

        let entry = reg.get("tiny").unwrap();
        let outs = entry.server.infer(Tensor::zeros(vec![1, 8, 8, 3])).unwrap();
        assert_eq!(outs[0].shape, vec![1, 4]);

        reg.unload("tiny").unwrap();
        assert!(reg.get("tiny").is_none());
        assert!(reg.unload("tiny").is_err());
        // a stale handle refuses new work after unload
        assert!(entry.server.try_submit(Tensor::zeros(vec![1, 8, 8, 3])).is_err());
    }

    #[test]
    fn hot_swap_drains_old_server() {
        let reg = ModelRegistry::new(ServerConfig::default());
        reg.install("m", "v1", tiny()).unwrap();
        let old = reg.get("m").unwrap();
        reg.install("m", "v2", tiny()).unwrap();
        let new = reg.get("m").unwrap();
        assert_eq!(new.source, "v2");
        // the replaced server was drained: refuses new work
        assert!(old.server.try_submit(Tensor::zeros(vec![1, 8, 8, 3])).is_err());
        // the new one serves
        assert!(new.server.infer(Tensor::zeros(vec![1, 8, 8, 3])).is_ok());
        reg.drain_all();
    }

    #[test]
    fn rejects_bad_names() {
        let reg = ModelRegistry::new(ServerConfig::default());
        assert!(reg.install("", "x", tiny()).is_err());
        assert!(reg.install("a/b", "x", tiny()).is_err());
    }
}

//! Minimal HTTP/1.1 message layer (std-only, no TLS): request parsing and
//! response serialization for the gateway's server side, plus a blocking
//! keep-alive client used by the loadgen, the CI smoke, and the tests.
//!
//! Deliberately small: `Content-Length` bodies only (no chunked encoding),
//! keep-alive by default, `Connection: close` honored. That subset is what
//! `curl`, Prometheus scrapers, and our own loadgen speak.
//!
//! Server-side parsing is a *resumable* state machine ([`StreamParser`]):
//! the event loop feeds it whatever bytes a non-blocking read produced and
//! it yields complete requests as they materialize — no thread ever blocks
//! waiting for a slow peer's next byte.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

/// Cap on request-line + header bytes (defense against garbage peers).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;

/// The raw wire format for tensor data: f32 little-endian. Defined once
/// here, next to the framing code, and shared by the gateway handlers,
/// the loadgen, and the integration tests.
pub fn f32s_to_le_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 * data.len());
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Inverse of [`f32s_to_le_bytes`]; trailing bytes that don't fill an f32
/// are ignored (callers validate lengths beforehand).
pub fn le_bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect()
}

/// Marker error: a reused connection failed before the server can have
/// received the full request (send error, or clean EOF before any response
/// byte) — the request was provably not executed, so a retry is safe even
/// for non-idempotent POSTs.
#[derive(Debug)]
pub struct StaleConnection;

impl std::fmt::Display for StaleConnection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "stale connection: request was not delivered")
    }
}

impl std::error::Error for StaleConnection {}

// ---------------------------------------------------------------------------
// messages
// ---------------------------------------------------------------------------

/// One parsed HTTP request.
#[derive(Debug, Default)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// peer asked to close after this exchange (`Connection: close` or 1.0)
    pub close: bool,
}

impl Request {
    pub fn new(method: &str, path: &str) -> Request {
        Request { method: method.to_string(), path: path.to_string(), ..Request::default() }
    }

    pub fn with_body(method: &str, path: &str, content_type: &str, body: Vec<u8>) -> Request {
        let mut r = Request::new(method, path);
        r.headers.push(("Content-Type".to_string(), content_type.to_string()));
        r.body = body;
        r
    }

    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// One outgoing HTTP response.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16, content_type: &str, body: Vec<u8>) -> Response {
        Response { status, content_type: content_type.to_string(), headers: Vec::new(), body }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response::new(status, "text/plain; charset=utf-8", body.as_bytes().to_vec())
    }

    pub fn json(status: u16, v: &crate::util::json::Json) -> Response {
        Response::new(status, "application/json", v.to_string().into_bytes())
    }

    pub fn bytes(status: u16, body: Vec<u8>) -> Response {
        Response::new(status, "application/octet-stream", body)
    }

    /// Builder-style extra header.
    pub fn header(mut self, k: &str, v: &str) -> Response {
        self.headers.push((k.to_string(), v.to_string()));
        self
    }

    /// Serialized status line + headers (everything before the body). The
    /// event loop queues this and the body as two separate chunks, so the
    /// body `Vec` is moved into the write queue without a copy.
    pub fn head_bytes(&self, close: bool) -> Vec<u8> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len(),
            if close { "close" } else { "keep-alive" },
        );
        for (k, v) in &self.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        head.into_bytes()
    }

    pub fn write_to(&self, w: &mut impl Write, close: bool) -> std::io::Result<()> {
        w.write_all(&self.head_bytes(close))?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "",
    }
}

// ---------------------------------------------------------------------------
// server-side parsing
// ---------------------------------------------------------------------------

/// One event the [`StreamParser`] can yield. Malformed input (bad request
/// line, bad header, bad `Content-Length`, oversized headers) comes back as
/// `Err` from [`StreamParser::next`]; the caller responds 400 and closes.
#[derive(Debug)]
pub enum ParseEvent {
    Request(Request),
    /// declared body exceeds the limit; respond 413 and close
    TooLarge(usize),
    /// request uses a feature this server does not implement (e.g.
    /// `Transfer-Encoding: chunked`); respond 501 and close
    Unsupported(&'static str),
}

enum ParseState {
    /// accumulating request-line + headers
    Head,
    /// head parsed; `need` body bytes outstanding
    Body { req: Box<Request>, need: usize },
}

/// Resumable HTTP/1.1 request parser. [`feed`](StreamParser::feed) it the
/// bytes a non-blocking read produced, then drain [`next`](StreamParser::next)
/// until it returns `Ok(None)` — pipelined requests yield multiple events
/// from one feed, and a request split across many reads completes when its
/// last byte arrives.
pub struct StreamParser {
    buf: Vec<u8>,
    state: ParseState,
    max_body: usize,
}

/// Byte offset just past the `\r\n\r\n` (or bare `\n\n`) head terminator.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] != b'\n' {
            i += 1;
            continue;
        }
        // a newline followed by an (optionally CR-prefixed) blank line
        if buf[i + 1..].starts_with(b"\r\n") {
            return Some(i + 3);
        }
        if buf[i + 1..].starts_with(b"\n") {
            return Some(i + 2);
        }
        i += 1;
    }
    None
}

impl StreamParser {
    pub fn new(max_body: usize) -> StreamParser {
        StreamParser { buf: Vec::new(), state: ParseState::Head, max_body }
    }

    /// Append bytes read off the socket.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// True when the peer is mid-message (bytes buffered or a body pending)
    /// — an EOF here is a truncated request, not a clean close.
    pub fn mid_message(&self) -> bool {
        !self.buf.is_empty() || matches!(self.state, ParseState::Body { .. })
    }

    /// Try to complete one event from the buffered bytes.
    pub fn next(&mut self) -> Result<Option<ParseEvent>> {
        loop {
            match &mut self.state {
                ParseState::Head => {
                    let Some(head_end) = find_head_end(&self.buf) else {
                        if self.buf.len() > MAX_HEADER_BYTES {
                            bail!("headers exceed {MAX_HEADER_BYTES} bytes");
                        }
                        return Ok(None);
                    };
                    if head_end > MAX_HEADER_BYTES {
                        bail!("headers exceed {MAX_HEADER_BYTES} bytes");
                    }
                    let (event, need) = parse_head(&self.buf[..head_end], self.max_body)?;
                    self.buf.drain(..head_end);
                    match (event, need) {
                        (ParseEvent::Request(req), n) if n > 0 => {
                            self.state = ParseState::Body { req: Box::new(req), need: n };
                            // fall through: the body may already be buffered
                        }
                        (event, _) => return Ok(Some(event)),
                    }
                }
                ParseState::Body { need, .. } => {
                    if self.buf.len() < *need {
                        return Ok(None);
                    }
                    let need = *need;
                    let rest = self.buf.split_off(need);
                    let body = std::mem::replace(&mut self.buf, rest);
                    let ParseState::Body { mut req, .. } =
                        std::mem::replace(&mut self.state, ParseState::Head)
                    else {
                        unreachable!("state checked above");
                    };
                    req.body = body;
                    return Ok(Some(ParseEvent::Request(*req)));
                }
            }
        }
    }
}

/// Parse a complete request head (everything through the blank line).
/// Returns the event plus the body length still to read (0 unless the event
/// is a `Request` with a `Content-Length`).
fn parse_head(head: &[u8], max_body: usize) -> Result<(ParseEvent, usize)> {
    let text = std::str::from_utf8(head).map_err(|_| anyhow!("non-utf8 request head"))?;
    let mut lines = text.lines();
    let line = lines.next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) => (m, p, v),
        _ => bail!("malformed request line {line:?}"),
    };
    let mut req = Request::new(method, path);
    req.close = version == "HTTP/1.0";
    for h in lines {
        let h = h.trim_end_matches('\r');
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':').ok_or_else(|| anyhow!("malformed header {h:?}"))?;
        req.headers.push((k.trim().to_string(), v.trim().to_string()));
    }
    if let Some(c) = req.header("connection") {
        if c.eq_ignore_ascii_case("close") {
            req.close = true;
        }
    }
    if req.header("transfer-encoding").is_some() {
        // chunked (or any transfer coding) is not implemented; RFC 9112
        // says a server may respond 501 — and must not guess at framing
        return Ok((ParseEvent::Unsupported("Transfer-Encoding is not supported"), 0));
    }
    let len = match req.header("content-length") {
        Some(v) => v.trim().parse::<usize>().context("bad content-length")?,
        None => 0,
    };
    if len > max_body {
        return Ok((ParseEvent::TooLarge(len), 0));
    }
    Ok((ParseEvent::Request(req), len))
}

// ---------------------------------------------------------------------------
// client side
// ---------------------------------------------------------------------------

/// A client-side response.
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|e| anyhow!("non-utf8 body: {e}"))
    }
}

/// Blocking HTTP/1.1 client with connection reuse (keep-alive). One
/// instance per sender thread; reconnects transparently when the server
/// closed the previous exchange.
pub struct HttpClient {
    addr: String,
    timeout: Duration,
    conn: Option<BufReader<TcpStream>>,
}

impl HttpClient {
    pub fn new(addr: &str, timeout: Duration) -> HttpClient {
        HttpClient { addr: addr.to_string(), timeout, conn: None }
    }

    fn connect(&self) -> Result<BufReader<TcpStream>> {
        let stream =
            TcpStream::connect(&self.addr).with_context(|| format!("connect {}", self.addr))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(self.timeout)).ok();
        stream.set_write_timeout(Some(self.timeout)).ok();
        Ok(BufReader::new(stream))
    }

    /// Send one request and read the response. A reused keep-alive
    /// connection is retried once on a fresh connection **only** when the
    /// failure proves the request never reached the server
    /// ([`StaleConnection`]: send error, or clean EOF before any response
    /// byte) — a timeout after a delivered request is NOT retried, so a
    /// non-idempotent `/infer` is never silently executed twice.
    pub fn send(&mut self, req: &Request) -> Result<ClientResponse> {
        let had_conn = self.conn.is_some();
        if self.conn.is_none() {
            self.conn = Some(self.connect()?);
        }
        match self.exchange(req) {
            Ok(resp) => Ok(resp),
            Err(e) if had_conn && e.is::<StaleConnection>() => {
                self.conn = Some(self.connect()?);
                self.exchange(req)
            }
            Err(e) => Err(e),
        }
    }

    fn exchange(&mut self, req: &Request) -> Result<ClientResponse> {
        // take the connection out: any error path below drops it
        let mut conn = self.conn.take().ok_or_else(|| anyhow!("not connected"))?;
        let mut head = format!(
            "{} {} HTTP/1.1\r\nHost: {}\r\nContent-Length: {}\r\n",
            req.method,
            req.path,
            self.addr,
            req.body.len()
        );
        for (k, v) in &req.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        let write_result: std::io::Result<()> = (|| {
            let stream = conn.get_mut();
            stream.write_all(head.as_bytes())?;
            stream.write_all(&req.body)?;
            stream.flush()
        })();
        if let Err(e) = write_result {
            // the server cannot have executed a request it never fully
            // received — mark as retry-safe
            return Err(anyhow::Error::new(StaleConnection).context(format!("send failed: {e}")));
        }
        match read_client_response(&mut conn) {
            Ok((resp, close)) => {
                if !close {
                    self.conn = Some(conn);
                }
                Ok(resp)
            }
            Err(e) => Err(e),
        }
    }
}

/// Parse a response: status line, headers, `Content-Length` body (or read
/// to EOF when absent). Returns the response and whether the server asked
/// to close the connection.
fn read_client_response<R: BufRead>(r: &mut R) -> Result<(ClientResponse, bool)> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        // clean EOF before any response byte: the server closed the idle
        // keep-alive without processing the request — safe to retry
        return Err(anyhow::Error::new(StaleConnection)
            .context("connection closed before response"));
    }
    let mut parts = line.split_whitespace();
    let _version = parts.next().ok_or_else(|| anyhow!("empty status line"))?;
    let status: u16 =
        parts.next().ok_or_else(|| anyhow!("no status code"))?.parse().context("status code")?;
    let mut headers = Vec::new();
    let mut header_bytes = 0usize;
    loop {
        let mut h = String::new();
        let n = r.read_line(&mut h)?;
        if n == 0 {
            bail!("connection closed mid-headers");
        }
        header_bytes += n;
        if header_bytes > MAX_HEADER_BYTES {
            bail!("headers exceed {MAX_HEADER_BYTES} bytes");
        }
        let h = h.trim_end_matches(&['\r', '\n'][..]);
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    let close = headers
        .iter()
        .any(|(k, v)| k.eq_ignore_ascii_case("connection") && v.eq_ignore_ascii_case("close"));
    let len = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| v.trim().parse::<usize>())
        .transpose()
        .context("bad content-length")?;
    let mut body = Vec::new();
    match len {
        Some(n) => {
            body.resize(n, 0);
            r.read_exact(&mut body).context("reading response body")?;
        }
        None => {
            r.read_to_end(&mut body)?;
        }
    }
    Ok((ClientResponse { status, headers, body }, close))
}

/// One-shot convenience for tests and simple probes: open a connection,
/// send, read the response, close.
pub fn http_once(
    addr: &str,
    method: &str,
    path: &str,
    content_type: &str,
    body: Vec<u8>,
) -> Result<ClientResponse> {
    let mut client = HttpClient::new(addr, Duration::from_secs(30));
    let mut req = Request::with_body(method, path, content_type, body);
    req.headers.push(("Connection".to_string(), "close".to_string()));
    client.send(&req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> Result<Option<ParseEvent>> {
        let mut p = StreamParser::new(1024);
        p.feed(raw);
        p.next()
    }

    #[test]
    fn parses_request_with_body() {
        let raw = b"POST /v1/models/m/infer HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: 4\r\n\r\nabcd";
        match parse(raw).unwrap() {
            Some(ParseEvent::Request(req)) => {
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/v1/models/m/infer");
                assert_eq!(req.header("content-type"), Some("application/json"));
                assert_eq!(req.body, b"abcd");
                assert!(!req.close);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn keep_alive_parses_back_to_back_requests() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut p = StreamParser::new(1024);
        p.feed(raw);
        let first = match p.next().unwrap() {
            Some(ParseEvent::Request(req)) => req,
            other => panic!("{other:?}"),
        };
        assert_eq!(first.path, "/healthz");
        assert!(!first.close);
        let second = match p.next().unwrap() {
            Some(ParseEvent::Request(req)) => req,
            other => panic!("{other:?}"),
        };
        assert_eq!(second.path, "/metrics");
        assert!(second.close);
        assert!(p.next().unwrap().is_none());
        assert!(!p.mid_message());
    }

    #[test]
    fn resumes_across_arbitrary_feed_boundaries() {
        let raw: &[u8] = b"POST /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nhelloGET /y HTTP/1.1\r\n\r\n";
        // every split point must yield the same two requests
        for cut in 1..raw.len() {
            let mut p = StreamParser::new(1024);
            p.feed(&raw[..cut]);
            let mut got = Vec::new();
            while let Some(ev) = p.next().unwrap() {
                got.push(ev);
            }
            if got.len() < 2 {
                assert!(p.mid_message(), "cut={cut} left no partial state");
            }
            p.feed(&raw[cut..]);
            while let Some(ev) = p.next().unwrap() {
                got.push(ev);
            }
            assert_eq!(got.len(), 2, "cut={cut}");
            match (&got[0], &got[1]) {
                (ParseEvent::Request(a), ParseEvent::Request(b)) => {
                    assert_eq!(a.path, "/x");
                    assert_eq!(a.body, b"hello");
                    assert_eq!(b.path, "/y");
                }
                other => panic!("cut={cut}: {other:?}"),
            }
            assert!(!p.mid_message());
        }
    }

    #[test]
    fn rejects_malformed_and_limits_body() {
        assert!(parse(b"NOT-HTTP\r\n\r\n").is_err());
        let big = b"POST /x HTTP/1.1\r\nContent-Length: 9999\r\n\r\n";
        assert!(matches!(parse(big).unwrap(), Some(ParseEvent::TooLarge(9999))));
        assert!(parse(b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n").is_err());
        // unterminated head past the cap errors instead of buffering forever
        let mut p = StreamParser::new(1024);
        p.feed(&vec![b'A'; MAX_HEADER_BYTES + 2]);
        assert!(p.next().is_err());
    }

    #[test]
    fn f32_wire_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25, f32::MIN_POSITIVE, f32::MAX];
        assert_eq!(le_bytes_to_f32s(&f32s_to_le_bytes(&xs)), xs);
        assert_eq!(f32s_to_le_bytes(&xs).len(), 4 * xs.len());
    }

    #[test]
    fn rejects_transfer_encoding_as_unsupported() {
        let raw = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n5\r\nhello\r\n0\r\n\r\n";
        match parse(raw).unwrap() {
            Some(ParseEvent::Unsupported(_)) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn http10_and_connection_close_set_close() {
        match parse(b"GET / HTTP/1.0\r\n\r\n").unwrap() {
            Some(ParseEvent::Request(req)) => assert!(req.close),
            other => panic!("{other:?}"),
        }
        // bare-LF line endings are tolerated too
        match parse(b"GET / HTTP/1.1\nConnection: close\n\n").unwrap() {
            Some(ParseEvent::Request(req)) => assert!(req.close),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn response_serializes_with_extra_headers() {
        let resp = Response::bytes(200, vec![1, 2, 3]).header("X-DLRT-Shapes", "[[1,3]]");
        let mut out = Vec::new();
        resp.write_to(&mut out, true).unwrap();
        let text = String::from_utf8_lossy(&out);
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 3\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.contains("X-DLRT-Shapes: [[1,3]]\r\n"));
        assert!(out.ends_with(&[1, 2, 3]));
    }

    #[test]
    fn client_parses_response() {
        let raw = b"HTTP/1.1 429 Too Many Requests\r\nContent-Length: 5\r\nRetry-After: 1\r\n\r\nwait\n";
        let mut r = Cursor::new(raw.to_vec());
        let (resp, close) = read_client_response(&mut r).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert_eq!(resp.body, b"wait\n");
        assert!(!close);
    }
}

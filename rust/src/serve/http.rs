//! Minimal HTTP/1.1 message layer (std-only, no TLS): request parsing and
//! response serialization for the gateway's server side, plus a blocking
//! keep-alive client used by the loadgen, the CI smoke, and the tests.
//!
//! Deliberately small: `Content-Length` bodies only (no chunked encoding),
//! keep-alive by default, `Connection: close` honored. That subset is what
//! `curl`, Prometheus scrapers, and our own loadgen speak.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

/// Cap on request-line + header bytes (defense against garbage peers).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;

/// How many consecutive socket-timeout reads to tolerate *mid-message*
/// (headers/body) before giving up on a stalled peer. With the gateway's
/// 500ms read timeout this allows ~60s of stall, so slow links finish
/// instead of getting a spurious 400. (Between requests the caller handles
/// timeouts itself via [`ReadOutcome::IdleTimeout`].)
const MAX_MID_MESSAGE_STALLS: u32 = 120;

/// The raw wire format for tensor data: f32 little-endian. Defined once
/// here, next to the framing code, and shared by the gateway handlers,
/// the loadgen, and the integration tests.
pub fn f32s_to_le_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 * data.len());
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Inverse of [`f32s_to_le_bytes`]; trailing bytes that don't fill an f32
/// are ignored (callers validate lengths beforehand).
pub fn le_bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect()
}

/// Marker error: a reused connection failed before the server can have
/// received the full request (send error, or clean EOF before any response
/// byte) — the request was provably not executed, so a retry is safe even
/// for non-idempotent POSTs.
#[derive(Debug)]
pub struct StaleConnection;

impl std::fmt::Display for StaleConnection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "stale connection: request was not delivered")
    }
}

impl std::error::Error for StaleConnection {}

// ---------------------------------------------------------------------------
// messages
// ---------------------------------------------------------------------------

/// One parsed HTTP request.
#[derive(Debug, Default)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// peer asked to close after this exchange (`Connection: close` or 1.0)
    pub close: bool,
}

impl Request {
    pub fn new(method: &str, path: &str) -> Request {
        Request { method: method.to_string(), path: path.to_string(), ..Request::default() }
    }

    pub fn with_body(method: &str, path: &str, content_type: &str, body: Vec<u8>) -> Request {
        let mut r = Request::new(method, path);
        r.headers.push(("Content-Type".to_string(), content_type.to_string()));
        r.body = body;
        r
    }

    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// One outgoing HTTP response.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16, content_type: &str, body: Vec<u8>) -> Response {
        Response { status, content_type: content_type.to_string(), headers: Vec::new(), body }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response::new(status, "text/plain; charset=utf-8", body.as_bytes().to_vec())
    }

    pub fn json(status: u16, v: &crate::util::json::Json) -> Response {
        Response::new(status, "application/json", v.to_string().into_bytes())
    }

    pub fn bytes(status: u16, body: Vec<u8>) -> Response {
        Response::new(status, "application/octet-stream", body)
    }

    /// Builder-style extra header.
    pub fn header(mut self, k: &str, v: &str) -> Response {
        self.headers.push((k.to_string(), v.to_string()));
        self
    }

    pub fn write_to(&self, w: &mut impl Write, close: bool) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len(),
            if close { "close" } else { "keep-alive" },
        );
        for (k, v) in &self.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "",
    }
}

// ---------------------------------------------------------------------------
// server-side parsing
// ---------------------------------------------------------------------------

/// Outcome of trying to read one request off a connection.
#[derive(Debug)]
pub enum ReadOutcome {
    Request(Request),
    /// peer closed cleanly between requests
    Eof,
    /// read timed out before the request line completed — the caller
    /// decides whether to keep waiting (idle keep-alive) or close;
    /// partially-read bytes stay in `line` and survive the retry
    IdleTimeout,
    /// declared body exceeds the limit; respond 413 and close
    TooLarge(usize),
    /// request uses a feature this server does not implement (e.g.
    /// `Transfer-Encoding: chunked`); respond 501 and close
    Unsupported(&'static str),
}

/// Read one line tolerating mid-line socket timeouts (the peer is slow,
/// not gone). Returns the bytes appended; 0 means EOF.
fn read_line_stalls<R: BufRead>(r: &mut R, line: &mut String) -> std::io::Result<usize> {
    let start = line.len();
    let mut stalls = 0u32;
    let mut last_len = line.len();
    loop {
        match r.read_line(line) {
            Ok(0) => return Ok(line.len() - start), // EOF (possibly mid-line)
            Ok(_) => {
                if line.ends_with('\n') {
                    return Ok(line.len() - start);
                }
                // partial without newline: keep reading
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e)
                if (e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut)
                    && stalls < MAX_MID_MESSAGE_STALLS =>
            {
                stalls += 1;
            }
            Err(e) => return Err(e),
        }
        // slow-but-alive peers reset the stall budget on any progress
        // (mirrors read_full_stalls)
        if line.len() > last_len {
            last_len = line.len();
            stalls = 0;
        }
    }
}

/// `read_exact` tolerating mid-body socket timeouts.
fn read_full_stalls<R: Read>(r: &mut R, buf: &mut [u8]) -> std::io::Result<()> {
    let mut filled = 0usize;
    let mut stalls = 0u32;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Err(std::io::Error::from(ErrorKind::UnexpectedEof)),
            Ok(n) => {
                filled += n;
                stalls = 0;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e)
                if (e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut)
                    && stalls < MAX_MID_MESSAGE_STALLS =>
            {
                stalls += 1;
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Read one request. `line` is caller-owned so a timeout mid-request-line
/// keeps the partial bytes for the next attempt (it is cleared only after
/// the request line parses).
pub fn read_request<R: BufRead>(
    r: &mut R,
    line: &mut String,
    max_body: usize,
) -> Result<ReadOutcome> {
    match r.read_line(line) {
        Ok(0) => return Ok(ReadOutcome::Eof),
        Ok(_) => {}
        Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
            return Ok(ReadOutcome::IdleTimeout)
        }
        Err(e) => return Err(e.into()),
    }
    if !line.ends_with('\n') {
        // timed out (or EOF'd) mid-line: report idle, keep partial bytes
        return Ok(ReadOutcome::IdleTimeout);
    }
    let mut parts = line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) => (m.to_string(), p.to_string(), v.to_string()),
        _ => bail!("malformed request line {line:?}"),
    };
    line.clear();
    let mut req = Request::new(&method, &path);
    req.close = version == "HTTP/1.0";

    // headers until the blank line (stall-tolerant: we are mid-message)
    let mut header_bytes = 0usize;
    loop {
        let mut h = String::new();
        let n = read_line_stalls(r, &mut h).context("reading header")?;
        if n == 0 {
            bail!("connection closed mid-headers");
        }
        header_bytes += n;
        if header_bytes > MAX_HEADER_BYTES {
            bail!("headers exceed {MAX_HEADER_BYTES} bytes");
        }
        let h = h.trim_end_matches(&['\r', '\n'][..]);
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':').ok_or_else(|| anyhow!("malformed header {h:?}"))?;
        req.headers.push((k.trim().to_string(), v.trim().to_string()));
    }
    if let Some(c) = req.header("connection") {
        if c.eq_ignore_ascii_case("close") {
            req.close = true;
        }
    }
    if req.header("transfer-encoding").is_some() {
        // chunked (or any transfer coding) is not implemented; RFC 9112
        // says a server may respond 501 — and must not guess at framing
        return Ok(ReadOutcome::Unsupported("Transfer-Encoding is not supported"));
    }

    let len = match req.header("content-length") {
        Some(v) => v.trim().parse::<usize>().context("bad content-length")?,
        None => 0,
    };
    if len > max_body {
        return Ok(ReadOutcome::TooLarge(len));
    }
    if len > 0 {
        let mut body = vec![0u8; len];
        read_full_stalls(r, &mut body).context("reading body")?;
        req.body = body;
    }
    Ok(ReadOutcome::Request(req))
}

// ---------------------------------------------------------------------------
// client side
// ---------------------------------------------------------------------------

/// A client-side response.
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|e| anyhow!("non-utf8 body: {e}"))
    }
}

/// Blocking HTTP/1.1 client with connection reuse (keep-alive). One
/// instance per sender thread; reconnects transparently when the server
/// closed the previous exchange.
pub struct HttpClient {
    addr: String,
    timeout: Duration,
    conn: Option<BufReader<TcpStream>>,
}

impl HttpClient {
    pub fn new(addr: &str, timeout: Duration) -> HttpClient {
        HttpClient { addr: addr.to_string(), timeout, conn: None }
    }

    fn connect(&self) -> Result<BufReader<TcpStream>> {
        let stream =
            TcpStream::connect(&self.addr).with_context(|| format!("connect {}", self.addr))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(self.timeout)).ok();
        stream.set_write_timeout(Some(self.timeout)).ok();
        Ok(BufReader::new(stream))
    }

    /// Send one request and read the response. A reused keep-alive
    /// connection is retried once on a fresh connection **only** when the
    /// failure proves the request never reached the server
    /// ([`StaleConnection`]: send error, or clean EOF before any response
    /// byte) — a timeout after a delivered request is NOT retried, so a
    /// non-idempotent `/infer` is never silently executed twice.
    pub fn send(&mut self, req: &Request) -> Result<ClientResponse> {
        let had_conn = self.conn.is_some();
        if self.conn.is_none() {
            self.conn = Some(self.connect()?);
        }
        match self.exchange(req) {
            Ok(resp) => Ok(resp),
            Err(e) if had_conn && e.is::<StaleConnection>() => {
                self.conn = Some(self.connect()?);
                self.exchange(req)
            }
            Err(e) => Err(e),
        }
    }

    fn exchange(&mut self, req: &Request) -> Result<ClientResponse> {
        // take the connection out: any error path below drops it
        let mut conn = self.conn.take().ok_or_else(|| anyhow!("not connected"))?;
        let mut head = format!(
            "{} {} HTTP/1.1\r\nHost: {}\r\nContent-Length: {}\r\n",
            req.method,
            req.path,
            self.addr,
            req.body.len()
        );
        for (k, v) in &req.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        let write_result: std::io::Result<()> = (|| {
            let stream = conn.get_mut();
            stream.write_all(head.as_bytes())?;
            stream.write_all(&req.body)?;
            stream.flush()
        })();
        if let Err(e) = write_result {
            // the server cannot have executed a request it never fully
            // received — mark as retry-safe
            return Err(anyhow::Error::new(StaleConnection).context(format!("send failed: {e}")));
        }
        match read_client_response(&mut conn) {
            Ok((resp, close)) => {
                if !close {
                    self.conn = Some(conn);
                }
                Ok(resp)
            }
            Err(e) => Err(e),
        }
    }
}

/// Parse a response: status line, headers, `Content-Length` body (or read
/// to EOF when absent). Returns the response and whether the server asked
/// to close the connection.
fn read_client_response<R: BufRead>(r: &mut R) -> Result<(ClientResponse, bool)> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        // clean EOF before any response byte: the server closed the idle
        // keep-alive without processing the request — safe to retry
        return Err(anyhow::Error::new(StaleConnection)
            .context("connection closed before response"));
    }
    let mut parts = line.split_whitespace();
    let _version = parts.next().ok_or_else(|| anyhow!("empty status line"))?;
    let status: u16 =
        parts.next().ok_or_else(|| anyhow!("no status code"))?.parse().context("status code")?;
    let mut headers = Vec::new();
    let mut header_bytes = 0usize;
    loop {
        let mut h = String::new();
        let n = r.read_line(&mut h)?;
        if n == 0 {
            bail!("connection closed mid-headers");
        }
        header_bytes += n;
        if header_bytes > MAX_HEADER_BYTES {
            bail!("headers exceed {MAX_HEADER_BYTES} bytes");
        }
        let h = h.trim_end_matches(&['\r', '\n'][..]);
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    let close = headers
        .iter()
        .any(|(k, v)| k.eq_ignore_ascii_case("connection") && v.eq_ignore_ascii_case("close"));
    let len = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| v.trim().parse::<usize>())
        .transpose()
        .context("bad content-length")?;
    let mut body = Vec::new();
    match len {
        Some(n) => {
            body.resize(n, 0);
            r.read_exact(&mut body).context("reading response body")?;
        }
        None => {
            r.read_to_end(&mut body)?;
        }
    }
    Ok((ClientResponse { status, headers, body }, close))
}

/// One-shot convenience for tests and simple probes: open a connection,
/// send, read the response, close.
pub fn http_once(
    addr: &str,
    method: &str,
    path: &str,
    content_type: &str,
    body: Vec<u8>,
) -> Result<ClientResponse> {
    let mut client = HttpClient::new(addr, Duration::from_secs(30));
    let mut req = Request::with_body(method, path, content_type, body);
    req.headers.push(("Connection".to_string(), "close".to_string()));
    client.send(&req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> Result<ReadOutcome> {
        let mut r = Cursor::new(raw.to_vec());
        let mut line = String::new();
        read_request(&mut r, &mut line, 1024)
    }

    #[test]
    fn parses_request_with_body() {
        let raw = b"POST /v1/models/m/infer HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: 4\r\n\r\nabcd";
        match parse(raw).unwrap() {
            ReadOutcome::Request(req) => {
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/v1/models/m/infer");
                assert_eq!(req.header("content-type"), Some("application/json"));
                assert_eq!(req.body, b"abcd");
                assert!(!req.close);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn keep_alive_parses_back_to_back_requests() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut r = Cursor::new(raw.to_vec());
        let mut line = String::new();
        let first = match read_request(&mut r, &mut line, 1024).unwrap() {
            ReadOutcome::Request(req) => req,
            other => panic!("{other:?}"),
        };
        assert_eq!(first.path, "/healthz");
        assert!(!first.close);
        let second = match read_request(&mut r, &mut line, 1024).unwrap() {
            ReadOutcome::Request(req) => req,
            other => panic!("{other:?}"),
        };
        assert_eq!(second.path, "/metrics");
        assert!(second.close);
        assert!(matches!(read_request(&mut r, &mut line, 1024).unwrap(), ReadOutcome::Eof));
    }

    #[test]
    fn rejects_malformed_and_limits_body() {
        assert!(parse(b"NOT-HTTP\r\n\r\n").is_err());
        let big = b"POST /x HTTP/1.1\r\nContent-Length: 9999\r\n\r\n";
        assert!(matches!(parse(big).unwrap(), ReadOutcome::TooLarge(9999)));
        assert!(parse(b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n").is_err());
    }

    #[test]
    fn f32_wire_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25, f32::MIN_POSITIVE, f32::MAX];
        assert_eq!(le_bytes_to_f32s(&f32s_to_le_bytes(&xs)), xs);
        assert_eq!(f32s_to_le_bytes(&xs).len(), 4 * xs.len());
    }

    #[test]
    fn rejects_transfer_encoding_as_unsupported() {
        let raw = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n5\r\nhello\r\n0\r\n\r\n";
        match parse(raw).unwrap() {
            ReadOutcome::Unsupported(_) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn http10_and_connection_close_set_close() {
        match parse(b"GET / HTTP/1.0\r\n\r\n").unwrap() {
            ReadOutcome::Request(req) => assert!(req.close),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn response_serializes_with_extra_headers() {
        let resp = Response::bytes(200, vec![1, 2, 3]).header("X-DLRT-Shapes", "[[1,3]]");
        let mut out = Vec::new();
        resp.write_to(&mut out, true).unwrap();
        let text = String::from_utf8_lossy(&out);
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 3\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.contains("X-DLRT-Shapes: [[1,3]]\r\n"));
        assert!(out.ends_with(&[1, 2, 3]));
    }

    #[test]
    fn client_parses_response() {
        let raw = b"HTTP/1.1 429 Too Many Requests\r\nContent-Length: 5\r\nRetry-After: 1\r\n\r\nwait\n";
        let mut r = Cursor::new(raw.to_vec());
        let (resp, close) = read_client_response(&mut r).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert_eq!(resp.body, b"wait\n");
        assert!(!close);
    }
}

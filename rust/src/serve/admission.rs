//! Gateway-side admission control: connection limiting and the HTTP
//! mapping of coordinator admission decisions.
//!
//! The policy split: the **coordinator** owns queue bounds and plan-aware
//! batch sizing (it knows the `ExecPlan` arena footprint); this module owns
//! what the network edge does when the coordinator says no — shed with 429
//! (queue full, retryable) or 503 (draining, come back after a re-load),
//! plus a hard cap on concurrent connections so a misbehaving client herd
//! can't exhaust gateway threads.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::coordinator::{MetricsSnapshot, SubmitError};
use crate::serve::http::Response;
use crate::util::json::{num, obj, s, Json};

/// Counting guard for concurrent connections.
pub struct ConnLimiter {
    active: AtomicUsize,
    max: usize,
}

impl ConnLimiter {
    pub fn new(max: usize) -> ConnLimiter {
        ConnLimiter { active: AtomicUsize::new(0), max: max.max(1) }
    }

    /// Try to take a slot; `false` means the caller must shed the
    /// connection. Pair every `true` with exactly one [`ConnLimiter::release`].
    pub fn try_acquire(&self) -> bool {
        let mut cur = self.active.load(Ordering::Relaxed);
        loop {
            if cur >= self.max {
                return false;
            }
            match self.active.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    pub fn release(&self) {
        self.active.fetch_sub(1, Ordering::AcqRel);
    }

    pub fn active(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }
}

/// Seconds a 429'd client should back off before retrying: the estimated
/// time for the *current* queue to clear — queued batches (depth rounded
/// up to whole `max_batch` groups, at least one) times the median
/// per-batch execution, floored at 1s. A deep queue quotes a longer
/// back-off than a queue that just tipped over the cap.
fn retry_after_secs(snap: &MetricsSnapshot, queue_depth: usize, max_batch: usize) -> u64 {
    let batches = queue_depth.div_ceil(max_batch.max(1)).max(1) as f64;
    let clear_ms = batches * snap.p50_exec_ms.max(1.0);
    (clear_ms / 1000.0).ceil().max(1.0) as u64
}

/// Map a coordinator admission refusal to its HTTP response.
/// `queue_depth` / `max_batch` size the `Retry-After` quote.
pub fn reject_response(
    err: &SubmitError,
    snap: &MetricsSnapshot,
    queue_depth: usize,
    max_batch: usize,
) -> Response {
    match err {
        SubmitError::QueueFull { cap } => {
            let body = obj(vec![
                ("error", s("queue full")),
                ("queue_cap", num(*cap as f64)),
            ]);
            Response::json(429, &body)
                .header("Retry-After", &retry_after_secs(snap, queue_depth, max_batch).to_string())
        }
        SubmitError::Stopping => {
            let body: Json = obj(vec![("error", s("model draining"))]);
            Response::json(503, &body)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn limiter_caps_and_releases() {
        let l = ConnLimiter::new(2);
        assert!(l.try_acquire());
        assert!(l.try_acquire());
        assert!(!l.try_acquire());
        l.release();
        assert_eq!(l.active(), 1);
        assert!(l.try_acquire());
    }

    #[test]
    fn queue_full_maps_to_429_with_retry_after() {
        let snap = MetricsSnapshot { p50_exec_ms: 40.0, ..MetricsSnapshot::default() };
        let resp = reject_response(&SubmitError::QueueFull { cap: 8 }, &snap, 8, 4);
        assert_eq!(resp.status, 429);
        assert!(resp.headers.iter().any(|(k, _)| k == "Retry-After"));
        let body = String::from_utf8(resp.body).unwrap();
        assert!(body.contains("queue full"));
        assert!(body.contains("8"));
    }

    #[test]
    fn stopping_maps_to_503() {
        let resp = reject_response(&SubmitError::Stopping, &MetricsSnapshot::default(), 0, 1);
        assert_eq!(resp.status, 503);
    }

    #[test]
    fn retry_after_scales_with_queue_depth() {
        // p50 of 500ms per batch: empty queue quotes the 1s floor, 64
        // queued at max_batch 8 is 8 batches = 4s, 160 queued is 10s
        let snap = MetricsSnapshot { p50_exec_ms: 500.0, ..MetricsSnapshot::default() };
        assert_eq!(retry_after_secs(&snap, 0, 8), 1);
        assert_eq!(retry_after_secs(&snap, 64, 8), 4);
        assert_eq!(retry_after_secs(&snap, 160, 8), 10);
        // max_batch of 0 must not divide by zero
        assert_eq!(retry_after_secs(&snap, 4, 0), 2);
    }
}

//! Network inference gateway: the `dlrt serve` HTTP surface.
//!
//! A std-only threaded HTTP/1.1 server (accept loop + one thread per
//! connection, keep-alive) in front of the [`registry::ModelRegistry`].
//! The request path is socket → registry lookup → bounded coordinator
//! queue → batcher → planned executor → response; admission refusals are
//! shed at the edge as 429/503 instead of queueing unboundedly.
//!
//! Endpoints:
//!
//! ```text
//!   GET  /healthz                     liveness
//!   GET  /metrics                     Prometheus text format 0.0.4
//!   GET  /v1/models                   registry listing + sizing + stats
//!   POST /v1/models/{name}/infer      raw f32 LE bytes or JSON {"data":[..]}
//!   POST /v1/models/{name}/load       {"path": ..} | {"builder": .., "res": ..}
//!   POST /v1/models/{name}/unload     stop serving (drains in-flight work)
//!   POST /v1/admin/shutdown           request graceful gateway drain
//! ```
//!
//! Wire format for `/infer`: request body is one `[1, H, W, C]` NHWC input
//! — either `Content-Type: application/octet-stream` with `H*W*C` f32
//! little-endian values, or `application/json` with `{"data": [floats],
//! "shape": [1,H,W,C]?}`. Raw responses concatenate every model output's
//! f32 data and carry an `X-DLRT-Shapes` JSON header; JSON responses are
//! `{"outputs": [{"shape": [...], "data": [...]}]}`. Both round-trip f32
//! exactly, so gateway outputs are bit-identical to a direct
//! `Executor::run` (the integration test asserts it).

pub mod admission;
pub mod http;
pub mod loadgen;
pub mod metrics;
pub mod registry;

use std::io::{BufReader, ErrorKind};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::dlrt::tensor::Tensor;
use crate::exec::CompiledModel;
use crate::obs::trace::{SpanKind, SpanRec, TraceBuffer};
use crate::util::json::{arr, num, obj, s, Json};

use self::http::{ReadOutcome, Request, Response};
use self::metrics::{GatewayStats, ModelStats};
use self::registry::{ModelRegistry, ModelSpec};

/// Spans retained by the in-memory trace ring behind `/v1/debug/trace`
/// (~40 B each; older spans are overwritten).
const TRACE_CAP: usize = 4096;

/// Where the gateway's structured access-log lines go (stderr by default;
/// tests capture them via [`Gateway::set_access_sink`]).
type AccessSink = Box<dyn Fn(&str) + Send + Sync>;

#[derive(Clone, Copy, Debug)]
pub struct GatewayConfig {
    /// request body limit (413 above this)
    pub max_body_bytes: usize,
    /// concurrent connections (503 above this)
    pub max_connections: usize,
    /// how long shutdown waits for in-flight connections to finish
    pub drain_timeout: Duration,
    /// per-read socket timeout; bounds shutdown latency of idle keep-alives
    pub read_timeout: Duration,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            max_body_bytes: 64 << 20,
            max_connections: 256,
            drain_timeout: Duration::from_secs(10),
            read_timeout: Duration::from_millis(500),
        }
    }
}

struct GwShared {
    registry: Arc<ModelRegistry>,
    stats: GatewayStats,
    conns: admission::ConnLimiter,
    /// stop accepting; close keep-alive connections after their response
    stop: AtomicBool,
    /// set by `POST /v1/admin/shutdown`; the CLI polls it and drains
    shutdown_requested: AtomicBool,
    /// bounded request-lifecycle span ring (`GET /v1/debug/trace`)
    trace: TraceBuffer,
    /// request sequence numbers — the numeric `tid` tying trace spans to
    /// access-log request IDs
    req_seq: AtomicU64,
    access_sink: RwLock<Option<AccessSink>>,
    cfg: GatewayConfig,
}

impl GwShared {
    fn log_access(&self, line: &str) {
        match &*self.access_sink.read().unwrap() {
            Some(sink) => sink(line),
            None => eprintln!("[access] {line}"),
        }
    }
}

/// A bound, serving gateway. Dropping it (or calling
/// [`Gateway::shutdown`]) stops the accept loop, waits for in-flight
/// connections, then drains every registered model server.
pub struct Gateway {
    addr: SocketAddr,
    shared: Arc<GwShared>,
    accept: Option<JoinHandle<()>>,
}

impl Gateway {
    /// Bind `listen` (e.g. `127.0.0.1:8080`, port 0 for ephemeral) and
    /// start serving `registry`.
    pub fn bind(
        listen: &str,
        registry: Arc<ModelRegistry>,
        cfg: GatewayConfig,
    ) -> Result<Gateway> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        // non-blocking accept so the loop can observe the stop flag
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(GwShared {
            registry,
            stats: GatewayStats::default(),
            conns: admission::ConnLimiter::new(cfg.max_connections),
            stop: AtomicBool::new(false),
            shutdown_requested: AtomicBool::new(false),
            trace: TraceBuffer::with_capacity(TRACE_CAP),
            req_seq: AtomicU64::new(1),
            access_sink: RwLock::new(None),
            cfg,
        });
        let accept = {
            let shared = shared.clone();
            std::thread::spawn(move || accept_loop(&listener, &shared))
        };
        Ok(Gateway { addr, shared, accept: Some(accept) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a client POSTed `/v1/admin/shutdown`.
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Redirect structured access-log lines (stderr by default). Tests
    /// install a capturing sink to assert on the lines.
    pub fn set_access_sink(&self, sink: AccessSink) {
        *self.shared.access_sink.write().unwrap() = Some(sink);
    }

    /// Graceful drain: stop accepting, let in-flight connections finish
    /// (bounded by `drain_timeout`), then drain every model server so
    /// queued inference completes before the process exits.
    pub fn shutdown(mut self) {
        self.stop_internal();
    }

    fn stop_internal(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Drain the model servers first: queued requests execute
        // immediately (the batcher skips its window while draining), which
        // unblocks the connection threads waiting on them; requests that
        // arrive on live keep-alive connections after this point are shed
        // with 503.
        self.shared.registry.drain_all();
        let deadline = Instant::now() + self.shared.cfg.drain_timeout;
        while self.shared.conns.active() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_internal();
        }
    }
}

fn accept_loop(listener: &TcpListener, shared: &Arc<GwShared>) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return; // listener drops here: port closes, backlog is reset
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let conn = shared.stats.connections.fetch_add(1, Ordering::Relaxed);
                shared.trace.record(SpanRec {
                    kind: SpanKind::Accept,
                    req: conn,
                    ts_us: shared.trace.now_us(),
                    dur_us: 0,
                    batch_index: 0,
                    batch_size: 0,
                    status: 0,
                });
                if !shared.conns.try_acquire() {
                    // over the connection cap: shed before spawning
                    let mut stream = stream;
                    let _ = stream.set_nonblocking(false);
                    let _ = Response::text(503, "too many connections\n")
                        .write_to(&mut stream, true);
                    shared.stats.record(503);
                    continue;
                }
                let shared = shared.clone();
                std::thread::spawn(move || {
                    handle_connection(stream, &shared);
                    shared.conns.release();
                });
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn handle_connection(stream: TcpStream, shared: &GwShared) {
    // accepted sockets may inherit the listener's non-blocking mode on
    // some platforms — force blocking + a finite read timeout
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(shared.cfg.read_timeout));
    // a peer that stops reading its response must not block this thread
    // (and its ConnLimiter slot) forever once the TCP send buffer fills
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    // an idle keep-alive may wait this many read timeouts for its next
    // request before we close it — without a cap, silent peers would hold
    // their ConnLimiter slots forever and lock out new connections
    let max_idle = 60u32;
    let mut idle = 0u32;
    loop {
        match http::read_request(&mut reader, &mut line, shared.cfg.max_body_bytes) {
            Ok(ReadOutcome::Eof) => return,
            Ok(ReadOutcome::IdleTimeout) => {
                idle += 1;
                if shared.stop.load(Ordering::SeqCst) || idle >= max_idle {
                    return; // draining, or idle too long: close the slot
                }
            }
            Ok(ReadOutcome::TooLarge(n)) => {
                let resp = Response::text(413, &format!("body of {n} bytes over limit\n"));
                shared.stats.record(resp.status);
                let _ = resp.write_to(&mut writer, true);
                return;
            }
            Ok(ReadOutcome::Unsupported(what)) => {
                let resp = Response::text(501, &format!("{what}\n"));
                shared.stats.record(resp.status);
                let _ = resp.write_to(&mut writer, true);
                return;
            }
            Ok(ReadOutcome::Request(req)) => {
                idle = 0;
                let close = req.close || shared.stop.load(Ordering::SeqCst);
                let resp = route(shared, &req);
                shared.stats.record(resp.status);
                if resp.write_to(&mut writer, close).is_err() || close {
                    return;
                }
            }
            Err(_) => {
                let resp = Response::text(400, "malformed request\n");
                shared.stats.record(resp.status);
                let _ = resp.write_to(&mut writer, true);
                return;
            }
        }
    }
}

fn route(shared: &GwShared, req: &Request) -> Response {
    let path = req.path.split('?').next().unwrap_or("");
    let parts: Vec<&str> = path.split('/').filter(|p| !p.is_empty()).collect();
    match (req.method.as_str(), parts.as_slice()) {
        ("GET", ["healthz"]) => Response::text(200, "ok\n"),
        ("GET", ["metrics"]) => {
            Response::new(200, "text/plain; version=0.0.4", render_metrics(shared).into_bytes())
        }
        ("GET", ["v1", "models"]) => models_json(shared),
        ("GET", ["v1", "debug", "trace"]) => trace_json(shared),
        // slice-pattern bindings on `&[&str]` are `&&str`: deref at use
        ("POST", ["v1", "models", name, "infer"]) => infer(shared, *name, req),
        ("POST", ["v1", "models", name, "load"]) => load_model(shared, *name, req),
        ("POST", ["v1", "models", name, "unload"]) => unload_model(shared, *name),
        ("POST", ["v1", "admin", "shutdown"]) => {
            shared.shutdown_requested.store(true, Ordering::SeqCst);
            Response::text(200, "draining\n")
        }
        // 405 only for known paths hit with the wrong method; unknown
        // paths (typos included) fall through to 404
        (_, ["healthz" | "metrics"])
        | (_, ["v1", "models"])
        | (_, ["v1", "debug", "trace"])
        | (_, ["v1", "models", _, "infer" | "load" | "unload"])
        | (_, ["v1", "admin", "shutdown"]) => Response::text(405, "method not allowed\n"),
        _ => Response::text(404, "not found\n"),
    }
}

// ---------------------------------------------------------------------------
// handlers
// ---------------------------------------------------------------------------

/// Per-request timing collected by [`infer_inner`] for the access log.
#[derive(Default)]
struct ReqTiming {
    batch_index: usize,
    batch_size: usize,
    queue_us: u64,
    exec_us: u64,
}

fn infer(shared: &GwShared, name: &str, req: &Request) -> Response {
    let t_start = Instant::now();
    // honor a client-supplied X-Request-Id; generate one otherwise
    let rid = req
        .header("x-request-id")
        .map(str::trim)
        .filter(|v| !v.is_empty())
        .map(str::to_string)
        .unwrap_or_else(crate::obs::gen_request_id);
    let seq = shared.req_seq.fetch_add(1, Ordering::Relaxed);
    let mut timing = ReqTiming::default();
    let resp = infer_inner(shared, name, req, seq, &mut timing);
    let total_us = t_start.elapsed().as_micros() as u64;
    shared.log_access(&crate::obs::access_line(
        crate::obs::unix_ms(),
        &rid,
        name,
        timing.batch_index,
        timing.batch_size,
        resp.status,
        timing.queue_us,
        timing.exec_us,
        total_us,
    ));
    resp.header("X-Request-Id", &rid)
}

fn infer_inner(
    shared: &GwShared,
    name: &str,
    req: &Request,
    seq: u64,
    timing: &mut ReqTiming,
) -> Response {
    let span = |kind: SpanKind, ts_us: u64, dur_us: u64, timing: &ReqTiming, status: u16| SpanRec {
        kind,
        req: seq,
        ts_us,
        dur_us,
        batch_index: timing.batch_index as u32,
        batch_size: timing.batch_size as u32,
        status,
    };
    let Some(entry) = shared.registry.get(name) else {
        return Response::text(404, &format!("no such model {name:?}\n"));
    };
    let json_io = req
        .header("content-type")
        .map(|c| c.starts_with("application/json"))
        .unwrap_or(false);
    let t_parse_us = shared.trace.now_us();
    let t_parse = Instant::now();
    let input = match parse_input(req, json_io, &entry.model) {
        Ok(t) => t,
        Err(e) => return Response::text(400, &format!("bad input: {e:#}\n")),
    };
    let parse_us = t_parse.elapsed().as_micros() as u64;
    shared.trace.record(span(SpanKind::Parse, t_parse_us, parse_us, timing, 0));
    let t_submit_us = shared.trace.now_us();
    match entry.server.try_submit(input) {
        Err(e) => admission::reject_response(&e, &entry.server.metrics()),
        Ok(rx) => {
            shared.stats.in_flight.fetch_add(1, Ordering::Relaxed);
            let got = rx.recv();
            shared.stats.in_flight.fetch_sub(1, Ordering::Relaxed);
            match got {
                Ok(Ok(reply)) => {
                    timing.batch_index = reply.batch_index;
                    timing.batch_size = reply.batch_size;
                    timing.queue_us = reply.queue_us;
                    timing.exec_us = reply.exec_us;
                    let t_recv_us = shared.trace.now_us();
                    // queue-wait from submit; batch = assembly window +
                    // execution; exec = the plan-execution tail of it
                    let t_batch_us = t_submit_us + reply.queue_us;
                    shared
                        .trace
                        .record(span(SpanKind::Queue, t_submit_us, reply.queue_us, timing, 200));
                    shared.trace.record(span(
                        SpanKind::Batch,
                        t_batch_us,
                        t_recv_us.saturating_sub(t_batch_us),
                        timing,
                        200,
                    ));
                    shared.trace.record(span(
                        SpanKind::Exec,
                        t_recv_us.saturating_sub(reply.exec_us),
                        reply.exec_us,
                        timing,
                        200,
                    ));
                    let t_resp_us = shared.trace.now_us();
                    let t_resp = Instant::now();
                    let resp = render_outputs(&reply.outputs, json_io);
                    shared.trace.record(span(
                        SpanKind::Respond,
                        t_resp_us,
                        t_resp.elapsed().as_micros() as u64,
                        timing,
                        200,
                    ));
                    resp
                }
                Ok(Err(e)) => {
                    if e.is::<crate::coordinator::ServerStopping>() {
                        Response::text(503, "server stopping\n")
                    } else {
                        Response::text(500, &format!("inference failed: {e:#}\n"))
                    }
                }
                Err(_) => Response::text(503, "model worker gone\n"),
            }
        }
    }
}

/// `GET /v1/debug/trace`: the retained span ring as a Chrome trace-event
/// document (load in Perfetto / `chrome://tracing`).
fn trace_json(shared: &GwShared) -> Response {
    Response::json(200, &crate::obs::trace::chrome_trace_json(&shared.trace.snapshot()))
}

/// Decode one `[1, H, W, C]` request input in either wire format.
fn parse_input(req: &Request, json_io: bool, model: &CompiledModel) -> Result<Tensor> {
    let mut shape = vec![1usize];
    shape.extend_from_slice(&model.plan.input_tail);
    let elems: usize = shape.iter().product();
    if json_io {
        let text = std::str::from_utf8(&req.body).context("body is not UTF-8")?;
        let v = Json::parse(text)?;
        if let Some(sh) = v.opt("shape") {
            let sh = sh.usize_vec()?;
            if sh != shape {
                bail!("shape {sh:?} does not match model input {shape:?}");
            }
        }
        let data = v.get("data")?.f32_vec()?;
        if data.len() != elems {
            bail!("data has {} values, model input {shape:?} wants {elems}", data.len());
        }
        Tensor::new(shape, data)
    } else {
        if req.body.len() != 4 * elems {
            bail!(
                "raw body is {} bytes, model input {shape:?} wants {} ({} f32 LE values)",
                req.body.len(),
                4 * elems,
                elems
            );
        }
        Tensor::new(shape, http::le_bytes_to_f32s(&req.body))
    }
}

fn shape_json(shape: &[usize]) -> Json {
    arr(shape.iter().map(|&d| num(d as f64)).collect())
}

fn render_outputs(outs: &[Tensor], json_io: bool) -> Response {
    if json_io {
        let outputs = arr(outs
            .iter()
            .map(|o| {
                obj(vec![
                    ("shape", shape_json(&o.shape)),
                    ("data", arr(o.data.iter().map(|&v| num(v as f64)).collect())),
                ])
            })
            .collect());
        Response::json(200, &obj(vec![("outputs", outputs)]))
    } else {
        let total: usize = outs.iter().map(|o| 4 * o.numel()).sum();
        let mut body = Vec::with_capacity(total);
        for o in outs {
            body.extend_from_slice(&http::f32s_to_le_bytes(&o.data));
        }
        let shapes = arr(outs.iter().map(|o| shape_json(&o.shape)).collect());
        Response::bytes(200, body).header("X-DLRT-Shapes", &shapes.to_string())
    }
}

fn models_json(shared: &GwShared) -> Response {
    let models = arr(shared
        .registry
        .list()
        .iter()
        .map(|e| {
            let cfg = e.server.config();
            let snap = e.server.metrics();
            let mut ishape = vec![1usize];
            ishape.extend_from_slice(&e.model.plan.input_tail);
            let engines = obj(e
                .model
                .engine_summary()
                .into_iter()
                .map(|(k, v)| (k, num(v as f64)))
                .collect());
            obj(vec![
                ("name", s(&e.name)),
                ("source", s(&e.source)),
                ("input_shape", shape_json(&ishape)),
                ("engines", engines),
                ("workers", num(cfg.workers as f64)),
                ("max_batch", num(cfg.max_batch as f64)),
                ("queue_cap", num(cfg.queue_cap as f64)),
                ("queue_depth", num(e.server.queue_depth() as f64)),
                ("arena_bytes_per_item", num(e.model.plan.arena_bytes(1) as f64)),
                ("completed", num(snap.completed as f64)),
                ("errors", num(snap.errors as f64)),
            ])
        })
        .collect());
    Response::json(200, &obj(vec![("models", models)]))
}

fn load_model(shared: &GwShared, name: &str, req: &Request) -> Response {
    let spec = match std::str::from_utf8(&req.body)
        .map_err(anyhow::Error::from)
        .and_then(Json::parse)
        .and_then(|v| ModelSpec::from_json(name, &v))
    {
        Ok(spec) => spec,
        Err(e) => return Response::text(400, &format!("bad load request: {e:#}\n")),
    };
    match shared.registry.load_spec(&spec) {
        Ok(()) => Response::json(200, &obj(vec![("loaded", s(name))])),
        Err(e) => Response::text(400, &format!("load failed: {e:#}\n")),
    }
}

fn unload_model(shared: &GwShared, name: &str) -> Response {
    match shared.registry.unload(name) {
        Ok(()) => Response::json(200, &obj(vec![("unloaded", s(name))])),
        Err(e) => Response::text(404, &format!("{e:#}\n")),
    }
}

fn render_metrics(shared: &GwShared) -> String {
    let models: Vec<ModelStats> = shared
        .registry
        .list()
        .iter()
        .map(|e| {
            let cfg = e.server.config();
            ModelStats {
                name: e.name.clone(),
                queue_depth: e.server.queue_depth(),
                queue_cap: cfg.queue_cap,
                max_batch: cfg.max_batch,
                workers: cfg.workers,
                arena_bytes_per_item: e.model.plan.arena_bytes(1),
                snap: e.server.metrics(),
            }
        })
        .collect();
    metrics::render_prometheus(&shared.stats, &models)
}

//! Network inference gateway: the `dlrt serve` HTTP surface.
//!
//! A std-only event-driven HTTP/1.1 server in front of the
//! [`registry::ModelRegistry`]. Connections are handled by N shard event
//! loops ([`event`]) on readiness-based polling — no thread-per-connection,
//! no blocking reads, and an accept path that never blocks on a client
//! socket. Inference requests are submitted to the coordinator with a
//! completion callback (`try_submit_cb`): the batch worker renders the
//! response straight from the batched output tensors and injects it back
//! into the owning shard, so unrelated sockets coalesce into one NHWC
//! batch and raw-f32 bodies cross exactly one copy between the executor's
//! arena-backed output and the socket write queue. Admission refusals are
//! shed at the edge as 429/503 instead of queueing unboundedly.
//!
//! Endpoints:
//!
//! ```text
//!   GET  /healthz                     liveness
//!   GET  /metrics                     Prometheus text format 0.0.4
//!   GET  /v1/models                   registry listing + sizing + stats
//!   POST /v1/models/{name}/infer      raw f32 LE bytes or JSON {"data":[..]}
//!   POST /v1/models/{name}/load       {"path": ..} | {"builder": .., "res": ..}
//!   POST /v1/models/{name}/unload     stop serving (drains in-flight work)
//!   POST /v1/admin/shutdown           request graceful gateway drain
//! ```
//!
//! Wire format for `/infer`: request body is one `[1, H, W, C]` NHWC input
//! — either `Content-Type: application/octet-stream` with `H*W*C` f32
//! little-endian values, or `application/json` with `{"data": [floats],
//! "shape": [1,H,W,C]?}`. Raw responses concatenate every model output's
//! f32 data and carry an `X-DLRT-Shapes` JSON header; JSON responses are
//! `{"outputs": [{"shape": [...], "data": [...]}]}`. Both round-trip f32
//! exactly, so gateway outputs are bit-identical to a direct
//! `Executor::run` (the integration test asserts it). Successful infer
//! responses also carry `X-DLRT-Batch-Index` / `X-DLRT-Batch-Size`, which
//! is how clients (and the cross-connection-batching test) observe
//! coalescing.

pub mod admission;
mod event;
pub mod http;
pub mod loadgen;
pub mod metrics;
pub mod registry;

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{ReplyCallback, ReplyOutcome};
use crate::dlrt::tensor::Tensor;
use crate::exec::CompiledModel;
use crate::obs::trace::{SpanKind, SpanRec, TraceBuffer};
use crate::util::json::{arr, num, obj, s, Json};

use self::http::{Request, Response};
use self::metrics::{GatewayStats, ModelStats};
use self::registry::{ModelRegistry, ModelSpec};

/// Spans retained by the in-memory trace ring behind `/v1/debug/trace`
/// (~40 B each; older spans are overwritten).
const TRACE_CAP: usize = 4096;

/// Where the gateway's structured access-log lines go (stderr by default;
/// tests capture them via [`Gateway::set_access_sink`]).
type AccessSink = Box<dyn Fn(&str) + Send + Sync>;

#[derive(Clone, Copy, Debug)]
pub struct GatewayConfig {
    /// request body limit (413 above this)
    pub max_body_bytes: usize,
    /// concurrent connections (503 above this)
    pub max_connections: usize,
    /// how long shutdown waits for in-flight connections to finish
    pub drain_timeout: Duration,
    /// close a keep-alive connection after this long with no request
    pub idle_timeout: Duration,
    /// shard event loops, each with its own listener and poll set;
    /// 0 = auto (min(4, available cores))
    pub event_loops: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            max_body_bytes: 64 << 20,
            max_connections: 256,
            drain_timeout: Duration::from_secs(10),
            idle_timeout: Duration::from_secs(30),
            event_loops: 0,
        }
    }
}

struct GwShared {
    registry: Arc<ModelRegistry>,
    stats: GatewayStats,
    conns: admission::ConnLimiter,
    /// stop accepting; shards drain in-flight work and exit
    stop: AtomicBool,
    /// set by `POST /v1/admin/shutdown`; the CLI blocks on it and drains
    shutdown_requested: AtomicBool,
    /// condvar pair behind [`Gateway::wait_shutdown_requested`]
    shutdown_signal: (Mutex<bool>, Condvar),
    /// bounded request-lifecycle span ring (`GET /v1/debug/trace`)
    trace: TraceBuffer,
    /// request sequence numbers — the numeric `tid` tying trace spans to
    /// access-log request IDs
    req_seq: AtomicU64,
    access_sink: RwLock<Option<AccessSink>>,
    cfg: GatewayConfig,
}

impl GwShared {
    fn log_access(&self, line: &str) {
        match &*self.access_sink.read().unwrap() {
            Some(sink) => sink(line),
            None => eprintln!("[access] {line}"),
        }
    }

    fn request_shutdown(&self) {
        self.shutdown_requested.store(true, Ordering::SeqCst);
        let (mu, cv) = &self.shutdown_signal;
        *mu.lock().unwrap() = true;
        cv.notify_all();
    }
}

/// What the event loop should do with a dispatched request.
enum Action {
    /// write this response now
    Respond(Response),
    /// a completion will arrive through the shard's injector later
    Pending,
}

/// Async-completion handle for one request: which connection to answer
/// (generation-checked token) and which shard mailbox the response goes
/// through.
struct ReqCtx {
    token: event::ConnToken,
    injector: Arc<event::Injector>,
}

/// A bound, serving gateway. Dropping it (or calling
/// [`Gateway::shutdown`]) stops the shard event loops, drains in-flight
/// connections, then drains every registered model server.
pub struct Gateway {
    addr: SocketAddr,
    shared: Arc<GwShared>,
    shards: Vec<event::ShardHandle>,
}

impl Gateway {
    /// Bind `listen` (e.g. `127.0.0.1:8080`, port 0 for ephemeral) and
    /// start serving `registry` on N shard event loops (`SO_REUSEPORT`
    /// sibling listeners where the platform has it).
    pub fn bind(
        listen: &str,
        registry: Arc<ModelRegistry>,
        cfg: GatewayConfig,
    ) -> Result<Gateway> {
        event::raise_nofile_limit(cfg.max_connections);
        let loops = if cfg.event_loops == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4)
        } else {
            cfg.event_loops
        };
        let shared = Arc::new(GwShared {
            registry,
            stats: GatewayStats::default(),
            conns: admission::ConnLimiter::new(cfg.max_connections),
            stop: AtomicBool::new(false),
            shutdown_requested: AtomicBool::new(false),
            shutdown_signal: (Mutex::new(false), Condvar::new()),
            trace: TraceBuffer::with_capacity(TRACE_CAP),
            req_seq: AtomicU64::new(1),
            access_sink: RwLock::new(None),
            cfg,
        });
        let (addr, shards) = event::spawn_shards(listen, loops, &shared)?;
        Ok(Gateway { addr, shared, shards })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a client POSTed `/v1/admin/shutdown`.
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Block until a client POSTs `/v1/admin/shutdown` (condvar wait; the
    /// CLI used to sleep-poll [`Gateway::shutdown_requested`] instead).
    pub fn wait_shutdown_requested(&self) {
        let (mu, cv) = &self.shared.shutdown_signal;
        let mut requested = mu.lock().unwrap();
        while !*requested {
            requested = cv.wait(requested).unwrap();
        }
    }

    /// Redirect structured access-log lines (stderr by default). Tests
    /// install a capturing sink to assert on the lines.
    pub fn set_access_sink(&self, sink: AccessSink) {
        *self.shared.access_sink.write().unwrap() = Some(sink);
    }

    /// Graceful drain: stop accepting (the port closes immediately), drain
    /// every model server so queued inference completes, deliver those
    /// responses, then join the shard loops (bounded by `drain_timeout`).
    pub fn shutdown(mut self) {
        self.stop_internal();
    }

    fn stop_internal(&mut self) {
        if self.shards.is_empty() {
            return;
        }
        self.shared.stop.store(true, Ordering::SeqCst);
        for shard in &self.shards {
            shard.injector.wake();
        }
        // Drain the model servers: queued requests execute immediately (the
        // batcher skips its window while draining) and their completion
        // callbacks land in the shard injectors; requests arriving on live
        // keep-alive connections after this point are shed with 503.
        self.shared.registry.drain_all();
        // Every completion is now queued or delivered — the shards flush,
        // close, and exit; joining replaces the old 10ms sleep-poll wait.
        for shard in self.shards.drain(..) {
            shard.injector.wake();
            let _ = shard.thread.join();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.stop_internal();
    }
}

/// Route one parsed request. Sync responses are recorded in the gateway
/// stats here; `Pending` paths (infer, load, unload) record when their
/// completion is pushed.
fn dispatch(shared: &Arc<GwShared>, req: Request, ctx: ReqCtx) -> Action {
    let action = route(shared, req, ctx);
    if let Action::Respond(resp) = &action {
        shared.stats.record(resp.status);
    }
    action
}

fn route(shared: &Arc<GwShared>, req: Request, ctx: ReqCtx) -> Action {
    let path = req.path.split('?').next().unwrap_or("").to_string();
    let parts: Vec<&str> = path.split('/').filter(|p| !p.is_empty()).collect();
    let resp = match (req.method.as_str(), parts.as_slice()) {
        ("GET", ["healthz"]) => Response::text(200, "ok\n"),
        ("GET", ["metrics"]) => {
            Response::new(200, "text/plain; version=0.0.4", render_metrics(shared).into_bytes())
        }
        ("GET", ["v1", "models"]) => models_json(shared),
        ("GET", ["v1", "debug", "trace"]) => trace_json(shared),
        // slice-pattern bindings on `&[&str]` are `&&str`: deref-coerced
        ("POST", ["v1", "models", name, "infer"]) => return infer(shared, name, &req, ctx),
        ("POST", ["v1", "models", name, "load"]) => return load_model(shared, name, &req, ctx),
        ("POST", ["v1", "models", name, "unload"]) => {
            return unload_model(shared, name, req.close, ctx)
        }
        ("POST", ["v1", "admin", "shutdown"]) => {
            shared.request_shutdown();
            Response::text(200, "draining\n")
        }
        // 405 only for known paths hit with the wrong method; unknown
        // paths (typos included) fall through to 404
        (_, ["healthz" | "metrics"])
        | (_, ["v1", "models"])
        | (_, ["v1", "debug", "trace"])
        | (_, ["v1", "models", _, "infer" | "load" | "unload"])
        | (_, ["v1", "admin", "shutdown"]) => Response::text(405, "method not allowed\n"),
        _ => Response::text(404, "not found\n"),
    };
    Action::Respond(resp)
}

// ---------------------------------------------------------------------------
// handlers
// ---------------------------------------------------------------------------

/// Per-request timing reported by the access log.
#[derive(Default)]
struct ReqTiming {
    batch_index: usize,
    batch_size: usize,
    queue_us: u64,
    exec_us: u64,
}

fn infer(shared: &Arc<GwShared>, name: &str, req: &Request, ctx: ReqCtx) -> Action {
    let t_start = Instant::now();
    // honor a client-supplied X-Request-Id; generate one otherwise
    let rid = req
        .header("x-request-id")
        .map(str::trim)
        .filter(|v| !v.is_empty())
        .map(str::to_string)
        .unwrap_or_else(crate::obs::gen_request_id);
    let seq = shared.req_seq.fetch_add(1, Ordering::Relaxed);
    match submit_infer(shared, name, req, ctx, seq, &rid, t_start) {
        Ok(()) => Action::Pending,
        Err(resp) => {
            // refused before reaching a worker: log the access line now
            let total_us = t_start.elapsed().as_micros() as u64;
            shared.log_access(&crate::obs::access_line(
                crate::obs::unix_ms(),
                &rid,
                name,
                0,
                0,
                resp.status,
                0,
                0,
                total_us,
            ));
            Action::Respond(resp.header("X-Request-Id", &rid))
        }
    }
}

/// Parse + submit one inference with a completion callback; `Err` is the
/// synchronous refusal (unknown model, bad input, admission shed).
fn submit_infer(
    shared: &Arc<GwShared>,
    name: &str,
    req: &Request,
    ctx: ReqCtx,
    seq: u64,
    rid: &str,
    t_start: Instant,
) -> std::result::Result<(), Response> {
    let Some(entry) = shared.registry.get(name) else {
        return Err(Response::text(404, &format!("no such model {name:?}\n")));
    };
    let json_io = req
        .header("content-type")
        .map(|c| c.starts_with("application/json"))
        .unwrap_or(false);
    let t_parse_us = shared.trace.now_us();
    let t_parse = Instant::now();
    let input = match parse_input(req, json_io, &entry.model) {
        Ok(t) => t,
        Err(e) => return Err(Response::text(400, &format!("bad input: {e:#}\n"))),
    };
    shared.trace.record(SpanRec {
        kind: SpanKind::Parse,
        req: seq,
        ts_us: t_parse_us,
        dur_us: t_parse.elapsed().as_micros() as u64,
        batch_index: 0,
        batch_size: 0,
        status: 0,
    });
    let t_submit_us = shared.trace.now_us();
    let cb_shared = shared.clone();
    let rid = rid.to_string();
    let model_name = name.to_string();
    let close = req.close;
    shared.stats.in_flight.fetch_add(1, Ordering::Relaxed);
    // Runs on the batch worker right after execution: render the response
    // from the *batched* outputs (one copy for raw bodies), then hand it
    // to the connection's shard — the worker never blocks on the peer.
    let cb: ReplyCallback = Box::new(move |outcome| {
        cb_shared.stats.in_flight.fetch_sub(1, Ordering::Relaxed);
        let (resp, timing) = match outcome {
            ReplyOutcome::Ok(r) => {
                let timing = ReqTiming {
                    batch_index: r.batch_index,
                    batch_size: r.batch_size,
                    queue_us: r.queue_us,
                    exec_us: r.exec_us,
                };
                let span = |kind: SpanKind, ts_us: u64, dur_us: u64| SpanRec {
                    kind,
                    req: seq,
                    ts_us,
                    dur_us,
                    batch_index: timing.batch_index as u32,
                    batch_size: timing.batch_size as u32,
                    status: 200,
                };
                let t_recv_us = cb_shared.trace.now_us();
                // queue-wait from submit; batch = assembly window +
                // execution; exec = the plan-execution tail of it
                let t_batch_us = t_submit_us + r.queue_us;
                cb_shared.trace.record(span(SpanKind::Queue, t_submit_us, r.queue_us));
                cb_shared.trace.record(span(
                    SpanKind::Batch,
                    t_batch_us,
                    t_recv_us.saturating_sub(t_batch_us),
                ));
                cb_shared.trace.record(span(
                    SpanKind::Exec,
                    t_recv_us.saturating_sub(r.exec_us),
                    r.exec_us,
                ));
                let t_resp_us = cb_shared.trace.now_us();
                let t_resp = Instant::now();
                let resp = render_batched(r.outputs, r.batch_index, json_io)
                    .header("X-DLRT-Batch-Index", &r.batch_index.to_string())
                    .header("X-DLRT-Batch-Size", &r.batch_size.to_string());
                cb_shared.trace.record(span(
                    SpanKind::Respond,
                    t_resp_us,
                    t_resp.elapsed().as_micros() as u64,
                ));
                (resp, timing)
            }
            ReplyOutcome::Err(e) => (
                Response::text(500, &format!("inference failed: {e:#}\n")),
                ReqTiming::default(),
            ),
            ReplyOutcome::Stopping => {
                (Response::text(503, "server stopping\n"), ReqTiming::default())
            }
        };
        let total_us = t_start.elapsed().as_micros() as u64;
        cb_shared.log_access(&crate::obs::access_line(
            crate::obs::unix_ms(),
            &rid,
            &model_name,
            timing.batch_index,
            timing.batch_size,
            resp.status,
            timing.queue_us,
            timing.exec_us,
            total_us,
        ));
        let resp = resp.header("X-Request-Id", &rid);
        cb_shared.stats.record(resp.status);
        ctx.injector.push(event::Completion { token: ctx.token, resp, close });
    });
    match entry.server.try_submit_cb(input, cb) {
        Ok(()) => Ok(()),
        Err(e) => {
            // the callback was never (and will never be) invoked
            shared.stats.in_flight.fetch_sub(1, Ordering::Relaxed);
            Err(admission::reject_response(
                &e,
                &entry.server.metrics(),
                entry.server.queue_depth(),
                entry.server.config().max_batch,
            ))
        }
    }
}

/// `GET /v1/debug/trace`: the retained span ring as a Chrome trace-event
/// document (load in Perfetto / `chrome://tracing`).
fn trace_json(shared: &GwShared) -> Response {
    Response::json(200, &crate::obs::trace::chrome_trace_json(&shared.trace.snapshot()))
}

/// Decode one `[1, H, W, C]` request input in either wire format.
fn parse_input(req: &Request, json_io: bool, model: &CompiledModel) -> Result<Tensor> {
    let mut shape = vec![1usize];
    shape.extend_from_slice(&model.plan.input_tail);
    let elems: usize = shape.iter().product();
    if json_io {
        let text = std::str::from_utf8(&req.body).context("body is not UTF-8")?;
        let v = Json::parse(text)?;
        if let Some(sh) = v.opt("shape") {
            let sh = sh.usize_vec()?;
            if sh != shape {
                bail!("shape {sh:?} does not match model input {shape:?}");
            }
        }
        let data = v.get("data")?.f32_vec()?;
        if data.len() != elems {
            bail!("data has {} values, model input {shape:?} wants {elems}", data.len());
        }
        Tensor::new(shape, data)
    } else {
        if req.body.len() != 4 * elems {
            bail!(
                "raw body is {} bytes, model input {shape:?} wants {} ({} f32 LE values)",
                req.body.len(),
                4 * elems,
                elems
            );
        }
        Tensor::new(shape, http::le_bytes_to_f32s(&req.body))
    }
}

fn shape_json(shape: &[usize]) -> Json {
    arr(shape.iter().map(|&d| num(d as f64)).collect())
}

/// Render sample `bi` straight from the batched output tensors. For the
/// raw wire format this is the single copy between the executor's output
/// buffer and the socket write queue (the event loop moves the rendered
/// body `Vec` into the connection without touching the bytes again).
fn render_batched(outs: &[Tensor], bi: usize, json_io: bool) -> Response {
    let per_sample: Vec<(Vec<usize>, &[f32])> = outs
        .iter()
        .map(|o| {
            let per: usize =
                if o.shape.is_empty() { 1 } else { o.shape[1..].iter().product() };
            let mut shape = o.shape.clone();
            match shape.first_mut() {
                Some(b) => *b = 1,
                None => shape.push(1),
            }
            let end = ((bi + 1) * per).min(o.data.len());
            let start = (bi * per).min(end);
            (shape, &o.data[start..end])
        })
        .collect();
    if json_io {
        let outputs = arr(per_sample
            .iter()
            .map(|(shape, data)| {
                obj(vec![
                    ("shape", shape_json(shape)),
                    ("data", arr(data.iter().map(|&v| num(v as f64)).collect())),
                ])
            })
            .collect());
        Response::json(200, &obj(vec![("outputs", outputs)]))
    } else {
        let total: usize = per_sample.iter().map(|(_, d)| 4 * d.len()).sum();
        let mut body = Vec::with_capacity(total);
        for (_, data) in &per_sample {
            for v in *data {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
        let shapes = arr(per_sample.iter().map(|(shape, _)| shape_json(shape)).collect());
        Response::bytes(200, body).header("X-DLRT-Shapes", &shapes.to_string())
    }
}

fn models_json(shared: &GwShared) -> Response {
    let models = arr(shared
        .registry
        .list()
        .iter()
        .map(|e| {
            let cfg = e.server.config();
            let snap = e.server.metrics();
            let mut ishape = vec![1usize];
            ishape.extend_from_slice(&e.model.plan.input_tail);
            let engines = obj(e
                .model
                .engine_summary()
                .into_iter()
                .map(|(k, v)| (k, num(v as f64)))
                .collect());
            obj(vec![
                ("name", s(&e.name)),
                ("source", s(&e.source)),
                ("input_shape", shape_json(&ishape)),
                ("engines", engines),
                ("workers", num(cfg.workers as f64)),
                ("replicas", num(cfg.replicas as f64)),
                ("max_batch", num(cfg.max_batch as f64)),
                ("queue_cap", num(cfg.queue_cap as f64)),
                ("queue_depth", num(e.server.queue_depth() as f64)),
                ("arena_bytes_per_item", num(e.model.plan.arena_bytes(1) as f64)),
                ("completed", num(snap.completed as f64)),
                ("errors", num(snap.errors as f64)),
            ])
        })
        .collect());
    Response::json(200, &obj(vec![("models", models)]))
}

/// Model loads compile on a helper thread — a multi-second compile must
/// not stall every other connection on the shard's event loop.
fn load_model(shared: &Arc<GwShared>, name: &str, req: &Request, ctx: ReqCtx) -> Action {
    let spec = match std::str::from_utf8(&req.body)
        .map_err(anyhow::Error::from)
        .and_then(Json::parse)
        .and_then(|v| ModelSpec::from_json(name, &v))
    {
        Ok(spec) => spec,
        Err(e) => {
            return Action::Respond(Response::text(400, &format!("bad load request: {e:#}\n")))
        }
    };
    let shared = shared.clone();
    let name = name.to_string();
    let close = req.close;
    std::thread::spawn(move || {
        let resp = match shared.registry.load_spec(&spec) {
            Ok(()) => Response::json(200, &obj(vec![("loaded", s(&name))])),
            Err(e) => Response::text(400, &format!("load failed: {e:#}\n")),
        };
        shared.stats.record(resp.status);
        ctx.injector.push(event::Completion { token: ctx.token, resp, close });
    });
    Action::Pending
}

/// Unloads drain the replaced server (in-flight work finishes) — also off
/// the event loop, for the same reason as [`load_model`].
fn unload_model(shared: &Arc<GwShared>, name: &str, close: bool, ctx: ReqCtx) -> Action {
    let shared = shared.clone();
    let name = name.to_string();
    std::thread::spawn(move || {
        let resp = match shared.registry.unload(&name) {
            Ok(()) => Response::json(200, &obj(vec![("unloaded", s(&name))])),
            Err(e) => Response::text(404, &format!("{e:#}\n")),
        };
        shared.stats.record(resp.status);
        ctx.injector.push(event::Completion { token: ctx.token, resp, close });
    });
    Action::Pending
}

fn render_metrics(shared: &GwShared) -> String {
    let models: Vec<ModelStats> = shared
        .registry
        .list()
        .iter()
        .map(|e| {
            let cfg = e.server.config();
            ModelStats {
                name: e.name.clone(),
                queue_depth: e.server.queue_depth(),
                queue_cap: cfg.queue_cap,
                max_batch: cfg.max_batch,
                workers: cfg.workers,
                arena_bytes_per_item: e.model.plan.arena_bytes(1),
                replica_busy: e.server.replica_occupancy(),
                snap: e.server.metrics(),
            }
        })
        .collect();
    metrics::render_prometheus(&shared.stats, &models)
}

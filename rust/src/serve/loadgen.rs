//! `dlrt client` — HTTP load generator for the gateway.
//!
//! Two modes:
//!
//! * **closed loop** (`rate == 0`): `concurrency` senders each keep one
//!   request outstanding — measures capacity.
//! * **open loop** (`rate > 0`): requests are scheduled on a fixed global
//!   cadence regardless of completions; latency is measured from each
//!   request's *scheduled* time, so queueing delay the server causes is
//!   charged to the server (no coordinated omission).
//!
//! The generator discovers the target model's input shape from
//! `GET /v1/models`, sends one deterministic random input repeatedly, and
//! reports p50/p95/p99 latency plus per-status error counts.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::metrics::percentile;
use crate::util::json::{arr, num, obj, Json};
use crate::util::rng::Rng;

use super::http::{HttpClient, Request};

#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    pub addr: String,
    /// empty = first model the gateway lists
    pub model: String,
    pub requests: usize,
    pub concurrency: usize,
    /// offered load in req/s (across all senders); 0 = closed loop
    pub rate: f64,
    /// send JSON bodies instead of raw f32 bytes
    pub json: bool,
    pub timeout: Duration,
    /// total keep-alive connections spread round-robin across senders
    /// (0 = one per sender); lets a small sender pool exercise thousands
    /// of concurrent sockets against the event-driven accept path
    pub conns: usize,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:8080".to_string(),
            model: String::new(),
            requests: 64,
            concurrency: 4,
            rate: 0.0,
            json: false,
            timeout: Duration::from_secs(30),
            conns: 0,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    pub model: String,
    pub sent: usize,
    pub ok: usize,
    /// non-2xx responses by status code
    pub status_counts: BTreeMap<u16, usize>,
    /// connect/read/write failures
    pub transport_errors: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub wall_s: f64,
    pub achieved_rps: f64,
}

impl LoadgenReport {
    /// Fraction of sent requests the gateway shed — 429 (queue full) plus
    /// 503 (connection cap / draining) over everything sent.
    pub fn shed_rate(&self) -> f64 {
        if self.sent == 0 {
            return 0.0;
        }
        let shed = self.status_counts.get(&429).unwrap_or(&0)
            + self.status_counts.get(&503).unwrap_or(&0);
        shed as f64 / self.sent as f64
    }

    /// Machine-readable run summary (`dlrt client --out`).
    pub fn to_json(&self) -> Json {
        let statuses = self
            .status_counts
            .iter()
            .map(|(st, n)| (st.to_string(), num(*n as f64)))
            .collect::<BTreeMap<String, Json>>();
        obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("sent", num(self.sent as f64)),
            ("ok", num(self.ok as f64)),
            ("transport_errors", num(self.transport_errors as f64)),
            ("status_counts", Json::Obj(statuses)),
            ("shed_rate", num(self.shed_rate())),
            ("shed_429", num(*self.status_counts.get(&429).unwrap_or(&0) as f64)),
            ("shed_503", num(*self.status_counts.get(&503).unwrap_or(&0) as f64)),
            ("p50_ms", num(self.p50_ms)),
            ("p95_ms", num(self.p95_ms)),
            ("p99_ms", num(self.p99_ms)),
            ("mean_ms", num(self.mean_ms)),
            ("wall_s", num(self.wall_s)),
            ("achieved_rps", num(self.achieved_rps)),
        ])
    }
}

/// Build the request body for `shape` (without the batch dim the element
/// count is the product of all dims; batch is always 1 per request).
fn build_body(shape: &[usize], json: bool) -> (String, Vec<u8>) {
    let elems: usize = shape.iter().product();
    let mut rng = Rng::new(42);
    if json {
        let data = arr((0..elems).map(|_| num(rng.f32() as f64)).collect());
        let v = obj(vec![("data", data)]);
        ("application/json".to_string(), v.to_string().into_bytes())
    } else {
        let data: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
        ("application/octet-stream".to_string(), super::http::f32s_to_le_bytes(&data))
    }
}

/// Resolve the target model name + input shape from `GET /v1/models`.
fn discover(cfg: &LoadgenConfig) -> Result<(String, Vec<usize>)> {
    let mut probe = HttpClient::new(&cfg.addr, cfg.timeout);
    let resp = probe.send(&Request::new("GET", "/v1/models"))?;
    if resp.status != 200 {
        bail!("GET /v1/models returned {}", resp.status);
    }
    let v = Json::parse(resp.body_str()?)?;
    let models = v.get("models")?.arr()?;
    let entry = if cfg.model.is_empty() {
        models.first().ok_or_else(|| anyhow!("gateway has no models registered"))?
    } else {
        models
            .iter()
            .find(|m| m.get("name").and_then(|n| n.str().map(String::from)).ok().as_deref()
                == Some(cfg.model.as_str()))
            .ok_or_else(|| anyhow!("model {:?} not registered on {}", cfg.model, cfg.addr))?
    };
    let name = entry.get("name")?.str()?.to_string();
    let shape = entry.get("input_shape")?.usize_vec()?;
    Ok((name, shape))
}

pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    // opening thousands of client sockets trips the default soft FD limit
    super::event::raise_nofile_limit(cfg.conns.max(cfg.concurrency));
    let (model, shape) = discover(cfg).context("discovering target model")?;
    let (content_type, body) = build_body(&shape, cfg.json);
    let path = format!("/v1/models/{model}/infer");
    let total = cfg.requests;
    let senders = cfg.concurrency.max(1);
    // connections per sender: each sender owns a disjoint slice of the
    // `conns` pool and round-robins its requests across them, so `conns`
    // keep-alive sockets stay live without `conns` OS threads
    let per_sender =
        if cfg.conns == 0 { 1 } else { cfg.conns.div_ceil(senders).max(1) };
    let interval = if cfg.rate > 0.0 {
        Some(Duration::from_secs_f64(1.0 / cfg.rate))
    } else {
        None
    };

    let next = AtomicUsize::new(0);
    // (status or 0 for transport error, latency ms)
    let results: Mutex<Vec<(u16, f64)>> = Mutex::new(Vec::with_capacity(total));
    let t_start = Instant::now();

    std::thread::scope(|scope| {
        for _ in 0..senders {
            scope.spawn(|| {
                let mut clients: Vec<HttpClient> =
                    (0..per_sender).map(|_| HttpClient::new(&cfg.addr, cfg.timeout)).collect();
                let mut turn = 0usize;
                let mut local: Vec<(u16, f64)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    let t0 = match interval {
                        Some(dt) => {
                            // open loop: fire at the scheduled instant and
                            // measure from it
                            let due = t_start + dt.mul_f64(i as f64);
                            let now = Instant::now();
                            if due > now {
                                std::thread::sleep(due - now);
                            }
                            due
                        }
                        None => Instant::now(),
                    };
                    let req = Request::with_body("POST", &path, &content_type, body.clone());
                    let client = &mut clients[turn % per_sender];
                    turn = turn.wrapping_add(1);
                    let status = match client.send(&req) {
                        Ok(resp) => resp.status,
                        Err(_) => 0,
                    };
                    local.push((status, t0.elapsed().as_secs_f64() * 1e3));
                }
                results.lock().unwrap().extend(local);
            });
        }
    });

    let wall_s = t_start.elapsed().as_secs_f64();
    let results = results.into_inner().unwrap();
    let ok_lat: Vec<f64> =
        results.iter().filter(|(st, _)| (200..300).contains(st)).map(|&(_, l)| l).collect();
    let mut status_counts: BTreeMap<u16, usize> = BTreeMap::new();
    let mut transport_errors = 0usize;
    for &(st, _) in &results {
        if st == 0 {
            transport_errors += 1;
        } else if !(200..300).contains(&st) {
            *status_counts.entry(st).or_insert(0) += 1;
        }
    }
    Ok(LoadgenReport {
        model,
        sent: results.len(),
        ok: ok_lat.len(),
        status_counts,
        transport_errors,
        p50_ms: percentile(&ok_lat, 0.50),
        p95_ms: percentile(&ok_lat, 0.95),
        p99_ms: percentile(&ok_lat, 0.99),
        mean_ms: if ok_lat.is_empty() {
            0.0
        } else {
            ok_lat.iter().sum::<f64>() / ok_lat.len() as f64
        },
        wall_s,
        achieved_rps: if wall_s > 0.0 { ok_lat.len() as f64 / wall_s } else { 0.0 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn body_matches_shape_and_is_deterministic() {
        let (ct, raw) = build_body(&[1, 4, 4, 3], false);
        assert_eq!(ct, "application/octet-stream");
        assert_eq!(raw.len(), 4 * 4 * 4 * 3);
        let (_, raw2) = build_body(&[1, 4, 4, 3], false);
        assert_eq!(raw, raw2);

        let (ct, js) = build_body(&[1, 2, 2, 1], true);
        assert_eq!(ct, "application/json");
        let v = Json::parse(std::str::from_utf8(&js).unwrap()).unwrap();
        assert_eq!(v.get("data").unwrap().arr().unwrap().len(), 4);
    }

    #[test]
    fn report_json_summary_round_trips() {
        let mut rep = LoadgenReport {
            model: "tiny".into(),
            sent: 10,
            ok: 8,
            p50_ms: 1.0,
            p95_ms: 2.0,
            p99_ms: 3.0,
            mean_ms: 1.5,
            wall_s: 0.5,
            achieved_rps: 16.0,
            ..Default::default()
        };
        rep.status_counts.insert(429, 2);
        assert!((rep.shed_rate() - 0.2).abs() < 1e-12);
        let v = Json::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(v.get("model").unwrap().str().unwrap(), "tiny");
        assert_eq!(v.get("sent").unwrap().usize().unwrap(), 10);
        assert_eq!(v.get("ok").unwrap().usize().unwrap(), 8);
        assert!((v.get("shed_rate").unwrap().num().unwrap() - 0.2).abs() < 1e-12);
        assert_eq!(v.get("status_counts").unwrap().get("429").unwrap().usize().unwrap(), 2);
        assert!((v.get("achieved_rps").unwrap().num().unwrap() - 16.0).abs() < 1e-12);
        // the summary splits queue sheds from connection/drain sheds
        assert_eq!(v.get("shed_429").unwrap().usize().unwrap(), 2);
        assert_eq!(v.get("shed_503").unwrap().usize().unwrap(), 0);
    }

    #[test]
    fn shed_rate_counts_both_429_and_503() {
        let mut rep = LoadgenReport { sent: 10, ok: 6, ..Default::default() };
        rep.status_counts.insert(429, 2);
        rep.status_counts.insert(503, 2);
        assert!((rep.shed_rate() - 0.4).abs() < 1e-12);
        let v = Json::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(v.get("shed_429").unwrap().usize().unwrap(), 2);
        assert_eq!(v.get("shed_503").unwrap().usize().unwrap(), 2);
        assert!((v.get("shed_rate").unwrap().num().unwrap() - 0.4).abs() < 1e-12);
    }
}

//! `dlrt tune` — per-(op, shape, engine, ISA) schedule search with a
//! persisted, versioned tuning DB.
//!
//! The registry (`kernels::ukernel`) carries one static tile geometry per
//! ISA; "Automating Generation of Low Precision Deep Learning Operators"
//! (PAPERS.md) shows searched schedules beat one-shape-fits-all constants.
//! This module searches candidate [`UKernelDesc`] overrides — tile_m /
//! tile_n / k_unroll, a thread split, and the im2col staging strategy —
//! per conv GEMM shape, **benchmarking candidates on the actual machine**
//! with the analytical cost model ([`crate::costmodel::conv_cost_s_for`])
//! demoted to the search *prior*: it ranks the candidate grid, the top of
//! the ranking gets measured, and only a measured ≥2% win is persisted —
//! so a tuned model is never slower than the defaults by construction.
//!
//! Winners land in a [`TuningDb`] (JSON, `version` 1) consulted by
//! `compiler::compile_graph_tuned` at compile time: exact-shape hit first,
//! then nearest-shape fallback (log-space distance under a cutoff), then
//! the static defaults. The DB ships inside `.dlrt` (format v3), travels
//! via `DLRT_TUNE_DB` ([`ambient_db`]), and every record is validated at
//! the trust boundary ([`validate_entry`]) — tile geometry > 0, within
//! the kernels' clamp limits, k_unroll a multiple of the ISA's native
//! chunk, known ISA tag, fp32 never thread-overridden.

use std::path::Path;
use std::sync::OnceLock;

use anyhow::{anyhow, bail, Context, Result};

use crate::bench_harness::bench_ms;
use crate::costmodel::{conv_cost_s_for, CORTEX_A72, EngineKind};
use crate::dlrt::graph::Graph;
use crate::exec::planner::{conv_gemm_shapes, ConvGemmShape};
use crate::kernels::bitserial::{pack_rows_u8, pack_weights_offset, MAX_TILE_M};
use crate::kernels::im2col::{
    im2col_f32, im2col_quant_u8, quantize_direct_u8, stage_direct_f32, ConvDims,
};
use crate::kernels::ukernel::{self, native_chunk, Isa, PackedW, UKernel, UKernelDesc};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::Rng;

/// Tuning-DB file format version (`{"version": 1, "entries": [...]}`).
pub const DB_VERSION: u32 = 1;

/// Largest `tile_n` a schedule may carry (pure loop blocking, but bounded
/// so corrupt records can't smuggle absurd values through the format).
pub const MAX_TILE_N: usize = 256;

/// Nearest-shape fallback cutoff: sum of |ln| distances over (m, k, n).
/// 2.0 ≈ "within one combined order of magnitude"; farther shapes fall
/// back to the static defaults instead of a stale schedule.
pub const NEAREST_CUTOFF: f64 = 2.0;

/// How a conv stages its im2col patch matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Staging {
    /// The general gather (padding/stride aware) — always correct.
    Gather,
    /// Flat copy/quantize for unit convs (1×1, stride 1, pad 0) reading a
    /// dense input; the executor falls back to gather when the instruction
    /// reads a strided view, so `Direct` is a hint, never a hazard.
    Direct,
}

impl Staging {
    pub fn name(self) -> &'static str {
        match self {
            Staging::Gather => "gather",
            Staging::Direct => "direct",
        }
    }

    pub fn parse(v: &str) -> Result<Staging> {
        match v {
            "gather" => Ok(Staging::Gather),
            "direct" => Ok(Staging::Direct),
            other => bail!("unknown staging {other:?} (expected gather|direct)"),
        }
    }
}

/// One tuned schedule: a [`UKernelDesc`] geometry override plus the thread
/// split and staging strategy. ISA-independent on purpose — the owning
/// [`TuneEntry`] carries the ISA, and `desc_for` re-attaches it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Schedule {
    pub tile_m: usize,
    pub tile_n: usize,
    pub k_unroll: usize,
    /// GEMM worker threads for this conv: 0 = inherit the request level.
    /// Integer GEMMs are bit-exact at any thread count; fp32 schedules
    /// must keep 0 (the fp32 row partition is not bit-stable).
    pub threads: usize,
    pub staging: Staging,
}

impl Schedule {
    /// The default (untuned) schedule of a kernel's static geometry.
    pub fn from_desc(d: &UKernelDesc) -> Schedule {
        Schedule {
            tile_m: d.tile_m,
            tile_n: d.tile_n,
            k_unroll: d.k_unroll,
            threads: 0,
            staging: Staging::Gather,
        }
    }

    /// Re-attach an ISA to get the override the GEMM runs with.
    pub fn desc_for(&self, isa: Isa) -> UKernelDesc {
        UKernelDesc { isa, tile_m: self.tile_m, tile_n: self.tile_n, k_unroll: self.k_unroll }
    }

    /// Effective GEMM thread count under a request-level `nthreads`.
    pub fn gemm_threads(&self, nthreads: usize) -> usize {
        if self.threads == 0 {
            nthreads
        } else {
            self.threads.min(nthreads.max(1))
        }
    }
}

/// One tuning-DB record: the schedule that won for a GEMM shape on one
/// engine and ISA, plus the measured gain (default_ms / tuned_ms).
#[derive(Clone, Debug, PartialEq)]
pub struct TuneEntry {
    pub op: String,
    /// GEMM M (im2col rows at batch 1).
    pub m: usize,
    /// GEMM K (patch elements).
    pub k: usize,
    /// GEMM N (output channels).
    pub n: usize,
    /// `bitserial` | `int8` | `fp32`.
    pub engine: String,
    pub isa: Isa,
    pub sched: Schedule,
    pub gain: f64,
}

/// How a lookup resolved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DbMatch {
    Exact,
    Nearest,
}

/// The versioned tuning DB: a flat record list, searched exact-first.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TuningDb {
    pub entries: Vec<TuneEntry>,
}

impl TuningDb {
    pub fn new() -> TuningDb {
        TuningDb::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Does any record target `isa`? (The cross-ISA fallback check.)
    pub fn has_isa(&self, isa: Isa) -> bool {
        self.entries.iter().any(|e| e.isa == isa)
    }

    /// Insert, replacing any record with the same (op, shape, engine, ISA).
    pub fn upsert(&mut self, entry: TuneEntry) {
        let pos = self.entries.iter().position(|e| {
            e.op == entry.op
                && e.m == entry.m
                && e.k == entry.k
                && e.n == entry.n
                && e.engine == entry.engine
                && e.isa == entry.isa
        });
        match pos {
            Some(i) => self.entries[i] = entry,
            None => self.entries.push(entry),
        }
    }

    /// Resolve a schedule for a GEMM shape: exact (op, m, k, n, engine,
    /// ISA) hit first, else the nearest same-(op, engine, ISA) shape by
    /// log-space distance under [`NEAREST_CUTOFF`], else `None` (static
    /// defaults).
    pub fn lookup(
        &self,
        op: &str,
        m: usize,
        k: usize,
        n: usize,
        engine: &str,
        isa: Isa,
    ) -> Option<(&TuneEntry, DbMatch)> {
        let mut best: Option<(&TuneEntry, f64)> = None;
        for e in &self.entries {
            if e.op != op || e.engine != engine || e.isa != isa {
                continue;
            }
            if e.m == m && e.k == k && e.n == n {
                return Some((e, DbMatch::Exact));
            }
            let d = ln_dist(e.m, m) + ln_dist(e.k, k) + ln_dist(e.n, n);
            if d <= NEAREST_CUTOFF && best.is_none_or(|(_, bd)| d < bd) {
                best = Some((e, d));
            }
        }
        best.map(|(e, _)| (e, DbMatch::Nearest))
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("version", num(DB_VERSION as f64)),
            ("entries", arr(self.entries.iter().map(entry_to_json).collect())),
        ])
    }

    /// Parse and validate a DB. `label` prefixes every diagnostic (the
    /// file path at the `format::load` / `DLRT_TUNE_DB` trust boundaries).
    pub fn from_json(label: &str, j: &Json) -> Result<TuningDb> {
        let version =
            j.get("version").and_then(|v| v.usize()).map_err(|e| anyhow!("{label}: {e}"))?;
        if version != DB_VERSION as usize {
            bail!("{label}: unsupported tuning DB version {version} (expected {DB_VERSION})");
        }
        let entries =
            j.get("entries").and_then(|v| v.arr()).map_err(|e| anyhow!("{label}: {e}"))?;
        let mut db = TuningDb::new();
        for (i, ej) in entries.iter().enumerate() {
            let e = entry_from_json(ej)
                .and_then(|e| validate_entry(&e).map(|()| e))
                .map_err(|err| anyhow!("{label}: tuning entry {i}: {err}"))?;
            db.entries.push(e);
        }
        Ok(db)
    }

    pub fn load(path: &Path) -> Result<TuningDb> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("{}: reading tuning DB", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        TuningDb::from_json(&path.display().to_string(), &j)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("{}: writing tuning DB", path.display()))
    }
}

fn ln_dist(a: usize, b: usize) -> f64 {
    ((a.max(1) as f64).ln() - (b.max(1) as f64).ln()).abs()
}

pub fn entry_to_json(e: &TuneEntry) -> Json {
    obj(vec![
        ("op", s(&e.op)),
        ("m", num(e.m as f64)),
        ("k", num(e.k as f64)),
        ("n", num(e.n as f64)),
        ("engine", s(&e.engine)),
        ("isa", s(e.isa.name())),
        ("tile_m", num(e.sched.tile_m as f64)),
        ("tile_n", num(e.sched.tile_n as f64)),
        ("k_unroll", num(e.sched.k_unroll as f64)),
        ("threads", num(e.sched.threads as f64)),
        ("staging", s(e.sched.staging.name())),
        ("gain", num(e.gain)),
    ])
}

pub fn entry_from_json(j: &Json) -> Result<TuneEntry> {
    let isa = Isa::parse(j.get("isa")?.str()?).map_err(|e| anyhow!("{e}"))?;
    Ok(TuneEntry {
        op: j.get("op")?.str()?.to_string(),
        m: j.get("m")?.usize()?,
        k: j.get("k")?.usize()?,
        n: j.get("n")?.usize()?,
        engine: j.get("engine")?.str()?.to_string(),
        isa,
        sched: Schedule {
            tile_m: j.get("tile_m")?.usize()?,
            tile_n: j.get("tile_n")?.usize()?,
            k_unroll: j.get("k_unroll")?.usize()?,
            threads: j.get("threads")?.usize()?,
            staging: Staging::parse(j.get("staging")?.str()?)?,
        },
        gain: j.get("gain")?.num()?,
    })
}

/// Bounds-check one record: the trust-boundary gate every DB passes
/// through before any schedule reaches a prepack or a GEMM.
pub fn validate_entry(e: &TuneEntry) -> Result<()> {
    if e.op != "conv" {
        bail!("unknown op {:?}", e.op);
    }
    if !matches!(e.engine.as_str(), "bitserial" | "int8" | "fp32") {
        bail!("unknown engine {:?}", e.engine);
    }
    if e.m == 0 || e.k == 0 || e.n == 0 {
        bail!("degenerate GEMM shape {}x{}x{}", e.m, e.k, e.n);
    }
    let sc = &e.sched;
    if sc.tile_m == 0 || sc.tile_m > MAX_TILE_M {
        bail!("tile_m {} outside 1..={MAX_TILE_M}", sc.tile_m);
    }
    if sc.tile_n == 0 || sc.tile_n > MAX_TILE_N {
        bail!("tile_n {} outside 1..={MAX_TILE_N}", sc.tile_n);
    }
    let chunk = native_chunk(e.isa);
    if sc.k_unroll == 0 || sc.k_unroll > 16 || sc.k_unroll % chunk != 0 {
        bail!(
            "k_unroll {} must be a multiple of {chunk} in 1..=16 for {}",
            sc.k_unroll,
            e.isa.name()
        );
    }
    if sc.threads > 256 {
        bail!("threads {} outside 0..=256", sc.threads);
    }
    if e.engine == "fp32" && sc.threads != 0 {
        bail!("fp32 schedules must inherit threads (threaded fp32 GEMM is not bit-stable)");
    }
    Ok(())
}

/// A bare [`Schedule`] as JSON — the per-conv `sched` record inside `.dlrt`
/// (the owning conv record carries engine/shape, so only geometry is here).
pub fn sched_to_json(sc: &Schedule) -> Json {
    obj(vec![
        ("tile_m", num(sc.tile_m as f64)),
        ("tile_n", num(sc.tile_n as f64)),
        ("k_unroll", num(sc.k_unroll as f64)),
        ("threads", num(sc.threads as f64)),
        ("staging", s(sc.staging.name())),
    ])
}

pub fn sched_from_json(j: &Json) -> Result<Schedule> {
    Ok(Schedule {
        tile_m: j.get("tile_m")?.usize()?,
        tile_n: j.get("tile_n")?.usize()?,
        k_unroll: j.get("k_unroll")?.usize()?,
        threads: j.get("threads")?.usize()?,
        staging: Staging::parse(j.get("staging")?.str()?)?,
    })
}

/// [`validate_entry`] for a bare per-conv schedule: wrap it in a synthetic
/// unit-shape entry so every geometry/thread/engine rule applies unchanged.
pub fn validate_sched(engine: &str, isa: Isa, sc: &Schedule) -> Result<()> {
    validate_entry(&TuneEntry {
        op: "conv".to_string(),
        m: 1,
        k: 1,
        n: 1,
        engine: engine.to_string(),
        isa,
        sched: *sc,
        gain: 1.0,
    })
}

/// The process-ambient tuning DB (`DLRT_TUNE_DB=<path>`), read once. A
/// missing/empty var is "no DB"; an unreadable or invalid file logs a
/// warning and is ignored — the override must never break a compile.
pub fn ambient_db() -> Option<&'static TuningDb> {
    static DB: OnceLock<Option<TuningDb>> = OnceLock::new();
    DB.get_or_init(|| {
        let path = std::env::var("DLRT_TUNE_DB").ok()?;
        let path = path.trim().to_string();
        if path.is_empty() {
            return None;
        }
        match TuningDb::load(Path::new(&path)) {
            Ok(db) => Some(db),
            Err(e) => {
                eprintln!("warning: ignoring DLRT_TUNE_DB: {e:#}");
                None
            }
        }
    })
    .as_ref()
}

/// A synthetic DB with deliberately odd (but valid) schedules for every
/// conv shape of `g` on every engine — the test substrate that rotates
/// `plan_parity` / `plan_fuzz` through tuned-vs-default plans without
/// paying for a real search.
pub fn synthetic_db(g: &Graph, isa: Isa) -> Result<TuningDb> {
    let shapes = conv_gemm_shapes(g)?;
    let mut db = TuningDb::new();
    for (i, sh) in shapes.iter().enumerate() {
        for engine in ["bitserial", "int8", "fp32"] {
            let sched = Schedule {
                tile_m: [5, 7, 11][i % 3],
                tile_n: [3, 13, 5][i % 3],
                k_unroll: native_chunk(isa) * (1 + i % 2),
                threads: if engine == "fp32" { 0 } else { [0, 2][i % 2] },
                staging: if sh.unit { Staging::Direct } else { Staging::Gather },
            };
            db.upsert(TuneEntry {
                op: "conv".to_string(),
                m: sh.rows,
                k: sh.k,
                n: sh.cout,
                engine: engine.to_string(),
                isa,
                sched,
                gain: 1.0,
            });
        }
    }
    Ok(db)
}

// ---------------------------------------------------------------------------
// The search
// ---------------------------------------------------------------------------

/// Search knobs for `tune_graph`.
#[derive(Clone, Copy, Debug)]
pub struct TuneOpts {
    /// Candidates benchmarked per (shape, engine) beyond the default —
    /// the prior ranks the grid, the top `budget` get measured.
    pub budget: usize,
    /// Timed repetitions per measurement (median-of-reps).
    pub reps: usize,
    /// Request-level thread count the schedules are tuned for.
    pub threads: usize,
}

impl Default for TuneOpts {
    fn default() -> Self {
        TuneOpts { budget: 8, reps: 5, threads: 1 }
    }
}

/// One (shape, engine) search outcome for reporting.
#[derive(Clone, Debug)]
pub struct ShapeReport {
    /// Representative conv name (first in node order with this shape).
    pub name: String,
    /// How many convs of the graph share the shape.
    pub convs: usize,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub engine: String,
    pub default_ms: f64,
    pub tuned_ms: f64,
    pub sched: Schedule,
    /// Whether the winner beat the default by the persistence margin.
    pub improved: bool,
}

/// Run the schedule search for every unique conv GEMM shape of `g` on
/// `isa`, upserting measured winners into `db`. Only a ≥2% measured win is
/// persisted — lookups on un-persisted shapes fall back to the defaults,
/// which is what makes "tuned is never slower" hold by construction.
pub fn tune_graph(
    g: &Graph,
    isa: Isa,
    opts: &TuneOpts,
    db: &mut TuningDb,
) -> Result<Vec<ShapeReport>> {
    let uk = ukernel::kernel_for(isa)
        .ok_or_else(|| anyhow!("ISA {} is not runnable on this host", isa.name()))?;
    let shapes = conv_gemm_shapes(g)?;
    // dedupe by (m, k, n, unit) keeping node order and a share count
    let mut uniq: Vec<(ConvGemmShape, usize)> = Vec::new();
    for sh in shapes {
        if let Some((_, count)) = uniq.iter_mut().find(|(u, _)| {
            u.rows == sh.rows && u.k == sh.k && u.cout == sh.cout && u.unit == sh.unit
        }) {
            *count += 1;
        } else {
            uniq.push((sh, 1));
        }
    }
    let mut reports = Vec::new();
    for (sh, convs) in &uniq {
        let stage = search_staging(sh.unit, sh.rows, sh.k, opts.reps);
        for engine in ["bitserial", "int8", "fp32"] {
            let (sched, default_ms, tuned_ms) = match engine {
                "bitserial" => search_bitserial(uk, sh.rows, sh.cout, sh.k, opts),
                "int8" => search_int8(uk, sh.rows, sh.cout, sh.k, opts),
                _ => (Schedule::from_desc(&uk.desc), 0.0, 0.0),
            };
            let (staging, stage_default, stage_tuned) = if engine == "fp32" {
                search_staging_f32(sh.unit, sh.rows, sh.k, opts.reps)
                    .unwrap_or((Staging::Gather, 0.0, 0.0))
            } else {
                stage.unwrap_or((Staging::Gather, 0.0, 0.0))
            };
            let sched = Schedule { staging, ..sched };
            let default_total = default_ms + stage_default;
            let tuned_total = tuned_ms + stage_tuned;
            let improved =
                tuned_total < default_total * 0.98 && sched != Schedule::from_desc(&uk.desc);
            if improved {
                db.upsert(TuneEntry {
                    op: "conv".to_string(),
                    m: sh.rows,
                    k: sh.k,
                    n: sh.cout,
                    engine: engine.to_string(),
                    isa,
                    sched,
                    gain: if tuned_total > 0.0 { default_total / tuned_total } else { 1.0 },
                });
            }
            reports.push(ShapeReport {
                name: sh.name.clone(),
                convs: *convs,
                m: sh.rows,
                k: sh.k,
                n: sh.cout,
                engine: engine.to_string(),
                default_ms: default_total,
                tuned_ms: tuned_total,
                sched,
                improved,
            });
        }
    }
    Ok(reports)
}

/// Bitserial geometry grid for `isa`: loop-blocking tiles (and, on SIMD
/// entries, the prepack chunk) the kernels can honor.
fn bit_candidates(isa: Isa) -> Vec<UKernelDesc> {
    let chunk = native_chunk(isa);
    let (tms, tns, kus): (&[usize], &[usize], Vec<usize>) = if isa == Isa::Scalar {
        (&[8, 16, 32, 64, 128], &[8, 16, 32, 64], vec![2])
    } else {
        (&[8, 16, 32], &[4, 8, 16, 32], vec![chunk, 2 * chunk])
    };
    let mut out = Vec::new();
    for &tm in tms {
        for &tn in tns {
            for &ku in &kus {
                out.push(UKernelDesc { isa, tile_m: tm, tile_n: tn, k_unroll: ku });
            }
        }
    }
    out
}

/// Search the bitserial schedule for one GEMM shape: the cost model ranks
/// (geometry × thread-split) candidates, the top `budget` are benchmarked
/// on synthetic 2A2W data, and the measured winner is returned alongside
/// the default's measurement.
fn search_bitserial(
    uk: &'static UKernel,
    m: usize,
    n: usize,
    k: usize,
    opts: &TuneOpts,
) -> (Schedule, f64, f64) {
    let isa = uk.desc.isa;
    let mut rng = Rng::new(0x7e57 ^ ((m as u64) << 32) ^ ((n as u64) << 16) ^ k as u64);
    let a: Vec<u8> = (0..m * k).map(|_| rng.usize(4) as u8).collect();
    let w: Vec<i32> = (0..n * k).map(|_| rng.range(-1, 2) as i32).collect();
    let ap = pack_rows_u8(&a, m, k, 2);
    let wp = pack_weights_offset(&w, n, k, 2);
    let mut out = vec![0i32; m * n];
    let eng = EngineKind::Bitserial { w_bits: 2, a_bits: 2 };

    let mut bench = |desc: &UKernelDesc, threads: usize| -> f64 {
        let pw = PackedW::from_packed(&wp, uk.weight_layout_for(desc));
        bench_ms(1, opts.reps, || (uk.gemm_bit)(desc, &ap, &pw, 2, &mut out, threads)).median_ms
    };

    let default_ms = bench(&uk.desc, opts.threads);
    // rank candidates by the cost-model prior, then measure the top
    let mut cands: Vec<(UKernelDesc, usize, f64)> = Vec::new();
    for desc in bit_candidates(isa) {
        for threads in thread_splits(opts.threads) {
            let t = if threads == 0 { opts.threads } else { threads };
            let prior = conv_cost_s_for(&CORTEX_A72, &desc, m, k, n, eng, t);
            cands.push((desc, threads, prior));
        }
    }
    cands.sort_by(|x, y| x.2.total_cmp(&y.2));
    cands.truncate(opts.budget.max(1));
    let mut best = (Schedule::from_desc(&uk.desc), default_ms);
    for (desc, threads, _) in &cands {
        if *desc == uk.desc && *threads == 0 {
            continue; // already measured as the default
        }
        let t = if *threads == 0 { opts.threads } else { *threads };
        let ms = bench(desc, t);
        if ms < best.1 {
            best = (Schedule { threads: *threads, ..Schedule::from_desc(desc) }, ms);
        }
    }
    (best.0, default_ms, best.1)
}

/// int8 search: geometry doesn't reach the int8 GEMM, so only the thread
/// split is searched.
fn search_int8(
    uk: &'static UKernel,
    m: usize,
    n: usize,
    k: usize,
    opts: &TuneOpts,
) -> (Schedule, f64, f64) {
    let mut rng = Rng::new(0x178 ^ ((m as u64) << 32) ^ ((n as u64) << 16) ^ k as u64);
    let a: Vec<u8> = (0..m * k).map(|_| rng.usize(256) as u8).collect();
    let b: Vec<i8> = (0..n * k).map(|_| rng.range(-128, 128) as i8).collect();
    let mut out = vec![0i32; m * n];
    let mut bench = |threads: usize| -> f64 {
        bench_ms(1, opts.reps, || (uk.gemm_u8i8)(&a, &b, m, n, k, &mut out, threads)).median_ms
    };
    let default_ms = bench(opts.threads);
    let mut best = (Schedule::from_desc(&uk.desc), default_ms);
    for threads in thread_splits(opts.threads) {
        if threads == 0 {
            continue;
        }
        let ms = bench(threads);
        if ms < best.1 {
            best = (Schedule { threads, ..Schedule::from_desc(&uk.desc) }, ms);
        }
    }
    (best.0, default_ms, best.1)
}

/// Thread-split candidates at request level `nthreads`: inherit (0) plus
/// explicit narrower splits (a small GEMM often wins single-threaded).
fn thread_splits(nthreads: usize) -> Vec<usize> {
    let mut v = vec![0];
    if nthreads > 1 {
        v.push(1);
        if nthreads > 2 {
            v.push(nthreads / 2);
        }
    }
    v
}

/// Benchmark gather-vs-direct staging of the quantized patch matrix for a
/// unit conv shape; `None` when the shape can't stage direct at all.
fn search_staging(unit: bool, rows: usize, k: usize, reps: usize) -> Option<(Staging, f64, f64)> {
    if !unit {
        return None;
    }
    let d = ConvDims::new(1, rows, 1, k, 1, 1, [1, 1], [0, 0]);
    let mut rng = Rng::new(0x57a6e ^ rows as u64 ^ ((k as u64) << 24));
    let x: Vec<f32> = (0..rows * k).map(|_| rng.range_f32(-0.2, 1.0)).collect();
    let mut cols = vec![0u8; rows * k];
    let gather = bench_ms(1, reps, || im2col_quant_u8(&x, &d, 0.1, 3, &mut cols)).median_ms;
    let direct = bench_ms(1, reps, || quantize_direct_u8(&x, 0.1, 3, &mut cols)).median_ms;
    if direct < gather {
        Some((Staging::Direct, gather, direct))
    } else {
        Some((Staging::Gather, gather, gather))
    }
}

/// `search_staging`'s f32 twin (the fp32 engine's only tunable today).
fn search_staging_f32(
    unit: bool,
    rows: usize,
    k: usize,
    reps: usize,
) -> Option<(Staging, f64, f64)> {
    if !unit {
        return None;
    }
    let d = ConvDims::new(1, rows, 1, k, 1, 1, [1, 1], [0, 0]);
    let mut rng = Rng::new(0xf32 ^ rows as u64 ^ ((k as u64) << 24));
    let x: Vec<f32> = (0..rows * k).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut cols = vec![0.0f32; rows * k];
    let gather = bench_ms(1, reps, || im2col_f32(&x, &d, &mut cols)).median_ms;
    let direct = bench_ms(1, reps, || stage_direct_f32(&x, &mut cols)).median_ms;
    if direct < gather {
        Some((Staging::Direct, gather, direct))
    } else {
        Some((Staging::Gather, gather, gather))
    }
}

/// Standalone bitserial geometry search for one GEMM shape — the hook the
/// fig benches use for their tuned-vs-default columns. Returns
/// `(winning desc, default median ms, tuned median ms)`; the winner is the
/// default itself when nothing beat it.
pub fn tune_bit_shape(
    isa: Isa,
    m: usize,
    n: usize,
    k: usize,
    budget: usize,
    reps: usize,
) -> Option<(UKernelDesc, f64, f64)> {
    let uk = ukernel::kernel_for(isa)?;
    let opts = TuneOpts { budget, reps, threads: 1 };
    let (sched, default_ms, tuned_ms) = search_bitserial(uk, m, n, k, &opts);
    Some((sched.desc_for(isa), default_ms, tuned_ms))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::tiny_test_graph;

    fn entry(m: usize, k: usize, n: usize, engine: &str, isa: Isa) -> TuneEntry {
        TuneEntry {
            op: "conv".to_string(),
            m,
            k,
            n,
            engine: engine.to_string(),
            isa,
            sched: Schedule {
                tile_m: 5,
                tile_n: 3,
                k_unroll: native_chunk(isa),
                threads: 0,
                staging: Staging::Gather,
            },
            gain: 1.5,
        }
    }

    #[test]
    fn lookup_prefers_exact_then_nearest_under_cutoff() {
        let mut db = TuningDb::new();
        db.upsert(entry(64, 288, 16, "bitserial", Isa::Scalar));
        db.upsert(entry(100, 300, 20, "bitserial", Isa::Scalar));
        let (e, m) = db.lookup("conv", 100, 300, 20, "bitserial", Isa::Scalar).unwrap();
        assert_eq!((e.m, m), (100, DbMatch::Exact));
        // near miss resolves to the closest shape
        let (e, m) = db.lookup("conv", 96, 290, 18, "bitserial", Isa::Scalar).unwrap();
        assert_eq!((e.m, m), (100, DbMatch::Nearest));
        // wrong engine/ISA never match
        assert!(db.lookup("conv", 100, 300, 20, "int8", Isa::Scalar).is_none());
        assert!(db.lookup("conv", 100, 300, 20, "bitserial", Isa::Neon).is_none());
        // far shapes fall off the cutoff
        assert!(db.lookup("conv", 100_000, 3, 9000, "bitserial", Isa::Scalar).is_none());
    }

    #[test]
    fn upsert_replaces_same_key() {
        let mut db = TuningDb::new();
        db.upsert(entry(8, 9, 4, "int8", Isa::Scalar));
        let mut e2 = entry(8, 9, 4, "int8", Isa::Scalar);
        e2.sched.tile_m = 7;
        db.upsert(e2);
        assert_eq!(db.entries.len(), 1);
        assert_eq!(db.entries[0].sched.tile_m, 7);
    }

    #[test]
    fn json_roundtrip_preserves_db() {
        let mut db = TuningDb::new();
        db.upsert(entry(784, 1152, 128, "bitserial", Isa::Scalar));
        db.upsert(TuneEntry {
            sched: Schedule {
                tile_m: 16,
                tile_n: 8,
                k_unroll: 2,
                threads: 2,
                staging: Staging::Direct,
            },
            ..entry(196, 2304, 256, "int8", Isa::Scalar)
        });
        let text = db.to_json().to_string();
        let back = TuningDb::from_json("test", &Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, db);
    }

    #[test]
    fn validation_rejects_garbage_records() {
        let base = entry(8, 9, 4, "bitserial", Isa::Scalar);
        let cases: Vec<(&str, Box<dyn Fn(&mut TuneEntry)>)> = vec![
            ("tile_m", Box::new(|e| e.sched.tile_m = 0)),
            ("tile_m", Box::new(|e| e.sched.tile_m = MAX_TILE_M + 1)),
            ("tile_n", Box::new(|e| e.sched.tile_n = 0)),
            ("tile_n", Box::new(|e| e.sched.tile_n = MAX_TILE_N + 1)),
            ("k_unroll", Box::new(|e| e.sched.k_unroll = 0)),
            ("k_unroll", Box::new(|e| e.sched.k_unroll = 64)),
            ("engine", Box::new(|e| e.engine = "cuda".to_string())),
            ("op", Box::new(|e| e.op = "dense".to_string())),
            ("shape", Box::new(|e| e.m = 0)),
            ("threads", Box::new(|e| e.sched.threads = 10_000)),
        ];
        for (what, mutate) in cases {
            let mut e = base.clone();
            mutate(&mut e);
            let err = validate_entry(&e).unwrap_err().to_string();
            assert!(err.contains(what), "{what}: diagnostic names the field: {err}");
        }
        // fp32 must never carry a thread override
        let mut e = base.clone();
        e.engine = "fp32".to_string();
        e.sched.threads = 2;
        let err = validate_entry(&e).unwrap_err().to_string();
        assert!(err.contains("fp32"), "{err}");
        // a k_unroll off the ISA's native chunk is rejected too
        let mut e = entry(8, 9, 4, "bitserial", Isa::Avx2);
        e.sched.k_unroll = 3;
        assert!(validate_entry(&e).is_err());
        validate_entry(&base).expect("baseline entry is valid");
    }

    #[test]
    fn from_json_rejects_bad_version_and_prefixes_label() {
        let err = TuningDb::from_json(
            "db.json",
            &Json::parse(r#"{"version": 99, "entries": []}"#).unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.starts_with("db.json:"), "{err}");
        assert!(err.contains("version 99"), "{err}");
        let bad = r#"{"version": 1, "entries": [{"op": "conv"}]}"#;
        let err = TuningDb::from_json("db.json", &Json::parse(bad).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("tuning entry 0"), "{err}");
    }

    #[test]
    fn synthetic_db_is_valid_and_covers_every_conv_engine() {
        let g = tiny_test_graph(true);
        let db = synthetic_db(&g, Isa::Scalar).unwrap();
        assert!(!db.is_empty());
        for e in &db.entries {
            validate_entry(e).expect("synthetic entries must pass the load gate");
        }
        for sh in conv_gemm_shapes(&g).unwrap() {
            for engine in ["bitserial", "int8", "fp32"] {
                let (e, m) = db
                    .lookup("conv", sh.rows, sh.k, sh.cout, engine, Isa::Scalar)
                    .expect("every conv shape has an entry");
                assert_eq!(m, DbMatch::Exact);
                assert_ne!(
                    e.sched,
                    Schedule::from_desc(&ukernel::kernel_for(Isa::Scalar).unwrap().desc),
                    "synthetic schedules are deliberately odd"
                );
            }
        }
    }

    #[test]
    fn tune_bit_shape_returns_measurements() {
        // tiny budget, tiny shape: this is the CI-smoke-sized search
        let (desc, default_ms, tuned_ms) = tune_bit_shape(Isa::Scalar, 16, 8, 64, 2, 1).unwrap();
        assert_eq!(desc.isa, Isa::Scalar);
        assert!(default_ms >= 0.0);
        // the winner is never slower than the default by construction
        assert!(tuned_ms <= default_ms);
    }
}

//! PJRT runtime: load + execute JAX-AOT HLO artifacts from the hot path.
//!
//! This is the "framework baseline" engine: the same model graphs the JAX
//! build path lowers (`make artifacts`) are compiled once by XLA's CPU
//! backend and then executed from Rust with zero Python involvement —
//! playing the role ONNX Runtime / TFLite play in the paper's comparisons,
//! and hosting the Pallas bitserial kernel graph for cross-layer parity.
//!
//! Wiring (see /opt/xla-example/load_hlo): HLO **text** is the interchange —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.
//!
//! Only compiled with the `pjrt` cargo feature: this module needs the `xla`
//! crate (not part of the offline vendored set — see `Cargo.toml`) and an
//! XLA toolchain on the host.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::dlrt::tensor::Tensor;
use crate::util::json::Json;

/// A compiled PJRT executable + its manifest (parameter order/shapes).
pub struct PjrtModel {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub input_shape: Vec<usize>,
    /// (name, shape) for params then state, in HLO parameter order.
    pub params: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<String>,
}

/// Thin wrapper around the PJRT CPU client with an executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<PjrtRuntime> {
        Ok(PjrtRuntime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load `<stem>.hlo.txt` (+ optional `<stem>.manifest.json`) and compile.
    pub fn load_hlo(&self, stem: &Path) -> Result<PjrtModel> {
        let hlo_path = with_suffix(stem, ".hlo.txt");
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .with_context(|| format!("parsing {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let manifest = read_manifest(&with_suffix(stem, ".manifest.json")).unwrap_or_default();
        Ok(PjrtModel {
            name: stem.file_name().map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
            manifest,
        })
    }
}

fn with_suffix(stem: &Path, suffix: &str) -> PathBuf {
    let mut s = stem.as_os_str().to_os_string();
    s.push(suffix);
    PathBuf::from(s)
}

fn read_manifest(path: &Path) -> Result<Manifest> {
    let v = Json::parse(&std::fs::read_to_string(path)?)?;
    let mut params = Vec::new();
    for section in ["params", "state"] {
        if let Some(arr) = v.opt(section) {
            for p in arr.arr()? {
                params.push((p.get("name")?.str()?.to_string(), p.get("shape")?.usize_vec()?));
            }
        }
    }
    Ok(Manifest {
        input_shape: v.opt("input_shape").map(|s| s.usize_vec()).transpose()?
            .unwrap_or_default(),
        params,
        outputs: v.opt("outputs")
            .map(|o| o.arr().map(|a| {
                a.iter().filter_map(|x| x.str().ok().map(String::from)).collect()
            }))
            .transpose()?
            .unwrap_or_default(),
    })
}

impl PjrtModel {
    /// Execute with f32 inputs; returns all tuple outputs as [`Tensor`]s.
    pub fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        decompose_tuple(result)
    }

    /// Execute with i32 inputs (the bitserial kernel artifact signature).
    pub fn run_i32(&self, inputs: &[(Vec<i32>, Vec<usize>)]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(data).reshape(&dims)?)
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        decompose_tuple(result)
    }
}

fn decompose_tuple(mut result: xla::Literal) -> Result<Vec<Tensor>> {
    let parts = result.decompose_tuple()?;
    let parts = if parts.is_empty() { vec![result] } else { parts };
    parts
        .into_iter()
        .map(|lit| {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data: Vec<f32> = match shape.ty() {
                xla::ElementType::F32 => lit.to_vec::<f32>()?,
                xla::ElementType::S32 => {
                    lit.to_vec::<i32>()?.into_iter().map(|v| v as f32).collect()
                }
                other => bail!("unsupported output element type {other:?}"),
            };
            Tensor::new(dims, data)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    //! Needs `make artifacts`; tests skip (with a notice) when absent.
    use super::*;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if dir.join("bitserial_gemm_m64k64n32_1a2w.hlo.txt").exists() {
            Some(dir)
        } else {
            eprintln!("skipping PJRT test: run `make artifacts` first");
            None
        }
    }

    #[test]
    fn pallas_bitserial_kernel_matches_native_engine() {
        let Some(dir) = artifacts() else { return };
        let rt = PjrtRuntime::cpu().unwrap();
        let model = rt.load_hlo(&dir.join("bitserial_gemm_m64k64n32_1a2w")).unwrap();
        let (m, k, n) = (64usize, 64usize, 32usize);
        let mut rng = crate::util::rng::Rng::new(99);
        let a: Vec<i32> = (0..m * k).map(|_| rng.range(0, 2) as i32).collect();
        let w: Vec<i32> = (0..n * k).map(|_| rng.range(-2, 2) as i32).collect();
        let outs = model
            .run_i32(&[(a.clone(), vec![m, k]), (w.clone(), vec![n, k])])
            .unwrap();
        assert_eq!(outs[0].shape, vec![m, n]);
        // native bitserial on the same codes
        let a8: Vec<u8> = a.iter().map(|&v| v as u8).collect();
        let ap = crate::kernels::bitserial::pack_rows_u8(&a8, m, k, 1);
        let wp = crate::kernels::bitserial::pack_weights_offset(&w, n, k, 2);
        let mut want = vec![0i32; m * n];
        crate::kernels::bitserial::gemm_bitserial(&ap, &wp, 2, &mut want, 1);
        let got: Vec<i32> = outs[0].data.iter().map(|&v| v as i32).collect();
        assert_eq!(got, want, "Pallas (via PJRT) != native bitserial");
    }
}

//! The "Deeplite Compiler" stage: arch.json + weights.bin → [`CompiledModel`].
//!
//! Responsibilities (paper §VI, Fig. 3):
//! 1. parse the interchange exported by the JAX build path,
//! 2. pick an engine per conv from its [`QCfg`] (mixed precision) or a
//!    forced [`EngineChoice`] (to build the FP32 / INT8 baselines from the
//!    same checkpoint),
//! 3. quantize + bitplane-pack weights,
//! 4. (optionally) serialize to a deployable `.dlrt` file — see
//!    [`crate::dlrt::format`].

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::dlrt::graph::{Graph, Node, NodeWeights, Op, QCfg};
use crate::exec::{CompiledConv, CompiledDense, CompiledModel, ConvKernel};
use crate::kernels::ukernel::{self, Isa, PackedW, WLayout};
use crate::quant;
use crate::util::json::Json;

/// Engine selection policy for a whole model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineChoice {
    /// Per-layer from QCfg: quantized layers → bitserial, FP32 layers → fp32.
    Auto,
    /// All convs on the FP32 engine (the paper's FP32 baselines).
    ForceFp32,
    /// All convs on the INT8 engine (the TFLite/ORT-INT8 baselines).
    ForceInt8,
}

impl EngineChoice {
    pub fn parse(s: &str) -> Result<EngineChoice> {
        Ok(match s {
            "auto" | "bitserial" => EngineChoice::Auto,
            "fp32" => EngineChoice::ForceFp32,
            "int8" => EngineChoice::ForceInt8,
            _ => bail!("unknown engine {s:?} (auto|fp32|int8)"),
        })
    }
}

/// Default activation scale for INT8 when a layer carries no QAT scale:
/// activations in our graphs are post-ReLU/SiLU features normalized by BN;
/// a [0, 6] range (ReLU6 convention) is the standard PTQ assumption.
const DEFAULT_INT8_ACT_MAX: f32 = 6.0;

/// Parse `arch.json` + `weights.bin` from a model directory.
pub fn load_arch(dir: &Path) -> Result<Graph> {
    let arch_text = std::fs::read_to_string(dir.join("arch.json"))
        .with_context(|| format!("reading {}", dir.join("arch.json").display()))?;
    let weights = read_f32_bin(&dir.join("weights.bin"))?;
    parse_arch(&arch_text, &weights)
}

pub fn read_f32_bin(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length not a multiple of 4", path.display());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

fn slice_ref<'a>(weights: &'a [f32], v: &Json) -> Result<&'a [f32]> {
    let off = v.get("offset")?.usize()?;
    let len = v.get("len")?.usize()?;
    weights
        .get(off..off + len)
        .ok_or_else(|| anyhow!("weight ref {off}+{len} out of range ({})", weights.len()))
}

/// Parse the JSON interchange into a weighted [`Graph`].
pub fn parse_arch(arch_text: &str, weights: &[f32]) -> Result<Graph> {
    let v = Json::parse(arch_text)?;
    let input = v.get("input")?;
    let shape = input.get("shape")?.usize_vec()?;
    if shape.len() != 4 {
        bail!("input must be NHWC, got {shape:?}");
    }
    let mut g = Graph {
        name: v.get("name")?.str()?.to_string(),
        input_name: input.get("name")?.str()?.to_string(),
        input_shape: [shape[0], shape[1], shape[2], shape[3]],
        nodes: Vec::new(),
        outputs: v.get("outputs")?.arr()?.iter().map(|o| Ok(o.str()?.to_string()))
            .collect::<Result<_>>()?,
        weights: Default::default(),
    };
    for jn in v.get("nodes")?.arr()? {
        let op_name = jn.get("op")?.str()?;
        let name = jn.get("name")?.str()?.to_string();
        let inputs: Vec<String> = jn.get("inputs")?.arr()?.iter()
            .map(|i| Ok(i.str()?.to_string())).collect::<Result<_>>()?;
        let output = jn.get("output")?.str()?.to_string();
        let pair = |key: &str| -> Result<[usize; 2]> {
            let v = jn.get(key)?.usize_vec()?;
            Ok([v[0], v[1]])
        };
        let op = match op_name {
            "conv2d" => {
                let qj = jn.get("qcfg")?;
                let qcfg = if qj.get("enabled")?.bool()? {
                    QCfg::new(qj.get("a_bits")?.usize()? as u8,
                              qj.get("w_bits")?.usize()? as u8)
                } else {
                    QCfg::FP32
                };
                let nw = NodeWeights {
                    w: slice_ref(weights, jn.get("w")?)?.to_vec(),
                    scale: slice_ref(weights, jn.get("scale")?)?.to_vec(),
                    bias: slice_ref(weights, jn.get("bias")?)?.to_vec(),
                    s_w: jn.opt("s_w").map(|v| v.f32()).transpose()?.unwrap_or(0.0),
                    s_a: jn.opt("s_a").map(|v| v.f32()).transpose()?.unwrap_or(0.0),
                };
                g.weights.insert(name.clone(), nw);
                Op::Conv2d {
                    stride: pair("stride")?,
                    padding: pair("padding")?,
                    kernel: pair("kernel")?,
                    cin: jn.get("cin")?.usize()?,
                    cout: jn.get("cout")?.usize()?,
                    qcfg,
                }
            }
            "dense" => {
                let nw = NodeWeights {
                    w: slice_ref(weights, jn.get("w")?)?.to_vec(),
                    scale: Vec::new(),
                    bias: slice_ref(weights, jn.get("b")?)?.to_vec(),
                    s_w: 0.0,
                    s_a: 0.0,
                };
                g.weights.insert(name.clone(), nw);
                Op::Dense { cin: jn.get("cin")?.usize()?, cout: jn.get("cout")?.usize()? }
            }
            "maxpool2d" => Op::MaxPool2d {
                kernel: pair("kernel")?,
                stride: pair("stride")?,
                padding: pair("padding")?,
            },
            "global_avg_pool" => Op::GlobalAvgPool,
            "add" => Op::Add,
            "concat" => Op::Concat,
            "upsample2x" => Op::Upsample2x,
            "relu" => Op::Relu,
            "relu6" => Op::Relu6,
            "silu" => Op::Silu,
            "leaky_relu" => Op::LeakyRelu,
            "sigmoid" => Op::Sigmoid,
            "flatten" => Op::Flatten,
            other => bail!("unknown op {other:?}"),
        };
        g.nodes.push(Node { op, name, inputs, output });
    }
    g.validate()?;
    Ok(g)
}

/// Compile a weighted graph into an executable model for the process's
/// selected micro-kernel ISA (`DLRT_FORCE_ISA` or the best the host
/// supports). Per-layer kernels plus the execution plan lowered by the
/// planner pass pipeline (see [`crate::exec::planner`]). Static shape
/// mismatches are compile errors.
pub fn compile_graph(g: &Graph, engine: EngineChoice) -> Result<CompiledModel> {
    let isa = ukernel::selected_isa().map_err(anyhow::Error::msg)?;
    compile_graph_tuned(g, engine, isa, crate::tune::ambient_db())
}

/// [`compile_graph`] pinned to an explicit micro-kernel ISA: bitserial
/// weights are prepacked into that kernel's tile-walk layout and the choice
/// is recorded on the model. Errors when this host cannot run `isa` (tests
/// sweep [`ukernel::available_isas`]).
pub fn compile_graph_for_isa(g: &Graph, engine: EngineChoice, isa: Isa) -> Result<CompiledModel> {
    compile_graph_tuned(g, engine, isa, crate::tune::ambient_db())
}

/// [`compile_graph_for_isa`] with an explicit tuning DB (`dlrt tune`
/// winners). Per conv the DB is consulted by (op, GEMM shape, engine, ISA):
/// exact-shape hit first, then nearest-shape within the log-distance
/// cutoff, else the kernel's static defaults. A matched schedule is
/// recorded on the [`CompiledConv`] and its bitserial weights are prepacked
/// in the *tuned* tile order, so the serving path never repacks. A DB with
/// no entries for `isa` (e.g. tuned on another machine, or `DLRT_FORCE_ISA`
/// overriding the tuned target) degrades to defaults with a note — never an
/// error.
pub fn compile_graph_tuned(
    g: &Graph,
    engine: EngineChoice,
    isa: Isa,
    db: Option<&crate::tune::TuningDb>,
) -> Result<CompiledModel> {
    let uk = ukernel::kernel_for(isa)
        .ok_or_else(|| anyhow!("ISA '{}' is not available on this host", isa.name()))?;
    let layout = uk.weight_layout();
    let db = db.filter(|d| !d.is_empty());
    if let Some(d) = db {
        if !d.has_isa(isa) {
            eprintln!("note: tuning DB has no entries for ISA '{}'; \
                       compiling with static kernel defaults", isa.name());
        }
    }
    // GEMM shapes for tuning lookups (only materialized when a DB is live)
    let gemm_shapes = match db {
        Some(_) => crate::exec::planner::conv_gemm_shapes(g)?,
        None => Vec::new(),
    };
    let mut convs = Vec::new();
    let mut denses = Vec::new();
    for node in &g.nodes {
        match &node.op {
            Op::Conv2d { kernel, cin, cout, qcfg, .. } => {
                let nw = g
                    .weights
                    .get(&node.name)
                    .ok_or_else(|| anyhow!("{}: missing weights", node.name))?;
                let k = kernel[0] * kernel[1] * cin;
                if nw.w.len() != k * cout {
                    bail!("{}: weight size {} != {}", node.name, nw.w.len(), k * cout);
                }
                let sched = db.and_then(|d| {
                    let sh = gemm_shapes.iter().find(|s| s.name == node.name)?;
                    let label = match (engine, qcfg.enabled) {
                        (EngineChoice::Auto, true) => "bitserial",
                        (EngineChoice::Auto, false) | (EngineChoice::ForceFp32, _) => "fp32",
                        (EngineChoice::ForceInt8, _) => "int8",
                    };
                    let (e, _) = d.lookup("conv", sh.rows, sh.k, sh.cout, label, isa)?;
                    Some(e.sched)
                });
                // a tuned schedule owns the prepack tile order for its conv
                let conv_layout = match &sched {
                    Some(s) => uk.weight_layout_for(&s.desc_for(isa)),
                    None => layout,
                };
                let compiled = compile_conv(&node.name, nw, k, *cout, kernel, *cin, *qcfg,
                                            engine, conv_layout, sched)?;
                convs.push(compiled);
            }
            Op::Dense { cin, cout } => {
                let nw = g.weights.get(&node.name)
                    .ok_or_else(|| anyhow!("{}: missing weights", node.name))?;
                if nw.w.len() != cin * cout {
                    bail!("{}: dense weight size mismatch", node.name);
                }
                denses.push(CompiledDense {
                    name: node.name.clone(),
                    w: nw.w.clone(),
                    b: nw.bias.clone(),
                });
            }
            _ => {}
        }
    }
    CompiledModel::new(g.clone(), convs, denses, isa)
}

#[allow(clippy::too_many_arguments)]
fn compile_conv(
    name: &str,
    nw: &NodeWeights,
    k: usize,
    cout: usize,
    kernel: &[usize; 2],
    cin: usize,
    qcfg: QCfg,
    engine: EngineChoice,
    layout: WLayout,
    sched: Option<crate::tune::Schedule>,
) -> Result<CompiledConv> {
    let kernel = match (engine, qcfg.enabled) {
        (EngineChoice::Auto, true) => {
            // QAT scales if provided, else PTQ min/max (paper §IV static PTQ)
            let s_w = if nw.s_w > 0.0 {
                nw.s_w
            } else {
                quant::calibrate_mse_signed(&nw.w, qcfg.w_bits, 40)
            };
            let s_a = if nw.s_a > 0.0 { nw.s_a } else { 0.1 };
            let packed =
                quant::pack_conv_weights(&nw.w, kernel[0], kernel[1], cin, cout, s_w,
                                         qcfg.w_bits);
            // prepack the bit-planes into the selected kernel's tile-walk
            // order once, at compile time — never on the serving path
            ConvKernel::Bitserial {
                packed: PackedW::from_packed(&packed, layout),
                s_w,
                s_a,
                w_bits: qcfg.w_bits,
                a_bits: qcfg.a_bits,
            }
        }
        (EngineChoice::Auto, false) | (EngineChoice::ForceFp32, _) => {
            ConvKernel::Fp32 { wt: quant::transpose_conv_weights(&nw.w, k, cout) }
        }
        (EngineChoice::ForceInt8, _) => {
            let wt = quant::transpose_conv_weights(&nw.w, k, cout);
            let (codes, s_w) = crate::kernels::int8::quantize_weights_i8(&wt);
            // activation scale: reuse the QAT range if known, else assume
            // the standard [0, 6] post-activation range
            let (qp_a, _) = crate::dlrt::graph::qp_qn(qcfg.a_bits.max(1), false);
            let a_max = if qcfg.enabled && nw.s_a > 0.0 {
                nw.s_a * qp_a as f32
            } else {
                DEFAULT_INT8_ACT_MAX
            };
            ConvKernel::Int8 { codes, s_w, s_a: a_max / 255.0 }
        }
    };
    Ok(CompiledConv {
        name: name.to_string(),
        kernel,
        scale: nw.scale.clone(),
        bias: nw.bias.clone(),
        sched,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::tiny_test_graph;

    #[test]
    fn engine_choice_parsing() {
        assert_eq!(EngineChoice::parse("auto").unwrap(), EngineChoice::Auto);
        assert_eq!(EngineChoice::parse("fp32").unwrap(), EngineChoice::ForceFp32);
        assert_eq!(EngineChoice::parse("int8").unwrap(), EngineChoice::ForceInt8);
        assert!(EngineChoice::parse("gpu").is_err());
    }

    #[test]
    fn auto_respects_mixed_precision() {
        let g = tiny_test_graph(true); // conv1 fp32, conv2+conv3 2A2W
        let m = compile_graph(&g, EngineChoice::Auto).unwrap();
        let summary = m.engine_summary();
        assert_eq!(summary.get("bitserial"), Some(&2));
        assert_eq!(summary.get("fp32"), Some(&1));
    }

    #[test]
    fn forced_engines_cover_all_convs() {
        let g = tiny_test_graph(true);
        let m8 = compile_graph(&g, EngineChoice::ForceInt8).unwrap();
        assert_eq!(m8.engine_summary().get("int8"), Some(&3));
        let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
        assert_eq!(mf.engine_summary().get("fp32"), Some(&3));
    }

    #[test]
    fn compiled_model_carries_a_lowered_plan() {
        let g = tiny_test_graph(true);
        let m = compile_graph(&g, EngineChoice::Auto).unwrap();
        // conv+relu pairs fused, flatten-free: 6 nodes lower to 4 instrs
        assert_eq!(m.plan.instrs.len(), 4);
        assert_eq!(m.plan.fused_instrs(), 2);
        assert!(m.plan.arena_elems(1) > 0);
    }

    #[test]
    fn per_isa_compilation_prepacks_to_the_kernel_layout() {
        let g = tiny_test_graph(true);
        for isa in ukernel::available_isas() {
            let m = compile_graph_for_isa(&g, EngineChoice::Auto, isa).unwrap();
            assert_eq!(m.isa, isa);
            let uk = ukernel::kernel_for(isa).unwrap();
            for c in &m.convs {
                // schedule-aware: a DLRT_TUNE_DB in the environment attaches
                // tuned schedules, which own their conv's prepack tile order
                let want = match &c.sched {
                    Some(s) => uk.weight_layout_for(&s.desc_for(isa)),
                    None => uk.weight_layout(),
                };
                if let ConvKernel::Bitserial { packed, .. } = &c.kernel {
                    assert_eq!(packed.layout, want, "{} on {}", c.name, isa.name());
                }
            }
        }
    }

    #[test]
    fn tuned_compilation_attaches_schedules_and_prepacks_their_layout() {
        let g = tiny_test_graph(true);
        for isa in ukernel::available_isas() {
            let db = crate::tune::synthetic_db(&g, isa).unwrap();
            let uk = ukernel::kernel_for(isa).unwrap();
            let m = compile_graph_tuned(&g, EngineChoice::Auto, isa, Some(&db)).unwrap();
            for c in &m.convs {
                let s = c.sched.expect("synthetic DB covers every conv/engine");
                if let ConvKernel::Bitserial { packed, .. } = &c.kernel {
                    assert_eq!(packed.layout, uk.weight_layout_for(&s.desc_for(isa)),
                               "{} on {}", c.name, isa.name());
                }
            }
            // a DB tuned only for a different ISA must fall back to defaults
            let other = ukernel::available_isas().into_iter().find(|i| *i != isa);
            if let Some(other) = other {
                let m2 = compile_graph_tuned(&g, EngineChoice::Auto, other, Some(&db)).unwrap();
                assert!(m2.convs.iter().all(|c| c.sched.is_none()),
                        "DB for {} must not schedule {}", isa.name(), other.name());
            }
        }
    }

    #[test]
    fn bitserial_compresses_storage() {
        let g = tiny_test_graph(true);
        let mq = compile_graph(&g, EngineChoice::Auto).unwrap();
        let mf = compile_graph(&g, EngineChoice::ForceFp32).unwrap();
        assert!(mq.weight_bytes() < mf.weight_bytes());
    }

    #[test]
    fn parse_arch_roundtrip_via_exported_file() {
        // exercise the real exported interchange when artifacts exist
        let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"),
                                               "/artifacts/models/resnet18_mini"));
        if !dir.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let g = load_arch(dir).unwrap();
        assert_eq!(g.name, "resnet18");
        assert_eq!(g.input_shape, [1, 64, 64, 3]);
        assert_eq!(g.conv_nodes().count(), 20);
        let m = compile_graph(&g, EngineChoice::Auto).unwrap();
        // mixed precision: stem fp32, the rest bitserial
        assert_eq!(m.engine_summary().get("fp32"), Some(&1));
        assert_eq!(m.engine_summary().get("bitserial"), Some(&19));
    }

    #[test]
    fn parse_arch_rejects_bad_refs() {
        let arch = r#"{"name":"x","input":{"name":"input","shape":[1,4,4,1]},
            "outputs":["c.out"],
            "nodes":[{"op":"conv2d","name":"c","inputs":["input"],"output":"c.out",
              "stride":[1,1],"padding":[0,0],"kernel":[1,1],"cin":1,"cout":1,
              "qcfg":{"w_bits":2,"a_bits":2,"enabled":false},
              "w":{"offset":0,"len":9},"scale":{"offset":0,"len":1},
              "bias":{"offset":0,"len":1}}]}"#;
        assert!(parse_arch(arch, &[0.0; 4]).is_err()); // ref past end
    }
}

//! Bitserial convolution engine — the paper's core contribution (§V).
//!
//! Dot products between w-bit weights and a-bit activations are computed
//! over bitplanes packed 64 lanes per `u64` word:
//!
//! ```text
//!   W · A = Σᵢ Σⱼ POPCOUNT(W[i] & A[j]) << (i + j)
//! ```
//!
//! Signed weights use the offset encoding `w' = w + Q_N ∈ [0, 2^w)`; the
//! correction `− Q_N · Σ a` is applied once per activation row (its Σa is
//! itself computed from the packed planes with popcounts).
//!
//! The Neon mapping of the paper (VAND + VCNT + VPADAL) becomes `&` +
//! `u64::count_ones()` + scalar adds, which LLVM lowers to `pand`/`popcnt`
//! on x86-64 — the same abstract bit-op machine, so the FP32:bitserial
//! *ratio* transfers (DESIGN.md §2). Tiling follows the paper: activations
//! rows are the parallel/outer dimension, output channels the middle loop,
//! packed words the inner loop.

use crate::dlrt::graph::qp_qn;
use crate::dlrt::tensor::Packed;
use crate::util::threads;

/// Max bitplanes per side the packing paths support (codes are `u8`).
pub const MAX_BITS: usize = 8;

/// Pack unsigned activation codes (`u8`, values < 2^bits) row-major.
pub fn pack_rows_u8(codes: &[u8], rows: usize, k: usize, bits: usize) -> Packed {
    let mut p = Packed::new_zeroed(rows, k, bits);
    fill_packed(codes, &mut p);
    p
}

/// In-place variant of [`pack_rows_u8`]: reshapes `p` and repacks, reusing
/// its plane buffer — zero heap allocation once the buffer has grown to the
/// largest layer (the executor's steady-state path).
pub fn pack_rows_u8_into(codes: &[u8], rows: usize, k: usize, bits: usize, p: &mut Packed) {
    let wpr = Packed::words_for(k);
    p.rows = rows;
    p.k = k;
    p.bits = bits;
    p.words_per_row = wpr;
    p.data.clear();
    p.data.resize(rows * bits * wpr, 0);
    fill_packed(codes, p);
}

fn fill_packed(codes: &[u8], p: &mut Packed) {
    let (rows, k, bits) = (p.rows, p.k, p.bits);
    assert!(
        (1..=MAX_BITS).contains(&bits),
        "bits={bits} outside supported 1..={MAX_BITS} range"
    );
    debug_assert_eq!(codes.len(), rows * k);
    let wpr = p.words_per_row;
    for r in 0..rows {
        let src = &codes[r * k..(r + 1) * k];
        let base = r * bits * wpr;
        for (jw, chunk) in src.chunks(64).enumerate() {
            // branchless bit-scatter: plane i collects bit i of every code
            let mut words = [0u64; MAX_BITS];
            match bits {
                1 => {
                    let mut w0 = 0u64;
                    for (lane, &v) in chunk.iter().enumerate() {
                        w0 |= ((v & 1) as u64) << lane;
                    }
                    words[0] = w0;
                }
                2 => {
                    let (mut w0, mut w1) = (0u64, 0u64);
                    for (lane, &v) in chunk.iter().enumerate() {
                        w0 |= ((v & 1) as u64) << lane;
                        w1 |= (((v >> 1) & 1) as u64) << lane;
                    }
                    words[0] = w0;
                    words[1] = w1;
                }
                _ => {
                    for (lane, &v) in chunk.iter().enumerate() {
                        for (i, w) in words.iter_mut().enumerate().take(bits) {
                            *w |= (((v >> i) & 1) as u64) << lane;
                        }
                    }
                }
            }
            for (i, &w) in words.iter().enumerate().take(bits) {
                p.data[base + i * wpr + jw] = w;
            }
        }
    }
}

/// Pack signed weight codes (`[-Q_N, Q_P]`) with the offset encoding.
/// Weight layout: rows = output channels, k = kh*kw*cin patch.
pub fn pack_weights_offset(wq: &[i32], rows: usize, k: usize, bits: usize) -> Packed {
    let (_, qn) = qp_qn(bits as u8, true);
    let codes: Vec<u8> = wq
        .iter()
        .map(|&v| {
            let u = v + qn;
            debug_assert!((0..(1 << bits)).contains(&u), "weight code {v} out of range");
            u as u8
        })
        .collect();
    pack_rows_u8(&codes, rows, k, bits)
}

/// Σ over codes of one packed row (from its planes): Σⱼ popcount(plane j)<<j.
#[inline]
pub fn row_code_sum(p: &Packed, row: usize) -> i32 {
    let mut s = 0u32;
    for i in 0..p.bits {
        let pc: u32 = p.row_plane(row, i).iter().map(|w| w.count_ones()).sum();
        s += pc << i;
    }
    s as i32
}

/// Default M (activation-row) tile of the blocked bitserial GEMM. One M-tile
/// of packed activation planes stays L1-resident while the kernel walks the
/// weight blocks — the paper's q-register amortization, at cache scale.
/// Read by `costmodel` and swept by `benches/ablation_tiling.rs`.
pub const TILE_M: usize = 32;
/// Default N (output-channel) tile: this many packed weight rows stay
/// resident across a whole M-tile.
pub const TILE_N: usize = 16;
/// Upper bound on the M tile (sizes the stack-resident correction buffer).
pub const MAX_TILE_M: usize = 128;

/// Bitserial GEMM: `out[m][n] = Σ_k a[m][k] * (w[n][k] signed)` in i32.
///
/// `a`: packed unsigned activations (M rows), `w`: packed offset-encoded
/// weights (N rows), `w_bits_signed`: the signed bit width (for Q_N).
/// Cache-tiled with the default [`TILE_M`]×[`TILE_N`] blocking.
pub fn gemm_bitserial(
    a: &Packed,
    w: &Packed,
    w_bits_signed: usize,
    out: &mut [i32],
    nthreads: usize,
) {
    gemm_bitserial_tiled(a, w, w_bits_signed, out, nthreads, TILE_M, TILE_N)
}

/// [`gemm_bitserial`] with explicit M×N tile sizes (the ablation bench
/// sweeps these; `tile_m` is clamped to [`MAX_TILE_M`]).
///
/// M rows are split into disjoint `&mut` row chunks across the worker pool
/// (no aliased writes); within a chunk the loop nest is
/// `m-tile → n-tile → row → channel`, so a block of `tile_n` packed weight
/// rows is reused by every row of the M-tile while both stay cache-hot.
/// All arithmetic is exact integer, so tiling cannot change results.
pub fn gemm_bitserial_tiled(
    a: &Packed,
    w: &Packed,
    w_bits_signed: usize,
    out: &mut [i32],
    nthreads: usize,
    tile_m: usize,
    tile_n: usize,
) {
    assert_eq!(a.k, w.k, "reduction dim mismatch");
    assert_eq!(a.words_per_row, w.words_per_row);
    let (m, n) = (a.rows, w.rows);
    assert_eq!(out.len(), m * n);
    let (_, qn) = qp_qn(w_bits_signed as u8, true);
    if m == 0 || n == 0 {
        return;
    }
    let tile_m = tile_m.clamp(1, MAX_TILE_M);
    let tile_n = tile_n.max(1);

    threads::par_chunks_rows(out, n, nthreads, |row0, chunk| {
        let rows = chunk.len() / n;
        // per-row signed-offset corrections for the current M-tile
        let mut corr = [0i32; MAX_TILE_M];
        let mut mt = 0;
        while mt < rows {
            let mt_end = (mt + tile_m).min(rows);
            for (c, mi) in corr.iter_mut().zip(mt..mt_end) {
                *c = qn * row_code_sum(a, row0 + mi);
            }
            let mut nt = 0;
            while nt < n {
                let nt_end = (nt + tile_n).min(n);
                for mi in mt..mt_end {
                    let c = corr[mi - mt];
                    let orow = &mut chunk[mi * n + nt..mi * n + nt_end];
                    for (j, o) in orow.iter_mut().enumerate() {
                        *o = dot_planes(a, row0 + mi, w, nt + j) - c;
                    }
                }
                nt = nt_end;
            }
            mt = mt_end;
        }
    });
}

/// One bitserial dot product between packed row `mi` of `a` and `ni` of `w`.
///
/// Specialized fast paths for the common ultra-low-bit cases (the paper's
/// 1A1W / 1A2W / 2A2W configs) walk both rows word-major in a single pass,
/// loading each activation/weight word once and touching all plane pairs —
/// the same load-amortization the paper's Neon kernels get from keeping
/// plane vectors resident in q-registers.
#[inline]
fn dot_planes(a: &Packed, mi: usize, w: &Packed, ni: usize) -> i32 {
    let nwords = a.words_per_row;
    let abase = mi * a.bits * nwords;
    let wbase = ni * w.bits * nwords;
    let adata = &a.data[abase..abase + a.bits * nwords];
    let wdata = &w.data[wbase..wbase + w.bits * nwords];
    dot_planes_raw(adata, a.bits, wdata, w.bits, nwords, nwords)
}

/// The same dot product over raw plane slices: `adata` holds `a_bits` planes
/// of `nwords` words each, `wdata` holds `w_bits` planes spaced
/// `w_plane_stride` words apart (`>= nwords`; padding words beyond `nwords`
/// are ignored). This is the portable micro-kernel shared by the row-major
/// [`Packed`] path above and the `ukernel` registry's prepacked layouts.
#[inline]
pub(crate) fn dot_planes_raw(
    adata: &[u64],
    a_bits: usize,
    wdata: &[u64],
    w_bits: usize,
    nwords: usize,
    w_plane_stride: usize,
) -> i32 {
    debug_assert!(w_plane_stride >= nwords);
    match (a_bits, w_bits) {
        (1, 1) => {
            let mut pc: u32 = 0;
            for (x, y) in adata[..nwords].iter().zip(&wdata[..nwords]) {
                pc += (x & y).count_ones();
            }
            pc as i32
        }
        (1, 2) => {
            let a0 = &adata[..nwords];
            let (w0, w1) = (&wdata[..nwords], &wdata[w_plane_stride..][..nwords]);
            let (mut p0, mut p1) = (0u32, 0u32);
            for i in 0..nwords {
                let x = a0[i];
                p0 += (x & w0[i]).count_ones();
                p1 += (x & w1[i]).count_ones();
            }
            (p0 + (p1 << 1)) as i32
        }
        (2, 2) => {
            let (a0, a1) = (&adata[..nwords], &adata[nwords..][..nwords]);
            let (w0, w1) = (&wdata[..nwords], &wdata[w_plane_stride..][..nwords]);
            // shift-bucket accumulators (out = s0 + 2*s1 + 4*s2), two
            // independent chains per bucket so the popcnt unit pipelines
            let mut s = [0u32; 8];
            let mut i = 0;
            while i + 2 <= nwords {
                let (x0, x1, y0, y1) = (a0[i], a1[i], w0[i], w1[i]);
                s[0] += (x0 & y0).count_ones();
                s[1] += (x1 & y0).count_ones();
                s[2] += (x0 & y1).count_ones();
                s[3] += (x1 & y1).count_ones();
                let (x0, x1, y0, y1) = (a0[i + 1], a1[i + 1], w0[i + 1], w1[i + 1]);
                s[4] += (x0 & y0).count_ones();
                s[5] += (x1 & y0).count_ones();
                s[6] += (x0 & y1).count_ones();
                s[7] += (x1 & y1).count_ones();
                i += 2;
            }
            if i < nwords {
                let (x0, x1, y0, y1) = (a0[i], a1[i], w0[i], w1[i]);
                s[0] += (x0 & y0).count_ones();
                s[1] += (x1 & y0).count_ones();
                s[2] += (x0 & y1).count_ones();
                s[3] += (x1 & y1).count_ones();
            }
            ((s[0] + s[4]) + ((s[1] + s[2] + s[5] + s[6]) << 1) + ((s[3] + s[7]) << 2))
                as i32
        }
        _ => {
            // generic multi-bit path
            let mut acc: u32 = 0;
            for i in 0..w_bits {
                let wp = &wdata[i * w_plane_stride..][..nwords];
                for j in 0..a_bits {
                    let ap = &adata[j * nwords..(j + 1) * nwords];
                    let mut pc: u32 = 0;
                    for (x, y) in ap.iter().zip(wp) {
                        pc += (x & y).count_ones();
                    }
                    acc += pc << (i + j);
                }
            }
            acc as i32
        }
    }
}

/// Dequantize a bitserial GEMM result into f32 with per-channel folded-BN
/// scale/bias: `out = (acc * s_a*s_w) * scale[c] + bias[c]`.
/// Op order matches `python/compile/jax_exec.py::_conv_deploy` exactly so
/// parity goldens are bit-identical.
pub fn dequant_scale_bias(
    acc: &[i32],
    cout: usize,
    s_aw: f32,
    scale: &[f32],
    bias: &[f32],
    out: &mut [f32],
) {
    dequant_scale_bias_act(acc, cout, s_aw, scale, bias, None, out);
}

/// [`dequant_scale_bias`] with an optional fused activation epilogue: the
/// activation is applied to each dequantized value in the same pass over
/// the accumulator, so a fused Conv2d+activation never materializes the
/// pre-activation tensor. The scalar activation performs the identical
/// float ops as the standalone elementwise pass, keeping fusion bit-exact.
pub fn dequant_scale_bias_act(
    acc: &[i32],
    cout: usize,
    s_aw: f32,
    scale: &[f32],
    bias: &[f32],
    act: Option<crate::kernels::elementwise::ActKind>,
    out: &mut [f32],
) {
    debug_assert_eq!(acc.len(), out.len());
    match act {
        None => {
            for (row_a, row_o) in acc.chunks(cout).zip(out.chunks_mut(cout)) {
                for c in 0..cout {
                    row_o[c] = (row_a[c] as f32 * s_aw) * scale[c] + bias[c];
                }
            }
        }
        Some(a) => {
            for (row_a, row_o) in acc.chunks(cout).zip(out.chunks_mut(cout)) {
                for c in 0..cout {
                    row_o[c] = a.apply_scalar((row_a[c] as f32 * s_aw) * scale[c] + bias[c]);
                }
            }
        }
    }
}

/// The general conv epilogue for the quantized engines (bitserial *and*
/// int8 share it): one pass over the i32 accumulator performing, in order,
/// dequant → per-channel scale/bias → optional pre-add activation →
/// optional **two-accumulator residual add** (`+ res[i]`, the planner's
/// Add/residual fusion) → optional post-add activation — written either
/// densely or into a channel stripe of a wider output row
/// (`out_stride`/`out_off`, the planner's concat-in-place lowering; pass
/// `out_stride == cout`, `out_off == 0` for a dense output).
///
/// Every float op matches the unfused sequence
/// `dequant_scale_bias → act → elementwise add → act` exactly, so fusion
/// stays bit-identical to the reference interpreter.
#[allow(clippy::too_many_arguments)]
pub fn dequant_scale_bias_add_act(
    acc: &[i32],
    cout: usize,
    s_aw: f32,
    scale: &[f32],
    bias: &[f32],
    act: Option<crate::kernels::elementwise::ActKind>,
    res: Option<&[f32]>,
    post: Option<crate::kernels::elementwise::ActKind>,
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
) {
    debug_assert!(out_off + cout <= out_stride);
    debug_assert_eq!(acc.len() % cout, 0);
    let rows = acc.len() / cout;
    debug_assert!(res.map(|r| r.len() == rows * cout).unwrap_or(true));
    debug_assert!(out.len() >= rows.saturating_sub(1) * out_stride + out_off + cout);
    for (r, row_a) in acc.chunks(cout).enumerate() {
        let row_o = &mut out[r * out_stride + out_off..][..cout];
        for c in 0..cout {
            let mut v = (row_a[c] as f32 * s_aw) * scale[c] + bias[c];
            if let Some(a) = act {
                v = a.apply_scalar(v);
            }
            if let Some(res) = res {
                v += res[r * cout + c];
            }
            if let Some(p) = post {
                v = p.apply_scalar(v);
            }
            row_o[c] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn naive_gemm_i32(a: &[u8], w: &[i32], m: usize, n: usize, k: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[i * n + j] =
                    (0..k).map(|kk| a[i * k + kk] as i32 * w[j * k + kk]).sum();
            }
        }
        out
    }

    #[test]
    fn paper_equation_1bit_unipolar() {
        // W·A = POPCOUNT(W & A) for 1-bit {0,1} weights/activations: check
        // via the unsigned path (offset encoding with qn=1 shifts w to
        // {0,1} and corrects) against a naive integer dot.
        prop::check(50, |rng, _| {
            let k = rng.usize(200) + 1;
            let a: Vec<u8> = (0..k).map(|_| rng.usize(2) as u8).collect();
            let w: Vec<i32> = (0..k).map(|_| rng.range(-1, 1) as i32).collect(); // {-1,0}
            let ap = pack_rows_u8(&a, 1, k, 1);
            let wp = pack_weights_offset(&w, 1, k, 1);
            let mut out = vec![0i32; 1];
            gemm_bitserial(&ap, &wp, 1, &mut out, 1);
            let want: i32 = (0..k).map(|i| a[i] as i32 * w[i]).sum();
            prop::ensure(out[0] == want, format!("k={k}: {} vs {want}", out[0]))
        });
    }

    #[test]
    fn matches_naive_all_bit_combos() {
        for &(ab, wb) in &[(1, 1), (1, 2), (2, 1), (2, 2), (3, 2), (2, 3), (4, 4)] {
            prop::check(25, |rng, _| {
                let m = rng.usize(9) + 1;
                let n = rng.usize(9) + 1;
                let k = rng.usize(150) + 1;
                let (qp, qn) = qp_qn(wb as u8, true);
                let a: Vec<u8> = (0..m * k).map(|_| rng.usize(1 << ab) as u8).collect();
                let w: Vec<i32> =
                    (0..n * k).map(|_| rng.range(-(qn as i64), qp as i64 + 1) as i32).collect();
                let ap = pack_rows_u8(&a, m, k, ab);
                let wp = pack_weights_offset(&w, n, k, wb);
                let mut out = vec![0i32; m * n];
                gemm_bitserial(&ap, &wp, wb, &mut out, 1);
                let want = naive_gemm_i32(&a, &w, m, n, k);
                prop::ensure(out == want, format!("ab={ab} wb={wb} m={m} n={n} k={k}"))
            });
        }
    }

    #[test]
    fn pack_supports_up_to_8_bits() {
        // regression: the generic path used a [0u64; 4] scratch and silently
        // dropped planes 4.. for bits > 4, returning wrong results.
        prop::check(30, |rng, _| {
            let bits = rng.usize(super::MAX_BITS) + 1;
            let rows = rng.usize(4) + 1;
            let k = rng.usize(150) + 1;
            let codes: Vec<u8> = (0..rows * k).map(|_| rng.usize(1 << bits) as u8).collect();
            let p = pack_rows_u8(&codes, rows, k, bits);
            let codes32: Vec<u32> = codes.iter().map(|&v| v as u32).collect();
            let want = crate::dlrt::tensor::Packed::pack(&codes32, rows, k, bits);
            prop::ensure(p == want, format!("bits={bits} rows={rows} k={k}"))
        });
    }

    #[test]
    #[should_panic(expected = "outside supported")]
    fn pack_rejects_more_than_8_bits() {
        pack_rows_u8(&[0u8; 4], 1, 4, 9);
    }

    #[test]
    fn pack_into_reuses_buffer_and_matches() {
        let mut rng = crate::util::rng::Rng::new(21);
        let mut scratch = crate::dlrt::tensor::Packed::new_zeroed(0, 0, 1);
        // biggest layer first: later repacks must not reallocate
        for &(rows, k, bits) in &[(40usize, 200usize, 3usize), (7, 130, 2), (12, 65, 8)] {
            let codes: Vec<u8> = (0..rows * k).map(|_| rng.usize(1 << bits) as u8).collect();
            pack_rows_u8_into(&codes, rows, k, bits, &mut scratch);
            assert_eq!(scratch, pack_rows_u8(&codes, rows, k, bits), "{rows}x{k}@{bits}");
        }
    }

    #[test]
    fn gemm_matches_naive_high_bits() {
        for &(ab, wb) in &[(5usize, 2usize), (2, 5), (8, 3), (6, 6)] {
            prop::check(8, |rng, _| {
                let m = rng.usize(5) + 1;
                let n = rng.usize(5) + 1;
                let k = rng.usize(80) + 1;
                let (qp, qn) = qp_qn(wb as u8, true);
                let a: Vec<u8> = (0..m * k).map(|_| rng.usize(1 << ab) as u8).collect();
                let w: Vec<i32> = (0..n * k)
                    .map(|_| rng.range(-(qn as i64), qp as i64 + 1) as i32)
                    .collect();
                let ap = pack_rows_u8(&a, m, k, ab);
                let wp = pack_weights_offset(&w, n, k, wb);
                let mut out = vec![0i32; m * n];
                gemm_bitserial(&ap, &wp, wb, &mut out, 1);
                let want = naive_gemm_i32(&a, &w, m, n, k);
                prop::ensure(out == want, format!("ab={ab} wb={wb} m={m} n={n} k={k}"))
            });
        }
    }

    #[test]
    fn tiled_matches_naive_at_tile_boundaries() {
        // shapes straddling the M/N tile edges, plus degenerate and oversized
        // explicit tiles — the blocked kernel must stay bit-exact everywhere.
        let mut rng = crate::util::rng::Rng::new(77);
        let k = 130; // 3 words per plane, not a multiple of 64
        for &m in &[1usize, TILE_M - 1, TILE_M, TILE_M + 1, 2 * TILE_M + 3] {
            for &n in &[1usize, TILE_N - 1, TILE_N, TILE_N + 1, 3 * TILE_N + 5] {
                let a: Vec<u8> = (0..m * k).map(|_| rng.usize(4) as u8).collect();
                let w: Vec<i32> = (0..n * k).map(|_| rng.range(-2, 2) as i32).collect();
                let ap = pack_rows_u8(&a, m, k, 2);
                let wp = pack_weights_offset(&w, n, k, 2);
                let want = naive_gemm_i32(&a, &w, m, n, k);
                for threads in [1usize, 3] {
                    let mut got = vec![0i32; m * n];
                    gemm_bitserial(&ap, &wp, 2, &mut got, threads);
                    assert_eq!(got, want, "m={m} n={n} threads={threads}");
                }
                for &(tm, tn) in &[(1usize, 1usize), (4, 4), (MAX_TILE_M, 64)] {
                    let mut got = vec![0i32; m * n];
                    gemm_bitserial_tiled(&ap, &wp, 2, &mut got, 2, tm, tn);
                    assert_eq!(got, want, "m={m} n={n} tile=({tm},{tn})");
                }
            }
        }
    }

    #[test]
    fn threaded_matches_single() {
        prop::check(10, |rng, _| {
            let (m, n, k) = (rng.usize(30) + 4, rng.usize(10) + 1, rng.usize(300) + 1);
            let a: Vec<u8> = (0..m * k).map(|_| rng.usize(4) as u8).collect();
            let w: Vec<i32> = (0..n * k).map(|_| rng.range(-2, 2) as i32).collect();
            let ap = pack_rows_u8(&a, m, k, 2);
            let wp = pack_weights_offset(&w, n, k, 2);
            let mut g1 = vec![0i32; m * n];
            let mut g3 = vec![0i32; m * n];
            gemm_bitserial(&ap, &wp, 2, &mut g1, 1);
            gemm_bitserial(&ap, &wp, 2, &mut g3, 3);
            prop::ensure(g1 == g3, "thread count changed result")
        });
    }

    #[test]
    fn row_code_sum_counts_codes() {
        let codes: Vec<u8> = vec![3, 0, 1, 2, 3, 3];
        let p = pack_rows_u8(&codes, 1, 6, 2);
        assert_eq!(row_code_sum(&p, 0), 12);
    }

    #[test]
    fn dequant_op_order() {
        let acc = vec![10, -4];
        let mut out = vec![0.0; 2];
        dequant_scale_bias(&acc, 2, 0.5, &[2.0, 1.0], &[0.5, -0.5], &mut out);
        assert_eq!(out, vec![10.0 * 0.5 * 2.0 + 0.5, -4.0 * 0.5 * 1.0 - 0.5]);
    }

    #[test]
    fn fused_epilogue_matches_unfused_bit_for_bit() {
        use crate::kernels::elementwise::ActKind;
        let mut rng = crate::util::rng::Rng::new(33);
        let (rows, cout) = (17, 9);
        let acc: Vec<i32> = (0..rows * cout).map(|_| rng.range(-500, 500) as i32).collect();
        let scale: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        for act in [
            ActKind::Relu,
            ActKind::Relu6,
            ActKind::LeakyRelu,
            ActKind::Silu,
            ActKind::Sigmoid,
        ] {
            let mut unfused = vec![0.0f32; rows * cout];
            dequant_scale_bias(&acc, cout, 0.031, &scale, &bias, &mut unfused);
            act.apply(&mut unfused);
            let mut fused = vec![0.0f32; rows * cout];
            dequant_scale_bias_act(&acc, cout, 0.031, &scale, &bias, Some(act), &mut fused);
            assert_eq!(fused, unfused, "fused {} epilogue diverged", act.name());
        }
    }

    #[test]
    fn two_accumulator_epilogue_matches_unfused_composition() {
        // dequant → act → residual add → post-act, fused in one accumulator
        // pass, must equal the four standalone passes bit for bit — and the
        // strided write must place the same values in its channel stripe.
        use crate::kernels::elementwise::{self as ew, ActKind};
        let mut rng = crate::util::rng::Rng::new(41);
        let (rows, cout) = (11, 7);
        let acc: Vec<i32> = (0..rows * cout).map(|_| rng.range(-300, 300) as i32).collect();
        let scale: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let res: Vec<f32> = (0..rows * cout).map(|_| rng.normal()).collect();
        for (act, post) in [
            (None, Some(ActKind::Relu)),               // resnet order: add then act
            (Some(ActKind::Silu), None),               // yolo order: act then add
            (Some(ActKind::Relu), Some(ActKind::Relu6)), // both
            (None, None),
        ] {
            let mut want = vec![0.0f32; rows * cout];
            dequant_scale_bias_act(&acc, cout, 0.07, &scale, &bias, act, &mut want);
            let mut tmp = vec![0.0f32; rows * cout];
            ew::add(&want.clone(), &res, &mut tmp);
            want = tmp;
            if let Some(p) = post {
                p.apply(&mut want);
            }
            let mut fused = vec![0.0f32; rows * cout];
            dequant_scale_bias_add_act(&acc, cout, 0.07, &scale, &bias, act, Some(&res),
                                       post, &mut fused, cout, 0);
            assert_eq!(fused, want, "act={act:?} post={post:?}");

            // strided: same values land at column 3 of 16-wide rows
            let (stride, off) = (16usize, 3usize);
            let mut strided = vec![0.0f32; rows * stride];
            dequant_scale_bias_add_act(&acc, cout, 0.07, &scale, &bias, act, Some(&res),
                                       post, &mut strided, stride, off);
            for r in 0..rows {
                assert_eq!(&strided[r * stride + off..][..cout], &want[r * cout..][..cout]);
            }
        }
    }

    #[test]
    fn general_epilogue_no_res_matches_specialized() {
        // with res=None and a dense view the general path must reproduce
        // dequant_scale_bias_act exactly (the executor switches between them)
        let mut rng = crate::util::rng::Rng::new(43);
        let (rows, cout) = (9, 5);
        let acc: Vec<i32> = (0..rows * cout).map(|_| rng.range(-300, 300) as i32).collect();
        let scale: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        for act in [None, Some(crate::kernels::elementwise::ActKind::Silu)] {
            let mut want = vec![0.0f32; rows * cout];
            dequant_scale_bias_act(&acc, cout, 0.031, &scale, &bias, act, &mut want);
            let mut got = vec![0.0f32; rows * cout];
            dequant_scale_bias_add_act(&acc, cout, 0.031, &scale, &bias, act, None, None,
                                       &mut got, cout, 0);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn goldens_from_python_oracle() {
        // artifacts/golden/kernels.json is produced by the JAX build path;
        // skip silently if artifacts haven't been built (unit-test context).
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/golden/kernels.json");
        let Ok(text) = std::fs::read_to_string(path) else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let v = crate::util::json::Json::parse(&text).unwrap();
        for case in v.get("gemm").unwrap().arr().unwrap() {
            let (ab, wb) = (case.get("a_bits").unwrap().usize().unwrap(),
                            case.get("w_bits").unwrap().usize().unwrap());
            let (m, n, k) = (case.get("m").unwrap().usize().unwrap(),
                             case.get("n").unwrap().usize().unwrap(),
                             case.get("k").unwrap().usize().unwrap());
            let a: Vec<u8> = case.get("a").unwrap().i32_vec().unwrap()
                .iter().map(|&v| v as u8).collect();
            let w = case.get("w").unwrap().i32_vec().unwrap();
            let want = case.get("out").unwrap().i32_vec().unwrap();
            let ap = pack_rows_u8(&a, m, k, ab);
            let wp = pack_weights_offset(&w, n, k, wb);
            let mut out = vec![0i32; m * n];
            gemm_bitserial(&ap, &wp, wb, &mut out, 1);
            assert_eq!(out, want, "golden mismatch {ab}A{wb}W m={m} n={n} k={k}");
        }
    }
}

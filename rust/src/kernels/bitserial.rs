//! Bitserial convolution engine — the paper's core contribution (§V).
//!
//! Dot products between w-bit weights and a-bit activations are computed
//! over bitplanes packed 64 lanes per `u64` word:
//!
//! ```text
//!   W · A = Σᵢ Σⱼ POPCOUNT(W[i] & A[j]) << (i + j)
//! ```
//!
//! Signed weights use the offset encoding `w' = w + Q_N ∈ [0, 2^w)`; the
//! correction `− Q_N · Σ a` is applied once per activation row (its Σa is
//! itself computed from the packed planes with popcounts).
//!
//! The Neon mapping of the paper (VAND + VCNT + VPADAL) becomes `&` +
//! `u64::count_ones()` + scalar adds, which LLVM lowers to `pand`/`popcnt`
//! on x86-64 — the same abstract bit-op machine, so the FP32:bitserial
//! *ratio* transfers (DESIGN.md §2). Tiling follows the paper: activations
//! rows are the parallel/outer dimension, output channels the middle loop,
//! packed words the inner loop.

use crate::dlrt::graph::qp_qn;
use crate::dlrt::tensor::Packed;
use crate::util::threads;

/// Pack unsigned activation codes (`u8`, values < 2^bits) row-major.
pub fn pack_rows_u8(codes: &[u8], rows: usize, k: usize, bits: usize) -> Packed {
    debug_assert_eq!(codes.len(), rows * k);
    let mut p = Packed::new_zeroed(rows, k, bits);
    let wpr = p.words_per_row;
    for r in 0..rows {
        let src = &codes[r * k..(r + 1) * k];
        let base = r * bits * wpr;
        for (jw, chunk) in src.chunks(64).enumerate() {
            // branchless bit-scatter: plane i collects bit i of every code
            let mut words = [0u64; 4]; // bits <= 4 supported on this path
            match bits {
                1 => {
                    let mut w0 = 0u64;
                    for (lane, &v) in chunk.iter().enumerate() {
                        w0 |= ((v & 1) as u64) << lane;
                    }
                    words[0] = w0;
                }
                2 => {
                    let (mut w0, mut w1) = (0u64, 0u64);
                    for (lane, &v) in chunk.iter().enumerate() {
                        w0 |= ((v & 1) as u64) << lane;
                        w1 |= (((v >> 1) & 1) as u64) << lane;
                    }
                    words[0] = w0;
                    words[1] = w1;
                }
                _ => {
                    for (lane, &v) in chunk.iter().enumerate() {
                        for (i, w) in words.iter_mut().enumerate().take(bits) {
                            *w |= (((v >> i) & 1) as u64) << lane;
                        }
                    }
                }
            }
            for (i, &w) in words.iter().enumerate().take(bits) {
                p.data[base + i * wpr + jw] = w;
            }
        }
    }
    p
}

/// Pack signed weight codes (`[-Q_N, Q_P]`) with the offset encoding.
/// Weight layout: rows = output channels, k = kh*kw*cin patch.
pub fn pack_weights_offset(wq: &[i32], rows: usize, k: usize, bits: usize) -> Packed {
    let (_, qn) = qp_qn(bits as u8, true);
    let codes: Vec<u8> = wq
        .iter()
        .map(|&v| {
            let u = v + qn;
            debug_assert!((0..(1 << bits)).contains(&u), "weight code {v} out of range");
            u as u8
        })
        .collect();
    pack_rows_u8(&codes, rows, k, bits)
}

/// Σ over codes of one packed row (from its planes): Σⱼ popcount(plane j)<<j.
#[inline]
pub fn row_code_sum(p: &Packed, row: usize) -> i32 {
    let mut s = 0u32;
    for i in 0..p.bits {
        let pc: u32 = p.row_plane(row, i).iter().map(|w| w.count_ones()).sum();
        s += pc << i;
    }
    s as i32
}

/// Bitserial GEMM: `out[m][n] = Σ_k a[m][k] * (w[n][k] signed)` in i32.
///
/// `a`: packed unsigned activations (M rows), `w`: packed offset-encoded
/// weights (N rows), `w_bits_signed`: the signed bit width (for Q_N).
pub fn gemm_bitserial(
    a: &Packed,
    w: &Packed,
    w_bits_signed: usize,
    out: &mut [i32],
    nthreads: usize,
) {
    assert_eq!(a.k, w.k, "reduction dim mismatch");
    assert_eq!(a.words_per_row, w.words_per_row);
    let (m, n) = (a.rows, w.rows);
    assert_eq!(out.len(), m * n);
    let (_, qn) = qp_qn(w_bits_signed as u8, true);

    threads::par_ranges(m, nthreads, |lo, hi| {
        // rows [lo, hi) are written by exactly one worker
        let out_ptr = out.as_ptr() as *mut i32;
        for mi in lo..hi {
            let a_sum = row_code_sum(a, mi);
            let corr = qn * a_sum;
            for ni in 0..n {
                let acc = dot_planes(a, mi, w, ni);
                unsafe { *out_ptr.add(mi * n + ni) = acc - corr };
            }
        }
    });
}

/// One bitserial dot product between packed row `mi` of `a` and `ni` of `w`.
///
/// Specialized fast paths for the common ultra-low-bit cases (the paper's
/// 1A1W / 1A2W / 2A2W configs) walk both rows word-major in a single pass,
/// loading each activation/weight word once and touching all plane pairs —
/// the same load-amortization the paper's Neon kernels get from keeping
/// plane vectors resident in q-registers.
#[inline]
fn dot_planes(a: &Packed, mi: usize, w: &Packed, ni: usize) -> i32 {
    let nwords = a.words_per_row;
    let abase = mi * a.bits * nwords;
    let wbase = ni * w.bits * nwords;
    let adata = &a.data[abase..abase + a.bits * nwords];
    let wdata = &w.data[wbase..wbase + w.bits * nwords];
    match (a.bits, w.bits) {
        (1, 1) => {
            let mut pc: u32 = 0;
            for (x, y) in adata.iter().zip(wdata) {
                pc += (x & y).count_ones();
            }
            pc as i32
        }
        (1, 2) => {
            let (a0, (w0, w1)) = (adata, wdata.split_at(nwords));
            let (mut p0, mut p1) = (0u32, 0u32);
            for i in 0..nwords {
                let x = a0[i];
                p0 += (x & w0[i]).count_ones();
                p1 += (x & w1[i]).count_ones();
            }
            (p0 + (p1 << 1)) as i32
        }
        (2, 2) => {
            let (a0, a1) = adata.split_at(nwords);
            let (w0, w1) = wdata.split_at(nwords);
            // shift-bucket accumulators (out = s0 + 2*s1 + 4*s2), two
            // independent chains per bucket so the popcnt unit pipelines
            let mut s = [0u32; 8];
            let mut i = 0;
            while i + 2 <= nwords {
                let (x0, x1, y0, y1) = (a0[i], a1[i], w0[i], w1[i]);
                s[0] += (x0 & y0).count_ones();
                s[1] += (x1 & y0).count_ones();
                s[2] += (x0 & y1).count_ones();
                s[3] += (x1 & y1).count_ones();
                let (x0, x1, y0, y1) = (a0[i + 1], a1[i + 1], w0[i + 1], w1[i + 1]);
                s[4] += (x0 & y0).count_ones();
                s[5] += (x1 & y0).count_ones();
                s[6] += (x0 & y1).count_ones();
                s[7] += (x1 & y1).count_ones();
                i += 2;
            }
            if i < nwords {
                let (x0, x1, y0, y1) = (a0[i], a1[i], w0[i], w1[i]);
                s[0] += (x0 & y0).count_ones();
                s[1] += (x1 & y0).count_ones();
                s[2] += (x0 & y1).count_ones();
                s[3] += (x1 & y1).count_ones();
            }
            ((s[0] + s[4]) + ((s[1] + s[2] + s[5] + s[6]) << 1) + ((s[3] + s[7]) << 2))
                as i32
        }
        _ => {
            // generic multi-bit path
            let mut acc: u32 = 0;
            for i in 0..w.bits {
                let wp = &wdata[i * nwords..(i + 1) * nwords];
                for j in 0..a.bits {
                    let ap = &adata[j * nwords..(j + 1) * nwords];
                    let mut pc: u32 = 0;
                    for (x, y) in ap.iter().zip(wp) {
                        pc += (x & y).count_ones();
                    }
                    acc += pc << (i + j);
                }
            }
            acc as i32
        }
    }
}

/// Dequantize a bitserial GEMM result into f32 with per-channel folded-BN
/// scale/bias: `out = (acc * s_a*s_w) * scale[c] + bias[c]`.
/// Op order matches `python/compile/jax_exec.py::_conv_deploy` exactly so
/// parity goldens are bit-identical.
pub fn dequant_scale_bias(
    acc: &[i32],
    cout: usize,
    s_aw: f32,
    scale: &[f32],
    bias: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(acc.len(), out.len());
    for (row_a, row_o) in acc.chunks(cout).zip(out.chunks_mut(cout)) {
        for c in 0..cout {
            row_o[c] = (row_a[c] as f32 * s_aw) * scale[c] + bias[c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn naive_gemm_i32(a: &[u8], w: &[i32], m: usize, n: usize, k: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[i * n + j] =
                    (0..k).map(|kk| a[i * k + kk] as i32 * w[j * k + kk]).sum();
            }
        }
        out
    }

    #[test]
    fn paper_equation_1bit_unipolar() {
        // W·A = POPCOUNT(W & A) for 1-bit {0,1} weights/activations: check
        // via the unsigned path (offset encoding with qn=1 shifts w to
        // {0,1} and corrects) against a naive integer dot.
        prop::check(50, |rng, _| {
            let k = rng.usize(200) + 1;
            let a: Vec<u8> = (0..k).map(|_| rng.usize(2) as u8).collect();
            let w: Vec<i32> = (0..k).map(|_| rng.range(-1, 1) as i32).collect(); // {-1,0}
            let ap = pack_rows_u8(&a, 1, k, 1);
            let wp = pack_weights_offset(&w, 1, k, 1);
            let mut out = vec![0i32; 1];
            gemm_bitserial(&ap, &wp, 1, &mut out, 1);
            let want: i32 = (0..k).map(|i| a[i] as i32 * w[i]).sum();
            prop::ensure(out[0] == want, format!("k={k}: {} vs {want}", out[0]))
        });
    }

    #[test]
    fn matches_naive_all_bit_combos() {
        for &(ab, wb) in &[(1, 1), (1, 2), (2, 1), (2, 2), (3, 2), (2, 3), (4, 4)] {
            prop::check(25, |rng, _| {
                let m = rng.usize(9) + 1;
                let n = rng.usize(9) + 1;
                let k = rng.usize(150) + 1;
                let (qp, qn) = qp_qn(wb as u8, true);
                let a: Vec<u8> = (0..m * k).map(|_| rng.usize(1 << ab) as u8).collect();
                let w: Vec<i32> =
                    (0..n * k).map(|_| rng.range(-(qn as i64), qp as i64 + 1) as i32).collect();
                let ap = pack_rows_u8(&a, m, k, ab);
                let wp = pack_weights_offset(&w, n, k, wb);
                let mut out = vec![0i32; m * n];
                gemm_bitserial(&ap, &wp, wb, &mut out, 1);
                let want = naive_gemm_i32(&a, &w, m, n, k);
                prop::ensure(out == want, format!("ab={ab} wb={wb} m={m} n={n} k={k}"))
            });
        }
    }

    #[test]
    fn threaded_matches_single() {
        prop::check(10, |rng, _| {
            let (m, n, k) = (rng.usize(30) + 4, rng.usize(10) + 1, rng.usize(300) + 1);
            let a: Vec<u8> = (0..m * k).map(|_| rng.usize(4) as u8).collect();
            let w: Vec<i32> = (0..n * k).map(|_| rng.range(-2, 2) as i32).collect();
            let ap = pack_rows_u8(&a, m, k, 2);
            let wp = pack_weights_offset(&w, n, k, 2);
            let mut g1 = vec![0i32; m * n];
            let mut g3 = vec![0i32; m * n];
            gemm_bitserial(&ap, &wp, 2, &mut g1, 1);
            gemm_bitserial(&ap, &wp, 2, &mut g3, 3);
            prop::ensure(g1 == g3, "thread count changed result")
        });
    }

    #[test]
    fn row_code_sum_counts_codes() {
        let codes: Vec<u8> = vec![3, 0, 1, 2, 3, 3];
        let p = pack_rows_u8(&codes, 1, 6, 2);
        assert_eq!(row_code_sum(&p, 0), 12);
    }

    #[test]
    fn dequant_op_order() {
        let acc = vec![10, -4];
        let mut out = vec![0.0; 2];
        dequant_scale_bias(&acc, 2, 0.5, &[2.0, 1.0], &[0.5, -0.5], &mut out);
        assert_eq!(out, vec![10.0 * 0.5 * 2.0 + 0.5, -4.0 * 0.5 * 1.0 - 0.5]);
    }

    #[test]
    fn goldens_from_python_oracle() {
        // artifacts/golden/kernels.json is produced by the JAX build path;
        // skip silently if artifacts haven't been built (unit-test context).
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/golden/kernels.json");
        let Ok(text) = std::fs::read_to_string(path) else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let v = crate::util::json::Json::parse(&text).unwrap();
        for case in v.get("gemm").unwrap().arr().unwrap() {
            let (ab, wb) = (case.get("a_bits").unwrap().usize().unwrap(),
                            case.get("w_bits").unwrap().usize().unwrap());
            let (m, n, k) = (case.get("m").unwrap().usize().unwrap(),
                             case.get("n").unwrap().usize().unwrap(),
                             case.get("k").unwrap().usize().unwrap());
            let a: Vec<u8> = case.get("a").unwrap().i32_vec().unwrap()
                .iter().map(|&v| v as u8).collect();
            let w = case.get("w").unwrap().i32_vec().unwrap();
            let want = case.get("out").unwrap().i32_vec().unwrap();
            let ap = pack_rows_u8(&a, m, k, ab);
            let wp = pack_weights_offset(&w, n, k, wb);
            let mut out = vec![0i32; m * n];
            gemm_bitserial(&ap, &wp, wb, &mut out, 1);
            assert_eq!(out, want, "golden mismatch {ab}A{wb}W m={m} n={n} k={k}");
        }
    }
}

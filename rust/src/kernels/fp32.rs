//! FP32 engine: im2col + blocked GEMM — the paper's "optimized FP32
//! baseline" role (what TFLite/ORT FP32 provides on Arm).
//!
//! GEMM computes `out[m][n] = Σ_k a[m][k] * b[n][k]` (B stored row-major by
//! output channel, i.e. already transposed — same layout the bitserial
//! engine uses for packed planes). Blocking: 4×4 register tile over (m, n)
//! with the k loop innermost, which autovectorizes reasonably on x86; rows
//! are parallelized across threads.

use crate::util::threads;

pub const MR: usize = 4;
pub const NR: usize = 4;

/// `a`: m×k row-major, `b`: n×k row-major (transposed B), `out`: m×n.
pub fn gemm_rowmajor_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize,
                        out: &mut [f32], nthreads: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    threads::par_chunks_rows(out, n, nthreads, |row0, chunk| {
        let rows = chunk.len() / n;
        gemm_block(&a[row0 * k..(row0 + rows) * k], b, rows, n, k, chunk);
    });
}

fn gemm_block(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    out.fill(0.0);
    let mut im = 0;
    while im < m {
        let mr = MR.min(m - im);
        let mut in_ = 0;
        while in_ < n {
            let nr = NR.min(n - in_);
            if mr == MR && nr == NR {
                kernel_4x4(a, b, im, in_, n, k, out);
            } else {
                kernel_edge(a, b, im, in_, mr, nr, n, k, out);
            }
            in_ += NR;
        }
        im += MR;
    }
}

/// 4x4 microkernel with 4-wide k vectorization: 16 accumulators of 4 f32
/// lanes each — exactly the 16 xmm registers, so LLVM keeps the whole tile
/// register-resident and emits packed FMAs.
#[inline]
fn kernel_4x4(a: &[f32], b: &[f32], im: usize, in_: usize, n: usize, k: usize,
              out: &mut [f32]) {
    let a0 = &a[im * k..(im + 1) * k];
    let a1 = &a[(im + 1) * k..(im + 2) * k];
    let a2 = &a[(im + 2) * k..(im + 3) * k];
    let a3 = &a[(im + 3) * k..(im + 4) * k];
    let b0 = &b[in_ * k..(in_ + 1) * k];
    let b1 = &b[(in_ + 1) * k..(in_ + 2) * k];
    let b2 = &b[(in_ + 2) * k..(in_ + 3) * k];
    let b3 = &b[(in_ + 3) * k..(in_ + 4) * k];
    let mut acc = [[[0.0f32; 4]; NR]; MR];
    let kv = k / 4 * 4;
    let mut kk = 0;
    while kk < kv {
        let av = [
            [a0[kk], a0[kk + 1], a0[kk + 2], a0[kk + 3]],
            [a1[kk], a1[kk + 1], a1[kk + 2], a1[kk + 3]],
            [a2[kk], a2[kk + 1], a2[kk + 2], a2[kk + 3]],
            [a3[kk], a3[kk + 1], a3[kk + 2], a3[kk + 3]],
        ];
        let bv = [
            [b0[kk], b0[kk + 1], b0[kk + 2], b0[kk + 3]],
            [b1[kk], b1[kk + 1], b1[kk + 2], b1[kk + 3]],
            [b2[kk], b2[kk + 1], b2[kk + 2], b2[kk + 3]],
            [b3[kk], b3[kk + 1], b3[kk + 2], b3[kk + 3]],
        ];
        for i in 0..MR {
            for j in 0..NR {
                for l in 0..4 {
                    acc[i][j][l] += av[i][l] * bv[j][l];
                }
            }
        }
        kk += 4;
    }
    let arows = [a0, a1, a2, a3];
    let brows = [b0, b1, b2, b3];
    for i in 0..MR {
        for j in 0..NR {
            let mut s = acc[i][j][0] + acc[i][j][1] + acc[i][j][2] + acc[i][j][3];
            for kk in kv..k {
                s += arows[i][kk] * brows[j][kk];
            }
            out[(im + i) * n + in_ + j] = s;
        }
    }
}

#[inline]
fn kernel_edge(a: &[f32], b: &[f32], im: usize, in_: usize, mr: usize, nr: usize,
               n: usize, k: usize, out: &mut [f32]) {
    let mut acc = [[0.0f32; NR]; MR];
    for kk in 0..k {
        let mut av = [0.0f32; MR];
        for (i, a_i) in av.iter_mut().enumerate().take(mr) {
            *a_i = a[(im + i) * k + kk];
        }
        for j in 0..nr {
            let bv = b[(in_ + j) * k + kk];
            for (i, &a_i) in av.iter().enumerate().take(mr) {
                acc[i][j] += a_i * bv;
            }
        }
    }
    for i in 0..mr {
        for j in 0..nr {
            out[(im + i) * n + in_ + j] = acc[i][j];
        }
    }
}

/// Naive reference GEMM (oracle for the blocked one).
pub fn gemm_naive(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for kk in 0..k {
                s += a[i * k + kk] * b[j * k + kk];
            }
            out[i * n + j] = s;
        }
    }
}

/// Apply per-channel scale/bias to a rows×cout GEMM result (BN folding).
pub fn scale_bias_rows(out: &mut [f32], cout: usize, scale: &[f32], bias: &[f32]) {
    scale_bias_rows_act(out, cout, scale, bias, None);
}

/// [`scale_bias_rows`] with an optional fused activation epilogue — the
/// FP32 engine's half of Conv2d+activation fusion: scale, bias, and
/// activation are applied in one pass over the GEMM result, with the exact
/// float ops of the standalone elementwise pass (fusion is bit-exact).
pub fn scale_bias_rows_act(
    out: &mut [f32],
    cout: usize,
    scale: &[f32],
    bias: &[f32],
    act: Option<crate::kernels::elementwise::ActKind>,
) {
    debug_assert_eq!(scale.len(), cout);
    debug_assert_eq!(bias.len(), cout);
    match act {
        None => {
            for row in out.chunks_mut(cout) {
                for (c, v) in row.iter_mut().enumerate() {
                    *v = *v * scale[c] + bias[c];
                }
            }
        }
        Some(a) => {
            for row in out.chunks_mut(cout) {
                for (c, v) in row.iter_mut().enumerate() {
                    *v = a.apply_scalar(*v * scale[c] + bias[c]);
                }
            }
        }
    }
}

/// The fp32 engine's general conv epilogue — [`scale_bias_rows_act`]'s
/// **two-accumulator** variant: reads the raw GEMM result from `src` and
/// writes `post(act(src*scale + bias) + res)` (each stage optional) into
/// `out`, either densely (`out_stride == cout`, `out_off == 0`) or into a
/// channel stripe of a wider row — the planner's Add/residual fusion and
/// concat-in-place lowering for FP32 convs. `src` may not alias `out`
/// (the strided path runs GEMM into scratch first).
///
/// Float ops and their order match the unfused
/// `scale_bias_rows → act → add → act` sequence exactly (bit-identical).
#[allow(clippy::too_many_arguments)]
pub fn scale_bias_rows_add_act(
    src: &[f32],
    cout: usize,
    scale: &[f32],
    bias: &[f32],
    act: Option<crate::kernels::elementwise::ActKind>,
    res: Option<&[f32]>,
    post: Option<crate::kernels::elementwise::ActKind>,
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
) {
    debug_assert_eq!(scale.len(), cout);
    debug_assert_eq!(bias.len(), cout);
    debug_assert!(out_off + cout <= out_stride);
    debug_assert_eq!(src.len() % cout, 0);
    let rows = src.len() / cout;
    debug_assert!(res.map(|r| r.len() == rows * cout).unwrap_or(true));
    debug_assert!(out.len() >= rows.saturating_sub(1) * out_stride + out_off + cout);
    for (r, row_s) in src.chunks(cout).enumerate() {
        let row_o = &mut out[r * out_stride + out_off..][..cout];
        for c in 0..cout {
            let mut v = row_s[c] * scale[c] + bias[c];
            if let Some(a) = act {
                v = a.apply_scalar(v);
            }
            if let Some(res) = res {
                v += res[r * cout + c];
            }
            if let Some(p) = post {
                v = p.apply_scalar(v);
            }
            row_o[c] = v;
        }
    }
}

/// Dense layer forward: `x` is rows×cin, `w` is cin×cout row-major (the
/// export layout), `b` has cout entries. Output rows are split across the
/// persistent worker pool exactly like the conv GEMMs (each worker owns a
/// disjoint `&mut` block of whole rows); zero activations skip their whole
/// weight row, which matters after ReLU-heavy backbones.
#[allow(clippy::too_many_arguments)]
pub fn dense_rowmajor(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    rows: usize,
    cin: usize,
    cout: usize,
    out: &mut [f32],
    nthreads: usize,
) {
    debug_assert_eq!(x.len(), rows * cin);
    debug_assert_eq!(w.len(), cin * cout);
    debug_assert_eq!(b.len(), cout);
    debug_assert_eq!(out.len(), rows * cout);
    threads::par_chunks_rows(out, cout, nthreads, |row0, chunk| {
        for (i, or) in chunk.chunks_mut(cout).enumerate() {
            let xr = &x[(row0 + i) * cin..(row0 + i + 1) * cin];
            or.copy_from_slice(b);
            for (j, &xv) in xr.iter().enumerate() {
                if xv != 0.0 {
                    let wr = &w[j * cout..(j + 1) * cout];
                    for (o, &wv) in or.iter_mut().zip(wr) {
                        *o += xv * wv;
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn blocked_matches_naive_property() {
        prop::check(60, |rng, _| {
            let m = rng.usize(33) + 1;
            let n = rng.usize(29) + 1;
            let k = rng.usize(70) + 1;
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            let mut got = vec![0.0; m * n];
            let mut want = vec![0.0; m * n];
            gemm_rowmajor_bt(&a, &b, m, n, k, &mut got, 1);
            gemm_naive(&a, &b, m, n, k, &mut want);
            prop::close(&got, &want, 1e-4, 1e-5)
        });
    }

    #[test]
    fn threaded_matches_single() {
        let mut rng = Rng::new(5);
        let (m, n, k) = (37, 19, 53);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        let mut got1 = vec![0.0; m * n];
        let mut got4 = vec![0.0; m * n];
        gemm_rowmajor_bt(&a, &b, m, n, k, &mut got1, 1);
        gemm_rowmajor_bt(&a, &b, m, n, k, &mut got4, 4);
        // thread partitioning shifts 4-row block boundaries → summation
        // order differs in edge rows; results agree to float round-off
        prop::close(&got1, &got4, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn scale_bias() {
        let mut out = vec![1.0, 2.0, 3.0, 4.0];
        scale_bias_rows(&mut out, 2, &[2.0, 0.5], &[1.0, -1.0]);
        assert_eq!(out, vec![3.0, 0.0, 7.0, 1.0]);
    }

    #[test]
    fn scale_bias_fused_act_matches_unfused() {
        use crate::kernels::elementwise::ActKind;
        let mut rng = Rng::new(11);
        let (rows, cout) = (13, 5);
        let base: Vec<f32> = (0..rows * cout).map(|_| rng.normal()).collect();
        let scale: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        for act in [ActKind::Relu, ActKind::Silu, ActKind::Relu6] {
            let mut unfused = base.clone();
            scale_bias_rows(&mut unfused, cout, &scale, &bias);
            act.apply(&mut unfused);
            let mut fused = base.clone();
            scale_bias_rows_act(&mut fused, cout, &scale, &bias, Some(act));
            assert_eq!(fused, unfused, "fused {} diverged", act.name());
        }
    }

    #[test]
    fn two_accumulator_epilogue_matches_unfused_composition() {
        use crate::kernels::elementwise::{self as ew, ActKind};
        let mut rng = Rng::new(29);
        let (rows, cout) = (10, 6);
        let src: Vec<f32> = (0..rows * cout).map(|_| rng.normal()).collect();
        let scale: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let res: Vec<f32> = (0..rows * cout).map(|_| rng.normal()).collect();
        for (act, post) in [
            (None, Some(ActKind::Relu)),
            (Some(ActKind::Silu), None),
            (Some(ActKind::LeakyRelu), Some(ActKind::Sigmoid)),
            (None, None),
        ] {
            let mut want = src.clone();
            scale_bias_rows_act(&mut want, cout, &scale, &bias, act);
            let mut tmp = vec![0.0f32; rows * cout];
            ew::add(&want, &res, &mut tmp);
            want = tmp;
            if let Some(p) = post {
                p.apply(&mut want);
            }
            let mut fused = vec![0.0f32; rows * cout];
            scale_bias_rows_add_act(&src, cout, &scale, &bias, act, Some(&res), post,
                                    &mut fused, cout, 0);
            assert_eq!(fused, want, "act={act:?} post={post:?}");

            let (stride, off) = (13usize, 4usize);
            let mut strided = vec![0.0f32; rows * stride];
            scale_bias_rows_add_act(&src, cout, &scale, &bias, act, Some(&res), post,
                                    &mut strided, stride, off);
            for r in 0..rows {
                assert_eq!(&strided[r * stride + off..][..cout], &want[r * cout..][..cout]);
            }
        }
        // res=None must reproduce the in-place specialized path exactly
        let mut want = src.clone();
        scale_bias_rows_act(&mut want, cout, &scale, &bias, Some(ActKind::Relu6));
        let mut got = vec![0.0f32; rows * cout];
        scale_bias_rows_add_act(&src, cout, &scale, &bias, Some(ActKind::Relu6), None, None,
                                &mut got, cout, 0);
        assert_eq!(got, want);
    }

    #[test]
    fn dense_matches_scalar_reference() {
        let mut rng = Rng::new(19);
        let (rows, cin, cout) = (7, 11, 6);
        let mut x: Vec<f32> = (0..rows * cin).map(|_| rng.normal()).collect();
        // sprinkle zeros so the sparsity skip is exercised
        for v in x.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let w: Vec<f32> = (0..cin * cout).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let mut want = vec![0.0f32; rows * cout];
        for r in 0..rows {
            for c in 0..cout {
                let mut s = b[c];
                for j in 0..cin {
                    s += x[r * cin + j] * w[j * cout + c];
                }
                want[r * cout + c] = s;
            }
        }
        for nthreads in [1usize, 3] {
            let mut got = vec![0.0f32; rows * cout];
            dense_rowmajor(&x, &w, &b, rows, cin, cout, &mut got, nthreads);
            prop::close(&got, &want, 1e-5, 1e-5).unwrap();
        }
    }

    #[test]
    fn dense_threaded_matches_single_exactly() {
        // per-row accumulation order is thread-count independent, so the
        // parallel dense must be bit-identical, not just close
        let mut rng = Rng::new(23);
        let (rows, cin, cout) = (16, 9, 4);
        let x: Vec<f32> = (0..rows * cin).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..cin * cout).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let mut g1 = vec![0.0f32; rows * cout];
        let mut g4 = vec![0.0f32; rows * cout];
        dense_rowmajor(&x, &w, &b, rows, cin, cout, &mut g1, 1);
        dense_rowmajor(&x, &w, &b, rows, cin, cout, &mut g4, 4);
        assert_eq!(g1, g4);
    }
}

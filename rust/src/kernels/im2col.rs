//! im2col: NHWC activations → (N*OH*OW, KH*KW*C) patch matrix.
//!
//! Patch layout is (kh, kw, c) row-major — identical to
//! `python/compile/kernels/ref.py::im2col` so cross-layer goldens line up
//! element-for-element. Out-of-image taps are zero (numerically correct for
//! FP32/INT8 and for bitserial unipolar codes, where 0 contributes nothing).

use crate::dlrt::graph::conv_out_hw;

/// Dimensions bundle for a conv lowering.
#[derive(Clone, Copy, Debug)]
pub struct ConvDims {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: [usize; 2],
    pub padding: [usize; 2],
    pub oh: usize,
    pub ow: usize,
}

impl ConvDims {
    pub fn new(
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        kh: usize,
        kw: usize,
        stride: [usize; 2],
        padding: [usize; 2],
    ) -> ConvDims {
        let (oh, ow) = conv_out_hw(h, w, [kh, kw], stride, padding);
        ConvDims { n, h, w, c, kh, kw, stride, padding, oh, ow }
    }

    pub fn rows(&self) -> usize {
        self.n * self.oh * self.ow
    }

    pub fn patch(&self) -> usize {
        self.kh * self.kw * self.c
    }
}

/// Fill `out` (rows × patch, pre-sized) with patches of `x` (NHWC).
pub fn im2col_f32(x: &[f32], d: &ConvDims, out: &mut [f32]) {
    im2col_f32_view(x, d, d.c, 0, out);
}

/// [`im2col_f32`] reading each input pixel's `d.c` channels from column
/// `src_off` of a row `src_stride` channels wide — the stride-aware *read*
/// path that lets a conv consume a channel stripe of a concat root slot
/// without densifying it first (`src_stride == d.c`, `src_off == 0` is the
/// dense layout). Out-of-image taps stay zero; patch layout is unchanged,
/// so the GEMM and epilogue never know the input was strided.
pub fn im2col_f32_view(
    x: &[f32],
    d: &ConvDims,
    src_stride: usize,
    src_off: usize,
    out: &mut [f32],
) {
    let patch = d.patch();
    debug_assert_eq!(out.len(), d.rows() * patch);
    debug_assert!(src_off + d.c <= src_stride);
    debug_assert!(x.len() >= d.n * d.h * d.w * src_stride);
    let (ph, pw) = (d.padding[0] as isize, d.padding[1] as isize);
    for n in 0..d.n {
        let xn = &x[n * d.h * d.w * src_stride..][..d.h * d.w * src_stride];
        for oy in 0..d.oh {
            let iy0 = (oy * d.stride[0]) as isize - ph;
            for ox in 0..d.ow {
                let ix0 = (ox * d.stride[1]) as isize - pw;
                let row = ((n * d.oh + oy) * d.ow + ox) * patch;
                let out_row = &mut out[row..row + patch];
                let mut o = 0;
                for ky in 0..d.kh {
                    let iy = iy0 + ky as isize;
                    if iy < 0 || iy >= d.h as isize {
                        out_row[o..o + d.kw * d.c].fill(0.0);
                        o += d.kw * d.c;
                        continue;
                    }
                    let rowbase = iy as usize * d.w * src_stride;
                    for kx in 0..d.kw {
                        let ix = ix0 + kx as isize;
                        if ix < 0 || ix >= d.w as isize {
                            out_row[o..o + d.c].fill(0.0);
                        } else {
                            let src = rowbase + ix as usize * src_stride + src_off;
                            out_row[o..o + d.c].copy_from_slice(&xn[src..src + d.c]);
                        }
                        o += d.c;
                    }
                }
            }
        }
    }
}

/// im2col with fused unsigned activation quantization:
/// `code = clip(round(x / s_a), 0, qp)` — feeds the bitserial/int8 engines.
/// Quantizing before patch extraction would also work, but fusing here keeps
/// a single pass over memory (this is on the hot path).
pub fn im2col_quant_u8(x: &[f32], d: &ConvDims, s_a: f32, qp: u8, out: &mut [u8]) {
    im2col_quant_u8_view(x, d, s_a, qp, d.c, 0, out);
}

/// [`im2col_quant_u8`] with the stride-aware read path of
/// [`im2col_f32_view`]: input pixels' channels live at column `src_off`
/// of a `src_stride`-wide row. Quantization is per element, so reading
/// through the view is bit-identical to densify-then-quantize.
pub fn im2col_quant_u8_view(
    x: &[f32],
    d: &ConvDims,
    s_a: f32,
    qp: u8,
    src_stride: usize,
    src_off: usize,
    out: &mut [u8],
) {
    let patch = d.patch();
    debug_assert_eq!(out.len(), d.rows() * patch);
    debug_assert!(src_off + d.c <= src_stride);
    debug_assert!(x.len() >= d.n * d.h * d.w * src_stride);
    let inv = 1.0 / s_a;
    let (ph, pw) = (d.padding[0] as isize, d.padding[1] as isize);
    // cast-based saturating quantizer: for v >= -0.5*s_a this equals
    // round-half-away (floor(v/s + 0.5)); negatives clip to 0 either way.
    // `as u32` saturates at 0 for negative floats, `min` caps at Q_P.
    let qpf = qp as u32;
    let q = |v: f32| -> u8 { ((v * inv + 0.5) as u32).min(qpf) as u8 };
    for n in 0..d.n {
        let xn = &x[n * d.h * d.w * src_stride..][..d.h * d.w * src_stride];
        for oy in 0..d.oh {
            let iy0 = (oy * d.stride[0]) as isize - ph;
            for ox in 0..d.ow {
                let ix0 = (ox * d.stride[1]) as isize - pw;
                let row = ((n * d.oh + oy) * d.ow + ox) * patch;
                let out_row = &mut out[row..row + patch];
                let mut o = 0;
                for ky in 0..d.kh {
                    let iy = iy0 + ky as isize;
                    if iy < 0 || iy >= d.h as isize {
                        out_row[o..o + d.kw * d.c].fill(0);
                        o += d.kw * d.c;
                        continue;
                    }
                    let rowbase = iy as usize * d.w * src_stride;
                    for kx in 0..d.kw {
                        let ix = ix0 + kx as isize;
                        if ix < 0 || ix >= d.w as isize {
                            out_row[o..o + d.c].fill(0);
                        } else {
                            let src = rowbase + ix as usize * src_stride + src_off;
                            for (dst, &v) in
                                out_row[o..o + d.c].iter_mut().zip(&xn[src..src + d.c])
                            {
                                *dst = q(v);
                            }
                        }
                        o += d.c;
                    }
                }
            }
        }
    }
}

/// Direct staging for a unit conv (1×1, stride 1, pad 0) over a dense
/// input: im2col is the identity permutation there, so staging the patch
/// matrix is one flat copy. Selected by a tuned schedule's
/// `staging = direct` (`crate::tune::Staging`); the gather path stays the
/// default and the only option for strided/padded reads.
pub fn stage_direct_f32(x: &[f32], out: &mut [f32]) {
    debug_assert!(x.len() >= out.len());
    out.copy_from_slice(&x[..out.len()]);
}

/// [`stage_direct_f32`]'s quantizing twin: the exact cast-based saturating
/// quantizer of [`im2col_quant_u8_view`] applied as one flat pass, so the
/// staged codes are bit-identical to the gather path's.
pub fn quantize_direct_u8(x: &[f32], s_a: f32, qp: u8, out: &mut [u8]) {
    debug_assert!(x.len() >= out.len());
    let inv = 1.0 / s_a;
    let qpf = qp as u32;
    for (dst, &v) in out.iter_mut().zip(x) {
        *dst = ((v * inv + 0.5) as u32).min(qpf) as u8;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1() {
        let d = ConvDims::new(1, 2, 2, 3, 1, 1, [1, 1], [0, 0]);
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let mut out = vec![0.0; d.rows() * d.patch()];
        im2col_f32(&x, &d, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn padding_zeroes_border() {
        let d = ConvDims::new(1, 2, 2, 1, 3, 3, [1, 1], [1, 1]);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut out = vec![-1.0; d.rows() * d.patch()];
        im2col_f32(&x, &d, &mut out);
        // first output pixel (0,0): patch rows ky=0 all zero (above image)
        assert_eq!(&out[0..3], &[0.0, 0.0, 0.0]);
        // center tap of patch (ky=1,kx=1) = x[0,0]
        assert_eq!(out[4], 1.0);
        assert_eq!(d.rows(), 4);
    }

    #[test]
    fn strides_select_correct_pixels() {
        let d = ConvDims::new(1, 4, 4, 1, 1, 1, [2, 2], [0, 0]);
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut out = vec![0.0; d.rows()];
        im2col_f32(&x, &d, &mut out);
        assert_eq!(out, vec![0.0, 2.0, 8.0, 10.0]);
    }

    /// Embed a dense NHWC tensor as a channel stripe of a wider buffer and
    /// im2col it through the view: every patch (padding rows included) must
    /// be bit-identical to densify-then-im2col, across off/stride sweeps.
    #[test]
    fn strided_reads_match_densify_then_run() {
        // padded 3x3 stride-1 and downsampling stride-2 cases
        for (k, s, p) in [(3usize, 1usize, 1usize), (3, 2, 1), (1, 1, 0)] {
            let d = ConvDims::new(2, 5, 4, 3, k, k, [s, s], [p, p]);
            let dense: Vec<f32> = (0..d.n * d.h * d.w * d.c)
                .map(|v| (v as f32 * 0.73).sin())
                .collect();
            let mut want = vec![0.0f32; d.rows() * d.patch()];
            im2col_f32(&dense, &d, &mut want);
            let mut want_q = vec![0u8; d.rows() * d.patch()];
            im2col_quant_u8(&dense, &d, 0.13, 3, &mut want_q);
            for (stride, off) in [(3usize, 0usize), (5, 0), (5, 2), (9, 4), (9, 6)] {
                // scatter the dense pixels into their stripe; poison the
                // other columns so any stray read shows up
                let mut wide = vec![f32::NAN; d.n * d.h * d.w * stride];
                for px in 0..d.n * d.h * d.w {
                    wide[px * stride + off..px * stride + off + d.c]
                        .copy_from_slice(&dense[px * d.c..(px + 1) * d.c]);
                }
                let mut got = vec![0.0f32; d.rows() * d.patch()];
                im2col_f32_view(&wide, &d, stride, off, &mut got);
                assert_eq!(got, want, "f32 k{k} s{s} stride {stride} off {off}");
                let mut got_q = vec![0u8; d.rows() * d.patch()];
                im2col_quant_u8_view(&wide, &d, 0.13, 3, stride, off, &mut got_q);
                assert_eq!(got_q, want_q, "u8 k{k} s{s} stride {stride} off {off}");
            }
        }
    }

    /// Direct staging must be bit-identical to the gather path on its only
    /// legal shape class (unit convs over dense inputs), f32 and quantized.
    #[test]
    fn direct_staging_matches_gather_on_unit_convs() {
        let d = ConvDims::new(2, 3, 4, 5, 1, 1, [1, 1], [0, 0]);
        let x: Vec<f32> =
            (0..d.n * d.h * d.w * d.c).map(|v| (v as f32 * 0.49).sin()).collect();
        let mut want = vec![0.0f32; d.rows() * d.patch()];
        im2col_f32(&x, &d, &mut want);
        let mut got = vec![0.0f32; want.len()];
        stage_direct_f32(&x, &mut got);
        assert_eq!(got, want);
        let mut want_q = vec![0u8; want.len()];
        im2col_quant_u8(&x, &d, 0.13, 3, &mut want_q);
        let mut got_q = vec![0u8; want.len()];
        quantize_direct_u8(&x, 0.13, 3, &mut got_q);
        assert_eq!(got_q, want_q);
    }

    #[test]
    fn quantized_matches_plain_quant() {
        let d = ConvDims::new(2, 5, 4, 3, 3, 3, [2, 1], [1, 0]);
        let x: Vec<f32> = (0..d.n * d.h * d.w * d.c)
            .map(|v| (v as f32 * 0.37).sin().abs())
            .collect();
        let mut cols = vec![0.0f32; d.rows() * d.patch()];
        im2col_f32(&x, &d, &mut cols);
        let mut q = vec![0u8; d.rows() * d.patch()];
        im2col_quant_u8(&x, &d, 0.11, 3, &mut q);
        for (c, qq) in cols.iter().zip(&q) {
            let want = ((c / 0.11).round()).clamp(0.0, 3.0) as u8;
            assert_eq!(want, *qq);
        }
    }
}

//! Elementwise activations + binary ops (match jax_exec semantics).
//!
//! [`ActKind`] is the value-level activation descriptor the execution
//! planner carries: fused conv epilogues and in-place activation
//! instructions both dispatch through it, and its scalar path performs the
//! exact same float operations as the slice functions below, so fused and
//! unfused execution stay bit-identical.

use crate::dlrt::graph::Op;

/// Scalar activation kinds the planner can fuse into a conv epilogue or
/// lower to an in-place slot mutation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActKind {
    Relu,
    Relu6,
    LeakyRelu,
    Silu,
    Sigmoid,
}

impl ActKind {
    pub fn from_op(op: &Op) -> Option<ActKind> {
        Some(match op {
            Op::Relu => ActKind::Relu,
            Op::Relu6 => ActKind::Relu6,
            Op::LeakyRelu => ActKind::LeakyRelu,
            Op::Silu => ActKind::Silu,
            Op::Sigmoid => ActKind::Sigmoid,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ActKind::Relu => "relu",
            ActKind::Relu6 => "relu6",
            ActKind::LeakyRelu => "leaky_relu",
            ActKind::Silu => "silu",
            ActKind::Sigmoid => "sigmoid",
        }
    }

    /// Same operations (and operation order) as the slice functions below —
    /// epilogue fusion must not change results.
    #[inline]
    pub fn apply_scalar(self, v: f32) -> f32 {
        match self {
            ActKind::Relu => {
                if v < 0.0 {
                    0.0
                } else {
                    v
                }
            }
            ActKind::Relu6 => v.clamp(0.0, 6.0),
            ActKind::LeakyRelu => {
                if v < 0.0 {
                    v * 0.1
                } else {
                    v
                }
            }
            ActKind::Silu => v * sigmoid_scalar(v),
            ActKind::Sigmoid => sigmoid_scalar(v),
        }
    }

    /// In-place slice application (delegates to the specialized loops).
    pub fn apply(self, x: &mut [f32]) {
        match self {
            ActKind::Relu => relu(x),
            ActKind::Relu6 => relu6(x),
            ActKind::LeakyRelu => leaky_relu(x),
            ActKind::Silu => silu(x),
            ActKind::Sigmoid => sigmoid(x),
        }
    }
}

pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

pub fn relu6(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.clamp(0.0, 6.0);
    }
}

#[inline]
pub fn sigmoid_scalar(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

pub fn sigmoid(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = sigmoid_scalar(*v);
    }
}

pub fn silu(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= sigmoid_scalar(*v);
    }
}

pub fn leaky_relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v *= 0.1;
        }
    }
}

pub fn add(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x + y;
    }
}

/// Channel-dim concat of NHWC tensors with equal spatial dims.
pub fn concat_channels(inputs: &[(&[f32], usize)], rows: usize, out: &mut [f32]) {
    let ctot: usize = inputs.iter().map(|(_, c)| c).sum();
    debug_assert_eq!(out.len(), rows * ctot);
    for r in 0..rows {
        let mut o = r * ctot;
        for (data, c) in inputs {
            out[o..o + c].copy_from_slice(&data[r * c..(r + 1) * c]);
            o += c;
        }
    }
}

/// Apply `kind` to `rows` rows of `c_in` channels from `src`, writing the
/// results into the channel stripe `[c_off, c_off + c_in)` of the
/// `rows × c_out` output — a standalone activation lowered to write
/// directly into its consuming concat's slot. Dispatches through
/// [`ActKind::apply`] on each stripe row, so the float ops are identical
/// to the dense copy-then-apply path (activations are elementwise; row
/// grouping cannot change results).
pub fn act_channels(
    kind: ActKind,
    src: &[f32],
    c_in: usize,
    c_out: usize,
    c_off: usize,
    rows: usize,
    out: &mut [f32],
) {
    act_view(kind, src, c_in, c_in, 0, rows, out, c_out, c_off);
}

/// The general strided activation: read `rows` rows of `c` channels at
/// column `in_off` of `in_stride`-wide source rows, apply `kind`, and
/// write them at column `out_off` of `out_stride`-wide output rows —
/// both sides of the planner's channel-stripe views. Dense on either
/// side when the stride equals `c` and the offset is 0; float ops match
/// [`act_channels`] / copy-then-[`ActKind::apply`] exactly.
#[allow(clippy::too_many_arguments)]
pub fn act_view(
    kind: ActKind,
    src: &[f32],
    c: usize,
    in_stride: usize,
    in_off: usize,
    rows: usize,
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
) {
    debug_assert!(in_off + c <= in_stride);
    debug_assert!(out_off + c <= out_stride);
    debug_assert!(src.len() >= rows.saturating_sub(1) * in_stride + in_off + c);
    debug_assert!(out.len() >= rows.saturating_sub(1) * out_stride + out_off + c);
    for r in 0..rows {
        let dst = &mut out[r * out_stride + out_off..][..c];
        dst.copy_from_slice(&src[r * in_stride + in_off..][..c]);
        kind.apply(dst);
    }
}

/// [`act_view`] over disjoint channel stripes of one buffer (an
/// activation consuming one concat-resident tensor and producing
/// another stripe of the same root). The caller guarantees the ranges
/// don't overlap, so every read sees the untouched input stripe.
pub fn act_same(
    kind: ActKind,
    buf: &mut [f32],
    c: usize,
    row_stride: usize,
    in_off: usize,
    out_off: usize,
    rows: usize,
) {
    debug_assert!(in_off + c <= row_stride && out_off + c <= row_stride);
    debug_assert!(in_off + c <= out_off || out_off + c <= in_off, "stripes overlap");
    debug_assert!(buf.len() >= rows.saturating_sub(1) * row_stride + out_off + c);
    for r in 0..rows {
        let base = r * row_stride;
        for ci in 0..c {
            buf[base + out_off + ci] = kind.apply_scalar(buf[base + in_off + ci]);
        }
    }
}

/// Copy one concat input into its channel stripe of the output: `rows` rows
/// of `c_in` channels from `src` land in columns `[c_off, c_off + c_in)` of
/// the `rows × c_out` output. The planned executor calls this once per
/// concat input so no per-call slice list is built on the hot path.
pub fn copy_channels(
    src: &[f32],
    c_in: usize,
    c_out: usize,
    c_off: usize,
    rows: usize,
    out: &mut [f32],
) {
    copy_channels_view(src, c_in, c_in, 0, rows, out, c_out, c_off);
}

/// [`copy_channels`] reading the source rows through a channel-stripe
/// view of a wider buffer (`in_stride`/`in_off`) — a concat copying an
/// input that is itself resident in another concat's root slot.
#[allow(clippy::too_many_arguments)]
pub fn copy_channels_view(
    src: &[f32],
    c: usize,
    in_stride: usize,
    in_off: usize,
    rows: usize,
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
) {
    debug_assert!(in_off + c <= in_stride);
    debug_assert!(out_off + c <= out_stride);
    debug_assert!(src.len() >= rows.saturating_sub(1) * in_stride + in_off + c);
    debug_assert!(out.len() >= rows.saturating_sub(1) * out_stride + out_off + c);
    for r in 0..rows {
        let o = r * out_stride + out_off;
        out[o..o + c].copy_from_slice(&src[r * in_stride + in_off..][..c]);
    }
}

/// [`copy_channels_view`] over disjoint channel stripes of one buffer (a
/// concat copying an input that lives in *this* concat's root — the
/// shared-root double-membership case).
pub fn copy_channels_same(
    buf: &mut [f32],
    c: usize,
    row_stride: usize,
    in_off: usize,
    out_off: usize,
    rows: usize,
) {
    debug_assert!(in_off + c <= row_stride && out_off + c <= row_stride);
    debug_assert!(in_off + c <= out_off || out_off + c <= in_off, "stripes overlap");
    debug_assert!(buf.len() >= rows.saturating_sub(1) * row_stride + out_off + c);
    for r in 0..rows {
        let base = r * row_stride;
        buf.copy_within(base + in_off..base + in_off + c, base + out_off);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activations() {
        let mut x = vec![-2.0, 0.0, 3.0, 8.0];
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 3.0, 8.0]);
        let mut x = vec![-2.0, 3.0, 8.0];
        relu6(&mut x);
        assert_eq!(x, vec![0.0, 3.0, 6.0]);
        let mut x = vec![-1.0, 1.0];
        leaky_relu(&mut x);
        assert_eq!(x, vec![-0.1, 1.0]);
        assert!((sigmoid_scalar(0.0) - 0.5).abs() < 1e-7);
        let mut x = vec![0.0];
        silu(&mut x);
        assert_eq!(x, vec![0.0]);
    }

    #[test]
    fn add_and_concat() {
        let mut out = vec![0.0; 3];
        add(&[1.0, 2.0, 3.0], &[10.0, 20.0, 30.0], &mut out);
        assert_eq!(out, vec![11.0, 22.0, 33.0]);

        // two rows: a has 2 channels, b has 1
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![9.0, 8.0];
        let mut out = vec![0.0; 6];
        concat_channels(&[(&a, 2), (&b, 1)], 2, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 9.0, 3.0, 4.0, 8.0]);

        // striped copy (planned path) reproduces the same layout
        let mut out2 = vec![0.0; 6];
        copy_channels(&a, 2, 3, 0, 2, &mut out2);
        copy_channels(&b, 1, 3, 2, 2, &mut out2);
        assert_eq!(out2, out);
    }

    #[test]
    fn act_channels_matches_copy_then_apply() {
        let mut rng = crate::util::rng::Rng::new(37);
        let (rows, c) = (6usize, 4usize);
        let src: Vec<f32> = (0..rows * c).map(|_| rng.normal()).collect();
        for kind in [ActKind::Relu, ActKind::Silu, ActKind::LeakyRelu] {
            let mut want = src.clone();
            kind.apply(&mut want);
            let (stride, off) = (9usize, 3usize);
            let mut out = vec![0.0f32; rows * stride];
            act_channels(kind, &src, c, stride, off, rows, &mut out);
            for r in 0..rows {
                assert_eq!(&out[r * stride + off..][..c], &want[r * c..][..c]);
            }
            // dense parameters reproduce the plain apply
            let mut dense = vec![0.0f32; rows * c];
            act_channels(kind, &src, c, c, 0, rows, &mut dense);
            assert_eq!(dense, want);
        }
    }

    /// act_view/copy_channels_view strided reads and the same-buffer
    /// stripe-to-stripe variants all reproduce densify-then-run exactly.
    #[test]
    fn view_and_same_buffer_variants_match_dense() {
        let mut rng = crate::util::rng::Rng::new(53);
        let (rows, c, stride) = (5usize, 3usize, 8usize);
        let mut wide = vec![0.0f32; rows * stride];
        for v in wide.iter_mut() {
            *v = rng.normal();
        }
        for (in_off, out_off) in [(0usize, 4usize), (5, 0), (2, 5)] {
            // dense oracle: extract the stripe, then act / copy
            let dense: Vec<f32> = (0..rows)
                .flat_map(|r| wide[r * stride + in_off..][..c].to_vec())
                .collect();
            for kind in [ActKind::Relu, ActKind::Silu, ActKind::Sigmoid] {
                let mut want = dense.clone();
                kind.apply(&mut want);
                // strided-in, dense-out
                let mut got = vec![0.0f32; rows * c];
                act_view(kind, &wide, c, stride, in_off, rows, &mut got, c, 0);
                assert_eq!(got, want, "{} in_off {in_off}", kind.name());
                // same-buffer stripe-to-stripe
                let mut buf = wide.clone();
                act_same(kind, &mut buf, c, stride, in_off, out_off, rows);
                for r in 0..rows {
                    assert_eq!(&buf[r * stride + out_off..][..c], &want[r * c..][..c]);
                    assert_eq!(&buf[r * stride + in_off..][..c],
                               &wide[r * stride + in_off..][..c],
                               "act_same clobbered its input stripe");
                }
            }
            // strided-in strided-out copy
            let mut got = vec![0.0f32; rows * stride];
            copy_channels_view(&wide, c, stride, in_off, rows, &mut got, stride, out_off);
            for r in 0..rows {
                assert_eq!(&got[r * stride + out_off..][..c], &dense[r * c..][..c]);
            }
            // same-buffer copy
            let mut buf = wide.clone();
            copy_channels_same(&mut buf, c, stride, in_off, out_off, rows);
            for r in 0..rows {
                assert_eq!(&buf[r * stride + out_off..][..c], &dense[r * c..][..c]);
            }
        }
    }

    #[test]
    fn act_kind_matches_slice_functions() {
        let vals = [-7.5f32, -1.0, -0.25, 0.0, 0.5, 3.0, 6.5, 42.0];
        for kind in [
            ActKind::Relu,
            ActKind::Relu6,
            ActKind::LeakyRelu,
            ActKind::Silu,
            ActKind::Sigmoid,
        ] {
            let mut slice = vals.to_vec();
            kind.apply(&mut slice);
            for (&v, &got) in vals.iter().zip(&slice) {
                let want = kind.apply_scalar(v);
                assert!(
                    want == got || (want.is_nan() && got.is_nan()),
                    "{}: scalar {want} vs slice {got} at input {v}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn act_kind_op_mapping() {
        use crate::dlrt::graph::Op;
        assert_eq!(ActKind::from_op(&Op::Relu), Some(ActKind::Relu));
        assert_eq!(ActKind::from_op(&Op::Silu), Some(ActKind::Silu));
        assert_eq!(ActKind::from_op(&Op::Add), None);
        assert_eq!(ActKind::from_op(&Op::Flatten), None);
    }
}

//! Elementwise activations + binary ops (match jax_exec semantics).

pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

pub fn relu6(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.clamp(0.0, 6.0);
    }
}

#[inline]
pub fn sigmoid_scalar(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

pub fn sigmoid(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = sigmoid_scalar(*v);
    }
}

pub fn silu(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= sigmoid_scalar(*v);
    }
}

pub fn leaky_relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v *= 0.1;
        }
    }
}

pub fn add(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x + y;
    }
}

/// Channel-dim concat of NHWC tensors with equal spatial dims.
pub fn concat_channels(inputs: &[(&[f32], usize)], rows: usize, out: &mut [f32]) {
    let ctot: usize = inputs.iter().map(|(_, c)| c).sum();
    debug_assert_eq!(out.len(), rows * ctot);
    for r in 0..rows {
        let mut o = r * ctot;
        for (data, c) in inputs {
            out[o..o + c].copy_from_slice(&data[r * c..(r + 1) * c]);
            o += c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activations() {
        let mut x = vec![-2.0, 0.0, 3.0, 8.0];
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 3.0, 8.0]);
        let mut x = vec![-2.0, 3.0, 8.0];
        relu6(&mut x);
        assert_eq!(x, vec![0.0, 3.0, 6.0]);
        let mut x = vec![-1.0, 1.0];
        leaky_relu(&mut x);
        assert_eq!(x, vec![-0.1, 1.0]);
        assert!((sigmoid_scalar(0.0) - 0.5).abs() < 1e-7);
        let mut x = vec![0.0];
        silu(&mut x);
        assert_eq!(x, vec![0.0]);
    }

    #[test]
    fn add_and_concat() {
        let mut out = vec![0.0; 3];
        add(&[1.0, 2.0, 3.0], &[10.0, 20.0, 30.0], &mut out);
        assert_eq!(out, vec![11.0, 22.0, 33.0]);

        // two rows: a has 2 channels, b has 1
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![9.0, 8.0];
        let mut out = vec![0.0; 6];
        concat_channels(&[(&a, 2), (&b, 1)], 2, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 9.0, 3.0, 4.0, 8.0]);
    }
}

//! Pooling ops (NHWC).

use crate::dlrt::graph::conv_out_hw;

/// Max pool; out-of-image taps act as -inf (matches jax reduce_window).
pub fn maxpool2d(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    kernel: [usize; 2],
    stride: [usize; 2],
    padding: [usize; 2],
    out: &mut [f32],
) {
    maxpool2d_strided(x, n, h, w, c, kernel, stride, padding, out, c, 0);
}

/// [`maxpool2d`] writing each output pixel's `c` channels at column
/// `out_off` of a row `out_stride` wide — the concat-in-place lowering's
/// stride-aware write path (`out_stride == c`, `out_off == 0` is dense).
/// Same taps, same compare order: bit-identical to the dense pool.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2d_strided(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    kernel: [usize; 2],
    stride: [usize; 2],
    padding: [usize; 2],
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
) {
    let (oh, ow) = conv_out_hw(h, w, kernel, stride, padding);
    debug_assert!(out_off + c <= out_stride);
    debug_assert!(out.len() >= (n * oh * ow).saturating_sub(1) * out_stride + out_off + c);
    let (ph, pw) = (padding[0] as isize, padding[1] as isize);
    for ni in 0..n {
        let xn = &x[ni * h * w * c..][..h * w * c];
        for oy in 0..oh {
            let iy0 = (oy * stride[0]) as isize - ph;
            for ox in 0..ow {
                let ix0 = (ox * stride[1]) as isize - pw;
                let obase = ((ni * oh + oy) * ow + ox) * out_stride + out_off;
                let orow = &mut out[obase..obase + c];
                orow.fill(f32::NEG_INFINITY);
                for ky in 0..kernel[0] {
                    let iy = iy0 + ky as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kernel[1] {
                        let ix = ix0 + kx as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = (iy as usize * w + ix as usize) * c;
                        for ci in 0..c {
                            let v = xn[src + ci];
                            if v > orow[ci] {
                                orow[ci] = v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Global average pool: NHWC → (N, C).
pub fn global_avg_pool(x: &[f32], n: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), n * c);
    let inv = 1.0 / (h * w) as f32;
    for ni in 0..n {
        let acc = &mut out[ni * c..(ni + 1) * c];
        acc.fill(0.0);
        let xn = &x[ni * h * w * c..][..h * w * c];
        for px in xn.chunks(c) {
            for (a, v) in acc.iter_mut().zip(px) {
                *a += v;
            }
        }
        for a in acc.iter_mut() {
            *a *= inv;
        }
    }
}

/// Nearest-neighbor 2x upsample.
pub fn upsample2x(x: &[f32], n: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    upsample2x_strided(x, n, h, w, c, out, c, 0);
}

/// [`upsample2x`] with stride-aware writes into a channel stripe of a
/// wider output row (see [`maxpool2d_strided`]).
#[allow(clippy::too_many_arguments)]
pub fn upsample2x_strided(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
) {
    debug_assert!(out_off + c <= out_stride);
    debug_assert!(out.len() >= (n * 4 * h * w).saturating_sub(1) * out_stride + out_off + c);
    let (oh, ow) = (2 * h, 2 * w);
    for ni in 0..n {
        for oy in 0..oh {
            let iy = oy / 2;
            for ox in 0..ow {
                let ix = ox / 2;
                let src = ((ni * h + iy) * w + ix) * c;
                let dst = ((ni * oh + oy) * ow + ox) * out_stride + out_off;
                out[dst..dst + c].copy_from_slice(&x[src..src + c]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_2x2() {
        // 1x4x4x1 ramp
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut out = vec![0.0; 4];
        maxpool2d(&x, 1, 4, 4, 1, [2, 2], [2, 2], [0, 0], &mut out);
        assert_eq!(out, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn maxpool_padding_ignores_outside() {
        let x = vec![-1.0, -2.0, -3.0, -4.0]; // 1x2x2x1, all negative
        let mut out = vec![0.0; 4];
        maxpool2d(&x, 1, 2, 2, 1, [2, 2], [2, 2], [1, 1], &mut out);
        // each window sees exactly one image pixel
        assert_eq!(out, vec![-1.0, -2.0, -3.0, -4.0]);
    }

    #[test]
    fn gap_means() {
        let x = vec![1.0, 10.0, 3.0, 20.0, 5.0, 30.0, 7.0, 40.0]; // 1x2x2x2
        let mut out = vec![0.0; 2];
        global_avg_pool(&x, 1, 2, 2, 2, &mut out);
        assert_eq!(out, vec![4.0, 25.0]);
    }

    #[test]
    fn strided_writes_match_dense() {
        // pool/upsample stride-aware writes place bit-identical values in
        // their channel stripe of a wider row (concat-in-place lowering)
        let mut rng = crate::util::rng::Rng::new(31);
        let (n, h, w, c) = (2usize, 5usize, 4usize, 3usize);
        let x: Vec<f32> = (0..n * h * w * c).map(|_| rng.normal()).collect();
        let (stride, off) = (8usize, 2usize);

        let (oh, ow) = conv_out_hw(h, w, [2, 2], [2, 2], [1, 1]);
        let mut dense = vec![0.0f32; n * oh * ow * c];
        maxpool2d(&x, n, h, w, c, [2, 2], [2, 2], [1, 1], &mut dense);
        let mut strided = vec![0.0f32; n * oh * ow * stride];
        maxpool2d_strided(&x, n, h, w, c, [2, 2], [2, 2], [1, 1], &mut strided, stride, off);
        for r in 0..n * oh * ow {
            assert_eq!(&strided[r * stride + off..][..c], &dense[r * c..][..c], "pool row {r}");
        }

        let mut dense = vec![0.0f32; n * 4 * h * w * c];
        upsample2x(&x, n, h, w, c, &mut dense);
        let mut strided = vec![0.0f32; n * 4 * h * w * stride];
        upsample2x_strided(&x, n, h, w, c, &mut strided, stride, off);
        for r in 0..n * 4 * h * w {
            assert_eq!(&strided[r * stride + off..][..c], &dense[r * c..][..c], "up row {r}");
        }
    }

    #[test]
    fn upsample_nearest() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 1x2x2x1
        let mut out = vec![0.0; 16];
        upsample2x(&x, 1, 2, 2, 1, &mut out);
        assert_eq!(out[0..4], [1.0, 1.0, 2.0, 2.0]);
        assert_eq!(out[12..16], [3.0, 3.0, 4.0, 4.0]);
    }
}

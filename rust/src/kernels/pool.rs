//! Pooling ops (NHWC).

use crate::dlrt::graph::conv_out_hw;

/// Max pool; out-of-image taps act as -inf (matches jax reduce_window).
pub fn maxpool2d(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    kernel: [usize; 2],
    stride: [usize; 2],
    padding: [usize; 2],
    out: &mut [f32],
) {
    maxpool2d_strided(x, n, h, w, c, kernel, stride, padding, out, c, 0);
}

/// [`maxpool2d`] writing each output pixel's `c` channels at column
/// `out_off` of a row `out_stride` wide — the concat-in-place lowering's
/// stride-aware write path (`out_stride == c`, `out_off == 0` is dense).
/// Same taps, same compare order: bit-identical to the dense pool.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2d_strided(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    kernel: [usize; 2],
    stride: [usize; 2],
    padding: [usize; 2],
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
) {
    maxpool2d_view(x, n, h, w, c, kernel, stride, padding, c, 0, out, out_stride, out_off);
}

/// The general max pool: reads each input pixel's `c` channels at column
/// `in_off` of a row `in_stride` wide *and* writes each output pixel at
/// column `out_off` of a row `out_stride` wide — both sides of the
/// planner's channel-stripe views (a pool consuming one concat-resident
/// tensor and producing another). Dense on either side when the stride
/// equals `c` and the offset is 0. Same taps, same compare order as
/// [`maxpool2d`]: bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2d_view(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    kernel: [usize; 2],
    stride: [usize; 2],
    padding: [usize; 2],
    in_stride: usize,
    in_off: usize,
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
) {
    let (oh, ow) = conv_out_hw(h, w, kernel, stride, padding);
    debug_assert!(in_off + c <= in_stride);
    debug_assert!(out_off + c <= out_stride);
    debug_assert!(x.len() >= n * h * w * in_stride);
    debug_assert!(out.len() >= (n * oh * ow).saturating_sub(1) * out_stride + out_off + c);
    let (ph, pw) = (padding[0] as isize, padding[1] as isize);
    for ni in 0..n {
        let xn = &x[ni * h * w * in_stride..][..h * w * in_stride];
        for oy in 0..oh {
            let iy0 = (oy * stride[0]) as isize - ph;
            for ox in 0..ow {
                let ix0 = (ox * stride[1]) as isize - pw;
                let obase = ((ni * oh + oy) * ow + ox) * out_stride + out_off;
                let orow = &mut out[obase..obase + c];
                orow.fill(f32::NEG_INFINITY);
                for ky in 0..kernel[0] {
                    let iy = iy0 + ky as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kernel[1] {
                        let ix = ix0 + kx as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = (iy as usize * w + ix as usize) * in_stride + in_off;
                        for ci in 0..c {
                            let v = xn[src + ci];
                            if v > orow[ci] {
                                orow[ci] = v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// [`maxpool2d_view`] where input and output are *disjoint channel
/// stripes of the same buffer* — the SPPF serial-pool pyramid, where each
/// pool reads the previous level's stripe of the concat root slot and
/// writes the next level's stripe of the same slot. One row stride serves
/// both sides (same root ⇒ same row width); the caller (and
/// `ExecPlan::validate`) guarantees `in_off`/`out_off` ranges don't
/// overlap, so every read sees the untouched input stripe. Same taps and
/// compare order as [`maxpool2d`]: bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2d_same(
    buf: &mut [f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    kernel: [usize; 2],
    stride: [usize; 2],
    padding: [usize; 2],
    row_stride: usize,
    in_off: usize,
    out_off: usize,
) {
    let (oh, ow) = conv_out_hw(h, w, kernel, stride, padding);
    debug_assert!(in_off + c <= row_stride && out_off + c <= row_stride);
    debug_assert!(in_off + c <= out_off || out_off + c <= in_off, "stripes overlap");
    debug_assert!(buf.len() >= n * h * w * row_stride);
    debug_assert!(
        buf.len() >= (n * oh * ow).saturating_sub(1) * row_stride + out_off + c
    );
    let (ph, pw) = (padding[0] as isize, padding[1] as isize);
    for ni in 0..n {
        let ibase = ni * h * w * row_stride;
        for oy in 0..oh {
            let iy0 = (oy * stride[0]) as isize - ph;
            for ox in 0..ow {
                let ix0 = (ox * stride[1]) as isize - pw;
                let obase = ((ni * oh + oy) * ow + ox) * row_stride + out_off;
                for ci in 0..c {
                    buf[obase + ci] = f32::NEG_INFINITY;
                }
                for ky in 0..kernel[0] {
                    let iy = iy0 + ky as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kernel[1] {
                        let ix = ix0 + kx as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src =
                            ibase + (iy as usize * w + ix as usize) * row_stride + in_off;
                        for ci in 0..c {
                            let v = buf[src + ci];
                            if v > buf[obase + ci] {
                                buf[obase + ci] = v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Global average pool: NHWC → (N, C).
pub fn global_avg_pool(x: &[f32], n: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    global_avg_pool_view(x, n, h, w, c, c, 0, out);
}

/// [`global_avg_pool`] reading each pixel's `c` channels at column
/// `in_off` of a row `in_stride` wide (a concat-resident input). Same
/// accumulation order: bit-identical to densify-then-pool.
#[allow(clippy::too_many_arguments)]
pub fn global_avg_pool_view(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    in_stride: usize,
    in_off: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), n * c);
    debug_assert!(in_off + c <= in_stride);
    debug_assert!(x.len() >= n * h * w * in_stride);
    let inv = 1.0 / (h * w) as f32;
    for ni in 0..n {
        let acc = &mut out[ni * c..(ni + 1) * c];
        acc.fill(0.0);
        let xn = &x[ni * h * w * in_stride..][..h * w * in_stride];
        for px in xn.chunks(in_stride) {
            for (a, v) in acc.iter_mut().zip(&px[in_off..in_off + c]) {
                *a += v;
            }
        }
        for a in acc.iter_mut() {
            *a *= inv;
        }
    }
}

/// Nearest-neighbor 2x upsample.
pub fn upsample2x(x: &[f32], n: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    upsample2x_strided(x, n, h, w, c, out, c, 0);
}

/// [`upsample2x`] with stride-aware writes into a channel stripe of a
/// wider output row (see [`maxpool2d_strided`]).
#[allow(clippy::too_many_arguments)]
pub fn upsample2x_strided(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
) {
    upsample2x_view(x, n, h, w, c, c, 0, out, out_stride, out_off);
}

/// The general nearest-neighbor 2x upsample: strided reads *and* strided
/// writes (see [`maxpool2d_view`]) — a PANet skip tensor resident in one
/// concat root upsampled straight into its stripe of another.
#[allow(clippy::too_many_arguments)]
pub fn upsample2x_view(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    in_stride: usize,
    in_off: usize,
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
) {
    debug_assert!(in_off + c <= in_stride);
    debug_assert!(out_off + c <= out_stride);
    debug_assert!(x.len() >= n * h * w * in_stride);
    debug_assert!(out.len() >= (n * 4 * h * w).saturating_sub(1) * out_stride + out_off + c);
    let (oh, ow) = (2 * h, 2 * w);
    for ni in 0..n {
        for oy in 0..oh {
            let iy = oy / 2;
            for ox in 0..ow {
                let ix = ox / 2;
                let src = ((ni * h + iy) * w + ix) * in_stride + in_off;
                let dst = ((ni * oh + oy) * ow + ox) * out_stride + out_off;
                out[dst..dst + c].copy_from_slice(&x[src..src + c]);
            }
        }
    }
}

/// [`upsample2x_view`] over disjoint channel stripes of one buffer (see
/// [`maxpool2d_same`]). Spatial dims double, so a planner-produced plan
/// never hits this (same root ⇒ same spatial), but the executor supports
/// every validated plan shape.
#[allow(clippy::too_many_arguments)]
pub fn upsample2x_same(
    buf: &mut [f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    row_stride: usize,
    in_off: usize,
    out_off: usize,
) {
    debug_assert!(in_off + c <= row_stride && out_off + c <= row_stride);
    debug_assert!(in_off + c <= out_off || out_off + c <= in_off, "stripes overlap");
    debug_assert!(buf.len() >= n * h * w * row_stride);
    debug_assert!(
        buf.len() >= (n * 4 * h * w).saturating_sub(1) * row_stride + out_off + c
    );
    let (oh, ow) = (2 * h, 2 * w);
    for ni in 0..n {
        for oy in 0..oh {
            let iy = oy / 2;
            for ox in 0..ow {
                let ix = ox / 2;
                let src = ((ni * h + iy) * w + ix) * row_stride + in_off;
                let dst = ((ni * oh + oy) * ow + ox) * row_stride + out_off;
                buf.copy_within(src..src + c, dst);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_2x2() {
        // 1x4x4x1 ramp
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut out = vec![0.0; 4];
        maxpool2d(&x, 1, 4, 4, 1, [2, 2], [2, 2], [0, 0], &mut out);
        assert_eq!(out, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn maxpool_padding_ignores_outside() {
        let x = vec![-1.0, -2.0, -3.0, -4.0]; // 1x2x2x1, all negative
        let mut out = vec![0.0; 4];
        maxpool2d(&x, 1, 2, 2, 1, [2, 2], [2, 2], [1, 1], &mut out);
        // each window sees exactly one image pixel
        assert_eq!(out, vec![-1.0, -2.0, -3.0, -4.0]);
    }

    #[test]
    fn gap_means() {
        let x = vec![1.0, 10.0, 3.0, 20.0, 5.0, 30.0, 7.0, 40.0]; // 1x2x2x2
        let mut out = vec![0.0; 2];
        global_avg_pool(&x, 1, 2, 2, 2, &mut out);
        assert_eq!(out, vec![4.0, 25.0]);
    }

    #[test]
    fn strided_writes_match_dense() {
        // pool/upsample stride-aware writes place bit-identical values in
        // their channel stripe of a wider row (concat-in-place lowering)
        let mut rng = crate::util::rng::Rng::new(31);
        let (n, h, w, c) = (2usize, 5usize, 4usize, 3usize);
        let x: Vec<f32> = (0..n * h * w * c).map(|_| rng.normal()).collect();
        let (stride, off) = (8usize, 2usize);

        let (oh, ow) = conv_out_hw(h, w, [2, 2], [2, 2], [1, 1]);
        let mut dense = vec![0.0f32; n * oh * ow * c];
        maxpool2d(&x, n, h, w, c, [2, 2], [2, 2], [1, 1], &mut dense);
        let mut strided = vec![0.0f32; n * oh * ow * stride];
        maxpool2d_strided(&x, n, h, w, c, [2, 2], [2, 2], [1, 1], &mut strided, stride, off);
        for r in 0..n * oh * ow {
            assert_eq!(&strided[r * stride + off..][..c], &dense[r * c..][..c], "pool row {r}");
        }

        let mut dense = vec![0.0f32; n * 4 * h * w * c];
        upsample2x(&x, n, h, w, c, &mut dense);
        let mut strided = vec![0.0f32; n * 4 * h * w * stride];
        upsample2x_strided(&x, n, h, w, c, &mut strided, stride, off);
        for r in 0..n * 4 * h * w {
            assert_eq!(&strided[r * stride + off..][..c], &dense[r * c..][..c], "up row {r}");
        }
    }

    /// Strided *reads*: embed the input as a channel stripe of a wider
    /// buffer (poisoned elsewhere) and pool/upsample/gap through the view
    /// — bit-exact vs densify-then-run, across off/stride sweeps and a
    /// padded pool whose windows cross the image border.
    #[test]
    fn strided_reads_match_densify_then_run() {
        let mut rng = crate::util::rng::Rng::new(41);
        let (n, h, w, c) = (2usize, 5usize, 4usize, 3usize);
        let x: Vec<f32> = (0..n * h * w * c).map(|_| rng.normal()).collect();
        for (stride, off) in [(3usize, 0usize), (7, 0), (7, 2), (7, 4), (10, 5)] {
            let mut wide = vec![f32::NAN; n * h * w * stride];
            for px in 0..n * h * w {
                wide[px * stride + off..px * stride + off + c]
                    .copy_from_slice(&x[px * c..(px + 1) * c]);
            }
            for (k, s, p) in [(2usize, 2usize, 1usize), (3, 1, 1), (2, 2, 0)] {
                let (oh, ow) = conv_out_hw(h, w, [k, k], [s, s], [p, p]);
                let mut want = vec![0.0f32; n * oh * ow * c];
                maxpool2d(&x, n, h, w, c, [k, k], [s, s], [p, p], &mut want);
                let mut got = vec![0.0f32; n * oh * ow * c];
                maxpool2d_view(&wide, n, h, w, c, [k, k], [s, s], [p, p], stride, off,
                               &mut got, c, 0);
                assert_eq!(got, want, "pool k{k}s{s}p{p} stride {stride} off {off}");
            }
            let mut want = vec![0.0f32; n * 4 * h * w * c];
            upsample2x(&x, n, h, w, c, &mut want);
            let mut got = vec![0.0f32; n * 4 * h * w * c];
            upsample2x_view(&wide, n, h, w, c, stride, off, &mut got, c, 0);
            assert_eq!(got, want, "upsample stride {stride} off {off}");

            let mut want = vec![0.0f32; n * c];
            global_avg_pool(&x, n, h, w, c, &mut want);
            let mut got = vec![0.0f32; n * c];
            global_avg_pool_view(&wide, n, h, w, c, stride, off, &mut got);
            assert_eq!(got, want, "gap stride {stride} off {off}");
        }
    }

    /// Same-buffer stripe-to-stripe (the SPPF pattern): pooling stripe A
    /// into stripe B of one buffer matches the two-buffer strided pool,
    /// and leaves stripe A untouched.
    #[test]
    fn same_buffer_stripe_to_stripe_matches_two_buffer() {
        let mut rng = crate::util::rng::Rng::new(43);
        let (n, h, w, c, stride) = (2usize, 4usize, 4usize, 3usize, 9usize);
        for (in_off, out_off) in [(0usize, 3usize), (0, 6), (6, 0), (3, 6)] {
            let mut buf = vec![0.0f32; n * h * w * stride];
            for v in buf.iter_mut() {
                *v = rng.normal();
            }
            let orig = buf.clone();
            // two-buffer oracle: same strided read, separate output
            let mut want = vec![0.0f32; n * h * w * c];
            maxpool2d_view(&orig, n, h, w, c, [3, 3], [1, 1], [1, 1], stride, in_off,
                           &mut want, c, 0);
            maxpool2d_same(&mut buf, n, h, w, c, [3, 3], [1, 1], [1, 1], stride, in_off,
                           out_off);
            for px in 0..n * h * w {
                assert_eq!(&buf[px * stride + out_off..][..c], &want[px * c..][..c],
                           "pool out px {px} in_off {in_off} out_off {out_off}");
                assert_eq!(&buf[px * stride + in_off..][..c],
                           &orig[px * stride + in_off..][..c],
                           "pool clobbered its input stripe at px {px}");
            }

            // upsample same-buffer (h halved so 2x fits the same rows)
            let (uh, uw) = (h / 2, w / 2);
            let mut buf = orig.clone();
            let mut want = vec![0.0f32; n * 4 * uh * uw * c];
            upsample2x_view(&orig, n, uh, uw, c, stride, in_off, &mut want, c, 0);
            upsample2x_same(&mut buf, n, uh, uw, c, stride, in_off, out_off);
            for px in 0..n * 4 * uh * uw {
                assert_eq!(&buf[px * stride + out_off..][..c], &want[px * c..][..c],
                           "upsample out px {px}");
            }
        }
    }

    #[test]
    fn upsample_nearest() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 1x2x2x1
        let mut out = vec![0.0; 16];
        upsample2x(&x, 1, 2, 2, 1, &mut out);
        assert_eq!(out[0..4], [1.0, 1.0, 2.0, 2.0]);
        assert_eq!(out[12..16], [3.0, 3.0, 4.0, 4.0]);
    }
}

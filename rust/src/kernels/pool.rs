//! Pooling ops (NHWC).

use crate::dlrt::graph::conv_out_hw;

/// Max pool; out-of-image taps act as -inf (matches jax reduce_window).
pub fn maxpool2d(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    kernel: [usize; 2],
    stride: [usize; 2],
    padding: [usize; 2],
    out: &mut [f32],
) {
    let (oh, ow) = conv_out_hw(h, w, kernel, stride, padding);
    debug_assert_eq!(out.len(), n * oh * ow * c);
    let (ph, pw) = (padding[0] as isize, padding[1] as isize);
    for ni in 0..n {
        let xn = &x[ni * h * w * c..][..h * w * c];
        for oy in 0..oh {
            let iy0 = (oy * stride[0]) as isize - ph;
            for ox in 0..ow {
                let ix0 = (ox * stride[1]) as isize - pw;
                let obase = ((ni * oh + oy) * ow + ox) * c;
                let orow = &mut out[obase..obase + c];
                orow.fill(f32::NEG_INFINITY);
                for ky in 0..kernel[0] {
                    let iy = iy0 + ky as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kernel[1] {
                        let ix = ix0 + kx as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = (iy as usize * w + ix as usize) * c;
                        for ci in 0..c {
                            let v = xn[src + ci];
                            if v > orow[ci] {
                                orow[ci] = v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Global average pool: NHWC → (N, C).
pub fn global_avg_pool(x: &[f32], n: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), n * c);
    let inv = 1.0 / (h * w) as f32;
    for ni in 0..n {
        let acc = &mut out[ni * c..(ni + 1) * c];
        acc.fill(0.0);
        let xn = &x[ni * h * w * c..][..h * w * c];
        for px in xn.chunks(c) {
            for (a, v) in acc.iter_mut().zip(px) {
                *a += v;
            }
        }
        for a in acc.iter_mut() {
            *a *= inv;
        }
    }
}

/// Nearest-neighbor 2x upsample.
pub fn upsample2x(x: &[f32], n: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), n * 4 * h * w * c);
    let (oh, ow) = (2 * h, 2 * w);
    for ni in 0..n {
        for oy in 0..oh {
            let iy = oy / 2;
            for ox in 0..ow {
                let ix = ox / 2;
                let src = ((ni * h + iy) * w + ix) * c;
                let dst = ((ni * oh + oy) * ow + ox) * c;
                out[dst..dst + c].copy_from_slice(&x[src..src + c]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_2x2() {
        // 1x4x4x1 ramp
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut out = vec![0.0; 4];
        maxpool2d(&x, 1, 4, 4, 1, [2, 2], [2, 2], [0, 0], &mut out);
        assert_eq!(out, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn maxpool_padding_ignores_outside() {
        let x = vec![-1.0, -2.0, -3.0, -4.0]; // 1x2x2x1, all negative
        let mut out = vec![0.0; 4];
        maxpool2d(&x, 1, 2, 2, 1, [2, 2], [2, 2], [1, 1], &mut out);
        // each window sees exactly one image pixel
        assert_eq!(out, vec![-1.0, -2.0, -3.0, -4.0]);
    }

    #[test]
    fn gap_means() {
        let x = vec![1.0, 10.0, 3.0, 20.0, 5.0, 30.0, 7.0, 40.0]; // 1x2x2x2
        let mut out = vec![0.0; 2];
        global_avg_pool(&x, 1, 2, 2, 2, &mut out);
        assert_eq!(out, vec![4.0, 25.0]);
    }

    #[test]
    fn upsample_nearest() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 1x2x2x1
        let mut out = vec![0.0; 16];
        upsample2x(&x, 1, 2, 2, 1, &mut out);
        assert_eq!(out[0..4], [1.0, 1.0, 2.0, 2.0]);
        assert_eq!(out[12..16], [3.0, 3.0, 4.0, 4.0]);
    }
}

//! The portable micro-kernel entry: the tiled scalar bitserial GEMM (the
//! pre-registry `kernels::bitserial` code, reading planes through a
//! [`PackedW`] so it also accepts padded layouts), the scalar int8 GEMM,
//! and the blocked fp32 GEMM. Always available; the bit-exactness oracle
//! every SIMD entry is tested against.

use super::{Isa, PackedW, UKernel, UKernelDesc};
use crate::dlrt::graph::qp_qn;
use crate::dlrt::tensor::Packed;
use crate::kernels::bitserial::{dot_planes_raw, row_code_sum, MAX_TILE_M, TILE_M, TILE_N};
use crate::util::threads;

pub static KERNEL: UKernel = UKernel {
    desc: UKernelDesc { isa: Isa::Scalar, tile_m: TILE_M, tile_n: TILE_N, k_unroll: 2 },
    gemm_bit,
    gemm_u8i8: crate::kernels::int8::gemm_u8i8_i32,
    gemm_f32: crate::kernels::fp32::gemm_rowmajor_bt,
};

/// Tiled scalar bitserial GEMM over a prepacked weight layout. Identical
/// loop nest and arithmetic to `bitserial::gemm_bitserial_tiled`, but the
/// weight planes are read at `w.plane_stride` spacing so both `RowMajor`
/// and chunk-padded `TileN` layouts work (padding words are zero and a
/// plane dot only reads the first `words_per_row` of each plane). Tile
/// geometry comes from `desc` (default or tuned); blocking never changes
/// the integer result, only the cache walk.
pub(super) fn gemm_bit(
    desc: &UKernelDesc,
    a: &Packed,
    w: &PackedW,
    w_bits_signed: usize,
    out: &mut [i32],
    nthreads: usize,
) {
    assert_eq!(a.k, w.k, "reduction dim mismatch");
    assert_eq!(a.words_per_row, w.words_per_row);
    let (m, n) = (a.rows, w.rows);
    assert_eq!(out.len(), m * n);
    let (_, qn) = qp_qn(w_bits_signed as u8, true);
    if m == 0 || n == 0 {
        return;
    }
    let (tile_m, tile_n) = (desc.tile_m.clamp(1, MAX_TILE_M), desc.tile_n.max(1));
    let nwords = a.words_per_row;

    threads::par_chunks_rows(out, n, nthreads, |row0, chunk| {
        let rows = chunk.len() / n;
        let mut corr = [0i32; MAX_TILE_M];
        let mut mt = 0;
        while mt < rows {
            let mt_end = (mt + tile_m).min(rows);
            for (c, mi) in corr.iter_mut().zip(mt..mt_end) {
                *c = qn * row_code_sum(a, row0 + mi);
            }
            let mut nt = 0;
            while nt < n {
                let nt_end = (nt + tile_n).min(n);
                for mi in mt..mt_end {
                    let c = corr[mi - mt];
                    let abase = (row0 + mi) * a.bits * nwords;
                    let adata = &a.data[abase..abase + a.bits * nwords];
                    let orow = &mut chunk[mi * n + nt..mi * n + nt_end];
                    for (j, o) in orow.iter_mut().enumerate() {
                        let wdata = &w.data[(nt + j) * w.bits * w.plane_stride..];
                        *o = dot_planes_raw(adata, a.bits, wdata, w.bits, nwords, w.plane_stride)
                            - c;
                    }
                }
                nt = nt_end;
            }
            mt = mt_end;
        }
    });
}

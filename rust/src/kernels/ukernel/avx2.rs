//! AVX2 micro-kernels (x86-64): a nibble-LUT popcount bitserial GEMM and a
//! widening `pmaddwd` int8 GEMM.
//!
//! The bitserial inner loop is the paper's VAND+VCNT+VPADAL structure on
//! 256-bit registers: AND packed planes, per-byte popcount via
//! `_mm256_shuffle_epi8` against a 16-entry nibble table, accumulate bytes
//! (each ≤ 8, so 31 chunks stay < 256), and flush to four u64 lanes with
//! `_mm256_sad_epu8`. Weight planes arrive chunk-padded (`WLayout::TileN`),
//! so every weight load is a whole in-bounds vector; the activation tail is
//! staged once per (row, plane) into a zero-padded stack chunk — zero words
//! AND to zero and contribute no popcount, keeping padding value-neutral.
//!
//! The int8 path widens u8/i8 to i16 (`cvtepu8`/`cvtepi8`) before
//! `_mm256_madd_epi16`: products reach 255·127 and pair-sums 64770, which
//! overflow the i16 saturation of `maddubs` but are exact in i32 — and i32
//! wrapping addition is associative, so lane order cannot change results
//! and the kernel stays bit-identical to the scalar reference.

use std::arch::x86_64::*;

use super::{Isa, PackedW, UKernel, UKernelDesc};
use crate::dlrt::graph::qp_qn;
use crate::dlrt::tensor::Packed;
use crate::kernels::bitserial::{row_code_sum, MAX_BITS};
use crate::util::threads;

/// `u64` words per 256-bit chunk.
const CHUNK: usize = 4;
/// Chunks between byte-accumulator flushes (per-byte counts ≤ 8·31 < 256).
const FLUSH: usize = 31;
/// M (activation-row) tile: corrections + staged plane tails per block.
const TILE_M: usize = 32;
/// N (output-channel) tile: weight planes kept L1-hot across an M-tile.
const TILE_N: usize = 16;

pub static KERNEL: UKernel = UKernel {
    desc: UKernelDesc { isa: Isa::Avx2, tile_m: TILE_M, tile_n: TILE_N, k_unroll: CHUNK },
    gemm_bit,
    gemm_u8i8,
    gemm_f32: crate::kernels::fp32::gemm_rowmajor_bt,
};

fn gemm_bit(
    desc: &UKernelDesc,
    a: &Packed,
    w: &PackedW,
    w_bits_signed: usize,
    out: &mut [i32],
    nthreads: usize,
) {
    assert_eq!(a.k, w.k, "reduction dim mismatch");
    assert_eq!(a.words_per_row, w.words_per_row);
    assert_eq!(w.plane_stride % CHUNK, 0, "AVX2 kernel needs chunk-padded weight planes");
    assert!(a.bits <= MAX_BITS && w.bits <= MAX_BITS);
    let (m, n) = (a.rows, w.rows);
    assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let (_, qn) = qp_qn(w_bits_signed as u8, true);
    // tuned geometry: M clamps to the stack-staged block (corrections +
    // activation tail chunks are const-sized), N is free loop blocking
    let tile_m = desc.tile_m.clamp(1, TILE_M);
    let tile_n = desc.tile_n.max(1);
    threads::par_chunks_rows(out, n, nthreads, |row0, chunk| {
        // SAFETY: this entry is only reachable through the registry, which
        // hands out the AVX2 kernel after `is_x86_feature_detected!("avx2")`
        // succeeded (`host_supports`), satisfying the target_feature
        // contract of `bit_rows_block`.
        unsafe { bit_rows_block(a, w, qn, row0, chunk, n, tile_m, tile_n) }
    });
}

/// One worker's block of whole output rows, tiled `tile_m`×`tile_n` like the
/// scalar kernel (exact integer arithmetic — tiling cannot change results).
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn bit_rows_block(
    a: &Packed,
    w: &PackedW,
    qn: i32,
    row0: usize,
    chunk: &mut [i32],
    n: usize,
    tile_m: usize,
    tile_n: usize,
) {
    let rows = chunk.len() / n;
    let nwords = a.words_per_row;
    let full = nwords / CHUNK * CHUNK;
    let tail = nwords - full;
    // per-row signed-offset corrections and zero-padded activation tail
    // chunks for the current M-tile (weight planes are pre-padded)
    let mut corr = [0i32; TILE_M];
    let mut tails = [[0u64; CHUNK]; TILE_M * MAX_BITS];
    let mut mt = 0;
    while mt < rows {
        let mt_end = (mt + tile_m).min(rows);
        for mi in mt..mt_end {
            corr[mi - mt] = qn * row_code_sum(a, row0 + mi);
            for ab in 0..a.bits {
                let plane = a.row_plane(row0 + mi, ab);
                let t = &mut tails[(mi - mt) * MAX_BITS + ab];
                *t = [0u64; CHUNK];
                t[..tail].copy_from_slice(&plane[full..]);
            }
        }
        let mut nt = 0;
        while nt < n {
            let nt_end = (nt + tile_n).min(n);
            for mi in mt..mt_end {
                let c = corr[mi - mt];
                for col in nt..nt_end {
                    let mut total = 0u64;
                    for wb in 0..w.bits {
                        let wplane = w.plane(col, wb);
                        for ab in 0..a.bits {
                            let aplane = a.row_plane(row0 + mi, ab);
                            let t = &tails[(mi - mt) * MAX_BITS + ab];
                            // SAFETY: `aplane` holds `full` (+tail) readable
                            // words, `t` is a CHUNK-word buffer, and
                            // `wplane` holds `plane_stride >= full + CHUNK·
                            // (tail > 0)` words — all in-bounds slices; AVX2
                            // is guaranteed by this fn's target_feature.
                            let cnt = unsafe {
                                dot_plane_pair(
                                    aplane.as_ptr(),
                                    wplane.as_ptr(),
                                    full,
                                    t.as_ptr(),
                                    tail > 0,
                                )
                            };
                            total += cnt << (wb + ab);
                        }
                    }
                    chunk[mi * n + col] = (total as u32 as i32) - c;
                }
            }
            nt = nt_end;
        }
        mt = mt_end;
    }
}

/// Popcount-AND dot of one activation plane against one chunk-padded weight
/// plane: `full` words as whole 256-bit chunks plus an optional zero-padded
/// tail chunk (`a_tail` vs the weight plane's own padding chunk).
#[target_feature(enable = "avx2")]
unsafe fn dot_plane_pair(
    a: *const u64,
    w: *const u64,
    full: usize,
    a_tail: *const u64,
    has_tail: bool,
) -> u64 {
    // SAFETY (whole body): the caller passes `a` with at least `full`
    // readable words, `a_tail` as a CHUNK-word buffer, and `w` with
    // `full` (+CHUNK when `has_tail`) readable words; all loads below stay
    // inside those bounds, and the AVX2 intrinsics are covered by this
    // fn's target_feature contract.
    unsafe {
        #[rustfmt::skip]
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let zero = _mm256_setzero_si256();
        let mut acc = zero; // four u64 lanes
        let mut bytes = zero; // per-byte counts, flushed every FLUSH chunks
        let mut pending = 0usize;
        for j in 0..(full / CHUNK) {
            let av = _mm256_loadu_si256(a.add(j * CHUNK) as *const __m256i);
            let wv = _mm256_loadu_si256(w.add(j * CHUNK) as *const __m256i);
            let x = _mm256_and_si256(av, wv);
            let lo = _mm256_shuffle_epi8(lut, _mm256_and_si256(x, low));
            let hi = _mm256_shuffle_epi8(lut, _mm256_and_si256(_mm256_srli_epi16::<4>(x), low));
            bytes = _mm256_add_epi8(bytes, _mm256_add_epi8(lo, hi));
            pending += 1;
            if pending == FLUSH {
                acc = _mm256_add_epi64(acc, _mm256_sad_epu8(bytes, zero));
                bytes = zero;
                pending = 0;
            }
        }
        if has_tail {
            let av = _mm256_loadu_si256(a_tail as *const __m256i);
            let wv = _mm256_loadu_si256(w.add(full) as *const __m256i);
            let x = _mm256_and_si256(av, wv);
            let lo = _mm256_shuffle_epi8(lut, _mm256_and_si256(x, low));
            let hi = _mm256_shuffle_epi8(lut, _mm256_and_si256(_mm256_srli_epi16::<4>(x), low));
            bytes = _mm256_add_epi8(bytes, _mm256_add_epi8(lo, hi));
            pending += 1;
        }
        if pending > 0 {
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(bytes, zero));
        }
        let mut lanes = [0u64; CHUNK];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        lanes[0] + lanes[1] + lanes[2] + lanes[3]
    }
}

fn gemm_u8i8(a: &[u8], b: &[i8], m: usize, n: usize, k: usize, out: &mut [i32], nthreads: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    threads::par_chunks_rows(out, n, nthreads, |row0, chunk| {
        // SAFETY: registry-gated AVX2 (see `gemm_bit`).
        unsafe { i8_rows_block(a, b, k, n, row0, chunk) }
    });
}

#[target_feature(enable = "avx2")]
unsafe fn i8_rows_block(a: &[u8], b: &[i8], k: usize, n: usize, row0: usize, chunk: &mut [i32]) {
    let kv = k / 16 * 16;
    for (i, orow) in chunk.chunks_mut(n).enumerate() {
        let arow = &a[(row0 + i) * k..(row0 + i + 1) * k];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            // SAFETY: every 16-byte load stays inside `arow`/`brow`
            // (`kk + 16 <= kv <= k`); AVX2 is guaranteed by this fn's
            // target_feature contract (upheld at the registry boundary).
            unsafe {
                let mut accv = _mm256_setzero_si256();
                let mut kk = 0;
                while kk < kv {
                    let av = _mm_loadu_si128(arow.as_ptr().add(kk) as *const __m128i);
                    let bv = _mm_loadu_si128(brow.as_ptr().add(kk) as *const __m128i);
                    let aw = _mm256_cvtepu8_epi16(av);
                    let bw = _mm256_cvtepi8_epi16(bv);
                    accv = _mm256_add_epi32(accv, _mm256_madd_epi16(aw, bw));
                    kk += 16;
                }
                let mut lanes = [0i32; 8];
                _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, accv);
                let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
                s += lanes[4] + lanes[5] + lanes[6] + lanes[7];
                for kk in kv..k {
                    s += arow[kk] as i32 * brow[kk] as i32;
                }
                *o = s;
            }
        }
    }
}

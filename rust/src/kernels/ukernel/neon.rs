//! NEON micro-kernels (aarch64): the paper's VAND + VCNT + accumulate
//! bitserial inner loop on 128-bit q-registers.
//!
//! Structure mirrors the AVX2 entry: AND two packed plane chunks, per-byte
//! popcount with `vcntq_u8` (each byte ≤ 8, so 31 chunks stay < 256 before
//! the `vaddlvq_u8` horizontal flush), weight planes chunk-padded by the
//! `TileN` prepack so every weight load is a whole in-bounds vector, and a
//! zero-padded stack chunk for the activation tail. The int8 path stays on
//! the portable scalar GEMM for now — the SDOT specialization is seeded as
//! a ROADMAP follow-up.

use std::arch::aarch64::*;

use super::{Isa, PackedW, UKernel, UKernelDesc};
use crate::dlrt::graph::qp_qn;
use crate::dlrt::tensor::Packed;
use crate::kernels::bitserial::{row_code_sum, MAX_BITS};
use crate::util::threads;

/// `u64` words per 128-bit chunk.
const CHUNK: usize = 2;
/// Chunks between byte-accumulator flushes (per-byte counts ≤ 8·31 < 256).
const FLUSH: usize = 31;
/// M (activation-row) tile.
const TILE_M: usize = 32;
/// N (output-channel) tile.
const TILE_N: usize = 16;

pub static KERNEL: UKernel = UKernel {
    desc: UKernelDesc { isa: Isa::Neon, tile_m: TILE_M, tile_n: TILE_N, k_unroll: CHUNK },
    gemm_bit,
    gemm_u8i8: crate::kernels::int8::gemm_u8i8_i32,
    gemm_f32: crate::kernels::fp32::gemm_rowmajor_bt,
};

fn gemm_bit(a: &Packed, w: &PackedW, w_bits_signed: usize, out: &mut [i32], nthreads: usize) {
    assert_eq!(a.k, w.k, "reduction dim mismatch");
    assert_eq!(a.words_per_row, w.words_per_row);
    assert_eq!(w.plane_stride % CHUNK, 0, "NEON kernel needs chunk-padded weight planes");
    assert!(a.bits <= MAX_BITS && w.bits <= MAX_BITS);
    let (m, n) = (a.rows, w.rows);
    assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let (_, qn) = qp_qn(w_bits_signed as u8, true);
    threads::par_chunks_rows(out, n, nthreads, |row0, chunk| {
        // SAFETY: this entry is only reachable through the registry, which
        // hands out the NEON kernel after runtime feature detection
        // (`host_supports`), satisfying `bit_rows_block`'s target_feature
        // contract.
        unsafe { bit_rows_block(a, w, qn, row0, chunk, n) }
    });
}

/// One worker's block of whole output rows, tiled `TILE_M`×`TILE_N` like the
/// scalar kernel (exact integer arithmetic — tiling cannot change results).
#[target_feature(enable = "neon")]
unsafe fn bit_rows_block(
    a: &Packed,
    w: &PackedW,
    qn: i32,
    row0: usize,
    chunk: &mut [i32],
    n: usize,
) {
    let rows = chunk.len() / n;
    let nwords = a.words_per_row;
    let full = nwords / CHUNK * CHUNK;
    let tail = nwords - full;
    let mut corr = [0i32; TILE_M];
    let mut tails = [[0u64; CHUNK]; TILE_M * MAX_BITS];
    let mut mt = 0;
    while mt < rows {
        let mt_end = (mt + TILE_M).min(rows);
        for mi in mt..mt_end {
            corr[mi - mt] = qn * row_code_sum(a, row0 + mi);
            for ab in 0..a.bits {
                let plane = a.row_plane(row0 + mi, ab);
                let t = &mut tails[(mi - mt) * MAX_BITS + ab];
                *t = [0u64; CHUNK];
                t[..tail].copy_from_slice(&plane[full..]);
            }
        }
        let mut nt = 0;
        while nt < n {
            let nt_end = (nt + TILE_N).min(n);
            for mi in mt..mt_end {
                let c = corr[mi - mt];
                for col in nt..nt_end {
                    let mut total = 0u64;
                    for wb in 0..w.bits {
                        let wplane = w.plane(col, wb);
                        for ab in 0..a.bits {
                            let aplane = a.row_plane(row0 + mi, ab);
                            let t = &tails[(mi - mt) * MAX_BITS + ab];
                            // SAFETY: `aplane` holds `full` (+tail) readable
                            // words, `t` is a CHUNK-word buffer, and
                            // `wplane` holds `plane_stride >= full + CHUNK·
                            // (tail > 0)` words — all in-bounds slices; NEON
                            // is guaranteed by this fn's target_feature.
                            let cnt = unsafe {
                                dot_plane_pair(
                                    aplane.as_ptr(),
                                    wplane.as_ptr(),
                                    full,
                                    t.as_ptr(),
                                    tail > 0,
                                )
                            };
                            total += cnt << (wb + ab);
                        }
                    }
                    chunk[mi * n + col] = (total as u32 as i32) - c;
                }
            }
            nt = nt_end;
        }
        mt = mt_end;
    }
}

/// Popcount-AND dot of one activation plane against one chunk-padded weight
/// plane (see the AVX2 twin for the accumulation-bound argument).
#[target_feature(enable = "neon")]
unsafe fn dot_plane_pair(
    a: *const u64,
    w: *const u64,
    full: usize,
    a_tail: *const u64,
    has_tail: bool,
) -> u64 {
    // SAFETY (whole body): the caller passes `a` with at least `full`
    // readable words, `a_tail` as a CHUNK-word buffer, and `w` with
    // `full` (+CHUNK when `has_tail`) readable words; all loads below stay
    // inside those bounds, and the NEON intrinsics are covered by this
    // fn's target_feature contract.
    unsafe {
        let mut total = 0u64;
        let mut bytes = vdupq_n_u8(0);
        let mut pending = 0usize;
        for j in 0..(full / CHUNK) {
            let av = vld1q_u64(a.add(j * CHUNK));
            let wv = vld1q_u64(w.add(j * CHUNK));
            let x = vreinterpretq_u8_u64(vandq_u64(av, wv));
            bytes = vaddq_u8(bytes, vcntq_u8(x));
            pending += 1;
            if pending == FLUSH {
                total += vaddlvq_u8(bytes) as u64;
                bytes = vdupq_n_u8(0);
                pending = 0;
            }
        }
        if has_tail {
            let av = vld1q_u64(a_tail);
            let wv = vld1q_u64(w.add(full));
            let x = vreinterpretq_u8_u64(vandq_u64(av, wv));
            bytes = vaddq_u8(bytes, vcntq_u8(x));
            pending += 1;
        }
        if pending > 0 {
            total += vaddlvq_u8(bytes) as u64;
        }
        total
    }
}
